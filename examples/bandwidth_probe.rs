//! Interactive latency/bandwidth probe — the Figure-3 measurement as a
//! stand-alone tool (put and get vs buffer size, with the fitted
//! communication model printed at the end).
//!
//! Usage: `bandwidth_probe [max_mb] [--copy IMPL]`

use posh::bench::{auto_batch, measure};
use posh::mem::copy::CopyImpl;
use posh::model::CostModel;
use posh::pe::{PoshConfig, World};

fn main() -> posh::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_mb: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let imp = args
        .iter()
        .position(|a| a == "--copy")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| CopyImpl::parse(s))
        .unwrap_or(CopyImpl::default_impl());

    let mut cfg = PoshConfig::default();
    cfg.heap_size = (max_mb << 20) + (8 << 20);
    let world = World::threads(2, cfg)?;
    println!("POSH bandwidth probe, copy impl: {}", imp.name());
    println!("{:>12} {:>14} {:>14} {:>14} {:>14}", "size", "put ns", "put Gb/s", "get ns", "get Gb/s");

    let results: Vec<Vec<(usize, f64, f64)>> = world.run_collect(|ctx| {
        let max_bytes = max_mb << 20;
        let buf = ctx.shmalloc_n::<u8>(max_bytes).unwrap();
        let mut out = Vec::new();
        if ctx.my_pe() == 0 {
            let src = vec![0xA5u8; max_bytes];
            let mut dst = vec![0u8; max_bytes];
            let mut size = 8usize;
            while size <= max_bytes {
                let batch = auto_batch(size as f64 / 10.0);
                let mput = measure(size, batch, || {
                    ctx.put_with(imp, buf, &src[..size], 1);
                });
                let mget = measure(size, batch, || {
                    ctx.get_with(imp, &mut dst[..size], buf, 1);
                });
                println!(
                    "{:>12} {:>14.1} {:>14.2} {:>14.1} {:>14.2}",
                    posh::util::fmt_bytes(size),
                    mput.latency_ns(),
                    mput.bandwidth_gbps(),
                    mget.latency_ns(),
                    mget.bandwidth_gbps()
                );
                out.push((size, mput.latency_ns(), mget.latency_ns()));
                size *= 4;
            }
        }
        ctx.barrier_all();
        out
    });

    let samples = &results[0];
    let put_model = CostModel::fit(&samples.iter().map(|&(s, p, _)| (s, p)).collect::<Vec<_>>());
    let get_model = CostModel::fit(&samples.iter().map(|&(s, _, g)| (s, g)).collect::<Vec<_>>());
    println!("\nfitted communication models (paper §1):");
    println!("  put: {put_model}");
    println!("  get: {get_model}");
    Ok(())
}
