/*
 * ring.c — demo input for the §4.2 pre-parser (`oshrun preparse`).
 *
 * A token circulates around the PE ring; each hop is recorded in `trace`.
 * The file-scope objects below are what the pre-parser must lift into the
 * symmetric heap: four statics (ring_value, hops, trace, tag) and one plain
 * global (world_visible_flag). The static *inside* ping() must stay local.
 */
#include <shmem.h>
#include <string.h>

static double ring_value;        /* 8 B, BSS            */
static int hops = 3;             /* 4 B, data segment   */
static double trace[64];         /* 512 B, BSS          */
static long tag;                 /* 8 B, BSS            */
int world_visible_flag;          /* plain global, BSS   */

static void ping(void) {
    static int calls;            /* function-local: NOT lifted */
    calls++;
}

int main(int argc, char **argv) {
    start_pes(0);
    int me = _my_pe();
    int npes = _num_pes();
    if (npes < 2) {
        return 1;
    }
    for (int h = 0; h < hops; h++) {
        ping();
        shmem_double_p(&ring_value, me * 1.0 + h, (me + 1) % npes);
        shmem_barrier_all();
        trace[h % 64] = ring_value;
    }
    tag = (long)me;
    world_visible_flag = 1;
    shmem_barrier_all();
    return 0;
}
