//! NUMA-aware two-level collective schedules ([`AlgoKind::Hierarchical`]).
//!
//! The flat families treat every pair of PEs as equidistant; on a NUMA box
//! they are not — §5's Magi10 (4 sockets) and Pastel (2 sockets) pay a
//! 2–2.5× latency and ~40% bandwidth penalty for every cross-socket hop.
//! The two-level schedules restructure broadcast / reduce / sync so that at
//! most one payload crosses each socket link per direction:
//!
//! * **reduce**: socket-local fan-in on each socket's *leader* (Lemma-1
//!   staging in the leader's heap), one partial per socket crosses to the
//!   root's leader slot, the root combines and fans the result back — to
//!   the other leaders first, who re-broadcast socket-locally.
//! * **broadcast**: the root serves its own socket's members directly and
//!   every other socket's leader once; leaders forward socket-locally.
//! * **sync** ([`crate::pe::TeamBarrierKind::Hierarchical`]): members fan
//!   in on their leader's counter, leaders on the root leader's, and the
//!   release ripples back down through per-cell epoch flags.
//!
//! **Leader election is a pure function, not a protocol.** The job's
//! PE→socket map is blocked (`socket = world_rank / pps`, with `pps` agreed
//! job-wide at world creation — see [`crate::pe::Ctx::pes_per_socket`]) and
//! a team's world ranks are strictly increasing in team rank, so each
//! socket's members form a *contiguous interval* of team indices
//! ([`socket_groups`]). Every member computes the same intervals from the
//! same inputs; the leader is simply the interval's lowest index. No
//! communication, nothing to disagree about — the property suite
//! (`tests/prop_hierarchy.rs`) checks the derived [`descriptor`] is
//! identical on every rank anyway.
//!
//! On a flat map (`pps == 0`, or one socket holding the whole team) there
//! is a single interval and every schedule degenerates to its linear-put
//! shape — correct anywhere, merely not faster. The adaptive selector
//! ([`crate::collectives::Tuning::select`]) only picks these schedules
//! where the two-tier cost model prices them cheaper.
//!
//! Combine order caveat: hierarchical grouping re-associates the reduction
//! (socket partials first). For the wrapping-integer and order-independent
//! operators this is unobservable; for floats it can differ from the flat
//! engines by rounding — the same latitude OpenSHMEM grants any reduction.

use super::reduce::{combine_into, ReduceElem, ReduceOp};
use super::state::ActiveSet;
use crate::pe::Ctx;
use crate::symheap::layout::CollOpTag;
use crate::symheap::SymPtr;
use std::sync::atomic::Ordering;

/// Partition a team's indices into contiguous per-socket intervals under
/// the job's blocked map: members whose world rank lands on the same socket
/// (`world_rank / pps`) share an interval. Returns half-open `(lo, hi)`
/// pairs in ascending team-index order; `pps == 0` (flat) yields the single
/// interval covering the whole team. Pure and deterministic — every member
/// computes the same partition from the same `(set, pps)`.
pub fn socket_groups(set: &ActiveSet, pps: usize) -> Vec<(usize, usize)> {
    if pps == 0 || set.size == 0 {
        return vec![(0, set.size)];
    }
    let mut groups = Vec::new();
    let mut lo = 0usize;
    let mut cur = set.rank_at(0) / pps;
    for i in 1..set.size {
        let s = set.rank_at(i) / pps;
        if s != cur {
            groups.push((lo, i));
            lo = i;
            cur = s;
        }
    }
    groups.push((lo, set.size));
    groups
}

/// The team's socket descriptor, as stamped into
/// [`crate::symheap::layout::TeamCell::socket_desc`]: low 32 bits hold
/// `pps + 1` (so 0 keeps meaning "unstamped"), high 32 bits the group
/// count. A pure function of `(membership, pps)`, so every member stamps
/// the same word — which is what the safe-mode split cross-check and the
/// determinism property test rely on.
pub fn descriptor(set: &ActiveSet, pps: usize) -> u64 {
    let ngroups = socket_groups(set, pps).len() as u64;
    (ngroups << 32) | (pps as u64 + 1)
}

/// Index of the interval containing team index `idx`.
fn group_of(groups: &[(usize, usize)], idx: usize) -> usize {
    groups
        .iter()
        .position(|&(lo, hi)| idx >= lo && idx < hi)
        .expect("team index outside its own socket partition")
}

impl Ctx {
    /// Two-level all-reduce: members deposit in their socket leader's
    /// Lemma-1 staging buffer, one partial per socket crosses to the root,
    /// the result fans back down through the leaders. Counter accounting
    /// per PE: root absorbs `(gsz₀−1) + (ngroups−1)` deposits; a leader
    /// `gsz−1` deposits then 1 root release; a member 1 leader release.
    pub(crate) fn reduce_hier<T: ReduceElem>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nreduce: usize,
        op: ReduceOp,
        set: &ActiveSet,
        idx: usize,
    ) {
        let groups = socket_groups(set, self.pes_per_socket());
        let ngroups = groups.len();
        let g = group_of(&groups, idx);
        let (lo, hi) = groups[g];
        let gsz = hi - lo;
        let gsz0 = groups[0].1 - groups[0].0;
        let bytes = nreduce * std::mem::size_of::<T>();
        let elem = std::mem::size_of::<T>();
        let root_pe = set.root();
        let me = self.my_pe();
        if idx == 0 {
            // Root leader. Staging layout: own group's members first (slot
            // k ← team index k+1), then the other sockets' partials (slot
            // gsz₀−1+g−1 ← group g's leader).
            let slots = (gsz0 - 1) + (ngroups - 1);
            let tmp = self
                .heap()
                .alloc_n::<T>(slots * nreduce)
                .expect("hierarchical root scratch allocation");
            self.coll_publish_buf(tmp);
            self.coll_wait_count(slots as u64);
            self.put_sym(target, me, source, me, nreduce);
            // SAFETY: every depositor signalled after its quiet, so the
            // staging buffer is quiescent; only we touch target.
            unsafe {
                let acc = self.local_mut(target);
                let stage = self.local(tmp);
                for k in 0..slots {
                    combine_into(op, &mut acc[..nreduce], &stage[k * nreduce..(k + 1) * nreduce]);
                }
            }
            self.heap().free(tmp).expect("freeing hierarchical root scratch");
            // Fan out: the other leaders (who re-broadcast socket-locally)
            // and my own socket's members. All of them have entered — their
            // deposits are how we got here.
            for i in (1..gsz0).chain(groups.iter().skip(1).map(|&(glo, _)| glo)) {
                self.put_sym(target, set.rank_at(i), target, me, nreduce);
            }
            self.fence();
            for i in (1..gsz0).chain(groups.iter().skip(1).map(|&(glo, _)| glo)) {
                self.coll_signal(set.rank_at(i));
            }
        } else if idx == lo {
            // Socket leader. Collect my members' contributions into target.
            if gsz > 1 {
                let tmp = self
                    .heap()
                    .alloc_n::<T>((gsz - 1) * nreduce)
                    .expect("hierarchical leader scratch allocation");
                self.coll_publish_buf(tmp);
                self.coll_wait_count((gsz - 1) as u64);
                self.put_sym(target, me, source, me, nreduce);
                // SAFETY: as the root's combine — deposits counted, buffers
                // quiescent, combine in ascending index order.
                unsafe {
                    let acc = self.local_mut(target);
                    let stage = self.local(tmp);
                    for k in 0..gsz - 1 {
                        combine_into(
                            op,
                            &mut acc[..nreduce],
                            &stage[k * nreduce..(k + 1) * nreduce],
                        );
                    }
                }
                self.heap().free(tmp).expect("freeing hierarchical leader scratch");
            } else {
                self.put_sym(target, me, source, me, nreduce);
            }
            // One cross-socket deposit: my socket's partial into the root's
            // leader slot.
            self.coll_check_peer(root_pe, CollOpTag::Reduce, bytes);
            let tmp_off = self.coll_wait_buf(root_pe);
            let slot: SymPtr<T> =
                SymPtr::from_raw(tmp_off + ((gsz0 - 1) + (g - 1)) * nreduce * elem, nreduce);
            self.put_sym(slot, root_pe, target, me, nreduce);
            self.quiet();
            self.coll_signal(root_pe);
            // The root writes the final result straight into our target
            // (safe: it only writes after our signal, and we only read
            // target again after its release signal below).
            self.coll_wait_count((gsz - 1) as u64 + 1);
            // Re-broadcast socket-locally.
            for i in lo + 1..hi {
                self.put_sym(target, set.rank_at(i), target, me, nreduce);
            }
            self.fence();
            for i in lo + 1..hi {
                self.coll_signal(set.rank_at(i));
            }
        } else {
            // Member: deposit in my leader's staging buffer, await the
            // result in target.
            let leader_pe = set.rank_at(lo);
            self.coll_check_peer(leader_pe, CollOpTag::Reduce, bytes);
            let tmp_off = self.coll_wait_buf(leader_pe);
            let slot: SymPtr<T> =
                SymPtr::from_raw(tmp_off + (idx - lo - 1) * nreduce * elem, nreduce);
            self.put_sym(slot, leader_pe, source, me, nreduce);
            self.quiet();
            self.coll_signal(leader_pe);
            self.coll_wait_count(1);
        }
    }

    /// Two-level broadcast: the root serves its own socket's members
    /// directly (that socket's leader included — it is a plain member
    /// here) and every other socket's leader once; those leaders forward
    /// socket-locally. The root's own `target` is not written (the
    /// OpenSHMEM broadcast contract the flat engines keep too).
    pub(crate) fn bcast_hier<T: Copy>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nelems: usize,
        root_idx: usize,
        set: &ActiveSet,
        idx: usize,
    ) {
        let groups = socket_groups(set, self.pes_per_socket());
        let g = group_of(&groups, idx);
        let rg = group_of(&groups, root_idx);
        let (lo, hi) = groups[g];
        let bytes = nelems * std::mem::size_of::<T>();
        let me = self.my_pe();
        if idx == root_idx {
            // Everyone this PE serves: own socket's other members, plus
            // each other socket's leader.
            let (rlo, rhi) = groups[rg];
            let served: Vec<usize> = (rlo..rhi)
                .filter(|&i| i != root_idx)
                .chain(
                    groups
                        .iter()
                        .enumerate()
                        .filter(|&(gi, _)| gi != rg)
                        .map(|(_, &(glo, _))| glo),
                )
                .collect();
            for &i in &served {
                let pe = set.rank_at(i);
                // §4.5.2: never write a member's target before it enters.
                self.coll_wait_entered(pe, CollOpTag::Broadcast);
                self.coll_check_peer(pe, CollOpTag::Broadcast, bytes);
                self.put_sym(target, pe, source, me, nelems);
            }
            self.fence();
            for &i in &served {
                self.coll_signal(set.rank_at(i));
            }
        } else if g != rg && idx == lo {
            // Leader of a socket the root is not on: one cross-socket
            // arrival, then forward from target socket-locally.
            self.coll_wait_count(1);
            for i in lo + 1..hi {
                let pe = set.rank_at(i);
                self.coll_wait_entered(pe, CollOpTag::Broadcast);
                self.coll_check_peer(pe, CollOpTag::Broadcast, bytes);
                self.put_sym(target, pe, target, me, nelems);
            }
            self.fence();
            for i in lo + 1..hi {
                self.coll_signal(set.rank_at(i));
            }
        } else {
            // Plain member (the root-socket leader lands here too): exactly
            // one arrival — from the root or from my socket's leader.
            self.coll_wait_count(1);
        }
    }

    /// Two-level team sync over a reserved slot's cells: members bump their
    /// leader's `sync_count`, leaders bump the root leader's, and the
    /// release ripples back down through each cell's `sync_flags[0]` epoch
    /// word. The counters accumulate monotonically (no resets): after `e`
    /// syncs a leader of `k` arrivals-per-epoch has seen exactly `e·k`, so
    /// the wait target is a pure function of the epoch — a fast peer one
    /// epoch ahead is absorbed by the `>=`, like the dissemination engine's
    /// mailboxes. Safe to share `sync_flags[0]` with dissemination because
    /// a team's engine is fixed job-wide (config or per-size tuning).
    pub(crate) fn team_sync_hier(&self, set: &ActiveSet, slot: usize) {
        let groups = socket_groups(set, self.pes_per_socket());
        let ngroups = groups.len();
        let me = self.my_pe();
        let idx = set.index_of(me).expect("hierarchical sync by a non-member");
        let g = group_of(&groups, idx);
        let (lo, hi) = groups[g];
        let gsz = hi - lo;
        let gsz0 = groups[0].1 - groups[0].0;
        let root_pe = set.root();
        let my_cell = &self.header_of(me).teams[slot];
        // Only this PE writes its own sync_epoch on this slot.
        let e = my_cell.sync_epoch.load(Ordering::Relaxed) + 1;
        if idx == 0 {
            // Root leader: own members + the other leaders arrive here.
            let per_epoch = ((gsz0 - 1) + (ngroups - 1)) as u64;
            self.spin_wait(|| my_cell.sync_count.load(Ordering::Acquire) >= e * per_epoch);
            my_cell.sync_flags[0].store(e, Ordering::Release);
            self.record_sync_rounds((gsz0 - 1) + (ngroups - 1));
        } else if idx == lo {
            // Socket leader: absorb my members, arrive at the root, wait
            // for its release, release my members.
            self.spin_wait(|| {
                my_cell.sync_count.load(Ordering::Acquire) >= e * (gsz - 1) as u64
            });
            self.header_of(root_pe).teams[slot].sync_count.fetch_add(1, Ordering::AcqRel);
            let root_cell = &self.header_of(root_pe).teams[slot];
            self.spin_wait(|| root_cell.sync_flags[0].load(Ordering::Acquire) >= e);
            my_cell.sync_flags[0].store(e, Ordering::Release);
            self.record_sync_rounds(gsz + 1);
        } else {
            // Member: arrive at my leader, wait for its release.
            let leader = &self.header_of(set.rank_at(lo)).teams[slot];
            leader.sync_count.fetch_add(1, Ordering::AcqRel);
            self.spin_wait(|| leader.sync_flags[0].load(Ordering::Acquire) >= e);
            self.record_sync_rounds(2);
        }
        my_cell.sync_epoch.store(e, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(start: usize, stride: usize, size: usize) -> ActiveSet {
        ActiveSet::strided(start, stride, size, 64)
    }

    #[test]
    fn groups_are_contiguous_and_cover() {
        for (s, pps, want) in [
            // Blocked world team, 2 per socket.
            (set(0, 1, 8), 2, vec![(0, 2), (2, 4), (4, 6), (6, 8)]),
            // Flat map: one interval.
            (set(0, 1, 8), 0, vec![(0, 8)]),
            // Stride 2 over 4-per-socket: pairs per socket.
            (set(0, 2, 6), 4, vec![(0, 2), (2, 4), (4, 6)]),
            // Stride spanning sockets: singleton groups.
            (set(1, 4, 3), 2, vec![(0, 1), (1, 2), (2, 3)]),
            // Offset start: first group shorter than pps.
            (set(1, 1, 5), 2, vec![(0, 1), (1, 3), (3, 5)]),
        ] {
            let got = socket_groups(&s, pps);
            assert_eq!(got, want, "{s:?} pps={pps}");
            // Invariants: cover 0..size, contiguous, non-empty.
            assert_eq!(got[0].0, 0);
            assert_eq!(got.last().unwrap().1, s.size);
            for w in got.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            assert!(got.iter().all(|&(lo, hi)| hi > lo));
        }
    }

    #[test]
    fn descriptor_encodes_shape() {
        let s = set(0, 1, 8);
        let d = descriptor(&s, 2);
        assert_eq!(d >> 32, 4, "4 groups of 2");
        assert_eq!(d & 0xFFFF_FFFF, 3, "pps+1");
        // Flat: one group, pps+1 == 1; still nonzero (0 means unstamped).
        let f = descriptor(&s, 0);
        assert_eq!(f >> 32, 1);
        assert_eq!(f & 0xFFFF_FFFF, 1);
        assert_ne!(f, 0);
    }

    #[test]
    fn group_of_finds_every_index() {
        let s = set(0, 1, 7);
        let groups = socket_groups(&s, 3); // (0,3) (3,6) (6,7)
        for (i, want) in [(0, 0), (2, 0), (3, 1), (5, 1), (6, 2)] {
            assert_eq!(group_of(&groups, i), want);
        }
    }
}
