//! Cross-module integration tests (thread mode): the full API surface used
//! together, larger worlds, stress mixes, and the PJRT runtime over real
//! artifacts when `make artifacts` has run.

use posh::collectives::{AlgoKind, ReduceOp};
use posh::pe::{BarrierKind, PoshConfig, World};
use posh::util::prng::Rng;

/// A multi-phase pipeline: scatter (iput) → transform → ring-shift →
/// allreduce → gather, with every phase checked.
#[test]
fn pipeline_across_modules() {
    let n = 4;
    let cols = 64usize;
    let w = World::threads(n, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let me = ctx.my_pe();
        let mat = ctx.shmalloc_n::<i64>(n * cols).unwrap();
        let vsum = ctx.shmalloc_n::<i64>(cols).unwrap();
        let tmp = ctx.shmalloc_n::<i64>(cols).unwrap();

        // Phase 1: everyone scatters its row into everyone's matrix (iput
        // with stride 1 at row offset).
        let row: Vec<i64> = (0..cols).map(|j| (me * 100 + j) as i64).collect();
        for pe in 0..n {
            ctx.iput(mat.slice(me * cols, cols), &row, 1, 1, cols, pe);
        }
        ctx.barrier_all();

        // Phase 2: local column sums.
        let mut col_sums = vec![0i64; cols];
        unsafe {
            let m = ctx.local(mat);
            for r in 0..n {
                for j in 0..cols {
                    col_sums[j] += m[r * cols + j];
                }
            }
        }
        let want: Vec<i64> = (0..cols)
            .map(|j| (0..n).map(|r| (r * 100 + j) as i64).sum())
            .collect();
        assert_eq!(col_sums, want);

        // Phase 3: ring-shift the sums (put to next PE).
        ctx.put(tmp, &col_sums, (me + 1) % n);
        ctx.barrier_all();

        // Phase 4: allreduce the shifted vectors — same totals on all.
        unsafe {
            ctx.local_mut(vsum).copy_from_slice(ctx.local(tmp));
        }
        ctx.barrier_all();
        let team = ctx.team_world();
        ctx.reduce_to_all(vsum, vsum, cols, ReduceOp::Max, &team);
        // Every PE's shifted vector is identical, so max == the vector.
        assert_eq!(unsafe { ctx.local(vsum).to_vec() }, want);
        ctx.barrier_all();
    });
}

/// Larger world: 12 PEs, all barrier kinds, all algorithms, one pass each.
#[test]
fn twelve_pes_all_algorithms() {
    for barrier in [BarrierKind::Dissemination, BarrierKind::Central] {
        for algo in AlgoKind::all() {
            let mut cfg = PoshConfig::small();
            cfg.barrier = barrier;
            cfg.coll_algo = Some(algo);
            let w = World::threads(12, cfg).unwrap();
            w.run(|ctx| {
                let team = ctx.team_world();
                let src = ctx.shmalloc_n::<i32>(8).unwrap();
                let dst = ctx.shmalloc_n::<i32>(8).unwrap();
                unsafe {
                    ctx.local_mut(src).fill(ctx.my_pe() as i32 + 1);
                }
                ctx.barrier_all();
                ctx.reduce_to_all(dst, src, 8, ReduceOp::Sum, &team);
                assert_eq!(unsafe { ctx.local(dst)[0] }, (1..=12).sum::<i32>());
                ctx.barrier_all();
            });
        }
    }
}

/// Concurrent disjoint teams run collectives simultaneously.
#[test]
fn disjoint_teams_run_concurrently() {
    let n = 6;
    let w = World::threads(n, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let world = ctx.team_world();
        let evens = world.split_strided(0, 2, 3); // 0, 2, 4
        let odds = world.split_strided(1, 2, 3); // 1, 3, 5
        let mine = if ctx.my_pe() % 2 == 0 { &evens } else { &odds };
        let mine = mine.as_ref().unwrap();
        let src = ctx.shmalloc_n::<i64>(16).unwrap();
        let dst = ctx.shmalloc_n::<i64>(16).unwrap();
        for round in 0..30 {
            unsafe {
                ctx.local_mut(src).fill((ctx.my_pe() + round) as i64);
            }
            ctx.reduce_to_all(dst, src, 16, ReduceOp::Sum, mine);
            let want: i64 = mine.ranks().map(|r| (r + round) as i64).sum();
            assert_eq!(unsafe { ctx.local(dst)[0] }, want, "round {round}");
        }
        ctx.barrier_all();
        for t in [evens, odds].into_iter().flatten() {
            t.destroy();
        }
        ctx.barrier_all();
    });
}

/// Randomised stress mix: interleave p2p, atomics, locks and collectives
/// from a deterministic script (seeds shared across PEs).
#[test]
fn stress_mix() {
    let n = 4;
    let w = World::threads(n, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let team = ctx.team_world();
        let counter = ctx.shmalloc_n::<i64>(1).unwrap();
        let lock = ctx.shmalloc_n::<i64>(1).unwrap();
        let buf = ctx.shmalloc_n::<i64>(64).unwrap();
        let red_src = ctx.shmalloc_n::<i64>(4).unwrap();
        let red_dst = ctx.shmalloc_n::<i64>(4).unwrap();
        // Same script on every PE (collective ops must line up).
        let mut script_rng = Rng::new(0x57AE55);
        let mut locked_adds = 0i64;
        let mut atomic_adds = 0i64;
        for _ in 0..120 {
            match script_rng.next_below(4) {
                0 => {
                    ctx.atomic_add(counter, 1, 0);
                    atomic_adds += 1;
                }
                1 => {
                    ctx.with_lock(lock, || {
                        let v = ctx.get_one(counter, 0);
                        ctx.put_one(counter, v + 1, 0);
                    });
                    locked_adds += 1;
                }
                2 => {
                    unsafe { ctx.local_mut(red_src).fill(ctx.my_pe() as i64) };
                    ctx.reduce_to_all(red_dst, red_src, 4, ReduceOp::Sum, &team);
                    assert_eq!(
                        unsafe { ctx.local(red_dst)[0] },
                        (0..n as i64).sum::<i64>()
                    );
                }
                _ => {
                    ctx.put_one(buf.at(ctx.my_pe()), 1, (ctx.my_pe() + 1) % n);
                    ctx.barrier_all();
                }
            }
        }
        ctx.barrier_all();
        if ctx.my_pe() == 0 {
            let total = ctx.get_one(counter, 0);
            assert_eq!(total, (locked_adds + atomic_adds) * n as i64);
        }
        ctx.barrier_all();
    });
}

/// PJRT runtime + trainer over the real artifacts (skips cleanly when
/// `make artifacts` has not run — e.g. a bare `cargo test`). Needs the
/// `xla` feature: without it the trainer is not compiled in at all.
#[cfg(feature = "xla")]
#[test]
fn trainer_over_artifacts_if_present() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    }
    use posh::coordinator::{Trainer, TrainerConfig};
    let n = 2;
    // Params + two gradient buffers (~1.7 MB each) need a roomier heap than
    // PoshConfig::small() provides.
    let mut wcfg = PoshConfig::small();
    wcfg.heap_size = 32 << 20;
    let w = World::threads(n, wcfg).unwrap();
    let cfg = TrainerConfig {
        steps: 8,
        log_every: 0,
        ..Default::default()
    };
    let reports = w.run_collect(move |ctx| Trainer::new(cfg.clone()).run(&ctx).unwrap());
    // Losses agree across PEs (they reduced the same numbers).
    assert!((reports[0].final_loss - reports[1].final_loss).abs() < 1e-9);
    assert!(reports[0].first_loss.is_finite());
    assert!(reports[0].param_count > 100_000);
    // PE 0 carries the log.
    assert_eq!(reports[0].log.steps.len(), 8);
}

/// The grad_reduce Pallas artifact agrees with the Rust-side reduction.
/// Needs the `xla` feature (PJRT execution).
#[cfg(feature = "xla")]
#[test]
fn pallas_reduce_artifact_matches_posh_reduce() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return;
    }
    use posh::runtime::{artifact::cached, Manifest};
    let m = Manifest::load("artifacts").unwrap();
    let shards = m.int("reduce_shards").unwrap() as usize;
    let chunk = m.int("reduce_chunk").unwrap() as usize;
    let art = cached(m.artifact_path("grad_reduce").unwrap()).unwrap();

    let mut rng = Rng::new(77);
    let parts: Vec<f32> = (0..shards * chunk).map(|_| rng.f32() - 0.5).collect();
    let lit = xla::Literal::vec1(&parts[..])
        .reshape(&[shards as i64, chunk as i64])
        .unwrap();
    let out = art.run_f32(&[lit]).unwrap();
    assert_eq!(out[0].len(), chunk);

    // POSH-side oracle: reduce the same shards through the collective layer.
    let w = World::threads(shards.min(8), PoshConfig::small()).unwrap();
    // (The kernel sums `shards` rows; emulate with plain arithmetic here —
    // the collective equivalence is covered by prop_collectives.)
    for j in 0..chunk {
        let want: f32 = (0..shards).map(|s| parts[s * chunk + j]).sum();
        assert!(
            (out[0][j] - want).abs() <= 1e-4 * want.abs().max(1.0),
            "elem {j}: {} vs {want}",
            out[0][j]
        );
    }
    drop(w);
}
