//! One loaded artifact: HLO text → compiled PJRT executable.
//!
//! Artifacts are **thread-local** (the `xla` crate's executables are
//! `Rc`-based): each PE thread — or PE process — compiles its own copy at
//! start-up, mirroring process mode where that is the only option. The
//! request path never compiles.

use super::client::with_client;
use crate::Result;
use anyhow::Context;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

/// A compiled computation ready to execute. Not `Send` — keep it on the
/// thread that loaded it.
pub struct Artifact {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Wall time spent compiling (a one-time start-up cost, reported by the
    /// examples; never on the request path).
    pub compile_time: std::time::Duration,
}

impl Artifact {
    /// Load an `.hlo.txt` file and compile it on this thread's client.
    pub fn load(path: impl AsRef<Path>) -> Result<Artifact> {
        let path = path.as_ref();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path is not UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = with_client(|c| Ok(c.compile(&comp)?))
            .with_context(|| format!("compiling {path:?}"))?;
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "artifact".into());
        Ok(Artifact { name, exe, compile_time: t0.elapsed() })
    }

    /// Artifact name (file stem).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with the given inputs. The AOT pipeline lowers every function
    /// with `return_tuple=True`, so the single output literal is a tuple;
    /// this returns its elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let first = out
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .context("executable returned no output")?;
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute and pull every output as `Vec<f32>` (convenience).
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?
            .into_iter()
            .map(|l| Ok(l.to_vec::<f32>()?))
            .collect()
    }
}

thread_local! {
    static CACHE: RefCell<HashMap<PathBuf, Rc<Artifact>>> = RefCell::new(HashMap::new());
}

/// Get-or-load from this thread's artifact cache: one compile per path per
/// thread, exactly like the paper's start-up-time remote-heap table.
pub fn cached(path: impl AsRef<Path>) -> Result<Rc<Artifact>> {
    let path = path.as_ref().to_path_buf();
    CACHE.with(|c| {
        let mut g = c.borrow_mut();
        if let Some(a) = g.get(&path) {
            return Ok(Rc::clone(a));
        }
        let a = Rc::new(Artifact::load(&path)?);
        g.insert(path, Rc::clone(&a));
        Ok(a)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// HLO text for f(x, y) = (x + y,) over f32[4] — hand-written, so the
    /// runtime tests run without `make artifacts`.
    const ADD_HLO: &str = r#"
HloModule add4, entry_computation_layout={(f32[4]{0}, f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  y = f32[4]{0} parameter(1)
  s = f32[4]{0} add(x, y)
  ROOT t = (f32[4]{0}) tuple(s)
}
"#;

    fn write_add_hlo() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("posh_art_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("add4.hlo.txt");
        std::fs::write(&p, ADD_HLO).unwrap();
        p
    }

    #[test]
    fn load_run_roundtrip() {
        let p = write_add_hlo();
        let art = Artifact::load(&p).unwrap();
        assert_eq!(art.name(), "add4.hlo");
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]);
        let y = xla::Literal::vec1(&[10f32, 20., 30., 40.]);
        let out = art.run_f32(&[x, y]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![11f32, 22., 33., 44.]);
    }

    #[test]
    fn cache_compiles_once_per_thread() {
        let p = write_add_hlo();
        let a1 = cached(&p).unwrap();
        let a2 = cached(&p).unwrap();
        assert!(Rc::ptr_eq(&a1, &a2));
    }

    #[test]
    fn missing_artifact_helpful_error() {
        let e = match Artifact::load("/no/such/file.hlo.txt") {
            Err(e) => e,
            Ok(_) => panic!("must fail"),
        };
        assert!(e.to_string().contains("parsing HLO text"));
    }
}
