//! Anonymous in-process segments (thread-mode worlds, tests, benches).

use super::Segment;
use crate::Result;
use anyhow::bail;

/// A private anonymous `mmap` region. Page-aligned like the POSIX variant so
/// both modes see identical alignment behaviour (Fact 1 depends on heap bases
/// being equally aligned everywhere).
pub struct InProcSegment {
    base: *mut u8,
    len: usize,
}

// SAFETY: the mapping is plain memory; cross-thread access discipline is the
// SHMEM memory model's job (explicit sync via barrier/fence/atomics).
unsafe impl Send for InProcSegment {}
unsafe impl Sync for InProcSegment {}

impl InProcSegment {
    /// Map `len` bytes (rounded up to a page) of zeroed anonymous memory.
    pub fn new(len: usize) -> Result<Self> {
        if len == 0 {
            bail!("segment length must be > 0");
        }
        let page = page_size();
        let len = crate::util::align_up(len, page);
        // SAFETY: standard anonymous mapping.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!(
                "mmap({} bytes) failed: {}",
                len,
                std::io::Error::last_os_error()
            );
        }
        Ok(Self {
            base: ptr as *mut u8,
            len,
        })
    }
}

impl Segment for InProcSegment {
    fn base(&self) -> *mut u8 {
        self.base
    }
    fn len(&self) -> usize {
        self.len
    }
}

impl Drop for InProcSegment {
    fn drop(&mut self) {
        // SAFETY: we own the mapping.
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.len);
        }
    }
}

/// System page size (cached).
pub fn page_size() -> usize {
    use std::sync::OnceLock;
    static PAGE: OnceLock<usize> = OnceLock::new();
    *PAGE.get_or_init(|| {
        // SAFETY: sysconf is always safe to call.
        let v = unsafe { libc::sysconf(libc::_SC_PAGESIZE) };
        if v <= 0 {
            4096
        } else {
            v as usize
        }
    })
}

/// Check that `ptr` lies within the segment.
pub fn contains(seg: &dyn Segment, ptr: *const u8) -> bool {
    let b = seg.base() as usize;
    let p = ptr as usize;
    p >= b && p < b + seg.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_and_zeroes() {
        let seg = InProcSegment::new(10_000).unwrap();
        assert!(seg.len() >= 10_000);
        assert_eq!(seg.len() % page_size(), 0);
        // zero-initialised
        let bytes = unsafe { seg.bytes() };
        assert!(bytes.iter().all(|&b| b == 0));
    }

    #[test]
    fn writable() {
        let seg = InProcSegment::new(4096).unwrap();
        unsafe {
            *seg.base() = 0xAB;
            *seg.base().add(seg.len() - 1) = 0xCD;
            assert_eq!(*seg.base(), 0xAB);
            assert_eq!(*seg.base().add(seg.len() - 1), 0xCD);
        }
    }

    #[test]
    fn zero_len_rejected() {
        assert!(InProcSegment::new(0).is_err());
    }

    #[test]
    fn page_aligned_base() {
        let seg = InProcSegment::new(1).unwrap();
        assert_eq!(seg.base() as usize % page_size(), 0);
    }

    #[test]
    fn contains_checks_bounds() {
        let seg = InProcSegment::new(4096).unwrap();
        assert!(contains(&seg, seg.base()));
        assert!(contains(&seg, unsafe { seg.base().add(seg.len() - 1) }));
        assert!(!contains(&seg, unsafe { seg.base().add(seg.len()) }));
    }
}
