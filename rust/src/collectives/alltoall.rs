//! All-to-all exchange (`shmem_alltoall` — an OpenSHMEM 1.3 collective,
//! shipped here as an extension; the paper's conclusion lists collective
//! algorithm work as a perspective).
//!
//! Member *i*'s `source` block *j* lands in member *j*'s `target` block *i*:
//! pure one-sided puts, each member pushes `size` blocks and receives
//! `size − 1` signals.

use super::tuning::CollOp;
use crate::pe::Ctx;
use crate::symheap::layout::CollOpTag;
use crate::symheap::SymPtr;
use crate::team::Team;

impl Ctx {
    /// `shmem_alltoall`: exchange `nelems`-element blocks between all
    /// members of the team.
    pub fn alltoall<T: Copy>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nelems: usize,
        team: &Team,
    ) {
        let set = &team.set;
        let bytes = nelems * std::mem::size_of::<T>();
        let idx = self.coll_enter(team, CollOpTag::Alltoall, bytes);
        // Routed through the engine; alltoall has a single (put-based)
        // protocol today, so the resolution records the decision without
        // branching.
        let _ = self.coll_algo_for(CollOp::Alltoall, set.size, bytes);
        if self.config().safe {
            assert!(source.len() >= nelems * set.size, "alltoall source too small");
            assert!(target.len() >= nelems * set.size, "alltoall target too small");
        }
        // Push block j to member j, into its block idx.
        for j in 0..set.size {
            let pe = set.rank_at(j);
            // §4.5.2: never write a member's target before it enters.
            self.coll_wait_entered(pe, CollOpTag::Alltoall);
            self.coll_check_peer(pe, CollOpTag::Alltoall, bytes);
            let src = source.slice(j * nelems, nelems);
            let dst = target.slice(idx * nelems, nelems);
            self.put_sym(dst, pe, src, self.my_pe(), nelems);
        }
        self.fence();
        for j in 0..set.size {
            let pe = set.rank_at(j);
            if pe != self.my_pe() {
                self.coll_signal(pe);
            }
        }
        self.coll_wait_count((set.size - 1) as u64);
        self.coll_exit(team);
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn alltoall_transpose() {
        let n = 4;
        let nelems = 3;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<u32>(n * nelems).unwrap();
            let dst = ctx.shmalloc_n::<u32>(n * nelems).unwrap();
            // src block j element k = me*10000 + j*100 + k
            unsafe {
                for (i, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    let (j, k) = (i / nelems, i % nelems);
                    *s = (ctx.my_pe() * 10000 + j * 100 + k) as u32;
                }
            }
            ctx.barrier_all();
            ctx.alltoall(dst, src, nelems, &team);
            // dst block i element k must be  i*10000 + me*100 + k
            let local = unsafe { ctx.local(dst) };
            for i in 0..n {
                for k in 0..nelems {
                    assert_eq!(
                        local[i * nelems + k],
                        (i * 10000 + ctx.my_pe() * 100 + k) as u32,
                        "from {i} elem {k}"
                    );
                }
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn alltoall_two_pes_swap() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<i64>(2).unwrap();
            let dst = ctx.shmalloc_n::<i64>(2).unwrap();
            unsafe {
                ctx.local_mut(src)
                    .copy_from_slice(&[ctx.my_pe() as i64 * 2, ctx.my_pe() as i64 * 2 + 1]);
            }
            ctx.barrier_all();
            ctx.alltoall(dst, src, 1, &team);
            let local = unsafe { ctx.local(dst) };
            // dst[0] = PE0's block me, dst[1] = PE1's block me.
            assert_eq!(local[0], ctx.my_pe() as i64);
            assert_eq!(local[1], 2 + ctx.my_pe() as i64);
            ctx.barrier_all();
        });
    }

    #[test]
    fn alltoall_repeated() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<u64>(3).unwrap();
            let dst = ctx.shmalloc_n::<u64>(3).unwrap();
            for round in 0..60u64 {
                unsafe {
                    for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                        *s = round * 100 + (ctx.my_pe() * 10 + j) as u64;
                    }
                }
                ctx.alltoall(dst, src, 1, &team);
                let local = unsafe { ctx.local(dst) };
                for i in 0..3 {
                    assert_eq!(local[i], round * 100 + (i * 10 + ctx.my_pe()) as u64);
                }
            }
        });
    }
}
