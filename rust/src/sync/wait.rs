//! `shmem_wait` / `shmem_wait_until`: block until a *local* symmetric
//! variable satisfies a condition — the receiver half of the flag-passing
//! idiom one-sided programs use instead of receives.

use crate::pe::Ctx;
use crate::symheap::SymPtr;
use std::sync::atomic::Ordering;

/// Comparison operators of `shmem_*_wait_until` (SHMEM_CMP_*).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmpOp {
    /// Equal.
    Eq,
    /// Not equal.
    Ne,
    /// Greater than.
    Gt,
    /// Greater or equal.
    Ge,
    /// Less than.
    Lt,
    /// Less or equal.
    Le,
}

impl CmpOp {
    /// Evaluate the comparison.
    #[inline]
    pub fn eval<T: PartialOrd>(&self, lhs: T, rhs: T) -> bool {
        match self {
            CmpOp::Eq => lhs == rhs,
            CmpOp::Ne => lhs != rhs,
            CmpOp::Gt => lhs > rhs,
            CmpOp::Ge => lhs >= rhs,
            CmpOp::Lt => lhs < rhs,
            CmpOp::Le => lhs <= rhs,
        }
    }
}

/// Integer types on which remote PEs may signal and local PEs may wait.
/// The load must be atomic because the writer is another PE.
pub trait WaitableInt: Copy + PartialOrd + 'static {
    /// Atomically load the value at `ptr`.
    ///
    /// # Safety
    /// `ptr` must be valid and naturally aligned.
    unsafe fn atomic_load(ptr: *const Self) -> Self;
}

macro_rules! impl_waitable {
    ($($t:ty => $a:ty),+ $(,)?) => {$(
        impl WaitableInt for $t {
            #[inline]
            unsafe fn atomic_load(ptr: *const Self) -> Self {
                (&*(ptr as *const $a)).load(Ordering::Acquire) as $t
            }
        }
    )+};
}

impl_waitable!(
    i32 => std::sync::atomic::AtomicI32,
    u32 => std::sync::atomic::AtomicU32,
    i64 => std::sync::atomic::AtomicI64,
    u64 => std::sync::atomic::AtomicU64,
    isize => std::sync::atomic::AtomicIsize,
    usize => std::sync::atomic::AtomicUsize,
);

impl Ctx {
    /// `shmem_wait_until`: spin until `*ptr OP value` on the **local** copy
    /// of the symmetric variable.
    pub fn wait_until<T: WaitableInt>(&self, ptr: SymPtr<T>, op: CmpOp, value: T) {
        // SAFETY: handle is in-bounds; loads are atomic.
        let addr = unsafe { self.remote_addr(ptr, self.my_pe()) } as *const T;
        self.spin_wait(|| op.eval(unsafe { T::atomic_load(addr) }, value));
    }

    /// `shmem_wait`: spin until the variable *changes from* `value`.
    pub fn wait<T: WaitableInt>(&self, ptr: SymPtr<T>, value: T) {
        self.wait_until(ptr, CmpOp::Ne, value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};

    #[test]
    fn cmp_ops() {
        assert!(CmpOp::Eq.eval(3, 3));
        assert!(CmpOp::Ne.eval(3, 4));
        assert!(CmpOp::Gt.eval(5, 4));
        assert!(CmpOp::Ge.eval(4, 4));
        assert!(CmpOp::Lt.eval(3, 4));
        assert!(CmpOp::Le.eval(4, 4));
        assert!(!CmpOp::Gt.eval(4, 4));
    }

    #[test]
    fn wait_until_released_by_remote_put() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let flag = ctx.shmalloc_n::<i64>(1).unwrap();
            if ctx.my_pe() == 0 {
                // Let PE1 get into the wait first (best effort), then signal.
                std::thread::sleep(std::time::Duration::from_millis(5));
                ctx.put_one(flag, 42, 1);
            } else {
                ctx.wait_until(flag, CmpOp::Eq, 42);
                assert_eq!(ctx.get_one(flag, 1), 42);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn wait_is_change_from() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let flag = ctx.shmalloc_n::<u64>(1).unwrap();
            if ctx.my_pe() == 0 {
                ctx.put_one(flag, 7, 1);
            } else {
                ctx.wait(flag, 0); // waits for "!= 0"
                assert_eq!(ctx.get_one(flag, 1), 7);
            }
            ctx.barrier_all();
        });
    }
}
