//! Property tests for the paper's memory-model theorems.
//!
//! * **Fact 1** — identical allocation sequences produce identical offsets
//!   on every PE, for arbitrary random alloc/align/free/realloc programs.
//! * **Corollary 1** — the address-translation formula resolves to the same
//!   cell the handle resolves to, for random handles on random heaps.
//! * **Lemma 1** — non-symmetric temporaries inside a collective leave the
//!   heaps byte-symmetric after the collective completes.

use posh::collectives::{AlgoKind, ReduceOp};
use posh::pe::{PoshConfig, World};
use posh::symheap::handle::translate;
use posh::util::quickcheck::{forall, Gen};

/// Random symmetric alloc/free program, mirrored on all PEs ⇒ same handles.
#[test]
fn fact1_random_symmetric_programs() {
    forall("fact1", 30, |g: &mut Gen| {
        let n_pes = g.usize_in(1..5);
        // Script of operations, generated once, replayed by every PE.
        #[derive(Clone)]
        enum Op {
            Alloc { size: usize, align: usize },
            Free { idx: usize },
            Realloc { idx: usize, count: usize },
        }
        let n_ops = g.usize_in(1..25);
        let mut script = Vec::new();
        let mut live = 0usize;
        for _ in 0..n_ops {
            if live > 0 && g.bool(0.3) {
                script.push(Op::Free { idx: g.usize_in(0..live) });
                live -= 1;
            } else if live > 0 && g.bool(0.2) {
                script.push(Op::Realloc { idx: g.usize_in(0..live), count: g.usize_in(1..2000) });
            } else {
                script.push(Op::Alloc {
                    size: g.usize_in(1..4000),
                    align: 1 << g.usize_in(0..8),
                });
                live += 1;
            }
        }
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let script = std::sync::Arc::new(script);
        let offsets = w.run_collect({
            let script = std::sync::Arc::clone(&script);
            move |ctx| {
                let mut handles = Vec::new();
                let mut trace = Vec::new();
                for op in script.iter() {
                    match op {
                        Op::Alloc { size, align } => {
                            let p = ctx.shmemalign_n::<u8>(*align, *size).unwrap();
                            trace.push(p.offset());
                            handles.push(p);
                        }
                        Op::Free { idx } => {
                            let p = handles.remove(*idx);
                            ctx.shfree(p).unwrap();
                        }
                        Op::Realloc { idx, count } => {
                            let p = handles[*idx];
                            let np = ctx.shrealloc(p, *count).unwrap();
                            trace.push(np.offset());
                            handles[*idx] = np;
                        }
                    }
                }
                trace.push(ctx.heap().journal_hash() as usize);
                trace
            }
        });
        for pe in 1..n_pes {
            if offsets[pe] != offsets[0] {
                return Err(format!(
                    "Fact 1 violated with {n_pes} PEs: PE {pe} trace {:?} != PE 0 trace {:?}",
                    offsets[pe], offsets[0]
                ));
            }
        }
        Ok(())
    });
}

/// Corollary 1 for random handles: formula == direct resolution.
#[test]
fn corollary1_random_handles() {
    let w = World::threads(3, PoshConfig::small()).unwrap();
    forall("corollary1", 50, |g: &mut Gen| {
        let count = g.usize_in(1..500);
        let seed_off = g.usize_in(0..64);
        let ctxs = w.run_collect(move |ctx| {
            let p = ctx.shmalloc_n::<u64>(count + seed_off).unwrap();
            let sub = p.slice(seed_off, count);
            let mut ok = true;
            for pe in 0..ctx.n_pes() {
                unsafe {
                    let local = ctx.remote_addr(sub, ctx.my_pe()) as *const u8;
                    let formula =
                        translate(local, ctx.base_of(ctx.my_pe()), ctx.base_of(pe));
                    ok &= formula as usize == ctx.remote_addr(sub, pe) as usize;
                }
            }
            ctx.shfree(p).unwrap();
            ok
        });
        if ctxs.iter().all(|&b| b) {
            Ok(())
        } else {
            Err("translation formula diverged from direct resolution".into())
        }
    });
}

/// Lemma 1: reductions allocate root-side scratch; afterwards allocation
/// state must be identical across PEs (same live count, same journal-visible
/// layout for subsequent allocations).
#[test]
fn lemma1_temporaries_restore_symmetry() {
    forall("lemma1", 12, |g: &mut Gen| {
        let n_pes = g.usize_in(2..5);
        let nreduce = g.usize_in(1..300);
        let algo = g.pick(&[
            AlgoKind::LinearPut,
            AlgoKind::Tree,
            AlgoKind::RecursiveDoubling,
            AlgoKind::LinearGet,
        ]);
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(algo);
        let w = World::threads(n_pes, cfg).unwrap();
        let states = w.run_collect(move |ctx| {
            let src = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            let dst = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            unsafe {
                for (i, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = (ctx.my_pe() + i) as i64;
                }
            }
            ctx.barrier_all();
            let team = ctx.team_world();
            ctx.reduce_to_all(dst, src, nreduce, ReduceOp::Max, &team);
            // After the collective: scratch freed everywhere.
            let live = ctx.heap().live_allocations();
            let bytes = ctx.heap().allocated_bytes();
            // A post-collective symmetric allocation must land at the same
            // offset on every PE — the operative meaning of Lemma 1.
            let probe = ctx.shmalloc_n::<u8>(64).unwrap();
            let off = probe.offset();
            (live, bytes, off)
        });
        if states.windows(2).all(|w| w[0] == w[1]) && states[0].0 == 2 {
            Ok(())
        } else {
            Err(format!("asymmetric post-collective state ({algo:?}): {states:?}"))
        }
    });
}

/// Fact 1 through the slab layer: programs biased toward size-class
/// requests (the sizes posh-kv issues for nodes and small values) must
/// keep offset traces AND journal hashes symmetric — slab page carving,
/// per-class free lists, and whole-page reclamation are all deterministic
/// allocator state, so identical call sequences must reproduce them bit
/// for bit on every PE.
#[test]
fn fact1_slab_biased_programs() {
    use posh::symheap::alloc::{SLAB_CLASSES, SLAB_MAX_BYTES};
    forall("fact1-slab", 30, |g: &mut Gen| {
        let n_pes = g.usize_in(2..5);
        #[derive(Clone)]
        enum Op {
            Alloc { size: usize, align: usize },
            Free { idx: usize },
        }
        let n_ops = g.usize_in(4..60);
        let mut script = Vec::new();
        let mut live = 0usize;
        for _ in 0..n_ops {
            if live > 0 && g.bool(0.4) {
                script.push(Op::Free { idx: g.usize_in(0..live) });
                live -= 1;
            } else {
                // 80% slab-ladder sizes: an exact class, one under, or one
                // over (one over the top class deliberately spills to the
                // first-fit map path). 20% clearly-map-path sizes.
                let size = if g.bool(0.8) {
                    let c = g.pick(&SLAB_CLASSES);
                    match g.usize_in(0..3) {
                        0 => c,
                        1 => c - 1,
                        _ => c + 1,
                    }
                } else {
                    g.usize_in(SLAB_MAX_BYTES + 1..8192)
                };
                script.push(Op::Alloc { size, align: 1 << g.usize_in(0..6) });
                live += 1;
            }
        }
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let script = std::sync::Arc::new(script);
        let traces = w.run_collect({
            let script = std::sync::Arc::clone(&script);
            move |ctx| {
                let mut handles = Vec::new();
                let mut trace = Vec::new();
                for op in script.iter() {
                    match op {
                        Op::Alloc { size, align } => {
                            let p = ctx.shmemalign_n::<u8>(*align, *size).unwrap();
                            trace.push(p.offset());
                            handles.push(p);
                        }
                        Op::Free { idx } => {
                            let p = handles.remove(*idx);
                            ctx.shfree(p).unwrap();
                        }
                    }
                }
                // Drain the rest so page reclamation runs too, then record
                // the journal hash over the whole history.
                for p in handles {
                    ctx.shfree(p).unwrap();
                }
                trace.push(ctx.heap().journal_hash() as usize);
                trace
            }
        });
        for pe in 1..n_pes {
            if traces[pe] != traces[0] {
                return Err(format!(
                    "slab Fact 1 violated with {n_pes} PEs: PE {pe} trace {:?} != PE 0 trace {:?}",
                    traces[pe], traces[0]
                ));
            }
        }
        Ok(())
    });
}

/// The statics area (§4.2) obeys Fact 1 too: same manifest ⇒ same offsets.
#[test]
fn statics_placement_symmetric() {
    forall("statics", 30, |g: &mut Gen| {
        let n_decls = g.usize_in(1..12);
        let sizes: Vec<usize> = (0..n_decls).map(|_| g.usize_in(1..512)).collect();
        let aligns: Vec<usize> = (0..n_decls).map(|_| 1usize << g.usize_in(0..5)).collect();
        let w = World::threads(2, PoshConfig::small()).unwrap();
        let placements = w.run_collect({
            let sizes = sizes.clone();
            let aligns = aligns.clone();
            move |ctx| {
                sizes
                    .iter()
                    .zip(&aligns)
                    .map(|(&s, &a)| ctx.heap().place_static(s, a).unwrap().offset())
                    .collect::<Vec<_>>()
            }
        });
        if placements[0] == placements[1] {
            Ok(())
        } else {
            Err(format!("{:?} != {:?}", placements[0], placements[1]))
        }
    });
}
