//! The world: all PEs of one job.

use super::config::{Mode, PoshConfig};
use super::ctx::Ctx;
use super::remote_table::{RemoteTable, SendPtr, TableOpts};
use crate::collectives::tuning::{self, Tuning, TuningSource};
use crate::model::CostModel;
use crate::shm::memfd::{self, MemfdSegment};
use crate::shm::naming::{fresh_job_id, heap_segment_name, memfd_debug_name};
use crate::shm::posix::PosixShmSegment;
use crate::shm::ShmEngine;
use crate::symheap::layout::Layout;
use crate::symheap::SymHeap;
use crate::Result;
use anyhow::{bail, Context as _};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Shared, immutable-after-init state of a job, viewed from one process.
pub struct WorldShared {
    pub(crate) cfg: PoshConfig,
    pub(crate) job_id: u64,
    pub(crate) n_pes: usize,
    pub(crate) mode: Mode,
    pub(crate) layout: Layout,
    /// Thread mode: one heap per PE. Process mode: exactly my heap.
    pub(crate) local_heaps: Vec<SymHeap>,
    /// Segment base of every PE's heap in this address space.
    pub(crate) bases: Vec<SendPtr>,
    /// Process mode: which PE this process is.
    pub(crate) my_pe_fixed: Option<usize>,
    /// Process mode: the demand-mapping remote-heap table every
    /// `Ctx::base_of` routes through (`None` in thread mode).
    pub(crate) remote: Option<RemoteTable>,
    /// Raised when any PE panics (thread mode); spin loops poll it so one
    /// failing PE aborts the job instead of hanging the barrier.
    pub(crate) abort: AtomicBool,
    /// The job's tuning engine (adaptive collective selection + NBI
    /// coalescing thresholds). Identical on every PE by construction:
    /// thread mode shares one value, process mode adopts rank 0's published
    /// model — adaptive decisions must agree job-wide or the collective
    /// protocols deadlock.
    pub(crate) tuning: Tuning,
}

/// Resolve the engine a world (or its rank 0) uses: a postulated config
/// model wins, otherwise the once-per-process engine (env / calibration /
/// paper fallback).
fn resolve_tuning(cfg: &PoshConfig) -> Tuning {
    match cfg.cost_model {
        Some(cm) => Tuning::new(cm, TuningSource::Postulated),
        None => *tuning::process_engine(),
    }
}

/// Resolve the job's blocked PE→socket map width (0 = flat): the synthetic
/// `--pes-per-socket` / `POSH_PES_PER_SOCKET` forcing wins, else the
/// detected NUMA layout spreads the PEs block-wise over its sockets. A map
/// that puts every PE on one socket carries no second level and collapses
/// to flat.
fn resolve_pps(cfg: &PoshConfig, n_pes: usize) -> usize {
    let pps = match cfg.pes_per_socket {
        Some(n) => n,
        None => {
            let topo = crate::model::Topology::detect();
            if topo.sockets() > 1 {
                topo.pes_per_socket(n_pes)
            } else {
                0
            }
        }
    };
    if pps == 0 || pps >= n_pes {
        0
    } else {
        pps
    }
}

/// Attach the job topology to a resolved engine: on a flat map the engine
/// passes through untouched; on a multi-socket one the cross-socket tier is
/// resolved ([`tuning::calibrate_xsock`]) and the two-level model armed.
fn with_job_topology(t: Tuning, cfg: &PoshConfig, n_pes: usize) -> Tuning {
    let pps = resolve_pps(cfg, n_pes);
    if pps == 0 {
        return t;
    }
    let (xsock, _provenance) = tuning::calibrate_xsock(t.model());
    t.with_topology(xsock, pps)
}

/// A POSH job handle.
pub struct World {
    pub(crate) shared: Arc<WorldShared>,
}

impl World {
    /// Thread-mode world: `n` PEs as threads, heaps as private mappings.
    pub fn threads(n: usize, cfg: PoshConfig) -> Result<World> {
        if n == 0 {
            bail!("world needs at least one PE");
        }
        let layout = Layout::compute(cfg.heap_size, cfg.statics_size);
        if let Some(imp) = cfg.copy_impl {
            crate::mem::copy::set_global_impl(imp);
        }
        let mut heaps = Vec::with_capacity(n);
        for rank in 0..n {
            let seg = crate::shm::create_inproc(layout.total)
                .with_context(|| format!("creating heap segment for PE {rank}"))?;
            heaps.push(SymHeap::new(seg, layout, rank)?);
        }
        let bases = heaps.iter().map(|h| SendPtr(h.base())).collect();
        // Threads share one address space, so every PE resolving the same
        // config resolves the same topology — job-wide agreement is free.
        let tuning = with_job_topology(resolve_tuning(&cfg), &cfg, n);
        Ok(World {
            shared: Arc::new(WorldShared {
                cfg,
                job_id: fresh_job_id(),
                n_pes: n,
                mode: Mode::Threads,
                layout,
                local_heaps: heaps,
                bases,
                my_pe_fixed: None,
                remote: None,
                abort: AtomicBool::new(false),
                tuning,
            }),
        })
    }

    /// Process-mode world: create (or map, under the memfd engine) this
    /// rank's segment, then build the demand-mapping remote-heap table —
    /// peers' segments map on first access (§4.1.1's cache, without the
    /// eager O(n) start-up cost; `POSH_EAGER_MAP=1` restores it).
    pub fn attach_process(
        job_id: u64,
        rank: usize,
        n_pes: usize,
        cfg: PoshConfig,
    ) -> Result<World> {
        if rank >= n_pes {
            bail!("rank {rank} out of range for {n_pes} PEs");
        }
        let layout = Layout::compute(cfg.heap_size, cfg.statics_size);
        if let Some(imp) = cfg.copy_impl {
            crate::mem::copy::set_global_impl(imp);
        }
        let timeout = Duration::from_secs(
            std::env::var("POSH_ATTACH_TIMEOUT_S")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(30),
        );
        let opts = TableOpts {
            timeout,
            max_mapped: cfg.max_mapped_segs,
            // Demand-mapping a peer must also wait out its header init:
            // the eager start-up ready-loop this replaces did that for the
            // whole world at once.
            wait_ready: true,
        };
        let (heap, table) = match ShmEngine::resolve() {
            ShmEngine::Posix => {
                let seg =
                    PosixShmSegment::create(&heap_segment_name(job_id, rank), layout.total)?;
                let heap = SymHeap::new(Box::new(seg), layout, rank)?;
                let table = RemoteTable::new_posix(
                    job_id,
                    rank,
                    n_pes,
                    heap.base(),
                    layout.total,
                    opts,
                )?;
                (heap, table)
            }
            ShmEngine::Memfd => match memfd::handoff_fds_from_env()? {
                Some(fds) => {
                    if fds.len() != n_pes {
                        bail!(
                            "{} carries {} fds but the world has {n_pes} PEs \
                             (launcher/PE world-size mismatch)",
                            memfd::SEGFDS_ENV,
                            fds.len()
                        );
                    }
                    let seg = MemfdSegment::map_existing(fds[rank], layout.total)
                        .with_context(|| format!("mapping my own heap (rank {rank})"))?;
                    let heap = SymHeap::new(Box::new(seg), layout, rank)?;
                    let table =
                        RemoteTable::with_memfds(fds, rank, heap.base(), layout.total, opts)?;
                    (heap, table)
                }
                // A 1-PE world has no peers to hand off: self-host the memfd.
                None if n_pes == 1 => {
                    let seg = MemfdSegment::create(&memfd_debug_name(job_id, rank), layout.total)?;
                    let fd = seg.fd();
                    let heap = SymHeap::new(Box::new(seg), layout, rank)?;
                    let table =
                        RemoteTable::with_memfds(vec![fd], rank, heap.base(), layout.total, opts)?;
                    (heap, table)
                }
                None => bail!(
                    "memfd shm engine needs the launcher's fd handoff ({}) for a \
                     {n_pes}-PE world — run under oshrun, or set \
                     POSH_SHM_ENGINE=posix on a machine with a writable /dev/shm",
                    memfd::SEGFDS_ENV
                ),
            },
        };
        if cfg.eager_map {
            // The paper's original start-up shape: map everyone now, under
            // one shared deadline.
            table.prefault_all()?;
        }
        // Agree on one tuning model job-wide: rank 0 resolves (config /
        // env / calibration) and publishes α, β, R² through its header;
        // everyone else adopts the published model, so the adaptive engine
        // selects identically on every PE — a per-PE calibration could
        // straddle a crossover threshold and deadlock mixed protocols.
        // (For rank != 0 this is the table's first demand map: it blocks
        // until PE 0's segment exists and its header is ready.)
        let hdr0 = unsafe { crate::symheap::layout::HeapHeader::at(table.base_of(0)) };
        let tuning = if rank == 0 {
            let t = with_job_topology(resolve_tuning(&cfg), &cfg, n_pes);
            hdr0.tuning_alpha_bits.store(t.model().alpha_ns.to_bits(), Ordering::Relaxed);
            hdr0.tuning_beta_bits
                .store(t.model().beta_bytes_per_ns.to_bits(), Ordering::Relaxed);
            hdr0.tuning_r2_bits.store(t.model().r2.to_bits(), Ordering::Relaxed);
            // The piecewise regime fits ride the same release fence: all 16
            // words land before tuning_ready flips.
            let wire = t.piecewise().to_wire();
            for (cell, w) in hdr0.tuning_pw.iter().zip(wire) {
                cell.store(w, Ordering::Relaxed);
            }
            // The cross-socket tier and the PE→socket geometry ride it too.
            // Publishing the geometry (rather than every rank re-detecting)
            // is what makes the socket map a job-wide agreement: the
            // two-level schedules deadlock if two PEs disagree on who leads.
            hdr0.tuning_xsock_alpha_bits
                .store(t.xsock_model().alpha_ns.to_bits(), Ordering::Relaxed);
            hdr0.tuning_xsock_beta_bits
                .store(t.xsock_model().beta_bytes_per_ns.to_bits(), Ordering::Relaxed);
            hdr0.tuning_xsock_geom.store(t.pes_per_socket() as u64, Ordering::Relaxed);
            hdr0.tuning_ready.store(t.source().to_wire(), Ordering::Release);
            t
        } else {
            let deadline = std::time::Instant::now() + timeout;
            let mut wire = 0u64;
            while wire == 0 {
                wire = hdr0.tuning_ready.load(Ordering::Acquire);
                if wire == 0 {
                    if std::time::Instant::now() > deadline {
                        bail!("PE 0 did not publish the tuning model within {timeout:?}");
                    }
                    std::hint::spin_loop();
                    std::thread::yield_now();
                }
            }
            let model = CostModel {
                alpha_ns: f64::from_bits(hdr0.tuning_alpha_bits.load(Ordering::Relaxed)),
                beta_bytes_per_ns: f64::from_bits(
                    hdr0.tuning_beta_bits.load(Ordering::Relaxed),
                ),
                r2: f64::from_bits(hdr0.tuning_r2_bits.load(Ordering::Relaxed)),
            };
            let mut pw_wire = [0u64; crate::model::piecewise::WIRE_WORDS];
            for (w, cell) in pw_wire.iter_mut().zip(hdr0.tuning_pw.iter()) {
                *w = cell.load(Ordering::Relaxed);
            }
            let pw = crate::model::PiecewiseModel::from_wire(&pw_wire);
            let source = TuningSource::from_wire(wire);
            let base = if pw.is_degenerate() {
                // All-zero (legacy publisher) or corrupt regime words:
                // adopt the scalar model uniformly — identical selections
                // to a pre-piecewise job.
                Tuning::new(model, source)
            } else {
                Tuning::new_piecewise(model, pw, source)
            };
            // Adopt the published topology. geom == 0 covers both a genuine
            // flat map and a legacy publisher whose header predates the
            // xsock words (they read back all-zero) — flat either way.
            let geom = hdr0.tuning_xsock_geom.load(Ordering::Relaxed) as usize;
            if geom == 0 {
                base
            } else {
                let xa = f64::from_bits(hdr0.tuning_xsock_alpha_bits.load(Ordering::Relaxed));
                let xb = f64::from_bits(hdr0.tuning_xsock_beta_bits.load(Ordering::Relaxed));
                let xsock = if xa.is_finite() && xa >= 0.0 && xb.is_finite() && xb > 0.0 {
                    CostModel { alpha_ns: xa, beta_bytes_per_ns: xb, r2: model.r2 }
                } else {
                    // Corrupt tier words with a live geometry: re-derive the
                    // deterministic tier every rank computes identically.
                    tuning::derived_xsock(&model)
                };
                base.with_topology(xsock, geom)
            }
        };
        Ok(World {
            shared: Arc::new(WorldShared {
                cfg,
                job_id,
                n_pes,
                mode: Mode::Processes,
                layout,
                local_heaps: vec![heap],
                // Process mode resolves bases through the demand table;
                // the flat vector is thread mode's path.
                bases: Vec::new(),
                my_pe_fixed: Some(rank),
                remote: Some(table),
                abort: AtomicBool::new(false),
                tuning,
            }),
        })
    }

    /// Process-mode attach from the environment `oshrun` provides
    /// (`POSH_JOB`, `POSH_RANK`, `POSH_NPES`, plus config overrides).
    pub fn from_env() -> Result<World> {
        let job = std::env::var("POSH_JOB")
            .context("POSH_JOB not set (run under oshrun)")?
            .parse::<u64>()
            .context("POSH_JOB must be a u64")?;
        let rank = std::env::var("POSH_RANK")
            .context("POSH_RANK not set")?
            .parse::<usize>()?;
        let n = std::env::var("POSH_NPES")
            .context("POSH_NPES not set")?
            .parse::<usize>()?;
        let cfg = PoshConfig::default().from_env();
        Self::attach_process(job, rank, n, cfg)
    }

    /// `true` if the `oshrun` environment is present.
    pub fn env_present() -> bool {
        std::env::var("POSH_JOB").is_ok()
    }

    /// Number of PEs in the job.
    pub fn n_pes(&self) -> usize {
        self.shared.n_pes
    }

    /// Execution mode.
    pub fn mode(&self) -> Mode {
        self.shared.mode
    }

    /// Job id.
    pub fn job_id(&self) -> u64 {
        self.shared.job_id
    }

    /// Build the context for PE `pe`. In process mode only this process's
    /// own rank is valid.
    pub fn ctx(&self, pe: usize) -> Ctx {
        assert!(pe < self.shared.n_pes, "PE {pe} out of range");
        if let Some(me) = self.shared.my_pe_fixed {
            assert_eq!(pe, me, "process-mode world can only build its own ctx");
        }
        Ctx::new(pe, Arc::clone(&self.shared))
    }

    /// Process mode: this process's context.
    pub fn my_ctx(&self) -> Ctx {
        let me = self
            .shared
            .my_pe_fixed
            .expect("my_ctx() is process-mode only; use ctx(pe)/run(f) in thread mode");
        Ctx::new(me, Arc::clone(&self.shared))
    }

    /// Thread mode: run `f` once per PE on its own thread; returns when all
    /// PEs finish. A panicking PE raises the job abort flag so peers blocked
    /// in barriers fail fast instead of hanging.
    pub fn run<F>(&self, f: F)
    where
        F: Fn(Ctx) + Send + Sync,
    {
        self.run_collect(|ctx| f(ctx));
    }

    /// Like [`World::run`] but collects each PE's return value, indexed by
    /// rank.
    pub fn run_collect<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(Ctx) -> R + Send + Sync,
    {
        assert_eq!(
            self.shared.mode,
            Mode::Threads,
            "run()/run_collect() are thread-mode entry points"
        );
        let shared = &self.shared;
        let f = &f;
        let mut out: Vec<Option<R>> = (0..shared.n_pes).map(|_| None).collect();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..shared.n_pes)
                .map(|pe| {
                    let ctx = Ctx::new(pe, Arc::clone(shared));
                    let abort = Arc::clone(shared);
                    s.spawn(move || {
                        // Abort the whole job if this PE panics, so peers
                        // spinning in barriers bail out.
                        struct Guard(Arc<WorldShared>);
                        impl Drop for Guard {
                            fn drop(&mut self) {
                                if std::thread::panicking() {
                                    self.0.abort.store(true, Ordering::Release);
                                }
                            }
                        }
                        let _g = Guard(abort);
                        f(ctx)
                    })
                })
                .collect();
            for (pe, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(r) => out[pe] = Some(r),
                    Err(p) => std::panic::resume_unwind(p),
                }
            }
        });
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_world_basics() {
        let w = World::threads(4, PoshConfig::small()).unwrap();
        assert_eq!(w.n_pes(), 4);
        assert_eq!(w.mode(), Mode::Threads);
        let ranks = w.run_collect(|ctx| ctx.my_pe());
        assert_eq!(ranks, vec![0, 1, 2, 3]);
    }

    #[test]
    fn zero_pes_rejected() {
        assert!(World::threads(0, PoshConfig::small()).is_err());
    }

    #[test]
    fn bases_are_distinct() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        let b: Vec<usize> = (0..3).map(|i| w.shared.bases[i].0 as usize).collect();
        assert_ne!(b[0], b[1]);
        assert_ne!(b[1], b[2]);
    }

    #[test]
    fn pe_panic_propagates() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            w.run(|ctx| {
                if ctx.my_pe() == 1 {
                    panic!("boom");
                }
                // PE 0 blocks on a barrier that PE 1 never reaches; the
                // abort flag must rescue it.
                ctx.barrier_all();
            });
        }));
        assert!(r.is_err());
    }

    #[test]
    fn synthetic_topology_arms_the_engine() {
        let mut cfg = PoshConfig::small();
        cfg.pes_per_socket = Some(2);
        let w = World::threads(4, cfg).unwrap();
        assert_eq!(w.shared.tuning.pes_per_socket(), 2);
        w.run(|ctx| {
            assert_eq!(ctx.pes_per_socket(), 2);
            assert_eq!(ctx.socket_of(0), 0);
            assert_eq!(ctx.socket_of(1), 0);
            assert_eq!(ctx.socket_of(2), 1);
            assert_eq!(ctx.socket_of(3), 1);
        });
        // A map that fits the whole job on one socket collapses to flat.
        let mut cfg = PoshConfig::small();
        cfg.pes_per_socket = Some(8);
        let w = World::threads(4, cfg).unwrap();
        assert_eq!(w.shared.tuning.pes_per_socket(), 0);
        w.run(|ctx| {
            assert_eq!(ctx.pes_per_socket(), 0);
            assert_eq!(ctx.socket_of(3), 0);
        });
    }

    #[test]
    fn process_mode_single_rank() {
        // A 1-PE process-mode world inside this test process.
        let job = crate::shm::naming::fresh_job_id();
        let w = World::attach_process(job, 0, 1, PoshConfig::small()).unwrap();
        let ctx = w.my_ctx();
        assert_eq!(ctx.my_pe(), 0);
        assert_eq!(ctx.n_pes(), 1);
        ctx.barrier_all();
        let p = ctx.shmalloc_n::<u64>(4).unwrap();
        ctx.put(p, &[9, 9, 9, 9], 0);
        assert_eq!(ctx.get_one(p, 0), 9);
    }
}
