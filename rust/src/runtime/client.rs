//! Per-thread PJRT CPU client.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (neither `Send` nor `Sync`),
//! so each PE thread owns its own client and compiles its own executables —
//! the same situation as process mode, where each PE process naturally has
//! one. Compilation is a start-up cost; the request path only executes.

use crate::Result;
use std::cell::RefCell;

thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// Run `f` with this thread's PJRT CPU client (created on first use).
pub fn with_client<R>(f: impl FnOnce(&xla::PjRtClient) -> Result<R>) -> Result<R> {
    CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            let client = xla::PjRtClient::cpu()?;
            // No `log` crate in the vendored registry; opt-in stderr note.
            if std::env::var_os("POSH_VERBOSE").is_some() {
                eprintln!(
                    "PJRT client up: platform={} devices={}",
                    client.platform_name(),
                    client.device_count()
                );
            }
            *slot = Some(client);
        }
        f(slot.as_ref().unwrap())
    })
}

/// Platform info string (for `oshrun info`).
pub fn platform_info() -> Result<String> {
    with_client(|c| Ok(format!("{} ({} device(s))", c.platform_name(), c.device_count())))
}

#[cfg(test)]
mod tests {
    #[test]
    fn client_available_and_reused() {
        let a = super::with_client(|c| Ok(c.platform_name())).unwrap();
        let b = super::with_client(|c| Ok(c.platform_name())).unwrap();
        assert_eq!(a, b);
        assert!(super::platform_info().unwrap().contains("device"));
    }
}
