//! Process spawning and contact information (§4.7).
//!
//! Contact information is engine-dependent: under the POSIX engine a PE
//! reaches any peer by rebuilding the segment name from `(job id, rank)`;
//! under the memfd engine (auto-selected when `/dev/shm` is unwritable)
//! the launcher pre-creates every heap segment and brokers the fds to the
//! children (see [`crate::rte::gateway::SegmentHandoff`]).

use super::gateway::SegmentHandoff;
use crate::pe::config::parse_size;
use crate::shm::naming::fresh_job_id;
use crate::shm::ShmEngine;
use crate::symheap::layout::Layout;
use crate::Result;
use anyhow::Context as _;
use std::os::unix::process::CommandExt as _;
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

/// Description of a parallel job to launch.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Number of PEs.
    pub n_pes: usize,
    /// Program (binary path) each PE runs.
    pub program: String,
    /// Arguments passed to every PE.
    pub args: Vec<String>,
    /// Extra environment (`POSH_HEAP_SIZE`, `POSH_COPY`, …).
    pub env: Vec<(String, String)>,
    /// Attach gdb-style stop at start-up (§4.7 "Run-time debugging": the
    /// child spins until a debugger clears the flag — exported as
    /// `POSH_DEBUG_WAIT=1`).
    pub debug_wait: bool,
}

impl JobSpec {
    /// A spec with defaults.
    pub fn new(n_pes: usize, program: &str) -> JobSpec {
        JobSpec {
            n_pes,
            program: program.to_string(),
            args: Vec::new(),
            env: Vec::new(),
            debug_wait: false,
        }
    }
}

/// One spawned PE.
pub struct PeProc {
    /// Rank of this PE.
    pub rank: usize,
    /// The OS child process.
    pub child: Child,
}

/// The launcher: spawns, then hands the children to gateway + monitor.
pub struct Launcher {
    /// Job id all children share (segment naming).
    pub job_id: u64,
    spec: JobSpec,
}

impl Launcher {
    /// Prepare a launch with a fresh job id.
    pub fn new(spec: JobSpec) -> Launcher {
        Launcher { job_id: fresh_job_id(), spec }
    }

    /// Spawn all PEs. Mirrors the paper's structure: "At first, a pool of
    /// threads is created: the workers thread group. Then each thread forks
    /// a process … the master thread then yields its slice of time and
    /// waits … eventually, the threads are joined."
    ///
    /// When the memfd engine is in play (forced via `POSH_SHM_ENGINE` in
    /// the spec's env, or auto-selected because `/dev/shm` is unwritable),
    /// the heap segments are created *here* and their fds brokered to every
    /// child before any PE starts.
    pub fn spawn_all(&self) -> Result<Vec<PeProc>> {
        let mut extra_env: Vec<(String, String)> = Vec::new();
        // Keep the handoff (and so the parent-side fds) alive until every
        // child has been spawned; children then own inherited copies.
        let handoff = if self.resolve_engine() == ShmEngine::Memfd {
            let h = SegmentHandoff::create(self.job_id, self.spec.n_pes, self.child_seg_len())?;
            // Pin the children to the engine the fds were brokered for —
            // a child must not re-probe /dev/shm and decide differently.
            extra_env.push(("POSH_SHM_ENGINE".to_string(), "memfd".to_string()));
            let (k, v) = h.env();
            extra_env.push((k, v));
            Some(h)
        } else {
            None
        };
        let results: Arc<Mutex<Vec<Option<Result<PeProc>>>>> =
            Arc::new(Mutex::new((0..self.spec.n_pes).map(|_| None).collect()));
        std::thread::scope(|s| {
            // Workers thread group: one spawner thread per PE.
            for rank in 0..self.spec.n_pes {
                let results = Arc::clone(&results);
                let spec = &self.spec;
                let job_id = self.job_id;
                let extra_env = &extra_env;
                s.spawn(move || {
                    let r = spawn_one(spec, extra_env, job_id, rank);
                    results.lock().unwrap()[rank] = Some(r);
                });
            }
            // Master yields while workers fork (sched_yield in the paper).
            std::thread::yield_now();
        }); // threads joined here
        drop(handoff); // all children spawned: parent fd copies can close
        let collected = Arc::try_unwrap(results)
            .map_err(|_| anyhow::anyhow!("spawner results still shared"))?
            .into_inner()
            .unwrap();
        let mut pes = Vec::with_capacity(self.spec.n_pes);
        for (rank, slot) in collected.into_iter().enumerate() {
            let proc = slot
                .with_context(|| format!("spawner thread for PE {rank} produced no result"))??;
            pes.push(proc);
        }
        Ok(pes)
    }

    /// The shm engine this launch will use: an explicit `POSH_SHM_ENGINE`
    /// in the job's env wins; otherwise the process-wide auto-selection
    /// (which probes `/dev/shm`).
    fn resolve_engine(&self) -> ShmEngine {
        for (k, v) in &self.spec.env {
            if k == "POSH_SHM_ENGINE" {
                if v.eq_ignore_ascii_case("memfd") {
                    return ShmEngine::Memfd;
                }
                if v.eq_ignore_ascii_case("posix") {
                    return ShmEngine::Posix;
                }
            }
        }
        ShmEngine::resolve()
    }

    /// The segment length each child will compute — replayed here from the
    /// same inputs the child sees (inherited env, then the spec's env
    /// overrides) so the brokered memfds are sized exactly right.
    fn child_seg_len(&self) -> usize {
        let mut cfg = crate::pe::PoshConfig::default().from_env();
        for (k, v) in &self.spec.env {
            if k == "POSH_HEAP_SIZE" {
                if let Some(n) = parse_size(v) {
                    cfg.heap_size = n;
                }
            } else if k == "POSH_STATICS_SIZE" {
                if let Some(n) = parse_size(v) {
                    cfg.statics_size = n;
                }
            }
        }
        Layout::compute(cfg.heap_size, cfg.statics_size).total
    }
}

fn spawn_one(
    spec: &JobSpec,
    extra_env: &[(String, String)],
    job_id: u64,
    rank: usize,
) -> Result<PeProc> {
    let mut cmd = Command::new(&spec.program);
    cmd.args(&spec.args)
        // Contact information (§4.7): job id + rank + world size determine
        // every segment name a PE needs.
        .env("POSH_JOB", job_id.to_string())
        .env("POSH_RANK", rank.to_string())
        .env("POSH_NPES", spec.n_pes.to_string())
        // IO forwarding: pipes back to the gateway; "the parallel processes
        // are offsprings of the gateway process: hence, their IOs are
        // forwarded by default" — we add rank-prefixing on top.
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .stdin(if rank == 0 { Stdio::inherit() } else { Stdio::null() });
    // Each PE leads its own process group so the monitor can terminate the
    // PE *and everything it spawned* (otherwise an orphaned grandchild keeps
    // the gateway's IO pipes open and the job lingers).
    cmd.process_group(0);
    if spec.debug_wait {
        cmd.env("POSH_DEBUG_WAIT", "1");
    }
    // Launcher-derived env (shm engine pin + fd handoff) first, so an
    // explicit pair in the user's spec still wins.
    for (k, v) in extra_env {
        cmd.env(k, v);
    }
    for (k, v) in &spec.env {
        cmd.env(k, v);
    }
    let child = cmd
        .spawn()
        .with_context(|| format!("spawning PE {rank}: {}", spec.program))?;
    Ok(PeProc { rank, child })
}

/// Child-side hook for §4.7 interactive debugging: "the parallel process is
/// stuck in an infinite loop at the beginning of its initialization" until a
/// debugger (or a signal) flips the flag. Call early in PE main.
pub fn debug_wait_if_requested() {
    if std::env::var("POSH_DEBUG_WAIT").as_deref() == Ok("1") {
        eprintln!(
            "POSH: PE {} (pid {}) waiting for debugger; `gdb -p {}` then `set var __posh_go=1`",
            std::env::var("POSH_RANK").unwrap_or_default(),
            std::process::id(),
            std::process::id(),
        );
        // Volatile so the debugger's write is observed.
        static mut GO: u32 = 0;
        loop {
            // SAFETY: single writer (debugger), volatile read.
            if unsafe { std::ptr::read_volatile(std::ptr::addr_of!(GO)) } != 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_echo_children() {
        // Use /bin/sh to emit rank info; verifies env plumbing end-to-end.
        let mut spec = JobSpec::new(3, "/bin/sh");
        spec.args = vec!["-c".into(), "echo rank=$POSH_RANK npes=$POSH_NPES".into()];
        let l = Launcher::new(spec);
        let pes = l.spawn_all().unwrap();
        assert_eq!(pes.len(), 3);
        let mut seen = Vec::new();
        for pe in pes {
            let out = pe.child.wait_with_output().unwrap();
            assert!(out.status.success());
            let text = String::from_utf8_lossy(&out.stdout).trim().to_string();
            assert!(text.contains("npes=3"), "{text}");
            seen.push(text);
        }
        seen.sort();
        assert_eq!(seen[0], "rank=0 npes=3");
        assert_eq!(seen[2], "rank=2 npes=3");
    }

    #[test]
    fn memfd_handoff_env_reaches_children() {
        if !crate::shm::memfd::memfd_supported() {
            eprintln!("skipping: memfd_create unavailable");
            return;
        }
        // Force the memfd engine: the launcher must broker one fd per rank
        // and the children must see the handoff variable (the fds
        // themselves are exercised end-to-end by tests/proc_mode.rs).
        let mut spec = JobSpec::new(2, "/bin/sh");
        spec.args = vec![
            "-c".into(),
            "test -n \"$POSH_SEGFDS\" && echo engine=$POSH_SHM_ENGINE".into(),
        ];
        spec.env.push(("POSH_SHM_ENGINE".into(), "memfd".into()));
        spec.env.push(("POSH_HEAP_SIZE".into(), "2M".into()));
        spec.env.push(("POSH_STATICS_SIZE".into(), "64k".into()));
        let l = Launcher::new(spec);
        let pes = l.spawn_all().unwrap();
        for pe in pes {
            let out = pe.child.wait_with_output().unwrap();
            assert!(out.status.success(), "child did not see the fd handoff");
            let text = String::from_utf8_lossy(&out.stdout);
            assert!(text.contains("engine=memfd"), "{text}");
        }
    }

    #[test]
    fn spawn_failure_reported() {
        let spec = JobSpec::new(2, "/nonexistent/binary/posh");
        let l = Launcher::new(spec);
        assert!(l.spawn_all().is_err());
    }

    #[test]
    fn job_ids_fresh() {
        let a = Launcher::new(JobSpec::new(1, "/bin/true"));
        let b = Launcher::new(JobSpec::new(1, "/bin/true"));
        assert_ne!(a.job_id, b.job_id);
    }
}
