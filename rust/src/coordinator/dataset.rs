//! Synthetic corpus with learnable structure.
//!
//! Token streams follow an affine recurrence with noise:
//! `next = (a·prev + c) mod V` with probability `1 − ε`, uniform otherwise.
//! A language model can push its cross-entropy towards the entropy of the
//! noise, so the e2e loss curve has a real signal to descend — unlike pure
//! uniform noise, whose optimal loss is a flat `log V`.
//!
//! Sharding is by PE: stream `(seed, pe, step)` is deterministic, so any PE
//! can regenerate any batch without communication (and the test oracle can
//! regenerate PE batches independently).

use crate::util::prng::Rng;

/// Corpus parameters.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    /// Vocabulary size.
    pub vocab: usize,
    /// Batch size per PE.
    pub batch: usize,
    /// Sequence length.
    pub seq: usize,
    /// Noise rate ε.
    pub noise: f64,
    /// Base seed.
    pub seed: u64,
}

impl CorpusSpec {
    /// The affine recurrence parameters (fixed, coprime with any vocab ≥ 8).
    pub const A: u64 = 5;
    /// Additive constant.
    pub const C: u64 = 7;

    /// Generate the batch for `(pe, step)` as row-major `[batch, seq]`
    /// tokens in `0..vocab`.
    pub fn batch_tokens(&self, pe: usize, step: usize) -> Vec<i32> {
        let mut rng = Rng::for_pe(self.seed ^ (step as u64).wrapping_mul(0x9E37), pe);
        let v = self.vocab as u64;
        let mut out = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let mut tok = rng.next_below(v);
            out.push(tok as i32);
            for _ in 1..self.seq {
                tok = if rng.bool(self.noise) {
                    rng.next_below(v)
                } else {
                    (Self::A * tok + Self::C) % v
                };
                out.push(tok as i32);
            }
        }
        out
    }

    /// The entropy floor of the stream in nats (lower bound on achievable
    /// cross-entropy): `H = ε·ln(V) + H₂(ε)` approximately, ignoring the
    /// ε/V collision term.
    pub fn entropy_floor_nats(&self) -> f64 {
        let e = self.noise;
        let v = self.vocab as f64;
        let h2 = if e > 0.0 && e < 1.0 {
            -(e * e.ln() + (1.0 - e) * (1.0 - e).ln())
        } else {
            0.0
        };
        e * v.ln() + h2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CorpusSpec {
        CorpusSpec { vocab: 64, batch: 4, seq: 16, noise: 0.1, seed: 42 }
    }

    #[test]
    fn deterministic_per_pe_step() {
        let s = spec();
        assert_eq!(s.batch_tokens(1, 7), s.batch_tokens(1, 7));
        assert_ne!(s.batch_tokens(1, 7), s.batch_tokens(2, 7));
        assert_ne!(s.batch_tokens(1, 7), s.batch_tokens(1, 8));
    }

    #[test]
    fn tokens_in_range_and_shaped() {
        let s = spec();
        let b = s.batch_tokens(0, 0);
        assert_eq!(b.len(), 4 * 16);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < s.vocab));
    }

    #[test]
    fn recurrence_dominates() {
        let s = spec();
        let b = s.batch_tokens(0, 3);
        let mut follow = 0usize;
        let mut total = 0usize;
        for row in b.chunks(s.seq) {
            for w in row.windows(2) {
                total += 1;
                let pred = (CorpusSpec::A * w[0] as u64 + CorpusSpec::C) % s.vocab as u64;
                if pred == w[1] as u64 {
                    follow += 1;
                }
            }
        }
        let frac = follow as f64 / total as f64;
        assert!(frac > 0.8, "recurrence followed only {frac}");
    }

    #[test]
    fn entropy_floor_sane() {
        let s = spec();
        let h = s.entropy_floor_nats();
        assert!(h > 0.0 && h < (s.vocab as f64).ln());
    }
}
