//! **Table 1** — "Comparison of the performance observed with various memcpy
//! implementations": latency (ns) and bandwidth (Gb/s) per copy
//! implementation.
//!
//! The paper's rows are five 2010-era machines; ours are (a) this container,
//! measured, and (b) the five paper machines *replayed from their fitted
//! cost models* (DESIGN.md §1 substitution) so the table shape is directly
//! comparable. The paper's columns memcpy/MMX/MMX2/SSE map to
//! stock/unrolled64/nontemporal/sse2 (+ avx2, today's continuation).
//!
//! Protocol = §5: 20 reps after warm-up; latency at 8 B, bandwidth at 64 MiB.

use posh::bench::{auto_batch, measure, Table};
use posh::mem::copy::{copy_bytes_with, CopyImpl};
use posh::model::machines::paper_machines;

const LAT_SIZE: usize = 8;
const BW_SIZE: usize = 64 << 20;

fn main() {
    let impls = CopyImpl::available();
    let names: Vec<&str> = impls.iter().map(|i| i.name()).collect();

    let mut lat = Table::new("Table 1a: memory copy latency", "ns", &names);
    let mut bw = Table::new("Table 1b: memory copy bandwidth", "Gb/s", &names);

    // --- Measured row: this machine.
    let src = vec![0xA5u8; BW_SIZE];
    let mut dst = vec![0u8; BW_SIZE];
    let mut lat_row = Vec::new();
    let mut bw_row = Vec::new();
    for &imp in &impls {
        let m = measure(LAT_SIZE, auto_batch(30.0), || unsafe {
            copy_bytes_with(imp, dst.as_mut_ptr(), src.as_ptr(), LAT_SIZE);
        });
        lat_row.push(m.latency_ns());
        let m = measure(BW_SIZE, 1, || unsafe {
            copy_bytes_with(imp, dst.as_mut_ptr(), src.as_ptr(), BW_SIZE);
        });
        bw_row.push(m.bandwidth_gbps());
    }
    lat.row("this-machine", lat_row.clone());
    bw.row("this-machine", bw_row.clone());

    // --- Replayed rows: the paper's machines from their fitted models
    // (stock memcpy + best tuned copy; the dead ISAs have no modern meaning,
    // so replay fills only the columns that map).
    for m in paper_machines() {
        let mut l = vec![0.0; impls.len()];
        let mut b = vec![0.0; impls.len()];
        for (i, imp) in impls.iter().enumerate() {
            match imp {
                CopyImpl::Stock => {
                    l[i] = m.memcpy.alpha_ns;
                    b[i] = m.memcpy.predict_gbps(BW_SIZE);
                }
                CopyImpl::Sse2 => {
                    l[i] = m.best_copy.alpha_ns;
                    b[i] = m.best_copy.predict_gbps(BW_SIZE);
                }
                _ => {}
            }
        }
        lat.row(&format!("paper:{}", m.name), l);
        bw.row(&format!("paper:{}", m.name), b);
    }

    lat.print();
    bw.print();
    lat.write_csv("table1_latency").unwrap();
    bw.write_csv("table1_bandwidth").unwrap();

    // --- Shape checks (the claims Table 1 supports in the paper).
    let stock_idx = impls.iter().position(|i| *i == CopyImpl::Stock).unwrap();
    let best_bw = bw_row.iter().cloned().fold(f64::MIN, f64::max);
    assert!(
        bw_row[stock_idx] >= 0.5 * best_bw,
        "stock memcpy must be within 2x of the best copy (paper: 'the stock \
         memcpy performs quite well'); got {:.1} vs best {:.1}",
        bw_row[stock_idx],
        best_bw
    );
    println!(
        "\nshape check OK: stock {:.1} Gb/s vs best {:.1} Gb/s (ratio {:.2})",
        bw_row[stock_idx],
        best_bw,
        bw_row[stock_idx] / best_bw
    );
    println!("csv: bench_out/table1_latency.csv, bench_out/table1_bandwidth.csv");
}
