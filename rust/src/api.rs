//! The flat OpenSHMEM C-style API (§4.3 "Datatype-specific routines"),
//! extended with the 1.4/1.5 team and context entry points.
//!
//! The paper's observation: SHMEM defines one function **per data type**
//! (`shmem_short_g`, `shmem_int_g`, `shmem_long_g`, …) and a C++ template
//! engine lets one write the body once — "that function is generated at
//! compile-time, not at run-time: consequently, calling that function is
//! just as fast as if it had been written manually."
//!
//! Rust generics are the same machinery; the macros below instantiate the
//! typed entry points from the generic `Ctx` core exactly as the paper's
//! `shmem_template_g<T>` does, C names and all. The same macro that emits
//! the deprecated 1.0 `shmem_<T>_<op>_to_all` active-set reductions emits
//! their 1.5 `shmem_<T>_<op>_reduce` team replacements.
//!
//! The implicit-context model of the C API (no handle arguments) is realised
//! with a thread-local `Ctx` installed by [`shmem_init`] (process mode picks
//! it up from the `oshrun` environment; thread-mode tests install one with
//! [`install_ctx`]). [`shmem_finalize`] tears the world down deterministically
//! — segments unlink on drop instead of leaking to process exit, which is
//! what the deprecated [`start_pes`] used to do via `mem::forget`.
//!
//! **Triplet deprecation**: every `(PE_start, logPE_stride, PE_size)` entry
//! point survives, marked `#[deprecated]`, as a thin shim that wraps the
//! triplet in a temporary legacy [`Team`] — source compatibility for 1.0
//! programs, teams for everything new.

use crate::ctx::{CommCtx, CtxOptions};
use crate::pe::{Ctx, World};
use crate::symheap::SymPtr;
use crate::team::Team;
use std::cell::RefCell;
use std::sync::Mutex;

thread_local! {
    static CURRENT: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// The process-global world created by [`shmem_init`], held so that
/// [`shmem_finalize`] can drop it deterministically (unmapping and — on the
/// owner side — unlinking its segments).
static WORLD_SLOT: Mutex<Option<World>> = Mutex::new(None);

/// Install the calling thread's implicit context (thread-mode worlds call
/// this from inside `world.run`; `shmem_init` does it in process mode).
pub fn install_ctx(ctx: Ctx) {
    CURRENT.with(|c| *c.borrow_mut() = Some(ctx));
}

/// Remove the implicit context (end of PE body).
pub fn clear_ctx() {
    CURRENT.with(|c| *c.borrow_mut() = None);
}

/// OpenSHMEM 1.4 thread levels (§9.2), in increasing order of permitted
/// concurrency — the declaration order makes `Ord` express exactly that.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ThreadLevel {
    /// `SHMEM_THREAD_SINGLE`: one thread exists; only it may call SHMEM.
    Single,
    /// `SHMEM_THREAD_FUNNELED`: many threads, but only the initialising
    /// ("main") thread makes SHMEM calls.
    Funneled,
    /// `SHMEM_THREAD_SERIALIZED`: any thread may call SHMEM, but the
    /// program promises the calls never overlap in time.
    Serialized,
    /// `SHMEM_THREAD_MULTIPLE`: any thread, any time, concurrently — the
    /// level the sharded NBI queues and per-thread context pools exist for.
    Multiple,
}

/// Why the calling thread has no implicit SHMEM context — the structured
/// form of the error [`ctx`] turns into a panic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CtxError {
    /// The library was never initialised on this process (no
    /// `shmem_init`/`shmem_init_thread`/`install_ctx` happened).
    Uninitialized,
    /// The library *is* initialised, but at a thread level that confines
    /// the SHMEM API to the initialising thread — and the caller is a
    /// different thread.
    LevelForbids {
        /// The level the job was initialised with.
        level: ThreadLevel,
    },
}

impl std::fmt::Display for CtxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CtxError::Uninitialized => write!(
                f,
                "no SHMEM context on this thread: call shmem_init()/\
                 shmem_init_thread(level)/install_ctx() first"
            ),
            CtxError::LevelForbids { level } => write!(
                f,
                "no SHMEM context on this thread: the job was initialised at \
                 thread level {level:?}, which confines the SHMEM API to the \
                 initialising thread — initialise with \
                 shmem_init_thread(ThreadLevel::Multiple) (or Serialized) to \
                 call SHMEM from spawned threads"
            ),
        }
    }
}

impl std::error::Error for CtxError {}

/// The process-wide thread environment established by [`shmem_init_thread`]
/// (or [`install_ctx_thread`]): the PE's context, the provided thread
/// level, and the initialising thread's identity for `SINGLE`/`FUNNELED`
/// enforcement.
struct ThreadEnv {
    ctx: Ctx,
    level: ThreadLevel,
    home: std::thread::ThreadId,
}

/// Process-global thread environment. Spawned threads of a
/// `SERIALIZED`/`MULTIPLE` job bootstrap their thread-local context from
/// here on first SHMEM call. (Thread-mode *test* worlds — many PEs in one
/// process — install per-PE contexts with [`install_ctx`] instead and
/// leave this slot alone.)
static THREAD_ENV: Mutex<Option<ThreadEnv>> = Mutex::new(None);

/// Fetch the implicit context; panics outside a PE body.
pub fn ctx() -> Ctx {
    match try_ctx() {
        Ok(c) => c,
        Err(e) => panic!("{e}"),
    }
}

/// Fetch the implicit context, structured-error form: the fast path is the
/// calling thread's cached handle; on a miss, a `SERIALIZED`/`MULTIPLE`
/// job hands any thread a clone of the PE context (cached thread-locally
/// for subsequent calls), a `SINGLE`/`FUNNELED` job only the initialising
/// thread.
pub fn try_ctx() -> Result<Ctx, CtxError> {
    if let Some(c) = CURRENT.with(|c| c.borrow().clone()) {
        return Ok(c);
    }
    let env = THREAD_ENV.lock().unwrap();
    match &*env {
        None => Err(CtxError::Uninitialized),
        Some(te) => {
            let allowed = matches!(te.level, ThreadLevel::Serialized | ThreadLevel::Multiple)
                || std::thread::current().id() == te.home;
            if allowed {
                let c = te.ctx.clone();
                drop(env);
                install_ctx(c.clone());
                Ok(c)
            } else {
                Err(CtxError::LevelForbids { level: te.level })
            }
        }
    }
}

/// Install the calling thread's context *and* publish the process-wide
/// thread environment at `level` (what [`shmem_init_thread`] does after
/// building the world; thread-level tests call it directly). Returns the
/// provided level — on shared memory every level is supportable, so the
/// request is granted verbatim.
pub fn install_ctx_thread(ctx: Ctx, level: ThreadLevel) -> ThreadLevel {
    install_ctx(ctx.clone());
    *THREAD_ENV.lock().unwrap() =
        Some(ThreadEnv { ctx, level, home: std::thread::current().id() });
    level
}

/// Remove both the calling thread's context and the process-wide thread
/// environment (finalize-time teardown).
pub fn clear_ctx_thread() {
    clear_ctx();
    *THREAD_ENV.lock().unwrap() = None;
}

/// `shmem_query_thread`: the thread level the job was initialised at, or
/// `None` before initialisation.
pub fn shmem_query_thread() -> Option<ThreadLevel> {
    THREAD_ENV.lock().unwrap().as_ref().map(|te| te.level)
}

/// `shmem_init` (OpenSHMEM 1.2 naming): initialise the library from the
/// `oshrun` environment (process mode), install the implicit context, and
/// park the world in a process-global slot so [`shmem_finalize`] can tear
/// it down deterministically. Returns the context for callers that also
/// want the explicit API.
///
/// Initialisation also resolves the job's tuning engine (the fitted
/// `T(n) = α + n/β` channel model behind adaptive collective selection):
/// rank 0 postulates it from `POSH_ALPHA_NS`/`POSH_BETA_GBPS` or runs the
/// fast micro-calibration, and publishes the result through its heap
/// header so every PE selects identically — see
/// [`crate::collectives::tuning`] and `docs/tuning.md`.
pub fn shmem_init() -> crate::Result<Ctx> {
    shmem_init_thread(ThreadLevel::Single).map(|(c, _)| c)
}

/// `shmem_init_thread` (OpenSHMEM 1.4 §9.2): initialise like [`shmem_init`]
/// and establish the job's thread level. Returns the context plus the
/// *provided* level — on a shared-memory node every level is supportable
/// (the sharded NBI queues make even `MULTIPLE`'s concurrent hot paths
/// lock-free), so the request is granted as asked. Under
/// `Serialized`/`Multiple`, any spawned thread may call the SHMEM API: its
/// first call bootstraps a thread-local context clone from the process
/// environment. Under `Single`/`Funneled`, calls from other threads fail
/// with [`CtxError::LevelForbids`].
pub fn shmem_init_thread(requested: ThreadLevel) -> crate::Result<(Ctx, ThreadLevel)> {
    let world = World::from_env()?;
    let c = world.my_ctx();
    let provided = install_ctx_thread(c.clone(), requested);
    *WORLD_SLOT.lock().unwrap() = Some(world);
    Ok((c, provided))
}

/// `shmem_finalize`: complete outstanding communication (the spec makes
/// finalize collective — a quiet plus a barrier), release the implicit
/// context, and drop the world created by [`shmem_init`]. Once the last
/// handle is gone the segments unmap and the ones this process owns unlink
/// — a clean shutdown instead of the historical leak-to-exit. Callers that
/// retain the `Ctx` returned by `shmem_init` (or a `Team`/`CommCtx` built
/// from it) keep the world alive until those handles drop too; the plain
/// C-style pattern (implicit context only) tears down here. Idempotent; a
/// no-op if `shmem_init` was never called.
pub fn shmem_finalize() {
    if let Some(c) = CURRENT.with(|c| c.borrow().clone()) {
        c.quiet_nbi();
        c.barrier_all();
    }
    clear_ctx_thread();
    *WORLD_SLOT.lock().unwrap() = None;
}

/// `start_pes(0)`: the OpenSHMEM 1.0 initialiser.
#[deprecated(note = "use shmem_init()/shmem_finalize(); start_pes leaked the world by design")]
pub fn start_pes(_npes_ignored: usize) -> crate::Result<Ctx> {
    shmem_init()
}

/// `shmem_my_pe` / `_my_pe`.
pub fn shmem_my_pe() -> i32 {
    ctx().my_pe() as i32
}

/// `shmem_n_pes` / `_num_pes`.
pub fn shmem_n_pes() -> i32 {
    ctx().n_pes() as i32
}

/// `shmalloc`: untyped symmetric allocation of `size` bytes.
pub fn shmalloc(size: usize) -> crate::Result<SymPtr<u8>> {
    let c = ctx();
    let p = c.heap().alloc_bytes(size, 16)?;
    c.barrier_all();
    Ok(p)
}

/// `shmemalign`.
pub fn shmemalign(align: usize, size: usize) -> crate::Result<SymPtr<u8>> {
    let c = ctx();
    let p = c.heap().alloc_bytes(size, align)?;
    c.barrier_all();
    Ok(p)
}

/// `shfree`.
pub fn shfree<T>(ptr: SymPtr<T>) -> crate::Result<()> {
    ctx().shfree(ptr)
}

/// `shmem_barrier_all`: synchronise every PE and complete all outstanding
/// memory updates (retiring the default NBI domain's accounting).
pub fn shmem_barrier_all() {
    ctx().barrier_all();
}

/// `shmem_sync_all` (OpenSHMEM 1.5): synchronise every PE **without** the
/// implicit quiet — the cheap, control-flow-only path.
pub fn shmem_sync_all() {
    ctx().sync_all();
}

/// `shmem_barrier(PE_start, logPE_stride, PE_size, pSync)` — `pSync` is
/// accepted for source compatibility and ignored (coordination runs over
/// header cells; see module docs of [`crate::collectives`]).
#[deprecated(note = "OpenSHMEM 1.0 active-set interface; use shmem_team_sync over a split team")]
pub fn shmem_barrier(pe_start: usize, log_pe_stride: usize, pe_size: usize, _psync: &[i64]) {
    let c = ctx();
    let team = Team::from_triplet(&c, pe_start, log_pe_stride, pe_size);
    c.barrier(&team);
}

/// `shmem_fence` (default context).
pub fn shmem_fence() {
    ctx().fence();
}

/// `shmem_quiet` (default context): completes all outstanding puts and
/// retires the default context's NBI accounting. Explicit contexts quiesce
/// through [`shmem_ctx_quiet`].
pub fn shmem_quiet() {
    ctx().quiet_nbi();
}

// ---------------------------------------------------------------------------
// Teams (OpenSHMEM 1.4 §9).
// ---------------------------------------------------------------------------

/// `SHMEM_TEAM_WORLD`: the world team handle.
pub fn shmem_team_world() -> Team {
    ctx().team_world()
}

/// `shmem_team_split_strided(parent, start, stride, size)`: collectively
/// split a sub-team; returns the new handle for members, `None` otherwise.
/// Unlike the 1.0 triplet, `stride` is an arbitrary positive integer.
pub fn shmem_team_split_strided(
    parent: &Team,
    start: usize,
    stride: usize,
    size: usize,
) -> Option<Team> {
    parent.split_strided(start, stride, size)
}

/// `shmem_team_split_2d(parent, xrange)`: collectively split the parent
/// into a row-major grid; returns this PE's `(x_team, y_team)`.
pub fn shmem_team_split_2d(parent: &Team, xrange: usize) -> (Team, Team) {
    parent.split_2d(xrange)
}

/// `shmem_team_my_pe`: the calling PE's rank in `team`, or `-1` if it is
/// not a member.
pub fn shmem_team_my_pe(team: &Team) -> i32 {
    if team.is_member() {
        team.my_pe() as i32
    } else {
        -1
    }
}

/// `shmem_team_n_pes`: the number of PEs in `team`.
pub fn shmem_team_n_pes(team: &Team) -> i32 {
    team.n_pes() as i32
}

/// `shmem_team_translate_pe`: translate `pe` of `src_team` into the rank
/// space of `dest_team`; `-1` if the PE is not a member of `dest_team`.
pub fn shmem_team_translate_pe(src_team: &Team, pe: usize, dest_team: &Team) -> i32 {
    match src_team.translate_pe(pe, dest_team) {
        Some(p) => p as i32,
        None => -1,
    }
}

/// `shmem_team_sync` (OpenSHMEM 1.5): synchronise the team's members
/// **without** an implicit quiet — outstanding puts are not guaranteed
/// visible afterwards and no NBI domain is retired. Use
/// [`shmem_team_barrier`] (or a fence/quiet first) when they must be.
pub fn shmem_team_sync(team: &Team) {
    team.sync();
}

/// Team barrier with the classic 1.0 contract: quiet, then synchronise the
/// team's members. (The spec spells this `shmem_barrier` over an active
/// set; the team-handle form is this library's spelling.)
pub fn shmem_team_barrier(team: &Team) {
    team.barrier();
}

/// `shmem_team_destroy`: collectively retire the team and recycle its
/// sync-cell slot.
pub fn shmem_team_destroy(team: Team) {
    team.destroy();
}

// ---------------------------------------------------------------------------
// Communication contexts (OpenSHMEM 1.4 §8).
// ---------------------------------------------------------------------------

/// `shmem_ctx_create` (team form): a context whose ordering domain is
/// `team`. PE arguments to context operations are team-relative.
pub fn shmem_ctx_create(team: &Team, opts: CtxOptions) -> CommCtx {
    CommCtx::create(team, opts)
}

/// `shmem_ctx_destroy`: quiesce and drop a context.
pub fn shmem_ctx_destroy(cctx: CommCtx) {
    cctx.destroy();
}

/// `shmem_ctx_quiet`: complete and retire the NBI operations issued on
/// `cctx` — and only those; sibling contexts and the default context keep
/// their pending operations.
pub fn shmem_ctx_quiet(cctx: &CommCtx) {
    cctx.quiet();
}

/// `shmem_ctx_fence`: order puts issued on `cctx` per destination PE.
pub fn shmem_ctx_fence(cctx: &CommCtx) {
    cctx.fence();
}

// ---------------------------------------------------------------------------
// Team collectives (generic element type via monomorphisation, §4.3).
// ---------------------------------------------------------------------------

/// `shmem_broadcast(team, …)` (1.5 team form): broadcast from team rank
/// `pe_root`; the root's `target` is not written.
pub fn shmem_team_broadcast<T: Copy>(
    team: &Team,
    target: SymPtr<T>,
    source: SymPtr<T>,
    nelems: usize,
    pe_root: usize,
) {
    ctx().broadcast(target, source, nelems, pe_root, team);
}

/// `shmem_fcollect(team, …)` (1.5 team form).
pub fn shmem_team_fcollect<T: Copy>(team: &Team, target: SymPtr<T>, source: SymPtr<T>, nelems: usize) {
    ctx().fcollect(target, source, nelems, team);
}

/// `shmem_collect(team, …)` (1.5 team form): variable contribution sizes;
/// returns the total gathered element count.
pub fn shmem_team_collect<T: Copy>(
    team: &Team,
    target: SymPtr<T>,
    source: SymPtr<T>,
    nelems: usize,
) -> usize {
    ctx().collect(target, source, nelems, team)
}

/// `shmem_alltoall(team, …)` (1.5 team form).
pub fn shmem_team_alltoall<T: Copy>(team: &Team, target: SymPtr<T>, source: SymPtr<T>, nelems: usize) {
    ctx().alltoall(target, source, nelems, team);
}

/// `shmem_broadcast64`-style 1.0 entry (element type via generic
/// monomorphism).
#[deprecated(note = "OpenSHMEM 1.0 active-set interface; use shmem_team_broadcast with a Team")]
pub fn shmem_broadcast<T: Copy>(
    target: SymPtr<T>,
    source: SymPtr<T>,
    nelems: usize,
    pe_root: usize,
    pe_start: usize,
    log_pe_stride: usize,
    pe_size: usize,
) {
    let c = ctx();
    let team = Team::from_triplet(&c, pe_start, log_pe_stride, pe_size);
    c.broadcast(target, source, nelems, pe_root, &team);
}

/// `shmem_fcollect`-style 1.0 entry.
#[deprecated(note = "OpenSHMEM 1.0 active-set interface; use shmem_team_fcollect with a Team")]
pub fn shmem_fcollect<T: Copy>(
    target: SymPtr<T>,
    source: SymPtr<T>,
    nelems: usize,
    pe_start: usize,
    log_pe_stride: usize,
    pe_size: usize,
) {
    let c = ctx();
    let team = Team::from_triplet(&c, pe_start, log_pe_stride, pe_size);
    c.fcollect(target, source, nelems, &team);
}

/// Generates `shmem_<ty>_{p,g,put,get,iput,iget}` — the §4.3 instantiation.
macro_rules! typed_p2p {
    ($($cname:ident : $t:ty),+ $(,)?) => {
        paste_each! { $($cname : $t),+ }
    };
}

// Minimal "paste" substitute: declare per-type modules with fixed fn names.
macro_rules! paste_each {
    ($($cname:ident : $t:ty),+ $(,)?) => {$(
        /// Typed OpenSHMEM entry points for one C data type (§4.3).
        pub mod $cname {
            use super::*;

            /// `shmem_<T>_p(addr, value, pe)`.
            pub fn p(dest: SymPtr<$t>, value: $t, pe: usize) {
                ctx().put_one(dest, value, pe)
            }
            /// `shmem_<T>_g(addr, pe)`.
            pub fn g(src: SymPtr<$t>, pe: usize) -> $t {
                ctx().get_one(src, pe)
            }
            /// `shmem_<T>_put(dest, src, nelems, pe)`.
            pub fn put(dest: SymPtr<$t>, src: &[$t], pe: usize) {
                ctx().put(dest, src, pe)
            }
            /// `shmem_<T>_get(dest, src, nelems, pe)`.
            pub fn get(dest: &mut [$t], src: SymPtr<$t>, pe: usize) {
                ctx().get(dest, src, pe)
            }
            /// `shmem_<T>_iput(dest, src, dst, sst, nelems, pe)`.
            pub fn iput(dest: SymPtr<$t>, src: &[$t], dst: usize, sst: usize, n: usize, pe: usize) {
                ctx().iput(dest, src, dst, sst, n, pe)
            }
            /// `shmem_<T>_iget(dest, src, dst, sst, nelems, pe)`.
            pub fn iget(dest: &mut [$t], src: SymPtr<$t>, dst: usize, sst: usize, n: usize, pe: usize) {
                ctx().iget(dest, src, dst, sst, n, pe)
            }
        }
    )+};
}

typed_p2p!(
    short: i16,
    int: i32,
    long: i64,
    longlong: i64,
    float: f32,
    double: f64,
);

/// Generates `shmem_<ty>_{swap,cswap,fadd,finc,add,inc}` atomics (§4.6).
macro_rules! typed_atomics {
    ($($cname:ident : $t:ty),+ $(,)?) => {$(
        /// Typed atomic entry points for one C data type.
        pub mod $cname {
            use super::super::*;

            /// `shmem_<T>_swap`.
            pub fn swap(target: SymPtr<$t>, value: $t, pe: usize) -> $t {
                ctx().atomic_swap(target, value, pe)
            }
            /// `shmem_<T>_cswap`.
            pub fn cswap(target: SymPtr<$t>, cond: $t, value: $t, pe: usize) -> $t {
                ctx().atomic_cswap(target, cond, value, pe)
            }
            /// `shmem_<T>_fadd`.
            pub fn fadd(target: SymPtr<$t>, value: $t, pe: usize) -> $t {
                ctx().atomic_fadd(target, value, pe)
            }
            /// `shmem_<T>_finc`.
            pub fn finc(target: SymPtr<$t>, pe: usize) -> $t {
                ctx().atomic_finc(target, pe)
            }
            /// `shmem_<T>_add`.
            pub fn add(target: SymPtr<$t>, value: $t, pe: usize) {
                ctx().atomic_add(target, value, pe)
            }
            /// `shmem_<T>_inc`.
            pub fn inc(target: SymPtr<$t>, pe: usize) {
                ctx().atomic_inc(target, pe)
            }
        }
    )+};
}

/// Atomic namespaces (`atomic::int::fadd` ≙ `shmem_int_fadd`).
pub mod atomic {
    typed_atomics!(int: i32, long: i64, longlong: i64);
}

/// `shmem_set_lock`.
pub fn shmem_set_lock(lock: SymPtr<i64>) {
    ctx().set_lock(lock)
}

/// `shmem_clear_lock`.
pub fn shmem_clear_lock(lock: SymPtr<i64>) {
    ctx().clear_lock(lock)
}

/// `shmem_test_lock` (returns 0 when acquired, like the C API).
pub fn shmem_test_lock(lock: SymPtr<i64>) -> i32 {
    if ctx().test_lock(lock) {
        0
    } else {
        1
    }
}

/// Generates, per data type and operator, both the deprecated 1.0
/// `shmem_<ty>_<op>_to_all` active-set reduction and its 1.5
/// `shmem_<ty>_<op>_reduce` team replacement. Operators are passed as bare
/// variant idents and qualified inside the expansion — no anchor imports
/// needed.
macro_rules! typed_reductions {
    ($($cname:ident : $t:ty => [$($old:ident | $new:ident : $op:ident),+ $(,)?]),+ $(,)?) => {$(
        /// Typed reduction entry points for one C data type.
        pub mod $cname {
            use super::super::*;
            $(
                /// `shmem_<T>_<op>_to_all` (OpenSHMEM 1.0 active-set form).
                /// `pWrk`/`pSync` omitted — see module docs.
                #[deprecated(note = "OpenSHMEM 1.0 active-set interface; \
                                     use the `_reduce` team form")]
                pub fn $old(
                    target: SymPtr<$t>,
                    source: SymPtr<$t>,
                    nreduce: usize,
                    pe_start: usize,
                    log_pe_stride: usize,
                    pe_size: usize,
                ) {
                    let c = ctx();
                    let team =
                        crate::team::Team::from_triplet(&c, pe_start, log_pe_stride, pe_size);
                    c.reduce_to_all(
                        target,
                        source,
                        nreduce,
                        crate::collectives::ReduceOp::$op,
                        &team,
                    );
                }

                /// `shmem_<T>_<op>_reduce(team, …)` (OpenSHMEM 1.5 team
                /// form): every member receives the reduction.
                pub fn $new(
                    team: &crate::team::Team,
                    target: SymPtr<$t>,
                    source: SymPtr<$t>,
                    nreduce: usize,
                ) {
                    ctx().reduce_to_all(
                        target,
                        source,
                        nreduce,
                        crate::collectives::ReduceOp::$op,
                        team,
                    );
                }
            )+
        }
    )+};
}

/// Reduction namespaces (`reduce::int::sum_reduce` ≙ `shmem_int_sum_reduce`,
/// `reduce::int::sum_to_all` ≙ the deprecated `shmem_int_sum_to_all`).
pub mod reduce {
    typed_reductions!(
        short: i16 => [
            sum_to_all | sum_reduce: Sum, prod_to_all | prod_reduce: Prod,
            min_to_all | min_reduce: Min, max_to_all | max_reduce: Max,
            and_to_all | and_reduce: And, or_to_all | or_reduce: Or,
            xor_to_all | xor_reduce: Xor,
        ],
        int: i32 => [
            sum_to_all | sum_reduce: Sum, prod_to_all | prod_reduce: Prod,
            min_to_all | min_reduce: Min, max_to_all | max_reduce: Max,
            and_to_all | and_reduce: And, or_to_all | or_reduce: Or,
            xor_to_all | xor_reduce: Xor,
        ],
        long: i64 => [
            sum_to_all | sum_reduce: Sum, prod_to_all | prod_reduce: Prod,
            min_to_all | min_reduce: Min, max_to_all | max_reduce: Max,
            and_to_all | and_reduce: And, or_to_all | or_reduce: Or,
            xor_to_all | xor_reduce: Xor,
        ],
        float: f32 => [
            sum_to_all | sum_reduce: Sum, prod_to_all | prod_reduce: Prod,
            min_to_all | min_reduce: Min, max_to_all | max_reduce: Max,
        ],
        double: f64 => [
            sum_to_all | sum_reduce: Sum, prod_to_all | prod_reduce: Prod,
            min_to_all | min_reduce: Min, max_to_all | max_reduce: Max,
        ],
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};

    /// Run a thread-mode world with the implicit API installed per PE.
    fn with_api(n: usize, f: impl Fn() + Send + Sync) {
        let w = World::threads(n, PoshConfig::small()).unwrap();
        w.run(|c| {
            install_ctx(c);
            f();
            clear_ctx();
        });
    }

    #[test]
    fn c_style_identity_and_p2p() {
        with_api(2, || {
            let me = shmem_my_pe() as usize;
            assert_eq!(shmem_n_pes(), 2);
            let c = ctx();
            let cell = c.shmalloc_n::<i32>(1).unwrap();
            int::p(cell, me as i32 * 11, (me + 1) % 2);
            shmem_barrier_all();
            // The peer's cell holds what *we* wrote into it.
            let peer = (me + 1) % 2;
            assert_eq!(int::g(cell, peer), me as i32 * 11);
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_put_get_arrays() {
        with_api(2, || {
            let c = ctx();
            let buf = c.shmalloc_n::<f64>(8).unwrap();
            if shmem_my_pe() == 0 {
                double::put(buf, &[2.5; 8], 1);
            }
            shmem_barrier_all();
            if shmem_my_pe() == 1 {
                let mut out = [0f64; 8];
                double::get(&mut out, buf, 1);
                assert_eq!(out, [2.5; 8]);
            }
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_atomics_and_locks() {
        with_api(3, || {
            let c = ctx();
            let counter = c.shmalloc_n::<i64>(1).unwrap();
            let lock = c.shmalloc_n::<i64>(1).unwrap();
            for _ in 0..50 {
                atomic::long::add(counter, 1, 0);
            }
            shmem_barrier_all();
            if shmem_my_pe() == 0 {
                assert_eq!(long::g(counter, 0), 150);
            }
            shmem_barrier_all();
            shmem_set_lock(lock);
            shmem_clear_lock(lock);
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_team_reduce_and_broadcast() {
        with_api(4, || {
            let c = ctx();
            let src = c.shmalloc_n::<i32>(4).unwrap();
            let dst = c.shmalloc_n::<i32>(4).unwrap();
            unsafe {
                for s in c.local_mut(src).iter_mut() {
                    *s = shmem_my_pe() + 1;
                }
            }
            shmem_barrier_all();
            let world = shmem_team_world();
            reduce::int::sum_reduce(&world, dst, src, 4);
            assert_eq!(unsafe { c.local(dst) }, &[1 + 2 + 3 + 4; 4][..]);
            shmem_barrier_all();
            shmem_team_broadcast(&world, dst, src, 4, 2);
            if shmem_my_pe() != 2 {
                assert_eq!(unsafe { c.local(dst) }, &[3; 4][..]);
            }
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_team_split_and_sync() {
        with_api(4, || {
            let world = shmem_team_world();
            assert_eq!(shmem_team_n_pes(&world), 4);
            assert_eq!(shmem_team_my_pe(&world), shmem_my_pe());
            // Even ranks 0, 2.
            let evens = shmem_team_split_strided(&world, 0, 2, 2);
            if shmem_my_pe() % 2 == 0 {
                let t = evens.unwrap();
                assert_eq!(shmem_team_my_pe(&t), shmem_my_pe() / 2);
                assert_eq!(
                    shmem_team_translate_pe(&t, shmem_team_my_pe(&t) as usize, &world),
                    shmem_my_pe()
                );
                shmem_team_sync(&t);
                shmem_barrier_all();
                shmem_team_destroy(t);
            } else {
                assert!(evens.is_none());
                shmem_barrier_all();
            }
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_ctx_quiet_scoping() {
        with_api(2, || {
            let c = ctx();
            let world = shmem_team_world();
            let cc = shmem_ctx_create(&world, CtxOptions::new().serialized());
            let buf = c.shmalloc_n::<u8>(4).unwrap();
            let peer = (shmem_my_pe() as usize + 1) % 2;
            cc.put_nbi(buf, &[5; 4], peer);
            c.put_nbi(buf, &[5; 4], peer);
            shmem_ctx_quiet(&cc);
            assert_eq!(cc.pending_nbi(), 0);
            assert_eq!(c.pending_nbi(), 1, "ctx quiet must not retire the default domain");
            shmem_quiet();
            assert_eq!(c.pending_nbi(), 0);
            shmem_ctx_fence(&cc);
            shmem_ctx_destroy(cc);
            shmem_barrier_all();
        });
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_triplet_shims_still_work() {
        with_api(4, || {
            let c = ctx();
            let src = c.shmalloc_n::<i32>(4).unwrap();
            let dst = c.shmalloc_n::<i32>(4).unwrap();
            unsafe {
                for s in c.local_mut(src).iter_mut() {
                    *s = shmem_my_pe() + 1;
                }
            }
            shmem_barrier_all();
            // 1.0 triplet forms, now shims over a temporary legacy team.
            reduce::int::sum_to_all(dst, src, 4, 0, 0, 4);
            assert_eq!(unsafe { c.local(dst) }, &[1 + 2 + 3 + 4; 4][..]);
            shmem_barrier_all();
            shmem_broadcast(dst, src, 4, 2, 0, 0, 4);
            if shmem_my_pe() != 2 {
                assert_eq!(unsafe { c.local(dst) }, &[3; 4][..]);
            }
            shmem_barrier_all();
            let psync = [0i64; 1];
            shmem_barrier(0, 0, 4, &psync);
            shmem_barrier_all();
        });
    }

    /// Serialises every test that reads or writes the process-global
    /// [`THREAD_ENV`] (libtest runs tests on threads of one process).
    /// Poison-tolerant: `missing_ctx_panics` unwinds while holding it.
    static ENV_GUARD: Mutex<()> = Mutex::new(());

    fn env_lock() -> std::sync::MutexGuard<'static, ()> {
        ENV_GUARD.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    #[should_panic(expected = "no SHMEM context")]
    fn missing_ctx_panics() {
        let _g = env_lock();
        clear_ctx_thread();
        let _ = shmem_my_pe();
    }

    /// The uninitialized path is a structured error, not just a panic
    /// string — and its message points at `shmem_init_thread`.
    #[test]
    fn uninitialized_try_ctx_is_structured_error() {
        let _g = env_lock();
        clear_ctx_thread();
        assert_eq!(shmem_query_thread(), None);
        let err = try_ctx().unwrap_err();
        assert_eq!(err, CtxError::Uninitialized);
        let msg = err.to_string();
        assert!(msg.contains("no SHMEM context"), "{msg}");
        assert!(msg.contains("shmem_init_thread"), "{msg}");
    }

    /// `SINGLE` confines the API to the initialising thread: it works
    /// there, and a spawned thread gets `LevelForbids` naming the level
    /// and the fix.
    #[test]
    fn single_level_confines_api_to_home_thread() {
        let _g = env_lock();
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|c| {
            let provided = install_ctx_thread(c, ThreadLevel::Single);
            assert_eq!(provided, ThreadLevel::Single);
            assert_eq!(shmem_query_thread(), Some(ThreadLevel::Single));
            assert_eq!(shmem_my_pe(), 0, "the home thread keeps full API access");
            std::thread::scope(|s| {
                s.spawn(|| {
                    let err = try_ctx().unwrap_err();
                    assert_eq!(err, CtxError::LevelForbids { level: ThreadLevel::Single });
                    let msg = err.to_string();
                    assert!(msg.contains("no SHMEM context"), "{msg}");
                    assert!(msg.contains("Single"), "{msg}");
                    assert!(msg.contains("shmem_init_thread"), "{msg}");
                });
            });
            clear_ctx_thread();
        });
    }

    /// `MULTIPLE` hands every spawned thread a context on first call, with
    /// no per-thread install.
    #[test]
    fn multiple_level_spawned_threads_get_ctx() {
        let _g = env_lock();
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|c| {
            let provided = install_ctx_thread(c, ThreadLevel::Multiple);
            assert_eq!(provided, ThreadLevel::Multiple);
            std::thread::scope(|s| {
                for _ in 0..4 {
                    s.spawn(|| {
                        assert_eq!(shmem_my_pe(), 0);
                        assert_eq!(shmem_n_pes(), 1);
                        assert_eq!(shmem_query_thread(), Some(ThreadLevel::Multiple));
                        // Drop this thread's bootstrapped clone before the
                        // world tears down.
                        clear_ctx();
                    });
                }
            });
            clear_ctx_thread();
        });
    }

    /// Levels order by permitted concurrency (spec §9.2 table).
    #[test]
    fn thread_levels_are_ordered() {
        assert!(ThreadLevel::Single < ThreadLevel::Funneled);
        assert!(ThreadLevel::Funneled < ThreadLevel::Serialized);
        assert!(ThreadLevel::Serialized < ThreadLevel::Multiple);
    }
}
