//! The pre-parser (paper §4.2 "Symmetric static data").
//!
//! "POSH uses a small trick: we put [global static variables] into the
//! symmetric heap at the very beginning of the execution of the program …
//! A specific program, called the *pre-parser*, parses the source code and
//! searches for global variables that are declared as static. It finds out
//! how they must be allocated (size, etc) and generates the appropriate
//! allocation/deallocation code lines" — injected at `start_pes` and before
//! each `return` of `main`.
//!
//! POSH-RS ships the same tool for C sources (`oshrun preparse file.c`):
//!
//! * [`lexer`] strips comments/strings and tokenises;
//! * [`decl`] recognises file-scope object declarations, computes each
//!   object's size/alignment from the C type (incl. arrays and initialiser
//!   counts), and classifies BSS vs data segment;
//! * [`codegen`] emits (a) the allocation/deallocation C lines the paper
//!   describes, (b) the transformed source with those lines spliced in, and
//!   (c) a machine-readable manifest that [`crate::symheap::SymHeap::place_static`]
//!   consumes to reserve the statics area at `start_pes` time.

pub mod codegen;
pub mod decl;
pub mod lexer;

pub use codegen::{transform_source, Manifest};
pub use decl::{parse_declarations, CType, StaticDecl};
