//! **Ablation C** — atomics & locks microbenchmarks (§4.6): remote
//! fetch-add/swap/cswap throughput and lock acquire/release cost under
//! contention levels. Not a paper table, but the data behind its §6 claim
//! that POSH is a platform for studying distributed algorithms (locks and
//! atomics being the named examples).

use posh::bench::{measure, Table};
use posh::pe::{PoshConfig, World};
use std::sync::atomic::{AtomicU64, Ordering};

fn main() {
    // --- Single-PE atomic op costs (no contention). The table is built and
    // printed inside the PE body (measurement happens on the PE's thread).
    let w = World::threads(1, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let cell = ctx.shmalloc_n::<i64>(1).unwrap();
        let row = vec![
            measure(8, 5000, || {
                ctx.atomic_fadd(cell, 1, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.atomic_finc(cell, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.atomic_swap(cell, 7, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.atomic_cswap(cell, 7, 7, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.put_one(cell, 1, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                std::hint::black_box(ctx.get_one(cell, 0));
            })
            .latency_ns(),
        ];
        let mut table = Table::new(
            "Ablation C1: atomic op latency (self, uncontended)",
            "ns/op",
            &["fadd", "finc", "swap", "cswap", "put_one", "get_one"],
        );
        table.row("1 PE", row);
        table.print();
        table.write_csv("ablationC_atomics").unwrap();
    });

    // --- Lock throughput under contention.
    let mut t2 = Table::new(
        "Ablation C2: lock acquire+release under contention",
        "ns/op (PE 0's view)",
        &["spec-ticket-lock", "named-lock"],
    );
    for &n in &[1usize, 2, 4] {
        let w = World::threads(n, PoshConfig::small()).unwrap();
        let spec_ns = AtomicU64::new(0);
        let named_ns = AtomicU64::new(0);
        w.run(|ctx| {
            let lock = ctx.shmalloc_n::<i64>(1).unwrap();
            ctx.barrier_all();
            let m = measure(0, 300, || {
                ctx.with_lock(lock, || {});
            });
            if ctx.my_pe() == 0 {
                spec_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            }
            ctx.barrier_all();
            let m = measure(0, 300, || {
                let _g = ctx.named_lock("bench", 0);
            });
            if ctx.my_pe() == 0 {
                named_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            }
            ctx.barrier_all();
        });
        t2.row(
            &format!("{n} PEs contending"),
            vec![
                spec_ns.load(Ordering::Relaxed) as f64,
                named_ns.load(Ordering::Relaxed) as f64,
            ],
        );
    }
    t2.print();
    t2.write_csv("ablationC_locks").unwrap();
    println!("\ncsv: bench_out/ablationC_atomics.csv, bench_out/ablationC_locks.csv");
}
