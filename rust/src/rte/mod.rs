//! The run-time environment (paper §4.7).
//!
//! "As with any parallel program, the run-time environment of OpenSHMEM is
//! here to: spawn the parallel processes; make sure they know how to
//! communicate with each other; monitor them, and take the appropriate
//! actions if one of them dies; terminate the execution when necessary;
//! forward the IOs and signals through the gateway process."
//!
//! POSH-RS's RTE is the `oshrun` binary (see `rust/src/main.rs`), built on:
//!
//! * [`launcher`] — spawns one child process per PE ("processes are spawned
//!   individually by separate threads" — a worker-thread pool forks the
//!   children, the master waits, the threads are joined), passing contact
//!   information through `POSH_*` environment variables (the segment names
//!   derive from job id + rank, §4.7 "Contact information").
//! * [`gateway`] — the IO-forwarding gateway: each child's stdout/stderr is
//!   piped back and re-emitted by the master, rank-prefixed; stdin can be
//!   fanned to rank 0.
//! * [`monitor`] — watches children; if one dies abnormally, the rest are
//!   terminated (the §4.7 monitoring contract), and stale segments are
//!   unlinked.

pub mod gateway;
pub mod launcher;
pub mod monitor;

pub use launcher::{JobSpec, Launcher};
