//! Multi-PE stress & conformance suite for the team-scalable sync engine.
//!
//! The dissemination team barrier and the per-team mailbox cells exist to
//! make *overlapping* teams synchronise without stealing each other's
//! signals, across slot recycling, under both engines, at high iteration
//! counts. These tests hammer exactly that: concurrent barriers/syncs on
//! overlapping split teams (thousands of iterations in release), randomized
//! team shapes via `split_strided`/`split_2d`, split/destroy churn over the
//! recycled slot pool, and the O(log n) round-count acceptance check.
//!
//! Iteration counts scale down in debug builds so the default `cargo test`
//! stays quick; the CI release job runs the full counts.

use posh::pe::{PoshConfig, TeamBarrierKind, World};
use posh::util::quickcheck::{forall, Gen};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Release-build iteration count, scaled down 8× for debug builds.
fn iters(release: usize) -> usize {
    if cfg!(debug_assertions) {
        (release / 8).max(1)
    } else {
        release
    }
}

/// Overlapping teams sharing a root PE, hammered concurrently: teams
/// {0,1} and {0,2} both have PE 0 as root. With the 1.0 shared set cells
/// this lost arrivals; per-team cells (and per-round mailboxes) must keep
/// every sync exact over thousands of back-to-back rounds.
fn overlapping_hammer(kind: TeamBarrierKind) {
    let rounds = iters(2000);
    let mut cfg = PoshConfig::small();
    cfg.team_barrier = Some(kind);
    let w = World::threads(3, cfg).unwrap();
    let a_pre = AtomicUsize::new(0);
    let b_pre = AtomicUsize::new(0);
    w.run(|ctx| {
        let world = ctx.team_world();
        let a = world.split_strided(0, 1, 2); // PEs {0, 1}
        let b = world.split_strided(0, 2, 2); // PEs {0, 2}
        for round in 1..=rounds {
            match ctx.my_pe() {
                0 => {
                    a_pre.fetch_add(1, Ordering::SeqCst);
                    a.as_ref().unwrap().sync();
                    assert!(a_pre.load(Ordering::SeqCst) >= 2 * round, "team A lost an arrival");
                    b_pre.fetch_add(1, Ordering::SeqCst);
                    b.as_ref().unwrap().sync();
                    assert!(b_pre.load(Ordering::SeqCst) >= 2 * round, "team B lost an arrival");
                }
                1 => {
                    a_pre.fetch_add(1, Ordering::SeqCst);
                    a.as_ref().unwrap().sync();
                    assert!(a_pre.load(Ordering::SeqCst) >= 2 * round, "team A lost an arrival");
                }
                _ => {
                    b_pre.fetch_add(1, Ordering::SeqCst);
                    b.as_ref().unwrap().sync();
                    assert!(b_pre.load(Ordering::SeqCst) >= 2 * round, "team B lost an arrival");
                }
            }
        }
        ctx.barrier_all();
        if let Some(t) = a {
            t.destroy();
        }
        if let Some(t) = b {
            t.destroy();
        }
        ctx.barrier_all();
    });
}

#[test]
fn overlapping_teams_hammer_dissemination() {
    overlapping_hammer(TeamBarrierKind::Dissemination);
}

#[test]
fn overlapping_teams_hammer_linear_fanin() {
    overlapping_hammer(TeamBarrierKind::LinearFanin);
}

/// Randomized strided shapes: two overlapping teams split from random
/// worlds, synced in lockstep with a per-team arrival oracle, with
/// `barrier_all` (the same engine on slot 0) interleaved.
#[test]
fn randomized_strided_shapes_no_cross_team_collision() {
    forall("strided stress", 12, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        // Team A: a prefix; team B: a strided set overlapping A's root.
        let a_size = g.usize_in(1..n_pes + 1);
        let b_stride = g.usize_in(1..4);
        let b_max = (n_pes + b_stride - 1) / b_stride;
        let b_size = g.usize_in(1..b_max + 1);
        let rounds = iters(400);
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let a_pre = AtomicUsize::new(0);
        let b_pre = AtomicUsize::new(0);
        let oks = w.run_collect(|ctx| {
            let world = ctx.team_world();
            let a = world.split_strided(0, 1, a_size);
            let b = world.split_strided(0, b_stride, b_size);
            let mut ok = true;
            for round in 1..=rounds {
                if let Some(t) = &a {
                    a_pre.fetch_add(1, Ordering::SeqCst);
                    t.sync();
                    ok &= a_pre.load(Ordering::SeqCst) >= a_size * round;
                }
                if let Some(t) = &b {
                    b_pre.fetch_add(1, Ordering::SeqCst);
                    t.sync();
                    ok &= b_pre.load(Ordering::SeqCst) >= b_size * round;
                }
                if round % 64 == 0 {
                    ctx.barrier_all();
                }
            }
            ctx.barrier_all();
            if let Some(t) = a {
                t.destroy();
            }
            if let Some(t) = b {
                t.destroy();
            }
            ctx.barrier_all();
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!(
                "lost arrival (n={n_pes}, a_size={a_size}, b=({b_stride},{b_size}))"
            ))
        }
    });
}

/// Randomized 2-D grids: every PE syncs its row team and its column team
/// in alternation — rows and columns overlap pairwise at every PE, the
/// densest overlap pattern the slot machinery supports.
#[test]
fn randomized_2d_grids_row_column_hammer() {
    forall("2d stress", 8, |g: &mut Gen| {
        let n_pes = g.usize_in(2..9);
        let xrange = g.usize_in(1..5);
        let rounds = iters(300);
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let pre = AtomicUsize::new(0);
        let oks = w.run_collect(|ctx| {
            let world = ctx.team_world();
            let (x, y) = world.split_2d(xrange);
            let mut ok = true;
            for round in 1..=rounds {
                pre.fetch_add(1, Ordering::SeqCst);
                x.sync();
                y.sync();
                // After syncing my row AND my column, every PE of both has
                // posted this round's increment; the weakest global claim
                // covering all grid shapes is my own row+column population.
                let floor = x.n_pes().max(y.n_pes()) * round;
                ok &= pre.load(Ordering::SeqCst) >= floor;
            }
            ctx.barrier_all();
            ok &= pre.load(Ordering::SeqCst) == n_pes * rounds;
            x.destroy();
            y.destroy();
            ctx.barrier_all();
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("grid sync lost an arrival (n={n_pes}, xrange={xrange})"))
        }
    });
}

/// Split/destroy churn over the slot pool: recycled slots carry the
/// previous occupant's monotone epochs, which the reset-at-claim in
/// `split_strided` must clear — otherwise a new team's first sync would
/// satisfy its `>=` waits instantly and desynchronise. Alternating team
/// sizes maximise the epoch mismatch.
#[test]
fn slot_recycling_under_churn() {
    let cycles = iters(6 * 32); // several times MAX_TEAMS
    let w = World::threads(4, PoshConfig::small()).unwrap();
    let pre = AtomicUsize::new(0);
    w.run(|ctx| {
        let world = ctx.team_world();
        for k in 0..cycles {
            let size = 2 + (k % 3); // 2, 3, 4 members
            let team = world.split_strided(0, 1, size);
            if let Some(t) = &team {
                // A burst of syncs so the fresh slot's epochs advance past
                // where a smaller predecessor team left them.
                for burst in 1..=3 {
                    pre.fetch_add(1, Ordering::SeqCst);
                    t.sync();
                    assert!(
                        pre.load(Ordering::SeqCst) >= size * burst,
                        "recycled slot lost an arrival (cycle {k}, size {size})"
                    );
                }
            }
            ctx.barrier_all();
            pre.fetch_sub(if team.is_some() { 3 } else { 0 }, Ordering::SeqCst);
            ctx.barrier_all();
            if let Some(t) = team {
                t.destroy();
            }
            ctx.barrier_all();
        }
    });
}

/// The acceptance hook: an 8-member team's dissemination sync completes in
/// exactly ⌈log₂ 8⌉ = 3 rounds — O(log n), not the linear baseline's n − 1
/// — and `barrier_all` reports the same engine over the world team.
#[test]
fn eight_member_team_syncs_in_log_rounds() {
    let w = World::threads(8, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let world = ctx.team_world();
        let team = world.split_strided(0, 1, 8).unwrap();
        team.sync();
        assert_eq!(
            ctx.last_sync_rounds(),
            3,
            "8-member dissemination sync must take ceil(log2 8) = 3 rounds"
        );
        ctx.barrier_all();
        assert_eq!(ctx.last_sync_rounds(), 3, "barrier_all runs the same engine");
        ctx.barrier_all();
        team.destroy();
        ctx.barrier_all();
    });
}

/// Same team, linear baseline: n − 1 serial steps — the number the
/// dissemination engine's 3 rounds are measured against in Ablation B.
#[test]
fn eight_member_linear_baseline_is_n_minus_1() {
    let mut cfg = PoshConfig::small();
    cfg.team_barrier = Some(TeamBarrierKind::LinearFanin);
    let w = World::threads(8, cfg).unwrap();
    w.run(|ctx| {
        let team = ctx.team_world().split_strided(0, 1, 8).unwrap();
        team.sync();
        assert_eq!(ctx.last_sync_rounds(), 7, "linear fan-in serialises through n-1");
        ctx.barrier_all();
        team.destroy();
        ctx.barrier_all();
    });
}

/// Uneven (non-power-of-two) team sizes through the dissemination rounds,
/// repeatedly, with members of the complement set also active.
#[test]
fn odd_sized_teams_dissemination() {
    for &size in &[3usize, 5, 6, 7] {
        let rounds = iters(500);
        let w = World::threads(size + 1, PoshConfig::small()).unwrap();
        let pre = AtomicUsize::new(0);
        w.run(|ctx| {
            let world = ctx.team_world();
            let team = world.split_strided(0, 1, size);
            for round in 1..=rounds {
                if let Some(t) = &team {
                    pre.fetch_add(1, Ordering::SeqCst);
                    t.sync();
                    assert!(pre.load(Ordering::SeqCst) >= size * round, "size {size}");
                }
            }
            ctx.barrier_all();
            if let Some(t) = team {
                t.destroy();
            }
            ctx.barrier_all();
        });
    }
}
