//! Topology-parser battery over the sysfs fixture trees under
//! `tests/fixtures/topology/` — single-node, 2-socket, and a 4-socket box
//! with holes in the node numbering (a memory-only node and a non-node
//! entry mixed in). Every test parses a fixture directory through
//! [`Topology::from_sysfs_root`]; **none depends on the runner's real
//! topology**, which is exactly what lets the suite pass on a single-node
//! CI box while still exercising multi-socket parsing.

use posh::model::{Topology, TopologySource};
use std::path::PathBuf;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/topology")
        .join(name)
}

#[test]
fn single_node_fixture() {
    let t = Topology::from_sysfs_root(fixture("single-node")).expect("fixture parses");
    assert_eq!(t.source, TopologySource::Sysfs);
    assert_eq!(t.sockets(), 1);
    assert_eq!(t.nodes[0].id, 0);
    assert_eq!(t.nodes[0].cpus, (0..8).collect::<Vec<_>>());
    assert_eq!(t.total_cpus(), 8);
    // One socket ⇒ the blocked map is flat: everyone on socket 0, and the
    // per-socket quota is the whole job.
    assert_eq!(t.pes_per_socket(8), 8);
    for pe in 0..8 {
        assert_eq!(t.pe_socket_of(pe, 8), 0);
    }
}

#[test]
fn two_socket_fixture() {
    let t = Topology::from_sysfs_root(fixture("2-socket")).expect("fixture parses");
    assert_eq!(t.source, TopologySource::Sysfs);
    assert_eq!(t.sockets(), 2);
    assert_eq!(t.nodes[0].id, 0);
    assert_eq!(t.nodes[1].id, 1);
    assert_eq!(t.nodes[0].cpus.len(), 16);
    assert_eq!(t.nodes[1].cpus, (16..32).collect::<Vec<_>>());
    assert_eq!(t.total_cpus(), 32);
    // Blocked map, even division: 4 PEs → 2 per socket.
    assert_eq!(t.pes_per_socket(4), 2);
    assert_eq!(
        (0..4).map(|pe| t.pe_socket_of(pe, 4)).collect::<Vec<_>>(),
        vec![0, 0, 1, 1]
    );
    // Ragged division: 5 PEs → ⌈5/2⌉ = 3 per socket → [0,0,0,1,1].
    assert_eq!(t.pes_per_socket(5), 3);
    assert_eq!(
        (0..5).map(|pe| t.pe_socket_of(pe, 5)).collect::<Vec<_>>(),
        vec![0, 0, 0, 1, 1]
    );
}

#[test]
fn four_socket_fixture_with_holes() {
    let t = Topology::from_sysfs_root(fixture("4-socket-holes")).expect("fixture parses");
    assert_eq!(t.source, TopologySource::Sysfs);
    // node1 is memory-only (no cpulist) and `possible` is not a node entry
    // (even though the fixture gives it a cpulist): both are skipped, and
    // the surviving ids keep their holes.
    assert_eq!(t.sockets(), 4);
    assert_eq!(
        t.nodes.iter().map(|n| n.id).collect::<Vec<_>>(),
        vec![0, 2, 4, 6]
    );
    // node0's cpulist is a two-range SMT pairing ("0-7,64-71").
    let cpus0: Vec<usize> = (0..8).chain(64..72).collect();
    assert_eq!(t.nodes[0].cpus, cpus0);
    assert_eq!(t.total_cpus(), 16 + 16 + 8 + 8);
    // Blocked map over 4 sockets: 8 PEs → 2 per socket.
    assert_eq!(t.pes_per_socket(8), 2);
    assert_eq!(
        (0..8).map(|pe| t.pe_socket_of(pe, 8)).collect::<Vec<_>>(),
        vec![0, 0, 1, 1, 2, 2, 3, 3]
    );
}

#[test]
fn missing_root_falls_back_flat() {
    // A directory that does not exist parses to None …
    assert!(Topology::from_sysfs_root(fixture("no-such-fixture")).is_none());
    // … and the caller-side fallback is the flat single socket.
    let t = Topology::flat();
    assert_eq!(t.source, TopologySource::Flat);
    assert_eq!(t.sockets(), 1);
    for n in [1usize, 2, 8, 1000] {
        assert_eq!(t.pes_per_socket(n), n.max(1));
        assert_eq!(t.pe_socket_of(n.saturating_sub(1), n), 0);
    }
}

#[test]
fn fixture_parse_is_deterministic() {
    // Leader election hangs off this map being a pure function: two
    // independent parses of the same tree must agree exactly (directory
    // enumeration order must not leak into the result).
    for name in ["single-node", "2-socket", "4-socket-holes"] {
        let a = Topology::from_sysfs_root(fixture(name)).unwrap();
        let b = Topology::from_sysfs_root(fixture(name)).unwrap();
        assert_eq!(a, b, "{name}");
    }
}

#[test]
fn blocked_map_is_monotone_and_covers() {
    // For every fixture and job size: socket indices are non-decreasing in
    // world rank (the contiguity the hierarchical schedules' leader
    // intervals rely on), start at 0, and never exceed the socket count.
    for name in ["single-node", "2-socket", "4-socket-holes"] {
        let t = Topology::from_sysfs_root(fixture(name)).unwrap();
        for n_pes in 1..=12usize {
            let map: Vec<usize> = (0..n_pes).map(|pe| t.pe_socket_of(pe, n_pes)).collect();
            assert_eq!(map[0], 0, "{name} n={n_pes}");
            for w in map.windows(2) {
                assert!(w[1] == w[0] || w[1] == w[0] + 1, "{name} n={n_pes}: {map:?}");
            }
            assert!(*map.last().unwrap() < t.sockets(), "{name} n={n_pes}: {map:?}");
        }
    }
}
