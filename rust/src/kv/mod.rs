//! `posh-kv` — a PE-sharded key-value store living entirely in the PGAS
//! symmetric heap.
//!
//! The paper's thesis is that a shared-memory symmetric heap serves
//! one-sided traffic at memcpy speed; `posh-kv` is the first subsystem in
//! this repo that treats the heap as a *serving substrate* rather than a
//! bandwidth testbed. Every byte of store state — skiplist nodes, value
//! blobs, shard headers — lives in symmetric memory, so any PE can read any
//! shard with one-sided operations and no server loop.
//!
//! Architecture (docs/kv.md has the full write-up):
//!
//! * **Sharding** — a key hashes to an *owner PE* (low hash bits) and a
//!   *shard index* on that PE (high hash bits). Each PE owns
//!   [`KvConfig::shards_per_pe`] shards; a shard is one bump arena
//!   allocated from the symmetric heap by a collective
//!   [`KvStore::create`]. By Fact 1 the arena handles are identical on
//!   every PE, so shard `s` of PE `p` is addressable from anywhere.
//! * **Memtable** — each shard holds a skiplist over its arena with a
//!   **fixed node layout** and arena-relative `u32` links, so a remote PE
//!   can walk it with nothing but `shmem_get`-style copies of the arena.
//!   Node heights are derived from the key hash — deterministic, no RNG
//!   state to keep symmetric.
//! * **Local fast path** — a PE operating on its own shard (or any shard,
//!   in shared-memory reach) resolves the arena base once via
//!   [`crate::pe::Ctx`]'s `shmem_ptr` and walks with plain loads/stores.
//! * **Remote reads** — copies through the size-aware planned copy
//!   dispatch ([`crate::pe::Ctx::get`]); no locks taken.
//! * **Remote updates** — bulk bytes travel as NBI puts on the calling
//!   thread's pooled context ([`crate::team::Team::ctx_for_thread`]);
//!   writers serialise on a per-shard [`crate::locks::named`] lock homed
//!   on the owner heap, and publication is flag-after-data: data puts,
//!   quiet, link/value words, quiet, *then* the shard version bump.
//! * **Consistency** — last-writer-wins per key. [`KvStore::put`] returns
//!   the shard-monotonic sequence number assigned under the shard lock;
//!   within a shard, seq order *is* the write order, which makes the LWW
//!   oracle in `tests/kv_store.rs` exact.
//!
//! Values are immutable blobs: an overwrite appends a fresh blob and swings
//! the node's packed value word (one atomic `u64`), so readers never see a
//! torn value. Deletions and arena compaction are out of scope for this
//! slice (the YCSB A/B/C mixes need neither); an exhausted arena makes
//! `put` return an error rather than corrupt state.

mod shard;
mod store;

pub mod driver;
pub mod ycsb;

pub use store::{KvStats, KvStore};

/// Configuration of a [`KvStore`] (symmetric across PEs — every PE must
/// pass an identical config to the collective [`KvStore::create`]).
#[derive(Clone, Debug)]
pub struct KvConfig {
    /// Shards owned by each PE. More shards mean more writer concurrency
    /// (one named lock per shard) and shorter skiplists.
    pub shards_per_pe: usize,
    /// Bytes per shard arena (allocated from the symmetric heap). Must fit
    /// the working set: nodes, keys, and every value version ever written.
    pub arena_bytes: usize,
    /// Maximum key length in bytes (fits the fixed node layout's `u16`).
    pub max_key_len: usize,
    /// Maximum value length in bytes.
    pub max_val_len: usize,
}

impl Default for KvConfig {
    fn default() -> Self {
        Self {
            shards_per_pe: 8,
            arena_bytes: 1 << 20,
            max_key_len: 64,
            max_val_len: 1024,
        }
    }
}

impl KvConfig {
    /// The default configuration (8 shards/PE, 1 MiB arenas).
    pub fn new() -> Self {
        Self::default()
    }

    /// A small footprint for tests: 4 shards/PE, 128 KiB arenas — fits the
    /// `PoshConfig::small()` 4 MiB heap comfortably.
    pub fn small() -> Self {
        Self { shards_per_pe: 4, arena_bytes: 128 * 1024, ..Self::default() }
    }
}

/// FNV-1a of the key — the routing hash (also feeds node heights).
pub(crate) fn key_hash(key: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in key {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Route a key hash: owner PE from the low hash bits, shard index on that
/// PE from the high bits (independent enough under FNV mixing that shard
/// load stays balanced even when `n_pes` divides `2^32`).
pub(crate) fn route(hash: u64, n_pes: usize, shards_per_pe: usize) -> (usize, usize) {
    let pe = (hash & 0xFFFF_FFFF) as usize % n_pes;
    let shard = (hash >> 32) as usize % shards_per_pe;
    (pe, shard)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_stable_and_in_range() {
        for n_pes in [1usize, 2, 3, 8] {
            for shards in [1usize, 4, 8] {
                for k in 0..200u32 {
                    let key = k.to_le_bytes();
                    let h = key_hash(&key);
                    let (pe, s) = route(h, n_pes, shards);
                    assert!(pe < n_pes && s < shards);
                    assert_eq!((pe, s), route(h, n_pes, shards));
                }
            }
        }
    }

    #[test]
    fn routing_spreads_keys() {
        // 4 PEs × 4 shards, 4096 keys: no shard should be empty and none
        // should hold more than ~4× its fair share.
        let mut counts = [[0usize; 4]; 4];
        for k in 0..4096u32 {
            let (pe, s) = route(key_hash(&k.to_le_bytes()), 4, 4);
            counts[pe][s] += 1;
        }
        let fair = 4096 / 16;
        for row in &counts {
            for &c in row {
                assert!(c > 0, "empty shard");
                assert!(c < 4 * fair, "shard holds {c} of 4096 (fair {fair})");
            }
        }
    }
}
