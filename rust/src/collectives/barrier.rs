//! `shmem_barrier` over an active set — the public face of the set barrier,
//! with the §4.5.5 safe-mode bookkeeping wrapped around it.
//!
//! (`shmem_barrier_all` lives in [`crate::sync::barrier`] and uses the
//! faster dissemination algorithm over the header mailboxes; the active-set
//! variant must work for arbitrary subsets, so it fans in on the set root.)

use super::state::ActiveSet;
use crate::pe::Ctx;
use crate::symheap::layout::CollOpTag;

impl Ctx {
    /// `shmem_barrier(PE_start, logPE_stride, PE_size)`: synchronise the
    /// active set and complete all outstanding memory updates.
    pub fn barrier(&self, set: &ActiveSet) {
        let _idx = self.coll_enter(set, CollOpTag::Barrier, 0);
        // barrier_set() opens with a quiet, giving the spec's "complete all
        // outstanding updates" guarantee; coll_exit runs it.
        self.coll_exit(set);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn subset_barrier_synchronises_members_only() {
        let w = World::threads(4, PoshConfig::small()).unwrap();
        let hits = AtomicUsize::new(0);
        w.run(|ctx| {
            let set = ActiveSet::new(0, 0, 2, 4); // PEs 0 and 1
            if set.contains(ctx.my_pe()) {
                for round in 1..=40 {
                    hits.fetch_add(1, Ordering::SeqCst);
                    ctx.barrier(&set);
                    assert!(hits.load(Ordering::SeqCst) >= 2 * round);
                    ctx.barrier(&set);
                }
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn barrier_flushes_puts() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let set = ActiveSet::world(3);
            let cell = ctx.shmalloc_n::<u64>(3).unwrap();
            for round in 1..30u64 {
                let peer = (ctx.my_pe() + 1) % 3;
                ctx.put_one(cell.at(ctx.my_pe()), round, peer);
                ctx.barrier(&set);
                let prev = (ctx.my_pe() + 2) % 3;
                assert_eq!(unsafe { ctx.local(cell)[prev] }, round);
                ctx.barrier(&set);
            }
        });
    }
}
