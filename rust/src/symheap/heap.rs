//! The symmetric heap object: one segment + the deterministic allocator +
//! the statics bump area.
//!
//! `SymHeap` is PE-local state (each PE owns exactly one); the *data* it
//! manages is what remote PEs read and write. The OpenSHMEM-facing
//! `shmalloc`-family entry points live on [`crate::pe::Ctx`], which wraps
//! these methods with the mandatory global barrier (§4.1.1: "memory
//! allocations which are performed in the symmetric heaps end by a call to a
//! global synchronization barrier").

use super::alloc::FreeList;
use super::handle::SymPtr;
use super::layout::{HeapHeader, Layout, MAGIC};
use crate::shm::BoxedSegment;
use crate::Result;
use anyhow::{bail, Context};
use std::sync::atomic::Ordering;
use std::sync::Mutex;

/// One PE's symmetric heap.
pub struct SymHeap {
    seg: BoxedSegment,
    layout: Layout,
    /// Dynamic-area allocator (offsets relative to `layout.heap_off`).
    alloc: Mutex<FreeList>,
    /// Bump cursor for the statics area (§4.2 pre-parser placements).
    statics_cursor: Mutex<usize>,
}

impl SymHeap {
    /// Initialise a heap over a fresh segment. `rank` is stamped into the
    /// header; `ready` is raised last (peers spin on it).
    pub fn new(seg: BoxedSegment, layout: Layout, rank: usize) -> Result<Self> {
        if seg.len() < layout.total {
            bail!(
                "segment too small: {} < layout total {}",
                seg.len(),
                layout.total
            );
        }
        let heap_capacity = layout.total - layout.heap_off;
        let h = Self {
            seg,
            layout,
            alloc: Mutex::new(FreeList::new(heap_capacity)),
            statics_cursor: Mutex::new(0),
        };
        let hdr = h.header();
        hdr.rank.store(rank as u64, Ordering::Relaxed);
        // Seed the team slot pool (only PE 0's copy is ever consulted, but
        // every header carries it so the layout stays rank-independent).
        hdr.team_slot_bitmap
            .store(super::layout::TEAM_SLOT_FREE_INIT, Ordering::Relaxed);
        hdr.magic.store(MAGIC, Ordering::Release);
        hdr.ready.store(1, Ordering::Release);
        Ok(h)
    }

    /// The segment layout.
    pub fn layout(&self) -> Layout {
        self.layout
    }

    /// Base address of the segment in this address space.
    pub fn base(&self) -> *mut u8 {
        self.seg.base()
    }

    /// The heap header (all-atomic, shared with remote PEs).
    pub fn header(&self) -> &HeapHeader {
        // SAFETY: segment is at least `layout.total` ≥ header region.
        unsafe { HeapHeader::at(self.seg.base()) }
    }

    /// The underlying segment.
    pub fn segment(&self) -> &BoxedSegment {
        &self.seg
    }

    /// Allocate `count` elements of `T` in the dynamic area (no barrier —
    /// see `Ctx::shmalloc_n` for the spec-compliant wrapper).
    pub fn alloc_n<T>(&self, count: usize) -> Result<SymPtr<T>> {
        self.alloc_aligned_n(std::mem::align_of::<T>(), count)
    }

    /// `shmemalign` core: allocate `count` elements of `T` at byte alignment
    /// `align`.
    pub fn alloc_aligned_n<T>(&self, align: usize, count: usize) -> Result<SymPtr<T>> {
        let size = count
            .checked_mul(std::mem::size_of::<T>())
            .context("allocation size overflow")?;
        let rel = self
            .alloc
            .lock()
            .unwrap()
            .alloc(size.max(1), align.max(std::mem::align_of::<T>()))?;
        Ok(SymPtr::from_raw(self.layout.heap_off + rel, count))
    }

    /// Raw byte allocation (used by collectives' temporary buffers).
    pub fn alloc_bytes(&self, size: usize, align: usize) -> Result<SymPtr<u8>> {
        let rel = self.alloc.lock().unwrap().alloc(size, align)?;
        Ok(SymPtr::from_raw(self.layout.heap_off + rel, size))
    }

    /// Free an allocation made by any of the `alloc_*` methods.
    pub fn free<T>(&self, ptr: SymPtr<T>) -> Result<()> {
        let off = ptr
            .offset()
            .checked_sub(self.layout.heap_off)
            .context("free of pointer outside the dynamic heap")?;
        self.alloc.lock().unwrap().free(off)
    }

    /// `shrealloc` core: allocate new, copy `min(old,new)`, free old.
    /// Returns the new handle. (OpenSHMEM 1.0 `shrealloc` semantics.)
    pub fn realloc<T>(&self, ptr: SymPtr<T>, new_count: usize) -> Result<SymPtr<T>> {
        let new_ptr = self.alloc_n::<T>(new_count)?;
        let copy_elems = ptr.len().min(new_count);
        // SAFETY: both handles are in-bounds allocations of this segment;
        // they cannot overlap because `alloc_n` returned fresh space.
        unsafe {
            crate::mem::copy_bytes(
                new_ptr.resolve(self.base()) as *mut u8,
                ptr.resolve(self.base()) as *const u8,
                copy_elems * std::mem::size_of::<T>(),
            );
        }
        self.free(ptr)?;
        Ok(new_ptr)
    }

    /// Place a static object in the statics area (pre-parser, §4.2). Bump
    /// allocation — statics are never freed, matching C global lifetime.
    pub fn place_static(&self, size: usize, align: usize) -> Result<SymPtr<u8>> {
        let mut cur = self.statics_cursor.lock().unwrap();
        let start = crate::util::align_up(*cur, align.max(1));
        if start + size > self.layout.statics_size {
            bail!(
                "statics area exhausted: need {size}B at {start}, area is {}B",
                self.layout.statics_size
            );
        }
        *cur = start + size;
        Ok(SymPtr::from_raw(self.layout.statics_off + start, size))
    }

    /// Local address of a handle (the *local* half of Corollary 1).
    ///
    /// # Safety
    /// The handle must be a live allocation of a heap with this layout.
    pub unsafe fn addr<T>(&self, ptr: SymPtr<T>) -> *mut T {
        debug_assert!(ptr.offset() + ptr.byte_len() <= self.layout.total);
        ptr.resolve(self.base())
    }

    /// View a handle as a local mutable slice.
    ///
    /// # Safety
    /// Caller must guarantee no concurrent conflicting remote access (the
    /// SHMEM memory model's race rules).
    pub unsafe fn slice_mut<T>(&self, ptr: SymPtr<T>) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.addr(ptr), ptr.len())
    }

    /// View a handle as a local shared slice.
    ///
    /// # Safety
    /// As [`Self::slice_mut`].
    pub unsafe fn slice<T>(&self, ptr: SymPtr<T>) -> &[T] {
        std::slice::from_raw_parts(self.addr(ptr), ptr.len())
    }

    /// Current allocation-journal hash (Fact-1 cross-check).
    pub fn journal_hash(&self) -> u64 {
        self.alloc.lock().unwrap().journal_hash()
    }

    /// Bytes currently allocated in the dynamic area.
    pub fn allocated_bytes(&self) -> usize {
        self.alloc.lock().unwrap().allocated
    }

    /// Allocator statistics snapshot: live/free block counts, fragmentation,
    /// per-size-class occupancy (surfaced by `oshrun info`).
    pub fn alloc_stats(&self) -> super::alloc::AllocStats {
        self.alloc.lock().unwrap().stats()
    }

    /// Number of live dynamic allocations.
    pub fn live_allocations(&self) -> usize {
        self.alloc.lock().unwrap().live_count()
    }

    /// Run the allocator's internal invariant check (tests / safe mode).
    pub fn check_allocator(&self) -> Result<()> {
        self.alloc.lock().unwrap().check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shm::create_inproc;
    use crate::symheap::layout::DEFAULT_STATICS_SIZE;

    fn mkheap(rank: usize) -> SymHeap {
        let layout = Layout::compute(1 << 20, DEFAULT_STATICS_SIZE);
        let seg = create_inproc(layout.total).unwrap();
        SymHeap::new(seg, layout, rank).unwrap()
    }

    #[test]
    fn header_initialised() {
        let h = mkheap(5);
        assert_eq!(h.header().magic.load(Ordering::Acquire), MAGIC);
        assert_eq!(h.header().rank.load(Ordering::Relaxed), 5);
        assert_eq!(h.header().ready.load(Ordering::Acquire), 1);
    }

    #[test]
    fn alloc_write_read() {
        let h = mkheap(0);
        let p = h.alloc_n::<u64>(16).unwrap();
        unsafe {
            let s = h.slice_mut(p);
            for (i, x) in s.iter_mut().enumerate() {
                *x = i as u64 * 3;
            }
            let r = h.slice(p);
            assert_eq!(r[15], 45);
        }
        h.free(p).unwrap();
        h.check_allocator().unwrap();
    }

    #[test]
    fn fact1_two_heaps_same_offsets() {
        // The heart of Fact 1: identical call sequences on two heaps yield
        // identical handles.
        let a = mkheap(0);
        let b = mkheap(1);
        let pa1 = a.alloc_n::<i32>(100).unwrap();
        let pb1 = b.alloc_n::<i32>(100).unwrap();
        assert_eq!(pa1, pb1);
        let pa2 = a.alloc_aligned_n::<f64>(256, 7).unwrap();
        let pb2 = b.alloc_aligned_n::<f64>(256, 7).unwrap();
        assert_eq!(pa2, pb2);
        a.free(pa1).unwrap();
        b.free(pb1).unwrap();
        let pa3 = a.alloc_n::<u8>(10).unwrap();
        let pb3 = b.alloc_n::<u8>(10).unwrap();
        assert_eq!(pa3, pb3);
        assert_eq!(a.journal_hash(), b.journal_hash());
    }

    #[test]
    fn realloc_preserves_prefix() {
        let h = mkheap(0);
        let p = h.alloc_n::<u32>(8).unwrap();
        unsafe {
            h.slice_mut(p).copy_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
        }
        let q = h.realloc(p, 16).unwrap();
        unsafe {
            assert_eq!(&h.slice(q)[..8], &[1, 2, 3, 4, 5, 6, 7, 8]);
        }
        let r = h.realloc(q, 4).unwrap();
        unsafe {
            assert_eq!(h.slice(r), &[1, 2, 3, 4]);
        }
        h.free(r).unwrap();
        assert_eq!(h.allocated_bytes(), 0);
    }

    #[test]
    fn statics_bump_and_exhaustion() {
        let layout = Layout::compute(1 << 16, 8192);
        let seg = create_inproc(layout.total).unwrap();
        let h = SymHeap::new(seg, layout, 0).unwrap();
        let a = h.place_static(100, 8).unwrap();
        let b = h.place_static(100, 8).unwrap();
        assert!(b.offset() >= a.offset() + 100);
        assert_eq!(a.offset() % 8, 0);
        // statics never land in the dynamic heap
        assert!(b.offset() + 100 <= layout.heap_off);
        assert!(h.place_static(1 << 20, 8).is_err());
    }

    #[test]
    fn statics_and_heap_disjoint() {
        let h = mkheap(0);
        let s = h.place_static(64, 16).unwrap();
        let d = h.alloc_n::<u8>(64).unwrap();
        let (s0, s1) = (s.offset(), s.offset() + 64);
        let (d0, d1) = (d.offset(), d.offset() + 64);
        assert!(s1 <= d0 || d1 <= s0, "statics {s0}..{s1} overlaps heap {d0}..{d1}");
    }

    #[test]
    fn free_foreign_pointer_errors() {
        let h = mkheap(0);
        let bogus: SymPtr<u8> = SymPtr::from_raw(0, 16); // header region
        assert!(h.free(bogus).is_err());
    }
}
