"""Layer 1: tiled matmul Pallas kernel — the transformer's compute hot-spot.

TPU mapping (DESIGN.md §6 Hardware-Adaptation): the block shape `(bm, bk) ×
(bk, bn)` is the VMEM working set (`(bm·bk + bk·bn + bm·bn)·4 B ≤ 16 MiB`)
and the inner `jnp.dot` hits the MXU systolic array. Block last-dims are kept
multiples of 128 (VPU lane width) by the model's shape choices; the grid
expresses the HBM↔VMEM schedule that a CUDA kernel would express with its
threadblock layout.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowers to plain HLO that any backend
(including the Rust-side CPU client) executes. Real-TPU performance is
*estimated* from the footprint in EXPERIMENTS.md §Perf, never from
interpret-mode wallclock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One (bm, bn) output tile. The grid's K axis revisits the same output
    block (its index map ignores k), so the tile accumulates across K steps
    — the classic MXU pipelining pattern, without scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is ≤ `preferred` (lane-friendly for the
    model's multiples-of-128 shapes; degrades gracefully for the odd shapes
    the hypothesis tests throw at it)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def _matmul_raw(x, w, bm: int, bn: int, bk: int):
    """The pallas_call itself (no autodiff rule)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(x, w, bm: int = 128, bn: int = 128, bk: int = 128):
    """`x @ w` via the Pallas kernel. x: [M, K], w: [K, N] -> [M, N] f32.

    Differentiable: the custom VJP routes both cotangent matmuls
    (`dx = g·wᵀ`, `dw = xᵀ·g`) back through the same Pallas kernel, so the
    backward pass of the lowered train_step artifact also runs on the MXU
    tiles.
    """
    return _matmul_raw(x, w, bm, bn, bk)


def _matmul_fwd(x, w, bm, bn, bk):
    return _matmul_raw(x, w, bm, bn, bk), (x, w)


def _matmul_bwd(bm, bn, bk, res, g):
    x, w = res
    dx = _matmul_raw(g, w.T, bm, bn, bk)
    dw = _matmul_raw(x.T, g, bm, bn, bk)
    return dx.astype(x.dtype), dw.astype(w.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step (x tile + w tile + out tile)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
