//! Baseline one-sided communication libraries for the paper's §5.3
//! comparison (Table 3).
//!
//! The paper measures Berkeley UPC, whose shared-memory conduit (GASNet)
//! "uses memcpy to move data". [`upc`] is an independent implementation of
//! that programming model — UPC-style global pointers with per-access
//! affinity resolution — over the same segments POSH uses, so the comparison
//! isolates the *model* overhead (pointer arithmetic + conduit dispatch per
//! access) from the substrate.

pub mod upc;
