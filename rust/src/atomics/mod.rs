//! Remote atomic operations on symmetric objects (§4.6).
//!
//! The paper delegates atomicity to Boost's atomic-functor execution on the
//! managed segment. POSH-RS maps each operation directly onto hardware
//! atomics executed on the target's mapped memory — the origin core performs
//! the RMW on the shared cache line, which is exactly what a shared-memory
//! SHMEM implementation compiles down to. Works identically in thread and
//! process mode (x86 atomics don't care which page table the line came
//! through).
//!
//! Supported (OpenSHMEM 1.0 §8.3): `swap`, `cswap`, `fadd`, `finc`, `add`,
//! `inc` on 32/64-bit integers; `swap` additionally on `f32`/`f64` (the spec
//! allows float swap), implemented as bit-pattern exchange.

use crate::pe::Ctx;
use crate::symheap::SymPtr;
use std::sync::atomic::{AtomicI32, AtomicI64, AtomicU32, AtomicU64, Ordering};

/// Integer types supporting the full remote-atomic set.
pub trait AtomicInt: Copy + Eq + 'static {
    /// Atomic fetch-add at `ptr`.
    ///
    /// # Safety
    /// `ptr` valid, naturally aligned, and only accessed atomically by
    /// concurrent PEs.
    unsafe fn fetch_add_at(ptr: *mut Self, v: Self) -> Self;
    /// Atomic swap at `ptr`.
    ///
    /// # Safety
    /// As [`AtomicInt::fetch_add_at`].
    unsafe fn swap_at(ptr: *mut Self, v: Self) -> Self;
    /// Atomic compare-and-swap; returns the prior value.
    ///
    /// # Safety
    /// As [`AtomicInt::fetch_add_at`].
    unsafe fn cswap_at(ptr: *mut Self, expected: Self, desired: Self) -> Self;
}

macro_rules! impl_atomic_int {
    ($($t:ty => $a:ty),+ $(,)?) => {$(
        impl AtomicInt for $t {
            #[inline]
            unsafe fn fetch_add_at(ptr: *mut Self, v: Self) -> Self {
                (&*(ptr as *const $a)).fetch_add(v, Ordering::AcqRel)
            }
            #[inline]
            unsafe fn swap_at(ptr: *mut Self, v: Self) -> Self {
                (&*(ptr as *const $a)).swap(v, Ordering::AcqRel)
            }
            #[inline]
            unsafe fn cswap_at(ptr: *mut Self, expected: Self, desired: Self) -> Self {
                match (&*(ptr as *const $a)).compare_exchange(
                    expected, desired, Ordering::AcqRel, Ordering::Acquire,
                ) {
                    Ok(prev) => prev,
                    Err(prev) => prev,
                }
            }
        }
    )+};
}

impl_atomic_int!(i32 => AtomicI32, u32 => AtomicU32, i64 => AtomicI64, u64 => AtomicU64);

impl Ctx {
    /// `shmem_<type>_fadd`: atomically add `value` to the symmetric variable
    /// on PE `pe`, returning the prior value.
    pub fn atomic_fadd<T: AtomicInt>(&self, target: SymPtr<T>, value: T, pe: usize) -> T {
        debug_assert!(pe < self.n_pes());
        // SAFETY: in-bounds symmetric cell, atomic access only.
        unsafe { T::fetch_add_at(self.remote_addr(target, pe), value) }
    }

    /// `shmem_<type>_finc`: fetch-and-increment.
    pub fn atomic_finc<T: AtomicInt + From<u8>>(&self, target: SymPtr<T>, pe: usize) -> T {
        self.atomic_fadd(target, T::from(1u8), pe)
    }

    /// `shmem_<type>_add`: add without fetching.
    pub fn atomic_add<T: AtomicInt>(&self, target: SymPtr<T>, value: T, pe: usize) {
        let _ = self.atomic_fadd(target, value, pe);
    }

    /// `shmem_<type>_inc`: increment without fetching.
    pub fn atomic_inc<T: AtomicInt + From<u8>>(&self, target: SymPtr<T>, pe: usize) {
        let _ = self.atomic_finc(target, pe);
    }

    /// `shmem_<type>_swap`: unconditional atomic exchange.
    pub fn atomic_swap<T: AtomicInt>(&self, target: SymPtr<T>, value: T, pe: usize) -> T {
        debug_assert!(pe < self.n_pes());
        // SAFETY: as fadd.
        unsafe { T::swap_at(self.remote_addr(target, pe), value) }
    }

    /// `shmem_<type>_cswap`: compare-and-swap; returns the prior value
    /// (equal to `expected` iff the swap happened).
    pub fn atomic_cswap<T: AtomicInt>(
        &self,
        target: SymPtr<T>,
        expected: T,
        desired: T,
        pe: usize,
    ) -> T {
        debug_assert!(pe < self.n_pes());
        // SAFETY: as fadd.
        unsafe { T::cswap_at(self.remote_addr(target, pe), expected, desired) }
    }

    /// `shmem_float_swap`: atomic exchange of an `f32` (bit-pattern swap).
    pub fn atomic_swap_f32(&self, target: SymPtr<f32>, value: f32, pe: usize) -> f32 {
        let bits: SymPtr<u32> = crate::symheap::SymPtr::from_raw(target.offset(), target.len());
        f32::from_bits(self.atomic_swap(bits, value.to_bits(), pe))
    }

    /// `shmem_double_swap`: atomic exchange of an `f64` (bit-pattern swap).
    pub fn atomic_swap_f64(&self, target: SymPtr<f64>, value: f64, pe: usize) -> f64 {
        let bits: SymPtr<u64> = crate::symheap::SymPtr::from_raw(target.offset(), target.len());
        f64::from_bits(self.atomic_swap(bits, value.to_bits(), pe))
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn concurrent_fadd_sums_exactly() {
        let n = 4;
        let iters = 2_000i64;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let counter = ctx.shmalloc_n::<i64>(1).unwrap();
            for _ in 0..iters {
                ctx.atomic_add(counter, 1, 0); // everyone hammers PE 0
            }
            ctx.barrier_all();
            if ctx.my_pe() == 0 {
                assert_eq!(ctx.get_one(counter, 0), n as i64 * iters);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn finc_returns_unique_tickets() {
        let n = 4;
        let per = 500usize;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        let all = w.run_collect(|ctx| {
            let counter = ctx.shmalloc_n::<u64>(1).unwrap();
            let mut mine = Vec::with_capacity(per);
            for _ in 0..per {
                mine.push(ctx.atomic_finc(counter, 0));
            }
            ctx.barrier_all();
            mine
        });
        let mut tickets: Vec<u64> = all.into_iter().flatten().collect();
        tickets.sort_unstable();
        let expect: Vec<u64> = (0..(n * per) as u64).collect();
        assert_eq!(tickets, expect, "tickets must be a permutation of 0..n*per");
    }

    #[test]
    fn cswap_exactly_one_winner() {
        let n = 4;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        let winners = w.run_collect(|ctx| {
            let cell = ctx.shmalloc_n::<i32>(1).unwrap();
            ctx.barrier_all();
            let prev = ctx.atomic_cswap(cell, 0, ctx.my_pe() as i32 + 1, 0);
            ctx.barrier_all();
            prev == 0
        });
        assert_eq!(winners.iter().filter(|&&won| won).count(), 1);
    }

    #[test]
    fn swap_chains() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let cell = ctx.shmalloc_n::<u64>(1).unwrap();
            assert_eq!(ctx.atomic_swap(cell, 5, 0), 0);
            assert_eq!(ctx.atomic_swap(cell, 9, 0), 5);
            assert_eq!(ctx.get_one(cell, 0), 9);
        });
    }

    #[test]
    fn float_swaps() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let f = ctx.shmalloc_n::<f32>(1).unwrap();
            assert_eq!(ctx.atomic_swap_f32(f, 1.5, 0), 0.0);
            assert_eq!(ctx.atomic_swap_f32(f, -2.25, 0), 1.5);
            let d = ctx.shmalloc_n::<f64>(1).unwrap();
            assert_eq!(ctx.atomic_swap_f64(d, 3.75, 0), 0.0);
            assert_eq!(ctx.get_one(d, 0), 3.75);
        });
    }
}
