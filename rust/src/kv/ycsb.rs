//! YCSB-style workload generation for the KV bench: key-popularity
//! distributions (uniform, zipfian) and the standard read/write mixes.
//!
//! The zipfian generator is Gray et al.'s rejection-free construction (the
//! one the original YCSB `ZipfianGenerator` uses): draw `u ∈ [0,1)`, map it
//! through the closed form of the generalised-harmonic CDF with
//! `θ = 0.99`. Raw zipfian ranks make *low indices* popular, which would
//! let popular keys cluster in one shard; like YCSB's
//! `ScrambledZipfianGenerator` we scatter ranks over the key space with an
//! FNV-style remix, so hot keys land on uniformly-random PEs while keeping
//! the zipfian popularity profile.
//!
//! Everything is seed-deterministic ([`crate::util::prng::Rng`]): PE `p`
//! thread `t` regenerates its exact operation stream from `(seed, p, t)`,
//! which is what lets the LWW oracle replay a run without logging keys.

use crate::util::prng::Rng;

/// Key-popularity distribution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    /// Every key equally likely.
    Uniform,
    /// Zipfian with the YCSB-standard constant θ = 0.99, rank-scrambled.
    Zipfian,
}

impl Distribution {
    /// Parse a CLI token (`uniform` / `zipfian`).
    pub fn parse(s: &str) -> Option<Distribution> {
        match s {
            "uniform" => Some(Distribution::Uniform),
            "zipfian" => Some(Distribution::Zipfian),
            _ => None,
        }
    }
}

/// A YCSB core-workload mix: the read fraction of the operation stream
/// (the rest are writes/updates).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mix {
    /// Short name (`A`, `B`, `C`, `W`) used in reports and JSON.
    pub name: &'static str,
    /// Fraction of operations that are reads, in `[0, 1]`.
    pub read_fraction: f64,
}

/// YCSB-A: update-heavy, 50% reads / 50% writes.
pub const MIX_A: Mix = Mix { name: "A", read_fraction: 0.50 };
/// YCSB-B: read-mostly, 95% reads / 5% writes.
pub const MIX_B: Mix = Mix { name: "B", read_fraction: 0.95 };
/// YCSB-C: read-only.
pub const MIX_C: Mix = Mix { name: "C", read_fraction: 1.0 };
/// Write-heavy complement (5% reads / 95% writes) — not a YCSB core mix,
/// but the stressor that exercises the NBI defer/drain knobs
/// (docs/tuning.md §NBI re-derivation).
pub const MIX_W: Mix = Mix { name: "W", read_fraction: 0.05 };

/// All mixes the driver knows, in report order.
pub const ALL_MIXES: [Mix; 4] = [MIX_A, MIX_B, MIX_C, MIX_W];

impl Mix {
    /// Look up a mix by its short name (case-insensitive).
    pub fn by_name(name: &str) -> Option<Mix> {
        ALL_MIXES.iter().find(|m| m.name.eq_ignore_ascii_case(name)).copied()
    }
}

/// The standard YCSB key of index `i`: `"user"` + a fixed-width number, so
/// every key is the same length (~20 bytes) and lexicographic order is
/// index order.
pub fn key_of(i: usize) -> String {
    format!("user{i:016x}")
}

/// One operation of a generated stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Read the key with this index.
    Read(usize),
    /// Write the key with this index (the driver synthesises the value).
    Write(usize),
}

/// A seed-deterministic stream of YCSB operations over `n_keys` keys.
#[derive(Clone, Debug)]
pub struct Workload {
    dist: Distribution,
    mix: Mix,
    n_keys: usize,
    /// ζ(n, θ): the zipfian normaliser, precomputed once (O(n)).
    zetan: f64,
    /// Gray's closed-form constants.
    theta: f64,
    alpha: f64,
    eta: f64,
    rng: Rng,
}

/// The YCSB-standard zipfian skew constant.
const THETA: f64 = 0.99;

/// Generalised harmonic number ζ(n, θ) = Σ_{i=1..n} 1/i^θ.
fn zeta(n: usize, theta: f64) -> f64 {
    let mut z = 0.0;
    for i in 1..=n {
        z += 1.0 / (i as f64).powf(theta);
    }
    z
}

impl Workload {
    /// Build a stream. `seed` should incorporate PE and thread identity
    /// (e.g. via [`Rng::for_pe`]-style mixing) so streams are independent.
    pub fn new(dist: Distribution, mix: Mix, n_keys: usize, seed: u64) -> Workload {
        assert!(n_keys > 0, "workload over an empty key space");
        let theta = THETA;
        let zetan = zeta(n_keys, theta);
        let zeta2 = zeta(2.min(n_keys), theta);
        let alpha = 1.0 / (1.0 - theta);
        let eta = (1.0 - (2.0 / n_keys as f64).powf(1.0 - theta)) / (1.0 - zeta2 / zetan);
        Workload { dist, mix, n_keys, zetan, theta, alpha, eta, rng: Rng::new(seed) }
    }

    /// The number of distinct keys in the key space.
    pub fn n_keys(&self) -> usize {
        self.n_keys
    }

    /// The mix this stream was built with.
    pub fn mix(&self) -> Mix {
        self.mix
    }

    /// Zipfian *rank* in `[0, n)` — rank 0 is the most popular.
    fn zipf_rank(&mut self) -> usize {
        let u = self.rng.f64();
        let uz = u * self.zetan;
        if uz < 1.0 {
            return 0;
        }
        if uz < 1.0 + 0.5f64.powf(self.theta) {
            return 1;
        }
        let r = (self.n_keys as f64 * (self.eta * u - self.eta + 1.0).powf(self.alpha)) as usize;
        r.min(self.n_keys - 1)
    }

    /// Next key index under the configured distribution.
    pub fn next_key(&mut self) -> usize {
        match self.dist {
            Distribution::Uniform => self.rng.usize_in(0, self.n_keys),
            Distribution::Zipfian => {
                // Scramble the rank so popular keys scatter across shards.
                let rank = self.zipf_rank() as u64;
                let h = rank
                    .wrapping_mul(0x100000001b3)
                    .wrapping_add(0xcbf29ce484222325)
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((h >> 16) % self.n_keys as u64) as usize
            }
        }
    }

    /// Next operation of the stream.
    pub fn next_op(&mut self) -> Op {
        let read = self.rng.f64() < self.mix.read_fraction;
        let k = self.next_key();
        if read {
            Op::Read(k)
        } else {
            Op::Write(k)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_deterministic() {
        let mut a = Workload::new(Distribution::Zipfian, MIX_A, 1000, 42);
        let mut b = Workload::new(Distribution::Zipfian, MIX_A, 1000, 42);
        for _ in 0..500 {
            assert_eq!(a.next_op(), b.next_op());
        }
        let mut c = Workload::new(Distribution::Zipfian, MIX_A, 1000, 43);
        let same = (0..500).filter(|_| a.next_op() == c.next_op()).count();
        assert!(same < 100, "distinct seeds produced near-identical streams");
    }

    #[test]
    fn keys_stay_in_range() {
        for dist in [Distribution::Uniform, Distribution::Zipfian] {
            for n in [1usize, 2, 10, 4096] {
                let mut w = Workload::new(dist, MIX_C, n, 7);
                for _ in 0..2000 {
                    assert!(w.next_key() < n, "{dist:?} n={n}");
                }
            }
        }
    }

    #[test]
    fn zipfian_is_skewed_uniform_is_not() {
        let n = 1000;
        let draws = 100_000;
        let top_share = |dist: Distribution| {
            let mut w = Workload::new(dist, MIX_C, n, 11);
            let mut counts = vec![0usize; n];
            for _ in 0..draws {
                counts[w.next_key()] += 1;
            }
            counts.sort_unstable_by(|a, b| b.cmp(a));
            counts[..10].iter().sum::<usize>() as f64 / draws as f64
        };
        let zipf = top_share(Distribution::Zipfian);
        let unif = top_share(Distribution::Uniform);
        // θ=0.99 over 1000 keys: the hottest 10 keys draw a large share
        // (~35–50%); uniform gives them ~1%.
        assert!(zipf > 0.20, "zipfian top-10 share only {zipf}");
        assert!(unif < 0.05, "uniform top-10 share {unif}");
    }

    #[test]
    fn mix_fractions_hold() {
        for mix in ALL_MIXES {
            let mut w = Workload::new(Distribution::Uniform, mix, 100, 5);
            let reads = (0..20_000)
                .filter(|_| matches!(w.next_op(), Op::Read(_)))
                .count() as f64
                / 20_000.0;
            assert!(
                (reads - mix.read_fraction).abs() < 0.02,
                "mix {} read fraction {reads} (want {})",
                mix.name,
                mix.read_fraction
            );
        }
    }

    #[test]
    fn mix_lookup_and_parse() {
        assert_eq!(Mix::by_name("a").unwrap().name, "A");
        assert_eq!(Mix::by_name("C").unwrap().read_fraction, 1.0);
        assert!(Mix::by_name("z").is_none());
        assert_eq!(Distribution::parse("zipfian"), Some(Distribution::Zipfian));
        assert_eq!(Distribution::parse("uniform"), Some(Distribution::Uniform));
        assert!(Distribution::parse("pareto").is_none());
    }

    #[test]
    fn key_of_is_fixed_width_and_ordered() {
        assert_eq!(key_of(0).len(), 20);
        assert_eq!(key_of(usize::MAX).len(), 20);
        assert!(key_of(3) < key_of(10));
    }
}
