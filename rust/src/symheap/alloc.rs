//! Deterministic first-fit free-list allocator for the symmetric heap.
//!
//! Determinism is the point: Fact 1 (same offsets on every PE) holds iff the
//! allocator is a pure function of the call sequence. Boost's
//! `managed_shared_memory` allocator has this property when calls are
//! symmetric; ours has it unconditionally:
//!
//! * free blocks live in a `BTreeMap<offset, size>` — iteration order is the
//!   address order, so "first fit" is well-defined and stable;
//! * splits always return the *low* part and keep the high remainder free;
//! * frees coalesce with both neighbours immediately.
//!
//! Metadata lives in private memory (not in the shared segment), which keeps
//! the data area byte-exact symmetric and makes corruption-by-remote-write
//! impossible (a deliberate hardening over the paper, recorded in DESIGN.md).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Minimum allocation granularity (bytes). Also the minimum alignment
/// returned by `alloc`. 16 matches `max_align_t` on x86_64 so any C type can
/// live at any allocation start.
pub const MIN_ALIGN: usize = 16;

/// One entry of the allocation journal (safe mode / Fact-1 checking).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JournalOp {
    /// `alloc(size, align) -> offset`
    Alloc { size: usize, align: usize, offset: usize },
    /// `free(offset)`
    Free { offset: usize },
}

/// First-fit free list over a `[0, capacity)` offset space.
#[derive(Debug)]
pub struct FreeList {
    capacity: usize,
    /// offset -> size of each free block, keyed by offset (address order).
    free: BTreeMap<usize, usize>,
    /// offset -> size of each live allocation.
    live: BTreeMap<usize, usize>,
    /// FNV-1a running hash of the journal (cheap cross-PE symmetry check).
    journal_hash: u64,
    /// Full journal (kept only when `record_journal` is set).
    journal: Vec<JournalOp>,
    record_journal: bool,
    /// Total bytes currently allocated.
    pub allocated: usize,
    /// High-water mark.
    pub peak: usize,
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

fn fnv_step(mut h: u64, v: u64) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

impl FreeList {
    /// A fresh allocator over `capacity` bytes (offsets `0..capacity`).
    pub fn new(capacity: usize) -> Self {
        let mut free = BTreeMap::new();
        if capacity > 0 {
            free.insert(0, capacity);
        }
        Self {
            capacity,
            free,
            live: BTreeMap::new(),
            journal_hash: FNV_OFFSET,
            journal: Vec::new(),
            record_journal: cfg!(any(feature = "safe-mode", test)),
            allocated: 0,
            peak: 0,
        }
    }

    /// Total capacity in bytes.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of live allocations.
    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Size of the live allocation at `offset`, if any.
    pub fn size_of(&self, offset: usize) -> Option<usize> {
        self.live.get(&offset).copied()
    }

    /// Running journal hash — equal across PEs iff the call sequences were
    /// identical (the Fact-1 precondition the OpenSHMEM spec §6.4 demands).
    pub fn journal_hash(&self) -> u64 {
        self.journal_hash
    }

    /// The recorded journal (empty unless safe mode or tests).
    pub fn journal(&self) -> &[JournalOp] {
        &self.journal
    }

    /// Allocate `size` bytes at alignment `align` (power of two ≥ 1).
    /// Returns the offset. First fit in address order; deterministic.
    pub fn alloc(&mut self, size: usize, align: usize) -> Result<usize> {
        if size == 0 {
            bail!("alloc of size 0");
        }
        if !align.is_power_of_two() {
            bail!("alignment {align} is not a power of two");
        }
        let align = align.max(MIN_ALIGN);
        let size = crate::util::align_up(size, MIN_ALIGN);
        // First fit: lowest-offset free block that can hold an aligned start.
        let mut found: Option<(usize, usize, usize)> = None; // (blk_off, blk_sz, start)
        for (&boff, &bsz) in &self.free {
            let start = crate::util::align_up(boff, align);
            if start + size <= boff + bsz {
                found = Some((boff, bsz, start));
                break;
            }
        }
        let Some((boff, bsz, start)) = found else {
            bail!(
                "symmetric heap exhausted: need {size}B (align {align}), \
                 {} live allocations, {}B allocated of {}B",
                self.live.len(),
                self.allocated,
                self.capacity
            );
        };
        self.free.remove(&boff);
        // Low remainder (alignment gap) stays free.
        if start > boff {
            self.free.insert(boff, start - boff);
        }
        // High remainder stays free.
        let end = start + size;
        let bend = boff + bsz;
        if bend > end {
            self.free.insert(end, bend - end);
        }
        self.live.insert(start, size);
        self.allocated += size;
        self.peak = self.peak.max(self.allocated);
        self.journal_hash = fnv_step(self.journal_hash, 0x11);
        self.journal_hash = fnv_step(self.journal_hash, size as u64);
        self.journal_hash = fnv_step(self.journal_hash, align as u64);
        self.journal_hash = fnv_step(self.journal_hash, start as u64);
        if self.record_journal {
            self.journal.push(JournalOp::Alloc { size, align, offset: start });
        }
        Ok(start)
    }

    /// Free the allocation starting at `offset`; coalesces with neighbours.
    pub fn free(&mut self, offset: usize) -> Result<()> {
        let Some(size) = self.live.remove(&offset) else {
            bail!("free of unallocated offset {offset}");
        };
        self.allocated -= size;
        let mut off = offset;
        let mut sz = size;
        // Coalesce with the block immediately before…
        if let Some((&poff, &psz)) = self.free.range(..off).next_back() {
            if poff + psz == off {
                self.free.remove(&poff);
                off = poff;
                sz += psz;
            }
        }
        // …and immediately after.
        if let Some(&nsz) = self.free.get(&(off + sz)) {
            self.free.remove(&(off + sz));
            sz += nsz;
        }
        self.free.insert(off, sz);
        self.journal_hash = fnv_step(self.journal_hash, 0x22);
        self.journal_hash = fnv_step(self.journal_hash, offset as u64);
        if self.record_journal {
            self.journal.push(JournalOp::Free { offset });
        }
        Ok(())
    }

    /// Internal consistency check used by tests: free + live blocks tile the
    /// space exactly, with no overlap and no gaps.
    pub fn check_invariants(&self) -> Result<()> {
        let mut regions: Vec<(usize, usize, bool)> = Vec::new();
        for (&o, &s) in &self.free {
            regions.push((o, s, true));
        }
        for (&o, &s) in &self.live {
            regions.push((o, s, false));
        }
        regions.sort();
        let mut cursor = 0usize;
        let mut prev_free = false;
        for (o, s, is_free) in regions {
            if o != cursor {
                bail!("gap or overlap at offset {cursor} (next region at {o})");
            }
            if is_free && prev_free {
                bail!("adjacent free blocks not coalesced at {o}");
            }
            if s == 0 {
                bail!("zero-size region at {o}");
            }
            cursor = o + s;
            prev_free = is_free;
        }
        if cursor != self.capacity {
            bail!("regions end at {cursor}, capacity {}", self.capacity);
        }
        let live_sum: usize = self.live.values().sum();
        if live_sum != self.allocated {
            bail!("allocated counter {} != live sum {live_sum}", self.allocated);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{forall, Gen};

    #[test]
    fn alloc_free_roundtrip() {
        let mut fl = FreeList::new(1 << 16);
        let a = fl.alloc(100, 1).unwrap();
        let b = fl.alloc(200, 1).unwrap();
        assert_ne!(a, b);
        fl.check_invariants().unwrap();
        fl.free(a).unwrap();
        fl.free(b).unwrap();
        fl.check_invariants().unwrap();
        assert_eq!(fl.allocated, 0);
        // After freeing everything the space must be one coalesced block.
        assert_eq!(fl.free.len(), 1);
    }

    #[test]
    fn alignment_respected() {
        let mut fl = FreeList::new(1 << 20);
        let _pad = fl.alloc(24, 1).unwrap();
        for align in [16usize, 32, 64, 128, 4096] {
            let o = fl.alloc(10, align).unwrap();
            assert_eq!(o % align, 0, "align {align}");
        }
        fl.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_errors() {
        let mut fl = FreeList::new(1024);
        let _a = fl.alloc(1000, 1).unwrap();
        assert!(fl.alloc(1000, 1).is_err());
    }

    #[test]
    fn double_free_errors() {
        let mut fl = FreeList::new(4096);
        let a = fl.alloc(64, 1).unwrap();
        fl.free(a).unwrap();
        assert!(fl.free(a).is_err());
    }

    #[test]
    fn free_of_garbage_errors() {
        let mut fl = FreeList::new(4096);
        assert!(fl.free(12345).is_err());
    }

    #[test]
    fn zero_size_rejected() {
        let mut fl = FreeList::new(4096);
        assert!(fl.alloc(0, 1).is_err());
    }

    #[test]
    fn determinism_identical_sequences() {
        // Fact 1's engine-room: two allocators fed the same sequence produce
        // identical offsets and journal hashes.
        forall("allocator determinism", 100, |g: &mut Gen| {
            let mut a = FreeList::new(1 << 18);
            let mut b = FreeList::new(1 << 18);
            let mut live: Vec<usize> = Vec::new();
            for _ in 0..g.usize_in(1..80) {
                if !live.is_empty() && g.bool(0.4) {
                    let idx = g.usize_in(0..live.len());
                    let off = live.swap_remove(idx);
                    a.free(off).map_err(|e| e.to_string())?;
                    b.free(off).map_err(|e| e.to_string())?;
                } else {
                    let size = g.usize_in(1..5000);
                    let align = 1usize << g.usize_in(0..8);
                    let oa = a.alloc(size, align);
                    let ob = b.alloc(size, align);
                    match (oa, ob) {
                        (Ok(x), Ok(y)) => {
                            if x != y {
                                return Err(format!("offsets diverged: {x} vs {y}"));
                            }
                            live.push(x);
                        }
                        (Err(_), Err(_)) => {}
                        _ => return Err("one failed, one succeeded".into()),
                    }
                }
            }
            if a.journal_hash() != b.journal_hash() {
                return Err("journal hashes diverged".into());
            }
            a.check_invariants().map_err(|e| e.to_string())?;
            Ok(())
        });
    }

    #[test]
    fn invariants_hold_under_random_workload() {
        forall("freelist invariants", 100, |g: &mut Gen| {
            let mut fl = FreeList::new(1 << 18);
            let mut live: Vec<usize> = Vec::new();
            for _ in 0..g.usize_in(1..120) {
                if !live.is_empty() && g.bool(0.45) {
                    let idx = g.usize_in(0..live.len());
                    fl.free(live.swap_remove(idx)).map_err(|e| e.to_string())?;
                } else if let Ok(off) = fl.alloc(g.usize_in(1..8000), 1 << g.usize_in(0..7)) {
                    live.push(off);
                }
                fl.check_invariants().map_err(|e| e.to_string())?;
            }
            // Drain everything; space must fully coalesce.
            for off in live {
                fl.free(off).map_err(|e| e.to_string())?;
            }
            fl.check_invariants().map_err(|e| e.to_string())?;
            if fl.allocated != 0 {
                return Err("leak".into());
            }
            Ok(())
        });
    }

    #[test]
    fn journal_hash_detects_divergence() {
        let mut a = FreeList::new(1 << 16);
        let mut b = FreeList::new(1 << 16);
        a.alloc(100, 16).unwrap();
        b.alloc(104, 16).unwrap(); // rounds to same 112? 100->112? No: 100 aligns to 112, 104->112 too
        // sizes differ pre-rounding but journal records the rounded size, so
        // force a real divergence:
        a.alloc(300, 16).unwrap();
        b.alloc(400, 16).unwrap();
        assert_ne!(a.journal_hash(), b.journal_hash());
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut fl = FreeList::new(1 << 16);
        let a = fl.alloc(1024, 1).unwrap();
        let b = fl.alloc(2048, 1).unwrap();
        fl.free(a).unwrap();
        fl.free(b).unwrap();
        assert_eq!(fl.allocated, 0);
        assert!(fl.peak >= 3072);
    }
}
