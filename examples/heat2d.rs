//! 2-D heat diffusion with halo exchange — the classic SHMEM stencil
//! workload (the kind of kernel SHMEM was built for on the Cray T3).
//!
//! The global grid is split into horizontal bands, one per PE. Each Jacobi
//! iteration: exchange boundary rows with neighbours via one-sided `put`,
//! `barrier_all`, then relax the interior. Convergence is checked with a
//! max-reduction over the local residuals.
//!
//! Usage: `heat2d [rows cols iters]` (defaults 256×256×200), thread mode
//! with 4 PEs, or process mode under `oshrun -np K`.

use posh::collectives::ReduceOp;
use posh::pe::{Ctx, PoshConfig, World};

fn pe_body(ctx: Ctx, grid_rows: usize, cols: usize, iters: usize) {
    let n = ctx.n_pes();
    let me = ctx.my_pe();
    // Interior rows of this PE's horizontal band.
    let rows = grid_rows / n + if me < grid_rows % n { 1 } else { 0 };

    // Local band with two halo rows, double-buffered. Symmetric so
    // neighbours can push halos one-sidedly. Allocation calls must be
    // identical on every PE (Fact 1), so size every band for the *largest*
    // one even when grid_rows % n != 0; `rows` governs the local interior.
    let max_rows = grid_rows / n + if grid_rows % n != 0 { 1 } else { 0 };
    let total = (max_rows + 2) * cols;
    let cur = ctx.shmalloc_n::<f64>(total).unwrap();
    let nxt = ctx.shmalloc_n::<f64>(total).unwrap();
    let res_src = ctx.shmalloc_n::<f64>(1).unwrap();
    let res_dst = ctx.shmalloc_n::<f64>(1).unwrap();

    // Initial condition: hot top edge of the global grid, cold elsewhere.
    unsafe {
        let g = ctx.local_mut(cur);
        g.fill(0.0);
        if me == 0 {
            for c in 0..cols {
                g[cols + c] = 100.0; // first interior row of PE 0
            }
        }
        ctx.local_mut(nxt).copy_from_slice(ctx.local(cur));
    }
    ctx.barrier_all();

    let world = ctx.team_world();
    let up = me.checked_sub(1);
    let down = (me + 1 < n).then_some(me + 1);

    let mut src = cur;
    let mut dst = nxt;
    let mut residual = f64::INFINITY;
    for it in 0..iters {
        // --- Halo exchange: push my boundary rows into the neighbours'
        // halo rows (pure one-sided; no receives anywhere).
        let my_first = unsafe { ctx.local(src.slice(cols, cols)).to_vec() };
        let my_last = unsafe { ctx.local(src.slice(rows * cols, cols)).to_vec() };
        if let Some(u) = up {
            // My first interior row becomes u's bottom halo. u has the same
            // row count only if ranks divide evenly; compute u's halo slot
            // from its own row count.
            let u_rows = grid_rows / n + if u < grid_rows % n { 1 } else { 0 };
            ctx.put(src.slice((u_rows + 1) * cols, cols), &my_first, u);
        }
        if let Some(d) = down {
            // My last interior row becomes d's top halo (row 0).
            ctx.put(src.slice(0, cols), &my_last, d);
        }
        ctx.barrier_all();

        // --- Jacobi relaxation of the interior.
        let mut local_max = 0.0f64;
        unsafe {
            let s = ctx.local(src);
            let d = ctx.local_mut(dst);
            for r in 1..=rows {
                // Global boundary rows are Dirichlet: keep them fixed.
                let is_global_top = me == 0 && r == 1;
                let is_global_bottom = down.is_none() && r == rows;
                for c in 0..cols {
                    let idx = r * cols + c;
                    if is_global_top || is_global_bottom || c == 0 || c == cols - 1 {
                        d[idx] = s[idx];
                        continue;
                    }
                    let v = 0.25 * (s[idx - cols] + s[idx + cols] + s[idx - 1] + s[idx + 1]);
                    local_max = local_max.max((v - s[idx]).abs());
                    d[idx] = v;
                }
            }
        }

        // --- Global residual (max-reduction) every 20 iterations.
        if it % 20 == 19 {
            unsafe { ctx.local_mut(res_src)[0] = local_max };
            ctx.reduce_to_all(res_dst, res_src, 1, ReduceOp::Max, &world);
            residual = unsafe { ctx.local(res_dst)[0] };
            if me == 0 {
                println!("iter {:4}  residual {:.6}", it + 1, residual);
            }
            if residual < 1e-4 {
                break;
            }
        }
        std::mem::swap(&mut src, &mut dst);
        ctx.barrier_all();
    }

    // Sanity: heat flows downward — PE 0's band is warmer than the last's.
    let my_mean: f64 = unsafe {
        let g = ctx.local(src);
        g[cols..(rows + 1) * cols].iter().sum::<f64>() / (rows * cols) as f64
    };
    unsafe { ctx.local_mut(res_src)[0] = if me == 0 { my_mean } else { 0.0 } };
    ctx.barrier_all();
    ctx.reduce_to_all(res_dst, res_src, 1, ReduceOp::Sum, &world);
    let top_mean = unsafe { ctx.local(res_dst)[0] };
    unsafe { ctx.local_mut(res_src)[0] = if me == n - 1 { my_mean } else { 0.0 } };
    ctx.barrier_all();
    ctx.reduce_to_all(res_dst, res_src, 1, ReduceOp::Sum, &world);
    let bottom_mean = unsafe { ctx.local(res_dst)[0] };
    if me == 0 {
        println!("top band mean {top_mean:.4}, bottom band mean {bottom_mean:.4}, residual {residual:.6}");
        assert!(
            top_mean >= bottom_mean,
            "heat must not flow uphill: {top_mean} < {bottom_mean}"
        );
        println!("heat2d OK");
    }
    ctx.barrier_all();
}

fn main() -> posh::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rows: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(256);
    let cols: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let iters: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);
    if World::env_present() {
        let world = World::from_env()?;
        pe_body(world.my_ctx(), rows, cols, iters);
    } else {
        let world = World::threads(4, PoshConfig::default())?;
        world.run(|ctx| pe_body(ctx, rows, cols, iters));
    }
    Ok(())
}
