//! Property tests over the collective layer: random team splits (arbitrary
//! strides — beyond what the 1.0 triplet could express), random payloads,
//! every algorithm — results must match a serial oracle, repeated
//! collectives must not interfere (the §4.5.1 reset discipline), the
//! sync-vs-barrier completion contract holds (`shmem_team_sync` implies
//! **no** quiet, team/world barriers do), and adaptive selection never
//! changes collective *semantics*: over random team shapes, `Adaptive`
//! and every forced `AlgoKind` produce identical results — the model only
//! reschedules the data movement.

use posh::collectives::{AlgoKind, ReduceOp};
use posh::pe::{PoshConfig, World};
use posh::util::quickcheck::{forall, Gen};

fn algos(g: &mut Gen) -> AlgoKind {
    g.pick(&[
        AlgoKind::LinearPut,
        AlgoKind::LinearGet,
        AlgoKind::Tree,
        AlgoKind::RecursiveDoubling,
    ])
}

/// Random strided split parameters `(start, stride, size)` within a world
/// of `n_pes` — any stride, not just powers of two.
fn random_split(g: &mut Gen, n_pes: usize) -> (usize, usize, usize) {
    let stride = g.usize_in(1..4);
    let max_size = (n_pes + stride - 1) / stride;
    let size = g.usize_in(1..max_size + 1);
    let max_start = n_pes - (size - 1) * stride;
    let start = g.usize_in(0..max_start);
    (start, stride, size)
}

fn split_members(start: usize, stride: usize, size: usize) -> Vec<usize> {
    (0..size).map(|i| start + i * stride).collect()
}

#[test]
fn reduce_matches_oracle_random_teams() {
    forall("reduce oracle", 25, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let (start, stride, size) = random_split(g, n_pes);
        let nreduce = g.usize_in(1..200);
        let algo = algos(g);
        let op = g.pick(&ReduceOp::all());
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(algo);
        let w = World::threads(n_pes, cfg).unwrap();
        let results = w.run_collect(move |ctx| {
            let src = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            let dst = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = contrib(ctx.my_pe(), j);
                }
                ctx.local_mut(dst).fill(i64::MIN);
            }
            ctx.barrier_all();
            let team = ctx.team_world().split_strided(start, stride, size);
            let out = if let Some(team) = &team {
                ctx.reduce_to_all(dst, src, nreduce, op, team);
                Some(unsafe { ctx.local(dst).to_vec() })
            } else {
                None
            };
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            out
        });
        // Oracle.
        let members = split_members(start, stride, size);
        for j in 0..nreduce {
            let mut acc = contrib(members[0], j);
            for &m in &members[1..] {
                acc = combine(op, acc, contrib(m, j));
            }
            for &m in &members {
                let got = results[m].as_ref().unwrap()[j];
                if got != acc {
                    return Err(format!(
                        "{algo:?} {op:?} split ({start},{stride},{size}) elem {j}: \
                         PE {m} got {got}, want {acc}"
                    ));
                }
            }
        }
        Ok(())
    });
}

fn contrib(pe: usize, j: usize) -> i64 {
    ((pe as i64 + 3) * (j as i64 + 7)) % 41 + 1
}

fn combine(op: ReduceOp, a: i64, b: i64) -> i64 {
    use posh::collectives::reduce::ReduceElem;
    i64::combine(op, a, b)
}

#[test]
fn broadcast_matches_oracle_random_roots() {
    forall("broadcast oracle", 25, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let (start, stride, size) = random_split(g, n_pes);
        let nelems = g.usize_in(1..300);
        let root_idx = g.usize_in(0..size);
        let algo = algos(g);
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(algo);
        let w = World::threads(n_pes, cfg).unwrap();
        let results = w.run_collect(move |ctx| {
            let src = ctx.shmalloc_n::<u64>(nelems).unwrap();
            let dst = ctx.shmalloc_n::<u64>(nelems).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = (ctx.my_pe() * 1_000 + j) as u64;
                }
                ctx.local_mut(dst).fill(u64::MAX);
            }
            ctx.barrier_all();
            let team = ctx.team_world().split_strided(start, stride, size);
            let out = if let Some(team) = &team {
                ctx.broadcast(dst, src, nelems, root_idx, team);
                Some(unsafe { ctx.local(dst).to_vec() })
            } else {
                None
            };
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            out
        });
        let members = split_members(start, stride, size);
        let root_pe = members[root_idx];
        for &m in &members {
            let got = results[m].as_ref().unwrap();
            if m == root_pe {
                if got.iter().any(|&v| v != u64::MAX) {
                    return Err(format!("{algo:?}: root target written"));
                }
            } else {
                for (j, &v) in got.iter().enumerate() {
                    let want = (root_pe * 1_000 + j) as u64;
                    if v != want {
                        return Err(format!(
                            "{algo:?} split ({start},{stride},{size}) root {root_idx}: \
                             PE {m} elem {j} = {v}, want {want}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fcollect_matches_oracle() {
    forall("fcollect oracle", 20, |g: &mut Gen| {
        let n_pes = g.usize_in(2..6);
        let (start, stride, size) = random_split(g, n_pes);
        let nelems = g.usize_in(1..120);
        let algo = algos(g);
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(algo);
        let w = World::threads(n_pes, cfg).unwrap();
        let results = w.run_collect(move |ctx| {
            let src = ctx.shmalloc_n::<u32>(nelems).unwrap();
            let dst = ctx.shmalloc_n::<u32>(nelems * size).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = (ctx.my_pe() * 10_000 + j) as u32;
                }
            }
            ctx.barrier_all();
            let team = ctx.team_world().split_strided(start, stride, size);
            let out = if let Some(team) = &team {
                ctx.fcollect(dst, src, nelems, team);
                Some(unsafe { ctx.local(dst).to_vec() })
            } else {
                None
            };
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            out
        });
        let members = split_members(start, stride, size);
        for &m in &members {
            let got = results[m].as_ref().unwrap();
            for (i, &member) in members.iter().enumerate() {
                for j in 0..nelems {
                    let want = (member * 10_000 + j) as u32;
                    if got[i * nelems + j] != want {
                        return Err(format!(
                            "{algo:?}: PE {m} block {i} elem {j} = {}, want {want}",
                            got[i * nelems + j]
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// Adaptive selection is a *schedule*, not a semantic: over randomized
/// team shapes, payload sizes and operators, running the same collective
/// sequence under `Adaptive` and under every forced `AlgoKind` must yield
/// byte-identical results on every member. The payload sweep deliberately
/// brackets the model's crossover region (tiny → bulk) so the property
/// holds on both sides of every switching threshold.
#[test]
fn adaptive_matches_every_forced_algo() {
    forall("adaptive ≡ forced", 10, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let (start, stride, size) = random_split(g, n_pes);
        // Bracket the crossovers: latency-bound through bandwidth-bound.
        let nelems = g.pick(&[1usize, 7, 64, 700, 3000]);
        let root_idx = g.usize_in(0..size);
        let op = g.pick(&ReduceOp::all());
        type PeOut = Option<(Vec<i64>, Vec<u64>, Vec<u32>)>;
        let run_with = |algo: AlgoKind| -> Vec<PeOut> {
            let mut cfg = PoshConfig::small();
            cfg.coll_algo = Some(algo);
            // A postulated model with crossovers inside the payload sweep,
            // so the adaptive run genuinely switches algorithms.
            cfg.cost_model = Some(posh::model::CostModel::from_alpha_gbps(100.0, 80.0));
            // Roots stage Lemma-1 scratch for linear-put reductions.
            cfg.heap_size = (nelems * 8 * (n_pes + 6)).max(4 << 20);
            let w = World::threads(n_pes, cfg).unwrap();
            w.run_collect(move |ctx| {
                let rsrc = ctx.shmalloc_n::<i64>(nelems).unwrap();
                let rdst = ctx.shmalloc_n::<i64>(nelems).unwrap();
                let bsrc = ctx.shmalloc_n::<u64>(nelems).unwrap();
                let bdst = ctx.shmalloc_n::<u64>(nelems).unwrap();
                let fsrc = ctx.shmalloc_n::<u32>(nelems).unwrap();
                let fdst = ctx.shmalloc_n::<u32>(nelems * size).unwrap();
                unsafe {
                    for j in 0..nelems {
                        ctx.local_mut(rsrc)[j] = contrib(ctx.my_pe(), j);
                        ctx.local_mut(bsrc)[j] = (ctx.my_pe() * 1_000 + j) as u64;
                        ctx.local_mut(fsrc)[j] = (ctx.my_pe() * 10_000 + j) as u32;
                    }
                    ctx.local_mut(rdst).fill(i64::MIN);
                    ctx.local_mut(bdst).fill(u64::MAX);
                    ctx.local_mut(fdst).fill(u32::MAX);
                }
                ctx.barrier_all();
                let team = ctx.team_world().split_strided(start, stride, size);
                let out = if let Some(team) = &team {
                    ctx.reduce_to_all(rdst, rsrc, nelems, op, team);
                    ctx.broadcast(bdst, bsrc, nelems, root_idx, team);
                    ctx.fcollect(fdst, fsrc, nelems, team);
                    Some(unsafe {
                        (
                            ctx.local(rdst).to_vec(),
                            ctx.local(bdst).to_vec(),
                            ctx.local(fdst).to_vec(),
                        )
                    })
                } else {
                    None
                };
                ctx.barrier_all();
                if let Some(team) = team {
                    team.destroy();
                }
                out
            })
        };
        let adaptive = run_with(AlgoKind::Adaptive);
        for forced in AlgoKind::all() {
            let got = run_with(forced);
            if got != adaptive {
                return Err(format!(
                    "adaptive and {forced:?} disagree: split ({start},{stride},{size}), \
                     nelems {nelems}, op {op:?}, root {root_idx}"
                ));
            }
        }
        Ok(())
    });
}

/// Back-to-back mixed collectives on the same world must not interfere —
/// the sharpest test of the reset/§4.5.2 discipline.
#[test]
fn mixed_collective_sequences_are_isolated() {
    forall("mixed sequences", 10, |g: &mut Gen| {
        let n_pes = g.usize_in(2..5);
        let rounds = g.usize_in(3..10);
        let algo = algos(g);
        let seq: Vec<u8> = (0..rounds).map(|_| g.usize_in(0..4) as u8).collect();
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(algo);
        let w = World::threads(n_pes, cfg).unwrap();
        let seq2 = seq.clone();
        let oks = w.run_collect(move |ctx| {
            let n = ctx.n_pes();
            let team = ctx.team_world();
            let a = ctx.shmalloc_n::<i64>(64).unwrap();
            let b = ctx.shmalloc_n::<i64>(64 * n).unwrap();
            let mut ok = true;
            for (round, &kind) in seq2.iter().enumerate() {
                unsafe {
                    for (j, s) in ctx.local_mut(a).iter_mut().enumerate() {
                        *s = (round * 31 + ctx.my_pe() * 7 + j) as i64;
                    }
                }
                match kind {
                    0 => {
                        ctx.reduce_to_all(b.slice(0, 64), a, 64, ReduceOp::Sum, &team);
                        let want: i64 = (0..n).map(|pe| (round * 31 + pe * 7) as i64).sum();
                        ok &= unsafe { ctx.local(b)[0] } == want;
                    }
                    1 => {
                        let root = round % n;
                        ctx.broadcast(b.slice(0, 64), a, 64, root, &team);
                        if ctx.my_pe() != team.world_rank(root) {
                            ok &= unsafe { ctx.local(b)[63] }
                                == (round * 31 + root * 7 + 63) as i64;
                        }
                    }
                    2 => {
                        ctx.fcollect(b, a, 64, &team);
                        for pe in 0..n {
                            ok &= unsafe { ctx.local(b)[pe * 64] }
                                == (round * 31 + pe * 7) as i64;
                        }
                    }
                    _ => {
                        ctx.barrier(&team);
                    }
                }
            }
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("sequence {seq:?} with {algo:?} corrupted data"))
        }
    });
}

/// The guarantee→test rows for the 1.5 completion contract, over random
/// team shapes:
///
/// * `shmem_team_sync` (sync-only) must **not** imply a quiet — pending NBI
///   accounting on the default domain survives it;
/// * a team *barrier* (and `barrier_all`) folds a quiet in and retires it.
#[test]
fn team_sync_is_sync_only_barrier_quiets() {
    forall("sync-only vs barrier", 15, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let stride = g.usize_in(1..4);
        let max_size = (n_pes + stride - 1) / stride;
        let lo = 2usize.min(max_size);
        let size = g.usize_in(lo..max_size + 1);
        let max_start = n_pes - (size - 1) * stride;
        let start = g.usize_in(0..max_start);
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let oks = w.run_collect(move |ctx| {
            let world = ctx.team_world();
            let team = world.split_strided(start, stride, size);
            let mut ok = true;
            if let Some(t) = &team {
                let buf = ctx.heap().alloc_n::<u64>(4).unwrap();
                let next = t.world_rank((t.my_pe() + 1) % t.n_pes());
                for _ in 0..5 {
                    ctx.put_nbi(buf, &[9; 4], next);
                    ok &= ctx.pending_nbi() == 1;
                    t.sync();
                    // Sync-only: the default domain is untouched.
                    ok &= ctx.pending_nbi() == 1;
                    t.sync();
                    ok &= ctx.pending_nbi() == 1;
                    t.barrier();
                    // Barrier = quiet + sync: accounting retired.
                    ok &= ctx.pending_nbi() == 0;
                }
                ctx.heap().free(buf).unwrap();
            }
            ctx.barrier_all();
            ok &= ctx.pending_nbi() == 0;
            if let Some(t) = team {
                t.destroy();
            }
            ctx.barrier_all();
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!(
                "sync/barrier quiet contract violated (split ({start},{stride},{size}))"
            ))
        }
    });
}
