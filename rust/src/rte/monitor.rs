//! Child monitoring and job teardown (§4.7: "Monitor them, and take the
//! appropriate actions if one of them dies; terminate the execution when
//! necessary").

use super::launcher::PeProc;
use crate::shm::naming::heap_segment_name;
use crate::shm::posix::PosixShmSegment;

/// Outcome of a monitored job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobOutcome {
    /// Exit code per rank (`None` = killed by signal).
    pub exit_codes: Vec<Option<i32>>,
    /// First rank that failed, if any.
    pub first_failure: Option<usize>,
}

impl JobOutcome {
    /// Whole-job success.
    pub fn success(&self) -> bool {
        self.first_failure.is_none()
    }

    /// Exit code `oshrun` should return: rank 0's, or the first failure's.
    pub fn job_exit_code(&self) -> i32 {
        match self.first_failure {
            None => 0,
            Some(r) => self.exit_codes[r].unwrap_or(128 + libc::SIGTERM),
        }
    }
}

/// Wait for all PEs; if any exits abnormally, terminate the rest (SIGTERM)
/// — the paper's "take the appropriate actions if one of them dies".
pub fn wait_all(mut pes: Vec<PeProc>) -> JobOutcome {
    let n = pes.len();
    let pgids: Vec<i32> = pes.iter().map(|p| p.child.id() as i32).collect();
    let mut exit_codes: Vec<Option<i32>> = vec![None; n];
    let mut done = vec![false; n];
    let mut first_failure = None;
    let mut remaining = n;
    while remaining > 0 {
        let mut progressed = false;
        for i in 0..n {
            if done[i] {
                continue;
            }
            match pes[i].child.try_wait() {
                Ok(Some(status)) => {
                    done[i] = true;
                    remaining -= 1;
                    progressed = true;
                    exit_codes[i] = status.code();
                    let failed = !status.success();
                    if failed && first_failure.is_none() {
                        first_failure = Some(pes[i].rank);
                        // Kill the rest of the job.
                        for (j, pe) in pes.iter().enumerate() {
                            if !done[j] {
                                // Negative pid ⇒ the whole process group
                                // (the PE and all its descendants).
                                let _ = unsafe {
                                    libc::kill(-(pe.child.id() as libc::pid_t), libc::SIGTERM)
                                };
                            }
                        }
                    }
                }
                Ok(None) => {}
                Err(_) => {
                    done[i] = true;
                    remaining -= 1;
                    progressed = true;
                }
            }
        }
        if !progressed {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }
    if first_failure.is_some() {
        // Sweep any surviving descendants of already-reaped PEs too.
        for pgid in pgids {
            // SAFETY: plain kill(2); ESRCH for gone groups is fine.
            unsafe {
                libc::kill(-pgid, libc::SIGTERM);
            }
        }
    }
    JobOutcome { exit_codes, first_failure }
}

/// Unlink any segments the job may have left behind (crash cleanup). Safe
/// to call unconditionally: names are derived, unlink of absent names is a
/// no-op.
pub fn cleanup_job_segments(job_id: u64, n_pes: usize) {
    for rank in 0..n_pes {
        PosixShmSegment::unlink(&heap_segment_name(job_id, rank));
    }
}

/// Sweep `/dev/shm` for stale POSH segments (any job) — `oshrun --clean`.
/// Returns the names removed.
pub fn sweep_stale_segments() -> Vec<String> {
    let mut removed = Vec::new();
    let Ok(dir) = std::fs::read_dir("/dev/shm") else {
        return removed;
    };
    for entry in dir.flatten() {
        let name = format!("/{}", entry.file_name().to_string_lossy());
        if crate::shm::naming::parse_heap_name(&name).is_some() {
            PosixShmSegment::unlink(&name);
            removed.push(name);
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rte::launcher::{JobSpec, Launcher};

    #[test]
    fn all_success() {
        let mut spec = JobSpec::new(3, "/bin/sh");
        spec.args = vec!["-c".into(), "exit 0".into()];
        let pes = Launcher::new(spec).spawn_all().unwrap();
        let outcome = wait_all(pes);
        assert!(outcome.success());
        assert_eq!(outcome.job_exit_code(), 0);
        assert_eq!(outcome.exit_codes, vec![Some(0); 3]);
    }

    #[test]
    fn one_failure_kills_job() {
        // Rank 1 exits 3 immediately; others would sleep for 100 s if the
        // monitor did not terminate them.
        let mut spec = JobSpec::new(3, "/bin/sh");
        spec.args = vec![
            "-c".into(),
            "if [ \"$POSH_RANK\" = 1 ]; then exit 3; else sleep 100; fi".into(),
        ];
        let t0 = std::time::Instant::now();
        let pes = Launcher::new(spec).spawn_all().unwrap();
        let outcome = wait_all(pes);
        assert!(!outcome.success());
        assert_eq!(outcome.first_failure, Some(1));
        assert_eq!(outcome.job_exit_code(), 3);
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(30),
            "monitor must kill the sleepers"
        );
    }

    #[test]
    fn cleanup_unlinks_segments() {
        let job = crate::shm::naming::fresh_job_id();
        let name = heap_segment_name(job, 0);
        {
            // Create without dropping the owner flag: simulate a crash by
            // forgetting the segment (drop would unlink it).
            let seg = PosixShmSegment::create(&name, 4096).unwrap();
            std::mem::forget(seg);
        }
        assert!(std::path::Path::new(&format!("/dev/shm{name}")).exists());
        cleanup_job_segments(job, 1);
        assert!(!std::path::Path::new(&format!("/dev/shm{name}")).exists());
    }
}
