//! Segment layout: the heap header (synchronisation cells + the paper's
//! §4.5.1 collective data structure), the statics area (§4.2), and the
//! dynamic heap.
//!
//! The header is all atomics — it is concurrently written by *remote* PEs
//! (that is the whole point of one-sided communication), so every field is
//! an `Atomic*` accessed through shared references.

use std::sync::atomic::{AtomicU32, AtomicU64};

/// Segment magic ("POSHHEAP" little-endian-ish).
pub const MAGIC: u64 = 0x504F_5348_4845_4150;

/// log2 of the maximum member count supported by the dissemination sync
/// engine: every [`TeamCell`] carries one mailbox word per round, and the
/// world team (slot 0) is what `shmem_barrier_all` itself runs over.
pub const MAX_SYNC_ROUNDS: usize = 20; // up to 2^20 PEs per team

/// Number of named-lock slots in each header (§4.6 named mutexes).
pub const NAMED_LOCK_SLOTS: usize = 64;

/// Number of team sync-cell slots in each header. Slot 0 is permanently the
/// world team; the rest are claimed/released through the slot bitmap on
/// PE 0's header as teams are split and destroyed.
pub const MAX_TEAMS: usize = 32;

/// Initial value of [`HeapHeader::team_slot_bitmap`]: every slot free
/// (bit set) except slot 0, which is the world team.
pub const TEAM_SLOT_FREE_INIT: u64 = ((1u64 << MAX_TEAMS) - 1) & !1u64;

/// Default size of the statics area (pre-parser output target, §4.2).
pub const DEFAULT_STATICS_SIZE: usize = 1 << 20;

/// Collective operation tags stored in [`CollectiveState::op_type`].
/// (Paper §4.5.1: "a type, that keeps what collective operation is
/// underway".)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum CollOpTag {
    /// No collective in progress.
    None = 0,
    /// Barrier.
    Barrier = 1,
    /// Broadcast.
    Broadcast = 2,
    /// Reduction.
    Reduce = 3,
    /// Fixed-size collect (fcollect).
    Fcollect = 4,
    /// Variable-size collect.
    Collect = 5,
    /// All-to-all (extension).
    Alltoall = 6,
}

impl CollOpTag {
    /// Decode from the stored u32 (unknown values map to `None`).
    pub fn from_u32(v: u32) -> CollOpTag {
        match v {
            1 => CollOpTag::Barrier,
            2 => CollOpTag::Broadcast,
            3 => CollOpTag::Reduce,
            4 => CollOpTag::Fcollect,
            5 => CollOpTag::Collect,
            6 => CollOpTag::Alltoall,
            _ => CollOpTag::None,
        }
    }
}

/// The per-PE collective data structure (paper §4.5.1), one cache line.
///
/// * `buf_offset` — segment offset + 1 of the buffer the collective moves
///   ("a pointer to the buffer"); 0 means null. Offsets, not addresses,
///   cross PE boundaries (Corollary 1 / Boost handles).
/// * `counter` — "counts how many remote processes have accessed the local
///   data".
/// * `op_type` — which collective is underway ([`CollOpTag`]).
/// * `in_progress` — "whether the collective communication is already in
///   progress"; set remotely when a peer initialises our structure before we
///   enter the call (§4.5.2).
/// * `data_size` — "in debug and in safe mode we keep the size of the data
///   buffer" (§4.5.1, §4.5.5). Always present; only *checked* in safe mode.
/// * `seq` — collective epoch, distinguishes successive collectives so a
///   fast PE's next operation cannot be confused with the current one.
#[repr(C, align(128))]
pub struct CollectiveState {
    /// Offset+1 of the data buffer in the owner's segment; 0 = null.
    pub buf_offset: AtomicU64,
    /// Remote-access counter.
    pub counter: AtomicU64,
    /// Current operation tag.
    pub op_type: AtomicU32,
    /// 1 while a collective is underway on this PE (possibly set remotely).
    pub in_progress: AtomicU32,
    /// Byte size of the buffer (safe-mode check).
    pub data_size: AtomicU64,
    /// Collective sequence number.
    pub seq: AtomicU64,
}

/// Ablation/legacy barrier cells. The production `shmem_barrier_all` runs
/// the dissemination engine over the world team's [`TeamCell`] (slot 0) —
/// one engine for every barrier — so what remains here are the central
/// counter (ablation baseline) and the shared 1.0 active-set pair the
/// deprecated triplet shims still use.
#[repr(C, align(128))]
pub struct BarrierCells {
    /// This PE's completed-barrier epoch (monotone; central bookkeeping).
    pub epoch: AtomicU64,
    /// Central-counter barrier (ablation baseline): arrivals this round.
    pub central_count: AtomicU64,
    /// Central barrier sense word.
    pub central_sense: AtomicU64,
    /// Active-set barrier arrivals (root's cell counts its set's members).
    pub set_count: AtomicU64,
    /// Active-set barrier release word (monotone, bumped by the set root).
    pub set_sense: AtomicU64,
}

/// Per-team synchronisation cells, one slot per live team (OpenSHMEM 1.4
/// teams). Giving every team its own cells — instead of the single
/// `set_count`/`set_sense` pair the 1.0 active-set barrier used — is what
/// makes barriers on *overlapping* teams safe: two teams sharing a root PE
/// no longer race on one arrival counter.
///
/// The descriptor triple (`start`/`stride`/`size`, world ranks, `size` 0 =
/// slot unused) is written by every member at split time; safe mode
/// cross-checks it against the team root's copy, turning a membership
/// disagreement (a §6.4-style programmer error) into a loud panic.
///
/// Synchronisation runs through the per-round dissemination mailboxes
/// (`sync_flags`/`sync_epoch`, O(log n) rounds in team-rank space) — the
/// same engine `shmem_barrier_all` uses over the world team's slot 0. The
/// `sync_count`/`sync_sense` pair is the linear fan-in baseline, kept for
/// the Ablation-B A/B comparison (`PoshConfig::team_barrier`).
#[repr(C, align(128))]
pub struct TeamCell {
    /// First world rank of the team's strided membership.
    pub start: AtomicU64,
    /// World-rank stride between consecutive members (≥ 1 when live).
    pub stride: AtomicU64,
    /// Member count; 0 while the slot is unused.
    pub size: AtomicU64,
    /// Broadcast mailbox used during `split_*`: the parent root publishes
    /// the child's slot index here as `slot + 1` (0 = nothing published).
    pub pub_val: AtomicU64,
    /// Generation counter: bumped on this PE each time it joins a team on
    /// this slot and again when it destroys it. A `Team` handle records the
    /// value it saw, so `destroy` can detect a stale clone (slot recycled
    /// or already destroyed) instead of corrupting the current occupant.
    pub gen: AtomicU64,
    /// Linear fan-in arrivals (A/B baseline; counted on the team root's
    /// cell).
    pub sync_count: AtomicU64,
    /// Linear fan-in release word (A/B baseline; monotone, bumped by the
    /// team root).
    pub sync_sense: AtomicU64,
    /// Dissemination mailboxes: `sync_flags[r]` holds the highest epoch
    /// signalled to this member at round `r` by its round-`r` partner in
    /// **team-rank space**. Monotone, so cells need no per-barrier reset;
    /// they are zeroed once when the slot is (re)claimed at split time.
    pub sync_flags: [AtomicU64; MAX_SYNC_ROUNDS],
    /// This member's completed-sync epoch on this slot (monotone).
    pub sync_epoch: AtomicU64,
    /// Re-entrancy guard for `SHMEM_THREAD_MULTIPLE`: CAS'd 0→1 when this
    /// PE enters a sync/barrier on the slot, stored back to 0 on exit. Two
    /// threads of one PE entering the same team's sync concurrently is a
    /// program error (the spec forbids it even at `MULTIPLE`); without the
    /// guard it silently loses an arrival and hangs the team — with it, the
    /// second entry panics at the call site. Lives in what was padding, so
    /// the pinned 256-byte cell size is unchanged.
    pub entry_guard: AtomicU64,
    /// Leader/socket descriptor for the two-level (hierarchical) schedules,
    /// written by every member at split time alongside `start`/`stride`/
    /// `size`: low 32 bits hold the job's blocked PEs-per-socket count + 1
    /// (0 = unstamped, i.e. a pre-hierarchy slot or the world team, which
    /// derives its map from the published job topology), high 32 bits the
    /// team's socket-group count under that map. Because the blocked map is
    /// a pure function of `(world rank, pes_per_socket)` and strided teams
    /// are monotone in world rank, every member computes the same value —
    /// safe mode cross-checks it against the team root's copy exactly like
    /// the membership triple. Lives in what was padding: the pinned 256-byte
    /// cell size is unchanged.
    pub socket_desc: AtomicU64,
}

/// The header at offset 0 of every symmetric-heap segment.
#[repr(C)]
pub struct HeapHeader {
    /// [`MAGIC`] once the owner has initialised the segment.
    pub magic: AtomicU64,
    /// Owner's rank (debugging / sanity checks).
    pub rank: AtomicU64,
    /// Set to 1 when the owner finished initialising its heap; peers spin on
    /// this before first access (the paper's "wait a little bit and try
    /// again" applies to segment *existence*; this flag covers content).
    pub ready: AtomicU32,
    /// Set to 1 when the owner has left the job (clean shutdown signal).
    pub finished: AtomicU32,
    /// Fact-1 cross-check: the owner's allocation-journal hash, refreshed at
    /// every barrier in safe mode.
    pub journal_hash: AtomicU64,
    /// Barrier cells.
    pub barrier: BarrierCells,
    /// The §4.5.1 collective structure.
    pub coll: CollectiveState,
    /// Named-lock words (§4.6): 0 = unlocked, else holder's `rank+1`
    /// in the low 32 bits and a ticket in the high bits.
    pub named_locks: [AtomicU64; NAMED_LOCK_SLOTS],
    /// Per-PE "signal" mailbox used by wait/wait_until tests and the RTE.
    pub mailbox: AtomicU64,
    /// Free team slots as a bitmap (bit t set = slot t free). Only PE 0's
    /// copy is authoritative; teams claim slots with a CAS loop on it.
    pub team_slot_bitmap: AtomicU64,
    /// Published tuning model, α in ns as `f64::to_bits` (process mode:
    /// rank 0 writes at world attach; every rank adopts, so adaptive
    /// collective selection is identical job-wide). Only PE 0's copy is
    /// meaningful.
    pub tuning_alpha_bits: AtomicU64,
    /// Published tuning model, β in bytes/ns as `f64::to_bits`.
    pub tuning_beta_bits: AtomicU64,
    /// Published tuning model, fit R² as `f64::to_bits`.
    pub tuning_r2_bits: AtomicU64,
    /// Published piecewise channel model: 4 regime ranges × (upper bound,
    /// α bits, β bits, R² bits) — the wire form of
    /// [`crate::model::PiecewiseModel`]. Written by rank 0 before the
    /// `tuning_ready` release store; all-zero means "whole-sweep model
    /// only" (a legacy publisher), in which case adopters fall back to a
    /// uniform piecewise view of the three scalar words.
    pub tuning_pw: [AtomicU64; crate::model::piecewise::WIRE_WORDS],
    /// Published cross-socket tier, α in ns as `f64::to_bits` (the second
    /// α/β pair of the two-level collective model). Written by rank 0
    /// before the `tuning_ready` release store, like `tuning_pw`; all three
    /// `tuning_xsock_*` words zero means "flat publisher" (a pre-hierarchy
    /// binary), in which case adopters stay on the flat single-tier model —
    /// selections remain job-wide identical either way.
    pub tuning_xsock_alpha_bits: AtomicU64,
    /// Published cross-socket tier, β in bytes/ns as `f64::to_bits`.
    pub tuning_xsock_beta_bits: AtomicU64,
    /// Published job topology geometry: the blocked PEs-per-socket count
    /// (plain u64; 0 = flat topology, no hierarchical tier). Publishing the
    /// geometry — rather than letting each rank re-detect — is what makes
    /// the PE→socket map agreed job-wide even if ranks see different
    /// environments.
    pub tuning_xsock_geom: AtomicU64,
    /// 0 until the model is published; then the wire encoding of its
    /// [`crate::collectives::TuningSource`]. Peers spin on this before
    /// reading the three `tuning_*_bits` words, `tuning_pw`, and the
    /// `tuning_xsock_*` words.
    pub tuning_ready: AtomicU64,
    /// Per-team sync cells and membership descriptors (OpenSHMEM 1.4 teams).
    pub teams: [TeamCell; MAX_TEAMS],
}

impl HeapHeader {
    /// Size of the header region, rounded to a page so the statics area and
    /// the heap start page-aligned (Fact 1 requires identical bases).
    pub fn region_size() -> usize {
        crate::util::align_up(
            std::mem::size_of::<HeapHeader>(),
            crate::shm::inproc::page_size(),
        )
    }

    /// Reinterpret the start of a segment as the header.
    ///
    /// # Safety
    /// `base` must point at a segment of at least [`Self::region_size`]
    /// bytes, zero-initialised or previously initialised as a header.
    /// All fields are atomics, so concurrent access is sound.
    pub unsafe fn at(base: *mut u8) -> &'static HeapHeader {
        &*(base as *const HeapHeader)
    }
}

/// Computed byte offsets of the three regions of a segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout {
    /// Start of the statics area.
    pub statics_off: usize,
    /// Size of the statics area.
    pub statics_size: usize,
    /// Start of the dynamic heap.
    pub heap_off: usize,
    /// Total segment size.
    pub total: usize,
}

impl Layout {
    /// Compute the layout for a heap of `heap_size` data bytes and a statics
    /// area of `statics_size` bytes.
    pub fn compute(heap_size: usize, statics_size: usize) -> Layout {
        let page = crate::shm::inproc::page_size();
        let statics_off = HeapHeader::region_size();
        let statics_size = crate::util::align_up(statics_size, page);
        let heap_off = statics_off + statics_size;
        let total = heap_off + crate::util::align_up(heap_size, page);
        Layout { statics_off, statics_size, heap_off, total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn header_fits_small_region() {
        // Keep the header compact; if this grows past 4 pages something is
        // wrong (the team table dominates: MAX_TEAMS * 256B with the
        // per-round dissemination mailboxes, then the named-lock table:
        // 64 * 8B).
        assert!(std::mem::size_of::<HeapHeader>() < 16384);
        assert_eq!(HeapHeader::region_size() % crate::shm::inproc::page_size(), 0);
    }

    #[test]
    fn coll_state_is_cacheline_isolated() {
        assert_eq!(std::mem::align_of::<CollectiveState>(), 128);
        assert_eq!(std::mem::align_of::<BarrierCells>(), 128);
        assert_eq!(std::mem::align_of::<TeamCell>(), 128);
    }

    /// Byte offset of `field` within the struct that starts at `base`.
    fn off<B, F>(base: &B, field: &F) -> usize {
        field as *const F as usize - base as *const B as usize
    }

    /// Every `TeamCell` field at its expected offset, the whole cell
    /// 128-byte aligned and exactly two cache lines, and the team table
    /// inside its header budget — so layout drift (a reordered field, a
    /// round-count change, an accidental padding hole) fails loudly instead
    /// of silently desynchronising thread and process mode.
    #[test]
    fn team_cell_layout_pinned() {
        let seg = crate::shm::inproc::InProcSegment::new(HeapHeader::region_size()).unwrap();
        let hdr = unsafe { HeapHeader::at(seg.base()) };
        let cell = &hdr.teams[0];

        assert_eq!(off(cell, &cell.start), 0);
        assert_eq!(off(cell, &cell.stride), 8);
        assert_eq!(off(cell, &cell.size), 16);
        assert_eq!(off(cell, &cell.pub_val), 24);
        assert_eq!(off(cell, &cell.gen), 32);
        assert_eq!(off(cell, &cell.sync_count), 40);
        assert_eq!(off(cell, &cell.sync_sense), 48);
        assert_eq!(off(cell, &cell.sync_flags), 56);
        assert_eq!(off(cell, &cell.sync_flags[1]), 64);
        assert_eq!(off(cell, &cell.sync_epoch), 56 + 8 * MAX_SYNC_ROUNDS);
        // The entry guard fills the first padding word after the epoch; the
        // cell must NOT grow for it.
        assert_eq!(off(cell, &cell.entry_guard), 64 + 8 * MAX_SYNC_ROUNDS);
        // The socket/leader descriptor fills the next padding word; the
        // cell must NOT grow for it either.
        assert_eq!(off(cell, &cell.socket_desc), 72 + 8 * MAX_SYNC_ROUNDS);

        // 7 descriptor/linear words + MAX_SYNC_ROUNDS mailboxes + the epoch
        // + the entry guard + the socket descriptor, rounded up to the
        // 128-byte alignment: exactly 256 bytes today.
        assert_eq!(std::mem::size_of::<TeamCell>(), 256);
        assert_eq!(std::mem::align_of::<TeamCell>(), 128);
        // Consecutive slots are contiguous (no inter-element padding).
        assert_eq!(
            off(hdr, &hdr.teams[1]) - off(hdr, &hdr.teams[0]),
            std::mem::size_of::<TeamCell>()
        );

        // Header budget: the team table must stay within two pages so the
        // whole header keeps fitting `header_fits_small_region`'s bound.
        assert_eq!(MAX_TEAMS * std::mem::size_of::<TeamCell>(), 8192);
        assert!(MAX_TEAMS * std::mem::size_of::<TeamCell>() <= 2 * 4096);
        use crate::shm::Segment;
    }

    /// `BarrierCells` after the one-engine refactor: ablation/legacy words
    /// only, each at its pinned offset, one cache line total.
    #[test]
    fn barrier_cells_layout_pinned() {
        let seg = crate::shm::inproc::InProcSegment::new(HeapHeader::region_size()).unwrap();
        let hdr = unsafe { HeapHeader::at(seg.base()) };
        let b = &hdr.barrier;
        assert_eq!(off(b, &b.epoch), 0);
        assert_eq!(off(b, &b.central_count), 8);
        assert_eq!(off(b, &b.central_sense), 16);
        assert_eq!(off(b, &b.set_count), 24);
        assert_eq!(off(b, &b.set_sense), 32);
        assert_eq!(std::mem::size_of::<BarrierCells>(), 128);
        use crate::shm::Segment;
    }

    #[test]
    fn team_slot_bitmap_init_value() {
        // Slot 0 (world) reserved, everything else free.
        assert_eq!(TEAM_SLOT_FREE_INIT & 1, 0);
        assert_eq!(TEAM_SLOT_FREE_INIT.count_ones() as usize, MAX_TEAMS - 1);
    }

    #[test]
    fn header_view_over_zeroed_memory() {
        let seg = crate::shm::inproc::InProcSegment::new(HeapHeader::region_size()).unwrap();
        let hdr = unsafe { HeapHeader::at(seg.base()) };
        assert_eq!(hdr.magic.load(Ordering::Relaxed), 0);
        hdr.magic.store(MAGIC, Ordering::Release);
        assert_eq!(hdr.magic.load(Ordering::Acquire), MAGIC);
        hdr.teams[0].sync_flags[3].fetch_add(7, Ordering::AcqRel);
        assert_eq!(hdr.teams[0].sync_flags[3].load(Ordering::Relaxed), 7);
        use crate::shm::Segment;
    }

    #[test]
    fn layout_regions_ordered_and_aligned() {
        let l = Layout::compute(1 << 20, 1 << 16);
        let page = crate::shm::inproc::page_size();
        assert!(l.statics_off >= std::mem::size_of::<HeapHeader>());
        assert_eq!(l.statics_off % page, 0);
        assert_eq!(l.heap_off % page, 0);
        assert!(l.heap_off >= l.statics_off + (1 << 16));
        assert!(l.total >= l.heap_off + (1 << 20));
    }

    #[test]
    fn coll_tag_roundtrip() {
        for t in [
            CollOpTag::None,
            CollOpTag::Barrier,
            CollOpTag::Broadcast,
            CollOpTag::Reduce,
            CollOpTag::Fcollect,
            CollOpTag::Collect,
            CollOpTag::Alltoall,
        ] {
            assert_eq!(CollOpTag::from_u32(t as u32), t);
        }
        assert_eq!(CollOpTag::from_u32(999), CollOpTag::None);
    }
}
