//! **Table 2** — "Comparison of the performance observed by put and get
//! operations with POSH": latency (ns) and bandwidth (Gb/s) for get/put,
//! best-copy vs stock-memcpy engines, through the full POSH path
//! (handle → Corollary-1 translation → copy engine).
//!
//! The paper's headline claim, asserted at the bottom: "our peer-to-peer
//! communication engine adds little overhead … inter-process communications
//! are almost as fast as local memory copy operations."

use posh::bench::{auto_batch, measure, Table};
use posh::mem::copy::{copy_bytes_with, CopyImpl};
use posh::model::machines::paper_machines;
use posh::pe::{PoshConfig, World};

const LAT_SIZE: usize = 8;
const BW_SIZE: usize = 64 << 20;

fn best_copy_impl() -> CopyImpl {
    // Quick calibration: the bandwidth-best implementation on this machine.
    let src = vec![1u8; BW_SIZE];
    let mut dst = vec![0u8; BW_SIZE];
    let mut best = (CopyImpl::Stock, 0.0f64);
    for imp in CopyImpl::available() {
        let m = measure(BW_SIZE, 1, || unsafe {
            copy_bytes_with(imp, dst.as_mut_ptr(), src.as_ptr(), BW_SIZE);
        });
        if m.bandwidth_gbps() > best.1 {
            best = (imp, m.bandwidth_gbps());
        }
    }
    best.0
}

fn main() {
    let best = best_copy_impl();
    println!("best copy on this machine: {}", best.name());

    let mut cfg = PoshConfig::default();
    cfg.heap_size = BW_SIZE + (8 << 20);
    let world = World::threads(2, cfg).unwrap();

    // columns: get/put × best/stock (the paper's four columns).
    let cols = ["get(best)", "put(best)", "get(memcpy)", "put(memcpy)"];
    let mut lat = Table::new("Table 2a: SHMEM latency", "ns", &cols);
    let mut bw = Table::new("Table 2b: SHMEM bandwidth", "Gb/s", &cols);

    let rows: Vec<(Vec<f64>, Vec<f64>, f64)> = world.run_collect(|ctx| {
        let buf = ctx.shmalloc_n::<u8>(BW_SIZE).unwrap();
        let mut out = (Vec::new(), Vec::new(), 0.0);
        if ctx.my_pe() == 0 {
            let src = vec![0x5Au8; BW_SIZE];
            let mut dst = vec![0u8; BW_SIZE];
            for (imp, _) in [(best, "best"), (CopyImpl::Stock, "stock")] {
                // get latency / put latency
                let g = measure(LAT_SIZE, auto_batch(40.0), || {
                    ctx.get_with(imp, &mut dst[..LAT_SIZE], buf, 1);
                });
                let p = measure(LAT_SIZE, auto_batch(40.0), || {
                    ctx.put_with(imp, buf, &src[..LAT_SIZE], 1);
                });
                out.0.push(g.latency_ns());
                out.0.push(p.latency_ns());
                // bandwidth
                let g = measure(BW_SIZE, 1, || {
                    ctx.get_with(imp, &mut dst, buf, 1);
                });
                let p = measure(BW_SIZE, 1, || {
                    ctx.put_with(imp, buf, &src, 1);
                });
                out.1.push(g.bandwidth_gbps());
                out.1.push(p.bandwidth_gbps());
            }
            // raw local copy baseline for the overhead claim
            let raw = measure(BW_SIZE, 1, || unsafe {
                copy_bytes_with(CopyImpl::Stock, dst.as_mut_ptr(), src.as_ptr(), BW_SIZE);
            });
            out.2 = raw.bandwidth_gbps();
        }
        ctx.barrier_all();
        out
    });
    let (lat_row, bw_row, raw_bw) = rows.into_iter().next().unwrap();
    lat.row("this-machine", lat_row.clone());
    bw.row("this-machine", bw_row.clone());
    for m in paper_machines() {
        lat.row(
            &format!("paper:{}", m.name),
            vec![m.posh_get.alpha_ns, m.posh_put.alpha_ns, m.posh_get.alpha_ns, m.posh_put.alpha_ns],
        );
        bw.row(
            &format!("paper:{}", m.name),
            vec![
                m.posh_get.predict_gbps(BW_SIZE),
                m.posh_put.predict_gbps(BW_SIZE),
                m.memcpy.predict_gbps(BW_SIZE),
                m.memcpy.predict_gbps(BW_SIZE),
            ],
        );
    }
    lat.print();
    bw.print();
    lat.write_csv("table2_latency").unwrap();
    bw.write_csv("table2_bandwidth").unwrap();

    // --- The paper's headline claim: put/get ≈ raw memcpy.
    let posh_best_bw = bw_row[0].max(bw_row[1]);
    let ratio = posh_best_bw / raw_bw;
    println!(
        "\nraw local memcpy: {raw_bw:.1} Gb/s; POSH best p2p: {posh_best_bw:.1} Gb/s \
         (ratio {ratio:.3})"
    );
    assert!(
        ratio > 0.85,
        "POSH p2p must be within 15% of a raw memcpy (paper: 'negligible overhead')"
    );
    println!("shape check OK: one-sided engine overhead is negligible");
    println!("csv: bench_out/table2_latency.csv, bench_out/table2_bandwidth.csv");
}
