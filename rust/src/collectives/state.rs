//! Strided membership sets, the §4.5.1 state protocol, and the team-scoped
//! barrier that closes every collective.
//!
//! As of the team redesign, [`ActiveSet`] is an *internal* membership
//! representation: user code holds a [`crate::team::Team`] (built by
//! `split_strided`/`split_2d`), and every collective entry point takes a
//! `&Team`. The OpenSHMEM-1.0 `(PE_start, logPE_stride, PE_size)` triplet
//! survives only in the deprecated [`crate::api`] shims, which build a
//! temporary legacy team around an `ActiveSet`.

use crate::pe::Ctx;
use crate::symheap::layout::CollOpTag;
use crate::symheap::SymPtr;
use crate::team::{Team, TeamSlot};
use std::cell::Cell;
use std::sync::atomic::Ordering;

thread_local! {
    /// Rounds executed by this PE thread's most recent team sync — the
    /// round-count hook behind [`Ctx::last_sync_rounds`].
    static LAST_SYNC_ROUNDS: Cell<usize> = const { Cell::new(0) };
}

/// A strided PE set: world ranks `start + i·stride` for `i in 0..size`.
///
/// This is the membership representation behind [`crate::team::Team`] —
/// `split_strided` constructs one, collectives iterate it. Unlike the 1.0
/// active-set triplet, `stride` is an arbitrary positive integer, not a
/// power of two.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ActiveSet {
    /// First world rank of the set.
    pub start: usize,
    /// Stride in ranks between consecutive members (≥ 1).
    pub stride: usize,
    /// Number of members.
    pub size: usize,
}

impl ActiveSet {
    /// The whole world of `n` PEs.
    pub fn world(n: usize) -> ActiveSet {
        ActiveSet { start: 0, stride: 1, size: n }
    }

    /// Construct with an arbitrary stride and validate against a world size.
    pub fn strided(start: usize, stride: usize, size: usize, n_pes: usize) -> ActiveSet {
        assert!(size >= 1, "set must have at least one member");
        assert!(stride >= 1, "stride must be at least 1");
        let last = start + (size - 1) * stride;
        assert!(last < n_pes, "set [{start}..={last}] exceeds world of {n_pes}");
        ActiveSet { start, stride, size }
    }

    /// Construct from the OpenSHMEM-1.0 triplet (`PE_start`, `logPE_stride`,
    /// `PE_size`) — the power-of-two-stride special case the deprecated
    /// shims still speak.
    pub fn from_triplet(start: usize, logstride: usize, size: usize, n_pes: usize) -> ActiveSet {
        assert!(logstride < usize::BITS as usize, "logstride too large");
        Self::strided(start, 1usize << logstride, size, n_pes)
    }

    /// World rank of set index `i`.
    #[inline]
    pub fn rank_at(&self, i: usize) -> usize {
        debug_assert!(i < self.size);
        self.start + i * self.stride
    }

    /// Set index of a world rank, if the rank is a member.
    pub fn index_of(&self, rank: usize) -> Option<usize> {
        if rank < self.start {
            return None;
        }
        let d = rank - self.start;
        if d % self.stride != 0 {
            return None;
        }
        let i = d / self.stride;
        (i < self.size).then_some(i)
    }

    /// Membership test.
    pub fn contains(&self, rank: usize) -> bool {
        self.index_of(rank).is_some()
    }

    /// Iterate the member world ranks.
    pub fn ranks(&self) -> impl Iterator<Item = usize> + '_ {
        (0..self.size).map(move |i| self.rank_at(i))
    }

    /// The set's root (lowest rank — index 0).
    pub fn root(&self) -> usize {
        self.start
    }
}

impl Ctx {
    /// Enter a collective: §4.5.5 checks, then stamp our own state.
    /// Returns this PE's index within the team.
    pub(crate) fn coll_enter(&self, team: &Team, tag: CollOpTag, bytes: usize) -> usize {
        let me = self.my_pe();
        let idx = team.my_idx.unwrap_or_else(|| {
            panic!("PE {me} called a collective on a team it is not a member of")
        });
        debug_assert_eq!(team.set.index_of(me), Some(idx));
        let st = &self.header_of(me).coll;
        if self.config().safe {
            // "the safe mode checks that when a process wants to run a
            // collective communication, it is not already participating to
            // another collective communication" (§4.7) — except that a peer
            // may have legitimately pre-initialised us (§4.5.2), which is
            // encoded as in_progress=1 with *our* op tag unset locally.
            let cur = CollOpTag::from_u32(st.op_type.load(Ordering::Acquire));
            assert!(
                cur == CollOpTag::None || cur == tag,
                "PE {me} entering {tag:?} while a {cur:?} is underway (§4.5.5 check)"
            );
        }
        st.op_type.store(tag as u32, Ordering::Release);
        st.in_progress.store(1, Ordering::Release);
        st.data_size.store(bytes as u64, Ordering::Release);
        idx
    }

    /// Safe-mode §4.5.5 cross-check against a peer we are about to exchange
    /// with: same operation, compatible buffer size. Peers that have not
    /// entered yet (tag None) are skipped — that is the legal §4.5.2 state.
    pub(crate) fn coll_check_peer(&self, pe: usize, tag: CollOpTag, bytes: usize) {
        if !self.config().safe {
            return;
        }
        let st = &self.header_of(pe).coll;
        let peer_tag = CollOpTag::from_u32(st.op_type.load(Ordering::Acquire));
        if peer_tag == CollOpTag::None {
            return; // not entered yet — §4.5.2 allows us to proceed
        }
        assert_eq!(
            peer_tag, tag,
            "collective type mismatch with PE {pe}: we run {tag:?}, peer runs {peer_tag:?}"
        );
        let peer_bytes = st.data_size.load(Ordering::Acquire) as usize;
        if peer_bytes != 0 && bytes != 0 {
            assert_eq!(
                peer_bytes, bytes,
                "collective buffer size mismatch with PE {pe}: {bytes} vs {peer_bytes}"
            );
        }
    }

    /// Leave a collective: reset our state, then close with the team barrier.
    ///
    /// Reset-first is sound by the paper's own §4.5.2 argument: every
    /// algorithm's internal waits guarantee that, by the time its body
    /// returns, all signals and reads directed at this PE have landed —
    /// "a process exits the collective as soon as its participation is
    /// over; hence, no other process will access its collective data
    /// structure. It can therefore be reset." The closing team barrier then
    /// guarantees *peers*' state is also reset before anyone starts the next
    /// collective, so no PE can ever observe a stale `buf_offset`/`counter`
    /// from the previous operation.
    pub(crate) fn coll_exit(&self, team: &Team) {
        let st = &self.header_of(self.my_pe()).coll;
        st.op_type.store(CollOpTag::None as u32, Ordering::Release);
        st.in_progress.store(0, Ordering::Release);
        st.buf_offset.store(0, Ordering::Release);
        st.counter.store(0, Ordering::Release);
        st.data_size.store(0, Ordering::Release);
        st.seq.fetch_add(1, Ordering::AcqRel);
        self.team_barrier_raw(team);
    }

    /// Wait until PE `pe` has entered the current collective instance
    /// (§4.5.2 late-entry handling, simplified: where POSH remotely
    /// initialises the late PE's structure and defers the data movement,
    /// POSH-RS has the writer wait for the `in_progress` flag — equivalent
    /// observable behaviour, no remote initialisation to undo).
    ///
    /// Sound because collectives on one team are totally ordered by the
    /// exit barrier: a peer's `in_progress` can only be 1 for *this*
    /// instance (the previous instance cleared it before its exit barrier,
    /// and the next cannot start until we ourselves finish).
    pub(crate) fn coll_wait_entered(&self, pe: usize, tag: CollOpTag) {
        let st = &self.header_of(pe).coll;
        self.spin_wait(|| {
            st.in_progress.load(Ordering::Acquire) == 1
                && CollOpTag::from_u32(st.op_type.load(Ordering::Acquire)) == tag
        });
    }

    /// Publish a buffer handle in our collective state (get-based ops and
    /// Lemma-1 temporaries). Encoded as offset+1 so 0 stays "null".
    pub(crate) fn coll_publish_buf<T>(&self, ptr: SymPtr<T>) {
        self.header_of(self.my_pe())
            .coll
            .buf_offset
            .store(ptr.offset() as u64 + 1, Ordering::Release);
    }

    /// Wait for PE `pe` to publish a buffer handle; returns its offset.
    pub(crate) fn coll_wait_buf(&self, pe: usize) -> usize {
        let cell = &self.header_of(pe).coll.buf_offset;
        let mut v = 0u64;
        self.spin_wait(|| {
            v = cell.load(Ordering::Acquire);
            v != 0
        });
        (v - 1) as usize
    }

    /// Signal PE `pe`'s collective counter (one unit of our participation).
    pub(crate) fn coll_signal(&self, pe: usize) {
        self.header_of(pe).coll.counter.fetch_add(1, Ordering::AcqRel);
    }

    /// Wait until our own counter reaches `target`.
    pub(crate) fn coll_wait_count(&self, target: u64) {
        let cell = &self.header_of(self.my_pe()).coll.counter;
        self.spin_wait(|| cell.load(Ordering::Acquire) >= target);
        std::sync::atomic::fence(Ordering::Acquire);
    }

    /// The raw barrier over a team's members: quiet (which also retires the
    /// default NBI domain — a barrier *completes* outstanding operations),
    /// then the team sync engine. Reserved-slot teams use their own
    /// `TeamCell` sync cells — which is what makes barriers on *overlapping*
    /// teams safe; legacy triplet teams share the 1.0
    /// `set_count`/`set_sense` pair, preserving the historical behaviour of
    /// the deprecated shims.
    pub(crate) fn team_barrier_raw(&self, team: &Team) {
        self.quiet_nbi();
        self.team_sync_raw(team);
    }

    /// The pure synchronisation half of a team barrier: no quiet, no NBI
    /// retirement — the OpenSHMEM 1.5 `shmem_team_sync` semantics
    /// ([`Ctx::team_sync`](crate::pe::Ctx) is the public face).
    pub(crate) fn team_sync_raw(&self, team: &Team) {
        let set = &team.set;
        if set.size == 1 {
            LAST_SYNC_ROUNDS.with(|r| r.set(0));
            return;
        }
        debug_assert!(set.contains(self.my_pe()));
        match team.slot {
            TeamSlot::Legacy => self.set_barrier_cells(set),
            TeamSlot::Reserved(slot) => self.team_sync_cells(set, slot),
        }
    }

    /// Enter a team sync/barrier on `slot`: CAS this PE's per-slot entry
    /// guard 0→1. Both team-sync engines wait on *per-PE* mailboxes that a
    /// second same-PE caller would race (two threads of one PE consuming one
    /// epoch's signals), so under `SHMEM_THREAD_MULTIPLE` a concurrent
    /// same-PE entry is a program error — the spec requires the *program* to
    /// serialise collectives per PE. The guard turns that silent hang or
    /// lost-arrival into an immediate, diagnosable panic.
    ///
    /// Guarded at **entry points only** ([`Ctx::team_sync_cells`] and the
    /// world-slot dissemination arm of `sync_all`) — never inside
    /// `team_sync_dissemination` itself, which the guarded paths call.
    /// The legacy shared-cell paths (`set_barrier_cells`, `barrier_central`)
    /// predate teams and stay unguarded.
    pub(crate) fn coll_entry_guard_acquire(&self, slot: usize) {
        let me = self.my_pe();
        let guard = &self.header_of(me).teams[slot].entry_guard;
        if guard
            .compare_exchange(0, 1, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            panic!(
                "PE {me}: concurrent sync/barrier entry on team slot {slot} — another \
                 thread of this PE is already inside a synchronisation on this team; \
                 serialise collectives per PE (OpenSHMEM 1.4 §9.2)"
            );
        }
    }

    /// Leave a team sync/barrier on `slot`: release the entry guard taken by
    /// [`Ctx::coll_entry_guard_acquire`].
    pub(crate) fn coll_entry_guard_release(&self, slot: usize) {
        self.header_of(self.my_pe()).teams[slot].entry_guard.store(0, Ordering::Release);
    }

    /// Sync over a reserved slot's cells, algorithm per
    /// [`crate::pe::TeamBarrierKind`] — forced by `PoshConfig::team_barrier`
    /// (`POSH_TEAM_BARRIER`, the Ablation-B A/B switch) or, by default,
    /// chosen by the tuning engine per team size
    /// ([`crate::collectives::Tuning::select_barrier`]: `⌈log₂ n⌉·2α` rounds
    /// of dissemination vs `2(n−1)·α` of root-serialised fan-in, which
    /// resolves to dissemination at every size — the decision is now
    /// model-driven, the production schedule unchanged).
    pub(crate) fn team_sync_cells(&self, set: &ActiveSet, slot: usize) {
        let kind = self
            .config()
            .team_barrier
            .unwrap_or_else(|| self.tuning().select_barrier(set.size));
        self.coll_entry_guard_acquire(slot);
        match kind {
            crate::pe::TeamBarrierKind::Dissemination => {
                self.team_sync_dissemination(set, slot)
            }
            crate::pe::TeamBarrierKind::LinearFanin => self.team_sync_linear(set, slot),
            crate::pe::TeamBarrierKind::Hierarchical => self.team_sync_hier(set, slot),
        }
        self.coll_entry_guard_release(slot);
    }

    /// Dissemination sync in **team-rank space**: ⌈log₂ size⌉ rounds; in
    /// round *r* team rank *i* signals team rank *(i + 2ʳ) mod size* through
    /// the target's per-round mailbox on this slot and waits for the
    /// matching signal in its own. Epochs are monotone (`>=` absorbs a fast
    /// peer one epoch ahead), and the mailboxes are zeroed when the slot is
    /// claimed at split time, so a recycled slot cannot leak a stale epoch
    /// into a new team.
    ///
    /// This is *the* barrier engine: `shmem_barrier_all` runs it over the
    /// world team's slot 0 ([`Ctx::barrier_all`](crate::pe::Ctx)).
    pub(crate) fn team_sync_dissemination(&self, set: &ActiveSet, slot: usize) {
        let size = set.size;
        if size == 1 {
            LAST_SYNC_ROUNDS.with(|r| r.set(0));
            return;
        }
        let me = self.my_pe();
        let idx = set.index_of(me).expect("dissemination sync by a non-member");
        let my_cell = &self.header_of(me).teams[slot];
        // Only this PE writes its own sync_epoch on this slot.
        let epoch = my_cell.sync_epoch.load(Ordering::Relaxed) + 1;
        let rounds = crate::sync::barrier::ceil_log2(size);
        for r in 0..rounds {
            let dist = 1usize << r;
            let to = set.rank_at((idx + dist) % size);
            self.header_of(to).teams[slot].sync_flags[r].store(epoch, Ordering::Release);
            self.spin_wait(|| my_cell.sync_flags[r].load(Ordering::Acquire) >= epoch);
        }
        my_cell.sync_epoch.store(epoch, Ordering::Release);
        LAST_SYNC_ROUNDS.with(|r| r.set(rounds));
    }

    /// Linear fan-in/fan-out on the team root over the slot's
    /// `sync_count`/`sync_sense` pair — the pre-dissemination baseline, kept
    /// for the Ablation-B A/B comparison
    /// ([`crate::pe::TeamBarrierKind::LinearFanin`]).
    fn team_sync_linear(&self, set: &ActiveSet, slot: usize) {
        let me = self.my_pe();
        let root = set.root();
        if me == root {
            let cell = &self.header_of(root).teams[slot];
            let want = (set.size - 1) as u64;
            self.spin_wait(|| cell.sync_count.load(Ordering::Acquire) >= want);
            cell.sync_count.store(0, Ordering::Relaxed);
            for r in set.ranks() {
                if r != root {
                    self.header_of(r).teams[slot].sync_sense.fetch_add(1, Ordering::AcqRel);
                }
            }
        } else {
            let mine = &self.header_of(me).teams[slot].sync_sense;
            let before = mine.load(Ordering::Acquire);
            self.header_of(root).teams[slot].sync_count.fetch_add(1, Ordering::AcqRel);
            self.spin_wait(|| mine.load(Ordering::Acquire) > before);
        }
        // The serialisation depth: every non-root funnels through the root.
        LAST_SYNC_ROUNDS.with(|r| r.set(set.size - 1));
    }

    /// Record the step count of the sync that just ran (the hook behind
    /// [`Ctx::last_sync_rounds`]).
    pub(crate) fn record_sync_rounds(&self, rounds: usize) {
        LAST_SYNC_ROUNDS.with(|r| r.set(rounds));
    }

    /// Synchronisation steps this PE executed in its most recent sync or
    /// barrier: ⌈log₂ size⌉ for the dissemination engine, `size − 1` for
    /// the serial baselines (linear fan-in, legacy set cells, central
    /// counter), 0 for a single-member sync. The observable hook behind the
    /// O(log n) acceptance check.
    pub fn last_sync_rounds(&self) -> usize {
        LAST_SYNC_ROUNDS.with(|r| r.get())
    }

    /// Barrier over a raw active set (the deprecated 1.0 `shmem_barrier`
    /// path and the ablation benches).
    ///
    /// Linear fan-in/fan-out on the set root using the single shared
    /// `set_count`/`set_sense` cell pair. Monotone release word, count reset
    /// by the root *before* releasing, so back-to-back set barriers are
    /// safe. Unlike reserved-team barriers, two *overlapping* sets sharing a
    /// root must not barrier concurrently — that limitation is why teams
    /// carry their own cells.
    pub fn barrier_set(&self, set: &ActiveSet) {
        self.quiet_nbi();
        if set.size == 1 {
            return;
        }
        self.set_barrier_cells(set);
    }

    /// Fan-in/fan-out body shared by [`Ctx::barrier_set`] and legacy teams.
    fn set_barrier_cells(&self, set: &ActiveSet) {
        let me = self.my_pe();
        debug_assert!(set.contains(me));
        let root = set.root();
        if me == root {
            let h = self.header_of(root);
            let want = (set.size - 1) as u64;
            self.spin_wait(|| h.barrier.set_count.load(Ordering::Acquire) >= want);
            h.barrier.set_count.store(0, Ordering::Relaxed);
            for r in set.ranks() {
                if r != root {
                    self.header_of(r).barrier.set_sense.fetch_add(1, Ordering::AcqRel);
                }
            }
        } else {
            let mine = &self.header_of(me).barrier.set_sense;
            let before = mine.load(Ordering::Acquire);
            self.header_of(root).barrier.set_count.fetch_add(1, Ordering::AcqRel);
            self.spin_wait(|| mine.load(Ordering::Acquire) > before);
        }
        self.record_sync_rounds(set.size - 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};

    #[test]
    fn active_set_indexing() {
        let s = ActiveSet::strided(2, 2, 3, 8); // ranks 2, 4, 6
        assert_eq!(s.rank_at(0), 2);
        assert_eq!(s.rank_at(2), 6);
        assert_eq!(s.index_of(4), Some(1));
        assert_eq!(s.index_of(3), None);
        assert_eq!(s.index_of(8), None);
        assert!(s.contains(6));
        assert!(!s.contains(0));
        assert_eq!(s.ranks().collect::<Vec<_>>(), vec![2, 4, 6]);
        assert_eq!(s.root(), 2);
    }

    #[test]
    fn active_set_arbitrary_stride() {
        // Stride 3 — impossible to express as a 1.0 triplet.
        let s = ActiveSet::strided(1, 3, 3, 8); // ranks 1, 4, 7
        assert_eq!(s.ranks().collect::<Vec<_>>(), vec![1, 4, 7]);
        assert_eq!(s.index_of(4), Some(1));
        assert_eq!(s.index_of(5), None);
    }

    #[test]
    fn active_set_triplet_compat() {
        let s = ActiveSet::from_triplet(2, 1, 3, 8); // logstride 1 ⇒ stride 2
        assert_eq!(s, ActiveSet::strided(2, 2, 3, 8));
    }

    #[test]
    #[should_panic(expected = "exceeds world")]
    fn active_set_overflow_panics() {
        let _ = ActiveSet::strided(4, 2, 3, 8); // 4, 6, 8 — 8 is out
    }

    #[test]
    fn world_set_covers_all() {
        let s = ActiveSet::world(5);
        assert_eq!(s.ranks().collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn set_barrier_subset_and_repeat() {
        // Two disjoint sets barrier independently and repeatedly while the
        // complement set is also active — no cross-talk.
        let w = World::threads(4, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let evens = ActiveSet::strided(0, 2, 2, 4); // 0, 2
            let odds = ActiveSet::strided(1, 2, 2, 4); // 1, 3
            let mine = if ctx.my_pe() % 2 == 0 { evens } else { odds };
            for _ in 0..200 {
                ctx.barrier_set(&mine);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn set_barrier_world_equivalent() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        let set = ActiveSet::world(3);
        use std::sync::atomic::{AtomicUsize, Ordering};
        let c = AtomicUsize::new(0);
        w.run(|ctx| {
            for round in 1..50 {
                c.fetch_add(1, Ordering::SeqCst);
                ctx.barrier_set(&set);
                assert!(c.load(Ordering::SeqCst) >= 3 * round);
                ctx.barrier_set(&set);
            }
        });
    }

    /// Two threads of one PE entering a sync on the same team concurrently
    /// is a program error under `SHMEM_THREAD_MULTIPLE`; the per-slot entry
    /// guard must turn it into an immediate panic instead of a lost arrival
    /// or a silent hang. Simulated by pre-claiming PE 0's world-slot guard
    /// before its sync — exactly what a still-inside sibling thread looks
    /// like to the CAS.
    #[test]
    fn concurrent_sync_entry_panics() {
        use crate::team::WORLD_TEAM_SLOT;
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::atomic::Ordering;
        let w = World::threads(2, PoshConfig::small()).unwrap();
        let res = catch_unwind(AssertUnwindSafe(|| {
            w.run(|ctx| {
                if ctx.my_pe() == 0 {
                    ctx.header_of(0).teams[WORLD_TEAM_SLOT]
                        .entry_guard
                        .store(1, Ordering::Release);
                }
                ctx.team_world().sync();
            });
        }));
        // PE 0 panics on the guard CAS; PE 1's spin then aborts via the
        // panic flag. Which panic propagates is a race, so assert only
        // that the run failed.
        assert!(res.is_err(), "concurrent same-PE sync entry must panic");
    }

    #[test]
    fn overlapping_team_barriers_do_not_collide() {
        // Teams {0,1} and {0,2} share root PE 0. With the 1.0 shared set
        // cells this pattern lost arrivals (PE 2's increment could be
        // consumed by team A's barrier); per-team cells make it safe.
        let w = World::threads(3, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let world = ctx.team_world();
            let a = world.split_strided(0, 1, 2); // PEs {0, 1}
            let b = world.split_strided(0, 2, 2); // PEs {0, 2}
            for _ in 0..50 {
                match ctx.my_pe() {
                    0 => {
                        a.as_ref().unwrap().sync();
                        b.as_ref().unwrap().sync();
                    }
                    1 => a.as_ref().unwrap().sync(),
                    _ => b.as_ref().unwrap().sync(),
                }
            }
            ctx.barrier_all();
            if let Some(t) = a {
                t.destroy();
            }
            if let Some(t) = b {
                t.destroy();
            }
            ctx.barrier_all();
        });
    }
}
