//! Memory-movement engine (paper §4.4).
//!
//! "Peer-to-peer communications are using memory copies between local and
//! shared buffers. As a consequence, memory copy is a highly critical matter
//! of POSH." The paper ships several `memcpy` implementations (stock, MMX,
//! MMX2, SSE) selected at *compile time* to avoid conditional branches.
//!
//! This module reproduces that design with today's ISAs:
//!
//! * [`copy::CopyImpl::Stock`] — `core::ptr::copy_nonoverlapping`, i.e. the
//!   compiler/libc `memcpy` (the paper's "stock memcpy");
//! * [`copy::CopyImpl::Unrolled64`] — 8×-unrolled 64-bit word loop (the
//!   spiritual successor of the paper's MMX 64-bit path);
//! * [`copy::CopyImpl::Sse2`] — 128-bit vector loop (the paper's SSE path);
//! * [`copy::CopyImpl::Avx2`] — 256-bit vector loop (what SSE grew into);
//! * [`copy::CopyImpl::NonTemporal`] — 128-bit streaming stores (the paper's
//!   MMX2 `movntq` trick: bypass the cache for large one-shot copies);
//! * [`copy::CopyImpl::Avx512`] — 512-bit vector loop (runtime
//!   `avx512f`-gated, the widest temporal path);
//! * [`copy::CopyImpl::Avx512Nt`] — 512-bit streaming stores (the
//!   cache-bypass engine for copies past the LLC).
//!
//! The compile-time default is chosen by cargo feature (`copy-sse2`, …) as in
//! the paper. On top of that sits *size-aware planned dispatch*
//! ([`plan::CopyPlan`], the default when no engine is forced): tiny copies go
//! to `ptr::copy`, cache-resident copies to the widest temporal vector, and
//! past-LLC copies to non-temporal streaming stores, with the LLC crossover
//! detected from sysfs ([`plan::CacheInfo`]). `POSH_COPY=<engine>` or the
//! `copy-*` features still force one engine for every size, which is how the
//! Table-1 sweep measures each row.

pub mod copy;
pub mod plan;

pub use copy::{copy_bytes, copy_bytes_with, CopyImpl};
pub use plan::{CacheInfo, CopyPlan};
