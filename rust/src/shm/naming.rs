//! Segment naming (paper §4.7 "Contact information").
//!
//! "The name of this shared memory segment is built using a constant basis
//! and the rank of the target process. Hence, processes can communicate with
//! each other as soon as they know their rank." The job id keeps concurrent
//! POSH jobs on one machine from colliding.

/// Constant basis of every POSH segment name.
pub const BASIS: &str = "posh";

/// Name of the symmetric-heap segment of PE `rank` in job `job_id`.
///
/// POSIX requires the name to start with `/` and contain no further slashes.
pub fn heap_segment_name(job_id: u64, rank: usize) -> String {
    format!("/{BASIS}.{job_id:x}.heap.{rank}")
}

/// Name of the job-wide control segment (barrier flags, collective state
/// mirrors for process mode).
pub fn control_segment_name(job_id: u64) -> String {
    format!("/{BASIS}.{job_id:x}.ctl")
}

/// Parse a heap-segment name back into `(job_id, rank)`; `None` if it is not
/// a POSH heap name. Used by cleanup tooling (`oshrun --clean`).
pub fn parse_heap_name(name: &str) -> Option<(u64, usize)> {
    let stripped = name.strip_prefix('/').unwrap_or(name);
    let mut parts = stripped.split('.');
    if parts.next()? != BASIS {
        return None;
    }
    let job = u64::from_str_radix(parts.next()?, 16).ok()?;
    if parts.next()? != "heap" {
        return None;
    }
    let rank = parts.next()?.parse().ok()?;
    if parts.next().is_some() {
        return None;
    }
    Some((job, rank))
}

/// Debug label for the memfd backing PE `rank`'s heap in job `job_id`.
/// Memfds have no reachable filesystem name — peers find them through the
/// launcher's fd handoff, not this string — but the label shows up in
/// `/proc/<pid>/fd` and error messages, so keep it structured like the
/// POSIX names (minus the leading `/`, which memfd_create would reject in
/// spirit: the kernel prefixes `memfd:` itself).
pub fn memfd_debug_name(job_id: u64, rank: usize) -> String {
    format!("{BASIS}.{job_id:x}.heap.{rank}")
}

/// A fresh job id: time-seeded plus pid so two jobs launched in the same
/// nanosecond by different shells still diverge.
pub fn fresh_job_id() -> u64 {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let pid = std::process::id() as u64;
    t ^ (pid << 48) ^ pid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_posix_valid() {
        let n = heap_segment_name(0xabc, 3);
        assert!(n.starts_with('/'));
        assert_eq!(n.matches('/').count(), 1);
        assert!(n.len() < 255);
    }

    #[test]
    fn roundtrip() {
        for job in [0u64, 1, 0xdeadbeef, u64::MAX] {
            for rank in [0usize, 1, 127, 100_000] {
                let n = heap_segment_name(job, rank);
                assert_eq!(parse_heap_name(&n), Some((job, rank)));
            }
        }
    }

    #[test]
    fn rejects_foreign_names() {
        assert_eq!(parse_heap_name("/other.1.heap.0"), None);
        assert_eq!(parse_heap_name("/posh.zz.heap.0"), None);
        assert_eq!(parse_heap_name("/posh.1.ctl"), None);
        assert_eq!(parse_heap_name("/posh.1.heap.0.extra"), None);
    }

    #[test]
    fn job_ids_differ() {
        // Weak check: two calls in a row shouldn't collide (time advances or
        // xor with pid differs).
        let a = fresh_job_id();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = fresh_job_id();
        assert_ne!(a, b);
    }
}
