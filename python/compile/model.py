"""Layer 2: the transformer language model (JAX, build-time only).

A small pre-norm decoder-only transformer whose MLP matmuls run through the
Layer-1 Pallas kernel (`kernels.matmul`) so the kernel lowers into the same
HLO artifact the Rust coordinator executes.

Parameters live as a single flat f32 vector: the Rust side holds exactly one
buffer in the symmetric heap, and the gradient allreduce is one
`shmem_float_sum_to_all` over it. `ParamSpec` defines the (deterministic)
flattening; `unflatten` is pure slicing/reshaping, so it lowers cleanly.

Default size is deliberately laptop-scale (~1.8M params): the paper under
reproduction is a *communication library*, so the e2e driver's job is to
exercise put/get/collectives with a real gradient payload, not to set MLPerf
records — see DESIGN.md §3 "E2E". Scale knobs are all here.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul as pallas_matmul


class ModelConfig(NamedTuple):
    """Transformer hyper-parameters (defaults = the shipped artifacts)."""

    vocab: int = 256
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 512
    n_layers: int = 2
    seq: int = 32
    batch: int = 8
    lr: float = 0.05


def param_shapes(cfg: ModelConfig):
    """Ordered (name, shape) list — the flattening contract."""
    shapes = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq, cfg.d_model)),
    ]
    for layer in range(cfg.n_layers):
        p = f"l{layer}."
        shapes += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
    shapes += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    # Output projection ties to the embedding (classic weight tying) — no
    # extra matrix.
    return shapes


def param_count(cfg: ModelConfig) -> int:
    """Total flat parameter count."""
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_shapes(cfg))


def init_params(cfg: ModelConfig, key) -> jnp.ndarray:
    """Initialise the flat parameter vector (scaled-normal weights, unit
    layer-norm gains, zero biases)."""
    parts = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            parts.append(jnp.ones(shape, jnp.float32).ravel())
        elif name.endswith(("_b",)):
            parts.append(jnp.zeros(shape, jnp.float32).ravel())
        else:
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, jnp.float32) * (fan_in**-0.5)
            parts.append(w.ravel())
    return jnp.concatenate(parts)


def unflatten(cfg: ModelConfig, flat: jnp.ndarray) -> dict:
    """Flat vector -> named parameter dict (pure slicing, lowers to HLO)."""
    out = {}
    off = 0
    for name, shape in param_shapes(cfg):
        n = 1
        for d in shape:
            n *= d
        out[name] = flat[off : off + n].reshape(shape)
        off += n
    return out


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(cfg: ModelConfig, x, wqkv, wo):
    """Causal multi-head self-attention. x: [B, S, D]."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    qkv = jnp.einsum("bsd,de->bse", x, wqkv)  # [B, S, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)  # [B, H, S, hd]
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return jnp.einsum("bsd,de->bse", ctx, wo)


def _mlp(cfg: ModelConfig, x, w1, w2):
    """Position-wise MLP through the **Pallas matmul kernel** (Layer 1)."""
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    h = pallas_matmul(flat, w1)  # [B*S, d_ff] — MXU tile kernel
    h = jax.nn.gelu(h)
    out = pallas_matmul(h, w2)  # [B*S, D]
    return out.reshape(b, s, d)


def forward(cfg: ModelConfig, flat_params: jnp.ndarray, tokens: jnp.ndarray):
    """Logits for next-token prediction. tokens: [B, S] int32 -> [B, S, V]."""
    p = unflatten(cfg, flat_params)
    x = p["embed"][tokens] + p["pos"][None, :, :]
    for layer in range(cfg.n_layers):
        q = f"l{layer}."
        a = _attention(cfg, _layernorm(x, p[q + "ln1_g"], p[q + "ln1_b"]),
                       p[q + "wqkv"], p[q + "wo"])
        x = x + a
        m = _mlp(cfg, _layernorm(x, p[q + "ln2_g"], p[q + "ln2_b"]),
                 p[q + "w1"], p[q + "w2"])
        x = x + m
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    return jnp.einsum("bsd,vd->bsv", x, p["embed"])  # tied output head


def loss_fn(cfg: ModelConfig, flat_params: jnp.ndarray, tokens: jnp.ndarray):
    """Mean next-token cross-entropy in nats."""
    logits = forward(cfg, flat_params, tokens)  # [B, S, V]
    inputs = logits[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(inputs, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def train_step(cfg: ModelConfig, flat_params: jnp.ndarray, tokens: jnp.ndarray):
    """(loss, grads) — the artifact the Rust coordinator executes per step."""
    loss, grads = jax.value_and_grad(functools.partial(loss_fn, cfg))(
        flat_params, tokens
    )
    return loss, grads


def sgd_update(flat_params: jnp.ndarray, grad_sum: jnp.ndarray, scale: jnp.ndarray):
    """params − scale·grad_sum. `scale = lr / n_pes` folds the data-parallel
    mean into the update (the Rust side passes it as a rank-0 literal)."""
    return (flat_params - scale * grad_sum,)
