//! The coordinator: SHMEM-data-parallel training (the end-to-end workload
//! mandated by DESIGN.md §3 "E2E").
//!
//! Each PE runs the AOT-compiled `train_step` (Layer 2/1: JAX + Pallas,
//! compiled once by `make artifacts`) on its shard of a synthetic corpus;
//! gradients cross PEs through the **POSH symmetric heap** via
//! `reduce_to_all(Sum)` — the paper's system is the interconnect, the
//! transformer is the payload. Python never runs here.

pub mod dataset;
pub mod metrics;
// The trainer executes AOT-compiled HLO through PJRT, so it exists only when
// the `xla` feature (and its runtime) is compiled in; the corpus and metrics
// halves are pure Rust and always available.
#[cfg(feature = "xla")]
pub mod trainer;

#[cfg(feature = "xla")]
pub use trainer::{TrainReport, Trainer, TrainerConfig};
