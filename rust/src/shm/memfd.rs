//! `memfd_create`-backed segments — the shm-less fallback engine.
//!
//! POSIX `shm_open` segments (the paper's substrate) need a writable
//! `/dev/shm`. Hardened sandboxes and some CI runners mount it read-only or
//! not at all, which used to make process mode silently impossible. A memfd
//! is an anonymous tmpfs file that lives entirely in the fd table — no
//! filesystem name, no `/dev/shm` — so it works anywhere the kernel is
//! ≥ 3.17.
//!
//! The catch is the paper's §4.7 "contact information" mechanism: a memfd
//! has no *name* a peer can rebuild from a rank, so the segment cannot be
//! opened by a stranger. Instead the RTE gateway (the `oshrun` launcher,
//! which is every PE's parent) acts as the broker: it creates one
//! *inheritable* memfd per rank before spawning, and publishes the fd
//! numbers through [`SEGFDS_ENV`]. Children inherit the fd table entries
//! across `fork`/`exec`, so `fds[rank]` is valid in every PE and the
//! remote-heap table can demand-map any peer by `mmap`ing that fd — same
//! [`Segment`] trait, same huge-page attempt, no `/dev/shm` anywhere.

use super::{HugePageStatus, Segment};
use crate::Result;
use anyhow::{bail, Context};
use std::ffi::CString;
use std::os::unix::io::RawFd;

/// Environment variable carrying the comma-separated, rank-indexed memfd
/// list from the launcher to every PE (e.g. `POSH_SEGFDS=12,13,14`).
pub const SEGFDS_ENV: &str = "POSH_SEGFDS";

/// `true` if this kernel/sandbox supports `memfd_create` (cached probe).
pub fn memfd_supported() -> bool {
    use std::sync::OnceLock;
    static SUPPORTED: OnceLock<bool> = OnceLock::new();
    *SUPPORTED.get_or_init(|| {
        // SAFETY: FFI with a static NUL-terminated name; probe fd is closed
        // immediately.
        let fd = unsafe {
            libc::memfd_create(
                b"posh.probe\0".as_ptr() as *const libc::c_char,
                libc::MFD_CLOEXEC,
            )
        };
        if fd >= 0 {
            // SAFETY: valid fd we just created.
            unsafe {
                libc::close(fd);
            }
            true
        } else {
            false
        }
    })
}

/// Create a bare, *inheritable* (no `CLOEXEC`) memfd of `len` bytes without
/// mapping it — the launcher-side half of the fd handoff. The caller owns
/// the fd and must `close` it (children keep their inherited copies).
pub fn create_handoff_fd(name: &str, len: usize) -> Result<RawFd> {
    create_fd(name, len, false)
}

fn create_fd(name: &str, len: usize, cloexec: bool) -> Result<RawFd> {
    if len == 0 {
        bail!("segment length must be > 0");
    }
    let len = crate::util::align_up(len, super::inproc::page_size());
    let cname = CString::new(name).context("segment name contains NUL")?;
    let flags = if cloexec { libc::MFD_CLOEXEC } else { 0 };
    // SAFETY: FFI with a valid C string.
    let fd = unsafe { libc::memfd_create(cname.as_ptr(), flags) };
    if fd < 0 {
        bail!(
            "memfd_create({name}) failed: {}",
            std::io::Error::last_os_error()
        );
    }
    // SAFETY: valid fd.
    let rc = unsafe { libc::ftruncate(fd, len as libc::off_t) };
    if rc != 0 {
        let e = std::io::Error::last_os_error();
        // SAFETY: valid fd; best-effort cleanup.
        unsafe {
            libc::close(fd);
        }
        bail!("ftruncate(memfd {name}, {len}) failed: {e}");
    }
    Ok(fd)
}

/// Encode a rank-indexed fd list for [`SEGFDS_ENV`].
pub fn encode_fd_list(fds: &[RawFd]) -> String {
    fds.iter().map(|fd| fd.to_string()).collect::<Vec<_>>().join(",")
}

/// Decode [`SEGFDS_ENV`] from this process's environment. `Ok(None)` means
/// no handoff was published (not running under a memfd-brokering launcher);
/// a present-but-garbled value is an error, never a silent fallback.
pub fn handoff_fds_from_env() -> Result<Option<Vec<RawFd>>> {
    let Ok(raw) = std::env::var(SEGFDS_ENV) else {
        return Ok(None);
    };
    let mut fds = Vec::new();
    for part in raw.split(',') {
        let fd: RawFd = part
            .trim()
            .parse()
            .with_context(|| format!("{SEGFDS_ENV} entry {part:?} is not an fd number"))?;
        if fd < 0 {
            bail!("{SEGFDS_ENV} entry {fd} is negative");
        }
        fds.push(fd);
    }
    if fds.is_empty() {
        bail!("{SEGFDS_ENV} is set but empty");
    }
    Ok(Some(fds))
}

/// A shared mapping backed by a memfd. Plays the [`PosixShmSegment`] role
/// when `/dev/shm` is unavailable: same [`Segment`] trait, same transparent
/// huge-page attempt, but reachable through an inherited fd instead of a
/// rebuildable name.
///
/// [`PosixShmSegment`]: super::posix::PosixShmSegment
pub struct MemfdSegment {
    base: *mut u8,
    len: usize,
    name: String,
    fd: RawFd,
    /// Creator-owned segments close their fd on drop; handoff views leave
    /// the inherited fd table entry alone (other tables may still map it).
    owns_fd: bool,
    huge: HugePageStatus,
}

// SAFETY: plain shared bytes; the SHMEM memory model governs access.
unsafe impl Send for MemfdSegment {}
unsafe impl Sync for MemfdSegment {}

impl MemfdSegment {
    /// Create a segment of `len` bytes. The backing memfd is `CLOEXEC` (it
    /// is not part of any handoff) and is closed when the segment drops.
    pub fn create(name: &str, len: usize) -> Result<Self> {
        let fd = create_fd(name, len, true)?;
        let len = crate::util::align_up(len, super::inproc::page_size());
        match super::map_shared_fd(fd, len) {
            Ok((base, huge)) => Ok(Self {
                base,
                len,
                name: name.to_string(),
                fd,
                owns_fd: true,
                huge,
            }),
            Err(e) => {
                // SAFETY: valid fd; best-effort cleanup.
                unsafe {
                    libc::close(fd);
                }
                Err(e)
            }
        }
    }

    /// Map an existing memfd (an inherited handoff fd). Validates the fd is
    /// live and large enough before mapping; does **not** take ownership of
    /// the fd (the fd table entry outlives this view so peers — and a
    /// remapping after LRU eviction — can map it again).
    pub fn map_existing(fd: RawFd, len: usize) -> Result<Self> {
        let len = crate::util::align_up(len, super::inproc::page_size());
        // SAFETY: fstat with a valid out-pointer; a bad fd returns -1.
        let mut st: libc::stat = unsafe { std::mem::zeroed() };
        let rc = unsafe { libc::fstat(fd, &mut st) };
        if rc != 0 {
            bail!(
                "fstat(segment fd {fd}) failed: {} (stale {SEGFDS_ENV} handoff?)",
                std::io::Error::last_os_error()
            );
        }
        if (st.st_size as usize) < len {
            bail!(
                "segment fd {fd} is {} bytes, expected >= {len} \
                 (launcher/PE heap-size mismatch)",
                st.st_size
            );
        }
        let (base, huge) = super::map_shared_fd(fd, len)?;
        Ok(Self {
            base,
            len,
            name: format!("memfd:fd{fd}"),
            fd,
            owns_fd: false,
            huge,
        })
    }

    /// The backing fd (for handoff publication or re-mapping).
    pub fn fd(&self) -> RawFd {
        self.fd
    }
}

impl Segment for MemfdSegment {
    fn base(&self) -> *mut u8 {
        self.base
    }
    fn len(&self) -> usize {
        self.len
    }
    fn name(&self) -> Option<&str> {
        Some(&self.name)
    }
    fn huge_pages(&self) -> HugePageStatus {
        self.huge
    }
}

impl Drop for MemfdSegment {
    fn drop(&mut self) {
        // SAFETY: we own this mapping (and the fd, when creator).
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.len);
            if self.owns_fd {
                libc::close(self.fd);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_map_write_remap() {
        if !memfd_supported() {
            eprintln!("skipping: memfd_create unavailable");
            return;
        }
        let seg = MemfdSegment::create("posh.test.cmwr", 8192).unwrap();
        assert!(seg.len() >= 8192);
        unsafe {
            *seg.base() = 42;
            *seg.base().add(100) = 43;
        }
        // A second mapping of the same fd sees the data (the demand-map
        // path a peer PE takes).
        let view = MemfdSegment::map_existing(seg.fd(), 8192).unwrap();
        unsafe {
            assert_eq!(*view.base(), 42);
            assert_eq!(*view.base().add(100), 43);
            *view.base().add(7) = 9;
            assert_eq!(*seg.base().add(7), 9);
        }
        assert_ne!(view.base(), seg.base());
        assert!(view.name().unwrap().starts_with("memfd:"));
    }

    #[test]
    fn zeroed_and_page_aligned() {
        if !memfd_supported() {
            eprintln!("skipping: memfd_create unavailable");
            return;
        }
        let seg = MemfdSegment::create("posh.test.zero", 10_000).unwrap();
        assert_eq!(seg.base() as usize % super::super::inproc::page_size(), 0);
        let bytes = unsafe { seg.bytes() };
        assert!(bytes.iter().all(|&b| b == 0));
    }

    #[test]
    fn zero_len_rejected() {
        assert!(MemfdSegment::create("posh.test.empty", 0).is_err());
    }

    #[test]
    fn map_existing_rejects_bad_fd() {
        // An fd number far past anything open.
        assert!(MemfdSegment::map_existing(1 << 20, 4096).is_err());
    }

    #[test]
    fn map_existing_rejects_short_segment() {
        if !memfd_supported() {
            eprintln!("skipping: memfd_create unavailable");
            return;
        }
        let seg = MemfdSegment::create("posh.test.short", 4096).unwrap();
        let r = MemfdSegment::map_existing(seg.fd(), 1 << 20);
        assert!(r.is_err(), "size mismatch must be a loud error");
    }

    /// `SEGFDS_ENV` is process-global and libtest runs tests concurrently;
    /// every test that mutates it holds this lock.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn fd_list_roundtrip() {
        let _g = ENV_LOCK.lock().unwrap();
        let fds: Vec<RawFd> = vec![3, 17, 255];
        let enc = encode_fd_list(&fds);
        assert_eq!(enc, "3,17,255");
        std::env::set_var(SEGFDS_ENV, &enc);
        let dec = handoff_fds_from_env().unwrap().unwrap();
        std::env::remove_var(SEGFDS_ENV);
        assert_eq!(dec, fds);
    }

    #[test]
    fn fd_list_garbage_is_error_not_fallback() {
        let _g = ENV_LOCK.lock().unwrap();
        std::env::set_var(SEGFDS_ENV, "3,banana");
        let r = handoff_fds_from_env();
        std::env::remove_var(SEGFDS_ENV);
        assert!(r.is_err());
    }

    #[test]
    fn absent_env_is_none() {
        let _g = ENV_LOCK.lock().unwrap();
        std::env::remove_var(SEGFDS_ENV);
        assert!(handoff_fds_from_env().unwrap().is_none());
    }

    #[test]
    fn handoff_fd_is_bare_and_inheritable() {
        if !memfd_supported() {
            eprintln!("skipping: memfd_create unavailable");
            return;
        }
        let fd = create_handoff_fd("posh.test.handoff", 4096).unwrap();
        // No CLOEXEC: the flag word must not carry FD_CLOEXEC.
        // SAFETY: valid fd.
        let flags = unsafe { libc::fcntl(fd, libc::F_GETFD) };
        assert!(flags >= 0);
        assert_eq!(flags & libc::FD_CLOEXEC, 0, "handoff fds must survive exec");
        let view = MemfdSegment::map_existing(fd, 4096).unwrap();
        unsafe {
            *view.base() = 7;
            assert_eq!(*view.base(), 7);
        }
        drop(view);
        // SAFETY: we own the bare fd.
        unsafe {
            libc::close(fd);
        }
    }
}
