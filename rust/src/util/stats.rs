//! Descriptive statistics and least-squares fitting used by the bench harness
//! (§5 measurement protocol) and the communication cost model (T(n)=α+n/β).

/// Summary statistics over a sample of `f64` observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Number of observations.
    pub n: usize,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (p50).
    pub median: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Sample standard deviation.
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Self {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            stddev: var.sqrt(),
        }
    }
}

/// Percentile (linear interpolation) of an already-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least-squares fit of `y = a + b*x`. Returns `(a, b, r2)`.
///
/// Used by [`crate::model::costmodel`] to fit the paper's communication model
/// `T(n) = α + n/β` (so `α = a`, `β = 1/b`).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need >=2 points to fit a line");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Geometric mean of strictly-positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let logsum: f64 = xs.iter().map(|&x| x.max(f64::MIN_POSITIVE).ln()).sum();
    (logsum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p95, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fit_noisy_line_r2() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 1.0 + 0.5 * x + if i % 2 == 0 { 0.1 } else { -0.1 })
            .collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 0.05);
        assert!((b - 0.5).abs() < 0.01);
        assert!(r2 > 0.99);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
