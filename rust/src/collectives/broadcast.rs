//! Broadcast (`shmem_broadcast32/64` semantics, team-scoped).
//!
//! OpenSHMEM quirk preserved: the **root's `target` is not written** — only
//! the other members of the team receive the data.
//!
//! Variants (§4.5 put- vs get-based, §4.5.4 switching — resolved per call
//! by the tuning engine when the algorithm is `Adaptive`, the default):
//! * `LinearPut` — root pushes into every member's target, then signals.
//!   The model's pick below the latency crossover (small payloads, small
//!   teams: one serial writer, minimal handshaking).
//! * `LinearGet` — root publishes its source handle; members pull
//!   (§4.5.2: the root may not have entered yet, so members spin on the
//!   published handle). The model's pick for bulk payloads, where the
//!   members' pulls proceed in parallel.
//! * `Tree` / `RecursiveDoubling` — binomial tree, log₂(size) rounds;
//!   interior nodes forward from their own `target`. The model's pick for
//!   latency-bound operations on larger teams.

use super::state::ActiveSet;
use super::tuning::CollOp;
use crate::pe::Ctx;
use crate::symheap::layout::CollOpTag;
use crate::symheap::SymPtr;
use crate::team::Team;

impl Ctx {
    /// Broadcast `nelems` elements from the member at team rank `root_idx`'s
    /// `source` to every other member's `target`.
    pub fn broadcast<T: Copy>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nelems: usize,
        root_idx: usize,
        team: &Team,
    ) {
        let set = &team.set;
        assert!(root_idx < set.size, "root index {root_idx} outside team");
        let bytes = nelems * std::mem::size_of::<T>();
        let idx = self.coll_enter(team, CollOpTag::Broadcast, bytes);
        match self.coll_algo_for(CollOp::Broadcast, set.size, bytes) {
            super::AlgoKind::LinearPut => {
                self.bcast_linear_put(target, source, nelems, root_idx, set, idx)
            }
            super::AlgoKind::LinearGet => {
                self.bcast_linear_get(target, source, nelems, root_idx, set, idx)
            }
            super::AlgoKind::Tree | super::AlgoKind::RecursiveDoubling => {
                self.bcast_tree(target, source, nelems, root_idx, set, idx)
            }
            super::AlgoKind::Hierarchical => {
                self.bcast_hier(target, source, nelems, root_idx, set, idx)
            }
            super::AlgoKind::Adaptive => unreachable!("resolved by coll_algo_for"),
        }
        self.coll_exit(team);
    }

    fn bcast_linear_put<T: Copy>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nelems: usize,
        root_idx: usize,
        set: &ActiveSet,
        idx: usize,
    ) {
        if idx == root_idx {
            for i in 0..set.size {
                if i == root_idx {
                    continue;
                }
                let pe = set.rank_at(i);
                // §4.5.2: the member may not have entered yet — wait before
                // touching its user buffer.
                self.coll_wait_entered(pe, CollOpTag::Broadcast);
                self.coll_check_peer(pe, CollOpTag::Broadcast, nelems * std::mem::size_of::<T>());
                self.put_sym(target, pe, source, self.my_pe(), nelems);
            }
            self.fence();
            for i in 0..set.size {
                if i != root_idx {
                    self.coll_signal(set.rank_at(i));
                }
            }
        } else {
            self.coll_wait_count(1);
        }
    }

    fn bcast_linear_get<T: Copy>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nelems: usize,
        root_idx: usize,
        set: &ActiveSet,
        idx: usize,
    ) {
        let root_pe = set.rank_at(root_idx);
        if idx == root_idx {
            // Publish the source; peers may already be spinning (§4.5.2).
            self.coll_publish_buf(source);
            // Wait until every member has pulled.
            self.coll_wait_count((set.size - 1) as u64);
        } else {
            let src_off = self.coll_wait_buf(root_pe);
            let remote_src: SymPtr<T> = SymPtr::from_raw(src_off, nelems);
            self.put_sym(target, self.my_pe(), remote_src, root_pe, nelems);
            self.quiet();
            self.coll_signal(root_pe);
        }
    }

    fn bcast_tree<T: Copy>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nelems: usize,
        root_idx: usize,
        set: &ActiveSet,
        idx: usize,
    ) {
        let size = set.size;
        // Work in root-relative indices.
        let rel = (idx + size - root_idx) % size;
        // Receive round: lowest set bit of rel.
        let mut mask = 1usize;
        while mask < size {
            if rel & mask != 0 {
                break;
            }
            mask <<= 1;
        }
        if rel != 0 {
            // Exactly one parent signal is coming.
            self.coll_wait_count(1);
        }
        // Forward to children: descending masks below our receive bit
        // (for the root, below the first power of two ≥ size).
        let mut m = mask >> 1;
        // What we forward: the root sends `source`, everyone else `target`.
        let from = if rel == 0 { source } else { target };
        while m >= 1 {
            let child_rel = rel + m;
            if child_rel < size {
                let child_idx = (child_rel + root_idx) % size;
                let child_pe = set.rank_at(child_idx);
                // §4.5.2: don't write the child's target before it enters.
                self.coll_wait_entered(child_pe, CollOpTag::Broadcast);
                self.coll_check_peer(child_pe, CollOpTag::Broadcast, nelems * std::mem::size_of::<T>());
                self.put_sym(target, child_pe, from, self.my_pe(), nelems);
                self.fence();
                self.coll_signal(child_pe);
            }
            m >>= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::collectives::AlgoKind;
    use crate::pe::{PoshConfig, World};

    fn bcast_case(algo: AlgoKind, n: usize, root_idx: usize, nelems: usize) {
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(algo);
        let w = World::threads(n, cfg).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<u64>(nelems.max(1)).unwrap();
            let dst = ctx.shmalloc_n::<u64>(nelems.max(1)).unwrap();
            // Root fills its source; everyone poisons target.
            unsafe {
                for (i, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = 1000 + i as u64;
                }
                for d in ctx.local_mut(dst).iter_mut() {
                    *d = u64::MAX;
                }
            }
            ctx.barrier_all();
            ctx.broadcast(dst, src, nelems, root_idx, &team);
            let me = ctx.my_pe();
            let local = unsafe { ctx.local(dst) };
            if team.team_rank_of(me) == Some(root_idx) {
                // Root's target untouched (spec quirk).
                assert!(local[..nelems].iter().all(|&v| v == u64::MAX), "{algo:?}");
            } else {
                for (i, &v) in local[..nelems].iter().enumerate() {
                    assert_eq!(v, 1000 + i as u64, "{algo:?} n={n} root={root_idx} i={i}");
                }
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn broadcast_all_algos_various_sizes() {
        for algo in AlgoKind::all() {
            for &n in &[2usize, 3, 4, 5, 8] {
                bcast_case(algo, n, 0, 33);
            }
        }
    }

    #[test]
    fn broadcast_nonzero_root() {
        for algo in AlgoKind::all() {
            bcast_case(algo, 5, 3, 17);
            bcast_case(algo, 4, 1, 64);
        }
    }

    #[test]
    fn broadcast_single_element() {
        for algo in AlgoKind::all() {
            bcast_case(algo, 3, 0, 1);
        }
    }

    #[test]
    fn broadcast_on_split_team() {
        // Team = ranks {1, 3, 5} of 6; outsiders do unrelated barriers.
        let w = World::threads(6, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world().split_strided(1, 2, 3);
            let src = ctx.shmalloc_n::<u32>(8).unwrap();
            let dst = ctx.shmalloc_n::<u32>(8).unwrap();
            unsafe {
                ctx.local_mut(src).copy_from_slice(&[7; 8]);
            }
            ctx.barrier_all();
            if let Some(team) = &team {
                ctx.broadcast(dst, src, 8, 0, team);
                if team.my_pe() != 0 {
                    assert_eq!(unsafe { ctx.local(dst) }, &[7u32; 8][..]);
                }
            }
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn broadcast_repeated_back_to_back() {
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(AlgoKind::Tree);
        let w = World::threads(4, cfg).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<u64>(4).unwrap();
            let dst = ctx.shmalloc_n::<u64>(4).unwrap();
            for round in 0..100u64 {
                unsafe {
                    for s in ctx.local_mut(src).iter_mut() {
                        *s = round;
                    }
                }
                ctx.broadcast(dst, src, 4, (round % 4) as usize, &team);
                if team.my_pe() != (round % 4) as usize {
                    assert_eq!(unsafe { ctx.local(dst) }, &[round; 4][..]);
                }
            }
        });
    }
}
