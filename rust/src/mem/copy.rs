//! The copy implementations themselves. See module docs in [`crate::mem`].
//!
//! All variants have the same contract as `memcpy`: `dst` and `src` must not
//! overlap and both must be valid for `len` bytes. Every vector variant
//! handles unaligned heads/tails by falling back to byte copies at the edges
//! and runs its vector body on the aligned middle, exactly like the paper's
//! hand-written loops.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which copy implementation to use. Mirrors the paper's
/// `-D_MEMCPY_{MMX,MMX2,SSE}` compile switches; see [`CopyImpl::default_impl`]
/// for the feature wiring.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum CopyImpl {
    /// libc/compiler `memcpy` — the paper's "stock" row.
    Stock = 0,
    /// 8×-unrolled 64-bit scalar loop — the MMX-era 64-bit path.
    Unrolled64 = 1,
    /// 128-bit SSE2 loads/stores — the paper's SSE row.
    Sse2 = 2,
    /// 256-bit AVX2 loads/stores — the modern continuation of the sweep.
    Avx2 = 3,
    /// 128-bit non-temporal (streaming) stores — the MMX2 `movnt` trick.
    NonTemporal = 4,
    /// 512-bit AVX-512F loads/stores — the widest temporal vector path.
    Avx512 = 5,
    /// 512-bit AVX-512F non-temporal (streaming) stores — the cache-bypass
    /// engine for copies past the LLC.
    Avx512Nt = 6,
}

impl CopyImpl {
    /// All variants that can run on the current CPU, in table order.
    pub fn available() -> Vec<CopyImpl> {
        let mut v = vec![CopyImpl::Stock, CopyImpl::Unrolled64];
        #[cfg(target_arch = "x86_64")]
        {
            // SSE2 is baseline on x86_64.
            v.push(CopyImpl::Sse2);
            if std::arch::is_x86_feature_detected!("avx2") {
                v.push(CopyImpl::Avx2);
            }
            v.push(CopyImpl::NonTemporal);
            if std::arch::is_x86_feature_detected!("avx512f") {
                v.push(CopyImpl::Avx512);
                v.push(CopyImpl::Avx512Nt);
            }
        }
        v
    }

    /// Decode a stored discriminant. Exhaustive by construction: every
    /// variant decodes to itself, everything else is `None` — so a future
    /// variant added without updating this function shows up as a loud
    /// fallback at the caller instead of silently becoming some other
    /// engine (the historical `_ => NonTemporal` bug).
    pub fn from_u8(v: u8) -> Option<CopyImpl> {
        match v {
            0 => Some(CopyImpl::Stock),
            1 => Some(CopyImpl::Unrolled64),
            2 => Some(CopyImpl::Sse2),
            3 => Some(CopyImpl::Avx2),
            4 => Some(CopyImpl::NonTemporal),
            5 => Some(CopyImpl::Avx512),
            6 => Some(CopyImpl::Avx512Nt),
            _ => None,
        }
    }

    /// The compile-time default (paper §4.4: one impl is activated by a
    /// compiler directive; default = stock with a note in the build log).
    pub const fn default_impl() -> CopyImpl {
        #[cfg(feature = "copy-avx2")]
        {
            return CopyImpl::Avx2;
        }
        #[cfg(all(feature = "copy-sse2", not(feature = "copy-avx2")))]
        {
            return CopyImpl::Sse2;
        }
        #[cfg(all(
            feature = "copy-unrolled",
            not(any(feature = "copy-sse2", feature = "copy-avx2"))
        ))]
        {
            return CopyImpl::Unrolled64;
        }
        #[cfg(all(
            feature = "copy-nontemporal",
            not(any(
                feature = "copy-sse2",
                feature = "copy-avx2",
                feature = "copy-unrolled"
            ))
        ))]
        {
            return CopyImpl::NonTemporal;
        }
        #[allow(unreachable_code)]
        CopyImpl::Stock
    }

    /// Human-readable name matching the paper's table headers.
    pub fn name(&self) -> &'static str {
        match self {
            CopyImpl::Stock => "memcpy",
            CopyImpl::Unrolled64 => "unrolled64",
            CopyImpl::Sse2 => "sse2",
            CopyImpl::Avx2 => "avx2",
            CopyImpl::NonTemporal => "nontemporal",
            CopyImpl::Avx512 => "avx512",
            CopyImpl::Avx512Nt => "avx512nt",
        }
    }

    /// Parse from CLI / env spellings.
    pub fn parse(s: &str) -> Option<CopyImpl> {
        match s.to_ascii_lowercase().as_str() {
            "stock" | "memcpy" => Some(CopyImpl::Stock),
            "unrolled" | "unrolled64" | "mmx" => Some(CopyImpl::Unrolled64),
            "sse" | "sse2" => Some(CopyImpl::Sse2),
            "avx" | "avx2" => Some(CopyImpl::Avx2),
            "nt" | "nontemporal" | "mmx2" => Some(CopyImpl::NonTemporal),
            "avx512" | "avx512f" => Some(CopyImpl::Avx512),
            "avx512nt" | "ntavx512" => Some(CopyImpl::Avx512Nt),
            _ => None,
        }
    }
}

/// Sentinel stored in [`GLOBAL_IMPL`] when no engine is forced: the copy
/// path then dispatches per size through the global [`super::plan::CopyPlan`].
const PLANNED_SENTINEL: u8 = u8::MAX;

/// Raw start-up value of the dispatch word: a `copy-*` cargo feature pins
/// its engine exactly as the paper's `-D_MEMCPY_*` switches did; with no
/// feature the default is size-aware planned dispatch.
const fn default_raw() -> u8 {
    #[cfg(any(
        feature = "copy-avx2",
        feature = "copy-sse2",
        feature = "copy-unrolled",
        feature = "copy-nontemporal"
    ))]
    {
        return CopyImpl::default_impl() as u8;
    }
    #[allow(unreachable_code)]
    PLANNED_SENTINEL
}

/// Process-wide dispatch state (runtime dispatch). Either a forced engine
/// discriminant (compile-time feature or `POSH_COPY=`/`set_global_impl`) or
/// [`PLANNED_SENTINEL`], in which case every copy resolves its engine per
/// size class through the global [`super::plan::CopyPlan`]. The hot path
/// reads it with a relaxed load — one predictable branch, matching the
/// paper's "no conditional branches on the data path" goal as closely as a
/// size switch allows.
static GLOBAL_IMPL: AtomicU8 = AtomicU8::new(default_raw());

/// Force one process-wide copy implementation for every size (the paper's
/// compile-time model, relocated to start-up).
pub fn set_global_impl(imp: CopyImpl) {
    GLOBAL_IMPL.store(imp as u8, Ordering::Relaxed);
}

/// Restore size-aware planned dispatch (undoes [`set_global_impl`]).
pub fn set_global_planned() {
    GLOBAL_IMPL.store(PLANNED_SENTINEL, Ordering::Relaxed);
}

/// The forced process-wide engine, if one is installed. `None` means
/// size-aware planned dispatch (the default), and — by the exhaustive
/// [`CopyImpl::from_u8`] decode — also any unknown discriminant, so a
/// corrupted or future value degrades to the plan, never to a silently
/// wrong fixed engine.
#[inline]
pub fn forced_impl() -> Option<CopyImpl> {
    CopyImpl::from_u8(GLOBAL_IMPL.load(Ordering::Relaxed))
}

/// Read the process-wide copy implementation a size-less caller would get.
///
/// Decodes the dispatch word exhaustively via [`CopyImpl::from_u8`] and
/// falls back to [`CopyImpl::Stock`] — under planned dispatch (no forced
/// engine) there *is* no single engine, and stock is the honest size-less
/// answer. Size-carrying callers should use [`engine_for`] instead.
#[inline]
pub fn global_impl() -> CopyImpl {
    forced_impl().unwrap_or(CopyImpl::Stock)
}

/// The engine a `len`-byte copy dispatches to right now: the forced engine
/// when one is installed, otherwise the global plan's size class.
#[inline]
pub fn engine_for(len: usize) -> CopyImpl {
    match forced_impl() {
        Some(imp) => imp,
        None => super::plan::planned_engine_for(len),
    }
}

/// Human-readable description of the current dispatch mode (bench headers,
/// `oshrun info`).
pub fn dispatch_name() -> String {
    match forced_impl() {
        Some(imp) => imp.name().to_string(),
        None => format!("planned[{}]", super::plan::global_plan()),
    }
}

/// Copy `len` bytes with the process-wide dispatch (forced engine or the
/// size-aware plan).
///
/// # Safety
/// Same contract as `memcpy`: non-overlapping, both valid for `len`.
#[inline]
pub unsafe fn copy_bytes(dst: *mut u8, src: *const u8, len: usize) {
    copy_bytes_with(engine_for(len), dst, src, len)
}

/// Copy `len` bytes with an explicit implementation (bench sweeps).
///
/// # Safety
/// Same contract as `memcpy`.
#[inline]
pub unsafe fn copy_bytes_with(imp: CopyImpl, dst: *mut u8, src: *const u8, len: usize) {
    match imp {
        CopyImpl::Stock => std::ptr::copy_nonoverlapping(src, dst, len),
        CopyImpl::Unrolled64 => copy_unrolled64(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::Sse2 => copy_sse2(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::Avx2 => copy_avx2(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::NonTemporal => copy_nontemporal(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::Avx512 => copy_avx512(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::Avx512Nt => copy_avx512_nt(dst, src, len),
        #[cfg(not(target_arch = "x86_64"))]
        _ => std::ptr::copy_nonoverlapping(src, dst, len),
    }
}

/// 8×-unrolled 64-bit word loop with byte head/tail.
///
/// # Safety
/// `memcpy` contract.
pub unsafe fn copy_unrolled64(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    // Align the *destination* to 8 bytes (stores are the expensive side).
    while len > 0 && (dst as usize) & 7 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    let mut d = dst as *mut u64;
    let mut s = src as *const u64;
    while len >= 64 {
        // Unaligned reads are fine on x86; the stores are aligned.
        let v0 = (s as *const u64).read_unaligned();
        let v1 = (s.add(1) as *const u64).read_unaligned();
        let v2 = (s.add(2) as *const u64).read_unaligned();
        let v3 = (s.add(3) as *const u64).read_unaligned();
        let v4 = (s.add(4) as *const u64).read_unaligned();
        let v5 = (s.add(5) as *const u64).read_unaligned();
        let v6 = (s.add(6) as *const u64).read_unaligned();
        let v7 = (s.add(7) as *const u64).read_unaligned();
        d.write(v0);
        d.add(1).write(v1);
        d.add(2).write(v2);
        d.add(3).write(v3);
        d.add(4).write(v4);
        d.add(5).write(v5);
        d.add(6).write(v6);
        d.add(7).write(v7);
        d = d.add(8);
        s = s.add(8);
        len -= 64;
    }
    while len >= 8 {
        d.write((s as *const u64).read_unaligned());
        d = d.add(1);
        s = s.add(1);
        len -= 8;
    }
    let mut db = d as *mut u8;
    let mut sb = s as *const u8;
    while len > 0 {
        *db = *sb;
        db = db.add(1);
        sb = sb.add(1);
        len -= 1;
    }
}

/// 128-bit SSE2 loop (paper's SSE implementation).
///
/// # Safety
/// `memcpy` contract; x86_64 only (SSE2 is baseline there).
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_sse2(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 15 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 64 {
        let v0 = _mm_loadu_si128(src as *const __m128i);
        let v1 = _mm_loadu_si128(src.add(16) as *const __m128i);
        let v2 = _mm_loadu_si128(src.add(32) as *const __m128i);
        let v3 = _mm_loadu_si128(src.add(48) as *const __m128i);
        _mm_store_si128(dst as *mut __m128i, v0);
        _mm_store_si128(dst.add(16) as *mut __m128i, v1);
        _mm_store_si128(dst.add(32) as *mut __m128i, v2);
        _mm_store_si128(dst.add(48) as *mut __m128i, v3);
        dst = dst.add(64);
        src = src.add(64);
        len -= 64;
    }
    while len >= 16 {
        let v = _mm_loadu_si128(src as *const __m128i);
        _mm_store_si128(dst as *mut __m128i, v);
        dst = dst.add(16);
        src = src.add(16);
        len -= 16;
    }
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
}

/// 256-bit AVX2 loop. Falls back to SSE2 when AVX2 is absent.
///
/// # Safety
/// `memcpy` contract.
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_avx2(dst: *mut u8, src: *const u8, len: usize) {
    if std::arch::is_x86_feature_detected!("avx2") {
        copy_avx2_inner(dst, src, len);
    } else {
        copy_sse2(dst, src, len);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn copy_avx2_inner(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 31 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 128 {
        let v0 = _mm256_loadu_si256(src as *const __m256i);
        let v1 = _mm256_loadu_si256(src.add(32) as *const __m256i);
        let v2 = _mm256_loadu_si256(src.add(64) as *const __m256i);
        let v3 = _mm256_loadu_si256(src.add(96) as *const __m256i);
        _mm256_store_si256(dst as *mut __m256i, v0);
        _mm256_store_si256(dst.add(32) as *mut __m256i, v1);
        _mm256_store_si256(dst.add(64) as *mut __m256i, v2);
        _mm256_store_si256(dst.add(96) as *mut __m256i, v3);
        dst = dst.add(128);
        src = src.add(128);
        len -= 128;
    }
    while len >= 32 {
        let v = _mm256_loadu_si256(src as *const __m256i);
        _mm256_store_si256(dst as *mut __m256i, v);
        dst = dst.add(32);
        src = src.add(32);
        len -= 32;
    }
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
}

/// 128-bit streaming (non-temporal) stores + trailing sfence — the MMX2
/// `movntq` idea: don't pollute the cache with data the producer will not
/// re-read. Only profitable for large copies; the put/get engine never picks
/// it for small messages.
///
/// The `sfence` comes **after every store of the copy, including the byte
/// tail** — the quiet/fence guarantee ("all of this put is visible") must
/// never depend on the tail happening to be strongly-ordered plain stores;
/// see docs/memory_model.md.
///
/// # Safety
/// `memcpy` contract.
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_nontemporal(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 15 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 64 {
        let v0 = _mm_loadu_si128(src as *const __m128i);
        let v1 = _mm_loadu_si128(src.add(16) as *const __m128i);
        let v2 = _mm_loadu_si128(src.add(32) as *const __m128i);
        let v3 = _mm_loadu_si128(src.add(48) as *const __m128i);
        _mm_stream_si128(dst as *mut __m128i, v0);
        _mm_stream_si128(dst.add(16) as *mut __m128i, v1);
        _mm_stream_si128(dst.add(32) as *mut __m128i, v2);
        _mm_stream_si128(dst.add(48) as *mut __m128i, v3);
        dst = dst.add(64);
        src = src.add(64);
        len -= 64;
    }
    while len >= 16 {
        let v = _mm_loadu_si128(src as *const __m128i);
        _mm_stream_si128(dst as *mut __m128i, v);
        dst = dst.add(16);
        src = src.add(16);
        len -= 16;
    }
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    // Streaming stores are weakly ordered; fence after ALL stores (vector
    // body and byte tail alike) so the copy is globally visible before any
    // subsequent signal/flag store.
    _mm_sfence();
}

/// 512-bit AVX-512F loop (temporal). Falls back to AVX2 (which itself falls
/// back to SSE2) when AVX-512F is absent.
///
/// # Safety
/// `memcpy` contract.
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_avx512(dst: *mut u8, src: *const u8, len: usize) {
    if std::arch::is_x86_feature_detected!("avx512f") {
        copy_avx512_inner(dst, src, len);
    } else {
        copy_avx2(dst, src, len);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn copy_avx512_inner(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 63 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 256 {
        let v0 = _mm512_loadu_si512(src as *const _);
        let v1 = _mm512_loadu_si512(src.add(64) as *const _);
        let v2 = _mm512_loadu_si512(src.add(128) as *const _);
        let v3 = _mm512_loadu_si512(src.add(192) as *const _);
        _mm512_store_si512(dst as *mut _, v0);
        _mm512_store_si512(dst.add(64) as *mut _, v1);
        _mm512_store_si512(dst.add(128) as *mut _, v2);
        _mm512_store_si512(dst.add(192) as *mut _, v3);
        dst = dst.add(256);
        src = src.add(256);
        len -= 256;
    }
    while len >= 64 {
        let v = _mm512_loadu_si512(src as *const _);
        _mm512_store_si512(dst as *mut _, v);
        dst = dst.add(64);
        src = src.add(64);
        len -= 64;
    }
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
}

/// 512-bit AVX-512F streaming (non-temporal) stores + trailing sfence — the
/// cache-bypass engine for copies past the LLC. Falls back to the 128-bit
/// non-temporal loop when AVX-512F is absent.
///
/// Same fence obligation as [`copy_nontemporal`]: the `sfence` is issued
/// after **all** stores, tail included.
///
/// # Safety
/// `memcpy` contract.
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_avx512_nt(dst: *mut u8, src: *const u8, len: usize) {
    if std::arch::is_x86_feature_detected!("avx512f") {
        copy_avx512_nt_inner(dst, src, len);
    } else {
        copy_nontemporal(dst, src, len);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx512f")]
unsafe fn copy_avx512_nt_inner(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 63 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 256 {
        let v0 = _mm512_loadu_si512(src as *const _);
        let v1 = _mm512_loadu_si512(src.add(64) as *const _);
        let v2 = _mm512_loadu_si512(src.add(128) as *const _);
        let v3 = _mm512_loadu_si512(src.add(192) as *const _);
        _mm512_stream_si512(dst as *mut _, v0);
        _mm512_stream_si512(dst.add(64) as *mut _, v1);
        _mm512_stream_si512(dst.add(128) as *mut _, v2);
        _mm512_stream_si512(dst.add(192) as *mut _, v3);
        dst = dst.add(256);
        src = src.add(256);
        len -= 256;
    }
    while len >= 64 {
        let v = _mm512_loadu_si512(src as *const _);
        _mm512_stream_si512(dst as *mut _, v);
        dst = dst.add(64);
        src = src.add(64);
        len -= 64;
    }
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    // Fence after ALL stores — see copy_nontemporal.
    _mm_sfence();
}

/// Safe wrapper: copy between slices (must be same length, non-overlapping by
/// construction).
pub fn copy_slice_with(imp: CopyImpl, dst: &mut [u8], src: &[u8]) {
    assert_eq!(dst.len(), src.len(), "copy_slice_with length mismatch");
    unsafe { copy_bytes_with(imp, dst.as_mut_ptr(), src.as_ptr(), src.len()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn check_impl(imp: CopyImpl) {
        let mut rng = Rng::new(0xDEAD ^ imp as u64);
        // Cover: 0, tiny, word-size, odd sizes, vector sizes, unaligned offsets.
        for &len in &[0usize, 1, 3, 7, 8, 15, 16, 17, 31, 32, 63, 64, 65, 100, 127, 128, 1000, 4096, 10_000] {
            for &(doff, soff) in &[(0usize, 0usize), (1, 0), (0, 1), (3, 5), (7, 9)] {
                let mut src = vec![0u8; len + soff];
                rng.fill_bytes(&mut src);
                let mut dst = vec![0xAAu8; len + doff + 1]; // +1 canary
                let canary_idx = len + doff;
                dst[canary_idx] = 0x5C;
                unsafe {
                    copy_bytes_with(imp, dst.as_mut_ptr().add(doff), src.as_ptr().add(soff), len);
                }
                assert_eq!(&dst[doff..doff + len], &src[soff..soff + len],
                    "{:?} len={} doff={} soff={}", imp, len, doff, soff);
                assert_eq!(dst[canary_idx], 0x5C, "{:?} overwrote past end (len={})", imp, len);
                // head must be untouched
                assert!(dst[..doff].iter().all(|&b| b == 0xAA), "{:?} underwrote (len={})", imp, len);
            }
        }
    }

    #[test]
    fn stock_correct() {
        check_impl(CopyImpl::Stock);
    }

    #[test]
    fn unrolled64_correct() {
        check_impl(CopyImpl::Unrolled64);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse2_correct() {
        check_impl(CopyImpl::Sse2);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_correct() {
        check_impl(CopyImpl::Avx2); // falls back to sse2 when unavailable
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn nontemporal_correct() {
        check_impl(CopyImpl::NonTemporal);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_correct() {
        check_impl(CopyImpl::Avx512); // falls back to avx2 when unavailable
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx512_nt_correct() {
        check_impl(CopyImpl::Avx512Nt); // falls back to nontemporal when unavailable
    }

    #[test]
    fn from_u8_exhaustive() {
        // Every advertised engine decodes back to itself...
        for imp in CopyImpl::available() {
            assert_eq!(CopyImpl::from_u8(imp as u8), Some(imp));
        }
        // ...and every unknown discriminant decodes to None (never silently
        // to NonTemporal, which was the old catch-all bug).
        for raw in 7u8..=255 {
            assert_eq!(CopyImpl::from_u8(raw), None, "raw={raw}");
        }
    }

    #[test]
    fn available_contains_baselines() {
        let avail = CopyImpl::available();
        assert!(avail.contains(&CopyImpl::Stock));
        assert!(avail.contains(&CopyImpl::Unrolled64));
    }

    #[test]
    fn parse_roundtrip() {
        for imp in CopyImpl::available() {
            assert_eq!(CopyImpl::parse(imp.name()), Some(imp));
        }
        assert_eq!(CopyImpl::parse("mmx"), Some(CopyImpl::Unrolled64));
        assert_eq!(CopyImpl::parse("bogus"), None);
    }

    // One test owns all GLOBAL_IMPL mutation: the harness runs tests in
    // parallel threads, so splitting these into separate #[test] fns would
    // race on the process-wide dispatch state.
    #[test]
    fn global_dispatch_states() {
        let _guard = super::super::plan::TEST_DISPATCH_LOCK.lock().unwrap();
        let before = forced_impl();

        // Forced-engine mode round-trips.
        set_global_impl(CopyImpl::Unrolled64);
        assert_eq!(global_impl(), CopyImpl::Unrolled64);
        assert_eq!(forced_impl(), Some(CopyImpl::Unrolled64));
        assert_eq!(dispatch_name(), "unrolled64");

        // Planned mode: no forced engine; global_impl() reports the
        // conservative Stock fallback while engine_for() consults the
        // size-class plan.
        set_global_planned();
        assert_eq!(forced_impl(), None);
        assert_eq!(global_impl(), CopyImpl::Stock);
        let plan = super::super::plan::global_plan();
        for &len in &[0usize, 1, 64, 4096, 1 << 20, 64 << 20] {
            assert_eq!(engine_for(len), plan.engine_for(len), "len={len}");
        }
        assert!(dispatch_name().starts_with("planned["));

        // Restore exactly, including the "planned" (no forced engine) state.
        match before {
            Some(imp) => set_global_impl(imp),
            None => set_global_planned(),
        }
    }

    #[test]
    fn copy_slice_matches() {
        let src: Vec<u8> = (0..=255u8).cycle().take(777).collect();
        for imp in CopyImpl::available() {
            let mut dst = vec![0u8; 777];
            copy_slice_with(imp, &mut dst, &src);
            assert_eq!(dst, src);
        }
    }
}
