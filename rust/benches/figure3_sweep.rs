//! **Figure 3** — "Communication performance of Paris OpenSHMEM on Maximum":
//! latency and bandwidth of put/get vs buffer size (log-log), regenerated as
//! CSV series + terminal ASCII plots, with the paper's Maximum model plotted
//! alongside for the shape comparison.

use posh::bench::{ascii_plot, auto_batch, measure, write_series_csv, Series};
use posh::mem::copy::dispatch_name;
use posh::model::machines::paper_machines;
use posh::model::CostModel;
use posh::pe::{PoshConfig, World};

const MAX_SIZE: usize = 64 << 20;

fn main() {
    let mut cfg = PoshConfig::default();
    cfg.heap_size = MAX_SIZE + (8 << 20);
    let world = World::threads(2, cfg).unwrap();
    println!("Figure 3 sweep: put/get, 8 B .. 64 MiB, copy dispatch {}", dispatch_name());

    let samples: Vec<Vec<(usize, f64, f64)>> = world.run_collect(|ctx| {
        let buf = ctx.shmalloc_n::<u8>(MAX_SIZE).unwrap();
        let mut out = Vec::new();
        if ctx.my_pe() == 0 {
            let src = vec![0x3Cu8; MAX_SIZE];
            let mut dst = vec![0u8; MAX_SIZE];
            let mut size = 8usize;
            while size <= MAX_SIZE {
                let batch = auto_batch(20.0 + size as f64 / 8.0);
                let put = measure(size, batch, || {
                    ctx.put(buf, &src[..size], 1);
                });
                let get = measure(size, batch, || {
                    ctx.get(&mut dst[..size], buf, 1);
                });
                out.push((size, put.latency_ns(), get.latency_ns()));
                size *= 2;
            }
        }
        ctx.barrier_all();
        out
    });
    let samples = &samples[0];

    let mut put_lat = Series::new("put_ns");
    let mut get_lat = Series::new("get_ns");
    let mut put_bw = Series::new("put_gbps");
    let mut get_bw = Series::new("get_gbps");
    let mut paper_put = Series::new("paper_maximum_put_gbps");
    let max_model = paper_machines().into_iter().find(|m| m.name == "Maximum").unwrap();
    for &(size, p, g) in samples {
        put_lat.push(size, p);
        get_lat.push(size, g);
        put_bw.push(size, size as f64 * 8.0 / p);
        get_bw.push(size, size as f64 * 8.0 / g);
        paper_put.push(size, max_model.posh_put.predict_gbps(size));
    }

    println!("\nlatency (ns) vs size:");
    ascii_plot(&put_lat, 10);
    println!("\nbandwidth (Gb/s) vs size:");
    ascii_plot(&put_bw, 10);

    write_series_csv("figure3_latency", "bytes", &[put_lat, get_lat]).unwrap();
    write_series_csv(
        "figure3_bandwidth",
        "bytes",
        &[put_bw, get_bw, paper_put],
    )
    .unwrap();

    // --- Fitted communication model (paper §1) + shape checks.
    let put_model = CostModel::fit(&samples.iter().map(|&(s, p, _)| (s, p)).collect::<Vec<_>>());
    let get_model = CostModel::fit(&samples.iter().map(|&(s, _, g)| (s, g)).collect::<Vec<_>>());
    println!("\nfitted: put {put_model}");
    println!("fitted: get {get_model}");
    // Under planned dispatch the sweep crosses engine boundaries (stock →
    // temporal vector → NT streaming), so a *single* affine fit is looser
    // than it was with one pinned engine — each regime alone is tight (the
    // piecewise model in `oshrun calibrate` shows per-range R²), but one
    // α/β across regimes absorbs the β steps. 0.9 still rejects any
    // non-affine shape while tolerating the plan's β discontinuities.
    assert!(put_model.r2 > 0.9, "put must follow T(n)=α+n/β (R² {})", put_model.r2);
    assert!(get_model.r2 > 0.9, "get must follow T(n)=α+n/β (R² {})", get_model.r2);
    // Figure-3 shape: monotone latency, bandwidth saturating at large sizes
    // (final point within 3x of peak; small sizes latency-bound).
    let last = samples.last().unwrap();
    let last_bw = last.0 as f64 * 8.0 / last.1;
    assert!(
        last_bw > put_model.peak_gbps() / 3.0,
        "bandwidth must approach the asymptote at 64 MiB"
    );
    let first = samples.first().unwrap();
    let first_bw = first.0 as f64 * 8.0 / first.1;
    assert!(first_bw < last_bw, "small messages are latency-bound");
    println!("shape check OK; csv: bench_out/figure3_latency.csv, bench_out/figure3_bandwidth.csv");
}
