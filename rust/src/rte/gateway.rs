//! The gateway process's IO forwarding (§4.7 "Inputs and outputs").
//!
//! "The parallel application is made of several processes, whereas the user
//! is in contact with only one process: the master process, which is used as
//! a gateway between the user and the application." Child stdout/stderr are
//! piped to the master and re-emitted line-by-line with a rank prefix
//! (`[PE k] …`), each stream on its own forwarder thread so interleaving is
//! line-granular, never byte-granular.

use std::io::{BufRead, BufReader, Read, Write};
use std::sync::mpsc::{channel, Receiver, Sender};

/// One forwarded line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IoLine {
    /// Which PE produced it.
    pub rank: usize,
    /// `false` = stdout, `true` = stderr.
    pub is_err: bool,
    /// The text, without the trailing newline.
    pub line: String,
}

impl IoLine {
    /// The gateway's display format.
    pub fn render(&self) -> String {
        if self.is_err {
            format!("[PE {}!] {}", self.rank, self.line)
        } else {
            format!("[PE {}] {}", self.rank, self.line)
        }
    }
}

/// Collects forwarder threads and the line channel.
pub struct Gateway {
    tx: Sender<IoLine>,
    rx: Receiver<IoLine>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Default for Gateway {
    fn default() -> Self {
        Self::new()
    }
}

impl Gateway {
    /// New, with no streams attached yet.
    pub fn new() -> Gateway {
        let (tx, rx) = channel();
        Gateway { tx, rx, threads: Vec::new() }
    }

    /// Attach one child stream; a forwarder thread pumps it until EOF.
    pub fn attach<R: Read + Send + 'static>(&mut self, rank: usize, is_err: bool, stream: R) {
        let tx = self.tx.clone();
        self.threads.push(std::thread::spawn(move || {
            let reader = BufReader::new(stream);
            for line in reader.lines() {
                let Ok(line) = line else { break };
                if tx.send(IoLine { rank, is_err, line }).is_err() {
                    break;
                }
            }
        }));
    }

    /// Pump every pending and future line to the given sink until all
    /// attached streams hit EOF; returns the forwarded lines.
    pub fn pump_to<W: Write>(mut self, sink: &mut W) -> std::io::Result<Vec<IoLine>> {
        drop(self.tx); // close our clone so rx terminates at last-EOF
        let mut all = Vec::new();
        for line in self.rx.iter() {
            writeln!(sink, "{}", line.render())?;
            all.push(line);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        Ok(all)
    }
}

/// Fan a signal out to every child (the §4.7 signal-forwarding contract:
/// "if the user sends a signal to the gateway process, this signal is sent
/// to all the processes of the parallel application").
pub fn forward_signal(pids: &[u32], signal: i32) {
    for &pid in pids {
        // SAFETY: plain kill(2); failure (ESRCH on exited child) is fine.
        unsafe {
            libc::kill(pid as libc::pid_t, signal);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_are_rank_prefixed_and_complete() {
        let mut gw = Gateway::new();
        gw.attach(0, false, std::io::Cursor::new("alpha\nbeta\n"));
        gw.attach(1, false, std::io::Cursor::new("gamma\n"));
        gw.attach(1, true, std::io::Cursor::new("oops\n"));
        let mut out = Vec::new();
        let lines = gw.pump_to(&mut out).unwrap();
        assert_eq!(lines.len(), 4);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("[PE 0] alpha"));
        assert!(text.contains("[PE 0] beta"));
        assert!(text.contains("[PE 1] gamma"));
        assert!(text.contains("[PE 1!] oops"));
    }

    #[test]
    fn empty_streams_terminate() {
        let mut gw = Gateway::new();
        gw.attach(0, false, std::io::Cursor::new(""));
        let mut out = Vec::new();
        let lines = gw.pump_to(&mut out).unwrap();
        assert!(lines.is_empty());
    }

    #[test]
    fn forwarding_from_real_children() {
        use crate::rte::launcher::{JobSpec, Launcher};
        let mut spec = JobSpec::new(2, "/bin/sh");
        spec.args = vec!["-c".into(), "echo hello-from-$POSH_RANK".into()];
        let l = Launcher::new(spec);
        let mut pes = l.spawn_all().unwrap();
        let mut gw = Gateway::new();
        for pe in pes.iter_mut() {
            gw.attach(pe.rank, false, pe.child.stdout.take().unwrap());
            gw.attach(pe.rank, true, pe.child.stderr.take().unwrap());
        }
        let mut out = Vec::new();
        let lines = gw.pump_to(&mut out).unwrap();
        for pe in pes.iter_mut() {
            assert!(pe.child.wait().unwrap().success());
        }
        let mut stdouts: Vec<String> =
            lines.iter().filter(|l| !l.is_err).map(|l| l.render()).collect();
        stdouts.sort();
        assert_eq!(stdouts, vec!["[PE 0] hello-from-0", "[PE 1] hello-from-1"]);
    }
}
