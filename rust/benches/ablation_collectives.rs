//! **Ablation A** (DESIGN.md §3; paper §4.5.4) — the collective-algorithm
//! switch: broadcast and reduce latency per algorithm family × payload size
//! × PE count. Regenerates the data a POSH maintainer would use to pick the
//! compile-time default.

use posh::bench::{measure, Table};
use posh::collectives::{AlgoKind, ReduceOp};
use posh::pe::{PoshConfig, World};
use std::sync::atomic::{AtomicU64, Ordering};

fn bench_world(n: usize, algo: AlgoKind, nelems: usize) -> (f64, f64) {
    let mut cfg = PoshConfig::small();
    cfg.coll_algo = Some(algo);
    // LinearPut roots stage (n-1) contributions (Lemma-1 scratch): size for it.
    cfg.heap_size = (nelems * 8 * (n + 4)).max(4 << 20);
    let w = World::threads(n, cfg).unwrap();
    let bcast_ns = AtomicU64::new(0);
    let reduce_ns = AtomicU64::new(0);
    w.run(|ctx| {
        let team = ctx.team_world();
        let src = ctx.shmalloc_n::<i64>(nelems).unwrap();
        let dst = ctx.shmalloc_n::<i64>(nelems).unwrap();
        unsafe {
            for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                *s = (ctx.my_pe() + j) as i64;
            }
        }
        ctx.barrier_all();
        let reps = if nelems >= 1 << 18 { 5 } else { 30 };
        let m = measure(nelems * 8, reps, || {
            ctx.broadcast(dst, src, nelems, 0, &team);
        });
        if ctx.my_pe() == 0 {
            bcast_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
        }
        ctx.barrier_all();
        let m = measure(nelems * 8, reps, || {
            ctx.reduce_to_all(dst, src, nelems, ReduceOp::Sum, &team);
        });
        if ctx.my_pe() == 0 {
            reduce_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
        }
        ctx.barrier_all();
    });
    (
        bcast_ns.load(Ordering::Relaxed) as f64,
        reduce_ns.load(Ordering::Relaxed) as f64,
    )
}

fn main() {
    let algo_names: Vec<&str> = AlgoKind::all().iter().map(|a| a.name()).collect();
    for &nelems in &[64usize, 8192, 262_144] {
        let mut bcast = Table::new(
            &format!("Ablation A: broadcast, {} i64/PE", nelems),
            "ns/op",
            &algo_names,
        );
        let mut reduce = Table::new(
            &format!("Ablation A: reduce(sum), {} i64/PE", nelems),
            "ns/op",
            &algo_names,
        );
        for &n in &[2usize, 4, 8] {
            let mut brow = Vec::new();
            let mut rrow = Vec::new();
            for algo in AlgoKind::all() {
                let (b, r) = bench_world(n, algo, nelems);
                brow.push(b);
                rrow.push(r);
            }
            bcast.row(&format!("{n} PEs"), brow);
            reduce.row(&format!("{n} PEs"), rrow);
        }
        bcast.print();
        reduce.print();
        bcast.write_csv(&format!("ablationA_broadcast_{nelems}")).unwrap();
        reduce.write_csv(&format!("ablationA_reduce_{nelems}")).unwrap();
    }
    println!("\ncsv: bench_out/ablationA_*.csv");
}
