//! **Table 3** — "Comparison of the performance observed by put and get
//! operations with UPC" (Berkeley UPC / GASNet smp in the paper; our
//! independent UPC-model baseline here — DESIGN.md §1).
//!
//! Printed side by side with POSH so §5.3's conclusion is checkable: "both
//! POSH and another one-sided communication library have performance that
//! are close to a memory copy within the address space of a single process"
//! — with UPC paying its per-access resolution cost at small sizes.

use posh::baseline::upc::{Consistency, UpcWorld};
use posh::bench::{auto_batch, measure, Table};
use posh::mem::copy::{copy_bytes_with, CopyImpl};
use posh::model::machines::paper_machines;
use posh::pe::{PoshConfig, World};

const LAT_SIZE: usize = 8;
const BW_SIZE: usize = 64 << 20;

fn main() {
    // --- UPC-model measurements.
    let upc = UpcWorld::new(2, BW_SIZE + (1 << 20)).unwrap();
    let p = upc.global_ptr(1, 0);
    let src = vec![0xB4u8; BW_SIZE];
    let mut dst = vec![0u8; BW_SIZE];

    let upc_get_lat = measure(LAT_SIZE, auto_batch(60.0), || {
        upc.memget(&mut dst[..LAT_SIZE], p, Consistency::Relaxed);
    })
    .latency_ns();
    let upc_put_lat = measure(LAT_SIZE, auto_batch(60.0), || {
        upc.memput(p, &src[..LAT_SIZE], Consistency::Relaxed);
    })
    .latency_ns();
    let upc_get_bw = measure(BW_SIZE, 1, || {
        upc.memget(&mut dst, p, Consistency::Relaxed);
    })
    .bandwidth_gbps();
    let upc_put_bw = measure(BW_SIZE, 1, || {
        upc.memput(p, &src, Consistency::Relaxed);
    })
    .bandwidth_gbps();

    // --- POSH measurements on the same sizes (stock engine = same memcpy).
    let mut cfg = PoshConfig::default();
    cfg.heap_size = BW_SIZE + (8 << 20);
    let world = World::threads(2, cfg).unwrap();
    let posh: Vec<(f64, f64, f64, f64)> = world.run_collect(|ctx| {
        let buf = ctx.shmalloc_n::<u8>(BW_SIZE).unwrap();
        let mut r = (0.0, 0.0, 0.0, 0.0);
        if ctx.my_pe() == 0 {
            let s = vec![0xB4u8; BW_SIZE];
            let mut d = vec![0u8; BW_SIZE];
            r.0 = measure(LAT_SIZE, auto_batch(40.0), || {
                ctx.get_with(CopyImpl::Stock, &mut d[..LAT_SIZE], buf, 1);
            })
            .latency_ns();
            r.1 = measure(LAT_SIZE, auto_batch(40.0), || {
                ctx.put_with(CopyImpl::Stock, buf, &s[..LAT_SIZE], 1);
            })
            .latency_ns();
            r.2 = measure(BW_SIZE, 1, || {
                ctx.get_with(CopyImpl::Stock, &mut d, buf, 1);
            })
            .bandwidth_gbps();
            r.3 = measure(BW_SIZE, 1, || {
                ctx.put_with(CopyImpl::Stock, buf, &s, 1);
            })
            .bandwidth_gbps();
        }
        ctx.barrier_all();
        r
    });
    let (posh_get_lat, posh_put_lat, posh_get_bw, posh_put_bw) = posh[0];

    // --- Raw memcpy anchor.
    let raw_bw = measure(BW_SIZE, 1, || unsafe {
        copy_bytes_with(CopyImpl::Stock, dst.as_mut_ptr(), src.as_ptr(), BW_SIZE);
    })
    .bandwidth_gbps();

    let cols = ["get", "put"];
    let mut lat = Table::new("Table 3a: UPC vs POSH latency", "ns", &cols);
    let mut bw = Table::new("Table 3b: UPC vs POSH bandwidth", "Gb/s", &cols);
    lat.row("upc(this)", vec![upc_get_lat, upc_put_lat]);
    lat.row("posh(this)", vec![posh_get_lat, posh_put_lat]);
    bw.row("upc(this)", vec![upc_get_bw, upc_put_bw]);
    bw.row("posh(this)", vec![posh_get_bw, posh_put_bw]);
    for m in paper_machines() {
        lat.row(&format!("paper-upc:{}", m.name), vec![m.upc_get.alpha_ns, m.upc_put.alpha_ns]);
        bw.row(
            &format!("paper-upc:{}", m.name),
            vec![m.upc_get.predict_gbps(BW_SIZE), m.upc_put.predict_gbps(BW_SIZE)],
        );
    }
    lat.print();
    bw.print();
    lat.write_csv("table3_latency").unwrap();
    bw.write_csv("table3_bandwidth").unwrap();

    // --- §5.3 shape checks.
    println!("\nraw memcpy anchor: {raw_bw:.1} Gb/s");
    for (name, v) in [("upc get", upc_get_bw), ("upc put", upc_put_bw),
                      ("posh get", posh_get_bw), ("posh put", posh_put_bw)] {
        let ratio = v / raw_bw;
        println!("  {name:9} {v:7.1} Gb/s  ({ratio:.2} of raw)");
        // Loose bound: on a shared 1-vCPU container the raw anchor itself
        // varies run to run by ~2x; "same order as a memcpy" is the claim.
        assert!(ratio > 0.4, "{name} must be the same order as a raw memcpy at 64 MiB");
    }
    // POSH must not lose to UPC on bandwidth (paper: comparable), and UPC's
    // per-access resolution shows up at 8 B (POSH ≤ UPC latency + noise).
    assert!(
        posh_put_bw >= 0.75 * upc_put_bw && posh_get_bw >= 0.75 * upc_get_bw,
        "POSH bandwidth must be comparable to UPC's"
    );
    println!("shape check OK: both ≈ memcpy; POSH ≥ UPC on bandwidth");
    println!("csv: bench_out/table3_latency.csv, bench_out/table3_bandwidth.csv");
}
