//! **Ablation B** (DESIGN.md §3) — barrier algorithms: dissemination vs
//! central counter across PE counts, plus the active-set barrier. The
//! dissemination barrier is O(log n) rounds with no hot cache line; the
//! central counter is the O(n)-fan-in baseline.

use posh::bench::{measure, Table};
use posh::collectives::ActiveSet;
use posh::pe::{BarrierKind, PoshConfig, World};
use std::sync::atomic::{AtomicU64, Ordering};

fn bench_barrier(n: usize, kind: BarrierKind) -> f64 {
    let mut cfg = PoshConfig::small();
    cfg.barrier = kind;
    let w = World::threads(n, cfg).unwrap();
    let ns = AtomicU64::new(0);
    w.run(|ctx| {
        ctx.barrier_all();
        let m = measure(0, 200, || {
            ctx.barrier_all();
        });
        if ctx.my_pe() == 0 {
            ns.store(m.latency_ns() as u64, Ordering::Relaxed);
        }
        ctx.barrier_all();
    });
    ns.load(Ordering::Relaxed) as f64
}

fn bench_set_barrier(n: usize) -> f64 {
    let w = World::threads(n, PoshConfig::small()).unwrap();
    let ns = AtomicU64::new(0);
    w.run(|ctx| {
        let set = ActiveSet::world(n);
        ctx.barrier_all();
        let m = measure(0, 200, || {
            ctx.barrier_set(&set);
        });
        if ctx.my_pe() == 0 {
            ns.store(m.latency_ns() as u64, Ordering::Relaxed);
        }
        ctx.barrier_all();
    });
    ns.load(Ordering::Relaxed) as f64
}

fn main() {
    let mut t = Table::new(
        "Ablation B: barrier latency",
        "ns/op",
        &["dissemination", "central", "set-linear"],
    );
    for &n in &[2usize, 4, 8, 16] {
        t.row(
            &format!("{n} PEs"),
            vec![
                bench_barrier(n, BarrierKind::Dissemination),
                bench_barrier(n, BarrierKind::Central),
                bench_set_barrier(n),
            ],
        );
    }
    t.print();
    t.write_csv("ablationB_barrier").unwrap();
    println!("\n(1-core container: expect flat-ish numbers dominated by \
              scheduling; on a real multicore the dissemination barrier's \
              log-n scaling separates from the central counter's linear \
              fan-in)");
    println!("csv: bench_out/ablationB_barrier.csv");
}
