//! End-to-end multi-PROCESS integration test (`harness = false`).
//!
//! The test binary plays both roles: invoked plain it acts as the launcher
//! (spawning itself under the RTE, §4.7); invoked with the `POSH_*`
//! environment it acts as a PE, attaches to the job's segments, and runs a
//! full SHMEM workout — put/get, atomics, locks, barrier, reduce,
//! broadcast, fcollect, team splits — over *real* shared segments across
//! processes.
//!
//! Engine routing: the launcher probes both process-mode substrates. A
//! writable `/dev/shm` selects the POSIX engine; an unwritable one routes
//! the whole run to the memfd fallback (launcher-brokered fds). Only when
//! *neither* engine works does the test skip — loudly, with the counted
//! `POSH-SKIP[proc_mode]` marker CI greps for. `POSH_SHM_ENGINE` forces an
//! engine end-to-end (the forced-memfd CI job runs exactly that).
//!
//! Three jobs run back to back: the 3-PE workout; a 32-PE `lazy32` phase
//! asserting the demand-mapping invariant — a PE never maps the whole
//! world just to attach and barrier (a dissemination barrier touches only
//! ⌈log₂ n⌉ partners); and the 4-PE `hier4` forced-topology smoke — a
//! synthetic 2-PEs-per-socket map with postulated cost-model tiers, so the
//! adaptive engine must pick flat for small payloads and the two-level
//! schedule for large ones, deterministically on any runner.

use posh::collectives::{AlgoKind, ReduceOp};
use posh::pe::World;
use posh::rte::gateway::Gateway;
use posh::rte::launcher::{JobSpec, Launcher};
use posh::rte::monitor;

const N_PES: usize = 3;
const LAZY_N: usize = 32;

fn pe_body() {
    let world = World::from_env().expect("attach from oshrun env");
    let ctx = world.my_ctx();
    let me = ctx.my_pe();
    let n = ctx.n_pes();
    assert_eq!(n, N_PES);

    // Demand mapping (§4.1.1): straight out of attach only our own segment
    // is mapped, plus PE 0's for the tuning handshake on rank != 0. The
    // eager table mapped all n here; the 32-PE `lazy32` phase below pins
    // the invariant at scale.
    let s = ctx.remote_table_stats().expect("process mode has a remote table");
    assert!(
        s.mapped <= 2 && s.mapped < n,
        "PE {me}: attach eagerly mapped {} of {n} segments ({s})",
        s.mapped
    );

    // p2p ring.
    let cell = ctx.shmalloc_n::<i64>(1).unwrap();
    ctx.put_one(cell, me as i64 + 1, (me + 1) % n);
    ctx.barrier_all();
    let got = ctx.get_one(cell, me);
    assert_eq!(got, ((me + n - 1) % n) as i64 + 1, "ring value on PE {me}");

    // bulk put/get across processes.
    let buf = ctx.shmalloc_n::<u64>(4096).unwrap();
    if me == 0 {
        let data: Vec<u64> = (0..4096u64).map(|i| i * 3 + 1).collect();
        for pe in 1..n {
            ctx.put(buf, &data, pe);
        }
    }
    ctx.barrier_all();
    if me != 0 {
        let local = unsafe { ctx.local(buf) };
        assert!(local.iter().enumerate().all(|(i, &v)| v == i as u64 * 3 + 1));
    }

    // atomics across processes.
    let counter = ctx.shmalloc_n::<i64>(1).unwrap();
    for _ in 0..500 {
        ctx.atomic_add(counter, 1, 0);
    }
    ctx.barrier_all();
    if me == 0 {
        assert_eq!(ctx.get_one(counter, 0), (n as i64) * 500);
    }

    // lock across processes.
    let lock = ctx.shmalloc_n::<i64>(1).unwrap();
    let shared = ctx.shmalloc_n::<i64>(1).unwrap();
    for _ in 0..100 {
        ctx.with_lock(lock, || {
            let v = ctx.get_one(shared, 0);
            ctx.put_one(shared, v + 1, 0);
        });
    }
    ctx.barrier_all();
    if me == 0 {
        assert_eq!(ctx.get_one(shared, 0), (n as i64) * 100);
    }

    // collectives across processes (team surface over real shm headers).
    let team = ctx.team_world();
    let src = ctx.shmalloc_n::<i64>(32).unwrap();
    let dst = ctx.shmalloc_n::<i64>(32).unwrap();
    unsafe {
        for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
            *s = (me * 10 + j) as i64;
        }
    }
    ctx.barrier_all();
    ctx.reduce_to_all(dst, src, 32, ReduceOp::Sum, &team);
    for j in 0..32 {
        let want: i64 = (0..n).map(|pe| (pe * 10 + j) as i64).sum();
        assert_eq!(unsafe { ctx.local(dst)[j] }, want);
    }
    ctx.broadcast(dst, src, 32, 1, &team);
    if me != 1 {
        assert_eq!(unsafe { ctx.local(dst)[5] }, 15);
    }
    let gat = ctx.shmalloc_n::<i64>(32 * n).unwrap();
    ctx.fcollect(gat, src, 32, &team);
    for pe in 0..n {
        assert_eq!(unsafe { ctx.local(gat)[pe * 32 + 7] }, (pe * 10 + 7) as i64);
    }

    // team split across processes: slot claim + membership agreement run
    // over PE 0's real shared-memory header.
    let front = team.split_strided(0, 1, 2); // PEs {0, 1}
    if me < 2 {
        let t = front.as_ref().unwrap();
        assert_eq!(t.my_pe(), me);
        assert_eq!(t.n_pes(), 2);
        t.sync();
        ctx.reduce_to_all(dst, src, 32, ReduceOp::Max, t);
        assert_eq!(unsafe { ctx.local(dst)[0] }, 10); // max over PEs 0,1 of pe*10
    } else {
        assert!(front.is_none());
    }
    ctx.barrier_all();
    if let Some(t) = front {
        t.destroy();
    }

    // posh-kv across processes: deterministic cross-PE reads over the real
    // shm segments, then a concurrent same-key LWW race resolved through a
    // symmetric seq array (the process-mode half of tests/kv_store.rs).
    {
        use posh::kv::{KvConfig, KvStore};
        let kv = KvStore::create(
            &ctx,
            KvConfig {
                shards_per_pe: 4,
                arena_bytes: 128 * 1024,
                max_key_len: 32,
                max_val_len: 64,
            },
        )
        .unwrap();
        ctx.barrier_all();
        // Deterministic phase: each PE writes its own keys; every PE reads
        // every key (local fast path for its own, one-sided for the rest).
        for i in 0..16 {
            let key = format!("p{me}-{i}");
            let val = format!("{key}={}", i * 7 + me);
            kv.put(key.as_bytes(), val.as_bytes()).unwrap();
        }
        ctx.barrier_all();
        for pe in 0..n {
            for i in 0..16 {
                let key = format!("p{pe}-{i}");
                let want = format!("{key}={}", i * 7 + pe);
                assert_eq!(
                    kv.get(key.as_bytes()).as_deref(),
                    Some(want.as_bytes()),
                    "PE {me}: kv read of {key} across processes"
                );
            }
        }
        assert_eq!(kv.len(), (16 * n) as u64);

        // LWW race: two threads per PE hammer one hot key. Each PE
        // publishes its highest committed shard seq into a symmetric
        // array; the final stored seq must be the global max, and the PE
        // that committed it checks the stored value is its own.
        let (a, b) = std::thread::scope(|s| {
            let hammer = |t: usize| {
                let kv = &kv;
                move || {
                    let mut best = (0u64, String::new());
                    for i in 0..200 {
                        let val = format!("hot#{me}.{t}.{i}");
                        let seq = kv.put(b"hot", val.as_bytes()).unwrap();
                        if seq > best.0 {
                            best = (seq, val);
                        }
                    }
                    best
                }
            };
            let ha = s.spawn(hammer(0));
            let hb = s.spawn(hammer(1));
            (ha.join().unwrap(), hb.join().unwrap())
        });
        let my_best = if a.0 >= b.0 { a } else { b };
        let seqs = ctx.shmalloc_n::<i64>(n).unwrap();
        for pe in 0..n {
            ctx.put_one(seqs.at(me), my_best.0 as i64, pe);
        }
        ctx.barrier_all();
        let all: Vec<i64> = (0..n).map(|i| ctx.get_one(seqs.at(i), me)).collect();
        let gmax = *all.iter().max().unwrap() as u64;
        let (fseq, fval) = kv.get_versioned(b"hot").expect("hot key exists");
        assert_eq!(fseq, gmax, "PE {me}: final hot-key seq is not the global max");
        if my_best.0 == gmax {
            assert_eq!(fval, my_best.1.into_bytes(), "LWW winner value mismatch");
        }
        ctx.barrier_all();
        kv.destroy().unwrap();
    }

    ctx.barrier_all();
    println!("PE {me}: process-mode workout OK");
}

/// The 32-PE demand-mapping phase. Deliberately NO symmetric allocation:
/// safe-mode `shmalloc` cross-checks every PE's journal hash, which would
/// map the whole world and defeat the laziness assertion. Attach + two
/// barriers is exactly the footprint we want to measure.
fn lazy32_body() {
    let world = World::from_env().expect("attach from oshrun env");
    let ctx = world.my_ctx();
    let me = ctx.my_pe();
    let n = ctx.n_pes();
    assert_eq!(n, LAZY_N);
    let s0 = ctx.remote_table_stats().expect("process mode has a remote table");
    assert!(
        s0.mapped <= 2,
        "PE {me}: attach mapped {} of {n} segments — want self + at most PE 0 ({s0})",
        s0.mapped
    );
    ctx.barrier_all();
    // A dissemination barrier touches ⌈log₂ 32⌉ = 5 partners; even with the
    // PE-0 tuning handshake on top the table must stay far below the world.
    let s1 = ctx.remote_table_stats().unwrap();
    assert!(
        s1.mapped < n,
        "PE {me}: one barrier mapped the whole world ({} of {n}; {s1})",
        s1.mapped
    );
    ctx.barrier_all();
    println!("PE {me}: lazy32 OK (mapped {} of {n} after barriers)", s1.mapped);
}

/// The forced-topology smoke (what `oshrun --pes-per-socket 2` injects,
/// over 4 process PEs): a synthetic 2-PEs-per-socket map with both
/// cost-model tiers postulated through the env, so the adaptive argmin is
/// pure arithmetic and runner-independent — a 64 B payload must resolve to
/// a flat family, a 256 KiB one to the two-level schedule, and both must
/// produce correct results over real cross-process segments.
fn hier4_body() {
    const HIER_N: usize = 4;
    let world = World::from_env().expect("attach from oshrun env");
    let ctx = world.my_ctx();
    let me = ctx.my_pe();
    let n = ctx.n_pes();
    assert_eq!(n, HIER_N);
    // The blocked map rank 0 published through the tuning_xsock_geom word.
    assert_eq!(ctx.pes_per_socket(), 2, "PE {me}: synthetic pps not adopted");
    let map: Vec<usize> = (0..n).map(|pe| ctx.socket_of(pe)).collect();
    assert_eq!(map, vec![0, 0, 1, 1], "PE {me}: blocked socket map");

    let team = ctx.team_world();
    const BIG: usize = 32 * 1024; // 256 KiB — far above the ~1.5 KiB crossover
    const SMALL: usize = 8; // 64 B — far below it
    let src = ctx.shmalloc_n::<i64>(BIG).unwrap();
    let dst = ctx.shmalloc_n::<i64>(BIG).unwrap();
    unsafe {
        for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
            *s = (me * 7 + j % 13) as i64;
        }
    }
    ctx.barrier_all();

    // Small reduce: the α-heavy two-level schedule must NOT be picked.
    ctx.reduce_to_all(dst, src, SMALL, ReduceOp::Sum, &team);
    let small_algo = ctx.last_coll_algo().expect("a collective ran");
    assert_ne!(
        small_algo,
        AlgoKind::Hierarchical,
        "PE {me}: {SMALL}-elem reduce resolved to the two-level schedule"
    );
    for j in 0..SMALL {
        let want: i64 = (0..n).map(|pe| (pe * 7 + j % 13) as i64).sum();
        assert_eq!(unsafe { ctx.local(dst)[j] }, want, "PE {me}: small reduce j={j}");
    }

    // Large reduce: the link-frugal two-level schedule must win.
    ctx.reduce_to_all(dst, src, BIG, ReduceOp::Sum, &team);
    assert_eq!(
        ctx.last_coll_algo(),
        Some(AlgoKind::Hierarchical),
        "PE {me}: {BIG}-elem reduce did not resolve hierarchical"
    );
    for j in [0usize, 1, BIG / 2, BIG - 1] {
        let want: i64 = (0..n).map(|pe| (pe * 7 + j % 13) as i64).sum();
        assert_eq!(unsafe { ctx.local(dst)[j] }, want, "PE {me}: big reduce j={j}");
    }

    // Broadcast splits at the same boundary (root 2 lives on socket 1, so
    // the large transfer really exercises the leader exchange).
    ctx.broadcast(dst, src, SMALL, 2, &team);
    assert_ne!(
        ctx.last_coll_algo(),
        Some(AlgoKind::Hierarchical),
        "PE {me}: small bcast resolved to the two-level schedule"
    );
    ctx.broadcast(dst, src, BIG, 2, &team);
    assert_eq!(
        ctx.last_coll_algo(),
        Some(AlgoKind::Hierarchical),
        "PE {me}: large bcast did not resolve hierarchical"
    );
    if me != 2 {
        for j in [0usize, 1, BIG / 2, BIG - 1] {
            assert_eq!(
                unsafe { ctx.local(dst)[j] },
                (2 * 7 + j % 13) as i64,
                "PE {me}: bcast payload j={j}"
            );
        }
    }
    ctx.barrier_all();
    println!("PE {me}: hier4 OK (small→{}, large→hierarchical)", small_algo.name());
}

/// Spawn `n_pes` copies of this binary with `extra_env`, pump their IO
/// through the gateway, and require every PE to print `marker`.
fn run_job(n_pes: usize, extra_env: Vec<(String, String)>, marker: &str) {
    let exe = std::env::current_exe().unwrap();
    let mut spec = JobSpec::new(n_pes, exe.to_str().unwrap());
    // libtest arg so a stray harness doesn't eat the run; ignored by us.
    spec.args = vec!["--posh-child".into()];
    spec.env = extra_env;
    let launcher = Launcher::new(spec);
    let job = launcher.job_id;
    let mut pes = launcher.spawn_all().expect("spawn PEs");
    let mut gw = Gateway::new();
    for pe in pes.iter_mut() {
        gw.attach(pe.rank, false, pe.child.stdout.take().unwrap());
        gw.attach(pe.rank, true, pe.child.stderr.take().unwrap());
    }
    let io = std::thread::spawn(move || {
        let mut sink = Vec::new();
        gw.pump_to(&mut sink).unwrap()
    });
    let outcome = monitor::wait_all(pes);
    let lines = io.join().unwrap();
    monitor::cleanup_job_segments(job, n_pes);
    assert!(
        outcome.success(),
        "job failed: {:?}\nIO:\n{}",
        outcome.exit_codes,
        lines.iter().map(|l| l.render()).collect::<Vec<_>>().join("\n")
    );
    let ok_lines = lines.iter().filter(|l| l.line.contains(marker)).count();
    assert_eq!(ok_lines, n_pes, "every PE must report {marker:?}");
}

fn launcher_role() {
    run_job(
        N_PES,
        vec![("POSH_HEAP_SIZE".into(), "8M".into())],
        "process-mode workout OK",
    );
    println!("proc_mode integration: {N_PES} processes OK");
}

fn lazy32_launcher() {
    run_job(
        LAZY_N,
        vec![
            ("POSH_HEAP_SIZE".into(), "4M".into()),
            ("POSH_STATICS_SIZE".into(), "64k".into()),
            ("POSH_TEST_BODY".into(), "lazy32".into()),
        ],
        "lazy32 OK",
    );
    println!("proc_mode lazy32: {LAZY_N} processes demand-mapped OK");
}

fn hier4_launcher() {
    run_job(
        4,
        vec![
            ("POSH_HEAP_SIZE".into(), "16M".into()),
            ("POSH_TEST_BODY".into(), "hier4".into()),
            // Exactly what `oshrun --pes-per-socket 2` injects.
            ("POSH_PES_PER_SOCKET".into(), "2".into()),
            // Postulate both tiers so the argmin is runner-independent:
            // intra 100 ns / 80 Gb/s vs cross-socket 1000 ns / 8 Gb/s puts
            // the flat↔hier crossover near 1.5 KiB for reduce and bcast.
            ("POSH_ALPHA_NS".into(), "100".into()),
            ("POSH_BETA_GBPS".into(), "80".into()),
            ("POSH_XSOCK_ALPHA_NS".into(), "1000".into()),
            ("POSH_XSOCK_BETA_GBPS".into(), "8".into()),
        ],
        "hier4 OK",
    );
    println!("proc_mode hier4: forced 2-per-socket topology smoke OK");
}

fn main() {
    if World::env_present() {
        match std::env::var("POSH_TEST_BODY").as_deref() {
            Ok("lazy32") => lazy32_body(),
            Ok("hier4") => hier4_body(),
            _ => pe_body(),
        }
        return;
    }
    // Launcher side: probe both engines before spawning anything. A forced
    // engine (POSH_SHM_ENGINE, which `Launcher::resolve_engine` also
    // honours) must run on exactly that engine or skip — never silently
    // fall back to the other one.
    let posix_ok = posh::shm::dev_shm_writable();
    let memfd_ok = posh::shm::memfd::memfd_supported();
    let forced = std::env::var("POSH_SHM_ENGINE")
        .map(|v| v.to_ascii_lowercase())
        .unwrap_or_default();
    let engine_ok = match forced.as_str() {
        "memfd" => memfd_ok,
        "posix" => posix_ok,
        _ => posix_ok || memfd_ok,
    };
    if !engine_ok {
        // The counted marker CI greps for — the only legitimate skip, and
        // it is loud. (The forced-memfd CI job fails if it ever appears.)
        println!(
            "POSH-SKIP[proc_mode]: no usable shm engine \
             (/dev/shm writable: {posix_ok}, memfd_create available: {memfd_ok}, \
             POSH_SHM_ENGINE: {:?})",
            if forced.is_empty() { "auto" } else { forced.as_str() }
        );
        return;
    }
    if !posix_ok {
        println!("proc_mode: /dev/shm unwritable — running on the memfd fallback engine");
    }
    launcher_role();
    lazy32_launcher();
    hier4_launcher();
}
