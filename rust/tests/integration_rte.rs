//! Integration tests of the `oshrun` binary itself (§4.7): launching real
//! jobs, IO forwarding, failure handling, the pre-parser CLI, and segment
//! cleanup. Uses `CARGO_BIN_EXE_oshrun`, which cargo builds for us.

use std::process::Command;

fn oshrun() -> Command {
    Command::new(env!("CARGO_BIN_EXE_oshrun"))
}

#[test]
fn info_reports_platform() {
    let out = oshrun().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("POSH-RS"));
    assert!(text.contains("available copy impls"));
    assert!(text.contains("memcpy"));
}

#[test]
fn calibrate_reports_model_and_writes_csv() {
    let dir = std::env::temp_dir().join(format!("posh_calib_{}", std::process::id()));
    let csv = dir.join("calibration.csv");
    let out = oshrun()
        .args(["calibrate", "--csv", csv.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("shm channel model"));
    assert!(text.contains("alpha_ns"));
    assert!(text.contains("r2"));
    assert!(text.contains("adaptive selection"));
    let content = std::fs::read_to_string(&csv).unwrap();
    assert!(content.starts_with("quantity,value"));
    assert!(content.contains("alpha_ns,"));
    assert!(content.contains("n_half_bytes,"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn launches_shell_job_with_rank_prefixed_io() {
    let out = oshrun()
        .args(["-np", "3", "--", "/bin/sh", "-c", "echo hello from $POSH_RANK"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for pe in 0..3 {
        assert!(
            text.contains(&format!("[PE {pe}] hello from {pe}")),
            "missing PE {pe} line in:\n{text}"
        );
    }
}

#[test]
fn propagates_failure_exit_code_and_kills_job() {
    let t0 = std::time::Instant::now();
    let out = oshrun()
        .args([
            "-np",
            "3",
            "--",
            "/bin/sh",
            "-c",
            "if [ \"$POSH_RANK\" = 2 ]; then exit 7; else sleep 60; fi",
        ])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(7));
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "monitor must kill the sleepers promptly"
    );
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("PE 2 failed"), "{err}");
}

#[test]
fn env_knobs_are_forwarded() {
    let out = oshrun()
        .args([
            "-np", "1", "--heap", "16M", "--copy", "sse2", "--coll", "tree", "--safe", "--",
            "/bin/sh", "-c",
            "echo heap=$POSH_HEAP_SIZE copy=$POSH_COPY coll=$POSH_COLL_ALGO safe=$POSH_SAFE",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("heap=16M"));
    assert!(text.contains("copy=sse2"));
    assert!(text.contains("coll=tree"));
    assert!(text.contains("safe=1"));
}

#[test]
fn preparse_cli_transforms_the_demo_program() {
    let src = concat!(env!("CARGO_MANIFEST_DIR"), "/examples/c/ring.c");
    let out_c = std::env::temp_dir().join(format!("posh_ring_{}.c", std::process::id()));
    let out_m = std::env::temp_dir().join(format!("posh_ring_{}.manifest", std::process::id()));
    let out = oshrun()
        .args([
            "preparse",
            src,
            "-o",
            out_c.to_str().unwrap(),
            "--manifest",
            out_m.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let report = String::from_utf8_lossy(&out.stderr);
    // The five file-scope objects, and only those.
    for name in ["ring_value", "hops", "trace", "tag", "world_visible_flag"] {
        assert!(report.contains(name), "missing {name} in report:\n{report}");
    }
    assert!(!report.contains("calls"), "function-local static must not be lifted");

    let transformed = std::fs::read_to_string(&out_c).unwrap();
    // Alloc block follows start_pes; both returns get epilogues.
    let sp = transformed.find("start_pes(0);").unwrap();
    assert!(transformed[sp..].contains("__posh_static_ring_value = shmemalign(8, 8);"));
    assert!(transformed.contains("memcpy(__posh_static_hops, &hops, 4);"));
    assert_eq!(transformed.matches("shfree(__posh_static_trace);").count(), 2);

    let manifest = std::fs::read_to_string(&out_m).unwrap();
    assert!(manifest.contains("trace double 64 512 8 bss"));
    assert!(manifest.contains("hops int 1 4 4 data"));
}

#[test]
fn clean_subcommand_sweeps_stale_segments() {
    use posh::shm::naming::heap_segment_name;
    use posh::shm::posix::PosixShmSegment;
    // Fabricate a stale segment as if a job had crashed.
    let job = posh::shm::naming::fresh_job_id();
    let name = heap_segment_name(job, 0);
    let seg = PosixShmSegment::create(&name, 4096).unwrap();
    std::mem::forget(seg); // crash simulation: owner never unlinks
    assert!(std::path::Path::new(&format!("/dev/shm{name}")).exists());

    let out = oshrun().arg("clean").output().unwrap();
    assert!(out.status.success());
    assert!(!std::path::Path::new(&format!("/dev/shm{name}")).exists());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("removed"), "{text}");
}

#[test]
fn quickstart_example_runs_under_oshrun_if_built() {
    // Examples aren't guaranteed to be built before tests; skip when absent.
    let exe = std::path::Path::new(env!("CARGO_BIN_EXE_oshrun"))
        .parent()
        .unwrap()
        .join("examples/quickstart");
    if !exe.exists() {
        eprintln!("skipping: {exe:?} not built (cargo build --examples)");
        return;
    }
    let out = oshrun()
        .args(["-np", "3", "--heap", "16M", "--", exe.to_str().unwrap()])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("quickstart OK"), "{text}");
}
