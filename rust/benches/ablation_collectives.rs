//! **Ablation A** (DESIGN.md §3; paper §4.5.4) — the collective-algorithm
//! switch: broadcast and reduce latency per algorithm family × payload size
//! × PE count, plus the **adaptive-vs-fixed** columns: the cost-model
//! engine's pick measured against the best fixed algorithm at every point.
//! Regenerates the data a POSH maintainer would use to pick the §4.5.4
//! default — and checks that no maintainer is needed: the adaptive row must
//! stay within 10% of the best fixed row at every measured size (one noise
//! retry; set `POSH_BENCH_NO_ASSERT=1` to demote the check to a report on
//! heavily oversubscribed boxes).
//!
//! The second half is the **hier-vs-flat A/B**: the same sweep on a
//! synthetic 2-PEs-per-socket topology (`PoshConfig::pes_per_socket`), with
//! the forced two-level schedule next to the best flat family and the
//! adaptive engine — which must stay within the same ≤ 1.10 gate of the
//! best of *both* worlds. Emits `bench_out/BENCH_hier.json` alongside the
//! CSV for the ablation trajectory.

use posh::bench::{measure, Table};
use posh::collectives::{AlgoKind, ReduceOp};
use posh::pe::{PoshConfig, World};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One measured cell: the latency and the algorithm that actually ran (the
/// forced family's name, or what the adaptive engine resolved to on PE 0).
#[derive(Clone, Copy)]
struct Point {
    ns: f64,
    algo: &'static str,
}

fn bench_world(n: usize, algo: AlgoKind, nelems: usize, pps: usize) -> (Point, Point) {
    let mut cfg = PoshConfig::small();
    cfg.coll_algo = Some(algo);
    if pps > 0 {
        // Synthetic blocked socket map: arms the two-level tier (and the
        // hierarchical candidate) without needing NUMA hardware.
        cfg.pes_per_socket = Some(pps);
    }
    // LinearPut roots stage (n-1) contributions (Lemma-1 scratch): size for it.
    cfg.heap_size = (nelems * 8 * (n + 4)).max(4 << 20);
    let w = World::threads(n, cfg).unwrap();
    let bcast_ns = AtomicU64::new(0);
    let reduce_ns = AtomicU64::new(0);
    let resolved = Mutex::new(("?", "?"));
    w.run(|ctx| {
        let team = ctx.team_world();
        let src = ctx.shmalloc_n::<i64>(nelems).unwrap();
        let dst = ctx.shmalloc_n::<i64>(nelems).unwrap();
        unsafe {
            for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                *s = (ctx.my_pe() + j) as i64;
            }
        }
        ctx.barrier_all();
        let reps = if nelems >= 1 << 18 { 5 } else { 30 };
        let m = measure(nelems * 8, reps, || {
            ctx.broadcast(dst, src, nelems, 0, &team);
        });
        if ctx.my_pe() == 0 {
            bcast_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            let ran = ctx.last_coll_algo().map_or("?", |a| a.name());
            resolved.lock().unwrap().0 = ran;
            if algo == AlgoKind::Adaptive {
                eprintln!(
                    "# adaptive broadcast {n} PEs x {nelems} i64 (pps={pps}) resolved to {ran}"
                );
            }
        }
        ctx.barrier_all();
        let m = measure(nelems * 8, reps, || {
            ctx.reduce_to_all(dst, src, nelems, ReduceOp::Sum, &team);
        });
        if ctx.my_pe() == 0 {
            reduce_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            let ran = ctx.last_coll_algo().map_or("?", |a| a.name());
            resolved.lock().unwrap().1 = ran;
            if algo == AlgoKind::Adaptive {
                eprintln!(
                    "# adaptive reduce    {n} PEs x {nelems} i64 (pps={pps}) resolved to {ran}"
                );
            }
        }
        ctx.barrier_all();
    });
    let (ba, ra) = *resolved.lock().unwrap();
    (
        Point { ns: bcast_ns.load(Ordering::Relaxed) as f64, algo: ba },
        Point { ns: reduce_ns.load(Ordering::Relaxed) as f64, algo: ra },
    )
}

/// The acceptance gate: adaptive may not lose more than 10% to the best
/// fixed algorithm (hierarchical included when a topology is armed).
/// Thread-mode latencies on an oversubscribed runner are noisy, so a
/// failing point gets one fresh re-measurement of both sides (min-of-two)
/// before the verdict — and the verdict names the exact (op, size, algo)
/// triple on both sides, so a CI failure line is diagnosable on its own.
#[allow(clippy::too_many_arguments)]
fn check_adaptive(
    op: &str,
    n: usize,
    nelems: usize,
    pps: usize,
    pick: impl Fn((Point, Point)) -> Point,
    fixed_best: f64,
    fixed_best_algo: &'static str,
    adaptive: Point,
) -> (f64, f64) {
    let mut best = fixed_best;
    let mut best_algo = fixed_best_algo;
    let mut adapt = adaptive.ns;
    let mut ran = adaptive.algo;
    if adapt > 1.10 * best {
        // One retry: re-measure adaptive and the field, keep minima.
        let re = pick(bench_world(n, AlgoKind::Adaptive, nelems, pps));
        if re.ns < adapt {
            adapt = re.ns;
            ran = re.algo;
        }
        for algo in AlgoKind::all() {
            let p = pick(bench_world(n, algo, nelems, pps));
            if p.ns < best {
                best = p.ns;
                best_algo = algo.name();
            }
        }
        if pps > 0 {
            let p = pick(bench_world(n, AlgoKind::Hierarchical, nelems, pps));
            if p.ns < best {
                best = p.ns;
                best_algo = AlgoKind::Hierarchical.name();
            }
        }
    }
    let ratio = adapt / best.max(1.0);
    let strict = std::env::var("POSH_BENCH_NO_ASSERT").map_or(true, |v| v != "1");
    if strict {
        assert!(
            ratio <= 1.10,
            "(op={op}, size={bytes}B, algo=adaptive→{ran}) {n} PEs pps={pps}: \
             {adapt:.0} ns vs best fixed (op={op}, size={bytes}B, algo={best_algo}) \
             {best:.0} ns — ratio {ratio:.3} > 1.10 after one noise retry",
            bytes = nelems * 8,
        );
    } else if ratio > 1.10 {
        eprintln!(
            "# WARNING (op={op}, size={bytes}B, algo=adaptive→{ran}) {n} PEs \
             pps={pps}: adaptive/best(algo={best_algo}) = {ratio:.3} (> 1.10)",
            bytes = nelems * 8,
        );
    }
    (best, adapt)
}

fn main() {
    let fixed = AlgoKind::all();
    let mut columns: Vec<&str> = fixed.iter().map(|a| a.name()).collect();
    columns.extend(["adaptive", "best-fixed", "adapt/best"]);
    for &nelems in &[64usize, 8192, 262_144] {
        let mut bcast = Table::new(
            &format!("Ablation A: broadcast, {} i64/PE", nelems),
            "ns/op",
            &columns,
        );
        let mut reduce = Table::new(
            &format!("Ablation A: reduce(sum), {} i64/PE", nelems),
            "ns/op",
            &columns,
        );
        for &n in &[2usize, 4, 8] {
            let mut brow = Vec::new();
            let mut rrow = Vec::new();
            let (mut bbest, mut bbest_algo) = (f64::MAX, "?");
            let (mut rbest, mut rbest_algo) = (f64::MAX, "?");
            for algo in fixed {
                let (b, r) = bench_world(n, algo, nelems, 0);
                brow.push(b.ns);
                rrow.push(r.ns);
                if b.ns < bbest {
                    bbest = b.ns;
                    bbest_algo = algo.name();
                }
                if r.ns < rbest {
                    rbest = r.ns;
                    rbest_algo = algo.name();
                }
            }
            let (ab, ar) = bench_world(n, AlgoKind::Adaptive, nelems, 0);
            let (bbest, ab) =
                check_adaptive("broadcast", n, nelems, 0, |p| p.0, bbest, bbest_algo, ab);
            let (rbest, ar) =
                check_adaptive("reduce", n, nelems, 0, |p| p.1, rbest, rbest_algo, ar);
            brow.extend([ab, bbest, ab / bbest.max(1.0)]);
            rrow.extend([ar, rbest, ar / rbest.max(1.0)]);
            bcast.row(&format!("{n} PEs"), brow);
            reduce.row(&format!("{n} PEs"), rrow);
        }
        bcast.print();
        reduce.print();
        bcast.write_csv(&format!("ablationA_broadcast_{nelems}")).unwrap();
        reduce.write_csv(&format!("ablationA_reduce_{nelems}")).unwrap();
    }
    hier_ab();
    println!(
        "\ncsv: bench_out/ablationA_*.csv, bench_out/ablation_hier.csv  \
         (adaptive-vs-fixed and hier-vs-flat columns included); \
         json: bench_out/BENCH_hier.json"
    );
}

/// The hier-vs-flat A/B on a synthetic 2-PEs-per-socket topology: the best
/// forced flat family, the forced two-level schedule, and the adaptive
/// engine with the topology armed (hier joins its candidate set).
///
/// The ≤ 1.10 gate splits by what the runner actually is. A synthetic map
/// on a single-socket box deliberately lies to the model ("pretend the
/// link is 2.2× slower"), so adaptive optimises a fictional machine —
/// comparing it against flat there tests the fiction, not the engine.
/// What *is* testable everywhere: adaptive must cost within 10% of the
/// forced measurement of whatever family it resolved to (selection adds no
/// overhead). On a genuinely multi-socket runner the full best-of-both
/// gate applies on top, through [`check_adaptive`].
fn hier_ab() {
    const PPS: usize = 2;
    let real_numa = posh::model::Topology::detect().sockets() > 1;
    let mut table = Table::new(
        "Ablation A-hier: flat vs two-level, synthetic 2-per-socket topology",
        "ns/op",
        &["flat-best", "hier", "adaptive", "adapt/best"],
    );
    let mut rows: Vec<String> = Vec::new();
    for &nelems in &[64usize, 8192, 262_144] {
        for &n in &[4usize, 8] {
            // The forced field: every flat family (forcing bypasses
            // selection, so the synthetic map does not change what they
            // run) plus the two-level schedule.
            let mut field: Vec<(&'static str, f64, f64)> = Vec::new();
            let (mut bflat, mut rflat) = ((f64::MAX, "?"), (f64::MAX, "?"));
            for algo in AlgoKind::all() {
                let (b, r) = bench_world(n, algo, nelems, PPS);
                field.push((algo.name(), b.ns, r.ns));
                if b.ns < bflat.0 {
                    bflat = (b.ns, algo.name());
                }
                if r.ns < rflat.0 {
                    rflat = (r.ns, algo.name());
                }
            }
            let (hb, hr) = bench_world(n, AlgoKind::Hierarchical, nelems, PPS);
            field.push((AlgoKind::Hierarchical.name(), hb.ns, hr.ns));
            let (ab, ar) = bench_world(n, AlgoKind::Adaptive, nelems, PPS);
            let cells = [
                ("broadcast", bflat, hb, ab),
                ("reduce", rflat, hr, ar),
            ];
            for (op, flat, hier, adaptive) in cells {
                let is_bcast = op == "broadcast";
                let mut adapt = adaptive.ns;
                let best = if real_numa {
                    let seed = if hier.ns < flat.0 {
                        (hier.ns, AlgoKind::Hierarchical.name())
                    } else {
                        flat
                    };
                    let (best, a) = check_adaptive(
                        op,
                        n,
                        nelems,
                        PPS,
                        move |p| if is_bcast { p.0 } else { p.1 },
                        seed.0,
                        seed.1,
                        adaptive,
                    );
                    adapt = a;
                    best
                } else {
                    // Flat box: gate adaptive against the forced run of the
                    // family it resolved to (one noise retry, min-of-two).
                    let reference = |ran: &str| {
                        field
                            .iter()
                            .find(|e| e.0 == ran)
                            .map(|e| if is_bcast { e.1 } else { e.2 })
                    };
                    let mut ran = adaptive.algo;
                    let mut reference_ns = reference(ran).unwrap_or(flat.0);
                    if adapt > 1.10 * reference_ns {
                        let re = bench_world(n, AlgoKind::Adaptive, nelems, PPS);
                        let re = if is_bcast { re.0 } else { re.1 };
                        if re.ns < adapt {
                            adapt = re.ns;
                            ran = re.algo;
                            reference_ns = reference(ran).unwrap_or(flat.0);
                        }
                    }
                    let ratio = adapt / reference_ns.max(1.0);
                    let strict =
                        std::env::var("POSH_BENCH_NO_ASSERT").map_or(true, |v| v != "1");
                    if strict {
                        assert!(
                            ratio <= 1.10,
                            "(op={op}, size={bytes}B, algo=adaptive→{ran}) {n} PEs \
                             pps={PPS}: {adapt:.0} ns vs the same family forced \
                             (op={op}, size={bytes}B, algo={ran}) {reference_ns:.0} ns \
                             — ratio {ratio:.3} > 1.10 after one noise retry",
                            bytes = nelems * 8,
                        );
                    } else if ratio > 1.10 {
                        eprintln!(
                            "# WARNING (op={op}, size={bytes}B, algo=adaptive→{ran}) \
                             {n} PEs pps={PPS}: adaptive/forced-self = {ratio:.3}",
                            bytes = nelems * 8,
                        );
                    }
                    flat.0.min(hier.ns)
                };
                table.row(
                    &format!("{op} {n} PEs x {nelems}"),
                    vec![flat.0, hier.ns, adapt, adapt / best.max(1.0)],
                );
                rows.push(format!(
                    "    {{\"op\": \"{op}\", \"pes\": {n}, \"nelems\": {nelems}, \
                     \"pes_per_socket\": {PPS}, \"real_numa\": {real_numa}, \
                     \"flat_best_ns\": {:.1}, \"flat_best_algo\": \"{}\", \
                     \"hier_ns\": {:.1}, \"adaptive_ns\": {:.1}, \
                     \"adaptive_resolved\": \"{}\", \"adapt_over_best\": {:.4}}}",
                    flat.0,
                    flat.1,
                    hier.ns,
                    adapt,
                    adaptive.algo,
                    adapt / best.max(1.0),
                ));
            }
        }
    }
    table.print();
    table.write_csv("ablation_hier").unwrap();
    let json = format!(
        "{{\n  \"bench\": \"ablation_hier\",\n  \"unit\": \"ns/op\",\n  \
         \"rows\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::create_dir_all("bench_out").unwrap();
    std::fs::write("bench_out/BENCH_hier.json", json).unwrap();
}
