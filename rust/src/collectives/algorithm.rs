//! Collective algorithm selection (paper §4.5.4).
//!
//! "In order to reduce the number of conditional branches, collective
//! communication algorithms are chosen at compile-time … A default choice is
//! provided if no option is passed to the compiler."
//!
//! POSH-RS: cargo features `coll-linear` / `coll-tree` / `coll-recdbl` fix
//! the compile-time default ([`AlgoKind::default_algo`]); `PoshConfig` or
//! `POSH_COLL_ALGO` may override it once at start-up. The per-op dispatch is
//! resolved before any data moves.

/// Which algorithm family a collective uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    /// Put-based linear: the root (or every writer) pushes; O(n) puts.
    LinearPut,
    /// Get-based linear: readers pull after the owner publishes its buffer
    /// handle (§4.5.2 late-entry protocol).
    LinearGet,
    /// Binomial tree: log₂(n) rounds.
    Tree,
    /// Recursive doubling: log₂(n) rounds, all PEs finish with the result
    /// (power-of-two set sizes; falls back to the linear variant otherwise).
    RecursiveDoubling,
}

impl AlgoKind {
    /// Compile-time default from cargo features; `LinearPut` if none set.
    pub const fn default_algo() -> AlgoKind {
        #[cfg(feature = "coll-recdbl")]
        {
            return AlgoKind::RecursiveDoubling;
        }
        #[cfg(all(feature = "coll-tree", not(feature = "coll-recdbl")))]
        {
            return AlgoKind::Tree;
        }
        #[allow(unreachable_code)]
        AlgoKind::LinearPut
    }

    /// Parse CLI/env spellings.
    pub fn parse(s: &str) -> Option<AlgoKind> {
        match s.to_ascii_lowercase().as_str() {
            "linear" | "linear-put" | "put" => Some(AlgoKind::LinearPut),
            "linear-get" | "get" => Some(AlgoKind::LinearGet),
            "tree" | "binomial" => Some(AlgoKind::Tree),
            "recdbl" | "recursive-doubling" | "rd" => Some(AlgoKind::RecursiveDoubling),
            _ => None,
        }
    }

    /// Display name (bench tables).
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::LinearPut => "linear-put",
            AlgoKind::LinearGet => "linear-get",
            AlgoKind::Tree => "tree",
            AlgoKind::RecursiveDoubling => "recdbl",
        }
    }

    /// All variants (ablation sweeps).
    pub fn all() -> [AlgoKind; 4] {
        [
            AlgoKind::LinearPut,
            AlgoKind::LinearGet,
            AlgoKind::Tree,
            AlgoKind::RecursiveDoubling,
        ]
    }
}

impl crate::pe::Ctx {
    /// The algorithm collectives on this context use: config override or the
    /// compile-time default.
    #[inline]
    pub fn coll_algo(&self) -> AlgoKind {
        self.config().coll_algo.unwrap_or(AlgoKind::default_algo())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for a in AlgoKind::all() {
            assert_eq!(AlgoKind::parse(a.name()), Some(a));
        }
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn default_is_linear_without_features() {
        #[cfg(not(any(feature = "coll-tree", feature = "coll-recdbl")))]
        assert_eq!(AlgoKind::default_algo(), AlgoKind::LinearPut);
    }
}
