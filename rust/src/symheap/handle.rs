//! Symmetric pointers — Boost handles + Corollary 1.
//!
//! The paper's trick (§4.1.1): a Boost *handle* is an offset relative to a
//! shared-memory segment; by symmetry (Fact 1) the handle obtained for an
//! object in the *local* heap designates the "same" object in any *remote*
//! heap. [`SymPtr`] is that handle, made typed: a segment offset plus an
//! element count, `Copy`, and valid on every PE.
//!
//! [`translate`] is Corollary 1 verbatim:
//! `addr_remote = heap_remote + (addr_local − heap_local)`.

use std::marker::PhantomData;

/// A typed symmetric pointer: `offset` bytes from a segment base, `len`
/// elements of `T`. Valid on all PEs by Fact 1.
pub struct SymPtr<T> {
    offset: usize,
    len: usize,
    _t: PhantomData<*const T>,
}

// Handles are plain data; the pointee's cross-PE discipline is the memory
// model's concern, the handle itself is freely shareable.
unsafe impl<T> Send for SymPtr<T> {}
unsafe impl<T> Sync for SymPtr<T> {}

impl<T> Clone for SymPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SymPtr<T> {}

impl<T> std::fmt::Debug for SymPtr<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SymPtr<{}>{{off={:#x}, len={}}}",
            std::any::type_name::<T>(),
            self.offset,
            self.len
        )
    }
}

impl<T> PartialEq for SymPtr<T> {
    fn eq(&self, other: &Self) -> bool {
        self.offset == other.offset && self.len == other.len
    }
}
impl<T> Eq for SymPtr<T> {}

impl<T> SymPtr<T> {
    /// Construct from a raw segment offset (normally done by the heap).
    pub fn from_raw(offset: usize, len: usize) -> Self {
        debug_assert_eq!(
            offset % std::mem::align_of::<T>().max(1),
            0,
            "misaligned SymPtr for {}",
            std::any::type_name::<T>()
        );
        Self { offset, len, _t: PhantomData }
    }

    /// Segment offset in bytes (the Boost handle).
    pub fn offset(&self) -> usize {
        self.offset
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// `true` if this handle covers zero elements.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Byte size of the pointee.
    pub fn byte_len(&self) -> usize {
        self.len * std::mem::size_of::<T>()
    }

    /// A sub-range `[start, start+len)` of this allocation.
    pub fn slice(&self, start: usize, len: usize) -> SymPtr<T> {
        assert!(
            start + len <= self.len,
            "slice [{start}, {}) out of bounds (len {})",
            start + len,
            self.len
        );
        SymPtr {
            offset: self.offset + start * std::mem::size_of::<T>(),
            len,
            _t: PhantomData,
        }
    }

    /// Single-element handle at `index`.
    pub fn at(&self, index: usize) -> SymPtr<T> {
        self.slice(index, 1)
    }

    /// Reinterpret as a handle to raw bytes.
    pub fn as_bytes(&self) -> SymPtr<u8> {
        SymPtr { offset: self.offset, len: self.byte_len(), _t: PhantomData }
    }

    /// Resolve to a concrete address inside a mapped segment base.
    ///
    /// # Safety
    /// `base` must be the base of a segment at least `offset + byte_len`
    /// long.
    pub unsafe fn resolve(&self, base: *mut u8) -> *mut T {
        base.add(self.offset) as *mut T
    }
}

/// Corollary 1: translate a local address into the corresponding remote
/// address, given both heap bases *as mapped in the local address space*.
///
/// `addr_remote = heap_remote + (addr_local − heap_local)`
#[inline]
pub fn translate(addr_local: *const u8, heap_local: *const u8, heap_remote: *mut u8) -> *mut u8 {
    debug_assert!(addr_local as usize >= heap_local as usize);
    let delta = addr_local as usize - heap_local as usize;
    // SAFETY of the arithmetic: delta is within the segment by the caller's
    // contract; wrapping is impossible for valid mappings.
    unsafe { heap_remote.add(delta) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corollary1_formula() {
        let heap_local = 0x1000 as *const u8;
        let heap_remote = 0x9000 as *mut u8;
        let addr_local = 0x1230 as *const u8;
        let r = translate(addr_local, heap_local, heap_remote);
        assert_eq!(r as usize, 0x9230);
    }

    #[test]
    fn symptr_slice_offsets() {
        let p: SymPtr<u64> = SymPtr::from_raw(0x100, 10);
        let s = p.slice(3, 4);
        assert_eq!(s.offset(), 0x100 + 3 * 8);
        assert_eq!(s.len(), 4);
        let one = p.at(9);
        assert_eq!(one.offset(), 0x100 + 9 * 8);
        assert_eq!(one.len(), 1);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn symptr_slice_oob_panics() {
        let p: SymPtr<u32> = SymPtr::from_raw(0, 4);
        let _ = p.slice(2, 3);
    }

    #[test]
    fn as_bytes_len() {
        let p: SymPtr<f64> = SymPtr::from_raw(64, 5);
        let b = p.as_bytes();
        assert_eq!(b.len(), 40);
        assert_eq!(b.offset(), 64);
    }

    #[test]
    fn resolve_on_real_segment() {
        use crate::shm::Segment;
        let seg = crate::shm::inproc::InProcSegment::new(4096).unwrap();
        let p: SymPtr<u32> = SymPtr::from_raw(128, 4);
        unsafe {
            let addr = p.resolve(seg.base());
            *addr = 0xDEAD_BEEF;
            assert_eq!(*(seg.base().add(128) as *const u32), 0xDEAD_BEEF);
        }
    }

    #[test]
    fn translate_matches_resolve_across_segments() {
        use crate::shm::Segment;
        // Two "PEs": identical offsets, different bases — the in-process
        // picture of Fig. 1. translate() from a local pointer must land on
        // the same offset in the remote segment that resolve() computes.
        let a = crate::shm::inproc::InProcSegment::new(8192).unwrap();
        let b = crate::shm::inproc::InProcSegment::new(8192).unwrap();
        let p: SymPtr<u16> = SymPtr::from_raw(0x700, 3);
        unsafe {
            let la = p.resolve(a.base()) as *const u8;
            let via_translate = translate(la, a.base(), b.base());
            let direct = p.resolve(b.base()) as *mut u8;
            assert_eq!(via_translate, direct);
        }
    }
}
