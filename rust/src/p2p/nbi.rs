//! Non-blocking-implicit transfers: `shmem_put_nbi` / `shmem_get_nbi`.
//!
//! **Extension** (OpenSHMEM 1.3; not in the 1.0 spec the paper implements —
//! listed under "future works" in its conclusion). On a shared-memory node
//! the origin core performs the copy either way, so the useful freedom NBI
//! grants an implementation is *deferral*: batch small transfers and issue
//! them at the next `quiet`, amortising per-call overhead.
//!
//! POSH-RS issues NBI transfers eagerly (measurements in EXPERIMENTS.md
//! show deferral buys nothing when the transport is a local memcpy — there
//! is no NIC to overlap with) but keeps the full accounting contract:
//! `pending_nbi()` counts issued-but-unretired operations and `quiet()`
//! retires them, so programs written against the 1.3 semantics run
//! unmodified and the completion discipline is testable.

use crate::pe::Ctx;
use crate::symheap::SymPtr;
use std::cell::Cell;

thread_local! {
    /// Issued-but-unretired NBI operations of the calling PE thread.
    static PENDING: Cell<u64> = const { Cell::new(0) };
}

impl Ctx {
    /// `shmem_put_nbi`: start a put; completion only at the next `quiet`
    /// (or barrier, which includes one).
    pub fn put_nbi<T: Copy>(&self, dest: SymPtr<T>, src: &[T], pe: usize) {
        self.put(dest, src, pe);
        PENDING.with(|p| p.set(p.get() + 1));
    }

    /// `shmem_get_nbi`: start a get; the value is only guaranteed after the
    /// next `quiet`.
    pub fn get_nbi<T: Copy>(&self, dest: &mut [T], src: SymPtr<T>, pe: usize) {
        self.get(dest, src, pe);
        PENDING.with(|p| p.set(p.get() + 1));
    }

    /// Number of NBI operations issued by this PE and not yet retired by a
    /// `quiet`/barrier.
    pub fn pending_nbi(&self) -> u64 {
        PENDING.with(|p| p.get())
    }

    /// Retire NBI operations (called from `quiet`).
    pub(crate) fn retire_nbi(&self) {
        PENDING.with(|p| p.set(0));
    }

    /// `shmem_quiet` variant that also retires NBI accounting. (The plain
    /// `quiet` in `sync::order` is the fence; this is the bookkeeping face
    /// used by programs that check `pending_nbi`.)
    pub fn quiet_nbi(&self) {
        self.quiet();
        self.retire_nbi();
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn nbi_accounting() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let buf = ctx.shmalloc_n::<u32>(8).unwrap();
            assert_eq!(ctx.pending_nbi(), 0);
            ctx.put_nbi(buf, &[1; 8], (ctx.my_pe() + 1) % 2);
            let mut tmp = [0u32; 8];
            ctx.get_nbi(&mut tmp, buf, ctx.my_pe());
            assert_eq!(ctx.pending_nbi(), 2);
            ctx.quiet_nbi();
            assert_eq!(ctx.pending_nbi(), 0);
            ctx.barrier_all();
            // Data actually arrived.
            assert_eq!(unsafe { ctx.local(buf) }, &[1u32; 8][..]);
            ctx.barrier_all();
        });
    }
}
