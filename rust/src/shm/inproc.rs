//! Anonymous in-process segments (thread-mode worlds, tests, benches).

use super::{HugePageStatus, Segment};
use crate::Result;
use anyhow::bail;

/// Size of an x86-64 huge page; segments at least this large attempt
/// huge-page backing.
pub const HUGE_PAGE_BYTES: usize = 2 << 20;

/// A private anonymous `mmap` region. Page-aligned like the POSIX variant so
/// both modes see identical alignment behaviour (Fact 1 depends on heap bases
/// being equally aligned everywhere).
///
/// Segments of [`HUGE_PAGE_BYTES`] or more first try an explicit
/// `MAP_HUGETLB` mapping (guaranteed huge pages, but requires a pre-reserved
/// hugetlb pool — usually absent); failing that they fall back to an ordinary
/// mapping with `madvise(MADV_HUGEPAGE)` so the kernel's THP machinery can
/// promote it. Either way the caller gets zeroed page-aligned memory; only
/// [`Segment::huge_pages`] differs.
pub struct InProcSegment {
    base: *mut u8,
    len: usize,
    huge: HugePageStatus,
}

// SAFETY: the mapping is plain memory; cross-thread access discipline is the
// SHMEM memory model's job (explicit sync via barrier/fence/atomics).
unsafe impl Send for InProcSegment {}
unsafe impl Sync for InProcSegment {}

impl InProcSegment {
    /// Map `len` bytes (rounded up to a page) of zeroed anonymous memory.
    pub fn new(len: usize) -> Result<Self> {
        if len == 0 {
            bail!("segment length must be > 0");
        }
        let page = page_size();
        let len = crate::util::align_up(len, page);
        if len >= HUGE_PAGE_BYTES {
            // Explicit huge pages need a length that is a multiple of the
            // huge-page size; the handful of extra bytes is invisible to
            // callers (len() reports the mapped size either way).
            let hlen = crate::util::align_up(len, HUGE_PAGE_BYTES);
            // SAFETY: anonymous mapping; MAP_HUGETLB fails cleanly (ENOMEM)
            // when no hugetlb pool is reserved.
            let ptr = unsafe {
                libc::mmap(
                    std::ptr::null_mut(),
                    hlen,
                    libc::PROT_READ | libc::PROT_WRITE,
                    libc::MAP_PRIVATE | libc::MAP_ANONYMOUS | libc::MAP_HUGETLB,
                    -1,
                    0,
                )
            };
            if ptr != libc::MAP_FAILED {
                return Ok(Self {
                    base: ptr as *mut u8,
                    len: hlen,
                    huge: HugePageStatus::Explicit,
                });
            }
            // No pool (the common case) — fall through to ordinary pages.
        }
        // SAFETY: standard anonymous mapping.
        let ptr = unsafe {
            libc::mmap(
                std::ptr::null_mut(),
                len,
                libc::PROT_READ | libc::PROT_WRITE,
                libc::MAP_PRIVATE | libc::MAP_ANONYMOUS,
                -1,
                0,
            )
        };
        if ptr == libc::MAP_FAILED {
            bail!(
                "mmap({} bytes) failed: {}",
                len,
                std::io::Error::last_os_error()
            );
        }
        let huge = if len >= HUGE_PAGE_BYTES {
            // SAFETY: advising our own fresh mapping; failure (THP compiled
            // out, or `transparent_hugepage=never`) leaves plain pages.
            let rc = unsafe { libc::madvise(ptr, len, libc::MADV_HUGEPAGE) };
            if rc == 0 {
                HugePageStatus::Transparent
            } else {
                HugePageStatus::None
            }
        } else {
            HugePageStatus::None
        };
        Ok(Self {
            base: ptr as *mut u8,
            len,
            huge,
        })
    }
}

impl Segment for InProcSegment {
    fn base(&self) -> *mut u8 {
        self.base
    }
    fn len(&self) -> usize {
        self.len
    }
    fn huge_pages(&self) -> HugePageStatus {
        self.huge
    }
}

impl Drop for InProcSegment {
    fn drop(&mut self) {
        // SAFETY: we own the mapping.
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.len);
        }
    }
}

/// System page size (cached).
pub fn page_size() -> usize {
    use std::sync::OnceLock;
    static PAGE: OnceLock<usize> = OnceLock::new();
    *PAGE.get_or_init(|| {
        // SAFETY: sysconf is always safe to call.
        let v = unsafe { libc::sysconf(libc::_SC_PAGESIZE) };
        if v <= 0 {
            4096
        } else {
            v as usize
        }
    })
}

/// Check that `ptr` lies within the segment.
pub fn contains(seg: &dyn Segment, ptr: *const u8) -> bool {
    let b = seg.base() as usize;
    let p = ptr as usize;
    p >= b && p < b + seg.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_and_zeroes() {
        let seg = InProcSegment::new(10_000).unwrap();
        assert!(seg.len() >= 10_000);
        assert_eq!(seg.len() % page_size(), 0);
        // zero-initialised
        let bytes = unsafe { seg.bytes() };
        assert!(bytes.iter().all(|&b| b == 0));
    }

    #[test]
    fn writable() {
        let seg = InProcSegment::new(4096).unwrap();
        unsafe {
            *seg.base() = 0xAB;
            *seg.base().add(seg.len() - 1) = 0xCD;
            assert_eq!(*seg.base(), 0xAB);
            assert_eq!(*seg.base().add(seg.len() - 1), 0xCD);
        }
    }

    #[test]
    fn zero_len_rejected() {
        assert!(InProcSegment::new(0).is_err());
    }

    #[test]
    fn page_aligned_base() {
        let seg = InProcSegment::new(1).unwrap();
        assert_eq!(seg.base() as usize % page_size(), 0);
    }

    #[test]
    fn small_segments_never_claim_huge_pages() {
        let seg = InProcSegment::new(4096).unwrap();
        assert_eq!(seg.huge_pages(), HugePageStatus::None);
    }

    #[test]
    fn large_segment_usable_whatever_the_backing() {
        // Whichever of the three outcomes the kernel grants, the segment
        // must behave identically: zeroed, writable, size-preserving.
        let seg = InProcSegment::new(HUGE_PAGE_BYTES + 1).unwrap();
        assert!(seg.len() >= HUGE_PAGE_BYTES + 1);
        let status = seg.huge_pages();
        assert!(!format!("{status}").is_empty());
        if status == HugePageStatus::Explicit {
            // Explicit mappings are rounded to whole huge pages.
            assert_eq!(seg.len() % HUGE_PAGE_BYTES, 0);
            assert_eq!(seg.base() as usize % HUGE_PAGE_BYTES, 0);
        }
        unsafe {
            assert_eq!(*seg.base(), 0);
            *seg.base().add(seg.len() - 1) = 0x7E;
            assert_eq!(*seg.base().add(seg.len() - 1), 0x7E);
        }
    }

    #[test]
    fn contains_checks_bounds() {
        let seg = InProcSegment::new(4096).unwrap();
        assert!(contains(&seg, seg.base()));
        assert!(contains(&seg, unsafe { seg.base().add(seg.len() - 1) }));
        assert!(!contains(&seg, unsafe { seg.base().add(seg.len()) }));
    }
}
