//! `shmem_ptr` and the accessibility queries (OpenSHMEM 1.0 §8.1).
//!
//! `shmem_ptr` is the API that only shared-memory implementations like POSH
//! can honour: a *direct load/store pointer* to another PE's symmetric
//! object. On clusters it returns NULL; here it is the whole point — once
//! you hold the pointer, communication is ordinary memory access with no
//! library call at all (the paper's §2 "registers of shared memory" model
//! in its purest form).
//!
//! `shmem_pe_accessible` / `shmem_addr_accessible` report what `shmem_ptr`
//! will succeed on; within one POSH node everything is accessible.

use crate::pe::Ctx;
use crate::symheap::SymPtr;

impl Ctx {
    /// `shmem_ptr`: a raw pointer to `target` **on PE `pe`**, valid in this
    /// address space for the lifetime of the world. Returns `None` for an
    /// out-of-range PE (the spec's NULL).
    ///
    /// Loads/stores through the pointer bypass every POSH code path; the
    /// caller owns all memory-model obligations (use `fence`/`quiet`/
    /// barriers, or atomics for concurrent cells).
    pub fn shmem_ptr<T>(&self, target: SymPtr<T>, pe: usize) -> Option<*mut T> {
        if pe >= self.n_pes() {
            return None;
        }
        // SAFETY: in-range PE; handle resolution is bounds-debug-checked.
        Some(unsafe { self.remote_addr(target, pe) })
    }

    /// `shmem_pe_accessible`: can `pe` be reached by SHMEM operations?
    pub fn pe_accessible(&self, pe: usize) -> bool {
        pe < self.n_pes()
    }

    /// `shmem_addr_accessible`: is `addr` a symmetric address reachable on
    /// PE `pe`? True iff the PE exists and the address falls inside the
    /// statics area or the dynamic heap (the two remotely-accessible regions
    /// of fig. 1 — the header is implementation-private).
    pub fn addr_accessible<T>(&self, target: SymPtr<T>, pe: usize) -> bool {
        if pe >= self.n_pes() {
            return false;
        }
        let layout = self.heap().layout();
        let start = target.offset();
        let end = start + target.byte_len();
        start >= layout.statics_off && end <= layout.total
    }
}

/// OpenSHMEM 1.0 §8.8 cache-control operations. Deprecated in the spec and
/// no-ops on cache-coherent hardware (all of POSH's targets) — shipped for
/// source compatibility, exactly as POSH itself must.
pub mod cache {
    /// `shmem_set_cache_inv` — no-op on coherent hardware.
    pub fn shmem_set_cache_inv() {}
    /// `shmem_set_cache_line_inv` — no-op.
    pub fn shmem_set_cache_line_inv<T>(_target: *mut T) {}
    /// `shmem_clear_cache_inv` — no-op.
    pub fn shmem_clear_cache_inv() {}
    /// `shmem_clear_cache_line_inv` — no-op.
    pub fn shmem_clear_cache_line_inv<T>(_target: *mut T) {}
    /// `shmem_udcflush` — no-op.
    pub fn shmem_udcflush() {}
    /// `shmem_udcflush_line` — no-op.
    pub fn shmem_udcflush_line<T>(_target: *mut T) {}
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};
    use std::sync::atomic::{AtomicI64, Ordering};

    #[test]
    fn shmem_ptr_direct_store_visible() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let cell = ctx.shmalloc_n::<i64>(4).unwrap();
            if ctx.my_pe() == 0 {
                // Write PE 1's copy through a raw pointer — no put().
                let p = ctx.shmem_ptr(cell, 1).unwrap();
                unsafe {
                    p.write_volatile(42);
                    p.add(3).write_volatile(99);
                }
                ctx.quiet();
            }
            ctx.barrier_all();
            if ctx.my_pe() == 1 {
                let local = unsafe { ctx.local(cell) };
                assert_eq!(local[0], 42);
                assert_eq!(local[3], 99);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn shmem_ptr_enables_lockfree_sharing() {
        // The §2 "shared registers" model: both PEs hammer one cell through
        // raw pointers + hardware atomics.
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let cell = ctx.shmalloc_n::<i64>(1).unwrap();
            let p = ctx.shmem_ptr(cell, 0).unwrap();
            let a = unsafe { &*(p as *const AtomicI64) };
            for _ in 0..10_000 {
                a.fetch_add(1, Ordering::Relaxed);
            }
            ctx.barrier_all();
            if ctx.my_pe() == 0 {
                assert_eq!(a.load(Ordering::Relaxed), 20_000);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn out_of_range_pe_is_null() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let cell = ctx.shmalloc_n::<u8>(1).unwrap();
            assert!(ctx.shmem_ptr(cell, 5).is_none());
            assert!(!ctx.pe_accessible(5));
            assert!(ctx.pe_accessible(0));
        });
    }

    #[test]
    fn addr_accessible_classifies_regions() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let heap_obj = ctx.shmalloc_n::<u64>(8).unwrap();
            assert!(ctx.addr_accessible(heap_obj, 0));
            assert!(!ctx.addr_accessible(heap_obj, 9));
            let static_obj = ctx.heap().place_static(64, 8).unwrap();
            assert!(ctx.addr_accessible(static_obj, 0));
            // Header region (offset 0) is implementation-private.
            let header: crate::symheap::SymPtr<u8> =
                crate::symheap::SymPtr::from_raw(0, 8);
            assert!(!ctx.addr_accessible(header, 0));
        });
    }

    #[test]
    fn cache_ops_are_callable_noops() {
        super::cache::shmem_set_cache_inv();
        super::cache::shmem_clear_cache_inv();
        super::cache::shmem_udcflush();
        let mut x = 0u32;
        super::cache::shmem_set_cache_line_inv(&mut x);
        super::cache::shmem_clear_cache_line_inv(&mut x);
        super::cache::shmem_udcflush_line(&mut x);
    }
}
