//! POSIX `shm_open` segments — the paper's actual substrate (via Boost).
//!
//! Lifecycle protocol (paper §4.1.1):
//! * each PE *creates* its own heap segment at start-up;
//! * to reach PE *k*, a process builds the name from *k*'s rank, then maps
//!   the segment, **retrying with a short sleep if it does not exist yet**
//!   ("we wait a little bit and try again");
//! * mappings of remote heaps are cached in a per-PE table
//!   ([`crate::pe::remote_table`]) — creating them is expensive, looking
//!   them up is not.

use super::{HugePageStatus, Segment};
use crate::Result;
use anyhow::{bail, Context};
use std::ffi::CString;
use std::time::{Duration, Instant};

/// A named shared-memory segment backed by `/dev/shm`.
///
/// `MAP_HUGETLB` does not apply to `shm_open` objects (it needs a hugetlbfs
/// fd), so large segments request transparent huge pages instead via
/// `madvise(MADV_HUGEPAGE)` — effective when the kernel runs with
/// `shmem_enabled=advise` (or `always`), harmless otherwise.
pub struct PosixShmSegment {
    base: *mut u8,
    len: usize,
    name: String,
    /// Only the creator unlinks the name on drop.
    owner: bool,
    huge: HugePageStatus,
}

// SAFETY: plain shared bytes; the SHMEM memory model governs access.
unsafe impl Send for PosixShmSegment {}
unsafe impl Sync for PosixShmSegment {}

impl PosixShmSegment {
    /// Create (or replace) a segment of `len` bytes under `name`.
    pub fn create(name: &str, len: usize) -> Result<Self> {
        if len == 0 {
            bail!("segment length must be > 0");
        }
        let len = crate::util::align_up(len, super::inproc::page_size());
        let cname = CString::new(name).context("segment name contains NUL")?;
        // Replace any stale object from a crashed previous job.
        // SAFETY: FFI call with a valid C string.
        unsafe {
            libc::shm_unlink(cname.as_ptr());
        }
        // SAFETY: FFI; flags request creation with rw permissions.
        let fd = unsafe {
            libc::shm_open(
                cname.as_ptr(),
                libc::O_CREAT | libc::O_EXCL | libc::O_RDWR,
                0o600,
            )
        };
        if fd < 0 {
            bail!(
                "shm_open(create {name}) failed: {}",
                std::io::Error::last_os_error()
            );
        }
        // SAFETY: valid fd.
        let rc = unsafe { libc::ftruncate(fd, len as libc::off_t) };
        if rc != 0 {
            let e = std::io::Error::last_os_error();
            unsafe {
                libc::close(fd);
                libc::shm_unlink(cname.as_ptr());
            }
            bail!("ftruncate({name}, {len}) failed: {e}");
        }
        let mapped = super::map_shared_fd(fd, len);
        // SAFETY: fd no longer needed after mmap (or after a failed attempt).
        unsafe {
            libc::close(fd);
        }
        let (base, huge) = match mapped {
            Ok(v) => v,
            Err(e) => {
                unsafe {
                    libc::shm_unlink(cname.as_ptr());
                }
                return Err(e);
            }
        };
        Ok(Self {
            base,
            len,
            name: name.to_string(),
            owner: true,
            huge,
        })
    }

    /// Map an existing segment, retrying until `timeout` elapses — the
    /// paper's "wait a little bit and try again" handshake with a peer that
    /// has not created its heap yet.
    pub fn open_existing(name: &str, len: usize, timeout: Duration) -> Result<Self> {
        let len = crate::util::align_up(len, super::inproc::page_size());
        let cname = CString::new(name).context("segment name contains NUL")?;
        let deadline = Instant::now() + timeout;
        let mut backoff = Duration::from_micros(50);
        loop {
            // SAFETY: FFI with valid C string.
            let fd = unsafe { libc::shm_open(cname.as_ptr(), libc::O_RDWR, 0o600) };
            if fd >= 0 {
                // Wait until the creator has ftruncate'd it to full size.
                let mut st: libc::stat = unsafe { std::mem::zeroed() };
                // SAFETY: valid fd and out-pointer.
                let rc = unsafe { libc::fstat(fd, &mut st) };
                if rc == 0 && (st.st_size as usize) >= len {
                    let mapped = super::map_shared_fd(fd, len);
                    unsafe {
                        libc::close(fd);
                    }
                    let (base, huge) = mapped?;
                    return Ok(Self {
                        base,
                        len,
                        name: name.to_string(),
                        owner: false,
                        huge,
                    });
                }
                unsafe {
                    libc::close(fd);
                }
            }
            if Instant::now() >= deadline {
                bail!("segment {name} did not appear within {timeout:?}");
            }
            std::thread::sleep(backoff);
            backoff = (backoff * 2).min(Duration::from_millis(10));
        }
    }

    /// Explicitly unlink the name (normally done by the owner's drop).
    pub fn unlink(name: &str) {
        if let Ok(cname) = CString::new(name) {
            // SAFETY: FFI with valid C string; failure is fine (already gone).
            unsafe {
                libc::shm_unlink(cname.as_ptr());
            }
        }
    }
}

impl Segment for PosixShmSegment {
    fn base(&self) -> *mut u8 {
        self.base
    }
    fn len(&self) -> usize {
        self.len
    }
    fn name(&self) -> Option<&str> {
        Some(&self.name)
    }
    fn huge_pages(&self) -> HugePageStatus {
        self.huge
    }
}

impl Drop for PosixShmSegment {
    fn drop(&mut self) {
        // SAFETY: we own this mapping.
        unsafe {
            libc::munmap(self.base as *mut libc::c_void, self.len);
        }
        if self.owner {
            Self::unlink(&self.name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniq(tag: &str) -> String {
        format!("/posh.test.{}.{}", std::process::id(), tag)
    }

    #[test]
    fn create_map_write_reopen() {
        let name = uniq("cmwr");
        let seg = PosixShmSegment::create(&name, 8192).unwrap();
        unsafe {
            *seg.base() = 42;
            *seg.base().add(100) = 43;
        }
        // Second mapping of the same object sees the data.
        let seg2 =
            PosixShmSegment::open_existing(&name, 8192, Duration::from_millis(100)).unwrap();
        unsafe {
            assert_eq!(*seg2.base(), 42);
            assert_eq!(*seg2.base().add(100), 43);
        }
        // Writes propagate both ways.
        unsafe {
            *seg2.base().add(7) = 9;
            assert_eq!(*seg.base().add(7), 9);
        }
    }

    #[test]
    fn open_missing_times_out() {
        let name = uniq("missing");
        let t0 = Instant::now();
        let r = PosixShmSegment::open_existing(&name, 4096, Duration::from_millis(50));
        assert!(r.is_err());
        assert!(t0.elapsed() >= Duration::from_millis(50));
    }

    #[test]
    fn owner_unlinks_on_drop() {
        let name = uniq("unlink");
        {
            let _seg = PosixShmSegment::create(&name, 4096).unwrap();
            assert!(std::path::Path::new(&format!("/dev/shm{name}")).exists());
        }
        assert!(!std::path::Path::new(&format!("/dev/shm{name}")).exists());
    }

    #[test]
    fn non_owner_does_not_unlink() {
        let name = uniq("keep");
        let seg = PosixShmSegment::create(&name, 4096).unwrap();
        {
            let _view =
                PosixShmSegment::open_existing(&name, 4096, Duration::from_millis(100)).unwrap();
        }
        assert!(std::path::Path::new(&format!("/dev/shm{name}")).exists());
        drop(seg);
    }

    #[test]
    fn large_segment_reports_huge_status() {
        let name = uniq("huge");
        let seg =
            PosixShmSegment::create(&name, super::super::inproc::HUGE_PAGE_BYTES * 2).unwrap();
        // shm objects can only ever get THP (or nothing) — never MAP_HUGETLB.
        assert_ne!(seg.huge_pages(), HugePageStatus::Explicit);
        let small = PosixShmSegment::create(&uniq("small"), 4096).unwrap();
        assert_eq!(small.huge_pages(), HugePageStatus::None);
    }

    #[test]
    fn create_replaces_stale() {
        let name = uniq("stale");
        let a = PosixShmSegment::create(&name, 4096).unwrap();
        unsafe { *a.base() = 1 };
        // Simulates a crashed job leaving the object behind: create again.
        let b = PosixShmSegment::create(&name, 4096).unwrap();
        unsafe { assert_eq!(*b.base(), 0, "fresh object must be zeroed") };
        drop(b);
        drop(a);
    }
}
