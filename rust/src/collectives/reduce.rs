//! Reductions (`shmem_<type>_<op>_reduce`, team-scoped): every member of
//! the team ends with the element-wise reduction of all members' `source`
//! arrays in its `target` array.
//!
//! Algorithm variants:
//! * `LinearPut` — members push their contribution into a **temporary
//!   buffer in the root's heap** (a §4.5.3 / Lemma-1 non-symmetric
//!   allocation: it exists only inside the collective and is freed before
//!   exit); the root combines and pushes the result back to everyone.
//! * `LinearGet` — "all-read-all": every member publishes its source and
//!   reduces every peer's contribution locally. No temporaries at all.
//! * `Tree` — binomial fan-in to the root over per-node temporaries, then a
//!   linear fan-out of the result.
//! * `RecursiveDoubling` — ⌈log₂ n⌉ pairwise exchange rounds; every PE holds
//!   the full result with no separate broadcast. Falls back to `LinearPut`
//!   for non-power-of-two set sizes.

use super::state::ActiveSet;
use super::tuning::CollOp;
use crate::pe::Ctx;
use crate::symheap::layout::CollOpTag;
use crate::symheap::SymPtr;
use crate::team::Team;

/// Reduction operators of OpenSHMEM 1.0 §8.5.2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceOp {
    /// Element-wise sum.
    Sum,
    /// Element-wise product.
    Prod,
    /// Element-wise minimum.
    Min,
    /// Element-wise maximum.
    Max,
    /// Bitwise AND (integers only).
    And,
    /// Bitwise OR (integers only).
    Or,
    /// Bitwise XOR (integers only).
    Xor,
}

impl ReduceOp {
    /// All operators (test sweeps).
    pub fn all() -> [ReduceOp; 7] {
        [
            ReduceOp::Sum,
            ReduceOp::Prod,
            ReduceOp::Min,
            ReduceOp::Max,
            ReduceOp::And,
            ReduceOp::Or,
            ReduceOp::Xor,
        ]
    }

    /// Operators valid for floating-point element types.
    pub fn float_ops() -> [ReduceOp; 4] {
        [ReduceOp::Sum, ReduceOp::Prod, ReduceOp::Min, ReduceOp::Max]
    }

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::Prod => "prod",
            ReduceOp::Min => "min",
            ReduceOp::Max => "max",
            ReduceOp::And => "and",
            ReduceOp::Or => "or",
            ReduceOp::Xor => "xor",
        }
    }
}

/// Element types reducible with [`Ctx::reduce_to_all`] — the §4.3 "template"
/// trick: one generic implementation, monomorphised per type.
pub trait ReduceElem: Copy + Send + 'static {
    /// Apply `op` to a pair of elements.
    fn combine(op: ReduceOp, a: Self, b: Self) -> Self;
}

macro_rules! impl_reduce_int {
    ($($t:ty),+ $(,)?) => {$(
        impl ReduceElem for $t {
            #[inline]
            fn combine(op: ReduceOp, a: Self, b: Self) -> Self {
                match op {
                    ReduceOp::Sum => a.wrapping_add(b),
                    ReduceOp::Prod => a.wrapping_mul(b),
                    ReduceOp::Min => a.min(b),
                    ReduceOp::Max => a.max(b),
                    ReduceOp::And => a & b,
                    ReduceOp::Or => a | b,
                    ReduceOp::Xor => a ^ b,
                }
            }
        }
    )+};
}

macro_rules! impl_reduce_float {
    ($($t:ty),+ $(,)?) => {$(
        impl ReduceElem for $t {
            #[inline]
            fn combine(op: ReduceOp, a: Self, b: Self) -> Self {
                match op {
                    ReduceOp::Sum => a + b,
                    ReduceOp::Prod => a * b,
                    ReduceOp::Min => a.min(b),
                    ReduceOp::Max => a.max(b),
                    ReduceOp::And | ReduceOp::Or | ReduceOp::Xor => {
                        panic!("bitwise reduction on floating-point type")
                    }
                }
            }
        }
    )+};
}

impl_reduce_int!(i16, i32, i64, u16, u32, u64);
impl_reduce_float!(f32, f64);

pub(crate) fn combine_into<T: ReduceElem>(op: ReduceOp, acc: &mut [T], contrib: &[T]) {
    debug_assert_eq!(acc.len(), contrib.len());
    for (a, &c) in acc.iter_mut().zip(contrib) {
        *a = T::combine(op, *a, c);
    }
}

impl Ctx {
    /// `shmem_<type>_<op>_reduce` over the team (every member receives the
    /// result).
    pub fn reduce_to_all<T: ReduceElem>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nreduce: usize,
        op: ReduceOp,
        team: &Team,
    ) {
        let set = &team.set;
        let bytes = nreduce * std::mem::size_of::<T>();
        let idx = self.coll_enter(team, CollOpTag::Reduce, bytes);
        if set.size == 1 {
            // Degenerate team: result = own source.
            self.put_sym(target, self.my_pe(), source, self.my_pe(), nreduce);
            self.coll_exit(team);
            return;
        }
        match self.coll_algo_for(CollOp::Reduce, set.size, bytes) {
            super::AlgoKind::LinearPut => {
                self.reduce_linear_put(target, source, nreduce, op, set, idx)
            }
            super::AlgoKind::LinearGet => {
                self.reduce_linear_get(target, source, nreduce, op, set, idx)
            }
            super::AlgoKind::Tree => self.reduce_tree(target, source, nreduce, op, set, idx),
            super::AlgoKind::RecursiveDoubling => {
                if set.size.is_power_of_two() {
                    self.reduce_recdbl(target, source, nreduce, op, set, idx)
                } else {
                    // Forced recdbl on a non-power-of-two team (adaptive
                    // never selects it there — it is not a candidate).
                    self.reduce_linear_put(target, source, nreduce, op, set, idx)
                }
            }
            super::AlgoKind::Hierarchical => {
                self.reduce_hier(target, source, nreduce, op, set, idx)
            }
            super::AlgoKind::Adaptive => unreachable!("resolved by coll_algo_for"),
        }
        self.coll_exit(team);
    }

    /// Root-staged put-based reduction (Lemma-1 temporary in the root heap).
    fn reduce_linear_put<T: ReduceElem>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nreduce: usize,
        op: ReduceOp,
        set: &ActiveSet,
        idx: usize,
    ) {
        let root_pe = set.root();
        if idx == 0 {
            // Lemma-1 temporary: non-symmetric, root-only, freed before exit.
            let tmp = self
                .heap()
                .alloc_n::<T>((set.size - 1) * nreduce)
                .expect("root scratch allocation for reduction");
            self.coll_publish_buf(tmp);
            // Everyone has deposited?
            self.coll_wait_count((set.size - 1) as u64);
            // Combine: acc = source ⊕ every contribution.
            self.put_sym(target, self.my_pe(), source, self.my_pe(), nreduce);
            // SAFETY: contributions are complete (counter) and no one writes
            // tmp or target anymore.
            unsafe {
                let acc = self.local_mut(target);
                let stage = self.local(tmp);
                for k in 0..set.size - 1 {
                    combine_into(op, &mut acc[..nreduce], &stage[k * nreduce..(k + 1) * nreduce]);
                }
            }
            self.heap().free(tmp).expect("freeing reduction scratch");
            // Fan the result out.
            for i in 1..set.size {
                let pe = set.rank_at(i);
                self.put_sym(target, pe, target, self.my_pe(), nreduce);
            }
            self.fence();
            for i in 1..set.size {
                self.coll_signal(set.rank_at(i));
            }
        } else {
            self.coll_check_peer(root_pe, CollOpTag::Reduce, nreduce * std::mem::size_of::<T>());
            let tmp_off = self.coll_wait_buf(root_pe);
            let slot: SymPtr<T> =
                SymPtr::from_raw(tmp_off + (idx - 1) * nreduce * std::mem::size_of::<T>(), nreduce);
            self.put_sym(slot, root_pe, source, self.my_pe(), nreduce);
            self.quiet();
            self.coll_signal(root_pe);
            // Result arrives as one signal from the root.
            self.coll_wait_count(1);
        }
    }

    /// All-read-all get-based reduction.
    fn reduce_linear_get<T: ReduceElem>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nreduce: usize,
        op: ReduceOp,
        set: &ActiveSet,
        idx: usize,
    ) {
        self.coll_publish_buf(source);
        // Local contribution first.
        self.put_sym(target, self.my_pe(), source, self.my_pe(), nreduce);
        let me = self.my_pe();
        for i in 0..set.size {
            if i == idx {
                continue;
            }
            let pe = set.rank_at(i);
            let src_off = self.coll_wait_buf(pe);
            let remote: SymPtr<T> = SymPtr::from_raw(src_off, nreduce);
            // Pull the peer's source and fold it in. Order is by set index,
            // identical on every PE, so float results agree across PEs.
            // SAFETY: peer keeps its source immutable until all "done
            // reading" signals (counter) arrive; we signal below.
            unsafe {
                let acc = self.local_mut(target);
                let base = self.remote_addr(remote, pe) as *const T;
                let contrib = std::slice::from_raw_parts(base, nreduce);
                combine_into(op, &mut acc[..nreduce], contrib);
            }
            self.coll_signal(pe);
        }
        let _ = me;
        // Hold our source in place until everyone is done reading it.
        self.coll_wait_count((set.size - 1) as u64);
    }

    /// Binomial fan-in to the root, then linear fan-out.
    fn reduce_tree<T: ReduceElem>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nreduce: usize,
        op: ReduceOp,
        set: &ActiveSet,
        idx: usize,
    ) {
        let size = set.size;
        // Children of node `idx` in the binomial tree rooted at 0:
        // idx + m for each mask m where m > lowest_set_bit-run of idx.
        let lowbit = if idx == 0 { usize::MAX } else { idx & idx.wrapping_neg() };
        let mut children = Vec::new();
        let mut m = 1usize;
        while m < size && m < lowbit {
            if idx + m < size {
                children.push(idx + m);
            }
            m <<= 1;
        }
        // Accumulator = target (starts as our source).
        self.put_sym(target, self.my_pe(), source, self.my_pe(), nreduce);
        let n_children = children.len();
        if n_children > 0 {
            // Lemma-1 temporary staging for the children's partial results.
            let tmp = self
                .heap()
                .alloc_n::<T>(n_children * nreduce)
                .expect("tree-node scratch allocation");
            self.coll_publish_buf(tmp);
            self.coll_wait_count(n_children as u64);
            // SAFETY: children signalled completion; buffers quiescent.
            unsafe {
                let acc = self.local_mut(target);
                let stage = self.local(tmp);
                for k in 0..n_children {
                    combine_into(op, &mut acc[..nreduce], &stage[k * nreduce..(k + 1) * nreduce]);
                }
            }
            self.heap().free(tmp).expect("freeing tree-node scratch");
        }
        if idx != 0 {
            // Deposit our partial result in the parent's staging buffer.
            let parent = idx - lowbit;
            let parent_pe = set.rank_at(parent);
            // Which slot are we in the parent's child list? Children are
            // parent + 1, parent + 2, parent + 4, … ⇒ slot = log2(idx - parent).
            let slot_idx = (idx - parent).trailing_zeros() as usize;
            let tmp_off = self.coll_wait_buf(parent_pe);
            let slot: SymPtr<T> = SymPtr::from_raw(
                tmp_off + slot_idx * nreduce * std::mem::size_of::<T>(),
                nreduce,
            );
            self.put_sym(slot, parent_pe, target, self.my_pe(), nreduce);
            self.quiet();
            self.coll_signal(parent_pe);
            // Wait for the final result (root's fan-out signal). Our counter
            // already absorbed `n_children` child signals.
            self.coll_wait_count(n_children as u64 + 1);
        } else {
            // Root: fan the result out linearly.
            for i in 1..size {
                let pe = set.rank_at(i);
                self.put_sym(target, pe, target, self.my_pe(), nreduce);
            }
            self.fence();
            for i in 1..size {
                self.coll_signal(set.rank_at(i));
            }
        }
    }

    /// Recursive doubling (power-of-two sets): everyone finishes with the
    /// result after log₂(n) pairwise exchanges.
    ///
    /// Completion cannot ride on the single §4.5.1 counter: round partners
    /// differ per round, and a fast partner of round *r+1* would be
    /// indistinguishable from the awaited partner of round *r*. Each inbox
    /// slot therefore carries its own ready flag, written (with release
    /// ordering via the preceding quiet) after the slot's data.
    fn reduce_recdbl<T: ReduceElem>(
        &self,
        target: SymPtr<T>,
        source: SymPtr<T>,
        nreduce: usize,
        op: ReduceOp,
        set: &ActiveSet,
        idx: usize,
    ) {
        let size = set.size;
        let rounds = size.trailing_zeros() as usize;
        let chunk = crate::util::align_up(nreduce * std::mem::size_of::<T>(), 16);
        // Per-round inbox + per-round flags, one Lemma-1 temporary per PE,
        // published through the §4.5.1 structure (§4.5.2-safe: partners spin
        // on the published handle, so a late entrant is handled).
        let tmp = self
            .heap()
            .alloc_bytes(rounds * chunk + rounds * 8, 16)
            .expect("recdbl inbox allocation");
        let my_flags: SymPtr<u64> = SymPtr::from_raw(tmp.offset() + rounds * chunk, rounds);
        // The allocator reuses space: zero our flags *before* publishing.
        unsafe {
            for f in self.local_mut(my_flags) {
                *f = 0;
            }
        }
        self.coll_publish_buf(tmp);
        self.put_sym(target, self.my_pe(), source, self.my_pe(), nreduce);
        for r in 0..rounds {
            let partner_idx = idx ^ (1 << r);
            let partner_pe = set.rank_at(partner_idx);
            self.coll_check_peer(partner_pe, CollOpTag::Reduce, nreduce * std::mem::size_of::<T>());
            let partner_tmp = self.coll_wait_buf(partner_pe);
            let slot: SymPtr<T> = SymPtr::from_raw(partner_tmp + r * chunk, nreduce);
            let flag: SymPtr<u64> = SymPtr::from_raw(partner_tmp + rounds * chunk + r * 8, 1);
            // Send current accumulator to the partner's round-r inbox, then
            // raise the slot's flag.
            self.put_sym(slot, partner_pe, target, self.my_pe(), nreduce);
            self.quiet();
            self.put_one(flag, 1, partner_pe);
            // Receive the partner's round-r contribution.
            self.wait_until(my_flags.at(r), crate::sync::CmpOp::Eq, 1);
            std::sync::atomic::fence(std::sync::atomic::Ordering::Acquire);
            // SAFETY: flag ordering guarantees slot r is complete; the
            // combine order (acc ⊕ inbox) is identical on both partners.
            unsafe {
                let acc = self.local_mut(target);
                let inbox: SymPtr<T> = SymPtr::from_raw(tmp.offset() + r * chunk, nreduce);
                let inbox = self.local(inbox);
                combine_into(op, &mut acc[..nreduce], inbox);
            }
        }
        self.heap().free(tmp).expect("freeing recdbl inbox");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::AlgoKind;
    use crate::pe::{PoshConfig, World};

    fn expected_i64(op: ReduceOp, contributions: &[i64]) -> i64 {
        contributions[1..]
            .iter()
            .fold(contributions[0], |a, &b| i64::combine(op, a, b))
    }

    fn reduce_case(algo: AlgoKind, n: usize, op: ReduceOp, nreduce: usize) {
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(algo);
        let w = World::threads(n, cfg).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            let dst = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = (ctx.my_pe() as i64 + 2) * (j as i64 + 1) % 13 + 1;
                }
            }
            ctx.barrier_all();
            ctx.reduce_to_all(dst, src, nreduce, op, &team);
            // Independent oracle.
            for j in 0..nreduce {
                let contribs: Vec<i64> =
                    (0..n).map(|pe| (pe as i64 + 2) * (j as i64 + 1) % 13 + 1).collect();
                let want = expected_i64(op, &contribs);
                let got = unsafe { ctx.local(dst)[j] };
                assert_eq!(got, want, "{algo:?} {op:?} n={n} elem {j}");
            }
            // Lemma 1: scratch space fully reclaimed — heap symmetric again.
            ctx.barrier_all();
            assert_eq!(ctx.heap().live_allocations(), 2, "{algo:?}: temp leaked");
            ctx.barrier_all();
        });
    }

    #[test]
    fn reduce_all_ops_linear_put() {
        for op in ReduceOp::all() {
            reduce_case(AlgoKind::LinearPut, 4, op, 16);
        }
    }

    #[test]
    fn reduce_all_ops_linear_get() {
        for op in ReduceOp::all() {
            reduce_case(AlgoKind::LinearGet, 3, op, 9);
        }
    }

    #[test]
    fn reduce_all_ops_tree() {
        for op in ReduceOp::all() {
            reduce_case(AlgoKind::Tree, 5, op, 8);
        }
    }

    #[test]
    fn reduce_all_ops_recdbl_pow2() {
        for op in ReduceOp::all() {
            reduce_case(AlgoKind::RecursiveDoubling, 4, op, 12);
        }
    }

    #[test]
    fn reduce_recdbl_fallback_non_pow2() {
        reduce_case(AlgoKind::RecursiveDoubling, 6, ReduceOp::Sum, 10);
    }

    #[test]
    fn reduce_various_pe_counts() {
        for &n in &[2usize, 3, 7, 8] {
            for algo in AlgoKind::all() {
                reduce_case(algo, n, ReduceOp::Sum, 5);
            }
        }
    }

    #[test]
    fn reduce_single_pe_set() {
        reduce_case(AlgoKind::LinearPut, 1, ReduceOp::Sum, 4);
    }

    #[test]
    fn reduce_floats_sum_exact() {
        // Small integers in f64 are exact under any combine order.
        let w = World::threads(4, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<f64>(8).unwrap();
            let dst = ctx.shmalloc_n::<f64>(8).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = (ctx.my_pe() * 10 + j) as f64;
                }
            }
            ctx.barrier_all();
            ctx.reduce_to_all(dst, src, 8, ReduceOp::Sum, &team);
            for j in 0..8 {
                let want: f64 = (0..4).map(|pe| (pe * 10 + j) as f64).sum();
                assert_eq!(unsafe { ctx.local(dst)[j] }, want);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn reduce_on_split_team_strided() {
        // Reduce over ranks {0, 2, 4}; odd ranks stay out.
        let w = World::threads(5, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world().split_strided(0, 2, 3);
            let src = ctx.shmalloc_n::<i32>(4).unwrap();
            let dst = ctx.shmalloc_n::<i32>(4).unwrap();
            unsafe {
                for s in ctx.local_mut(src).iter_mut() {
                    *s = ctx.my_pe() as i32;
                }
            }
            ctx.barrier_all();
            if let Some(team) = &team {
                ctx.reduce_to_all(dst, src, 4, ReduceOp::Sum, team);
                assert_eq!(unsafe { ctx.local(dst) }, &[6; 4][..]); // 0 + 2 + 4
            }
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            ctx.barrier_all();
        });
    }

    #[test]
    #[should_panic(expected = "bitwise reduction on floating-point")]
    fn float_bitwise_rejected() {
        let _ = f32::combine(ReduceOp::And, 1.0, 2.0);
    }
}
