//! The sharded MPSC queue behind every explicit NBI ordering domain —
//! the `SHMEM_THREAD_MULTIPLE` hot path.
//!
//! The pre-thread-levels `NbiBatch` guarded its deferred-put queue with one
//! `Mutex<Vec<…>>`: correct, but every `put_nbi` from every application
//! thread of a `MULTIPLE` job serialised through that lock. This module
//! replaces it with a design whose **issue path is lock-free**:
//!
//! * the queue is split into [`Shard`]s; each pushing thread maps to a
//!   stable shard via its [`thread_slot`] (a process-wide monotone
//!   thread-local id, taken mod the shard count);
//! * a push is a Treiber-stack CAS onto the shard's `head` — no lock, no
//!   syscall, O(1) with only CAS retries under contention *on the same
//!   shard*;
//! * a drain takes the shard's `drain_lock`, `swap`s the whole stack out
//!   with one `Acquire` exchange, reverses it to FIFO order, and delivers.
//!   Holding the lock **through delivery** is what preserves per-thread
//!   delivery order across concurrent quiets: a second drainer cannot
//!   deliver a thread's later puts before its earlier puts finish.
//!
//! Accounting is two counters, not a counter behind the queue lock:
//! `pending` is incremented *before* the push (so the Release CAS publishes
//! the increment to any Acquire drain that takes the node) and decremented
//! only by [`ShardedQueue::quiet`]; `completed` absorbs operations that
//! were *delivered* without being *retired* — eager bulk ops (delivered at
//! issue) and fence-path drains (fences deliver, they never retire). A
//! quiet retires `drained_now + completed.swap(0)` in one subtraction:
//! every operation is retired exactly once, no drained-but-still-counted
//! op can survive a quiet, and no op can be counted away while it still
//! sits in a shard (its `pending` increment happens-before any drain that
//! observes it).
//!
//! The same source builds under `--cfg loom` against the `loom` model
//! checker's shimmed atomics (the loom job in CI adds the dev-dependency;
//! the vendored registry this crate normally builds against does not carry
//! it, so there is deliberately no `Cargo.toml` entry). The
//! `loom_model` tests exhaustively interleave 2–3 threads over push /
//! drain / drop and pin: no lost entry, no double-delivery, and the
//! quiet's Release/Acquire pairing. The plain `tests` module replays the
//! same schedules deterministically (join-ordering instead of model
//! exploration) so the default `cargo test` run covers them too.

#[cfg(loom)]
use loom::sync::atomic::{fence, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
use loom::sync::Mutex;
#[cfg(not(loom))]
use std::sync::atomic::{fence, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
use std::sync::Mutex;

use std::ptr;

/// Process-wide monotone thread-id allocator backing [`thread_slot`].
/// Always `std` atomics, even under loom: slot assignment is identity, not
/// synchronisation, and loom models pass explicit slots instead.
static NEXT_SLOT: std::sync::atomic::AtomicUsize = std::sync::atomic::AtomicUsize::new(0);

thread_local! {
    static MY_SLOT: usize = NEXT_SLOT.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
}

/// The calling thread's stable queue slot: assigned once per thread, for
/// its lifetime, from a process-wide counter. Same thread ⇒ same slot ⇒
/// same shard ⇒ FIFO delivery of that thread's deferred puts; distinct
/// threads usually land on distinct shards (mod the shard count) and never
/// contend on the issue path when they do.
pub(crate) fn thread_slot() -> usize {
    MY_SLOT.with(|s| *s)
}

/// One queued entry: the item plus its byte weight (for the per-shard
/// inline-drain cap).
struct Node<T> {
    item: T,
    nbytes: usize,
    next: *mut Node<T>,
}

/// One shard: a Treiber stack of pending entries plus the drain lock that
/// serialises delivery (issue stays lock-free).
struct Shard<T> {
    /// Stack head; pushes CAS here with `Release`, drains `swap` with
    /// `Acquire`.
    head: AtomicPtr<Node<T>>,
    /// Held across `swap`-and-deliver so concurrent drains keep per-thread
    /// delivery order.
    drain_lock: Mutex<()>,
    /// Bytes currently queued on this shard (advisory, for the inline-drain
    /// cap; maintained with relaxed ops).
    queued_bytes: AtomicUsize,
}

/// A sharded multi-producer queue with quiet/fence-shaped accounting. See
/// the module docs for the design and the ordering argument.
pub(crate) struct ShardedQueue<T> {
    shards: Box<[Shard<T>]>,
    /// Issued-but-unretired operations (queued, eagerly delivered, or
    /// fence-drained — everything a future quiet still owes a retirement).
    pending: AtomicU64,
    /// Delivered-but-unretired operations: eager ops and fence-path drains
    /// park their count here until a quiet subtracts it from `pending`.
    completed: AtomicU64,
}

// The queue moves `T` values across threads (push on one, deliver on
// another) exactly like a channel, so `Send` on `T` is the right bound for
// both. The auto impls would be unconditional (`AtomicPtr<Node<T>>` is
// always `Send + Sync`), which would be unsound for `!Send` payloads —
// these manual impls restore the bound.
unsafe impl<T: Send> Send for ShardedQueue<T> {}
unsafe impl<T: Send> Sync for ShardedQueue<T> {}

impl<T> ShardedQueue<T> {
    /// An empty queue with `shards` shards (≥ 1).
    pub(crate) fn new(shards: usize) -> ShardedQueue<T> {
        assert!(shards >= 1, "a sharded queue needs at least one shard");
        let shards = (0..shards)
            .map(|_| Shard {
                head: AtomicPtr::new(ptr::null_mut()),
                drain_lock: Mutex::new(()),
                queued_bytes: AtomicUsize::new(0),
            })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        ShardedQueue { shards, pending: AtomicU64::new(0), completed: AtomicU64::new(0) }
    }

    /// Issued-but-unretired operation count.
    pub(crate) fn pending(&self) -> u64 {
        self.pending.load(Ordering::Relaxed)
    }

    /// Lock-free push of `item` (weighing `nbytes`) onto the shard for
    /// `slot`. Returns the shard's queued bytes after the push so the
    /// caller can trigger an inline drain past its cap.
    ///
    /// `pending` is incremented **before** the node is published: the
    /// `Release` CAS then carries the increment to any drain whose
    /// `Acquire` swap takes the node, so a concurrent quiet can never
    /// retire an op it did not deliver.
    pub(crate) fn push(&self, slot: usize, item: T, nbytes: usize) -> usize {
        let shard = &self.shards[slot % self.shards.len()];
        self.pending.fetch_add(1, Ordering::Relaxed);
        let node = Box::into_raw(Box::new(Node { item, nbytes, next: ptr::null_mut() }));
        let mut head = shard.head.load(Ordering::Relaxed);
        loop {
            // SAFETY: `node` is ours until the CAS publishes it.
            unsafe { (*node).next = head };
            match shard.head.compare_exchange_weak(head, node, Ordering::Release, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(cur) => head = cur,
            }
        }
        shard.queued_bytes.fetch_add(nbytes, Ordering::Relaxed) + nbytes
    }

    /// Count one eagerly-delivered operation (a bulk put or a get): it is
    /// already complete, so it goes straight to `completed` and retires at
    /// the next quiet. `pending` first — a quiet interleaving between the
    /// two increments then sees a still-pending op (truthful: its
    /// retirement waits for the next quiet) rather than a negative balance.
    pub(crate) fn note_eager(&self) {
        self.pending.fetch_add(1, Ordering::Relaxed);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Swap out and deliver one shard's queue in FIFO order under its drain
    /// lock. Returns the number of entries delivered. Accounting untouched.
    fn drain_shard(&self, idx: usize, deliver: &mut dyn FnMut(Vec<T>)) -> u64 {
        let shard = &self.shards[idx];
        let _guard = shard.drain_lock.lock().unwrap();
        let mut head = shard.head.swap(ptr::null_mut(), Ordering::Acquire);
        if head.is_null() {
            return 0;
        }
        // Reverse the LIFO stack into issue (FIFO) order.
        let mut items = Vec::new();
        let mut bytes = 0usize;
        while !head.is_null() {
            // SAFETY: the swap made this list exclusively ours; every node
            // was created by `Box::into_raw` in `push`.
            let node = unsafe { Box::from_raw(head) };
            head = node.next;
            bytes += node.nbytes;
            items.push(node.item);
        }
        items.reverse();
        let n = items.len() as u64;
        shard.queued_bytes.fetch_sub(bytes, Ordering::Relaxed);
        // Deliver while still holding the drain lock: a racing drain of the
        // same shard must not ship later entries before these land.
        deliver(items);
        n
    }

    /// Fence-path drain of every shard: deliver everything queued, leave
    /// the accounting pending (fences order, they do not retire). The
    /// delivered count parks in `completed` for the next quiet.
    pub(crate) fn drain(&self, deliver: &mut dyn FnMut(Vec<T>)) {
        let mut n = 0;
        for idx in 0..self.shards.len() {
            n += self.drain_shard(idx, deliver);
        }
        if n > 0 {
            self.completed.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Inline-cap drain of a single slot's shard (the `put_nbi` overflow
    /// path): deliver, keep pending, park the count in `completed`.
    pub(crate) fn drain_slot(&self, slot: usize, deliver: &mut dyn FnMut(Vec<T>)) {
        let n = self.drain_shard(slot % self.shards.len(), deliver);
        if n > 0 {
            self.completed.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// The quiet: deliver every shard, publish with **one** `Release`
    /// fence, then retire everything delivered — by this quiet directly,
    /// plus whatever eager/fence traffic parked in `completed`. Concurrent
    /// quiets each retire exactly the operations they (or the swap they
    /// won) delivered, so every op retires exactly once.
    pub(crate) fn quiet(&self, deliver: &mut dyn FnMut(Vec<T>)) {
        let mut n = 0;
        for idx in 0..self.shards.len() {
            n += self.drain_shard(idx, deliver);
        }
        fence(Ordering::Release);
        let done = n + self.completed.swap(0, Ordering::Relaxed);
        if done > 0 {
            self.pending.fetch_sub(done, Ordering::Relaxed);
        }
    }
}

impl<T> Drop for ShardedQueue<T> {
    /// Free any still-queued nodes. (The NBI layer quiesces on context drop
    /// before this runs, so in production the shards are already empty;
    /// this covers direct users and the error paths.)
    fn drop(&mut self) {
        for shard in self.shards.iter() {
            let mut head = shard.head.swap(ptr::null_mut(), Ordering::Acquire);
            while !head.is_null() {
                // SAFETY: `&mut self` — no concurrent pushes; nodes are
                // `Box::into_raw` allocations owned by the queue.
                let node = unsafe { Box::from_raw(head) };
                head = node.next;
            }
        }
    }
}

impl<T> std::fmt::Debug for ShardedQueue<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardedQueue")
            .field("shards", &self.shards.len())
            .field("pending", &self.pending())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Loom model checks: exhaustive interleavings of the 2–3-thread schedules.
// Only built under `--cfg loom` (CI's loom job adds the dev-dependency and
// runs `cargo test --lib p2p::shard_queue::loom_model` with the cfg set).
// ---------------------------------------------------------------------------
#[cfg(all(test, loom))]
mod loom_model {
    use super::ShardedQueue;
    use loom::sync::{Arc, Mutex};
    use loom::thread;

    /// Two pushers on distinct shards racing one another: the quiet after
    /// both joins must deliver both entries and retire both.
    #[test]
    fn two_pushers_nothing_lost() {
        loom::model(|| {
            let q = Arc::new(ShardedQueue::<u32>::new(2));
            let q1 = Arc::clone(&q);
            let t = thread::spawn(move || {
                q1.push(0, 1, 4);
            });
            q.push(1, 2, 4);
            t.join().unwrap();
            let mut got = Vec::new();
            q.quiet(&mut |items| got.extend(items));
            got.sort_unstable();
            assert_eq!(got, vec![1, 2], "an entry was lost or duplicated");
            assert_eq!(q.pending(), 0, "quiet must retire everything it delivered");
        });
    }

    /// A push racing a quiet: whichever way the interleaving falls, the
    /// entry is delivered exactly once (by the racing quiet or the final
    /// one) and the accounting converges to zero — the Release/Acquire
    /// pairing between the push's CAS and the drain's swap.
    #[test]
    fn push_racing_quiet_exactly_once() {
        loom::model(|| {
            let q = Arc::new(ShardedQueue::<u8>::new(1));
            let log = Arc::new(Mutex::new(Vec::new()));
            let q1 = Arc::clone(&q);
            let t = thread::spawn(move || {
                q1.push(0, 7u8, 1);
            });
            let l = Arc::clone(&log);
            q.quiet(&mut |items| l.lock().unwrap().extend(items));
            t.join().unwrap();
            let l = Arc::clone(&log);
            q.quiet(&mut |items| l.lock().unwrap().extend(items));
            assert_eq!(&*log.lock().unwrap(), &[7u8], "lost or double-delivered");
            assert_eq!(q.pending(), 0);
        });
    }

    /// Two concurrent quiets over one pre-filled shard: the drain lock +
    /// swap guarantee each entry is delivered exactly once and in FIFO
    /// order, and the two retirements sum to exactly the delivered count.
    #[test]
    fn concurrent_quiets_no_double_drain() {
        loom::model(|| {
            let q = Arc::new(ShardedQueue::<u8>::new(1));
            q.push(0, 1, 1);
            q.push(0, 2, 1);
            let log = Arc::new(Mutex::new(Vec::new()));
            let (qa, la) = (Arc::clone(&q), Arc::clone(&log));
            let t = thread::spawn(move || qa.quiet(&mut |items| la.lock().unwrap().extend(items)));
            let l = Arc::clone(&log);
            q.quiet(&mut |items| l.lock().unwrap().extend(items));
            t.join().unwrap();
            assert_eq!(&*log.lock().unwrap(), &[1, 2], "must stay FIFO, exactly once");
            assert_eq!(q.pending(), 0);
        });
    }

    /// Drop with a racing pusher that finished before the drop: nothing
    /// leaks, nothing double-frees (loom tracks the allocations).
    #[test]
    fn drop_after_push_frees_nodes() {
        loom::model(|| {
            let q = Arc::new(ShardedQueue::<Vec<u8>>::new(1));
            let q1 = Arc::clone(&q);
            let t = thread::spawn(move || {
                q1.push(0, vec![1, 2, 3], 3);
            });
            t.join().unwrap();
            drop(q);
        });
    }
}

// ---------------------------------------------------------------------------
// Default-harness tests: the same schedules, replayed deterministically via
// join ordering, plus multi-thread hammers. These run under plain
// `cargo test`, under Miri (provenance/leak checking of the raw-pointer
// stack), and under ThreadSanitizer in CI.
// ---------------------------------------------------------------------------
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn drain_all(q: &ShardedQueue<u64>) -> Vec<u64> {
        let mut got = Vec::new();
        q.quiet(&mut |items| got.extend(items));
        got
    }

    #[test]
    fn fifo_per_slot_and_accounting() {
        let q = ShardedQueue::<u64>::new(4);
        for v in [10, 11, 12] {
            q.push(0, v, 8);
        }
        assert_eq!(q.pending(), 3);
        assert_eq!(drain_all(&q), vec![10, 11, 12], "push order must be delivery order");
        assert_eq!(q.pending(), 0);
        // Empty quiet is a no-op.
        assert_eq!(drain_all(&q), Vec::<u64>::new());
        assert_eq!(q.pending(), 0);
    }

    /// Loom schedule A, deterministically: push completes before the first
    /// quiet (join first), the quiet delivers it, the second quiet is empty.
    #[test]
    fn schedule_push_then_quiet() {
        let q = Arc::new(ShardedQueue::<u64>::new(1));
        let q1 = Arc::clone(&q);
        std::thread::spawn(move || {
            q1.push(0, 7, 1);
        })
        .join()
        .unwrap();
        assert_eq!(drain_all(&q), vec![7]);
        assert_eq!(drain_all(&q), Vec::<u64>::new());
        assert_eq!(q.pending(), 0);
    }

    /// Loom schedule B, deterministically: the quiet runs before the push
    /// (delivering nothing and retiring nothing of it); the entry is still
    /// pending afterwards and the second quiet delivers it — a racing quiet
    /// can never count away an undelivered op.
    #[test]
    fn schedule_quiet_then_push() {
        let q = Arc::new(ShardedQueue::<u64>::new(1));
        assert_eq!(drain_all(&q), Vec::<u64>::new());
        let q1 = Arc::clone(&q);
        std::thread::spawn(move || {
            q1.push(0, 9, 1);
        })
        .join()
        .unwrap();
        assert_eq!(q.pending(), 1, "op issued after the quiet stays pending");
        assert_eq!(drain_all(&q), vec![9]);
        assert_eq!(q.pending(), 0);
    }

    /// Many concurrent pushers, one final quiet: every entry arrives
    /// exactly once, and each thread's own entries arrive in its program
    /// order (same thread ⇒ same shard ⇒ FIFO).
    #[test]
    fn concurrent_pushers_nothing_lost_or_reordered() {
        const THREADS: usize = 4;
        let per_thread: u64 = if cfg!(miri) { 25 } else { 2000 };
        // One shard per thread slot: each delivered batch is one thread's
        // FIFO stream.
        let q = Arc::new(ShardedQueue::<u64>::new(THREADS));
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..per_thread {
                        // Encode (thread, seq) so the check below can split
                        // the streams back out.
                        q.push(t, ((t as u64) << 32) | i, 8);
                    }
                });
            }
        });
        assert_eq!(q.pending(), THREADS as u64 * per_thread);
        let mut batches = Vec::new();
        q.quiet(&mut |items| batches.push(items));
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, THREADS * per_thread as usize, "lost or duplicated entries");
        for batch in &batches {
            // A batch is one shard = one thread here: consecutive sequence
            // numbers, single owner.
            let owner = batch[0] >> 32;
            let first = batch[0] & 0xFFFF_FFFF;
            for (k, &v) in batch.iter().enumerate() {
                assert_eq!(v >> 32, owner, "shard mixing across slots");
                assert_eq!(v & 0xFFFF_FFFF, first + k as u64, "per-thread FIFO violated");
            }
        }
        assert_eq!(q.pending(), 0);
    }

    /// Pushers racing quiet-ers: across all interleavings, the union of
    /// everything delivered is exactly the set pushed, and the final
    /// pending count is zero.
    #[test]
    fn pushers_racing_quiets_exactly_once() {
        const PUSHERS: usize = 2;
        let per_thread: u64 = if cfg!(miri) { 20 } else { 1000 };
        let q = Arc::new(ShardedQueue::<u64>::new(2));
        let delivered = Arc::new(std::sync::Mutex::new(Vec::new()));
        std::thread::scope(|s| {
            for t in 0..PUSHERS {
                let q = Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..per_thread {
                        q.push(t, ((t as u64) << 32) | i, 8);
                    }
                });
            }
            for _ in 0..2 {
                let q = Arc::clone(&q);
                let delivered = Arc::clone(&delivered);
                s.spawn(move || {
                    for _ in 0..50 {
                        q.quiet(&mut |items| delivered.lock().unwrap().extend(items));
                    }
                });
            }
        });
        let q = Arc::clone(&q);
        q.quiet(&mut |items| delivered.lock().unwrap().extend(items));
        let mut all = delivered.lock().unwrap().clone();
        all.sort_unstable();
        let mut want: Vec<u64> = (0..PUSHERS as u64)
            .flat_map(|t| (0..per_thread).map(move |i| (t << 32) | i))
            .collect();
        want.sort_unstable();
        assert_eq!(all, want, "racing quiets lost or double-delivered an entry");
        assert_eq!(q.pending(), 0);
    }

    /// Eager (already-delivered) ops retire only at a quiet, exactly once.
    #[test]
    fn eager_ops_retire_at_quiet() {
        let q = ShardedQueue::<u64>::new(2);
        q.note_eager();
        q.note_eager();
        assert_eq!(q.pending(), 2, "eager ops count until a quiet");
        drain_all(&q);
        assert_eq!(q.pending(), 0);
        drain_all(&q);
        assert_eq!(q.pending(), 0, "no double retirement");
    }

    /// The fence path delivers but keeps the accounting pending; the next
    /// quiet retires it without re-delivering.
    #[test]
    fn fence_drain_delivers_but_keeps_pending() {
        let q = ShardedQueue::<u64>::new(2);
        q.push(0, 5, 8);
        q.push(1, 6, 8);
        let mut got = Vec::new();
        q.drain(&mut |items| got.extend(items));
        got.sort_unstable();
        assert_eq!(got, vec![5, 6], "fence must deliver everything queued");
        assert_eq!(q.pending(), 2, "fences never retire");
        assert_eq!(drain_all(&q), Vec::<u64>::new(), "no re-delivery at the quiet");
        assert_eq!(q.pending(), 0, "quiet retires the fence-delivered ops");
    }

    /// The inline-cap path: drain one slot only, pending preserved.
    #[test]
    fn drain_slot_is_scoped_and_pending_preserved() {
        let q = ShardedQueue::<u64>::new(2);
        q.push(0, 1, 8);
        q.push(1, 2, 8);
        let mut got = Vec::new();
        q.drain_slot(0, &mut |items| got.extend(items));
        assert_eq!(got, vec![1], "only slot 0's shard drains");
        assert_eq!(q.pending(), 2);
        assert_eq!(drain_all(&q), vec![2]);
        assert_eq!(q.pending(), 0);
    }

    /// Push returns the shard's queued bytes (the inline-drain trigger).
    #[test]
    fn push_reports_shard_bytes() {
        let q = ShardedQueue::<u64>::new(2);
        assert_eq!(q.push(0, 1, 100), 100);
        assert_eq!(q.push(0, 2, 50), 150);
        assert_eq!(q.push(1, 3, 7), 7, "byte caps are per shard");
        drain_all(&q);
        assert_eq!(q.push(0, 4, 10), 10, "drain resets the shard's byte count");
        drain_all(&q);
    }

    /// Dropping a queue with entries still queued frees them (Miri checks
    /// for leaks and double-frees of the raw-pointer stack).
    #[test]
    fn drop_frees_queued_entries() {
        let q = ShardedQueue::<Vec<u8>>::new(3);
        for slot in 0..5 {
            q.push(slot, vec![slot as u8; 32], 32);
        }
        drop(q);
    }

    /// Distinct threads get distinct slots; a thread's slot is stable.
    #[test]
    fn thread_slots_are_stable_and_distinct() {
        let here = thread_slot();
        assert_eq!(here, thread_slot());
        let other = std::thread::spawn(thread_slot).join().unwrap();
        assert_ne!(here, other);
    }
}
