//! Demand-mapping behaviour of the process-mode remote-heap table
//! (§4.1.1), driven directly over memfd segments so one test process can
//! stand in for a whole world: each "PE heap" is a `MemfdSegment`, the
//! table maps peers through the same fds a launcher handoff would broker.
//!
//! Covered here (tests/proc_mode.rs covers the real multi-process story):
//! * lazy mapping at 32 PEs — nothing maps until first touch;
//! * the `POSH_MAX_MAPPED_SEGS`-style LRU cap — eviction, bounded
//!   residency, and correct remapping of an evicted peer;
//! * concurrent first touch — two threads racing a cold PE agree on one
//!   base and the peer is mapped exactly once.

use posh::pe::remote_table::{RemoteTable, TableOpts};
use posh::shm::memfd::{memfd_supported, MemfdSegment};
use posh::shm::Segment as _;
use std::os::unix::io::RawFd;
use std::time::Duration;

const SEG_LEN: usize = 64 * 1024;
/// Offset of the per-PE marker byte each test plants (clear of anything a
/// header would use — these segments are raw bytes, never a real heap).
const MARK: usize = 128;

/// Build an in-process "world": one memfd segment per rank, each stamped
/// with a distinguishing byte, plus the rank-indexed fd list a launcher
/// handoff would provide. `None` (with a loud note) where the kernel has no
/// `memfd_create`.
fn mk_world(n: usize) -> Option<(Vec<MemfdSegment>, Vec<RawFd>)> {
    if !memfd_supported() {
        eprintln!("skipping: memfd_create unavailable on this kernel");
        return None;
    }
    let mut segs = Vec::with_capacity(n);
    let mut fds = Vec::with_capacity(n);
    for r in 0..n {
        let seg = MemfdSegment::create(&format!("posh.test.demand.{r}"), SEG_LEN).unwrap();
        // SAFETY: fresh private mapping, in bounds.
        unsafe {
            *seg.base().add(MARK) = r as u8 + 1;
        }
        fds.push(seg.fd());
        segs.push(seg);
    }
    Some((segs, fds))
}

fn opts() -> TableOpts {
    TableOpts {
        timeout: Duration::from_millis(500),
        ..TableOpts::default()
    }
}

#[test]
fn lazy_mapping_32() {
    let n = 32;
    let Some((segs, fds)) = mk_world(n) else { return };
    let table = RemoteTable::with_memfds(fds, 0, segs[0].base(), SEG_LEN, opts()).unwrap();

    // Construction maps nothing but self.
    let s = table.stats();
    assert_eq!(s.mapped, 1, "{s}");
    assert_eq!(s.mapped_total, 0, "{s}");

    // First touches map exactly the touched peers — and resolve to *their*
    // segments (the marker byte distinguishes every rank).
    for pe in [7usize, 19] {
        let b = table.base_of(pe);
        assert_eq!(unsafe { *b.add(MARK) }, pe as u8 + 1, "wrong segment for PE {pe}");
    }
    let s = table.stats();
    assert_eq!(s.mapped, 3, "{s}");
    assert_eq!(s.mapped_total, 2, "{s}");
    assert!(s.mapped < n, "a 32-PE world must not be fully mapped by 2 touches");

    // Re-touching costs no new mapping.
    table.base_of(7);
    assert_eq!(table.stats().mapped_total, 2);

    // Touching everyone converges on the eager table's footprint.
    for pe in 0..n {
        let b = table.base_of(pe);
        assert_eq!(unsafe { *b.add(MARK) }, pe as u8 + 1);
    }
    let s = table.stats();
    assert_eq!(s.mapped, n, "{s}");
    assert_eq!(s.mapped_total, (n - 1) as u64, "{s}");
    assert_eq!(s.evicted, 0, "{s}");
}

#[test]
fn lru_eviction_and_remap() {
    let n = 12;
    let cap = 4;
    let Some((segs, fds)) = mk_world(n) else { return };
    let table = RemoteTable::with_memfds(
        fds,
        0,
        segs[0].base(),
        SEG_LEN,
        TableOpts {
            max_mapped: Some(cap),
            ..opts()
        },
    )
    .unwrap();

    // Touch far more peers than the cap admits; residency stays bounded at
    // cap peers + self the whole way, and every touch still resolves the
    // right segment.
    for pe in 1..=10usize {
        let b = table.base_of(pe);
        assert_eq!(unsafe { *b.add(MARK) }, pe as u8 + 1, "wrong segment for PE {pe}");
        let s = table.stats();
        assert!(s.mapped <= cap + 1, "cap violated after touching PE {pe}: {s}");
    }
    let s = table.stats();
    assert_eq!(s.mapped_total, 10, "{s}");
    assert_eq!(s.evicted, (10 - cap) as u64, "{s}");
    assert_eq!(s.remapped, 0, "{s}");
    assert!(s.peak_mapped <= cap + 1, "{s}");

    // PE 1 is long evicted; touching it again must transparently remap and
    // read the same data (Fact 1: the offset-addressed content is
    // mapping-independent — a remap changes the base, never the bytes).
    let b = table.base_of(1);
    assert_eq!(unsafe { *b.add(MARK) }, 2);
    let s = table.stats();
    assert!(s.remapped >= 1, "{s}");
    assert_eq!(s.evicted, (10 - cap + 1) as u64, "{s}");
    assert!(s.mapped <= cap + 1, "{s}");
}

#[test]
fn concurrent_first_touch_maps_once() {
    let n = 4;
    let Some((segs, fds)) = mk_world(n) else { return };
    let table = RemoteTable::with_memfds(fds, 0, segs[0].base(), SEG_LEN, opts()).unwrap();

    // Two threads race the same cold peer: the per-PE once-lock must admit
    // exactly one mapper, and both callers must see the same base.
    let pe = 3usize;
    let (a, b) = std::thread::scope(|s| {
        let ta = s.spawn(|| table.base_of(pe) as usize);
        let tb = s.spawn(|| table.base_of(pe) as usize);
        (ta.join().unwrap(), tb.join().unwrap())
    });
    assert_eq!(a, b, "racing first-touchers must agree on the mapping");
    assert_eq!(unsafe { *(a as *const u8).add(MARK) }, pe as u8 + 1);
    let s = table.stats();
    assert_eq!(s.mapped, 2, "{s}");
    assert_eq!(s.mapped_total, 1, "double-mapped under a first-touch race: {s}");

    // And the race leaves the table fully serviceable.
    for other in 0..n {
        assert_eq!(unsafe { *table.base_of(other).add(MARK) }, other as u8 + 1);
    }
}
