//! The flat OpenSHMEM-1.0 C-style API (§4.3 "Datatype-specific routines").
//!
//! The paper's observation: SHMEM defines one function **per data type**
//! (`shmem_short_g`, `shmem_int_g`, `shmem_long_g`, …) and a C++ template
//! engine lets one write the body once — "that function is generated at
//! compile-time, not at run-time: consequently, calling that function is
//! just as fast as if it had been written manually."
//!
//! Rust generics are the same machinery; the macros below instantiate the
//! typed entry points from the generic `Ctx` core exactly as the paper's
//! `shmem_template_g<T>` does, C names and all.
//!
//! The implicit-context model of the C API (no handle arguments) is realised
//! with a thread-local `Ctx` installed by [`start_pes`] (process mode picks
//! it up from the `oshrun` environment; thread-mode tests install one with
//! [`install_ctx`]).

use crate::collectives::ActiveSet;
use crate::pe::Ctx;
use crate::symheap::SymPtr;
use std::cell::RefCell;

thread_local! {
    static CURRENT: RefCell<Option<Ctx>> = const { RefCell::new(None) };
}

/// Install the calling thread's implicit context (thread-mode worlds call
/// this from inside `world.run`; `start_pes` does it in process mode).
pub fn install_ctx(ctx: Ctx) {
    CURRENT.with(|c| *c.borrow_mut() = Some(ctx));
}

/// Remove the implicit context (end of PE body).
pub fn clear_ctx() {
    CURRENT.with(|c| *c.borrow_mut() = None);
}

/// Fetch the implicit context; panics outside a PE body.
pub fn ctx() -> Ctx {
    CURRENT.with(|c| {
        c.borrow()
            .clone()
            .expect("no SHMEM context on this thread: call start_pes()/install_ctx() first")
    })
}

/// `start_pes(0)`: initialise the library from the `oshrun` environment
/// (process mode) and install the implicit context. Returns the context for
/// callers that also want the explicit API.
pub fn start_pes(_npes_ignored: usize) -> crate::Result<Ctx> {
    let world = crate::pe::World::from_env()?;
    let c = world.my_ctx();
    install_ctx(c.clone());
    // Leak the world: the C API has no shutdown handle; process exit cleans
    // up (the segment owner unlinks via the RTE's job teardown).
    std::mem::forget(world);
    Ok(c)
}

/// `shmem_my_pe` / `_my_pe`.
pub fn shmem_my_pe() -> i32 {
    ctx().my_pe() as i32
}

/// `shmem_n_pes` / `_num_pes`.
pub fn shmem_n_pes() -> i32 {
    ctx().n_pes() as i32
}

/// `shmalloc`: untyped symmetric allocation of `size` bytes.
pub fn shmalloc(size: usize) -> crate::Result<SymPtr<u8>> {
    let c = ctx();
    let p = c.heap().alloc_bytes(size, 16)?;
    c.barrier_all();
    Ok(p)
}

/// `shmemalign`.
pub fn shmemalign(align: usize, size: usize) -> crate::Result<SymPtr<u8>> {
    let c = ctx();
    let p = c.heap().alloc_bytes(size, align)?;
    c.barrier_all();
    Ok(p)
}

/// `shfree`.
pub fn shfree<T>(ptr: SymPtr<T>) -> crate::Result<()> {
    ctx().shfree(ptr)
}

/// `shmem_barrier_all`.
pub fn shmem_barrier_all() {
    ctx().barrier_all();
}

/// `shmem_barrier(PE_start, logPE_stride, PE_size, pSync)` — `pSync` is
/// accepted for source compatibility and ignored (coordination runs over
/// header cells; see module docs of [`crate::collectives`]).
pub fn shmem_barrier(pe_start: usize, log_pe_stride: usize, pe_size: usize, _psync: &[i64]) {
    let c = ctx();
    let set = ActiveSet::new(pe_start, log_pe_stride, pe_size, c.n_pes());
    c.barrier(&set);
}

/// `shmem_fence`.
pub fn shmem_fence() {
    ctx().fence();
}

/// `shmem_quiet`.
pub fn shmem_quiet() {
    ctx().quiet_nbi();
}

/// Generates `shmem_<ty>_{p,g,put,get,iput,iget}` — the §4.3 instantiation.
macro_rules! typed_p2p {
    ($($cname:ident : $t:ty),+ $(,)?) => {
        paste_each! { $($cname : $t),+ }
    };
}

// Minimal "paste" substitute: declare per-type modules with fixed fn names.
macro_rules! paste_each {
    ($($cname:ident : $t:ty),+ $(,)?) => {$(
        /// Typed OpenSHMEM entry points for one C data type (§4.3).
        pub mod $cname {
            use super::*;

            /// `shmem_<T>_p(addr, value, pe)`.
            pub fn p(dest: SymPtr<$t>, value: $t, pe: usize) {
                ctx().put_one(dest, value, pe)
            }
            /// `shmem_<T>_g(addr, pe)`.
            pub fn g(src: SymPtr<$t>, pe: usize) -> $t {
                ctx().get_one(src, pe)
            }
            /// `shmem_<T>_put(dest, src, nelems, pe)`.
            pub fn put(dest: SymPtr<$t>, src: &[$t], pe: usize) {
                ctx().put(dest, src, pe)
            }
            /// `shmem_<T>_get(dest, src, nelems, pe)`.
            pub fn get(dest: &mut [$t], src: SymPtr<$t>, pe: usize) {
                ctx().get(dest, src, pe)
            }
            /// `shmem_<T>_iput(dest, src, dst, sst, nelems, pe)`.
            pub fn iput(dest: SymPtr<$t>, src: &[$t], dst: usize, sst: usize, n: usize, pe: usize) {
                ctx().iput(dest, src, dst, sst, n, pe)
            }
            /// `shmem_<T>_iget(dest, src, dst, sst, nelems, pe)`.
            pub fn iget(dest: &mut [$t], src: SymPtr<$t>, dst: usize, sst: usize, n: usize, pe: usize) {
                ctx().iget(dest, src, dst, sst, n, pe)
            }
        }
    )+};
}

typed_p2p!(
    short: i16,
    int: i32,
    long: i64,
    longlong: i64,
    float: f32,
    double: f64,
);

/// Generates `shmem_<ty>_{swap,cswap,fadd,finc,add,inc}` atomics (§4.6).
macro_rules! typed_atomics {
    ($($cname:ident : $t:ty),+ $(,)?) => {$(
        /// Typed atomic entry points for one C data type.
        pub mod $cname {
            use super::super::*;

            /// `shmem_<T>_swap`.
            pub fn swap(target: SymPtr<$t>, value: $t, pe: usize) -> $t {
                ctx().atomic_swap(target, value, pe)
            }
            /// `shmem_<T>_cswap`.
            pub fn cswap(target: SymPtr<$t>, cond: $t, value: $t, pe: usize) -> $t {
                ctx().atomic_cswap(target, cond, value, pe)
            }
            /// `shmem_<T>_fadd`.
            pub fn fadd(target: SymPtr<$t>, value: $t, pe: usize) -> $t {
                ctx().atomic_fadd(target, value, pe)
            }
            /// `shmem_<T>_finc`.
            pub fn finc(target: SymPtr<$t>, pe: usize) -> $t {
                ctx().atomic_finc(target, pe)
            }
            /// `shmem_<T>_add`.
            pub fn add(target: SymPtr<$t>, value: $t, pe: usize) {
                ctx().atomic_add(target, value, pe)
            }
            /// `shmem_<T>_inc`.
            pub fn inc(target: SymPtr<$t>, pe: usize) {
                ctx().atomic_inc(target, pe)
            }
        }
    )+};
}

/// Atomic namespaces (`atomic::int::fadd` ≙ `shmem_int_fadd`).
pub mod atomic {
    typed_atomics!(int: i32, long: i64, longlong: i64);
}

/// `shmem_set_lock`.
pub fn shmem_set_lock(lock: SymPtr<i64>) {
    ctx().set_lock(lock)
}

/// `shmem_clear_lock`.
pub fn shmem_clear_lock(lock: SymPtr<i64>) {
    ctx().clear_lock(lock)
}

/// `shmem_test_lock` (returns 0 when acquired, like the C API).
pub fn shmem_test_lock(lock: SymPtr<i64>) -> i32 {
    if ctx().test_lock(lock) {
        0
    } else {
        1
    }
}

/// Generates `shmem_<ty>_<op>_to_all` reductions.
macro_rules! typed_reductions {
    ($($cname:ident : $t:ty => [$($opname:ident : $op:expr),+ $(,)?]),+ $(,)?) => {$(
        /// Typed reduction entry points for one C data type.
        pub mod $cname {
            use super::super::*;
            use crate::collectives::ReduceOp;
            $(
                /// `shmem_<T>_<op>_to_all`. `pWrk`/`pSync` omitted — see
                /// module docs.
                pub fn $opname(
                    target: SymPtr<$t>,
                    source: SymPtr<$t>,
                    nreduce: usize,
                    pe_start: usize,
                    log_pe_stride: usize,
                    pe_size: usize,
                ) {
                    let c = ctx();
                    let set = ActiveSet::new(pe_start, log_pe_stride, pe_size, c.n_pes());
                    let _ = ReduceOp::Sum; // anchor the import
                    c.reduce_to_all(target, source, nreduce, $op, &set);
                }
            )+
        }
    )+};
}

/// Reduction namespaces (`reduce::int::sum_to_all` ≙ `shmem_int_sum_to_all`).
pub mod reduce {
    typed_reductions!(
        short: i16 => [
            sum_to_all: ReduceOp::Sum, prod_to_all: ReduceOp::Prod,
            min_to_all: ReduceOp::Min, max_to_all: ReduceOp::Max,
            and_to_all: ReduceOp::And, or_to_all: ReduceOp::Or,
            xor_to_all: ReduceOp::Xor,
        ],
        int: i32 => [
            sum_to_all: ReduceOp::Sum, prod_to_all: ReduceOp::Prod,
            min_to_all: ReduceOp::Min, max_to_all: ReduceOp::Max,
            and_to_all: ReduceOp::And, or_to_all: ReduceOp::Or,
            xor_to_all: ReduceOp::Xor,
        ],
        long: i64 => [
            sum_to_all: ReduceOp::Sum, prod_to_all: ReduceOp::Prod,
            min_to_all: ReduceOp::Min, max_to_all: ReduceOp::Max,
            and_to_all: ReduceOp::And, or_to_all: ReduceOp::Or,
            xor_to_all: ReduceOp::Xor,
        ],
        float: f32 => [
            sum_to_all: ReduceOp::Sum, prod_to_all: ReduceOp::Prod,
            min_to_all: ReduceOp::Min, max_to_all: ReduceOp::Max,
        ],
        double: f64 => [
            sum_to_all: ReduceOp::Sum, prod_to_all: ReduceOp::Prod,
            min_to_all: ReduceOp::Min, max_to_all: ReduceOp::Max,
        ],
    );
}

/// `shmem_broadcast64`-style entry (element type via generic monomorphism).
pub fn shmem_broadcast<T: Copy>(
    target: SymPtr<T>,
    source: SymPtr<T>,
    nelems: usize,
    pe_root: usize,
    pe_start: usize,
    log_pe_stride: usize,
    pe_size: usize,
) {
    let c = ctx();
    let set = ActiveSet::new(pe_start, log_pe_stride, pe_size, c.n_pes());
    c.broadcast(target, source, nelems, pe_root, &set);
}

/// `shmem_fcollect`-style entry.
pub fn shmem_fcollect<T: Copy>(
    target: SymPtr<T>,
    source: SymPtr<T>,
    nelems: usize,
    pe_start: usize,
    log_pe_stride: usize,
    pe_size: usize,
) {
    let c = ctx();
    let set = ActiveSet::new(pe_start, log_pe_stride, pe_size, c.n_pes());
    c.fcollect(target, source, nelems, &set);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};

    /// Run a thread-mode world with the implicit API installed per PE.
    fn with_api(n: usize, f: impl Fn() + Send + Sync) {
        let w = World::threads(n, PoshConfig::small()).unwrap();
        w.run(|c| {
            install_ctx(c);
            f();
            clear_ctx();
        });
    }

    #[test]
    fn c_style_identity_and_p2p() {
        with_api(2, || {
            let me = shmem_my_pe() as usize;
            assert_eq!(shmem_n_pes(), 2);
            let c = ctx();
            let cell = c.shmalloc_n::<i32>(1).unwrap();
            int::p(cell, me as i32 * 11, (me + 1) % 2);
            shmem_barrier_all();
            // The peer's cell holds what *we* wrote into it.
            let peer = (me + 1) % 2;
            assert_eq!(int::g(cell, peer), me as i32 * 11);
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_put_get_arrays() {
        with_api(2, || {
            let c = ctx();
            let buf = c.shmalloc_n::<f64>(8).unwrap();
            if shmem_my_pe() == 0 {
                double::put(buf, &[2.5; 8], 1);
            }
            shmem_barrier_all();
            if shmem_my_pe() == 1 {
                let mut out = [0f64; 8];
                double::get(&mut out, buf, 1);
                assert_eq!(out, [2.5; 8]);
            }
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_atomics_and_locks() {
        with_api(3, || {
            let c = ctx();
            let counter = c.shmalloc_n::<i64>(1).unwrap();
            let lock = c.shmalloc_n::<i64>(1).unwrap();
            for _ in 0..50 {
                atomic::long::add(counter, 1, 0);
            }
            shmem_barrier_all();
            if shmem_my_pe() == 0 {
                assert_eq!(long::g(counter, 0), 150);
            }
            shmem_barrier_all();
            shmem_set_lock(lock);
            shmem_clear_lock(lock);
            shmem_barrier_all();
        });
    }

    #[test]
    fn c_style_reduce_and_broadcast() {
        with_api(4, || {
            let c = ctx();
            let src = c.shmalloc_n::<i32>(4).unwrap();
            let dst = c.shmalloc_n::<i32>(4).unwrap();
            unsafe {
                for s in c.local_mut(src).iter_mut() {
                    *s = shmem_my_pe() + 1;
                }
            }
            shmem_barrier_all();
            reduce::int::sum_to_all(dst, src, 4, 0, 0, 4);
            assert_eq!(unsafe { c.local(dst) }, &[1 + 2 + 3 + 4; 4][..]);
            shmem_barrier_all();
            shmem_broadcast(dst, src, 4, 2, 0, 0, 4);
            if shmem_my_pe() != 2 {
                assert_eq!(unsafe { c.local(dst) }, &[3; 4][..]);
            }
            shmem_barrier_all();
        });
    }

    #[test]
    #[should_panic(expected = "no SHMEM context")]
    fn missing_ctx_panics() {
        clear_ctx();
        let _ = shmem_my_pe();
    }
}
