//! Collective algorithm selection (paper §4.5.4, now model-driven).
//!
//! "In order to reduce the number of conditional branches, collective
//! communication algorithms are chosen at compile-time … A default choice is
//! provided if no option is passed to the compiler."
//!
//! POSH-RS closes the loop the paper leaves open between this switch and
//! its own `T(n) = α + n/β` channel model (§5): the default is now
//! [`AlgoKind::Adaptive`], which resolves per `(operation, payload size,
//! team size)` through the fitted cost model
//! ([`crate::collectives::tuning`]). The fixed families survive as *forced*
//! overrides — cargo features `coll-linear` / `coll-tree` / `coll-recdbl`
//! fix the compile-time default ([`AlgoKind::default_algo`]); `PoshConfig`
//! or `POSH_COLL_ALGO` override once at start-up — so every Ablation-A A/B
//! comparison stays reproducible. Either way the per-op dispatch is
//! resolved before any data moves.

use super::tuning::{self, CollOp};

/// Which algorithm family a collective uses.
///
/// ```
/// use posh::collectives::AlgoKind;
/// // Every spelling round-trips through parse/name, `adaptive` included.
/// assert_eq!(AlgoKind::parse("tree"), Some(AlgoKind::Tree));
/// assert_eq!(AlgoKind::parse("adaptive"), Some(AlgoKind::Adaptive));
/// assert_eq!(AlgoKind::Adaptive.name(), "adaptive");
/// // `all()` enumerates only the forced families (ablation sweeps);
/// // Adaptive is the selector, not a member of the sweep.
/// assert!(!AlgoKind::all().contains(&AlgoKind::Adaptive));
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AlgoKind {
    /// Put-based linear: the root (or every writer) pushes; O(n) puts.
    LinearPut,
    /// Get-based linear: readers pull after the owner publishes its buffer
    /// handle (§4.5.2 late-entry protocol).
    LinearGet,
    /// Binomial tree: log₂(n) rounds.
    Tree,
    /// Recursive doubling: log₂(n) rounds, all PEs finish with the result
    /// (power-of-two set sizes; falls back to the linear variant otherwise).
    RecursiveDoubling,
    /// Two-level socket hierarchy (NUMA-aware): socket-local reduce →
    /// cross-socket leader exchange → socket-local broadcast, with the
    /// leader staging buffers carved from the leaders' symmetric heaps
    /// ([`crate::collectives::hierarchy`]). A candidate for the adaptive
    /// selector only on multi-socket topologies; forcible everywhere
    /// (degenerates to a single linear-put-shaped group on a flat map).
    /// Deliberately not part of [`AlgoKind::all`]: the ablation sweeps
    /// force it explicitly, in its own A/B column pair.
    Hierarchical,
    /// Pick per call through the fitted cost model
    /// ([`crate::collectives::tuning::Tuning::select`]): linear-put below
    /// the latency crossover, tree/recursive-doubling above it, get-based
    /// pull where bulk parallelism wins, and the two-level hierarchical
    /// schedule where the cross-socket tier prices it cheaper. The
    /// production default.
    Adaptive,
}

impl AlgoKind {
    /// Compile-time default from cargo features; [`AlgoKind::Adaptive`]
    /// (model-driven selection) if none set.
    pub const fn default_algo() -> AlgoKind {
        #[cfg(feature = "coll-recdbl")]
        {
            return AlgoKind::RecursiveDoubling;
        }
        #[cfg(all(feature = "coll-tree", not(feature = "coll-recdbl")))]
        {
            return AlgoKind::Tree;
        }
        #[cfg(all(
            feature = "coll-linear",
            not(any(feature = "coll-tree", feature = "coll-recdbl"))
        ))]
        {
            return AlgoKind::LinearPut;
        }
        #[allow(unreachable_code)]
        AlgoKind::Adaptive
    }

    /// Parse CLI/env spellings.
    pub fn parse(s: &str) -> Option<AlgoKind> {
        match s.to_ascii_lowercase().as_str() {
            "linear" | "linear-put" | "put" => Some(AlgoKind::LinearPut),
            "linear-get" | "get" => Some(AlgoKind::LinearGet),
            "tree" | "binomial" => Some(AlgoKind::Tree),
            "recdbl" | "recursive-doubling" | "rd" => Some(AlgoKind::RecursiveDoubling),
            "hier" | "hierarchical" | "numa" => Some(AlgoKind::Hierarchical),
            "adaptive" | "auto" | "model" => Some(AlgoKind::Adaptive),
            _ => None,
        }
    }

    /// Display name (bench tables).
    pub fn name(&self) -> &'static str {
        match self {
            AlgoKind::LinearPut => "linear-put",
            AlgoKind::LinearGet => "linear-get",
            AlgoKind::Tree => "tree",
            AlgoKind::RecursiveDoubling => "recdbl",
            AlgoKind::Hierarchical => "hier",
            AlgoKind::Adaptive => "adaptive",
        }
    }

    /// All *forced* flat families (ablation sweeps). [`AlgoKind::Adaptive`]
    /// is deliberately absent (it is the selector over these), and so is
    /// [`AlgoKind::Hierarchical`] (topology-dependent; the ablation benches
    /// force it in a dedicated hier-vs-flat column pair instead).
    pub fn all() -> [AlgoKind; 4] {
        [
            AlgoKind::LinearPut,
            AlgoKind::LinearGet,
            AlgoKind::Tree,
            AlgoKind::RecursiveDoubling,
        ]
    }
}

impl crate::pe::Ctx {
    /// The *requested* algorithm for collectives on this context: config
    /// override or the compile-time default. May be [`AlgoKind::Adaptive`];
    /// collectives resolve it per call through
    /// [`coll_algo_for`](crate::pe::Ctx::coll_algo_for).
    #[inline]
    pub fn coll_algo(&self) -> AlgoKind {
        self.config().coll_algo.unwrap_or(AlgoKind::default_algo())
    }

    /// Resolve the algorithm one collective call will run: a forced kind
    /// passes through untouched; [`AlgoKind::Adaptive`] asks the world's
    /// tuning engine for the model's argmin at this `(op, team size,
    /// payload bytes)`. Never returns `Adaptive`; the resolution is
    /// recorded for [`last_coll_algo`](crate::pe::Ctx::last_coll_algo).
    ///
    /// Every PE resolves identically for the same call: forced kinds are
    /// job-wide config, and the adaptive engine's model is shared (thread
    /// mode) or published by rank 0 and adopted by every peer (process
    /// mode) — see [`crate::collectives::tuning`].
    #[inline]
    pub fn coll_algo_for(&self, op: CollOp, team_size: usize, bytes: usize) -> AlgoKind {
        let resolved = match self.coll_algo() {
            AlgoKind::Adaptive => self.tuning().select(op, team_size, bytes),
            fixed => fixed,
        };
        tuning::record_last_algo(resolved);
        resolved
    }

    /// The algorithm the most recent collective on this PE thread resolved
    /// to (`None` before the first one) — the observability hook behind the
    /// crossover tests and the ablation benches, the selection-side
    /// counterpart of [`Ctx::last_sync_rounds`](crate::pe::Ctx).
    pub fn last_coll_algo(&self) -> Option<AlgoKind> {
        tuning::last_algo()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for a in AlgoKind::all() {
            assert_eq!(AlgoKind::parse(a.name()), Some(a));
        }
        assert_eq!(AlgoKind::parse("adaptive"), Some(AlgoKind::Adaptive));
        assert_eq!(AlgoKind::parse(AlgoKind::Adaptive.name()), Some(AlgoKind::Adaptive));
        assert_eq!(AlgoKind::parse("hier"), Some(AlgoKind::Hierarchical));
        assert_eq!(
            AlgoKind::parse(AlgoKind::Hierarchical.name()),
            Some(AlgoKind::Hierarchical)
        );
        assert_eq!(AlgoKind::parse("nope"), None);
    }

    #[test]
    fn default_is_adaptive_without_features() {
        #[cfg(not(any(
            feature = "coll-linear",
            feature = "coll-tree",
            feature = "coll-recdbl"
        )))]
        assert_eq!(AlgoKind::default_algo(), AlgoKind::Adaptive);
        #[cfg(all(
            feature = "coll-linear",
            not(any(feature = "coll-tree", feature = "coll-recdbl"))
        ))]
        assert_eq!(AlgoKind::default_algo(), AlgoKind::LinearPut);
    }

    #[test]
    fn all_is_the_forced_sweep() {
        assert_eq!(AlgoKind::all().len(), 4);
        assert!(!AlgoKind::all().contains(&AlgoKind::Adaptive));
        assert!(!AlgoKind::all().contains(&AlgoKind::Hierarchical));
    }
}
