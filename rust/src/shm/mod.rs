//! Shared-memory segments (paper §4.1).
//!
//! POSH builds each PE's symmetric heap on a Boost.Interprocess
//! `managed_shared_memory`, which is itself a wrapper over POSIX `shm`.
//! Here the same substrate is written directly over `libc`:
//!
//! * [`posix::PosixShmSegment`] — a `/dev/shm` object created with
//!   `shm_open` + `ftruncate` + `mmap(MAP_SHARED)`. This is what the
//!   multi-process mode (`oshrun`) uses; any process that knows the segment
//!   *name* can map it, which is exactly the paper's "contact information"
//!   mechanism (§4.7: names are `constant basis + rank`).
//! * [`inproc::InProcSegment`] — an anonymous private mapping. Thread-mode
//!   worlds use it; unit tests and benches run on it without touching
//!   `/dev/shm`.
//!
//! Both implement [`Segment`]; everything above this module (allocator,
//! p2p engine, collectives) is generic over it.

pub mod inproc;
pub mod naming;
pub mod posix;

use crate::Result;

/// How a segment's backing pages relate to huge pages. The symmetric heap
/// is the hottest mapping in the job — every put/get walks it — so TLB
/// reach matters for the DRAM-regime copies the streaming engines target.
/// Segments *attempt* huge-page backing and report what they got; nothing
/// fails if the machine has no huge pages (`oshrun info` surfaces the
/// outcome).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HugePageStatus {
    /// The mapping was created with `MAP_HUGETLB` — pages come from the
    /// pre-reserved hugetlb pool and are guaranteed huge.
    Explicit,
    /// The mapping is ordinary but `madvise(MADV_HUGEPAGE)` succeeded —
    /// the kernel's THP machinery *may* back it with huge pages.
    Transparent,
    /// Ordinary pages only (small segment, or the kernel refused both
    /// mechanisms).
    None,
}

impl std::fmt::Display for HugePageStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            HugePageStatus::Explicit => "explicit (MAP_HUGETLB)",
            HugePageStatus::Transparent => "transparent (MADV_HUGEPAGE)",
            HugePageStatus::None => "none",
        })
    }
}

/// A mapped region of memory that other PEs may also have mapped.
///
/// # Safety-relevant contract
/// `base()..base()+len()` stays valid and constant for the lifetime of the
/// object; the memory is plain bytes with no destructor.
pub trait Segment: Send + Sync {
    /// Base address of the mapping *in this address space*.
    fn base(&self) -> *mut u8;
    /// Mapping length in bytes.
    fn len(&self) -> usize;
    /// The segment's global name, if it has one (POSIX segments do; private
    /// in-process segments do not).
    fn name(&self) -> Option<&str> {
        None
    }
    /// Huge-page backing the segment ended up with (best effort; see
    /// [`HugePageStatus`]).
    fn huge_pages(&self) -> HugePageStatus {
        HugePageStatus::None
    }
    /// Byte slice view. Unsafe because aliasing across PEs is the caller's
    /// (i.e. the SHMEM memory model's) responsibility.
    ///
    /// # Safety
    /// Caller must uphold the OpenSHMEM data-race rules: no concurrent
    /// conflicting access without an intervening synchronisation.
    unsafe fn bytes(&self) -> &[u8] {
        std::slice::from_raw_parts(self.base(), self.len())
    }
}

/// Boxed segment used by the world structures.
pub type BoxedSegment = Box<dyn Segment>;

/// Create the segment kind appropriate for an execution mode.
pub fn create_inproc(len: usize) -> Result<BoxedSegment> {
    Ok(Box::new(inproc::InProcSegment::new(len)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boxed_segment_trait_object() {
        let seg = create_inproc(4096).unwrap();
        assert_eq!(seg.len(), 4096);
        assert!(!seg.base().is_null());
        assert!(seg.name().is_none());
        assert_eq!(seg.huge_pages(), HugePageStatus::None);
    }
}
