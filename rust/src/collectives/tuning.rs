//! Cost-model-driven adaptive collective selection.
//!
//! The paper fixes collective algorithms at compile time (§4.5.4) and,
//! separately, derives the Hockney model `T(n) = α + n/β` for its
//! shared-memory channel (§5) — but never closes the loop between the two.
//! This module is that loop: it composes the fitted point-to-point model
//! into **per-algorithm collective cost models** and picks, per
//! `(operation, payload size, team size)`, the algorithm the model predicts
//! fastest. `AlgoKind::Adaptive` (the default since this landed) routes
//! every collective through [`Tuning::select`]; the fixed families survive
//! untouched as forced overrides (`POSH_COLL_ALGO`, `PoshConfig::coll_algo`,
//! the `coll-*` cargo features) so every Ablation-A A/B comparison stays
//! reproducible.
//!
//! **Where the model comes from**, in priority order:
//!
//! 1. `POSH_ALPHA_NS` + `POSH_BETA_GBPS` (or `PoshConfig::cost_model`) —
//!    postulated constants, no measurement;
//! 2. a fast α/β micro-calibration over the shm channel
//!    ([`calibrate_piecewise`] — on a shared-memory node a put *is* a copy
//!    by the origin core, so timing the size-aware copy dispatch over a
//!    size sweep *is* measuring the channel), run once per process. The
//!    calibration fits one α/β **per size regime** (L1/L2/LLC/DRAM buckets,
//!    boundaries from [`CacheInfo::detect`]) plus the pooled whole-sweep
//!    fit; [`Tuning::select`] prices candidates with the bucket that
//!    governs the payload ([`Tuning::coll_model_at`]), so an L1-regime flag
//!    exchange and a DRAM-regime broadcast can argmin to different
//!    algorithms;
//! 3. if the calibration fit is degenerate
//!    ([`crate::model::CostModel::is_degenerate`]) or too noisy, the
//!    paper's postulated constants ([`POSTULATED_ALPHA_NS`] /
//!    [`POSTULATED_BETA_GBPS`]) with a warning.
//!
//! **Job-wide agreement.** Every PE of a job must make the *same* decision
//! for the same collective call, or the protocols deadlock (one PE pushing
//! put-based while its peer spins in the get-based rendezvous). In thread
//! mode all PEs share this process's engine; in process mode rank 0
//! publishes its fitted α/β through its heap header at world attach and
//! every other rank adopts the published model (`pe::world`).
//!
//! The same fitted model also prices the NBI drain-time coalescing of
//! `p2p::nbi`: merging two queued puts saves one per-call latency α and
//! costs one extra staging copy `s/β`, so coalescing pays while the merged
//! run stays under `n₁/₂ = α·β` bytes ([`Tuning::coalesce_threshold_bytes`]).
//!
//! The cost formulas are deliberately simple compositions of `m(s) = α +
//! s/β` (one message) and `α` (one signal/handshake); they are documented
//! per algorithm on [`Tuning::coll_model`] and, with worked examples, in
//! `docs/tuning.md`.

use super::algorithm::AlgoKind;
use crate::mem::plan::CacheInfo;
use crate::model::piecewise::{PiecewiseModel, RangeModel};
use crate::model::CostModel;
use crate::pe::TeamBarrierKind;
use crate::sync::barrier::ceil_log2;
use std::cell::Cell;
use std::sync::OnceLock;

/// Which collective operation a selection is for (the tuning-engine face of
/// the §4.5.1 `CollOpTag`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollOp {
    /// Team barrier / sync (selection is over [`TeamBarrierKind`], not
    /// [`AlgoKind`] — see [`Tuning::select_barrier`]).
    Barrier,
    /// Broadcast from a root.
    Broadcast,
    /// All-reduce (every member receives the reduction).
    Reduce,
    /// Fixed-size concatenation (`fcollect`).
    Fcollect,
    /// Variable-size concatenation (`collect`).
    Collect,
    /// All-to-all block exchange.
    Alltoall,
}

impl CollOp {
    /// Display name (bench tables, `oshrun calibrate`).
    pub fn name(&self) -> &'static str {
        match self {
            CollOp::Barrier => "barrier",
            CollOp::Broadcast => "broadcast",
            CollOp::Reduce => "reduce",
            CollOp::Fcollect => "fcollect",
            CollOp::Collect => "collect",
            CollOp::Alltoall => "alltoall",
        }
    }
}

/// Where the engine's model came from (reported by `oshrun calibrate`; in
/// process mode rank 0 publishes its source alongside the model and every
/// rank adopts both, so the provenance is job-wide too).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuningSource {
    /// Fitted by the per-process micro-calibration.
    Calibrated,
    /// Postulated from `POSH_ALPHA_NS`/`POSH_BETA_GBPS` or
    /// `PoshConfig::cost_model`.
    Postulated,
    /// Calibration was degenerate/noisy; the paper's constants were used.
    Fallback,
}

impl TuningSource {
    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            TuningSource::Calibrated => "calibrated",
            TuningSource::Postulated => "postulated",
            TuningSource::Fallback => "fallback",
        }
    }

    /// Wire encoding for the heap-header publication (0 = not published).
    pub(crate) fn to_wire(self) -> u64 {
        match self {
            TuningSource::Calibrated => 1,
            TuningSource::Postulated => 2,
            TuningSource::Fallback => 3,
        }
    }

    /// Decode the wire encoding; unknown values read as `Fallback`.
    pub(crate) fn from_wire(v: u64) -> TuningSource {
        match v {
            1 => TuningSource::Calibrated,
            2 => TuningSource::Postulated,
            _ => TuningSource::Fallback,
        }
    }
}

/// The paper's postulated α (ns): the put latency of its fastest machine
/// ("Maximum", Table 2) — the fallback when calibration cannot be trusted.
pub const POSTULATED_ALPHA_NS: f64 = 38.4;

/// The paper's postulated asymptotic bandwidth (Gb/s): the put bandwidth of
/// "Maximum" (Table 2).
pub const POSTULATED_BETA_GBPS: f64 = 76.15;

/// R² below which a calibration fit is treated as too noisy to trust and
/// the engine falls back to the postulated constants.
pub const MIN_CALIBRATION_R2: f64 = 0.5;

/// The adaptive selection engine: a point-to-point cost model plus the
/// per-algorithm composition rules.
///
/// ```
/// use posh::collectives::{AlgoKind, CollOp, Tuning};
/// // A postulated channel: 100 ns latency, 80 Gb/s (10 B/ns).
/// let t = Tuning::postulated(100.0, 80.0);
/// // 2-member broadcast: one push is unbeatable at any size.
/// assert_eq!(t.select(CollOp::Broadcast, 2, 8), AlgoKind::LinearPut);
/// // 8-member broadcast: linear-put below the latency crossover,
/// // binomial tree in the middle …
/// assert_eq!(t.select(CollOp::Broadcast, 8, 64), AlgoKind::LinearPut);
/// assert_eq!(t.select(CollOp::Broadcast, 8, 300), AlgoKind::Tree);
/// // … and get-based pull parallelism once payloads are large.
/// assert_eq!(t.select(CollOp::Broadcast, 8, 1 << 20), AlgoKind::LinearGet);
/// // The decision is exactly the model's argmin:
/// let (n, s) = (8, 4096);
/// let best = Tuning::candidates(CollOp::Broadcast, n)
///     .iter()
///     .copied()
///     .min_by(|&a, &b| {
///         t.coll_model(CollOp::Broadcast, a, n)
///             .predict_ns(s)
///             .total_cmp(&t.coll_model(CollOp::Broadcast, b, n).predict_ns(s))
///     })
///     .unwrap();
/// assert_eq!(t.select(CollOp::Broadcast, n, s), best);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct Tuning {
    model: CostModel,
    pw: PiecewiseModel,
    source: TuningSource,
}

impl Tuning {
    /// Build an engine from a single explicit model: every size regime is
    /// priced by the same α/β (the piecewise view is
    /// [`PiecewiseModel::uniform`]).
    pub fn new(model: CostModel, source: TuningSource) -> Tuning {
        Tuning {
            model,
            pw: PiecewiseModel::uniform(model),
            source,
        }
    }

    /// Build an engine from a per-range calibration: `model` is the
    /// whole-sweep affine fit (display, the coalescing `n₁/₂`, legacy wire
    /// adopters), `pw` the per-regime fits that [`Tuning::select`] prices
    /// with.
    pub fn new_piecewise(model: CostModel, pw: PiecewiseModel, source: TuningSource) -> Tuning {
        Tuning { model, pw, source }
    }

    /// Convenience: an engine postulated from α (ns) and bandwidth (Gb/s) —
    /// what `POSH_ALPHA_NS`/`POSH_BETA_GBPS` construct.
    pub fn postulated(alpha_ns: f64, gbps: f64) -> Tuning {
        Tuning::new(CostModel::from_alpha_gbps(alpha_ns, gbps), TuningSource::Postulated)
    }

    /// The whole-sweep point-to-point model.
    pub fn model(&self) -> &CostModel {
        &self.model
    }

    /// The per-size-regime channel model.
    pub fn piecewise(&self) -> &PiecewiseModel {
        &self.pw
    }

    /// The α/β governing a `bytes`-sized payload (the regime bucket's fit).
    pub fn model_for(&self, bytes: usize) -> &CostModel {
        self.pw.model_for(bytes)
    }

    /// Where the model came from.
    pub fn source(&self) -> TuningSource {
        self.source
    }

    /// The algorithm families actually implemented for `op` on a team of
    /// `team_size` (recursive doubling only exists for power-of-two reduce
    /// teams; `collect`/`alltoall` have a single protocol). Order is the
    /// tie-break order of [`Tuning::select`].
    pub fn candidates(op: CollOp, team_size: usize) -> &'static [AlgoKind] {
        use AlgoKind::*;
        match op {
            CollOp::Broadcast => &[LinearPut, Tree, LinearGet],
            CollOp::Reduce => {
                if team_size.is_power_of_two() {
                    &[LinearPut, LinearGet, Tree, RecursiveDoubling]
                } else {
                    &[LinearPut, LinearGet, Tree]
                }
            }
            CollOp::Fcollect => &[LinearPut, LinearGet],
            CollOp::Barrier | CollOp::Collect | CollOp::Alltoall => &[LinearPut],
        }
    }

    /// The composed cost model of running `op` with `algo` on a team of
    /// `team_size`: an affine `T(s) = base + s·slope` returned as a
    /// [`CostModel`] so [`CostModel::predict_ns`] and
    /// [`CostModel::crossover_bytes`] apply directly.
    ///
    /// Writing `m(s) = α + s/β` for one message and `α` for one
    /// signal/handshake, with `n` members and ⌈log₂ n⌉ = `L`:
    ///
    /// | op | algorithm | cost |
    /// |---|---|---|
    /// | broadcast | linear-put | `(n−1)·m(s) + α` — root pushes serially, one fence+signal sweep |
    /// | broadcast | tree | `L·(m(s) + 2α)` — per hop: entry wait, copy, signal |
    /// | broadcast | linear-get | `3α + s/β + (n−1)·α` — publish/observe handshake, pulls in parallel, serialized completion signals |
    /// | reduce | linear-put | `n·m(s) + (n−1)·s/β + 2α` — parallel deposits, root combines and fans out serially |
    /// | reduce | linear-get | `(n−1)·(α + 2s/β) + α` — all-read-all: every PE pulls+combines n−1 contributions, concurrently |
    /// | reduce | tree | `L·(m(s) + s/β + 2α) + (n−1)·m(s) + α` — binomial fan-in with combines, linear fan-out |
    /// | reduce | recdbl | `L·(m(s) + s/β + 2α)` — pairwise exchange rounds (power-of-two teams) |
    /// | fcollect | linear-put | `(n−1)·m(s) + α` — all-push-all, concurrent across PEs |
    /// | fcollect | linear-get | `(n−1)·m(s) + 3α` — same traffic plus the publish handshake |
    /// | collect | linear-put | `(n−1)·m(s) + n·α` — the size exchange costs one signal per member |
    /// | alltoall | linear-put | `(n−1)·m(s) + α` |
    /// | barrier | (see [`Tuning::select_barrier`]) | dissemination `L·2α` vs linear fan-in `2(n−1)·α` |
    pub fn coll_model(&self, op: CollOp, algo: AlgoKind, team_size: usize) -> CostModel {
        self.compose(&self.model, op, algo, team_size, 0)
    }

    /// [`Tuning::coll_model`] priced with the regime that governs a
    /// `bytes`-sized payload ([`Tuning::model_for`]): the per-range α/β is
    /// substituted as the point-to-point base model, so an L1-resident flag
    /// exchange and a DRAM-streaming broadcast compose different costs —
    /// and can argmin to different algorithms.
    pub fn coll_model_at(
        &self,
        op: CollOp,
        algo: AlgoKind,
        team_size: usize,
        bytes: usize,
    ) -> CostModel {
        self.compose(self.pw.model_for(bytes), op, algo, team_size, bytes)
    }

    /// The shared composition: `base` is the point-to-point model to build
    /// on (whole-sweep or one regime's fit), `bytes` only feeds the
    /// `Adaptive` re-selection arm.
    fn compose(
        &self,
        base: &CostModel,
        op: CollOp,
        algo: AlgoKind,
        team_size: usize,
        bytes: usize,
    ) -> CostModel {
        let a = base.alpha_ns;
        // ns per byte of one copy; 0 when the base model is degenerate
        // (β = ∞) so the composition degrades to pure latency comparison.
        let c = if base.beta_bytes_per_ns.is_finite() {
            1.0 / base.beta_bytes_per_ns
        } else {
            0.0
        };
        let r2 = base.r2;
        let n1 = team_size.saturating_sub(1) as f64;
        let n = team_size as f64;
        let l = ceil_log2(team_size.max(1)) as f64;
        let (base, slope) = match (op, algo) {
            // `Adaptive` is a selector, not a schedule; its "model" is the
            // argmin's at this payload (select never returns Adaptive, so
            // this cannot recurse).
            (_, AlgoKind::Adaptive) => {
                return self.compose(
                    base,
                    op,
                    self.select(op, team_size, bytes),
                    team_size,
                    bytes,
                );
            }
            (CollOp::Broadcast, AlgoKind::LinearPut) => (n1 * a + a, n1 * c),
            (CollOp::Broadcast, AlgoKind::Tree | AlgoKind::RecursiveDoubling) => {
                (l * 3.0 * a, l * c)
            }
            (CollOp::Broadcast, AlgoKind::LinearGet) => (3.0 * a + n1 * a, c),
            (CollOp::Reduce, AlgoKind::LinearPut) => (n * a + 2.0 * a, n * c + n1 * c),
            (CollOp::Reduce, AlgoKind::LinearGet) => (n1 * a + a, n1 * 2.0 * c),
            (CollOp::Reduce, AlgoKind::Tree) => {
                (l * 3.0 * a + n1 * a + a, l * 2.0 * c + n1 * c)
            }
            (CollOp::Reduce, AlgoKind::RecursiveDoubling) => (l * 3.0 * a, l * 2.0 * c),
            (CollOp::Fcollect, AlgoKind::LinearGet) => (n1 * a + 3.0 * a, n1 * c),
            (CollOp::Collect, _) => (n1 * a + n * a, n1 * c),
            // Everything else runs the put-based all-push/linear protocol.
            (CollOp::Fcollect | CollOp::Alltoall | CollOp::Barrier, _) => (n1 * a + a, n1 * c),
        };
        CostModel {
            alpha_ns: base,
            beta_bytes_per_ns: if slope > 0.0 { 1.0 / slope } else { f64::INFINITY },
            r2,
        }
    }

    /// Pick the algorithm the model predicts fastest for `op` moving
    /// `bytes` per member over a team of `team_size` — the argmin of
    /// [`Tuning::coll_model_at`] over [`Tuning::candidates`], ties broken by
    /// candidate order. Never returns [`AlgoKind::Adaptive`].
    ///
    /// Pricing goes through the piecewise model: the regime bucket of
    /// `bytes` supplies the α/β the candidates are composed from, so the
    /// same operation can resolve differently in the L1 and DRAM regimes.
    /// (With a single-model engine every bucket is identical and this is
    /// exactly the classic whole-sweep argmin.)
    pub fn select(&self, op: CollOp, team_size: usize, bytes: usize) -> AlgoKind {
        let cands = Self::candidates(op, team_size);
        let mut best = cands[0];
        if team_size <= 1 {
            return best; // degenerate team: nothing to schedule
        }
        let mut best_ns = self.coll_model_at(op, best, team_size, bytes).predict_ns(bytes);
        for &c in &cands[1..] {
            let ns = self.coll_model_at(op, c, team_size, bytes).predict_ns(bytes);
            if ns < best_ns {
                best = c;
                best_ns = ns;
            }
        }
        best
    }

    /// Pick the team-sync engine for a team of `team_size`: dissemination
    /// (`⌈log₂ n⌉·2α`) vs the linear fan-in baseline (`2(n−1)·α`), ties
    /// (n = 2, where both are one round) broken toward dissemination so the
    /// adaptive default matches the pre-adaptive production engine exactly.
    pub fn select_barrier(&self, team_size: usize) -> TeamBarrierKind {
        let a = self.model.alpha_ns;
        let dissem = ceil_log2(team_size.max(1)) as f64 * 2.0 * a;
        let linear = 2.0 * team_size.saturating_sub(1) as f64 * a;
        if dissem <= linear {
            TeamBarrierKind::Dissemination
        } else {
            TeamBarrierKind::LinearFanin
        }
    }

    /// The payload size at which `b` overtakes `a` for `op` on a team of
    /// `team_size`, if the composed models cross (`None` when one dominates
    /// everywhere). This is the threshold [`Tuning::select`]'s argmin
    /// realises.
    pub fn crossover_bytes(
        &self,
        op: CollOp,
        a: AlgoKind,
        b: AlgoKind,
        team_size: usize,
    ) -> Option<f64> {
        self.coll_model(op, b, team_size)
            .crossover_bytes(&self.coll_model(op, a, team_size))
    }

    /// Maximum size (bytes) of a coalesced run of adjacent deferred NBI
    /// puts: merging saves one per-call latency α and costs one extra
    /// staging copy `s/β`, so it pays while the run stays under
    /// `n₁/₂ = α·β` — clamped to `[64, NBI_DEFER_MAX_BYTES]` so pathological
    /// models still coalesce flag-sized puts and never pin unbounded runs.
    pub fn coalesce_threshold_bytes(&self) -> usize {
        let n_half = self.model.n_half();
        let cap = crate::p2p::nbi::nbi_defer_max_bytes();
        if !n_half.is_finite() {
            return cap;
        }
        (n_half as usize).clamp(64, cap)
    }
}

impl std::fmt::Display for Tuning {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} [{}]", self.model, self.source.name())
    }
}

/// Micro-calibrate the shm channel: time the configured copy engine
/// (`mem::copy`) over a latency-to-bandwidth size sweep and fit
/// `T(n) = α + n/β`. On a shared-memory node the origin core performs
/// every put/get as a copy (paper §5), so this *is* the channel model.
/// Each size takes the minimum over a few batched repetitions — minima are
/// robust against scheduler preemption, the failure mode of a loaded CI
/// box. Budget: ~1–2 ms.
pub fn calibrate() -> CostModel {
    const SIZES: [usize; 6] = [64, 512, 4096, 32 << 10, 256 << 10, 1 << 20];
    const REPS: usize = 5;
    let max = *SIZES.last().unwrap();
    let src = vec![0x5Au8; max];
    let mut dst = vec![0u8; max];
    let mut samples = Vec::with_capacity(SIZES.len());
    for &s in &SIZES {
        samples.push((s, time_copy_ns(&mut dst, &src, s, REPS)));
    }
    CostModel::fit(&samples)
}

/// Time one `s`-byte copy through the engine planned dispatch resolves for
/// that size (or the forced engine when one is configured): minimum over
/// `reps` batched repetitions, in ns per copy. Minima are robust against
/// scheduler preemption; rep 0 is the warm-up (page faults, cache
/// training). The batch keeps one repetition ≥ ~10 µs so the clock read
/// amortises.
fn time_copy_ns(dst: &mut [u8], src: &[u8], s: usize, reps: usize) -> f64 {
    let imp = crate::mem::copy::engine_for(s);
    let batch = ((128 << 10) / s.max(1)).clamp(1, 4096);
    let mut best = f64::MAX;
    for rep in 0..=reps {
        let t0 = std::time::Instant::now();
        for _ in 0..batch {
            crate::mem::copy::copy_slice_with(imp, &mut dst[..s], &src[..s]);
            std::hint::black_box(&dst);
        }
        let ns = t0.elapsed().as_nanos() as f64 / batch as f64;
        if rep > 0 {
            best = best.min(ns);
        }
    }
    best
}

/// Cap on any single calibration copy: keeps the startup budget bounded on
/// machines with very large LLCs (where the DRAM regime would otherwise ask
/// for hundreds-of-MiB buffers). Ranges whose sample sizes all fall outside
/// their bucket after capping simply reuse the whole-sweep fit.
const MAX_CAL_BYTES: usize = 32 << 20;

/// Extend [`calibrate`] into a per-range fit: one α/β per L1/L2/LLC/DRAM
/// bucket (boundaries from [`CacheInfo::detect`]), each fitted from 2–4
/// samples inside its bucket, measured through the same size-aware copy
/// dispatch the data path uses. Returns the whole-sweep fit (all samples
/// pooled — the legacy single-model view) plus the piecewise model.
///
/// Robustness rules, per range: fewer than two in-bucket samples (the
/// bucket collapsed under [`MAX_CAL_BYTES`] capping or an exotic topology)
/// or a degenerate in-bucket fit ⇒ that range reuses the whole-sweep fit.
/// Budget: ~10–40 ms once per process, dominated by the DRAM samples.
pub fn calibrate_piecewise() -> (CostModel, PiecewiseModel) {
    const REPS: usize = 3;
    let cache = CacheInfo::detect();
    let bounds = PiecewiseModel::bounds(&cache);
    // Candidate sizes per bucket: log-ish spacing anchored at the bucket
    // edges, clamped to (lo, hi] ∩ [64, MAX_CAL_BYTES].
    let lo_of = |i: usize| if i == 0 { 0 } else { bounds[i - 1] };
    let mut range_sizes: [Vec<usize>; 4] = Default::default();
    for (i, sizes) in range_sizes.iter_mut().enumerate() {
        let lo = lo_of(i);
        let hi = bounds[i];
        let cands: [usize; 6] = if hi == usize::MAX {
            [lo.saturating_mul(2), lo.saturating_mul(4), 0, 0, 0, 0]
        } else {
            [64, lo.saturating_mul(2), hi / 4, hi / 2, hi, hi.min(MAX_CAL_BYTES)]
        };
        for s in cands {
            if s > lo && s <= hi && s >= 64 && s <= MAX_CAL_BYTES && !sizes.contains(&s) {
                sizes.push(s);
            }
        }
        sizes.sort_unstable();
    }
    let max = range_sizes
        .iter()
        .flatten()
        .copied()
        .max()
        .unwrap_or(1 << 20);
    let src = vec![0x5Au8; max];
    let mut dst = vec![0u8; max];
    let mut all = Vec::new();
    let mut per_range: [Vec<(usize, f64)>; 4] = Default::default();
    for (i, sizes) in range_sizes.iter().enumerate() {
        for &s in sizes {
            let t = time_copy_ns(&mut dst, &src, s, REPS);
            all.push((s, t));
            per_range[i].push((s, t));
        }
    }
    let whole = CostModel::fit(&all);
    let model_of = |i: usize| -> CostModel {
        let rs = &per_range[i];
        if rs.len() >= 2 {
            let fit = CostModel::fit(rs);
            if !fit.is_degenerate() {
                return fit;
            }
        }
        whole
    };
    let pw = PiecewiseModel {
        ranges: [
            RangeModel { hi: bounds[0], model: model_of(0) },
            RangeModel { hi: bounds[1], model: model_of(1) },
            RangeModel { hi: bounds[2], model: model_of(2) },
            RangeModel { hi: bounds[3], model: model_of(3) },
        ],
    };
    (whole, pw)
}

/// The model `POSH_ALPHA_NS`/`POSH_BETA_GBPS` postulate, when both are set
/// and sane.
pub fn env_model() -> Option<CostModel> {
    let a = std::env::var("POSH_ALPHA_NS").ok()?.trim().parse::<f64>().ok()?;
    let b = std::env::var("POSH_BETA_GBPS").ok()?.trim().parse::<f64>().ok()?;
    (a >= 0.0 && a.is_finite() && b > 0.0 && b.is_finite())
        .then(|| CostModel::from_alpha_gbps(a, b))
}

static ENGINE: OnceLock<Tuning> = OnceLock::new();

/// This process's tuning engine, resolved once: env postulation, else
/// calibration, else (degenerate/noisy fit) the paper's constants with a
/// warning. Thread-mode worlds share it; process-mode worlds start from it
/// on rank 0 and publish it to the job (`pe::world`).
pub fn process_engine() -> &'static Tuning {
    ENGINE.get_or_init(|| {
        if let Some(cm) = env_model() {
            return Tuning::new(cm, TuningSource::Postulated);
        }
        let (fit, pw) = calibrate_piecewise();
        if fit.is_degenerate() || fit.r2 < MIN_CALIBRATION_R2 {
            eprintln!(
                "posh: shm-channel calibration unusable ({fit}); falling back to the \
                 paper's postulated constants (α = {POSTULATED_ALPHA_NS} ns, \
                 β = {POSTULATED_BETA_GBPS} Gb/s) — set POSH_ALPHA_NS/POSH_BETA_GBPS \
                 to postulate your own"
            );
            Tuning::new(
                CostModel::from_alpha_gbps(POSTULATED_ALPHA_NS, POSTULATED_BETA_GBPS),
                TuningSource::Fallback,
            )
        } else {
            Tuning::new_piecewise(fit, pw, TuningSource::Calibrated)
        }
    })
}

thread_local! {
    /// The algorithm resolved by this PE thread's most recent routed
    /// collective — the observability hook behind `Ctx::last_coll_algo`.
    static LAST_ALGO: Cell<Option<AlgoKind>> = const { Cell::new(None) };
}

/// Record the resolved algorithm of the routing decision that just ran.
pub(crate) fn record_last_algo(algo: AlgoKind) {
    LAST_ALGO.with(|c| c.set(Some(algo)));
}

/// The algorithm the most recent routed collective on this thread resolved
/// to (`None` before the first one). See `Ctx::last_coll_algo`.
pub(crate) fn last_algo() -> Option<AlgoKind> {
    LAST_ALGO.with(|c| c.get())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Independent argmin oracle: recompute the costs by hand from
    /// `coll_model` and check `select` agrees — at sizes bracketing every
    /// pairwise crossover, where a thresholding bug would flip the choice.
    #[test]
    fn select_is_model_argmin_around_every_crossover() {
        let t = Tuning::postulated(100.0, 80.0);
        for op in [CollOp::Broadcast, CollOp::Reduce, CollOp::Fcollect] {
            for n in [2usize, 3, 4, 5, 8, 16, 64] {
                let cands = Tuning::candidates(op, n);
                let mut probe_sizes = vec![0usize, 1, 64, 4096, 1 << 20, 64 << 20];
                for (i, &a) in cands.iter().enumerate() {
                    for &b in &cands[i + 1..] {
                        if let Some(x) = t.crossover_bytes(op, a, b, n) {
                            let x = x.max(2.0) as usize;
                            probe_sizes.push(x / 2);
                            probe_sizes.push(x * 2);
                        }
                    }
                }
                for &s in &probe_sizes {
                    let oracle = cands
                        .iter()
                        .copied()
                        .min_by(|&x, &y| {
                            t.coll_model(op, x, n)
                                .predict_ns(s)
                                .total_cmp(&t.coll_model(op, y, n).predict_ns(s))
                        })
                        .unwrap();
                    let chosen = t.select(op, n, s);
                    let chosen_ns = t.coll_model(op, chosen, n).predict_ns(s);
                    let oracle_ns = t.coll_model(op, oracle, n).predict_ns(s);
                    assert!(
                        chosen_ns <= oracle_ns,
                        "{op:?} n={n} s={s}: select={chosen:?} ({chosen_ns}) \
                         vs argmin={oracle:?} ({oracle_ns})"
                    );
                }
            }
        }
    }

    /// The qualitative regimes the issue names: put below the latency
    /// crossover, tree above it, get-based pull for large broadcasts.
    #[test]
    fn broadcast_regimes_match_the_paper_narrative() {
        let t = Tuning::postulated(100.0, 80.0);
        // Two members: one push, unbeatable.
        for s in [8usize, 1 << 20] {
            assert_eq!(t.select(CollOp::Broadcast, 2, s), AlgoKind::LinearPut);
        }
        // Eight members: put for tiny payloads, tree in the middle,
        // get-based pull parallelism for bulk.
        assert_eq!(t.select(CollOp::Broadcast, 8, 8), AlgoKind::LinearPut);
        let x_put_tree = t
            .crossover_bytes(CollOp::Broadcast, AlgoKind::LinearPut, AlgoKind::Tree, 8)
            .expect("put/tree must cross at n=8");
        let x_tree_get = t
            .crossover_bytes(CollOp::Broadcast, AlgoKind::Tree, AlgoKind::LinearGet, 8)
            .expect("tree/get must cross at n=8");
        assert!(x_put_tree < x_tree_get, "{x_put_tree} !< {x_tree_get}");
        let mid = ((x_put_tree + x_tree_get) / 2.0) as usize;
        assert_eq!(t.select(CollOp::Broadcast, 8, mid), AlgoKind::Tree);
        assert_eq!(
            t.select(CollOp::Broadcast, 8, (x_tree_get * 4.0) as usize),
            AlgoKind::LinearGet
        );
    }

    #[test]
    fn reduce_prefers_recdbl_on_large_pow2_teams() {
        let t = Tuning::postulated(100.0, 80.0);
        assert_eq!(
            t.select(CollOp::Reduce, 8, 64 << 10),
            AlgoKind::RecursiveDoubling
        );
        // Non-power-of-two: recdbl is not even a candidate.
        assert!(!Tuning::candidates(CollOp::Reduce, 6).contains(&AlgoKind::RecursiveDoubling));
        for s in [8usize, 1 << 20] {
            let a = t.select(CollOp::Reduce, 6, s);
            assert_ne!(a, AlgoKind::RecursiveDoubling);
            assert_ne!(a, AlgoKind::Adaptive);
        }
    }

    #[test]
    fn single_protocol_ops_always_linear_put() {
        let t = Tuning::postulated(50.0, 20.0);
        for n in [1usize, 2, 7, 32] {
            for s in [0usize, 1 << 16] {
                assert_eq!(t.select(CollOp::Alltoall, n, s), AlgoKind::LinearPut);
                assert_eq!(t.select(CollOp::Collect, n, s), AlgoKind::LinearPut);
            }
        }
    }

    #[test]
    fn barrier_selection_is_dissemination() {
        // ⌈log₂ n⌉ ≤ n−1 for all n ≥ 2 (equality at 2, broken toward
        // dissemination): the adaptive default must equal the pre-adaptive
        // production engine on every team size.
        let t = Tuning::postulated(100.0, 80.0);
        for n in [1usize, 2, 3, 8, 1000] {
            assert_eq!(t.select_barrier(n), TeamBarrierKind::Dissemination);
        }
    }

    #[test]
    fn coalesce_threshold_is_n_half_clamped() {
        // α = 100 ns, β = 10 B/ns ⇒ n₁/₂ = 1000 B.
        let t = Tuning::postulated(100.0, 80.0);
        assert_eq!(t.coalesce_threshold_bytes(), 1000);
        // Tiny α: clamped up to the 64-byte floor.
        assert_eq!(Tuning::postulated(0.1, 80.0).coalesce_threshold_bytes(), 64);
        // Huge α: clamped at the defer cap.
        assert_eq!(
            Tuning::postulated(1e9, 80.0).coalesce_threshold_bytes(),
            crate::p2p::nbi::NBI_DEFER_MAX_BYTES
        );
        // Degenerate model (β = ∞): cap, never a panic.
        let degenerate = Tuning::new(
            CostModel::fit(&[(8, 100.0), (1024, 10.0)]),
            TuningSource::Calibrated,
        );
        assert_eq!(
            degenerate.coalesce_threshold_bytes(),
            crate::p2p::nbi::NBI_DEFER_MAX_BYTES
        );
    }

    #[test]
    fn degenerate_model_still_selects_something_sane() {
        let degenerate = Tuning::new(
            CostModel::fit(&[(8, 100.0), (1024, 10.0)]),
            TuningSource::Calibrated,
        );
        for op in [CollOp::Broadcast, CollOp::Reduce, CollOp::Fcollect] {
            for n in [2usize, 8] {
                let a = degenerate.select(op, n, 4096);
                assert_ne!(a, AlgoKind::Adaptive);
                let ns = degenerate.coll_model(op, a, n).predict_ns(4096);
                assert!(ns.is_finite(), "{op:?} n={n}: {ns}");
            }
        }
    }

    #[test]
    fn calibration_on_this_host_is_usable_or_detectably_not() {
        // Whatever this box produces, the engine contract holds: either the
        // fit is healthy, or it is *flagged* (which is the whole point of
        // the degenerate-fit fix).
        let m = calibrate();
        if !m.is_degenerate() {
            assert!(m.alpha_ns >= 0.0);
            assert!(m.beta_bytes_per_ns > 0.0);
        }
        // The process engine never hands out a degenerate model.
        let e = process_engine();
        assert!(!e.model().is_degenerate(), "{e}");
    }

    /// End to end: a live adaptive world resolves exactly what the engine
    /// predicts, observable through `Ctx::last_coll_algo`, at payload sizes
    /// bracketing the broadcast crossovers.
    #[test]
    fn live_world_records_the_model_argmin() {
        use crate::pe::{PoshConfig, World};
        let mut cfg = PoshConfig::small();
        cfg.coll_algo = Some(AlgoKind::Adaptive);
        cfg.cost_model = Some(CostModel::from_alpha_gbps(100.0, 80.0));
        let n = 8;
        let w = World::threads(n, cfg).unwrap();
        w.run(|ctx| {
            let t = *ctx.tuning();
            let team = ctx.team_world();
            let x1 = t
                .crossover_bytes(CollOp::Broadcast, AlgoKind::LinearPut, AlgoKind::Tree, n)
                .unwrap();
            let x2 = t
                .crossover_bytes(CollOp::Broadcast, AlgoKind::Tree, AlgoKind::LinearGet, n)
                .unwrap();
            // Probe below, between, and above the two thresholds (u64
            // payloads, so nelems = bytes / 8).
            for bytes in [
                (x1 / 2.0) as usize,
                ((x1 + x2) / 2.0) as usize,
                (x2 * 2.0) as usize,
            ] {
                let nelems = (bytes / 8).max(1);
                let src = ctx.shmalloc_n::<u64>(nelems).unwrap();
                let dst = ctx.shmalloc_n::<u64>(nelems).unwrap();
                ctx.broadcast(dst, src, nelems, 0, &team);
                let want = t.select(CollOp::Broadcast, n, nelems * 8);
                assert_eq!(
                    ctx.last_coll_algo(),
                    Some(want),
                    "adaptive world must run the model argmin at {bytes} B"
                );
                ctx.barrier_all();
                ctx.shfree(dst).unwrap();
                ctx.shfree(src).unwrap();
            }
        });
    }

    /// The PR's acceptance bar: with a piecewise engine, an L1-regime
    /// payload and a DRAM-regime payload resolve to *different* α/β and can
    /// argmin to *different* algorithms for the same (op, team size).
    #[test]
    fn piecewise_regimes_argmin_differently() {
        // L1 bucket: huge per-message latency, fat pipe ⇒ minimise message
        // count ⇒ LinearPut. DRAM bucket: negligible latency, thin pipe ⇒
        // minimise serialized bytes ⇒ LinearGet (slope c vs n1·c).
        let l1 = CostModel {
            alpha_ns: 1000.0,
            beta_bytes_per_ns: 100.0,
            r2: 1.0,
        };
        let dram = CostModel {
            alpha_ns: 10.0,
            beta_bytes_per_ns: 0.1,
            r2: 1.0,
        };
        let pw = PiecewiseModel {
            ranges: [
                RangeModel { hi: 32 << 10, model: l1 },
                RangeModel { hi: 256 << 10, model: l1 },
                RangeModel { hi: 8 << 20, model: l1 },
                RangeModel { hi: usize::MAX, model: dram },
            ],
        };
        let whole = CostModel::fit(&[(64, 1000.0), (64 << 20, 1e9)]);
        let t = Tuning::new_piecewise(whole, pw, TuningSource::Calibrated);

        // The regimes resolve different base models…
        assert_eq!(t.model_for(8).alpha_ns, 1000.0);
        assert_eq!(t.model_for(64 << 20).alpha_ns, 10.0);
        assert_ne!(
            t.model_for(8).beta_bytes_per_ns,
            t.model_for(64 << 20).beta_bytes_per_ns
        );

        // …and the same (op, team) argmins differently per regime.
        let n = 8;
        assert_eq!(t.select(CollOp::Broadcast, n, 8), AlgoKind::LinearPut);
        assert_eq!(t.select(CollOp::Broadcast, n, 64 << 20), AlgoKind::LinearGet);

        // Each decision is the argmin of the governing bucket's composition.
        for bytes in [8usize, 64 << 20] {
            let cands = Tuning::candidates(CollOp::Broadcast, n);
            let oracle = cands
                .iter()
                .copied()
                .min_by(|&x, &y| {
                    t.coll_model_at(CollOp::Broadcast, x, n, bytes)
                        .predict_ns(bytes)
                        .total_cmp(
                            &t.coll_model_at(CollOp::Broadcast, y, n, bytes).predict_ns(bytes),
                        )
                })
                .unwrap();
            assert_eq!(t.select(CollOp::Broadcast, n, bytes), oracle, "bytes={bytes}");
        }
    }

    /// A single-model engine prices every bucket identically: `select`'s
    /// piecewise rewiring must be invisible for postulated engines.
    #[test]
    fn uniform_engine_coll_model_at_matches_coll_model() {
        let t = Tuning::postulated(100.0, 80.0);
        for op in [CollOp::Broadcast, CollOp::Reduce, CollOp::Fcollect] {
            for n in [2usize, 8, 64] {
                for bytes in [0usize, 8, 4096, 1 << 20, 64 << 20] {
                    for &algo in Tuning::candidates(op, n) {
                        assert_eq!(
                            t.coll_model_at(op, algo, n, bytes),
                            t.coll_model(op, algo, n),
                            "{op:?} {algo:?} n={n} bytes={bytes}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn piecewise_calibration_is_well_formed() {
        let (whole, pw) = calibrate_piecewise();
        // The pooled fit obeys the same contract as calibrate().
        if !whole.is_degenerate() {
            assert!(whole.alpha_ns >= 0.0);
            assert!(whole.beta_bytes_per_ns > 0.0);
        }
        // Bucket bounds are ascending and end open.
        assert_eq!(pw.ranges[3].hi, usize::MAX);
        for w in pw.ranges.windows(2) {
            assert!(w[0].hi <= w[1].hi);
        }
        // Every per-range model is either a healthy in-bucket fit or the
        // whole-sweep fallback — never an untagged degenerate.
        for r in &pw.ranges {
            assert!(
                !r.model.is_degenerate() || r.model == whole,
                "range hi={} carries a degenerate non-fallback model",
                r.hi
            );
        }
    }

    #[test]
    fn source_wire_roundtrip() {
        for s in [
            TuningSource::Calibrated,
            TuningSource::Postulated,
            TuningSource::Fallback,
        ] {
            assert_eq!(TuningSource::from_wire(s.to_wire()), s);
        }
        assert_eq!(TuningSource::from_wire(99), TuningSource::Fallback);
    }
}
