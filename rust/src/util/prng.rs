//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` for seeding, `Xoshiro256StarStar` for the stream — the same
//! construction the `rand` crate's small RNGs use. Determinism matters here:
//! property tests, workload generators and the data-parallel trainer all
//! derive per-PE streams from `(seed, pe)` so every processing element can
//! regenerate any other element's data without communication.

/// SplitMix64 — used to expand a single `u64` seed into a full RNG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Deterministic RNG from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Per-PE stream: statistically independent streams for each `(seed, pe)`.
    pub fn for_pe(seed: u64, pe: usize) -> Self {
        Self::new(seed ^ (pe as u64).wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next value in `[0, bound)`. Debiased via Lemire's method.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range [{lo},{hi})");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fill a byte slice with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let w = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&w[..rem.len()]);
        }
    }

    /// Random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.usize_in(0, i + 1);
            v.swap(i, j);
        }
        v
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn per_pe_streams_differ() {
        let mut a = Rng::for_pe(7, 0);
        let mut b = Rng::for_pe(7, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(1);
        for bound in [1u64, 2, 3, 7, 100, 1 << 33] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn fill_bytes_covers_tail() {
        let mut r = Rng::new(11);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        // all-zero tail would indicate the remainder path was skipped
        assert!(buf[8..].iter().any(|&b| b != 0) || buf[..8].iter().any(|&b| b != 0));
    }
}
