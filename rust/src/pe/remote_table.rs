//! The remote-heap table (paper §4.1.1) — now a demand-mapping cache.
//!
//! "Building the remote heap's name and the corresponding shared object is
//! quite expensive […] As a consequence, they are all created at
//! startup-time and cached in a local structure (a table)."
//!
//! Eager creation is the paper's answer at 8 PEs; at the hundreds-to-
//! thousands of PEs the ROADMAP targets, O(n) mappings per PE (O(n²)
//! job-wide) is exactly what stops process mode from scaling. So the table
//! now maps a peer on *first access* instead:
//!
//! * **fast path** — [`RemoteTable::base_of`] is one `Acquire` load of an
//!   atomic base-pointer array plus a null check. A mapped peer costs the
//!   same vector index it always did.
//! * **slow path** — a null base takes a per-PE lock, re-checks (exactly one
//!   thread maps a cold peer; racers block and reuse its mapping), opens
//!   the segment through the engine-specific source (named POSIX object or
//!   launcher-inherited memfd), optionally waits for the peer's heap header
//!   `ready` flag, and publishes the base with `Release`.
//! * **optional LRU cap** — `POSH_MAX_MAPPED_SEGS` bounds resident peer
//!   mappings; mapping past the cap unmaps the coldest peer (last-touch
//!   stamps). Off by default: with no cap, a published base is immutable
//!   and the fast path is wait-free forever. With a cap, an addresses
//!   handed out *before* an eviction may dangle — see the safety note on
//!   [`TableOpts::max_mapped`].
//!
//! Start-up may still want the old behaviour (e.g. to smoke-test a world);
//! [`RemoteTable::prefault_all`] maps everyone under **one shared
//! deadline** — not one full timeout per absent peer — and its error names
//! the ranks that never appeared.

use crate::shm::memfd::MemfdSegment;
use crate::shm::naming::heap_segment_name;
use crate::shm::posix::PosixShmSegment;
use crate::shm::{BoxedSegment, Segment};
use crate::Result;
use anyhow::bail;
use std::os::unix::io::RawFd;
use std::sync::atomic::{AtomicBool, AtomicPtr, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A `*mut u8` that may cross threads. The pointee is a shared segment whose
/// access discipline is the SHMEM memory model's responsibility.
#[derive(Clone, Copy, Debug)]
pub struct SendPtr(pub *mut u8);
// SAFETY: see type docs.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Per-attempt slice of the shared deadline used by
/// [`RemoteTable::prefault_all`]: each round gives each still-absent peer at
/// most this long before moving on, so one slow peer cannot starve the
/// others' retries.
const PREFAULT_SLICE: Duration = Duration::from_millis(50);

/// Tuning knobs for a [`RemoteTable`].
#[derive(Clone, Copy, Debug)]
pub struct TableOpts {
    /// Deadline for a peer's segment to appear (demand path: per first
    /// touch; [`RemoteTable::prefault_all`]: shared across the whole
    /// world). Mirrors `POSH_ATTACH_TIMEOUT_S`.
    pub timeout: Duration,
    /// LRU cap on concurrently mapped *peer* segments (`None` =
    /// unlimited, the default — every published base then stays valid for
    /// the table's lifetime).
    ///
    /// # Safety note
    /// With a cap, an eviction `munmap`s a peer segment while raw
    /// addresses previously returned by [`RemoteTable::base_of`] may still
    /// be held by in-flight operations on other threads. Single-threaded
    /// PEs (and `SHMEM_THREAD_SINGLE`/`SERIALIZED` jobs) are safe; under
    /// `THREAD_MULTIPLE` a capped table requires the application to quiesce
    /// concurrent ops to evictable peers. `oshrun info` and docs/tuning.md
    /// carry the same warning.
    pub max_mapped: Option<usize>,
    /// Spin for the peer's heap-header `ready` flag after mapping (the
    /// start-up handshake a real world needs; raw-segment tests leave it
    /// off because nothing ever raises the flag).
    pub wait_ready: bool,
}

impl Default for TableOpts {
    fn default() -> Self {
        TableOpts {
            timeout: Duration::from_secs(30),
            max_mapped: None,
            wait_ready: false,
        }
    }
}

/// How peer segments are reached — the engine-specific half of the table.
enum PeerSource {
    /// Rebuild the §4.7 name from the rank and `shm_open` it.
    Posix {
        /// Job id the segment names are keyed by.
        job_id: u64,
    },
    /// `mmap` the launcher-inherited memfd for that rank.
    Memfd {
        /// Rank-indexed fds (from [`crate::shm::memfd::SEGFDS_ENV`]).
        fds: Vec<RawFd>,
    },
}

/// Per-PE slow-path state, guarded by the slot mutex.
struct Slot {
    /// The live mapping (owns the `munmap`). `None` when unmapped and for
    /// my own rank (the local heap owns that mapping).
    seg: Option<BoxedSegment>,
    /// Whether this PE was ever LRU-evicted (a later map counts as a
    /// *remap* in the stats).
    evicted: bool,
}

/// Counters describing a [`RemoteTable`]'s mapping activity (surfaced by
/// `oshrun info`).
#[derive(Clone, Copy, Debug)]
pub struct RemoteTableStats {
    /// World size the table covers.
    pub n_pes: usize,
    /// Segments currently resolvable without mapping work (peers + self;
    /// 0 after [`RemoteTable::clear`]).
    pub mapped: usize,
    /// High-water mark of `mapped`.
    pub peak_mapped: usize,
    /// Peer mappings ever created (first maps + remaps).
    pub mapped_total: u64,
    /// LRU evictions performed.
    pub evicted: u64,
    /// Maps of a peer that had previously been evicted.
    pub remapped: u64,
}

impl std::fmt::Display for RemoteTableStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mapped {}/{} (peak {}, {} mapped total, {} evicted, {} remapped)",
            self.mapped, self.n_pes, self.peak_mapped, self.mapped_total, self.evicted,
            self.remapped
        )
    }
}

/// Demand-mapping cache of peer segment mappings (process mode).
pub struct RemoteTable {
    my_pe: usize,
    n_pes: usize,
    seg_len: usize,
    opts: TableOpts,
    source: PeerSource,
    /// `bases[pe]` is null until PE `pe`'s segment is mapped; then the base
    /// address of that mapping in this address space. The lock-free fast
    /// path of [`RemoteTable::base_of`].
    bases: Vec<AtomicPtr<u8>>,
    /// Per-PE once-lock for the map/unmap slow path.
    slots: Vec<Mutex<Slot>>,
    /// Last-touch stamps (LRU clock ticks; only maintained under a cap).
    stamps: Vec<AtomicU64>,
    /// Monotonic touch clock feeding `stamps`.
    clock: AtomicU64,
    /// Poison flag: raised by [`RemoteTable::clear`], checked by the slow
    /// path so post-clear resolution fails by name instead of returning a
    /// dangling pointer.
    cleared: AtomicBool,
    /// Currently mapped *peer* segments (excludes my own rank).
    peers_mapped: AtomicUsize,
    peak_mapped: AtomicUsize,
    mapped_total: AtomicU64,
    evicted_n: AtomicU64,
    remapped_n: AtomicU64,
}

impl RemoteTable {
    /// Demand-mapping table over named POSIX segments: peers are reached by
    /// rebuilding `heap_segment_name(job_id, pe)`. Nothing is mapped yet
    /// except my own heap (`my_base`).
    pub fn new_posix(
        job_id: u64,
        my_pe: usize,
        n_pes: usize,
        my_base: *mut u8,
        seg_len: usize,
        opts: TableOpts,
    ) -> Result<Self> {
        Self::with_source(PeerSource::Posix { job_id }, my_pe, n_pes, my_base, seg_len, opts)
    }

    /// Demand-mapping table over launcher-inherited memfds: `fds[pe]` is
    /// the backing fd of PE `pe`'s heap (world size = `fds.len()`). The
    /// fds are borrowed, not owned — the fd-table entries must outlive the
    /// table (they do: they are inherited process state).
    pub fn with_memfds(
        fds: Vec<RawFd>,
        my_pe: usize,
        my_base: *mut u8,
        seg_len: usize,
        opts: TableOpts,
    ) -> Result<Self> {
        let n_pes = fds.len();
        Self::with_source(PeerSource::Memfd { fds }, my_pe, n_pes, my_base, seg_len, opts)
    }

    fn with_source(
        source: PeerSource,
        my_pe: usize,
        n_pes: usize,
        my_base: *mut u8,
        seg_len: usize,
        opts: TableOpts,
    ) -> Result<Self> {
        if n_pes == 0 {
            bail!("remote-heap table needs at least one PE");
        }
        if my_pe >= n_pes {
            bail!("rank {my_pe} out of range for {n_pes} PEs");
        }
        if my_base.is_null() {
            bail!("my_base must be the local heap's (non-null) base");
        }
        let table = RemoteTable {
            my_pe,
            n_pes,
            seg_len,
            opts,
            source,
            bases: (0..n_pes).map(|_| AtomicPtr::new(std::ptr::null_mut())).collect(),
            slots: (0..n_pes)
                .map(|_| {
                    Mutex::new(Slot {
                        seg: None,
                        evicted: false,
                    })
                })
                .collect(),
            stamps: (0..n_pes).map(|_| AtomicU64::new(0)).collect(),
            clock: AtomicU64::new(0),
            cleared: AtomicBool::new(false),
            peers_mapped: AtomicUsize::new(0),
            peak_mapped: AtomicUsize::new(1),
            mapped_total: AtomicU64::new(0),
            evicted_n: AtomicU64::new(0),
            remapped_n: AtomicU64::new(0),
        };
        // My own heap is resolvable from the start (the local SymHeap owns
        // the mapping; the table never evicts it).
        table.bases[my_pe].store(my_base, Ordering::Release);
        Ok(table)
    }

    /// Eager-compat constructor: build a POSIX table and map every peer up
    /// front under one shared `timeout`. Kept for callers that want the
    /// paper's original start-up shape (and for the seed tests).
    pub fn build(
        job_id: u64,
        my_pe: usize,
        n_pes: usize,
        my_base: *mut u8,
        seg_len: usize,
        timeout: Duration,
    ) -> Result<Self> {
        let table = Self::new_posix(
            job_id,
            my_pe,
            n_pes,
            my_base,
            seg_len,
            TableOpts {
                timeout,
                ..TableOpts::default()
            },
        )?;
        table.prefault_all()?;
        Ok(table)
    }

    /// Base address of PE `pe`'s heap in this address space, mapping the
    /// segment on first access. Mapped peers cost one `Acquire` load (the
    /// cached-table lookup of §4.1.1); cold peers take the slow path.
    ///
    /// # Panics
    /// On resolution failure — peer never appeared within the deadline, or
    /// the table was [`RemoteTable::clear`]ed. The data path (`Ctx`) has no
    /// error channel on loads/stores; use [`RemoteTable::try_base_of`]
    /// where an error is handleable.
    #[inline]
    pub fn base_of(&self, pe: usize) -> *mut u8 {
        match self.try_base_of(pe) {
            Ok(p) => p,
            Err(e) => panic!("posh remote-heap table: cannot resolve PE {pe}'s heap: {e:#}"),
        }
    }

    /// Fallible twin of [`RemoteTable::base_of`].
    #[inline]
    pub fn try_base_of(&self, pe: usize) -> Result<*mut u8> {
        let p = self.bases[pe].load(Ordering::Acquire);
        if !p.is_null() {
            self.touch(pe);
            return Ok(p);
        }
        self.map_slow(pe, self.opts.timeout)
    }

    /// Record an LRU touch (no-op without a cap, keeping the fast path
    /// store-free in the default configuration).
    #[inline]
    fn touch(&self, pe: usize) {
        if self.opts.max_mapped.is_some() {
            let t = self.clock.fetch_add(1, Ordering::Relaxed) + 1;
            self.stamps[pe].store(t, Ordering::Relaxed);
        }
    }

    /// Map a cold peer under its slot lock. `budget` bounds how long the
    /// POSIX open retries (the paper's "wait a little bit and try again");
    /// memfd opens are non-blocking, so any failure there is immediate.
    fn map_slow(&self, pe: usize, budget: Duration) -> Result<*mut u8> {
        if pe >= self.n_pes {
            bail!("PE {pe} out of range for {} PEs", self.n_pes);
        }
        if self.cleared.load(Ordering::Acquire) {
            bail!(
                "remote-heap table used after clear(): PE {pe}'s base was \
                 invalidated and will not be remapped"
            );
        }
        let mut slot = self.slots[pe].lock().expect("remote-table slot lock poisoned");
        // Double-check: a racer may have mapped it while we waited for the
        // lock — both threads then see the one mapping.
        let cur = self.bases[pe].load(Ordering::Acquire);
        if !cur.is_null() {
            self.touch(pe);
            return Ok(cur);
        }
        // Make room under the LRU cap (best effort: contended victims are
        // skipped rather than waited on).
        if let Some(cap) = self.opts.max_mapped {
            let cap = cap.max(1);
            while self.peers_mapped.load(Ordering::Relaxed) >= cap {
                if !self.evict_coldest(pe) {
                    break;
                }
            }
        }
        let seg = self.open_peer(pe, budget)?;
        let base = seg.base();
        if self.opts.wait_ready {
            // Wait *before* publishing: nobody may see a base whose heap
            // header is still being initialised by the peer.
            self.wait_heap_ready(pe, base)?;
        }
        let was_evicted = slot.evicted;
        slot.seg = Some(seg);
        self.bases[pe].store(base, Ordering::Release);
        let peers = self.peers_mapped.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_mapped.fetch_max(peers + 1, Ordering::Relaxed);
        self.mapped_total.fetch_add(1, Ordering::Relaxed);
        if was_evicted {
            self.remapped_n.fetch_add(1, Ordering::Relaxed);
        }
        self.touch(pe);
        Ok(base)
    }

    /// Open PE `pe`'s segment through the table's source.
    fn open_peer(&self, pe: usize, budget: Duration) -> Result<BoxedSegment> {
        match &self.source {
            PeerSource::Posix { job_id } => {
                let name = heap_segment_name(*job_id, pe);
                let seg = PosixShmSegment::open_existing(&name, self.seg_len, budget)?;
                Ok(Box::new(seg))
            }
            PeerSource::Memfd { fds } => {
                let seg = MemfdSegment::map_existing(fds[pe], self.seg_len)?;
                Ok(Box::new(seg))
            }
        }
    }

    /// Spin until PE `pe`'s heap header raises its `ready` flag.
    fn wait_heap_ready(&self, pe: usize, base: *mut u8) -> Result<()> {
        // SAFETY: `base` is a live mapping of at least a header-sized
        // segment (open_peer validated the length).
        let hdr = unsafe { crate::symheap::layout::HeapHeader::at(base) };
        let deadline = Instant::now() + self.opts.timeout;
        while hdr.ready.load(Ordering::Acquire) == 0 {
            if Instant::now() > deadline {
                bail!("PE {pe} heap header not ready within {:?}", self.opts.timeout);
            }
            std::hint::spin_loop();
            std::thread::yield_now();
        }
        Ok(())
    }

    /// Unmap the least-recently-touched mapped peer (never my own rank,
    /// never `exclude`). Returns `false` when there is no evictable victim
    /// — all candidates unmapped, or the coldest one's slot is contended.
    fn evict_coldest(&self, exclude: usize) -> bool {
        let mut best: Option<(usize, u64)> = None;
        for pe in 0..self.n_pes {
            if pe == self.my_pe || pe == exclude {
                continue;
            }
            if self.bases[pe].load(Ordering::Acquire).is_null() {
                continue;
            }
            let s = self.stamps[pe].load(Ordering::Relaxed);
            let colder = match best {
                None => true,
                Some((_, bs)) => s < bs,
            };
            if colder {
                best = Some((pe, s));
            }
        }
        let Some((victim, _)) = best else {
            return false;
        };
        // try_lock, not lock: the victim's slot may be held by a thread
        // mapping it right now; skipping keeps the eviction path
        // deadlock-free (we already hold `exclude`'s slot lock).
        let Ok(mut vslot) = self.slots[victim].try_lock() else {
            return false;
        };
        if self.bases[victim].load(Ordering::Acquire).is_null() {
            return false;
        }
        // Unpublish before unmapping so no new fast-path reader acquires
        // the dying address.
        self.bases[victim].store(std::ptr::null_mut(), Ordering::Release);
        vslot.seg = None; // munmap
        vslot.evicted = true;
        self.peers_mapped.fetch_sub(1, Ordering::Relaxed);
        self.evicted_n.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Map every peer now, under **one shared deadline** (`opts.timeout`
    /// total, not per peer — a dead world fails in one timeout, not n).
    /// The error names every rank that never appeared.
    pub fn prefault_all(&self) -> Result<()> {
        if self.cleared.load(Ordering::Acquire) {
            bail!("remote-heap table used after clear(): cannot prefault");
        }
        let deadline = Instant::now() + self.opts.timeout;
        let mut pending: Vec<usize> = (0..self.n_pes)
            .filter(|&pe| self.bases[pe].load(Ordering::Acquire).is_null())
            .collect();
        let mut last_err = None;
        while !pending.is_empty() {
            let mut still = Vec::new();
            for &pe in &pending {
                let now = Instant::now();
                let budget = if now >= deadline {
                    // Past the deadline each peer gets exactly one
                    // non-blocking attempt before we give up on it.
                    Duration::ZERO
                } else {
                    (deadline - now).min(PREFAULT_SLICE)
                };
                match self.map_slow(pe, budget) {
                    Ok(_) => {}
                    Err(e) => {
                        // memfd opens never block: an error there is a hard
                        // fault (bad/closed fd), not a peer still starting.
                        if matches!(self.source, PeerSource::Memfd { .. }) {
                            return Err(e);
                        }
                        last_err = Some(e);
                        still.push(pe);
                    }
                }
            }
            if Instant::now() >= deadline && !still.is_empty() {
                let detail = match last_err {
                    Some(e) => format!(" (last error: {e:#})"),
                    None => String::new(),
                };
                bail!(
                    "PEs {still:?} did not appear within the shared attach \
                     deadline of {:?}{detail}",
                    self.opts.timeout
                );
            }
            pending = still;
        }
        Ok(())
    }

    /// Number of PEs covered.
    pub fn len(&self) -> usize {
        self.n_pes
    }

    /// True if the table is empty.
    pub fn is_empty(&self) -> bool {
        self.n_pes == 0
    }

    /// Mapping-activity counters (see [`RemoteTableStats`]).
    pub fn stats(&self) -> RemoteTableStats {
        let cleared = self.cleared.load(Ordering::Acquire);
        let peers = self.peers_mapped.load(Ordering::Relaxed);
        RemoteTableStats {
            n_pes: self.n_pes,
            mapped: if cleared { 0 } else { peers + 1 },
            peak_mapped: self.peak_mapped.load(Ordering::Relaxed),
            mapped_total: self.mapped_total.load(Ordering::Relaxed),
            evicted: self.evicted_n.load(Ordering::Relaxed),
            remapped: self.remapped_n.load(Ordering::Relaxed),
        }
    }

    /// Drop all remote mappings and **poison the table**: every base
    /// (including my own rank's) is invalidated, and any later
    /// [`RemoteTable::base_of`] fails with a named "used after clear()"
    /// error instead of returning a dangling pointer.
    pub fn clear(&mut self) {
        // Raise the poison flag first so concurrent slow paths that have
        // not yet mapped bail instead of racing the teardown.
        self.cleared.store(true, Ordering::Release);
        for base in &self.bases {
            base.store(std::ptr::null_mut(), Ordering::Release);
        }
        for slot in &self.slots {
            let mut s = slot.lock().expect("remote-table slot lock poisoned");
            s.seg = None;
        }
        self.peers_mapped.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shm::naming::fresh_job_id;

    #[test]
    fn build_maps_peers_created_in_same_process() {
        // Simulate two PEs' segments existing, then build rank 0's table.
        let job = fresh_job_id();
        let len = 64 << 10;
        let seg0 = PosixShmSegment::create(&heap_segment_name(job, 0), len).unwrap();
        let seg1 = PosixShmSegment::create(&heap_segment_name(job, 1), len).unwrap();
        unsafe {
            *seg1.base().add(100) = 77;
        }
        let table =
            RemoteTable::build(job, 0, 2, seg0.base(), len, Duration::from_millis(200)).unwrap();
        assert_eq!(table.len(), 2);
        assert_eq!(table.base_of(0), seg0.base());
        // The table's mapping of PE1 is a *different* mapping of the same
        // object: different address, same bytes.
        unsafe {
            assert_eq!(*table.base_of(1).add(100), 77);
        }
        assert_ne!(table.base_of(1), seg1.base());
        // Eager build maps the world up front.
        assert_eq!(table.stats().mapped, 2);
    }

    #[test]
    fn build_times_out_on_missing_peer() {
        let job = fresh_job_id();
        let len = 16 << 10;
        let seg0 = PosixShmSegment::create(&heap_segment_name(job, 0), len).unwrap();
        let r = RemoteTable::build(job, 0, 3, seg0.base(), len, Duration::from_millis(50));
        assert!(r.is_err());
    }

    #[test]
    fn build_deadline_is_shared_and_names_missing_pes() {
        // 5 missing peers, 100 ms timeout: the old per-peer retry would
        // take ≥500 ms; the shared deadline must stay close to one timeout
        // and the error must say *which* ranks never appeared.
        let job = fresh_job_id();
        let len = 16 << 10;
        let seg0 = PosixShmSegment::create(&heap_segment_name(job, 0), len).unwrap();
        let t0 = Instant::now();
        let r = RemoteTable::build(job, 0, 6, seg0.base(), len, Duration::from_millis(100));
        let elapsed = t0.elapsed();
        let msg = format!("{:#}", r.unwrap_err());
        assert!(
            elapsed < Duration::from_millis(400),
            "shared deadline regressed to per-peer stacking: {elapsed:?}"
        );
        for pe in 1..6 {
            assert!(msg.contains(&pe.to_string()), "error must name PE {pe}: {msg}");
        }
    }

    #[test]
    fn demand_table_maps_lazily() {
        let job = fresh_job_id();
        let len = 16 << 10;
        let seg0 = PosixShmSegment::create(&heap_segment_name(job, 0), len).unwrap();
        let seg1 = PosixShmSegment::create(&heap_segment_name(job, 1), len).unwrap();
        let seg2 = PosixShmSegment::create(&heap_segment_name(job, 2), len).unwrap();
        unsafe {
            *seg2.base().add(8) = 0x5A;
        }
        let table = RemoteTable::new_posix(
            job,
            0,
            3,
            seg0.base(),
            len,
            TableOpts {
                timeout: Duration::from_millis(200),
                ..TableOpts::default()
            },
        )
        .unwrap();
        // Nothing mapped yet but my own heap.
        assert_eq!(table.stats().mapped, 1);
        assert_eq!(table.stats().mapped_total, 0);
        // First touch maps exactly that peer.
        unsafe {
            assert_eq!(*table.base_of(2).add(8), 0x5A);
        }
        assert_eq!(table.stats().mapped, 2);
        assert_eq!(table.stats().mapped_total, 1);
        // Second touch is the cached fast path (no new mapping).
        let _ = table.base_of(2);
        assert_eq!(table.stats().mapped_total, 1);
        drop(seg1);
    }

    #[test]
    fn clear_poisons_bases() {
        let job = fresh_job_id();
        let len = 16 << 10;
        let seg0 = PosixShmSegment::create(&heap_segment_name(job, 0), len).unwrap();
        let seg1 = PosixShmSegment::create(&heap_segment_name(job, 1), len).unwrap();
        let mut table =
            RemoteTable::build(job, 0, 2, seg0.base(), len, Duration::from_millis(200)).unwrap();
        assert!(!table.base_of(1).is_null());
        table.clear();
        assert_eq!(table.stats().mapped, 0);
        // Post-clear resolution must fail by name — not return the old
        // (now dangling) pointer.
        let err = format!("{:#}", table.try_base_of(1).unwrap_err());
        assert!(err.contains("after clear"), "unexpected error: {err}");
        let self_err = format!("{:#}", table.try_base_of(0).unwrap_err());
        assert!(self_err.contains("after clear"), "self base must be poisoned too");
        drop(seg1);
    }
}
