//! End-to-end driver (DESIGN.md §3 "E2E"): data-parallel training of the
//! AOT-compiled transformer LM with POSH gradient exchange.
//!
//! Proves the full three-layer stack composes:
//!   Layer 1  Pallas matmul kernel (inside the HLO artifact)
//!   Layer 2  JAX transformer fwd/bwd + SGD (the HLO artifacts)
//!   Layer 3  this process: POSH symmetric heap + reductions + barriers
//!
//! Requires `make artifacts`. Usage:
//! `e2e_training [steps] [n_pes] [--lr X] [--algo linear-put|tree|recdbl|linear-get]`
//!
//! The loss curve lands in `bench_out/e2e_loss.csv`; the run is recorded in
//! EXPERIMENTS.md.

use posh::collectives::AlgoKind;
use posh::coordinator::{Trainer, TrainerConfig};
use posh::pe::{PoshConfig, World};

fn main() -> posh::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let n_pes: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let lr = args
        .iter()
        .position(|a| a == "--lr")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse::<f64>().ok());
    let algo = args
        .iter()
        .position(|a| a == "--algo")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| AlgoKind::parse(s));

    let tcfg = TrainerConfig {
        steps,
        lr,
        ..Default::default()
    };

    let mut cfg = PoshConfig::default();
    cfg.coll_algo = algo;

    let t0 = std::time::Instant::now();
    let reports = if World::env_present() {
        let world = World::from_env()?;
        let ctx = world.my_ctx();
        vec![Trainer::new(tcfg.clone()).run(&ctx)?]
    } else {
        let world = World::threads(n_pes, cfg)?;
        let results = world.run_collect(|ctx| Trainer::new(tcfg.clone()).run(&ctx));
        results.into_iter().collect::<posh::Result<Vec<_>>>()?
    };
    let wall = t0.elapsed();

    let r0 = &reports[0];
    println!("\n=== e2e training summary ===");
    println!("params          : {}", r0.param_count);
    println!("PEs             : {}", reports.len().max(1));
    println!("steps           : {steps}");
    println!("first loss      : {:.4}", r0.first_loss);
    println!("final loss (10) : {:.4}", r0.final_loss);
    println!("wall time       : {wall:?}");
    let (compute, comm) = r0.log.totals();
    if !r0.log.steps.is_empty() {
        println!(
            "compute/comm    : {compute:?} / {comm:?} ({:.1}% comm)",
            100.0 * comm.as_secs_f64() / (compute + comm).as_secs_f64().max(1e-9)
        );
        r0.log.write_csv("bench_out/e2e_loss.csv")?;
        println!("loss curve      : bench_out/e2e_loss.csv");
    }
    // The training signal must be real: loss falls by a clear margin. Short
    // smoke runs (< 150 steps) only need a downward trend.
    if steps >= 150 {
        assert!(
            r0.final_loss < r0.first_loss * 0.8,
            "loss did not fall: {:.4} -> {:.4}",
            r0.first_loss,
            r0.final_loss
        );
    } else {
        assert!(
            r0.final_loss < r0.first_loss,
            "loss did not trend down: {:.4} -> {:.4}",
            r0.first_loss,
            r0.final_loss
        );
    }
    println!("e2e_training OK");
    Ok(())
}
