//! Non-blocking-implicit transfers: `shmem_put_nbi` / `shmem_get_nbi`,
//! accounted — and, on explicit contexts, **batched** — per ordering
//! domain.
//!
//! **Extension** (OpenSHMEM 1.3; not in the 1.0 spec the paper implements —
//! listed under "future works" in its conclusion). On a shared-memory node
//! the origin core performs the copy either way, so the useful freedom NBI
//! grants an implementation is *deferral*: batch small transfers and issue
//! them at the next `quiet`, amortising per-call overhead and letting one
//! context's stream quiesce without fencing the world.
//!
//! The two domains behave differently, deliberately:
//!
//! * the **default domain** is a thread-local counter and issues eagerly —
//!   the 1.0 behaviour, bit-for-bit: [`Ctx::put_nbi`] copies immediately,
//!   [`Ctx::quiet_nbi`] is the full `SeqCst` completion fence plus
//!   retirement;
//! * each **explicit domain** (an `NbiBatch`, owned by one
//!   [`crate::ctx::CommCtx`]) *defers* puts of up to
//!   [`NBI_DEFER_MAX_BYTES`] into a private queue. `ctx.quiet()` drains
//!   that queue — and only that queue — then issues a release fence: the
//!   batched drain needs no process-wide `SeqCst` fence because the drain
//!   itself performs the copies and the copy engine orders its own
//!   streaming stores. At drain time, consecutive queued puts to the same
//!   PE at byte-adjacent offsets are **coalesced** into one copy, up to a
//!   run size derived from the fitted channel model (the `n₁/₂ = α·β`
//!   break-even — see `Ctx::drain`'s doc and `docs/tuning.md`); delivery is
//!   byte-identical either way, which the drain tests pin. Larger puts are
//!   issued eagerly (a bulk copy gains nothing from deferral) but still
//!   count against the domain; gets are always eager (the destination
//!   borrow ends when the call returns) and likewise counted.
//!
//! **Threading** (`SHMEM_THREAD_MULTIPLE`, OpenSHMEM 1.4 §9.2): an
//! explicit domain's queue is a sharded MPSC structure
//! ([`crate::p2p::shard_queue::ShardedQueue`]), not a mutex-guarded `Vec`.
//! Each application thread pushes onto the shard selected by its
//! process-wide [`crate::p2p::shard_queue::thread_slot`] with a single
//! Release CAS — the issue path takes **no lock** — and a quiet drains all
//! [`NBI_SHARDS`] shards, publishes with one Release fence, and retires
//! exactly what was delivered. Delivery order is FIFO *per issuing thread*
//! (same thread ⇒ same shard); cross-thread order on one context is
//! unspecified, as the spec allows. The coalescer therefore also operates
//! per thread: only a single thread's adjacent puts merge, so its
//! last-writer-wins guarantees are untouched by threading.
//!
//! `pending_nbi()` counts issued-but-unretired operations per domain, so
//! programs written against the 1.3/1.4 semantics run unmodified and the
//! completion discipline — including its per-context scoping — is testable:
//! a deferred put is *provably* not delivered until its own context
//! quiesces (see the flag-after-data conformance tests in
//! `tests/prop_teams.rs`, and the multi-thread isolation tests in
//! `tests/stress_threads.rs`).

use crate::p2p::shard_queue::{thread_slot, ShardedQueue};
use crate::pe::Ctx;
use crate::symheap::SymPtr;
use std::cell::Cell;

/// Largest put (in bytes) an explicit context defers into its batch;
/// anything bigger is issued eagerly (and still counted). Small enough that
/// a batch of control-plane puts stays cache-resident, large enough to
/// cover every flag/descriptor-sized message.
///
/// Default re-derived from the archived Ablation-B/calibration trajectory
/// and the KV write-heavy mix (docs/tuning.md §"NBI batching knobs"): with
/// the fitted channel at α ≈ 40 ns, β ≈ 10 B/ns the coalescing break-even
/// `n₁/₂ = α·β` sits near 400 B, so a deferral cap of ~20·n₁/₂ captures
/// every control-plane run that can profit from coalescing while keeping a
/// full batch inside L1. The previous 16 KiB cap bought no extra merges
/// (runs are clamped to `n₁/₂` anyway) and doubled staging-copy residency.
/// Override at run time with `POSH_NBI_DEFER_MAX` (e.g. `16K`).
pub const NBI_DEFER_MAX_BYTES: usize = 8 * 1024;

/// Queued bytes **per shard** at which a batch drains that shard inline
/// (the ops are issued, the accounting stays pending until the next quiet)
/// — bounds the memory one issuing thread can pin between quiets.
///
/// Default re-derived alongside [`NBI_DEFER_MAX_BYTES`]: 256 KiB keeps a
/// shard's staged bytes L2-resident on every calibrated machine (the
/// per-range channel model shows β dropping ~3× past the L2 bound, so
/// draining from L2 beats draining a megabyte from LLC/DRAM), while still
/// amortising the drain's fence over ≥32 deferred puts at the cap. The
/// KV write-heavy mix regressed under the old 1 MiB watermark for exactly
/// that reason. Override at run time with `POSH_NBI_DRAIN_BYTES`.
pub const NBI_BATCH_DRAIN_BYTES: usize = 256 * 1024;

/// Effective deferral cap: [`NBI_DEFER_MAX_BYTES`] unless overridden by the
/// `POSH_NBI_DEFER_MAX` environment variable (size-suffix syntax, e.g.
/// `4K`, `16384`). Read once per process.
pub fn nbi_defer_max_bytes() -> usize {
    static CAP: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("POSH_NBI_DEFER_MAX")
            .ok()
            .and_then(|s| crate::pe::config::parse_size(&s))
            .filter(|&n| n > 0)
            .unwrap_or(NBI_DEFER_MAX_BYTES)
    })
}

/// Effective per-shard drain watermark: [`NBI_BATCH_DRAIN_BYTES`] unless
/// overridden by the `POSH_NBI_DRAIN_BYTES` environment variable. Read once
/// per process.
pub fn nbi_batch_drain_bytes() -> usize {
    static CAP: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CAP.get_or_init(|| {
        std::env::var("POSH_NBI_DRAIN_BYTES")
            .ok()
            .and_then(|s| crate::pe::config::parse_size(&s))
            .filter(|&n| n > 0)
            .unwrap_or(NBI_BATCH_DRAIN_BYTES)
    })
}

/// Shard count of every explicit domain's deferred-put queue. Each issuing
/// thread maps to `thread_slot() % NBI_SHARDS`, so up to this many threads
/// push with zero contention; beyond it, threads sharing a shard still only
/// contend on CAS retries, never a lock.
pub const NBI_SHARDS: usize = 16;

thread_local! {
    /// Issued-but-unretired NBI operations of the calling PE thread's
    /// default domain.
    static PENDING: Cell<u64> = const { Cell::new(0) };
}

/// One deferred put: an owned copy of the source bytes, the destination
/// handle's byte offset, and the (world-rank) target PE.
#[derive(Debug)]
struct DeferredPut {
    dest_off: usize,
    bytes: Vec<u8>,
    pe: usize,
}

/// An explicit NBI ordering domain: the private accounting **and** deferred
/// put batch of one [`crate::ctx::CommCtx`]. The queue is sharded per
/// issuing thread so concurrent users of a `MULTIPLE` context never take a
/// lock to issue — see [`crate::p2p::shard_queue`] for the design and its
/// loom/Miri/TSan coverage.
#[derive(Debug)]
pub(crate) struct NbiBatch {
    /// Deferred puts plus the pending/completed accounting.
    queue: ShardedQueue<DeferredPut>,
}

impl NbiBatch {
    /// An empty domain.
    pub(crate) fn new() -> NbiBatch {
        NbiBatch { queue: ShardedQueue::new(NBI_SHARDS) }
    }

    /// Issued-but-unretired operation count.
    pub(crate) fn pending(&self) -> u64 {
        self.queue.pending()
    }
}

impl Default for NbiBatch {
    fn default() -> NbiBatch {
        NbiBatch::new()
    }
}

/// An NBI ordering domain: where issued-but-unretired operations are
/// counted, and which queue/counter a quiet drains and retires.
pub(crate) enum NbiDomain<'a> {
    /// The thread-local default context (OpenSHMEM 1.0 behaviour: eager).
    Default,
    /// An explicit context's private batch.
    Explicit(&'a NbiBatch),
}

impl Ctx {
    /// Record one issued NBI operation in `domain`.
    pub(crate) fn nbi_issued(&self, domain: &NbiDomain<'_>) {
        match domain {
            NbiDomain::Default => PENDING.with(|p| p.set(p.get() + 1)),
            NbiDomain::Explicit(batch) => batch.queue.note_eager(),
        }
    }

    /// Retire every pending NBI operation of `domain`. For the default
    /// domain this is accounting only (the caller has already fenced); an
    /// explicit domain cannot retire blindly — a `put_nbi` racing in from
    /// another thread of a `MULTIPLE` context must not be counted away
    /// while its op sits undelivered in a shard — so it routes through the
    /// full drain-then-retire quiet, which retires exactly what it ships.
    pub(crate) fn nbi_retire(&self, domain: &NbiDomain<'_>) {
        match domain {
            NbiDomain::Default => PENDING.with(|p| p.set(0)),
            NbiDomain::Explicit(batch) => self.nbi_quiet_batch(batch),
        }
    }

    /// Issue every queued put of `batch`, in per-thread issue order.
    /// Accounting stays pending — draining completes the data movement,
    /// quiet retires (the delivered count parks inside the queue until
    /// then).
    pub(crate) fn nbi_drain(&self, batch: &NbiBatch) {
        batch.queue.drain(&mut |run| self.nbi_deliver_run(run));
    }

    /// The full explicit-domain quiet: drain every shard, publish with one
    /// Release fence, retire exactly what was delivered. An op pushed
    /// concurrently from another thread is either taken by this drain's
    /// Acquire swap (retiring it is correct — it shipped) or stays queued
    /// *with its pending increment intact* for the next quiet: the
    /// increment-before-Release-CAS protocol in
    /// [`crate::p2p::shard_queue::ShardedQueue::push`] makes counting an
    /// undelivered op away impossible.
    pub(crate) fn nbi_quiet_batch(&self, batch: &NbiBatch) {
        batch.queue.quiet(&mut |run| self.nbi_deliver_run(run));
    }

    /// Issue one drained shard's puts, **coalescing** runs of
    /// queue-consecutive ops that target the same PE at byte-adjacent
    /// offsets into one `put` (one `mem::copy` dispatch instead of one per
    /// op). A shard holds a single thread's stream in FIFO order, so
    /// merging only consecutive, exactly-adjacent entries preserves the
    /// per-thread delivery order a fence promises. The run-size cap comes
    /// from the fitted channel model
    /// ([`crate::collectives::Tuning::coalesce_threshold_bytes`]): merging
    /// saves one per-call latency α and costs one extra staging append
    /// `s/β`, so it pays while the run stays under `n₁/₂ = α·β`.
    fn nbi_deliver_run(&self, mut ops: Vec<DeferredPut>) {
        let max_run = self.tuning().coalesce_threshold_bytes();
        let mut i = 0;
        while i < ops.len() {
            let (dest_off, pe) = (ops[i].dest_off, ops[i].pe);
            let mut run = std::mem::take(&mut ops[i].bytes);
            let mut j = i + 1;
            while j < ops.len()
                && ops[j].pe == pe
                && ops[j].dest_off == dest_off + run.len()
                && run.len() + ops[j].bytes.len() <= max_run
            {
                run.extend_from_slice(&ops[j].bytes);
                j += 1;
            }
            let dest: SymPtr<u8> = SymPtr::from_raw(dest_off, run.len());
            self.put(dest, &run, pe);
            i = j;
        }
    }

    /// `put_nbi` into an explicit domain (the [`crate::ctx::CommCtx`] path):
    /// deferred into the context's batch when small, eager when bulk.
    pub(crate) fn put_nbi_domain<T: Copy>(
        &self,
        domain: &NbiDomain<'_>,
        dest: SymPtr<T>,
        src: &[T],
        pe: usize,
    ) {
        match domain {
            NbiDomain::Default => {
                self.put(dest, src, pe);
                self.nbi_issued(domain);
            }
            NbiDomain::Explicit(batch) => {
                let nbytes = std::mem::size_of_val(src);
                if nbytes > nbi_defer_max_bytes() {
                    // Eager: delivered by the time put() returns, so a
                    // concurrent quiet retiring it early is still truthful.
                    self.put(dest, src, pe);
                    batch.queue.note_eager();
                } else {
                    // Validate at issue time so a bad call fails at its own
                    // call site, not inside a later quiet.
                    if self.config().safe {
                        assert!(pe < self.n_pes(), "put_nbi: target PE {pe} out of range");
                        assert!(
                            src.len() <= dest.len(),
                            "put_nbi: {} elems into a {}-elem symmetric object",
                            src.len(),
                            dest.len()
                        );
                    } else {
                        debug_assert!(pe < self.n_pes());
                        debug_assert!(src.len() <= dest.len());
                    }
                    // SAFETY: `src` is a live initialised slice of `Copy`
                    // data; viewing it as bytes is always valid.
                    let bytes = unsafe {
                        std::slice::from_raw_parts(src.as_ptr() as *const u8, nbytes)
                    }
                    .to_vec();
                    // Lock-free enqueue onto this thread's shard. The push
                    // counts the op *before* publishing it, so a racing
                    // quiet either ships it (and retires it) or leaves it
                    // counted — never wipes the count of a queued op.
                    let slot = thread_slot();
                    let shard_bytes = batch.queue.push(
                        slot,
                        DeferredPut { dest_off: dest.offset(), bytes, pe },
                        nbytes,
                    );
                    if shard_bytes > nbi_batch_drain_bytes() {
                        batch.queue.drain_slot(slot, &mut |run| self.nbi_deliver_run(run));
                    }
                }
            }
        }
    }

    /// `get_nbi` into an explicit domain (the [`crate::ctx::CommCtx`]
    /// path). Always eager — the destination borrow ends when this call
    /// returns — but counted against the domain like any NBI op.
    pub(crate) fn get_nbi_domain<T: Copy>(
        &self,
        domain: &NbiDomain<'_>,
        dest: &mut [T],
        src: SymPtr<T>,
        pe: usize,
    ) {
        self.get(dest, src, pe);
        self.nbi_issued(domain);
    }

    /// `shmem_put_nbi` (default context): start a put; completion only at
    /// the next [`Ctx::quiet_nbi`] (or barrier, which includes a quiet).
    pub fn put_nbi<T: Copy>(&self, dest: SymPtr<T>, src: &[T], pe: usize) {
        self.put_nbi_domain(&NbiDomain::Default, dest, src, pe);
    }

    /// `shmem_get_nbi` (default context): start a get; the value is only
    /// guaranteed after the next [`Ctx::quiet_nbi`].
    pub fn get_nbi<T: Copy>(&self, dest: &mut [T], src: SymPtr<T>, pe: usize) {
        self.get_nbi_domain(&NbiDomain::Default, dest, src, pe);
    }

    /// Number of NBI operations issued by this PE on the **default**
    /// context and not yet retired by a [`Ctx::quiet_nbi`]. Explicit
    /// contexts keep their own count ([`crate::ctx::CommCtx::pending_nbi`]).
    pub fn pending_nbi(&self) -> u64 {
        PENDING.with(|p| p.get())
    }

    /// `shmem_quiet` variant that also retires the default context's NBI
    /// accounting. (The plain `quiet` in `sync::order` is the fence; this
    /// is the bookkeeping face used by programs that check `pending_nbi`,
    /// and the one barriers fold in.)
    pub fn quiet_nbi(&self) {
        self.quiet_domain(&NbiDomain::Default);
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn nbi_accounting() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let buf = ctx.shmalloc_n::<u32>(8).unwrap();
            assert_eq!(ctx.pending_nbi(), 0);
            ctx.put_nbi(buf, &[1; 8], (ctx.my_pe() + 1) % 2);
            let mut tmp = [0u32; 8];
            ctx.get_nbi(&mut tmp, buf, ctx.my_pe());
            assert_eq!(ctx.pending_nbi(), 2);
            ctx.quiet_nbi();
            assert_eq!(ctx.pending_nbi(), 0);
            ctx.barrier_all();
            // Data actually arrived.
            assert_eq!(unsafe { ctx.local(buf) }, &[1u32; 8][..]);
            ctx.barrier_all();
        });
    }

    #[test]
    fn barrier_retires_default_domain() {
        // A barrier folds in a quiet: outstanding default-domain NBI
        // accounting must be retired by it (and a sync-only must not — see
        // the conformance tests in tests/prop_collectives.rs).
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let buf = ctx.shmalloc_n::<u32>(4).unwrap();
            ctx.put_nbi(buf, &[3; 4], (ctx.my_pe() + 1) % 2);
            assert_eq!(ctx.pending_nbi(), 1);
            ctx.barrier_all();
            assert_eq!(ctx.pending_nbi(), 0, "barrier_all must retire the default domain");
            assert_eq!(unsafe { ctx.local(buf) }, &[3u32; 4][..]);
            ctx.barrier_all();
        });
    }

    /// An explicit context's small puts are deferred: until the context
    /// quiesces, the target memory is untouched — deterministically, which
    /// is what makes the cross-domain conformance oracle possible.
    #[test]
    fn explicit_domain_defers_small_puts() {
        use crate::ctx::CtxOptions;
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let world = ctx.team_world();
            let c = world.create_ctx(CtxOptions::new());
            let buf = ctx.shmalloc_n::<u64>(2).unwrap();
            unsafe { ctx.local_mut(buf).fill(0) };
            c.put_nbi(buf, &[11, 22], 0);
            assert_eq!(c.pending_nbi(), 1);
            // Deferred: our own memory still holds the old value.
            assert_eq!(unsafe { ctx.local(buf) }, &[0, 0][..]);
            c.quiet();
            assert_eq!(c.pending_nbi(), 0);
            assert_eq!(unsafe { ctx.local(buf) }, &[11, 22][..]);
            c.destroy();
        });
    }

    /// Coalesced and uncoalesced delivery are byte-identical: the same
    /// sequence of puts issued (a) deferred through a context batch — where
    /// adjacent runs coalesce at drain time — and (b) eagerly on the
    /// default domain must leave the target memory equal, including across
    /// gaps, PE switches, and an overlapping rewrite that must NOT merge
    /// (order within the drain is what keeps last-writer-wins intact).
    #[test]
    fn coalesced_drain_byte_identical_to_eager() {
        use crate::ctx::CtxOptions;
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let a = ctx.shmalloc_n::<u8>(512).unwrap(); // deferred+coalesced target
            let b = ctx.shmalloc_n::<u8>(512).unwrap(); // eager reference target
            unsafe {
                ctx.local_mut(a).fill(0);
                ctx.local_mut(b).fill(0);
            }
            ctx.barrier_all();
            if ctx.my_pe() == 0 {
                let world = ctx.team_world();
                let c = world.create_ctx(CtxOptions::new());
                // The put script: three adjacent runs (coalescable), a gap,
                // a PE switch in the middle, and an overlapping rewrite.
                let script: &[(usize, &[u8], usize)] = &[
                    (0, &[1; 16], 1),   // run start
                    (16, &[2; 16], 1),  // adjacent → coalesces
                    (32, &[3; 8], 1),   // adjacent → coalesces
                    (100, &[4; 4], 1),  // gap → new run
                    (104, &[5; 4], 0),  // adjacent offset but other PE → no merge
                    (108, &[6; 4], 1),  // not adjacent to the PE-1 run’s predecessor
                    (200, &[7; 32], 1), // fresh run…
                    (216, &[8; 32], 1), // …overlapping rewrite: must stay ordered
                ];
                for &(off, bytes, pe) in script {
                    c.put_nbi(a.slice(off, bytes.len()), bytes, pe);
                }
                assert_eq!(c.pending_nbi(), script.len() as u64);
                c.quiet();
                // Reference: the same script, eager blocking puts.
                for &(off, bytes, pe) in script {
                    ctx.put(b.slice(off, bytes.len()), bytes, pe);
                }
                ctx.quiet();
                c.destroy();
            }
            ctx.barrier_all();
            let (got, want) = unsafe { (ctx.local(a), ctx.local(b)) };
            assert_eq!(got, want, "PE {}: coalesced != eager delivery", ctx.my_pe());
            // And the overlap really was last-writer-wins on PE 1.
            if ctx.my_pe() == 1 {
                assert_eq!(got[200..216], [7; 16]);
                assert_eq!(got[216..248], [8; 32]);
            }
            ctx.barrier_all();
        });
    }

    /// Runs larger than the model-derived threshold stop coalescing but
    /// still deliver exactly; a postulated model with a tiny n₁/₂ floors at
    /// the 64-byte clamp.
    #[test]
    fn coalescing_respects_model_threshold() {
        use crate::ctx::CtxOptions;
        let mut cfg = PoshConfig::small();
        // α = 100 ns, β = 10 B/ns ⇒ n₁/₂ = 1000 B: a 1 KiB run cap.
        cfg.cost_model = Some(crate::model::CostModel::from_alpha_gbps(100.0, 80.0));
        let w = World::threads(1, cfg).unwrap();
        w.run(|ctx| {
            assert_eq!(ctx.tuning().coalesce_threshold_bytes(), 1000);
            let buf = ctx.shmalloc_n::<u8>(4096).unwrap();
            unsafe { ctx.local_mut(buf).fill(0) };
            let world = ctx.team_world();
            let c = world.create_ctx(CtxOptions::new());
            // 16 adjacent 256-B puts: 4 KiB total, must split into several
            // ≤1000-B runs — and still land byte-exact.
            for k in 0..16usize {
                let chunk = [(k + 1) as u8; 256];
                c.put_nbi(buf.slice(k * 256, 256), &chunk, 0);
            }
            c.quiet();
            let local = unsafe { ctx.local(buf) };
            for k in 0..16usize {
                assert!(
                    local[k * 256..(k + 1) * 256].iter().all(|&v| v == (k + 1) as u8),
                    "chunk {k} corrupted"
                );
            }
            c.destroy();
        });
    }

    /// Bulk puts bypass the batch (eager) but still count; the drain cap
    /// bounds queued memory.
    #[test]
    fn bulk_puts_are_eager_but_counted() {
        use super::NBI_DEFER_MAX_BYTES;
        use crate::ctx::CtxOptions;
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let world = ctx.team_world();
            let c = world.create_ctx(CtxOptions::new());
            let n = NBI_DEFER_MAX_BYTES / 8 + 1; // u64 count just over the cap
            let buf = ctx.shmalloc_n::<u64>(n).unwrap();
            let src = vec![5u64; n];
            c.put_nbi(buf, &src, 0);
            assert_eq!(c.pending_nbi(), 1);
            // Eager: already delivered, before any quiet.
            assert_eq!(unsafe { ctx.local(buf)[n - 1] }, 5);
            c.destroy();
            ctx.shfree(buf).unwrap();
        });
    }
}
