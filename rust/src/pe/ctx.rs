//! Per-PE context: identity, heap access, address translation, allocation.
//!
//! `Ctx` is the receiver of the whole POSH-RS API; sibling modules
//! (`p2p`, `atomics`, `locks`, `collectives`, `sync`) extend it with further
//! `impl Ctx` blocks. It is cheap to clone (an `Arc` and two integers).

use super::world::WorldShared;
use crate::symheap::layout::HeapHeader;
use crate::symheap::{SymHeap, SymPtr};
use crate::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// The context of one processing element.
#[derive(Clone)]
pub struct Ctx {
    pe: usize,
    shared: Arc<WorldShared>,
}

impl Ctx {
    pub(crate) fn new(pe: usize, shared: Arc<WorldShared>) -> Self {
        Self { pe, shared }
    }

    /// This PE's rank (`shmem_my_pe` / `_my_pe`).
    #[inline]
    pub fn my_pe(&self) -> usize {
        self.pe
    }

    /// Number of PEs in the job (`shmem_n_pes` / `_num_pes`).
    #[inline]
    pub fn n_pes(&self) -> usize {
        self.shared.n_pes
    }

    /// Job configuration.
    pub fn config(&self) -> &super::config::PoshConfig {
        &self.shared.cfg
    }

    /// The job's tuning engine: the fitted (or postulated) `T(n) = α + n/β`
    /// channel model plus the adaptive collective-selection and
    /// NBI-coalescing rules built on it. Identical on every PE of the job
    /// (process mode adopts rank 0's published model), which is what makes
    /// adaptive selection a job-wide agreement rather than a per-PE guess.
    #[inline]
    pub fn tuning(&self) -> &crate::collectives::Tuning {
        &self.shared.tuning
    }

    /// The job's blocked PE→socket map width: how many consecutive world
    /// ranks share a socket, `0` on a flat (single-socket or undetected)
    /// topology. Agreed job-wide at world creation — synthetic forcing
    /// (`--pes-per-socket`) beats sysfs detection, and process mode adopts
    /// rank 0's published geometry — because the two-level collective
    /// schedules deadlock if two PEs disagree on who leads a socket.
    #[inline]
    pub fn pes_per_socket(&self) -> usize {
        self.shared.tuning.pes_per_socket()
    }

    /// The socket a world rank maps to under the job's blocked map
    /// ([`Ctx::pes_per_socket`]); every PE is on socket 0 on a flat map.
    #[inline]
    pub fn socket_of(&self, pe: usize) -> usize {
        match self.pes_per_socket() {
            0 => 0,
            pps => pe / pps,
        }
    }

    /// Execution mode.
    pub fn mode(&self) -> super::config::Mode {
        self.shared.mode
    }

    /// Job id.
    pub fn job_id(&self) -> u64 {
        self.shared.job_id
    }

    #[allow(dead_code)]
    pub(crate) fn shared(&self) -> &WorldShared {
        &self.shared
    }

    /// The world team (`SHMEM_TEAM_WORLD`): every PE of the job, team rank
    /// = world rank. The starting point for [`crate::team::Team::split_strided`]
    /// and [`crate::team::Team::split_2d`].
    pub fn team_world(&self) -> crate::team::Team {
        crate::team::Team::world(self)
    }

    /// This PE's own symmetric heap.
    #[inline]
    pub fn heap(&self) -> &SymHeap {
        match self.shared.my_pe_fixed {
            Some(_) => &self.shared.local_heaps[0],
            None => &self.shared.local_heaps[self.pe],
        }
    }

    /// Base address of PE `pe`'s heap **in this address space** — the cached
    /// remote-table lookup of §4.1.1. In process mode the table demand-maps
    /// the peer's segment on first access (one `Acquire` load once mapped);
    /// thread mode keeps the flat world vector.
    #[inline]
    pub fn base_of(&self, pe: usize) -> *mut u8 {
        debug_assert!(pe < self.shared.n_pes);
        match &self.shared.remote {
            Some(table) => table.base_of(pe),
            None => self.shared.bases[pe].0,
        }
    }

    /// Mapping-activity counters of the process-mode remote-heap table
    /// (`None` in thread mode, where no demand mapping happens). What
    /// `oshrun info` and the lazy-mapping tests read.
    pub fn remote_table_stats(&self) -> Option<super::remote_table::RemoteTableStats> {
        self.shared.remote.as_ref().map(|t| t.stats())
    }

    /// Header of PE `pe`'s heap.
    #[inline]
    pub fn header_of(&self, pe: usize) -> &HeapHeader {
        // SAFETY: every base points at an initialised segment of the common
        // layout; headers are all-atomic.
        unsafe { HeapHeader::at(self.base_of(pe)) }
    }

    /// Resolve a symmetric handle on PE `pe` (Corollary 1: base + offset).
    ///
    /// # Safety
    /// The handle must denote a live symmetric object; access must respect
    /// the SHMEM race rules.
    #[inline]
    pub unsafe fn remote_addr<T>(&self, ptr: SymPtr<T>, pe: usize) -> *mut T {
        debug_assert!(
            ptr.offset() + ptr.byte_len() <= self.shared.layout.total,
            "handle outside segment"
        );
        ptr.resolve(self.base_of(pe))
    }

    /// Local view of a symmetric object as a mutable slice.
    ///
    /// # Safety
    /// No concurrent conflicting remote access (SHMEM memory model).
    pub unsafe fn local_mut<T>(&self, ptr: SymPtr<T>) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.remote_addr(ptr, self.pe), ptr.len())
    }

    /// Local view of a symmetric object as a shared slice.
    ///
    /// # Safety
    /// As [`Ctx::local_mut`].
    pub unsafe fn local<T>(&self, ptr: SymPtr<T>) -> &[T] {
        std::slice::from_raw_parts(self.remote_addr(ptr, self.pe), ptr.len())
    }

    // ---------------------------------------------------------------------
    // Symmetric allocation (§4.1.1): every entry point ends with the global
    // barrier the OpenSHMEM standard mandates, which is precisely what makes
    // Fact 1 enforceable.
    // ---------------------------------------------------------------------

    /// `shmalloc`: allocate `count` elements of `T`; collective.
    pub fn shmalloc_n<T>(&self, count: usize) -> Result<SymPtr<T>> {
        let p = self.heap().alloc_n::<T>(count)?;
        self.alloc_barrier();
        Ok(p)
    }

    /// `shmemalign`: aligned symmetric allocation; collective.
    pub fn shmemalign_n<T>(&self, align: usize, count: usize) -> Result<SymPtr<T>> {
        let p = self.heap().alloc_aligned_n::<T>(align, count)?;
        self.alloc_barrier();
        Ok(p)
    }

    /// `shfree`: free a symmetric allocation; collective.
    pub fn shfree<T>(&self, ptr: SymPtr<T>) -> Result<()> {
        self.heap().free(ptr)?;
        self.alloc_barrier();
        Ok(())
    }

    /// `shrealloc`: resize a symmetric allocation; collective.
    pub fn shrealloc<T>(&self, ptr: SymPtr<T>, new_count: usize) -> Result<SymPtr<T>> {
        let p = self.heap().realloc(ptr, new_count)?;
        self.alloc_barrier();
        Ok(p)
    }

    /// The barrier that terminates every symmetric allocation. In safe mode
    /// it additionally publishes the allocation-journal hash and verifies
    /// all PEs agree — turning a §6.4 "undefined behavior" programmer
    /// mistake into a loud error (Fact 1 checking).
    fn alloc_barrier(&self) {
        if self.config().safe {
            let h = self.heap().journal_hash();
            self.header_of(self.pe).journal_hash.store(h, Ordering::Release);
            self.barrier_all();
            for pe in 0..self.n_pes() {
                let other = self.header_of(pe).journal_hash.load(Ordering::Acquire);
                assert_eq!(
                    other, h,
                    "asymmetric allocation detected: PE {pe} journal {other:#x} != \
                     PE {} journal {h:#x} (OpenSHMEM §6.4 violation)",
                    self.pe
                );
            }
            self.barrier_all();
        } else {
            self.barrier_all();
        }
    }

    /// Spin until `cond` is true; polls the job abort flag so a dead peer
    /// fails the wait instead of hanging forever.
    #[inline]
    pub(crate) fn spin_wait(&self, mut cond: impl FnMut() -> bool) {
        let mut spins = 0u32;
        while !cond() {
            std::hint::spin_loop();
            spins = spins.wrapping_add(1);
            if spins & 0x3FF == 0 {
                if self.shared.abort.load(Ordering::Acquire) {
                    panic!("POSH job aborted (a peer PE failed)");
                }
                // Single-core friendliness: let the peer run.
                std::thread::yield_now();
            }
        }
    }
}

impl std::fmt::Debug for Ctx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Ctx{{pe={}/{}}}", self.pe, self.shared.n_pes)
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn identity() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            assert!(ctx.my_pe() < 3);
            assert_eq!(ctx.n_pes(), 3);
        });
    }

    #[test]
    fn symmetric_alloc_same_handle_everywhere() {
        let w = World::threads(4, PoshConfig::small()).unwrap();
        let handles = w.run_collect(|ctx| {
            let p = ctx.shmalloc_n::<u64>(32).unwrap();
            (p.offset(), p.len())
        });
        assert!(handles.windows(2).all(|w| w[0] == w[1]), "{handles:?}");
    }

    #[test]
    fn remote_addr_translation_consistent() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let p = ctx.shmalloc_n::<u32>(8).unwrap();
            unsafe {
                // My local address, translated via Corollary 1's formula,
                // equals the direct resolution on the peer's base.
                let peer = (ctx.my_pe() + 1) % 2;
                let local = ctx.remote_addr(p, ctx.my_pe()) as *const u8;
                let formula = crate::symheap::handle::translate(
                    local,
                    ctx.base_of(ctx.my_pe()),
                    ctx.base_of(peer),
                );
                assert_eq!(formula as *mut u32, ctx.remote_addr(p, peer));
            }
            ctx.barrier_all();
            ctx.shfree(p).unwrap();
        });
    }

    #[test]
    fn local_mut_reads_back() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let p = ctx.shmalloc_n::<i32>(4).unwrap();
            unsafe {
                ctx.local_mut(p).copy_from_slice(&[1, -2, 3, -4]);
                assert_eq!(ctx.local(p), &[1, -2, 3, -4]);
            }
        });
    }

    #[cfg(feature = "safe-mode")]
    #[test]
    fn asymmetric_alloc_detected_in_safe_mode() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            w.run(|ctx| {
                if ctx.my_pe() == 0 {
                    let _ = ctx.shmalloc_n::<u8>(100).unwrap();
                } else {
                    let _ = ctx.shmalloc_n::<u8>(200).unwrap();
                }
            });
        }));
        assert!(r.is_err());
    }
}
