//! Size-aware copy dispatch: the [`CopyPlan`].
//!
//! The paper selects ONE memcpy implementation per build (§4.4) because on
//! 2014 hardware the ranking was stable across sizes. On modern cores it is
//! not: temporal vector copies win while the working set fits in cache, and
//! non-temporal streaming stores win once a copy is larger than the LLC
//! (past that point every temporal store costs a read-for-ownership plus an
//! eventual writeback of a line nobody will re-read). Tiny copies are won by
//! plain `ptr::copy` — the vector loops' alignment prologue is pure overhead
//! at 8–256 bytes.
//!
//! A [`CopyPlan`] captures that piecewise ranking as two thresholds and
//! three engines:
//!
//! ```text
//!   len ≤ small_max          → small engine (stock memcpy)
//!   small_max < len < nt_min → mid engine   (widest temporal vector)
//!   len ≥ nt_min             → large engine (widest non-temporal vector)
//! ```
//!
//! `nt_min` is derived from the LLC size ([`CacheInfo::detect`], sysfs with
//! paper-constant fallback): ¾·LLC with a 1 MiB floor, so NT stores only
//! engage when a copy genuinely overflows cache. The process-wide plan is
//! consulted by [`super::copy::copy_bytes`] whenever no engine is forced.

use super::copy::CopyImpl;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::Once;

/// Where a [`CacheInfo`] came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheSource {
    /// Read from `/sys/devices/system/cpu/cpu0/cache`.
    Sysfs,
    /// Paper-era defaults (sysfs absent or unparsable).
    PaperDefault,
}

impl std::fmt::Display for CacheSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheSource::Sysfs => write!(f, "sysfs"),
            CacheSource::PaperDefault => write!(f, "paper-default"),
        }
    }
}

/// Per-core cache hierarchy sizes in bytes, used to place both the copy
/// plan's NT threshold and the piecewise cost-model bucket boundaries
/// (`collectives::tuning`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheInfo {
    /// L1 data cache size.
    pub l1d: usize,
    /// L2 (private) cache size.
    pub l2: usize,
    /// Last-level cache size (largest level reported).
    pub llc: usize,
    /// Whether the numbers came from sysfs or the paper-constant fallback.
    pub source: CacheSource,
}

/// Paper-era fallback: the Nehalem-class machines of §5 (32 KiB L1d,
/// 256 KiB L2, 8 MiB shared L3).
pub const PAPER_L1D: usize = 32 << 10;
/// See [`PAPER_L1D`].
pub const PAPER_L2: usize = 256 << 10;
/// See [`PAPER_L1D`].
pub const PAPER_LLC: usize = 8 << 20;

impl CacheInfo {
    /// The paper-constant fallback hierarchy.
    pub const fn paper_default() -> CacheInfo {
        CacheInfo {
            l1d: PAPER_L1D,
            l2: PAPER_L2,
            llc: PAPER_LLC,
            source: CacheSource::PaperDefault,
        }
    }

    /// Detect the cache hierarchy of cpu0 from sysfs, falling back to
    /// [`CacheInfo::paper_default`] when sysfs is absent (non-Linux,
    /// sandboxes) or yields nothing usable.
    pub fn detect() -> CacheInfo {
        Self::from_sysfs_root("/sys/devices/system/cpu/cpu0/cache")
            .unwrap_or_else(CacheInfo::paper_default)
    }

    /// Parse `<root>/index*/{level,type,size}`. Returns `None` unless at
    /// least an L1 data/unified cache was found.
    fn from_sysfs_root(root: &str) -> Option<CacheInfo> {
        let mut l1d = 0usize;
        let mut l2 = 0usize;
        let mut llc = 0usize;
        let mut llc_level = 0u32;
        for idx in 0..16 {
            let dir = format!("{root}/index{idx}");
            let read = |leaf: &str| std::fs::read_to_string(format!("{dir}/{leaf}")).ok();
            let (level, ty, size) = match (read("level"), read("type"), read("size")) {
                (Some(l), Some(t), Some(s)) => (l, t, s),
                _ => continue,
            };
            let level: u32 = match level.trim().parse() {
                Ok(l) => l,
                Err(_) => continue,
            };
            let ty = ty.trim().to_ascii_lowercase();
            if ty == "instruction" {
                continue;
            }
            let size = match crate::pe::config::parse_size(size.trim()) {
                Some(s) if s > 0 => s,
                _ => continue,
            };
            if level == 1 {
                l1d = l1d.max(size);
            }
            if level == 2 {
                l2 = l2.max(size);
            }
            if level > llc_level || (level == llc_level && size > llc) {
                llc_level = level;
                llc = size;
            }
        }
        if l1d == 0 {
            return None;
        }
        if l2 == 0 {
            l2 = l1d.max(PAPER_L2.min(llc.max(l1d)));
        }
        if llc < l2 {
            llc = l2;
        }
        Some(CacheInfo {
            l1d,
            l2,
            llc,
            source: CacheSource::Sysfs,
        })
    }
}

/// Default small-copy cutoff: below this, `ptr::copy` (which compilers lower
/// to tuned inline sequences / `rep movsb`) beats every explicit vector loop
/// with its alignment prologue.
pub const DEFAULT_SMALL_MAX: usize = 256;

/// Floor for the NT threshold: never stream below 1 MiB even on tiny-LLC
/// machines — the sfence + cache-bypass tax needs a long copy to amortise.
pub const NT_MIN_FLOOR: usize = 1 << 20;

/// A per-size-class copy engine selection. See the module docs for the
/// three-range layout and threshold semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CopyPlan {
    /// Copies of `len <= small_max` use [`CopyPlan::small`].
    pub small_max: usize,
    /// Copies of `len >= nt_min` use [`CopyPlan::large`]; in between,
    /// [`CopyPlan::mid`].
    pub nt_min: usize,
    /// Engine for the small range (`ptr::copy`).
    pub small: CopyImpl,
    /// Engine for the cache-resident middle range (widest temporal vector).
    pub mid: CopyImpl,
    /// Engine for the past-LLC range (widest non-temporal vector).
    pub large: CopyImpl,
}

impl CopyPlan {
    /// Build the plan for this machine: thresholds from `cache`, engines
    /// from what the CPU advertises. `POSH_PLAN_SMALL_MAX` /
    /// `POSH_PLAN_NT_MIN` (size strings: `4096`, `1M`, …) override the
    /// thresholds for experiments.
    pub fn for_machine(cache: &CacheInfo) -> CopyPlan {
        let mut small_max = DEFAULT_SMALL_MAX;
        let mut nt_min = NT_MIN_FLOOR.max(cache.llc / 4 * 3);
        if let Ok(v) = std::env::var("POSH_PLAN_SMALL_MAX") {
            if let Some(n) = crate::pe::config::parse_size(&v) {
                small_max = n;
            }
        }
        if let Ok(v) = std::env::var("POSH_PLAN_NT_MIN") {
            if let Some(n) = crate::pe::config::parse_size(&v) {
                nt_min = n;
            }
        }
        // Keep the ranges well-ordered whatever the overrides say.
        nt_min = nt_min.max(small_max + 1);

        let (mid, large);
        #[cfg(target_arch = "x86_64")]
        {
            mid = if std::arch::is_x86_feature_detected!("avx512f") {
                CopyImpl::Avx512
            } else if std::arch::is_x86_feature_detected!("avx2") {
                CopyImpl::Avx2
            } else {
                CopyImpl::Sse2
            };
            large = if std::arch::is_x86_feature_detected!("avx512f") {
                CopyImpl::Avx512Nt
            } else {
                CopyImpl::NonTemporal
            };
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            // No vector/NT engines off x86_64: one engine everywhere.
            mid = CopyImpl::Unrolled64;
            large = CopyImpl::Unrolled64;
        }
        CopyPlan {
            small_max,
            nt_min,
            small: CopyImpl::Stock,
            mid,
            large,
        }
    }

    /// The engine this plan dispatches a `len`-byte copy to.
    #[inline]
    pub fn engine_for(&self, len: usize) -> CopyImpl {
        if len <= self.small_max {
            self.small
        } else if len < self.nt_min {
            self.mid
        } else {
            self.large
        }
    }
}

impl std::fmt::Display for CopyPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}<={}B; {}<{}B; {}>={}B",
            self.small.name(),
            self.small_max,
            self.mid.name(),
            self.nt_min,
            self.large.name(),
            self.nt_min
        )
    }
}

// Process-wide plan storage. Plain atomics (no lock on the copy hot path);
// a torn read across fields is benign because every engine is byte-correct
// for every size — the plan only affects speed, never correctness.
static PLAN_SMALL_MAX: AtomicUsize = AtomicUsize::new(DEFAULT_SMALL_MAX);
static PLAN_NT_MIN: AtomicUsize = AtomicUsize::new(usize::MAX);
static PLAN_SMALL: AtomicU8 = AtomicU8::new(CopyImpl::Stock as u8);
static PLAN_MID: AtomicU8 = AtomicU8::new(CopyImpl::Stock as u8);
static PLAN_LARGE: AtomicU8 = AtomicU8::new(CopyImpl::Stock as u8);
static PLAN_INIT: Once = Once::new();

fn store_plan(plan: &CopyPlan) {
    PLAN_SMALL_MAX.store(plan.small_max, Ordering::Relaxed);
    PLAN_NT_MIN.store(plan.nt_min, Ordering::Relaxed);
    PLAN_SMALL.store(plan.small as u8, Ordering::Relaxed);
    PLAN_MID.store(plan.mid as u8, Ordering::Relaxed);
    PLAN_LARGE.store(plan.large as u8, Ordering::Relaxed);
}

/// Install `plan` as the process-wide dispatch plan (start-up, tests,
/// benches). Overrides the lazily-detected machine default.
pub fn install_global_plan(plan: &CopyPlan) {
    // Claim the Once so a later global_plan() can't clobber us with the
    // detected default.
    PLAN_INIT.call_once(|| {});
    store_plan(plan);
}

/// Serialises tests that mutate process-wide dispatch state (the global
/// plan here, the forced engine in `copy.rs`) — the test harness runs tests
/// on parallel threads.
#[cfg(test)]
pub(crate) static TEST_DISPATCH_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn ensure_plan() {
    PLAN_INIT.call_once(|| {
        let plan = CopyPlan::for_machine(&CacheInfo::detect());
        store_plan(&plan);
    });
}

/// The current process-wide [`CopyPlan`]. First use detects the machine
/// ([`CacheInfo::detect`] + [`CopyPlan::for_machine`]) unless
/// [`install_global_plan`] ran earlier.
pub fn global_plan() -> CopyPlan {
    ensure_plan();
    let decode = |a: &AtomicU8| {
        CopyImpl::from_u8(a.load(Ordering::Relaxed)).unwrap_or(CopyImpl::Stock)
    };
    CopyPlan {
        small_max: PLAN_SMALL_MAX.load(Ordering::Relaxed),
        nt_min: PLAN_NT_MIN.load(Ordering::Relaxed),
        small: decode(&PLAN_SMALL),
        mid: decode(&PLAN_MID),
        large: decode(&PLAN_LARGE),
    }
}

/// Hot-path resolve: the engine the global plan dispatches a `len`-byte
/// copy to, touching only the two threshold words and one engine byte
/// (cheaper than materialising the whole [`CopyPlan`] per copy).
#[inline]
pub fn planned_engine_for(len: usize) -> CopyImpl {
    ensure_plan();
    let cell = if len <= PLAN_SMALL_MAX.load(Ordering::Relaxed) {
        &PLAN_SMALL
    } else if len < PLAN_NT_MIN.load(Ordering::Relaxed) {
        &PLAN_MID
    } else {
        &PLAN_LARGE
    };
    CopyImpl::from_u8(cell.load(Ordering::Relaxed)).unwrap_or(CopyImpl::Stock)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::copy::copy_bytes_with;
    use crate::util::prng::Rng;

    #[test]
    fn paper_default_is_ordered() {
        let c = CacheInfo::paper_default();
        assert!(c.l1d < c.l2 && c.l2 < c.llc);
        assert_eq!(c.source, CacheSource::PaperDefault);
    }

    #[test]
    fn detect_is_sane() {
        // Whatever the box (sysfs present or not), detection must yield a
        // usable, ordered hierarchy.
        let c = CacheInfo::detect();
        assert!(c.l1d > 0);
        assert!(c.l2 >= c.l1d);
        assert!(c.llc >= c.l2);
    }

    #[test]
    fn machine_plan_is_well_formed() {
        let plan = CopyPlan::for_machine(&CacheInfo::detect());
        assert!(plan.small_max < plan.nt_min);
        assert!(plan.nt_min >= NT_MIN_FLOOR.min(plan.small_max + 1));
        let avail = CopyImpl::available();
        assert!(avail.contains(&plan.small));
        assert!(avail.contains(&plan.mid));
        assert!(avail.contains(&plan.large));
    }

    #[test]
    fn dispatch_boundaries() {
        let plan = CopyPlan {
            small_max: 256,
            nt_min: 4096,
            small: CopyImpl::Stock,
            mid: CopyImpl::Unrolled64,
            large: CopyImpl::NonTemporal,
        };
        assert_eq!(plan.engine_for(0), CopyImpl::Stock);
        assert_eq!(plan.engine_for(255), CopyImpl::Stock);
        assert_eq!(plan.engine_for(256), CopyImpl::Stock);
        assert_eq!(plan.engine_for(257), CopyImpl::Unrolled64);
        assert_eq!(plan.engine_for(4095), CopyImpl::Unrolled64);
        assert_eq!(plan.engine_for(4096), CopyImpl::NonTemporal);
        assert_eq!(plan.engine_for(4097), CopyImpl::NonTemporal);
        assert_eq!(plan.engine_for(usize::MAX), CopyImpl::NonTemporal);
    }

    /// The canary battery from `copy.rs`, pointed at planned dispatch:
    /// every size-class boundary ±1 of a realistic plan must deliver
    /// byte-exact copies with no over/underwrite, through whichever engine
    /// the plan resolves.
    #[test]
    fn planned_boundaries_byte_exact() {
        // Small thresholds so the test exercises all three engines without
        // hundred-MiB allocations; engines are the real machine ones.
        let machine = CopyPlan::for_machine(&CacheInfo::detect());
        let plan = CopyPlan {
            small_max: 256,
            nt_min: 8192,
            ..machine
        };
        let mut rng = Rng::new(0x504C414E); // "PLAN"
        let mut lens = vec![0usize, 1];
        for b in [plan.small_max, plan.nt_min] {
            lens.extend_from_slice(&[b - 1, b, b + 1]);
        }
        lens.push(plan.nt_min * 4);
        for &len in &lens {
            for &(doff, soff) in &[(0usize, 0usize), (1, 0), (0, 1), (3, 5), (7, 9)] {
                let imp = plan.engine_for(len);
                let mut src = vec![0u8; len + soff];
                rng.fill_bytes(&mut src);
                let mut dst = vec![0xAAu8; len + doff + 1];
                let canary_idx = len + doff;
                dst[canary_idx] = 0x5C;
                unsafe {
                    copy_bytes_with(imp, dst.as_mut_ptr().add(doff), src.as_ptr().add(soff), len);
                }
                assert_eq!(
                    &dst[doff..doff + len],
                    &src[soff..soff + len],
                    "{imp:?} len={len} doff={doff} soff={soff}"
                );
                assert_eq!(dst[canary_idx], 0x5C, "{imp:?} overwrote past end (len={len})");
                assert!(
                    dst[..doff].iter().all(|&b| b == 0xAA),
                    "{imp:?} underwrote (len={len})"
                );
            }
        }
    }

    #[test]
    fn global_plan_install_roundtrip() {
        let _guard = TEST_DISPATCH_LOCK.lock().unwrap();
        let before = global_plan();
        let custom = CopyPlan {
            small_max: 128,
            nt_min: 1 << 21,
            small: CopyImpl::Stock,
            mid: CopyImpl::Unrolled64,
            large: CopyImpl::Unrolled64,
        };
        install_global_plan(&custom);
        assert_eq!(global_plan(), custom);
        // The hot-path resolve agrees with the materialised plan.
        for len in [0usize, 127, 128, 129, (1 << 21) - 1, 1 << 21, 1 << 22] {
            assert_eq!(planned_engine_for(len), custom.engine_for(len), "len={len}");
        }
        install_global_plan(&before);
        assert_eq!(global_plan(), before);
    }

    #[test]
    fn display_lists_all_ranges() {
        let plan = CopyPlan::for_machine(&CacheInfo::paper_default());
        let s = plan.to_string();
        assert!(s.contains(plan.small.name()));
        assert!(s.contains(plan.mid.name()));
        assert!(s.contains(plan.large.name()));
    }
}
