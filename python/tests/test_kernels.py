"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle,
with hypothesis sweeping shapes and dtypes — the core build-time signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import copy as copy_k
from compile.kernels import matmul as matmul_k
from compile.kernels import reduce as reduce_k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 64),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_any_shape(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (m, k), jnp.float32)
    w = jax.random.normal(k2, (k, n), jnp.float32)
    got = matmul_k.matmul(x, w, bm=32, bn=32, bk=32)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 128), jnp.float32).astype(dtype)
    w = jax.random.normal(key, (128, 32), jnp.float32).astype(dtype)
    got = matmul_k.matmul(x, w)
    want = ref.matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("blocks", [(128, 128, 128), (64, 64, 64), (32, 128, 64)])
def test_matmul_block_shapes_agree(blocks):
    bm, bn, bk = blocks
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (128, 128), jnp.float32)
    w = jax.random.normal(key, (128, 256), jnp.float32)
    got = matmul_k.matmul(x, w, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)


def test_matmul_model_shape_exact():
    # The exact shapes the transformer MLP uses.
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (256, 128), jnp.float32)
    w = jax.random.normal(key, (128, 512), jnp.float32)
    np.testing.assert_allclose(
        matmul_k.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_matmul_vmem_within_budget():
    # Default blocks must fit comfortably in a 16 MiB VMEM.
    assert matmul_k.vmem_footprint_bytes(128, 128, 128) <= 16 << 20


# ------------------------------------------------------------------ copy


@settings(**SETTINGS)
@given(
    m=st.integers(1, 300),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_copy_matches_ref_any_shape(m, n, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (m, n), jnp.float32)
    got = copy_k.copy_tiled(x, bm=64, bn=64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.copy_ref(x)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32, jnp.bfloat16])
def test_copy_preserves_dtype(dtype):
    x = jnp.arange(128 * 256).reshape(128, 256).astype(dtype)
    got = copy_k.copy_tiled(x)
    assert got.dtype == dtype
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


@pytest.mark.parametrize("name,blocks", sorted(copy_k.VARIANTS.items()))
def test_copy_all_variants(name, blocks):
    bm, bn = blocks
    x = jax.random.normal(jax.random.PRNGKey(3), (1024, 1024), jnp.float32)
    got = copy_k.copy_tiled(x, bm=bm, bn=bn)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    # every variant must fit VMEM
    assert copy_k.vmem_footprint_bytes(bm, bn) <= 16 << 20, name


# ---------------------------------------------------------------- reduce


@settings(**SETTINGS)
@given(
    shards=st.integers(1, 12),
    chunk=st.integers(1, 4096),
    seed=st.integers(0, 2**31 - 1),
)
def test_reduce_matches_ref(shards, chunk, seed):
    key = jax.random.PRNGKey(seed)
    parts = jax.random.normal(key, (shards, chunk), jnp.float32)
    got = reduce_k.sum_reduce(parts)
    want = ref.sum_reduce_ref(parts)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_reduce_exact_on_integers():
    parts = jnp.arange(8 * 1024, dtype=jnp.float32).reshape(8, 1024)
    got = reduce_k.sum_reduce(parts)
    want = ref.sum_reduce_ref(parts)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------- matmul VJP


def test_matmul_custom_vjp_matches_jnp_grad():
    """The Pallas matmul's custom VJP must agree with autodiff through the
    plain jnp reference — this is what makes the lowered train_step's
    backward pass trustworthy."""
    key = jax.random.PRNGKey(42)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (32, 64), jnp.float32)
    w = jax.random.normal(k2, (64, 16), jnp.float32)
    g = jax.random.normal(k3, (32, 16), jnp.float32)

    def loss_pallas(x, w):
        return jnp.sum(matmul_k.matmul(x, w, bm=32, bn=16, bk=32) * g)

    def loss_ref(x, w):
        return jnp.sum(ref.matmul_ref(x, w) * g)

    dx_p, dw_p = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
    dx_r, dw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(dx_p, dx_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dw_p, dw_r, rtol=1e-4, atol=1e-4)


def test_matmul_grad_through_composition():
    """Gradient flows through chained Pallas matmuls (the MLP pattern)."""
    key = jax.random.PRNGKey(5)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (16, 32), jnp.float32)
    w1 = jax.random.normal(k2, (32, 32), jnp.float32)
    w2 = jax.random.normal(k3, (32, 8), jnp.float32)

    def f(w1, w2):
        h = jax.nn.gelu(matmul_k.matmul(x, w1, bm=16, bn=32, bk=32))
        return jnp.sum(matmul_k.matmul(h, w2, bm=16, bn=8, bk=32) ** 2)

    def f_ref(w1, w2):
        h = jax.nn.gelu(ref.matmul_ref(x, w1))
        return jnp.sum(ref.matmul_ref(h, w2) ** 2)

    g1, g2 = jax.grad(f, argnums=(0, 1))(w1, w2)
    r1, r2 = jax.grad(f_ref, argnums=(0, 1))(w1, w2)
    np.testing.assert_allclose(g1, r1, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(g2, r2, rtol=1e-3, atol=1e-3)
