//! **Ablation B** (DESIGN.md §3) — barrier algorithms: dissemination vs
//! central counter across PE counts, plus the legacy active-set barrier and
//! the team syncs of the 1.4/1.5 surface. Since the one-engine refactor,
//! `barrier_all` *is* the dissemination sync over the world team's slot-0
//! cells, and team syncs run the same engine over their own slot — so the
//! interesting A/B here is `team-dissem` (O(log n) rounds, the production
//! default) vs `team-linear` (the retired linear fan-in on the team root,
//! kept behind `PoshConfig::team_barrier` / `POSH_TEAM_BARRIER=linear` for
//! exactly this comparison).

use posh::bench::{measure, Table};
use posh::collectives::ActiveSet;
use posh::pe::{BarrierKind, PoshConfig, TeamBarrierKind, World};
use std::sync::atomic::{AtomicU64, Ordering};

fn bench_barrier(n: usize, kind: BarrierKind) -> f64 {
    let mut cfg = PoshConfig::small();
    cfg.barrier = kind;
    let w = World::threads(n, cfg).unwrap();
    let ns = AtomicU64::new(0);
    w.run(|ctx| {
        ctx.barrier_all();
        let m = measure(0, 200, || {
            ctx.barrier_all();
        });
        if ctx.my_pe() == 0 {
            ns.store(m.latency_ns() as u64, Ordering::Relaxed);
        }
        ctx.barrier_all();
    });
    ns.load(Ordering::Relaxed) as f64
}

fn bench_set_barrier(n: usize) -> f64 {
    let w = World::threads(n, PoshConfig::small()).unwrap();
    let ns = AtomicU64::new(0);
    w.run(|ctx| {
        let set = ActiveSet::world(n);
        ctx.barrier_all();
        let m = measure(0, 200, || {
            ctx.barrier_set(&set);
        });
        if ctx.my_pe() == 0 {
            ns.store(m.latency_ns() as u64, Ordering::Relaxed);
        }
        ctx.barrier_all();
    });
    ns.load(Ordering::Relaxed) as f64
}

/// Team sync over the whole world team (reserved slot 0 cells), with the
/// given engine — the dissemination-vs-linear-fan-in A/B column pair.
/// `pps > 0` forces a synthetic `pps`-PEs-per-socket map so the
/// hierarchical engine runs a real two-level sync (on a flat map it
/// degenerates to the fan-in it is built from).
fn bench_team_sync_world(n: usize, kind: TeamBarrierKind, pps: usize) -> f64 {
    let mut cfg = PoshConfig::small();
    cfg.team_barrier = Some(kind);
    if pps > 0 {
        cfg.pes_per_socket = Some(pps);
    }
    let w = World::threads(n, cfg).unwrap();
    let ns = AtomicU64::new(0);
    w.run(|ctx| {
        let team = ctx.team_world();
        ctx.barrier_all();
        let m = measure(0, 200, || {
            team.sync();
        });
        if ctx.my_pe() == 0 {
            ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            // The acceptance hook: dissemination completes in ⌈log₂ n⌉
            // rounds, the linear baseline serialises through n−1.
            eprintln!(
                "# team sync {n} PEs {kind:?}: {} rounds",
                ctx.last_sync_rounds()
            );
        }
        ctx.barrier_all();
    });
    ns.load(Ordering::Relaxed) as f64
}

/// Team sync over a split team of half the PEs (claimed slot cells):
/// the non-members idle, so this measures a sub-world ordering domain.
fn bench_team_sync_half(n: usize) -> f64 {
    let w = World::threads(n, PoshConfig::small()).unwrap();
    let ns = AtomicU64::new(0);
    w.run(|ctx| {
        let half = ctx.team_world().split_strided(0, 1, (n + 1) / 2);
        ctx.barrier_all();
        if let Some(team) = &half {
            let m = measure(0, 200, || {
                team.sync();
            });
            if ctx.my_pe() == 0 {
                ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            }
        }
        ctx.barrier_all();
        if let Some(team) = half {
            team.destroy();
        }
        ctx.barrier_all();
    });
    ns.load(Ordering::Relaxed) as f64
}

fn main() {
    let mut t = Table::new(
        "Ablation B: barrier latency",
        "ns/op",
        &[
            "dissemination",
            "central",
            "set-linear",
            "team-dissem",
            "team-linear",
            "team-hier",
            "team-half",
        ],
    );
    for &n in &[2usize, 4, 8, 16] {
        t.row(
            &format!("{n} PEs"),
            vec![
                bench_barrier(n, BarrierKind::Dissemination),
                bench_barrier(n, BarrierKind::Central),
                bench_set_barrier(n),
                bench_team_sync_world(n, TeamBarrierKind::Dissemination, 0),
                bench_team_sync_world(n, TeamBarrierKind::LinearFanin, 0),
                bench_team_sync_world(n, TeamBarrierKind::Hierarchical, 2),
                bench_team_sync_half(n),
            ],
        );
    }
    t.print();
    t.write_csv("ablationB_barrier").unwrap();
    println!("\n(1-core container: expect flat-ish numbers dominated by \
              scheduling; on a real multicore the dissemination engine's \
              log-n rounds separate from the linear fan-in's serial chain \
              as n grows — team-dissem vs team-linear is the direct A/B on \
              identical cells. team-hier forces a synthetic 2-per-socket \
              map, so it runs the two-level sync: socket-local fan-in, \
              leader dissemination, local release — on a real NUMA box it \
              should undercut team-dissem once n spans sockets. team-half \
              synchronises n/2 PEs, so it should sit below the full-world \
              columns)");
    println!("csv: bench_out/ablationB_barrier.csv");
}
