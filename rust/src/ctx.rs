//! Communication contexts (OpenSHMEM 1.4 §8): explicit ordering domains for
//! point-to-point traffic.
//!
//! A [`CommCtx`] is created from a [`Team`] and carries its **own**
//! non-blocking-implicit (NBI) domain — private accounting *and* a private
//! deferred-put batch (`p2p::nbi::NbiBatch`). Small `put_nbi`s are queued,
//! not issued; `ctx.quiet()` is a **batched drain**: it issues and retires
//! only the operations of *this* context — no process-wide fence, and
//! never the default context's or a sibling context's traffic. That is the
//! whole point — two independent streams of NBI puts (say, a gradient push
//! and a metrics trickle) quiesce independently instead of serialising
//! through the one global domain (and one global `mfence`) OpenSHMEM 1.0
//! offered.
//!
//! PE arguments to context operations are **team-relative** (translated
//! through the team the context was created from), matching the 1.4
//! team/context contract.
//!
//! The default context is the thread-local accounting behind
//! [`Ctx::put_nbi`]/[`Ctx::quiet_nbi`](crate::pe::Ctx) — exactly the 1.0
//! behaviour, untouched. See `docs/memory_model.md` §"Per-context ordering"
//! for the guarantee→test mapping.
//!
//! **Threads.** A `CommCtx` is `Send + Sync`: under
//! `SHMEM_THREAD_MULTIPLE` ([`crate::api::ThreadLevel::Multiple`]) many
//! threads may drive one context concurrently — the deferred-put queue is
//! sharded per thread, so the `put_nbi` issue path takes no lock, and
//! concurrent `quiet`s each retire exactly what they deliver (see
//! [`crate::p2p::nbi`] and `docs/memory_model.md` §"Thread levels"). The
//! `SERIALIZED`/`PRIVATE` options remain *promises*, not requirements for
//! soundness. For per-thread completion state — so one thread's quiet
//! cannot stall another's — use [`Team::ctx_for_thread`], which pools a
//! private `SERIALIZED` context per calling thread.

use crate::p2p::nbi::{NbiBatch, NbiDomain};
use crate::pe::Ctx;
use crate::symheap::SymPtr;
use crate::team::Team;

/// Creation options for a [`CommCtx`] (`SHMEM_CTX_SERIALIZED` /
/// `SHMEM_CTX_PRIVATE`). Both are *promises the program makes*, recorded on
/// the context and available to future scheduling decisions; neither changes
/// the memory-ordering guarantees.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CtxOptions {
    /// The program promises not to use the context from multiple threads
    /// concurrently (it may still move it between threads).
    pub serialized: bool,
    /// The program promises the context is used only by the creating
    /// thread.
    pub private: bool,
}

impl CtxOptions {
    /// No promises: the context may be shared freely.
    pub fn new() -> CtxOptions {
        CtxOptions::default()
    }

    /// Set the `SERIALIZED` promise.
    pub fn serialized(mut self) -> CtxOptions {
        self.serialized = true;
        self
    }

    /// Set the `PRIVATE` promise.
    pub fn private(mut self) -> CtxOptions {
        self.private = true;
        self
    }
}

/// An explicit communication context: a private NBI ordering domain bound
/// to a team.
///
/// Not `Clone` — the identity of a context *is* its accounting; hand out
/// references instead.
#[derive(Debug)]
pub struct CommCtx {
    ctx: Ctx,
    team: Team,
    opts: CtxOptions,
    /// This context's NBI domain: issued-but-unretired accounting plus the
    /// deferred-put batch a [`CommCtx::quiet`] drains.
    batch: NbiBatch,
}

impl CommCtx {
    /// `shmem_ctx_create`: build a context over `team`'s communication
    /// domain. The calling PE must be a member of the team.
    pub fn create(team: &Team, opts: CtxOptions) -> CommCtx {
        assert!(team.is_member(), "shmem_ctx_create: calling PE is not a member of the team");
        CommCtx {
            ctx: team.ctx().clone(),
            team: team.clone(),
            opts,
            batch: NbiBatch::new(),
        }
    }

    /// The team this context was created from (`shmem_ctx_get_team`).
    pub fn team(&self) -> &Team {
        &self.team
    }

    /// The options the context was created with.
    pub fn options(&self) -> CtxOptions {
        self.opts
    }

    /// This PE's rank within the context's team.
    pub fn my_pe(&self) -> usize {
        self.team.my_pe()
    }

    /// Number of PEs in the context's team.
    pub fn n_pes(&self) -> usize {
        self.team.n_pes()
    }

    /// Translate a team-relative PE argument to a world rank.
    #[inline]
    fn world_pe(&self, pe: usize) -> usize {
        self.team.world_rank(pe)
    }

    /// The explicit NBI domain of this context.
    #[inline]
    fn domain(&self) -> NbiDomain<'_> {
        NbiDomain::Explicit(&self.batch)
    }

    // -----------------------------------------------------------------
    // Blocking RMA (team-relative PE numbering).
    // -----------------------------------------------------------------

    /// `shmem_ctx_put`: blocking put to team rank `pe`.
    pub fn put<T: Copy>(&self, dest: SymPtr<T>, src: &[T], pe: usize) {
        self.ctx.put(dest, src, self.world_pe(pe));
    }

    /// `shmem_ctx_get`: blocking get from team rank `pe`.
    pub fn get<T: Copy>(&self, dest: &mut [T], src: SymPtr<T>, pe: usize) {
        self.ctx.get(dest, src, self.world_pe(pe));
    }

    /// `shmem_ctx_p`: single-element put.
    pub fn put_one<T: Copy>(&self, dest: SymPtr<T>, value: T, pe: usize) {
        self.ctx.put_one(dest, value, self.world_pe(pe));
    }

    /// `shmem_ctx_g`: single-element get.
    pub fn get_one<T: Copy>(&self, src: SymPtr<T>, pe: usize) -> T {
        self.ctx.get_one(src, self.world_pe(pe))
    }

    // -----------------------------------------------------------------
    // Non-blocking-implicit RMA: accounted on *this* context only.
    // -----------------------------------------------------------------

    /// `shmem_ctx_put_nbi`: start a put on this context; complete at the
    /// next [`CommCtx::quiet`]. Puts up to
    /// [`crate::p2p::nbi::NBI_DEFER_MAX_BYTES`] are *deferred* into this
    /// context's batch and delivered by the quiet's drain; larger ones are
    /// issued eagerly but retire with the same quiet.
    pub fn put_nbi<T: Copy>(&self, dest: SymPtr<T>, src: &[T], pe: usize) {
        let world = self.world_pe(pe);
        self.ctx.put_nbi_domain(&self.domain(), dest, src, world);
    }

    /// `shmem_ctx_get_nbi`: start a get on this context; the value is only
    /// guaranteed after the next [`CommCtx::quiet`].
    pub fn get_nbi<T: Copy>(&self, dest: &mut [T], src: SymPtr<T>, pe: usize) {
        let world = self.world_pe(pe);
        self.ctx.get_nbi_domain(&self.domain(), dest, src, world);
    }

    /// NBI operations issued on this context and not yet retired (both
    /// deferred-into-the-batch and eagerly-issued bulk ones).
    pub fn pending_nbi(&self) -> u64 {
        self.batch.pending()
    }

    // -----------------------------------------------------------------
    // Ordering, scoped to this context.
    // -----------------------------------------------------------------

    /// `shmem_ctx_quiet`: complete and retire the NBI operations issued on
    /// **this** context — a batched drain of the deferred puts followed by
    /// a release fence, *not* a process-wide completion fence. Pending
    /// operations on the default context or on sibling contexts are
    /// untouched: not delivered, not fenced for, not retired.
    pub fn quiet(&self) {
        self.ctx.quiet_domain(&self.domain());
    }

    /// `shmem_ctx_fence`: order the puts issued on this context per
    /// destination PE. Drains the deferred batch (delivery order must
    /// respect the fence) but does not retire NBI accounting (fences never
    /// do).
    pub fn fence(&self) {
        self.ctx.fence_domain(&self.domain());
    }

    /// `shmem_ctx_destroy`: quiesce the context and drop it. All pending
    /// NBI operations are completed first, as the spec requires.
    pub fn destroy(self) {
        self.quiet();
    }
}

impl Drop for CommCtx {
    /// Safety net for the deferred batch: a context dropped without
    /// [`CommCtx::destroy`] still quiesces, so queued puts are delivered,
    /// never silently discarded. (Redundant after `destroy` — the queue is
    /// already empty.)
    fn drop(&mut self) {
        self.quiet();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};

    #[test]
    fn ctx_ops_are_team_relative() {
        let w = World::threads(4, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let world = ctx.team_world();
            // Odd ranks 1, 3 — team ranks 0, 1.
            let odds = world.split_strided(1, 2, 2);
            let cell = ctx.shmalloc_n::<u64>(1).unwrap();
            if let Some(t) = &odds {
                let c = t.create_ctx(CtxOptions::new());
                assert_eq!(c.n_pes(), 2);
                // Team rank 0 writes team rank 1's cell: world PE 1 → 3.
                if c.my_pe() == 0 {
                    c.put_one(cell, 77, 1);
                }
                t.sync();
                if c.my_pe() == 1 {
                    assert_eq!(c.get_one(cell, 1), 77);
                    assert_eq!(ctx.my_pe(), 3, "team rank 1 must be world PE 3");
                }
                c.destroy();
            }
            ctx.barrier_all();
            if let Some(t) = odds {
                t.destroy();
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn quiet_is_scoped_to_one_context() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let world = ctx.team_world();
            let a = world.create_ctx(CtxOptions::new().private());
            let b = world.create_ctx(CtxOptions::new());
            let buf = ctx.shmalloc_n::<u32>(8).unwrap();
            let peer = (ctx.my_pe() + 1) % 2;

            a.put_nbi(buf, &[1; 8], peer);
            b.put_nbi(buf, &[1; 8], peer);
            ctx.put_nbi(buf, &[1; 8], peer); // default context
            assert_eq!(a.pending_nbi(), 1);
            assert_eq!(b.pending_nbi(), 1);
            assert_eq!(ctx.pending_nbi(), 1);

            // Quiet on A retires A only — B and the default domain still
            // hold their pending operations.
            a.quiet();
            assert_eq!(a.pending_nbi(), 0);
            assert_eq!(b.pending_nbi(), 1);
            assert_eq!(ctx.pending_nbi(), 1);

            // Default-context quiet leaves B alone.
            ctx.quiet_nbi();
            assert_eq!(ctx.pending_nbi(), 0);
            assert_eq!(b.pending_nbi(), 1);

            b.quiet();
            assert_eq!(b.pending_nbi(), 0);

            // Fence orders but never retires.
            b.put_nbi(buf, &[2; 8], peer);
            b.fence();
            assert_eq!(b.pending_nbi(), 1);
            b.destroy();
            ctx.barrier_all();
            assert_eq!(unsafe { ctx.local(buf) }, &[2u32; 8][..]);
            ctx.barrier_all();
        });
    }

    #[test]
    fn options_are_recorded() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let t = ctx.team_world();
            let c = t.create_ctx(CtxOptions::new().serialized().private());
            assert!(c.options().serialized);
            assert!(c.options().private);
            let d = t.create_ctx(CtxOptions::new());
            assert!(!d.options().serialized && !d.options().private);
            c.destroy();
            d.destroy();
        });
    }
}
