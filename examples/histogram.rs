//! Distributed histogram — the fine-grained-atomics workload of the SHMEM
//! world (GUPS-style random updates).
//!
//! The histogram is block-distributed across PE heaps: bin `b` lives on PE
//! `b / bins_per_pe`. Each PE draws samples and updates the owning PE's bin
//! with a remote `atomic_fadd` — no locks, no messages. A final `fcollect`
//! gathers the per-PE sub-histograms everywhere and the result is checked
//! against a serial oracle (possible because the per-PE sample streams are
//! deterministic).
//!
//! Usage: `histogram [samples_per_pe bins]` (defaults 200000, 64).

use posh::pe::{Ctx, PoshConfig, World};
use posh::util::prng::Rng;

fn sample(rng: &mut Rng, bins: usize) -> usize {
    // Triangular-ish distribution: sum of two uniforms ⇒ middle bins heavy.
    let a = rng.next_below(bins as u64) as usize;
    let b = rng.next_below(bins as u64) as usize;
    (a + b) / 2
}

fn pe_body(ctx: Ctx, samples: usize, bins: usize) {
    let n = ctx.n_pes();
    let me = ctx.my_pe();
    assert!(bins % n == 0, "bins must divide by PE count for this demo");
    let per_pe = bins / n;

    // Each PE owns `per_pe` bins of the global histogram.
    let mine = ctx.shmalloc_n::<i64>(per_pe).unwrap();
    let gathered = ctx.shmalloc_n::<i64>(bins).unwrap();
    unsafe { ctx.local_mut(mine).fill(0) };
    ctx.barrier_all();

    // Scatter updates with remote atomics.
    let mut rng = Rng::for_pe(0x415, me);
    let t0 = std::time::Instant::now();
    for _ in 0..samples {
        let bin = sample(&mut rng, bins);
        let owner = bin / per_pe;
        let slot = bin % per_pe;
        ctx.atomic_fadd(mine.at(slot), 1i64, owner);
    }
    ctx.barrier_all();
    let updates_per_s = samples as f64 / t0.elapsed().as_secs_f64();

    // Gather the distributed histogram on every PE.
    let world = ctx.team_world();
    ctx.fcollect(gathered, mine, per_pe, &world);
    let hist = unsafe { ctx.local(gathered).to_vec() };

    // Oracle: regenerate every PE's stream serially.
    let mut expect = vec![0i64; bins];
    for pe in 0..n {
        let mut r = Rng::for_pe(0x415, pe);
        for _ in 0..samples {
            expect[sample(&mut r, bins)] += 1;
        }
    }
    assert_eq!(hist, expect, "distributed histogram disagrees with oracle");
    let total: i64 = hist.iter().sum();
    assert_eq!(total, (samples * n) as i64);

    if me == 0 {
        println!("bins {bins}, PEs {n}, {samples} samples/PE");
        println!("remote atomic updates: {:.2} Mupdates/s/PE", updates_per_s / 1e6);
        // Crude shape print.
        let max = *hist.iter().max().unwrap() as f64;
        for (b, &v) in hist.iter().enumerate().step_by(bins / 16) {
            println!("bin {b:3} {:5} {}", v, "*".repeat((v as f64 / max * 40.0) as usize));
        }
        println!("histogram OK");
    }
    ctx.barrier_all();
}

fn main() -> posh::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let samples: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200_000);
    let bins: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    if World::env_present() {
        let world = World::from_env()?;
        pe_body(world.my_ctx(), samples, bins);
    } else {
        let world = World::threads(4, PoshConfig::default())?;
        world.run(|ctx| pe_body(ctx, samples, bins));
    }
    Ok(())
}
