//! The team barrier — the public face of the per-team barrier, with the
//! §4.5.5 safe-mode bookkeeping wrapped around it.
//!
//! (`shmem_barrier_all` lives in [`crate::sync::barrier`] and uses the
//! faster dissemination algorithm over the header mailboxes; the team
//! variant must work for arbitrary subsets, so it fans in on the team root
//! over the team's own sync cells.)

use crate::pe::Ctx;
use crate::symheap::layout::CollOpTag;
use crate::team::Team;

impl Ctx {
    /// `shmem_team_sync` / 1.0 `shmem_barrier`: synchronise the team's
    /// members and complete all outstanding memory updates.
    pub fn barrier(&self, team: &Team) {
        let _idx = self.coll_enter(team, CollOpTag::Barrier, 0);
        // team_barrier_raw() opens with a quiet, giving the spec's
        // "complete all outstanding updates" guarantee; coll_exit runs it.
        self.coll_exit(team);
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn subset_barrier_synchronises_members_only() {
        let w = World::threads(4, PoshConfig::small()).unwrap();
        let hits = AtomicUsize::new(0);
        w.run(|ctx| {
            let team = ctx.team_world().split_strided(0, 1, 2); // PEs 0 and 1
            if let Some(team) = &team {
                for round in 1..=40 {
                    hits.fetch_add(1, Ordering::SeqCst);
                    ctx.barrier(team);
                    assert!(hits.load(Ordering::SeqCst) >= 2 * round);
                    ctx.barrier(team);
                }
            }
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn barrier_flushes_puts() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let team = ctx.team_world();
            let cell = ctx.shmalloc_n::<u64>(3).unwrap();
            for round in 1..30u64 {
                let peer = (ctx.my_pe() + 1) % 3;
                ctx.put_one(cell.at(ctx.my_pe()), round, peer);
                ctx.barrier(&team);
                let prev = (ctx.my_pe() + 2) % 3;
                assert_eq!(unsafe { ctx.local(cell)[prev] }, round);
                ctx.barrier(&team);
            }
        });
    }

    #[test]
    fn legacy_triplet_barrier_still_works() {
        // The deprecated shims route through Team::from_triplet — the
        // 1.0-compatible legacy cells must still synchronise correctly.
        let w = World::threads(4, PoshConfig::small()).unwrap();
        let hits = AtomicUsize::new(0);
        w.run(|ctx| {
            let team = crate::team::Team::from_triplet(&ctx, 0, 1, 2, 4); // PEs 0, 2
            if team.is_member() {
                for round in 1..=25 {
                    hits.fetch_add(1, Ordering::SeqCst);
                    ctx.barrier(&team);
                    assert!(hits.load(Ordering::SeqCst) >= 2 * round);
                    ctx.barrier(&team);
                }
            }
            ctx.barrier_all();
        });
    }
}
