//! **Table 1** — "Comparison of the performance observed with various memcpy
//! implementations": latency (ns) and bandwidth (Gb/s) per copy
//! implementation — extended into the copy-plan regression gate.
//!
//! The paper's rows are five 2010-era machines; ours are (a) this container,
//! measured, and (b) the five paper machines *replayed from their fitted
//! cost models* (DESIGN.md §1 substitution) so the table shape is directly
//! comparable. The paper's columns memcpy/MMX/MMX2/SSE map to
//! stock/unrolled64/nontemporal/sse2 (+ avx2/avx512/avx512nt, today's
//! continuation), plus a `planned` column: the size-aware
//! [`CopyPlan`](posh::mem::plan::CopyPlan) dispatch.
//!
//! Beyond the paper's two operating points (latency at 8 B, bandwidth at
//! 64 MiB) this bench sweeps the full size range and **gates** the plan:
//! at every swept size the planned dispatch must land within 10% (plus
//! 1 ns of absolute grace for the timer floor) of the best fixed engine
//! (one noise retry; `POSH_BENCH_NO_ASSERT=1` demotes the gate to a
//! report). Results land in `bench_out/BENCH_table1.json` next to the
//! ablation CSVs.
//!
//! Protocol = §5: 20 reps after warm-up; latency at 8 B, bandwidth at 64 MiB.

use posh::bench::{auto_batch, measure, Table};
use posh::mem::copy::{
    copy_bytes, copy_bytes_with, dispatch_name, set_global_impl, set_global_planned, CopyImpl,
};
use posh::model::machines::paper_machines;

const LAT_SIZE: usize = 8;
const BW_SIZE: usize = 64 << 20;

/// Swept copy sizes: spans all three plan ranges (small / temporal /
/// non-temporal) on any plausible threshold placement.
const SWEEP: [usize; 11] = [
    8,
    64,
    256,
    1 << 10,
    4 << 10,
    64 << 10,
    256 << 10,
    1 << 20,
    4 << 20,
    16 << 20,
    64 << 20,
];

/// Bandwidth of a `size`-byte copy *as deployed*: through [`copy_bytes`]
/// dispatch, with either a forced engine or planned mode installed — so the
/// gate compares real configurations, dispatch cost included, not raw
/// engine calls.
fn bw_at(size: usize, dst: &mut [u8], src: &[u8], imp: Option<CopyImpl>) -> f64 {
    match imp {
        Some(imp) => set_global_impl(imp),
        None => set_global_planned(),
    }
    let batch = auto_batch(30.0 + size as f64 / 8.0);
    let m = measure(size, batch, || unsafe {
        copy_bytes(dst.as_mut_ptr(), src.as_ptr(), size);
    });
    set_global_planned();
    m.bandwidth_gbps()
}

fn main() {
    // The bench owns its process: measure the planned column through real
    // planned dispatch even if a copy-* feature pinned a default engine.
    set_global_planned();
    let impls = CopyImpl::available();
    let mut names: Vec<&str> = impls.iter().map(|i| i.name()).collect();
    names.push("planned");
    println!("copy dispatch: {}", dispatch_name());

    let mut lat = Table::new("Table 1a: memory copy latency", "ns", &names);
    let mut bw = Table::new("Table 1b: memory copy bandwidth", "Gb/s", &names);

    // --- Measured rows: this machine.
    let src = vec![0xA5u8; BW_SIZE];
    let mut dst = vec![0u8; BW_SIZE];
    let mut lat_row = Vec::new();
    let mut bw_row = Vec::new();
    for &imp in &impls {
        let m = measure(LAT_SIZE, auto_batch(30.0), || unsafe {
            copy_bytes_with(imp, dst.as_mut_ptr(), src.as_ptr(), LAT_SIZE);
        });
        lat_row.push(m.latency_ns());
        let m = measure(BW_SIZE, 1, || unsafe {
            copy_bytes_with(imp, dst.as_mut_ptr(), src.as_ptr(), BW_SIZE);
        });
        bw_row.push(m.bandwidth_gbps());
    }
    // Planned column.
    let m = measure(LAT_SIZE, auto_batch(30.0), || unsafe {
        copy_bytes(dst.as_mut_ptr(), src.as_ptr(), LAT_SIZE);
    });
    lat_row.push(m.latency_ns());
    let m = measure(BW_SIZE, 1, || unsafe {
        copy_bytes(dst.as_mut_ptr(), src.as_ptr(), BW_SIZE);
    });
    bw_row.push(m.bandwidth_gbps());
    lat.row("this-machine", lat_row.clone());
    bw.row("this-machine", bw_row.clone());

    // --- Replayed rows: the paper's machines from their fitted models
    // (stock memcpy + best tuned copy; the dead ISAs have no modern meaning,
    // so replay fills only the columns that map).
    for m in paper_machines() {
        let mut l = vec![0.0; names.len()];
        let mut b = vec![0.0; names.len()];
        for (i, imp) in impls.iter().enumerate() {
            match imp {
                CopyImpl::Stock => {
                    l[i] = m.memcpy.alpha_ns;
                    b[i] = m.memcpy.predict_gbps(BW_SIZE);
                }
                CopyImpl::Sse2 => {
                    l[i] = m.best_copy.alpha_ns;
                    b[i] = m.best_copy.predict_gbps(BW_SIZE);
                }
                _ => {}
            }
        }
        lat.row(&format!("paper:{}", m.name), l);
        bw.row(&format!("paper:{}", m.name), b);
    }

    lat.print();
    bw.print();
    lat.write_csv("table1_latency").unwrap();
    bw.write_csv("table1_bandwidth").unwrap();

    // --- The plan gate: sweep all sizes, planned vs every fixed engine.
    let strict = std::env::var("POSH_BENCH_NO_ASSERT").map_or(true, |v| v != "1");
    let stock_idx = impls.iter().position(|i| *i == CopyImpl::Stock).unwrap();
    let mut sweep_table = Table::new("Copy-plan sweep", "Gb/s", &names);
    let mut json = String::from("{\n  \"sizes\": [\n");
    let mut worst: (f64, usize) = (0.0, 0); // (plan slowdown ratio, size)
    for (si, &size) in SWEEP.iter().enumerate() {
        let mut row: Vec<f64> = impls
            .iter()
            .map(|&imp| bw_at(size, &mut dst, &src, Some(imp)))
            .collect();
        let mut planned = bw_at(size, &mut dst, &src, None);
        let (mut best_i, mut best) = (0usize, f64::MIN);
        for (i, &g) in row.iter().enumerate() {
            if g > best {
                best = g;
                best_i = i;
            }
        }
        // Gate: planned within 10% of the best fixed engine, plus 1 ns of
        // absolute grace — at 8 B a copy is a few ns and one extra
        // predicted load shows up as tens of percent while meaning nothing.
        let ns_of = |gbps: f64| size as f64 * 8.0 / gbps.max(1e-9);
        let over = |planned: f64, best: f64| ns_of(planned) > ns_of(best) * 1.10 + 1.0;
        if over(planned, best) {
            // One retry: re-measure both contenders, keep the better run
            // of each (median-of-20 is still occasionally noisy at 8 B).
            planned = planned.max(bw_at(size, &mut dst, &src, None));
            row[best_i] = row[best_i].max(bw_at(size, &mut dst, &src, Some(impls[best_i])));
            best = row[best_i];
        }
        let ratio = best / planned;
        if ratio > worst.0 {
            worst = (ratio, size);
        }
        println!(
            "  {:>9} B  planned {:>7.2} Gb/s  best {:>7.2} ({})  plan/best {:.3}",
            size,
            planned,
            best,
            impls[best_i].name(),
            planned / best
        );
        row.push(planned);
        sweep_table.row(&format!("{size}B"), row.clone());
        let engines_json: Vec<String> = impls
            .iter()
            .zip(&row)
            .map(|(imp, g)| format!("\"{}\": {:.4}", imp.name(), g))
            .collect();
        json.push_str(&format!(
            "    {{\"bytes\": {}, \"engines\": {{{}}}, \"planned_gbps\": {:.4}, \
             \"best_engine\": \"{}\", \"best_gbps\": {:.4}, \"plan_over_best\": {:.4}, \
             \"best_over_stock\": {:.4}}}{}\n",
            size,
            engines_json.join(", "),
            planned,
            impls[best_i].name(),
            best,
            planned / best,
            best / row[stock_idx].max(1e-9),
            if si + 1 == SWEEP.len() { "" } else { "," }
        ));
        if strict {
            assert!(
                !over(planned, best),
                "planned dispatch is {:.1}% slower than {} at {} B \
                 (gate: ≤10% + 1 ns; POSH_BENCH_NO_ASSERT=1 to record anyway)",
                (ratio - 1.0) * 100.0,
                impls[best_i].name(),
                size
            );
        } else if over(planned, best) {
            println!(
                "  WARN: plan {:.1}% behind {} at {} B (gate disabled)",
                (ratio - 1.0) * 100.0,
                impls[best_i].name(),
                size
            );
        }
    }
    json.push_str("  ]\n}\n");
    sweep_table.print();
    sweep_table.write_csv("table1_sweep").unwrap();
    std::fs::create_dir_all("bench_out").unwrap();
    std::fs::write("bench_out/BENCH_table1.json", json).unwrap();

    // --- Shape checks (the claims Table 1 supports in the paper).
    let best_bw = bw_row[..impls.len()].iter().cloned().fold(f64::MIN, f64::max);
    assert!(
        bw_row[stock_idx] >= 0.5 * best_bw,
        "stock memcpy must be within 2x of the best copy (paper: 'the stock \
         memcpy performs quite well'); got {:.1} vs best {:.1}",
        bw_row[stock_idx],
        best_bw
    );
    println!(
        "\nshape check OK: stock {:.1} Gb/s vs best {:.1} Gb/s (ratio {:.2}); \
         plan gate worst ratio {:.3} at {} B",
        bw_row[stock_idx],
        best_bw,
        bw_row[stock_idx] / best_bw,
        worst.0,
        worst.1
    );
    println!(
        "csv: bench_out/table1_latency.csv, bench_out/table1_bandwidth.csv, \
         bench_out/table1_sweep.csv; json: bench_out/BENCH_table1.json"
    );
}
