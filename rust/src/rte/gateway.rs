//! The gateway process's IO forwarding (§4.7 "Inputs and outputs").
//!
//! "The parallel application is made of several processes, whereas the user
//! is in contact with only one process: the master process, which is used as
//! a gateway between the user and the application." Child stdout/stderr are
//! piped to the master and re-emitted line-by-line with a rank prefix
//! (`[PE k] …`), each stream on its own forwarder thread so interleaving is
//! line-granular, never byte-granular.
//!
//! Under the memfd shm engine the gateway also plays *segment broker*: it
//! pre-creates one inheritable memfd per rank ([`SegmentHandoff`]) and
//! publishes the fd numbers to the children, replacing the §4.7 name-based
//! contact information that `/dev/shm`-less sandboxes cannot provide.

use crate::shm::memfd::{create_handoff_fd, encode_fd_list, SEGFDS_ENV};
use crate::shm::naming::memfd_debug_name;
use crate::Result;
use std::io::{BufRead, BufReader, Read, Write};
use std::os::unix::io::RawFd;
use std::sync::mpsc::{channel, Receiver, Sender};

/// One forwarded line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IoLine {
    /// Which PE produced it.
    pub rank: usize,
    /// `false` = stdout, `true` = stderr.
    pub is_err: bool,
    /// The text, without the trailing newline.
    pub line: String,
}

impl IoLine {
    /// The gateway's display format.
    pub fn render(&self) -> String {
        if self.is_err {
            format!("[PE {}!] {}", self.rank, self.line)
        } else {
            format!("[PE {}] {}", self.rank, self.line)
        }
    }
}

/// Collects forwarder threads and the line channel.
pub struct Gateway {
    tx: Sender<IoLine>,
    rx: Receiver<IoLine>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Default for Gateway {
    fn default() -> Self {
        Self::new()
    }
}

impl Gateway {
    /// New, with no streams attached yet.
    pub fn new() -> Gateway {
        let (tx, rx) = channel();
        Gateway { tx, rx, threads: Vec::new() }
    }

    /// Attach one child stream; a forwarder thread pumps it until EOF.
    pub fn attach<R: Read + Send + 'static>(&mut self, rank: usize, is_err: bool, stream: R) {
        let tx = self.tx.clone();
        self.threads.push(std::thread::spawn(move || {
            let reader = BufReader::new(stream);
            for line in reader.lines() {
                let Ok(line) = line else { break };
                if tx.send(IoLine { rank, is_err, line }).is_err() {
                    break;
                }
            }
        }));
    }

    /// Pump every pending and future line to the given sink until all
    /// attached streams hit EOF; returns the forwarded lines.
    pub fn pump_to<W: Write>(mut self, sink: &mut W) -> std::io::Result<Vec<IoLine>> {
        drop(self.tx); // close our clone so rx terminates at last-EOF
        let mut all = Vec::new();
        for line in self.rx.iter() {
            writeln!(sink, "{}", line.render())?;
            all.push(line);
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        Ok(all)
    }
}

/// The gateway-side half of the memfd fd handoff: one pre-created,
/// pre-sized, inheritable (non-`CLOEXEC`) memfd per rank. Children inherit
/// the fd-table entries across `fork`/`exec` and find the numbers in
/// [`SEGFDS_ENV`]; the parent's copies close when this drops (each child
/// owns independent entries, so dropping after spawn is safe).
pub struct SegmentHandoff {
    fds: Vec<RawFd>,
}

impl SegmentHandoff {
    /// Create and size one heap memfd per rank. `seg_len` must equal the
    /// children's `Layout::compute(..).total` — a PE validates the size
    /// when mapping and fails loudly on mismatch.
    pub fn create(job_id: u64, n_pes: usize, seg_len: usize) -> Result<SegmentHandoff> {
        let mut fds = Vec::with_capacity(n_pes);
        for rank in 0..n_pes {
            match create_handoff_fd(&memfd_debug_name(job_id, rank), seg_len) {
                Ok(fd) => fds.push(fd),
                Err(e) => {
                    for fd in fds {
                        // SAFETY: fds we just created; best-effort cleanup.
                        unsafe {
                            libc::close(fd);
                        }
                    }
                    return Err(e);
                }
            }
        }
        Ok(SegmentHandoff { fds })
    }

    /// The `(key, value)` pair to put in every child's environment.
    pub fn env(&self) -> (String, String) {
        (SEGFDS_ENV.to_string(), encode_fd_list(&self.fds))
    }

    /// The rank-indexed fds (parent-side numbers == child-side numbers).
    pub fn fds(&self) -> &[RawFd] {
        &self.fds
    }
}

impl Drop for SegmentHandoff {
    fn drop(&mut self) {
        for &fd in &self.fds {
            // SAFETY: closing our own fd-table entries; children hold
            // independent inherited copies.
            unsafe {
                libc::close(fd);
            }
        }
    }
}

/// Fan a signal out to every child (the §4.7 signal-forwarding contract:
/// "if the user sends a signal to the gateway process, this signal is sent
/// to all the processes of the parallel application").
pub fn forward_signal(pids: &[u32], signal: i32) {
    for &pid in pids {
        // SAFETY: plain kill(2); failure (ESRCH on exited child) is fine.
        unsafe {
            libc::kill(pid as libc::pid_t, signal);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lines_are_rank_prefixed_and_complete() {
        let mut gw = Gateway::new();
        gw.attach(0, false, std::io::Cursor::new("alpha\nbeta\n"));
        gw.attach(1, false, std::io::Cursor::new("gamma\n"));
        gw.attach(1, true, std::io::Cursor::new("oops\n"));
        let mut out = Vec::new();
        let lines = gw.pump_to(&mut out).unwrap();
        assert_eq!(lines.len(), 4);
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("[PE 0] alpha"));
        assert!(text.contains("[PE 0] beta"));
        assert!(text.contains("[PE 1] gamma"));
        assert!(text.contains("[PE 1!] oops"));
    }

    #[test]
    fn segment_handoff_creates_sized_inheritable_fds() {
        use crate::shm::Segment as _;
        if !crate::shm::memfd::memfd_supported() {
            eprintln!("skipping: memfd_create unavailable");
            return;
        }
        let h = SegmentHandoff::create(0xbeef, 3, 8192).unwrap();
        assert_eq!(h.fds().len(), 3);
        let (k, v) = h.env();
        assert_eq!(k, SEGFDS_ENV);
        assert_eq!(v.split(',').count(), 3);
        // Each brokered fd maps at full size and is zeroed — exactly what a
        // PE does with its own rank's entry.
        let seg = crate::shm::memfd::MemfdSegment::map_existing(h.fds()[1], 8192).unwrap();
        unsafe {
            assert_eq!(*seg.base(), 0);
        }
    }

    #[test]
    fn empty_streams_terminate() {
        let mut gw = Gateway::new();
        gw.attach(0, false, std::io::Cursor::new(""));
        let mut out = Vec::new();
        let lines = gw.pump_to(&mut out).unwrap();
        assert!(lines.is_empty());
    }

    #[test]
    fn forwarding_from_real_children() {
        use crate::rte::launcher::{JobSpec, Launcher};
        let mut spec = JobSpec::new(2, "/bin/sh");
        spec.args = vec!["-c".into(), "echo hello-from-$POSH_RANK".into()];
        let l = Launcher::new(spec);
        let mut pes = l.spawn_all().unwrap();
        let mut gw = Gateway::new();
        for pe in pes.iter_mut() {
            gw.attach(pe.rank, false, pe.child.stdout.take().unwrap());
            gw.attach(pe.rank, true, pe.child.stderr.take().unwrap());
        }
        let mut out = Vec::new();
        let lines = gw.pump_to(&mut out).unwrap();
        for pe in pes.iter_mut() {
            assert!(pe.child.wait().unwrap().success());
        }
        let mut stdouts: Vec<String> =
            lines.iter().filter(|l| !l.is_err).map(|l| l.render()).collect();
        stdouts.sort();
        assert_eq!(stdouts, vec!["[PE 0] hello-from-0", "[PE 1] hello-from-1"]);
    }
}
