//! Job-wide barriers — thin calls over the **one** sync engine.
//!
//! Since the team-scalable synchronisation refactor there is a single
//! barrier engine: the dissemination sync over per-team mailbox cells in
//! `collectives::state` (`team_sync_dissemination`). `shmem_barrier_all`
//! runs it over the world team's permanently-reserved slot 0, `Team::sync`
//! and `Team::barrier` run it over the team's own slot — same rounds, same
//! cells, same proofs. Two algorithms remain selectable via
//! [`crate::pe::BarrierKind`] (ablation B in DESIGN.md):
//!
//! * **Dissemination** — ⌈log₂ n⌉ rounds; in round *r* PE *i* signals PE
//!   *(i+2ʳ) mod n* and waits for the matching signal. Mailboxes are the
//!   world team's per-round epoch cells in each PE's heap header, so the
//!   algorithm is identical in thread and process mode. O(log n) latency,
//!   no hot spot.
//! * **Central** — one counter + sense-reversal epoch on PE 0. O(n) fan-in
//!   on a single cache line; the classic baseline the dissemination barrier
//!   is measured against.
//!
//! Epochs are monotone, so cells never need resetting and back-to-back
//! barriers cannot interfere (a peer one epoch ahead simply stores a larger
//! value, which `>=` absorbs).

use crate::collectives::ActiveSet;
use crate::pe::{BarrierKind, Ctx};
use crate::team::WORLD_TEAM_SLOT;
use std::sync::atomic::Ordering;

/// ⌈log₂ n⌉ for n ≥ 1.
pub fn ceil_log2(n: usize) -> usize {
    debug_assert!(n >= 1);
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

impl Ctx {
    /// `shmem_barrier_all`: synchronise every PE **and** complete all
    /// outstanding memory updates (the spec folds a quiet into the barrier;
    /// the default NBI domain's accounting retires with it).
    pub fn barrier_all(&self) {
        self.quiet_nbi();
        self.sync_all();
    }

    /// `shmem_sync_all` (OpenSHMEM 1.5): synchronise every PE **without**
    /// the implicit quiet — pure arrival/release, no completion guarantee
    /// for outstanding puts and no NBI retirement. The cheap path when only
    /// control synchronisation is needed.
    pub fn sync_all(&self) {
        let n = self.n_pes();
        if n == 1 {
            self.record_sync_rounds(0);
            return;
        }
        match self.config().barrier {
            BarrierKind::Dissemination => {
                // Same re-entrancy guard as `team_sync_cells`: this arm
                // reaches the world slot's mailboxes directly, so it must
                // claim the slot itself (a second thread of this PE in a
                // concurrent `sync_all`/`barrier_all` would consume this
                // epoch's signals and hang both).
                self.coll_entry_guard_acquire(WORLD_TEAM_SLOT);
                self.team_sync_dissemination(&ActiveSet::world(n), WORLD_TEAM_SLOT);
                self.coll_entry_guard_release(WORLD_TEAM_SLOT);
            }
            BarrierKind::Central => self.barrier_central(),
        }
    }

    /// Central-counter barrier (ablation baseline).
    pub(crate) fn barrier_central(&self) {
        let n = self.n_pes();
        if n == 1 {
            return;
        }
        let me = self.my_pe();
        let my_hdr = self.header_of(me);
        let epoch = my_hdr.barrier.epoch.load(Ordering::Relaxed) + 1;
        let h0 = self.header_of(0);
        let arrived = h0.barrier.central_count.fetch_add(1, Ordering::AcqRel) + 1;
        if arrived == n as u64 {
            h0.barrier.central_count.store(0, Ordering::Relaxed);
            h0.barrier.central_sense.store(epoch, Ordering::Release);
        } else {
            self.spin_wait(|| h0.barrier.central_sense.load(Ordering::Acquire) >= epoch);
        }
        my_hdr.barrier.epoch.store(epoch, Ordering::Release);
        // Serial depth of the central counter: n arrivals on one line.
        self.record_sync_rounds(n - 1);
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{BarrierKind, PoshConfig, World};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn ceil_log2_values() {
        use super::ceil_log2;
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    fn barrier_separates_phases(kind: BarrierKind, n: usize) {
        let mut cfg = PoshConfig::small();
        cfg.barrier = kind;
        let w = World::threads(n, cfg).unwrap();
        let phase = AtomicUsize::new(0);
        let pre = AtomicUsize::new(0);
        w.run(|ctx| {
            for round in 0..50 {
                pre.fetch_add(1, Ordering::SeqCst);
                ctx.barrier_all();
                // After the barrier, *everyone* must have done `pre` for
                // this round: pre == n*(round+1).
                let seen = pre.load(Ordering::SeqCst);
                assert!(
                    seen >= n * (round + 1),
                    "PE {} saw {} pre-increments in round {}",
                    ctx.my_pe(),
                    seen,
                    round
                );
                ctx.barrier_all();
            }
            phase.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(phase.load(Ordering::SeqCst), n);
    }

    #[test]
    fn dissemination_2() {
        barrier_separates_phases(BarrierKind::Dissemination, 2);
    }

    #[test]
    fn dissemination_3() {
        barrier_separates_phases(BarrierKind::Dissemination, 3);
    }

    #[test]
    fn dissemination_7() {
        barrier_separates_phases(BarrierKind::Dissemination, 7);
    }

    #[test]
    fn dissemination_8() {
        barrier_separates_phases(BarrierKind::Dissemination, 8);
    }

    #[test]
    fn central_2() {
        barrier_separates_phases(BarrierKind::Central, 2);
    }

    #[test]
    fn central_5() {
        barrier_separates_phases(BarrierKind::Central, 5);
    }

    #[test]
    fn single_pe_barrier_is_noop() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            for _ in 0..1000 {
                ctx.barrier_all();
            }
        });
    }

    /// `sync_all` is a real barrier (phase separation) even though it skips
    /// the quiet — and it interleaves safely with `barrier_all` on the same
    /// slot-0 cells.
    #[test]
    fn sync_all_separates_phases() {
        let n = 4;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        let pre = AtomicUsize::new(0);
        w.run(|ctx| {
            for round in 0..50 {
                pre.fetch_add(1, Ordering::SeqCst);
                ctx.sync_all();
                assert!(pre.load(Ordering::SeqCst) >= n * (round + 1));
                if round % 3 == 0 {
                    ctx.barrier_all();
                } else {
                    ctx.sync_all();
                }
            }
        });
    }
}
