//! Multi-threaded stress & conformance suite for the `SHMEM_THREAD_MULTIPLE`
//! hot paths: the sharded lock-free NBI queue, per-thread context pools, and
//! the per-context quiet/fence isolation guarantees.
//!
//! Every test runs 8 worker threads per PE hammering `put_nbi`/`get`/
//! `quiet`/`fence` concurrently, with flag-after-data oracles so a lost or
//! torn delivery is an assertion failure, not a silent corruption. The
//! guarantees pinned here are stated in `docs/memory_model.md` §"Thread
//! levels":
//!
//! * per-thread contexts from [`Team::ctx_for_thread`] never share
//!   completion state — one thread's quiet neither stalls nor drains a
//!   sibling's pending operations;
//! * a single shared context driven by many threads concurrently is sound:
//!   the issue path is lock-free (per-thread shards), concurrent quiets each
//!   retire exactly what they deliver, and per-thread program order is
//!   preserved (last-writer-wins within a thread's own slice);
//! * bulk (eager) puts issued from many threads are accounted exactly.
//!
//! Iteration counts scale down in debug builds so the default `cargo test`
//! stays quick; the CI release job runs the full counts.

use posh::ctx::CtxOptions;
use posh::pe::{PoshConfig, TeamBarrierKind, World};
use posh::sync::CmpOp;
use posh::util::prng::Rng;

/// Release-build iteration count, scaled down 8× for debug builds.
fn iters(release: usize) -> usize {
    if cfg!(debug_assertions) {
        (release / 8).max(1)
    } else {
        release
    }
}

/// Worker threads per PE.
const THREADS: usize = 8;
/// Elements (u64) in each thread's private slice of the data buffer.
const ELEMS: usize = 64;

/// A value unique per (writer PE, writer thread, round) — any lost, stale,
/// or cross-thread-torn delivery lands a wrong stamp in the verify step.
fn stamp(pe: usize, t: usize, round: u64) -> u64 {
    ((pe as u64 + 1) << 56) ^ ((t as u64 + 1) << 40) ^ (round << 8)
}

/// The flagship hammer: 2 PEs × 8 threads, each thread owning a private
/// context from [`Team::ctx_for_thread`] and a disjoint 64-element slice of
/// the peer's data buffer. Per round, each thread writes its slice in
/// randomly-sized `put_nbi` chunks, fences, raises a per-thread flag,
/// quiets, then verifies the symmetric incoming slice element-by-element
/// against the peer's stamp — lost and torn deliveries both fail loudly.
/// Every 8th round the thread `get`s its own outgoing slice back and
/// cross-checks it. An ack cell closes each round so a writer can never
/// overwrite data its reader has not verified.
fn per_thread_ctx_hammer(kind: TeamBarrierKind) {
    let rounds = iters(200) as u64;
    let mut cfg = PoshConfig::small();
    cfg.team_barrier = Some(kind);
    let w = World::threads(2, cfg).unwrap();
    w.run(|ctx| {
        let data = ctx.shmalloc_n::<u64>(THREADS * ELEMS).unwrap();
        let flags = ctx.shmalloc_n::<u64>(THREADS).unwrap();
        let acks = ctx.shmalloc_n::<u64>(THREADS).unwrap();
        unsafe {
            ctx.local_mut(data).fill(0);
            ctx.local_mut(flags).fill(0);
            ctx.local_mut(acks).fill(0);
        }
        ctx.barrier_all();
        let me = ctx.my_pe();
        let peer = 1 - me;
        let team = ctx.team_world();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let ctx = ctx.clone();
                let team = team.clone();
                s.spawn(move || {
                    let c = team.ctx_for_thread();
                    let mut rng = Rng::for_pe(0xC0FFEE + t as u64, me);
                    let base = t * ELEMS;
                    for round in 1..=rounds {
                        // Write my slice of the peer's buffer in random
                        // chunks, all deferred (512 B ≪ NBI_DEFER_MAX_BYTES).
                        let vals: Vec<u64> =
                            (0..ELEMS as u64).map(|i| stamp(me, t, round) + i).collect();
                        let mut off = 0;
                        while off < ELEMS {
                            let len = rng.usize_in(1, ELEMS - off + 1);
                            c.put_nbi(data.slice(base + off, len), &vals[off..off + len], peer);
                            off += len;
                        }
                        // Flag-after-data: fence orders the drain before the
                        // flag, quiet completes the round.
                        c.fence();
                        c.put_one(flags.at(t), round, peer);
                        c.quiet();
                        // Wait for the peer thread's matching write to me,
                        // then verify every element of my incoming slice.
                        ctx.wait_until(flags.at(t), CmpOp::Ge, round);
                        let seen = unsafe { ctx.local(data.slice(base, ELEMS)) };
                        for (i, &v) in seen.iter().enumerate() {
                            assert_eq!(
                                v,
                                stamp(peer, t, round) + i as u64,
                                "PE {me} thread {t} round {round}: lost/torn delivery at {i}"
                            );
                        }
                        if round % 8 == 0 {
                            // Read my own outgoing slice back from the peer:
                            // my quiet completed it, so it must match.
                            let mut back = vec![0u64; ELEMS];
                            c.get(&mut back, data.slice(base, ELEMS), peer);
                            assert_eq!(back, vals, "PE {me} thread {t} round {round}: get");
                        }
                        // Ack so the peer may overwrite; wait for ours.
                        c.put_one(acks.at(t), round, peer);
                        c.quiet();
                        ctx.wait_until(acks.at(t), CmpOp::Ge, round);
                    }
                });
            }
        });
        team.sync(); // the engine under test (kind) on the world slot
        ctx.barrier_all();
    });
}

#[test]
fn per_thread_ctx_hammer_dissemination() {
    per_thread_ctx_hammer(TeamBarrierKind::Dissemination);
}

#[test]
fn per_thread_ctx_hammer_linear_fanin() {
    per_thread_ctx_hammer(TeamBarrierKind::LinearFanin);
}

/// One context, no promises, shared by 8 threads concurrently — the
/// `SHMEM_THREAD_MULTIPLE` contract. The issue path must be lock-free-safe
/// under contention, interleaved quiets/fences from arbitrary threads must
/// retire exactly what they deliver, and per-thread program order must hold:
/// after the final quiet each thread's slice carries its **last** round's
/// values (the sharded queue keeps one thread's puts FIFO, so last-writer
/// -wins within a slice is guaranteed even though rounds raced).
#[test]
fn shared_multiple_ctx_concurrent_put_nbi() {
    let rounds = iters(300) as u64;
    let w = World::threads(2, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let data = ctx.shmalloc_n::<u64>(THREADS * ELEMS).unwrap();
        unsafe { ctx.local_mut(data).fill(0) };
        ctx.barrier_all();
        let me = ctx.my_pe();
        let peer = 1 - me;
        let world = ctx.team_world();
        let shared = world.create_ctx(CtxOptions::new());
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let shared = &shared;
                s.spawn(move || {
                    let mut rng = Rng::for_pe(0xBEEF + t as u64, me);
                    let base = t * ELEMS;
                    for round in 1..=rounds {
                        let vals: Vec<u64> =
                            (0..ELEMS as u64).map(|i| stamp(me, t, round) + i).collect();
                        let mut off = 0;
                        while off < ELEMS {
                            let len = rng.usize_in(1, ELEMS - off + 1);
                            shared.put_nbi(
                                data.slice(base + off, len),
                                &vals[off..off + len],
                                peer,
                            );
                            off += len;
                        }
                        // Arbitrary threads quiet and fence the shared
                        // context mid-stream; neither may lose or duplicate
                        // a sibling's queued operations.
                        if round % 16 == 0 {
                            shared.quiet();
                        }
                        if round % 7 == 0 {
                            shared.fence();
                        }
                    }
                });
            }
        });
        shared.quiet();
        assert_eq!(shared.pending_nbi(), 0, "quiet must retire everything issued");
        ctx.barrier_all();
        for t in 0..THREADS {
            let seen = unsafe { ctx.local(data.slice(t * ELEMS, ELEMS)) };
            for (i, &v) in seen.iter().enumerate() {
                assert_eq!(
                    v,
                    stamp(peer, t, rounds) + i as u64,
                    "thread {t} elem {i}: per-thread FIFO / last-writer-wins violated"
                );
            }
        }
        shared.destroy();
        ctx.barrier_all();
    });
}

/// Per-thread completion isolation, the [`Team::ctx_for_thread`] guarantee:
/// a sibling thread's quiet completes **its own** context only — it neither
/// delivers nor retires (nor waits for) another thread's deferred puts.
#[test]
fn thread_quiet_does_not_stall_or_drain_siblings() {
    let w = World::threads(1, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let buf = ctx.shmalloc_n::<u64>(4).unwrap();
        unsafe { ctx.local_mut(buf).fill(0) };
        let team = ctx.team_world();
        let a = team.ctx_for_thread();
        a.put_nbi(buf.slice(0, 2), &[7, 8], 0);
        assert_eq!(a.pending_nbi(), 1);
        std::thread::scope(|s| {
            let team = team.clone();
            let a_handle = a.clone();
            s.spawn(move || {
                let b = team.ctx_for_thread();
                assert!(
                    !std::sync::Arc::ptr_eq(&a_handle, &b),
                    "a spawned thread must get its own pooled context"
                );
                b.put_nbi(buf.slice(2, 2), &[9, 10], 0);
                b.quiet();
                assert_eq!(b.pending_nbi(), 0, "B's quiet retires B");
                assert_eq!(
                    a_handle.pending_nbi(),
                    1,
                    "B's quiet must not retire A's pending op"
                );
            });
        });
        // B's quiet delivered B's data — and provably did not drain A's.
        assert_eq!(unsafe { ctx.local(buf) }, &[0, 0, 9, 10][..]);
        assert_eq!(a.pending_nbi(), 1);
        a.quiet();
        assert_eq!(a.pending_nbi(), 0);
        assert_eq!(unsafe { ctx.local(buf) }, &[7, 8, 9, 10][..]);
    });
}

/// Bulk puts (above the deferral cap) are issued eagerly but still counted
/// on the issuing context; 8 threads × eager traffic on one shared context
/// must leave the accounting exactly balanced — no lost increments, no
/// double retirement from concurrent quiets.
#[test]
fn bulk_eager_threads_accounting() {
    let nelems = posh::p2p::nbi::NBI_DEFER_MAX_BYTES / std::mem::size_of::<u64>() + 1;
    let rounds = iters(40) as u64;
    let w = World::threads(1, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let buf = ctx.shmalloc_n::<u64>(THREADS * nelems).unwrap();
        let team = ctx.team_world();
        let shared = team.create_ctx(CtxOptions::new());
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let shared = &shared;
                s.spawn(move || {
                    for round in 1..=rounds {
                        let vals = vec![stamp(0, t, round); nelems];
                        shared.put_nbi(buf.slice(t * nelems, nelems), &vals, 0);
                        if round % 4 == 0 {
                            shared.quiet();
                        }
                    }
                    shared.quiet();
                });
            }
        });
        shared.quiet();
        assert_eq!(shared.pending_nbi(), 0, "eager accounting must balance");
        for t in 0..THREADS {
            let seen = unsafe { ctx.local(buf.slice(t * nelems, nelems)) };
            assert!(
                seen.iter().all(|&v| v == stamp(0, t, rounds)),
                "thread {t}: bulk data lost or stale"
            );
        }
        shared.destroy();
        ctx.shfree(buf).unwrap();
    });
}
