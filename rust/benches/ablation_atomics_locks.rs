//! **Ablation C** — atomics & locks microbenchmarks (§4.6): remote
//! fetch-add/swap/cswap throughput and lock acquire/release cost under
//! contention levels. Not a paper table, but the data behind its §6 claim
//! that POSH is a platform for studying distributed algorithms (locks and
//! atomics being the named examples).

use posh::bench::{measure, Table};
use posh::ctx::CtxOptions;
use posh::pe::{PoshConfig, World};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// C3 worker: aggregate `put_nbi` issue rates (ops/s) with `threads`
/// workers on one PE — (a) one `SERIALIZED` context shared behind a
/// `Mutex`, the only sound way to share a serialized context, vs (b) a
/// private context per thread from `Team::ctx_for_thread` (lock-free issue
/// path, independent quiets). Each op is an 8-element deferred put; a quiet
/// every 512 ops bounds queue growth identically on both sides.
fn ctx_threads_rates(threads: usize, ops_per_thread: usize) -> (f64, f64) {
    const CHUNK: usize = 8; // u64s per put_nbi — the deferred fast path
    let shared_bits = AtomicU64::new(0);
    let pooled_bits = AtomicU64::new(0);
    let w = World::threads(1, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let buf = ctx.shmalloc_n::<u64>(threads * 8 * CHUNK).unwrap();
        let team = ctx.team_world();
        let vals = [1u64; CHUNK];

        // (a) shared SERIALIZED ctx: every issue funnels through one lock.
        let locked = Mutex::new(team.create_ctx(CtxOptions::new().serialized()));
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for t in 0..threads {
                let locked = &locked;
                s.spawn(move || {
                    for i in 0..ops_per_thread {
                        let g = locked.lock().unwrap();
                        g.put_nbi(buf.slice(t * 8 * CHUNK + (i % 8) * CHUNK, CHUNK), &vals, 0);
                        if i % 512 == 511 {
                            g.quiet();
                        }
                    }
                });
            }
        });
        locked.lock().unwrap().quiet();
        let shared = (threads * ops_per_thread) as f64 / t0.elapsed().as_secs_f64();
        locked.into_inner().unwrap().destroy();

        // (b) ctx-per-thread: lock-free issue, per-thread completion.
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for t in 0..threads {
                let team = team.clone();
                s.spawn(move || {
                    let c = team.ctx_for_thread();
                    for i in 0..ops_per_thread {
                        c.put_nbi(buf.slice(t * 8 * CHUNK + (i % 8) * CHUNK, CHUNK), &vals, 0);
                        if i % 512 == 511 {
                            c.quiet();
                        }
                    }
                    c.quiet();
                });
            }
        });
        let pooled = (threads * ops_per_thread) as f64 / t0.elapsed().as_secs_f64();

        shared_bits.store(shared.to_bits(), Ordering::Relaxed);
        pooled_bits.store(pooled.to_bits(), Ordering::Relaxed);
    });
    (
        f64::from_bits(shared_bits.load(Ordering::Relaxed)),
        f64::from_bits(pooled_bits.load(Ordering::Relaxed)),
    )
}

/// Contended-tier worker: every thread on every PE hammers the SAME
/// symmetric cell (`atomic_fadd`) and then the SAME named lock, both homed
/// on PE 0 — the worst case for the spec's ticket locks, whose `serving`
/// word bounces between every contender's cache. This is the data feeding
/// the ROADMAP decision on local-spin MCS queue locks: if lock ns/op grows
/// superlinearly in `pes × threads` while fadd stays near-linear, the
/// ticket design (not raw atomic bandwidth) is the bottleneck.
///
/// Returns `(fadd_ns, lock_ns)` per-op costs from PE 0's view. The fadd
/// tier self-checks: the cell must equal the op count at the end.
fn contended_rates(
    pes: usize,
    threads: usize,
    fadd_iters: usize,
    lock_iters: usize,
) -> (f64, f64) {
    let fadd_bits = AtomicU64::new(0);
    let lock_bits = AtomicU64::new(0);
    let w = World::threads(pes, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let cell = ctx.shmalloc_n::<i64>(1).unwrap();
        if ctx.my_pe() == 0 {
            ctx.put_one(cell, 0, 0);
        }
        ctx.barrier_all();

        // Tier A: same-cell fetch-add from every thread of every PE.
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..threads {
                let ctx = ctx.clone();
                s.spawn(move || {
                    for _ in 0..fadd_iters {
                        ctx.atomic_fadd(cell, 1, 0);
                    }
                });
            }
        });
        let fadd_elapsed = t0.elapsed();
        ctx.barrier_all();
        let expect = (pes * threads * fadd_iters) as i64;
        let got = ctx.get_one(cell, 0);
        assert_eq!(got, expect, "contended fadd lost updates: {got} != {expect}");

        // Tier B: same named lock (ticket lock homed on PE 0) from every
        // thread of every PE. Empty critical section — the cost measured is
        // pure acquire + release under contention.
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for _ in 0..threads {
                let ctx = ctx.clone();
                s.spawn(move || {
                    for _ in 0..lock_iters {
                        let _g = ctx.named_lock("c-contend", 0);
                    }
                });
            }
        });
        let lock_elapsed = t0.elapsed();
        ctx.barrier_all();

        if ctx.my_pe() == 0 {
            let fadd_ns = fadd_elapsed.as_nanos() as f64 / (threads * fadd_iters) as f64;
            let lock_ns = lock_elapsed.as_nanos() as f64 / (threads * lock_iters) as f64;
            fadd_bits.store(fadd_ns.to_bits(), Ordering::Relaxed);
            lock_bits.store(lock_ns.to_bits(), Ordering::Relaxed);
        }
    });
    (
        f64::from_bits(fadd_bits.load(Ordering::Relaxed)),
        f64::from_bits(lock_bits.load(Ordering::Relaxed)),
    )
}

fn main() {
    // --- Single-PE atomic op costs (no contention). The table is built and
    // printed inside the PE body (measurement happens on the PE's thread).
    let w = World::threads(1, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let cell = ctx.shmalloc_n::<i64>(1).unwrap();
        let row = vec![
            measure(8, 5000, || {
                ctx.atomic_fadd(cell, 1, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.atomic_finc(cell, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.atomic_swap(cell, 7, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.atomic_cswap(cell, 7, 7, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                ctx.put_one(cell, 1, 0);
            })
            .latency_ns(),
            measure(8, 5000, || {
                std::hint::black_box(ctx.get_one(cell, 0));
            })
            .latency_ns(),
        ];
        let mut table = Table::new(
            "Ablation C1: atomic op latency (self, uncontended)",
            "ns/op",
            &["fadd", "finc", "swap", "cswap", "put_one", "get_one"],
        );
        table.row("1 PE", row);
        table.print();
        table.write_csv("ablationC_atomics").unwrap();
    });

    // --- Lock throughput under contention.
    let mut t2 = Table::new(
        "Ablation C2: lock acquire+release under contention",
        "ns/op (PE 0's view)",
        &["spec-ticket-lock", "named-lock"],
    );
    for &n in &[1usize, 2, 4] {
        let w = World::threads(n, PoshConfig::small()).unwrap();
        let spec_ns = AtomicU64::new(0);
        let named_ns = AtomicU64::new(0);
        w.run(|ctx| {
            let lock = ctx.shmalloc_n::<i64>(1).unwrap();
            ctx.barrier_all();
            let m = measure(0, 300, || {
                ctx.with_lock(lock, || {});
            });
            if ctx.my_pe() == 0 {
                spec_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            }
            ctx.barrier_all();
            let m = measure(0, 300, || {
                let _g = ctx.named_lock("bench", 0);
            });
            if ctx.my_pe() == 0 {
                named_ns.store(m.latency_ns() as u64, Ordering::Relaxed);
            }
            ctx.barrier_all();
        });
        t2.row(
            &format!("{n} PEs contending"),
            vec![
                spec_ns.load(Ordering::Relaxed) as f64,
                named_ns.load(Ordering::Relaxed) as f64,
            ],
        );
    }
    t2.print();
    t2.write_csv("ablationC_locks").unwrap();

    // --- C1/C2 contended tiers: 2/4/8 threads per PE, all PEs, one target
    // cell and one target lock. CSV feeds the ROADMAP MCS-lock decision
    // (see fn contended_rates docs).
    let mut tc = Table::new(
        "Ablation C contended tiers: same-cell fadd / same-lock acquire, all threads x all PEs",
        "ns/op (PE 0's view)",
        &["fadd", "named-lock"],
    );
    for &pes in &[1usize, 2, 4] {
        for &threads in &[2usize, 4, 8] {
            let (fadd_ns, lock_ns) = contended_rates(pes, threads, 20_000, 150);
            tc.row(&format!("{pes} PE x {threads} thr"), vec![fadd_ns, lock_ns]);
        }
    }
    tc.print();
    tc.write_csv("ablationC_contended").unwrap();

    // --- C3: SHMEM_THREAD_MULTIPLE scaling — one shared SERIALIZED
    // context (mutex-funnelled) vs a per-thread context pool. The ≥2×
    // acceptance gate at 8 threads pins the point of `ctx_for_thread`:
    // per-thread completion state scales where a shared lock serialises.
    let mut t3 = Table::new(
        "Ablation C3: aggregate put_nbi throughput — shared SERIALIZED ctx vs ctx-per-thread",
        "Mops/s aggregate (speedup column is the ratio)",
        &["shared-serialized", "ctx-per-thread", "speedup"],
    );
    let ops = 60_000;
    for &threads in &[1usize, 2, 4, 8] {
        let (mut a, mut b) = ctx_threads_rates(threads, ops);
        if threads == 8 && b < 2.0 * a {
            // One retry to shake scheduler noise before the gate.
            let (a2, b2) = ctx_threads_rates(threads, ops);
            a = a2;
            b = b2;
        }
        let speedup = b / a;
        if threads == 8 && std::env::var_os("POSH_BENCH_NO_ASSERT").is_none() {
            assert!(
                speedup >= 2.0,
                "(op=put_nbi, size=64B, algo=ctx-per-thread) must give >= 2x \
                 aggregate throughput over (op=put_nbi, size=64B, \
                 algo=shared-serialized) at 8 threads: got {speedup:.2}x \
                 ({b:.0} vs {a:.0} ops/s) after one noise retry; set \
                 POSH_BENCH_NO_ASSERT=1 to record anyway"
            );
        }
        t3.row(&format!("{threads} threads"), vec![a / 1e6, b / 1e6, speedup]);
    }
    t3.print();
    t3.write_csv("ablationC_ctx_threads").unwrap();
    println!(
        "\ncsv: bench_out/ablationC_atomics.csv, bench_out/ablationC_locks.csv, \
         bench_out/ablationC_contended.csv, bench_out/ablationC_ctx_threads.csv"
    );
}
