//! Code generation half of the pre-parser (§4.2): allocation/deallocation
//! lines, the transformed source, and the statics manifest.
//!
//! "When the OpenSHMEM library is initialized (i.e., when the `start_pes`
//! routine is called), it dumps the allocation code into the source code.
//! When the program exits (i.e., when the keyword `return` is found in the
//! main function), the deallocation code lines are inserted before each
//! `return` keyword."

use super::decl::{parse_declarations, StaticDecl};
use crate::symheap::SymHeap;
use crate::Result;

/// A machine-readable statics manifest: what `start_pes` must reserve.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    /// The declarations, in source order (placement order matters: the
    /// statics area is a bump allocator, so order ⇒ offsets).
    pub decls: Vec<StaticDecl>,
}

impl Manifest {
    /// Build from a C source.
    pub fn from_source(src: &str) -> Manifest {
        Manifest { decls: parse_declarations(src) }
    }

    /// Total bytes of the statics area this manifest needs (with per-object
    /// natural alignment, bump-allocated in order).
    pub fn total_bytes(&self) -> usize {
        let mut cursor = 0usize;
        for d in &self.decls {
            cursor = crate::util::align_up(cursor, d.align());
            cursor += d.byte_size();
        }
        cursor
    }

    /// Apply to a heap: place every object in the statics area, in order.
    /// Returns `(name, offset, size)` triples. This is the run-time half of
    /// the §4.2 trick — what the generated `start_pes` code does in C.
    pub fn place(&self, heap: &SymHeap) -> Result<Vec<(String, usize, usize)>> {
        let mut out = Vec::with_capacity(self.decls.len());
        for d in &self.decls {
            let p = heap.place_static(d.byte_size(), d.align())?;
            out.push((d.name.clone(), p.offset(), d.byte_size()));
        }
        Ok(out)
    }

    /// Serialise as the `posh.statics` text format (one line per object:
    /// `name type count bytes align init`).
    pub fn to_text(&self) -> String {
        let mut s = String::from("# posh statics manifest v1\n");
        for d in &self.decls {
            s.push_str(&format!(
                "{} {} {} {} {} {}\n",
                d.name,
                d.ty.c_name().replace(' ', "_"),
                d.count,
                d.byte_size(),
                d.align(),
                if d.initialized { "data" } else { "bss" },
            ));
        }
        s
    }

    /// The C allocation lines the paper's tool dumps into `start_pes`.
    pub fn alloc_code(&self) -> String {
        let mut s = String::new();
        s.push_str("/* POSH pre-parser: symmetric placements of global statics (auto-generated) */\n");
        for d in &self.decls {
            s.push_str(&format!(
                "__posh_static_{name} = shmemalign({align}, {size}); /* {ty} {name}[{n}] ({seg}) */\n",
                name = d.name,
                align = d.align(),
                size = d.byte_size(),
                ty = d.ty.c_name(),
                n = d.count,
                seg = if d.initialized { "data" } else { "bss" },
            ));
            if d.initialized {
                s.push_str(&format!(
                    "memcpy(__posh_static_{name}, &{name}, {size}); /* copy data-segment image */\n",
                    name = d.name,
                    size = d.byte_size(),
                ));
            }
        }
        s
    }

    /// The C deallocation lines inserted before each `return` of `main`.
    pub fn dealloc_code(&self) -> String {
        let mut s = String::new();
        s.push_str("/* POSH pre-parser: release symmetric statics (auto-generated) */\n");
        for d in self.decls.iter().rev() {
            s.push_str(&format!("shfree(__posh_static_{});\n", d.name));
        }
        s
    }
}

/// Transform a C source the way the paper describes: inject allocation code
/// right after the `start_pes(…)` call and deallocation code before every
/// `return` inside `main`.
pub fn transform_source(src: &str) -> (String, Manifest) {
    let manifest = Manifest::from_source(src);
    if manifest.decls.is_empty() {
        return (src.to_string(), manifest);
    }
    let alloc = manifest.alloc_code();
    let dealloc = manifest.dealloc_code();

    // Pass 1: inside main's body, prefix each `return` with the dealloc
    // code (done first, on the whole source, so `main` is still findable).
    let with_dealloc = inject_before_main_returns(src, &dealloc);
    // Pass 2: inject the allocation block after the ';' terminating the
    // start_pes(...) call.
    let out = if let Some(pos) = with_dealloc.find("start_pes") {
        if let Some(semi) = with_dealloc[pos..].find(';') {
            let cut = pos + semi + 1;
            format!("{}\n{}{}", &with_dealloc[..cut], alloc, &with_dealloc[cut..])
        } else {
            format!("/* POSH: no start_pes() found; alloc code follows */\n{alloc}{with_dealloc}")
        }
    } else {
        // No start_pes call found: emit the alloc block as a comment header
        // so the developer sees what would be injected.
        format!("/* POSH: no start_pes() found; alloc code follows */\n{alloc}{with_dealloc}")
    };
    (out, manifest)
}

/// Find `main`'s body and splice `code` before each of its `return`s.
fn inject_before_main_returns(src: &str, code: &str) -> String {
    let Some(main_pos) = find_main(src) else {
        return src.to_string();
    };
    let Some(body_open) = src[main_pos..].find('{').map(|p| p + main_pos) else {
        return src.to_string();
    };
    // Walk the body tracking brace depth; a `return` at any depth inside
    // main gets the epilogue (the paper: "before each return keyword").
    let bytes = src.as_bytes();
    let mut depth = 0usize;
    let mut i = body_open;
    let mut out = String::with_capacity(src.len() + code.len());
    out.push_str(&src[..body_open]);
    let mut last_emit = body_open;
    while i < bytes.len() {
        match bytes[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    i += 1;
                    break;
                }
            }
            b'r' if src[i..].starts_with("return")
                && !src[..i].ends_with(|c: char| c.is_ascii_alphanumeric() || c == '_')
                && !src[i + 6..].starts_with(|c: char| c.is_ascii_alphanumeric() || c == '_') =>
            {
                out.push_str(&src[last_emit..i]);
                out.push_str(code);
                last_emit = i;
            }
            _ => {}
        }
        i += 1;
    }
    out.push_str(&src[last_emit..]);
    out
}

/// Locate the definition of `main` (crudely: the identifier `main` followed
/// by `(` at file scope).
fn find_main(src: &str) -> Option<usize> {
    let mut from = 0;
    while let Some(rel) = src[from..].find("main") {
        let pos = from + rel;
        let before_ok = pos == 0
            || !src[..pos].ends_with(|c: char| c.is_ascii_alphanumeric() || c == '_');
        let after = &src[pos + 4..];
        let after_ok = after.trim_start().starts_with('(');
        if before_ok && after_ok {
            return Some(pos);
        }
        from = pos + 4;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shm::create_inproc;
    use crate::symheap::layout::Layout;

    const SAMPLE: &str = r#"
#include <shmem.h>
static int hits;
static double grid[64];
static long total = 7;

int main(int argc, char** argv) {
    start_pes(0);
    if (argc > 1) { return 1; }
    for (int i = 0; i < 64; i++) grid[i] = 0.0;
    return 0;
}
"#;

    #[test]
    fn manifest_extraction() {
        let m = Manifest::from_source(SAMPLE);
        assert_eq!(m.decls.len(), 3);
        assert_eq!(m.decls[0].name, "hits");
        assert_eq!(m.decls[1].byte_size(), 512);
        assert!(m.decls[2].initialized);
        // 4 (hits) -> pad to 8 -> 512 (grid) -> 8 (total) = 528
        assert_eq!(m.total_bytes(), 8 + 512 + 8);
    }

    #[test]
    fn placement_on_real_heap() {
        let layout = Layout::compute(1 << 16, 8192);
        let heap = SymHeap::new(create_inproc(layout.total).unwrap(), layout, 0).unwrap();
        let m = Manifest::from_source(SAMPLE);
        let placed = m.place(&heap).unwrap();
        assert_eq!(placed.len(), 3);
        // Offsets ordered and aligned.
        assert!(placed[0].1 < placed[1].1 && placed[1].1 < placed[2].1);
        assert_eq!(placed[1].1 % 8, 0);
        // Two heaps, same manifest ⇒ same offsets (Fact 1 for statics).
        let heap2 = SymHeap::new(create_inproc(layout.total).unwrap(), layout, 1).unwrap();
        let placed2 = m.place(&heap2).unwrap();
        assert_eq!(placed, placed2);
    }

    #[test]
    fn transform_injects_alloc_after_start_pes() {
        let (out, m) = transform_source(SAMPLE);
        assert_eq!(m.decls.len(), 3);
        let sp = out.find("start_pes(0);").unwrap();
        let alloc = out.find("__posh_static_hits = shmemalign").unwrap();
        assert!(alloc > sp, "alloc code must follow start_pes");
        // Initialised object gets its data-segment image copied.
        assert!(out.contains("memcpy(__posh_static_total, &total, 8);"));
    }

    #[test]
    fn transform_injects_dealloc_before_each_return() {
        let (out, _) = transform_source(SAMPLE);
        let frees = out.matches("shfree(__posh_static_hits);").count();
        assert_eq!(frees, 2, "two returns in main ⇒ two epilogues:\n{out}");
        // Epilogue precedes the final `return 0;`.
        let last_free = out.rfind("shfree(__posh_static_hits);").unwrap();
        let ret0 = out.rfind("return 0;").unwrap();
        assert!(last_free < ret0);
    }

    #[test]
    fn returns_outside_main_untouched() {
        let src = r#"
static int x;
int helper(void) { return 3; }
int main(void) { start_pes(0); return 0; }
"#;
        let (out, _) = transform_source(src);
        // helper's return must not get an epilogue.
        let helper_pos = out.find("helper").unwrap();
        let main_pos = out.find("main").unwrap();
        let first_free = out.find("shfree").unwrap();
        assert!(first_free > main_pos || first_free < helper_pos);
        assert_eq!(out.matches("shfree(__posh_static_x);").count(), 1);
    }

    #[test]
    fn source_without_statics_unchanged() {
        let src = "int main(void){ start_pes(0); return 0; }";
        let (out, m) = transform_source(src);
        assert!(m.decls.is_empty());
        assert_eq!(out, src);
    }

    #[test]
    fn manifest_text_format() {
        let m = Manifest::from_source("static int a[3] = {1,2,3};");
        let t = m.to_text();
        assert!(t.contains("a int 3 12 4 data"));
    }

    #[test]
    fn identifier_containing_return_not_confused() {
        let src = r#"
static int x;
int main(void) {
    int return_code = 0;
    start_pes(0);
    return return_code;
}
"#;
        let (out, _) = transform_source(src);
        // Only the real `return` statement gets the epilogue.
        assert_eq!(out.matches("shfree(__posh_static_x);").count(), 1);
        assert!(!out.contains("shfree(__posh_static_x);\nreturn_code"));
    }
}
