//! Job configuration. The paper fixes most of these at compile time
//! (§4.4 memcpy impl, §4.5.4 collective algorithm, §4.7 `_SAFE`/`_DEBUG`);
//! POSH-RS keeps the compile-time defaults (cargo features) and lets the
//! config/env override them once at start-up — resolved before the data
//! path, so the hot loop still sees a single indirect call, not a branch.

use crate::collectives::AlgoKind;
use crate::mem::copy::CopyImpl;
use crate::model::CostModel;

/// How PEs are realised.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// PEs are threads of one process; segments are private mappings.
    Threads,
    /// PEs are processes; segments are named POSIX shm objects.
    Processes,
}

/// Which barrier algorithm `barrier_all` uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BarrierKind {
    /// Dissemination barrier: log2(n) rounds of header-mailbox signals.
    Dissemination,
    /// Central counter + sense reversal (ablation baseline).
    Central,
}

/// Which algorithm team syncs run over the per-team cells. With
/// `PoshConfig::team_barrier = None` (the default) the tuning engine picks
/// per team size — which resolves to dissemination (O(log n) rounds in
/// team-rank space) on every size; the linear fan-in on the team root is
/// kept as the forced Ablation-B A/B baseline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TeamBarrierKind {
    /// Dissemination over the team's per-round mailbox cells.
    Dissemination,
    /// Linear fan-in/fan-out on the team root (pre-dissemination baseline).
    LinearFanin,
    /// Two-level socket-hierarchical sync: members fan in on their socket
    /// leader, leaders fan in on the root leader, release flows back down.
    /// Selected by the tuning engine only on multi-socket topologies; can
    /// be forced with `POSH_TEAM_BARRIER=hier` (degenerates to a linear
    /// fan-in on a flat topology — correct, just not faster).
    Hierarchical,
}

/// Job-wide configuration.
#[derive(Clone, Debug)]
pub struct PoshConfig {
    /// Dynamic symmetric-heap size per PE, in bytes.
    pub heap_size: usize,
    /// Statics area size per PE (§4.2 pre-parser placements).
    pub statics_size: usize,
    /// Forced copy implementation for every size; `None` keeps the default
    /// dispatch — size-aware planned ([`crate::mem::plan::CopyPlan`]) unless
    /// a `copy-*` cargo feature pins an engine.
    pub copy_impl: Option<CopyImpl>,
    /// Default collective algorithm; `None` keeps the compile-time default
    /// (which is [`AlgoKind::Adaptive`] unless a `coll-*` feature pins it).
    pub coll_algo: Option<AlgoKind>,
    /// Barrier algorithm.
    pub barrier: BarrierKind,
    /// Team-sync algorithm over the per-team cells; `None` lets the tuning
    /// engine decide per team size (`Some` forces one — the Ablation-B A/B
    /// switch).
    pub team_barrier: Option<TeamBarrierKind>,
    /// Postulated channel model for the tuning engine (α/β, what
    /// `POSH_ALPHA_NS`/`POSH_BETA_GBPS` set); `None` calibrates at world
    /// creation (thread mode shares the process engine; process mode rank 0
    /// publishes and peers adopt).
    pub cost_model: Option<CostModel>,
    /// Run-time safe mode (§4.5.5 checks). The `safe-mode` cargo feature
    /// forces this on.
    pub safe: bool,
    /// LRU cap on concurrently mapped peer segments in the process-mode
    /// remote-heap table (`POSH_MAX_MAPPED_SEGS`; `None` = unlimited).
    /// See [`crate::pe::remote_table::TableOpts::max_mapped`] for the
    /// THREAD_MULTIPLE safety caveat.
    pub max_mapped_segs: Option<usize>,
    /// Map every peer's segment eagerly at attach (`POSH_EAGER_MAP=1`) —
    /// the paper's original start-up shape, now opt-in. Default is demand
    /// mapping: peers map on first access.
    pub eager_map: bool,
    /// Synthetic topology shaping (`POSH_PES_PER_SOCKET` /
    /// `oshrun --pes-per-socket N`): force the blocked PE→socket map to put
    /// `N` consecutive world ranks per socket, bypassing sysfs detection.
    /// `None` (the default) detects the real NUMA layout and falls back to
    /// flat. This is how the hierarchical schedules are exercised on a
    /// single-socket CI runner.
    pub pes_per_socket: Option<usize>,
}

impl Default for PoshConfig {
    fn default() -> Self {
        Self {
            heap_size: 64 << 20,
            statics_size: crate::symheap::layout::DEFAULT_STATICS_SIZE,
            copy_impl: None,
            coll_algo: None,
            barrier: BarrierKind::Dissemination,
            team_barrier: None,
            cost_model: None,
            safe: cfg!(feature = "safe-mode"),
            max_mapped_segs: None,
            eager_map: false,
            pes_per_socket: None,
        }
    }
}

impl PoshConfig {
    /// A small-heap config for tests (fast to map and zero).
    pub fn small() -> Self {
        Self {
            heap_size: 4 << 20,
            statics_size: 64 << 10,
            ..Default::default()
        }
    }

    /// Apply `POSH_*` environment overrides (used by `oshrun` children):
    /// `POSH_HEAP_SIZE`, `POSH_STATICS_SIZE`, `POSH_COPY`, `POSH_COLL_ALGO`,
    /// `POSH_BARRIER`, `POSH_TEAM_BARRIER`, `POSH_ALPHA_NS` +
    /// `POSH_BETA_GBPS`, `POSH_SAFE`, `POSH_PES_PER_SOCKET`. See
    /// `docs/tuning.md` for the knob handbook (the cross-socket tier knobs
    /// `POSH_XSOCK_ALPHA_NS`/`POSH_XSOCK_BETA_GBPS` are read at world
    /// creation, not here).
    pub fn from_env(mut self) -> Self {
        if let Ok(v) = std::env::var("POSH_HEAP_SIZE") {
            if let Some(n) = parse_size(&v) {
                self.heap_size = n;
            }
        }
        if let Ok(v) = std::env::var("POSH_STATICS_SIZE") {
            if let Some(n) = parse_size(&v) {
                self.statics_size = n;
            }
        }
        if let Ok(v) = std::env::var("POSH_COPY") {
            match v.to_ascii_lowercase().as_str() {
                // Explicitly restore size-aware planned dispatch (undoes a
                // forced engine from earlier in the process).
                "planned" | "plan" | "auto" => {
                    self.copy_impl = None;
                    crate::mem::copy::set_global_planned();
                }
                _ => self.copy_impl = CopyImpl::parse(&v),
            }
        }
        if let Ok(v) = std::env::var("POSH_COLL_ALGO") {
            self.coll_algo = AlgoKind::parse(&v);
        }
        if let Ok(v) = std::env::var("POSH_BARRIER") {
            self.barrier = match v.to_ascii_lowercase().as_str() {
                "central" => BarrierKind::Central,
                _ => BarrierKind::Dissemination,
            };
        }
        if let Ok(v) = std::env::var("POSH_TEAM_BARRIER") {
            self.team_barrier = match v.to_ascii_lowercase().as_str() {
                "linear" | "fanin" => Some(TeamBarrierKind::LinearFanin),
                "hier" | "hierarchical" => Some(TeamBarrierKind::Hierarchical),
                "adaptive" | "auto" | "" => None,
                _ => Some(TeamBarrierKind::Dissemination),
            };
        }
        if let Some(cm) = crate::collectives::tuning::env_model() {
            self.cost_model = Some(cm);
        }
        if let Ok(v) = std::env::var("POSH_SAFE") {
            self.safe = v == "1" || v.eq_ignore_ascii_case("true");
        }
        if let Ok(v) = std::env::var("POSH_MAX_MAPPED_SEGS") {
            // 0 / "unlimited" / unparsable all mean "no cap".
            self.max_mapped_segs = v.parse::<usize>().ok().filter(|&n| n > 0);
        }
        if let Ok(v) = std::env::var("POSH_EAGER_MAP") {
            self.eager_map = v == "1" || v.eq_ignore_ascii_case("true");
        }
        if let Ok(v) = std::env::var("POSH_PES_PER_SOCKET") {
            // 0 / unparsable mean "no forcing" (fall back to detection).
            self.pes_per_socket = v.parse::<usize>().ok().filter(|&n| n > 0);
        }
        self
    }
}

/// Parse "64M", "1G", "4096", "16k" style sizes.
pub fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    if s.is_empty() {
        return None;
    }
    let (num, mult) = match s.as_bytes()[s.len() - 1].to_ascii_lowercase() {
        b'k' => (&s[..s.len() - 1], 1usize << 10),
        b'm' => (&s[..s.len() - 1], 1 << 20),
        b'g' => (&s[..s.len() - 1], 1 << 30),
        _ => (s, 1),
    };
    num.trim().parse::<usize>().ok().map(|n| n * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_size_units() {
        assert_eq!(parse_size("4096"), Some(4096));
        assert_eq!(parse_size("16k"), Some(16 << 10));
        assert_eq!(parse_size("64M"), Some(64 << 20));
        assert_eq!(parse_size("2G"), Some(2 << 30));
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("x"), None);
    }

    #[test]
    fn default_config_sane() {
        let c = PoshConfig::default();
        assert!(c.heap_size >= 1 << 20);
        assert!(c.statics_size >= 1 << 12);
        assert_eq!(c.barrier, BarrierKind::Dissemination);
        // Team sync and collective algorithm default to model-driven
        // (adaptive) selection; the cost model defaults to calibration.
        assert_eq!(c.team_barrier, None);
        assert_eq!(c.coll_algo, None);
        assert!(c.cost_model.is_none());
    }
}
