//! Shard internals: a skiplist memtable with a **fixed on-heap layout**,
//! readable and writable through either a direct pointer (local fast path)
//! or one-sided copies (remote path) — the same bytes, two access planes.
//!
//! # Arena layout
//!
//! A shard is one `u8` arena from the symmetric heap. All offsets below are
//! arena-relative; link words are `u32` arena offsets, so an arena must be
//! smaller than 4 GiB. Offset `0` is the header, which conveniently makes
//! `0` the null link.
//!
//! ```text
//! 0   bump cursor        u64   next free byte (starts at ARENA_HDR)
//! 8   version            u64   publication flag; last committed seq
//! 16  key count          u64   distinct keys in the shard
//! 24  (pad)
//! 32  head links         u32 × MAX_HEIGHT
//! 80  = ARENA_HDR        nodes and value blobs, bump-allocated
//! ```
//!
//! # Node layout (at an 8-aligned arena offset)
//!
//! ```text
//! 0   key length         u16 LE
//! 2   height             u8      (1..=MAX_HEIGHT, derived from the key hash)
//! 3   (pad to 8)
//! 8   value word         u64     (value offset << 32) | value length
//! 16  seq                u64     last-writer-wins sequence number
//! 24  links              u32 × height
//! 24 + 4·height  key bytes
//! ```
//!
//! Value blobs are immutable: an overwrite appends a new blob and swings the
//! node's value word (one 8-byte store), so a reader can never observe a
//! torn value. Node heights come from the key hash — deterministic, so no
//! per-shard RNG state needs to live in the arena.
//!
//! # Concurrency contract
//!
//! *Writers* hold the shard's named lock, so the structure is single-writer.
//! *Readers* are lock-free and may race the writer; safety comes from
//! publication order (enforced by [`ShardView::flush_data`] before any link
//! or value word becomes visible): a node's bytes are fully delivered before
//! any link points at it, links splice bottom-up, and blobs are written
//! before the value word swings. A racing reader sees the old state or the
//! new state of each word, never garbage.

use crate::ctx::CommCtx;
use crate::pe::Ctx;
use crate::symheap::SymPtr;
use crate::util::align_up;
use crate::Result;
use anyhow::bail;
use std::sync::atomic::{fence, AtomicU32, AtomicU64, Ordering};

/// Maximum skiplist height (p = 1/4 ⇒ comfortable up to ~16M keys/shard).
pub(crate) const MAX_HEIGHT: usize = 12;

/// Header field offsets (see module docs).
pub(crate) const OFF_BUMP: usize = 0;
pub(crate) const OFF_VERSION: usize = 8;
pub(crate) const OFF_COUNT: usize = 16;
pub(crate) const OFF_HEAD: usize = 32;
/// First byte available to the bump allocator (16-aligned).
pub(crate) const ARENA_HDR: usize = 80;

/// Node field offsets (node-relative).
const NODE_VAL: usize = 8;
const NODE_SEQ: usize = 16;
const NODE_LINKS: usize = 24;

/// One shard, seen either through a direct pointer (shared-memory reach —
/// the `shmem_ptr` fast path) or through one-sided copies addressed by the
/// arena handle (the portable remote plane).
pub(crate) enum ShardView<'a> {
    /// Direct load/store access to the arena base in this address space.
    Local {
        /// Arena base pointer (resolved once via `shmem_ptr`).
        base: *mut u8,
    },
    /// One-sided access: reads via `get`/`get_one`, bulk writes as NBI puts
    /// on `comm` (the calling thread's pooled context), word writes as
    /// immediate `put_one`s.
    Remote {
        /// The calling PE's context (carries the remote-heap table).
        ctx: &'a Ctx,
        /// Owner PE of the shard (world rank == world-team rank).
        pe: usize,
        /// The shard arena handle (identical on every PE by Fact 1).
        arena: SymPtr<u8>,
        /// NBI domain for bulk writes; `None` on read-only paths.
        comm: Option<&'a CommCtx>,
    },
}

impl ShardView<'_> {
    /// Read `dst.len()` bytes starting at arena offset `off`.
    pub(crate) fn read_bytes(&self, off: usize, dst: &mut [u8]) {
        match self {
            ShardView::Local { base } => {
                // SAFETY: callers stay within the arena (offsets come from
                // bump-allocated records; bounds guaranteed by `put`).
                unsafe {
                    std::ptr::copy_nonoverlapping(base.add(off), dst.as_mut_ptr(), dst.len());
                }
            }
            ShardView::Remote { ctx, pe, arena, .. } => {
                ctx.get(dst, arena.slice(off, dst.len()), *pe);
            }
        }
    }

    /// Atomic read of the `u32` at arena offset `off` (4-aligned).
    fn read_u32(&self, off: usize) -> u32 {
        debug_assert_eq!(off % 4, 0);
        match self {
            ShardView::Local { base } => {
                // SAFETY: in-arena, 4-aligned (layout invariant).
                unsafe { (*(base.add(off) as *const AtomicU32)).load(Ordering::Acquire) }
            }
            ShardView::Remote { ctx, pe, arena, .. } => {
                ctx.get_one(SymPtr::<u32>::from_raw(arena.offset() + off, 1), *pe)
            }
        }
    }

    /// Atomic read of the `u64` at arena offset `off` (8-aligned).
    pub(crate) fn read_u64(&self, off: usize) -> u64 {
        debug_assert_eq!(off % 8, 0);
        match self {
            ShardView::Local { base } => {
                // SAFETY: in-arena, 8-aligned (layout invariant).
                unsafe { (*(base.add(off) as *const AtomicU64)).load(Ordering::Acquire) }
            }
            ShardView::Remote { ctx, pe, arena, .. } => {
                ctx.get_one(SymPtr::<u64>::from_raw(arena.offset() + off, 1), *pe)
            }
        }
    }

    /// Bulk write (node bytes, value blobs). Remote: an NBI put on the
    /// thread's context — *not yet delivered*; call [`Self::flush_data`]
    /// before publishing anything that points at these bytes.
    fn write_bytes(&self, off: usize, src: &[u8]) {
        match self {
            ShardView::Local { base } => {
                // SAFETY: in-arena; region is unpublished bump space, so no
                // reader can be looking at it yet.
                unsafe {
                    std::ptr::copy_nonoverlapping(src.as_ptr(), base.add(off), src.len());
                }
            }
            ShardView::Remote { ctx, pe, arena, comm } => {
                let dest = arena.slice(off, src.len());
                match comm {
                    Some(c) => c.put_nbi(dest, src, *pe),
                    None => ctx.put(dest, src, *pe),
                }
            }
        }
    }

    /// Publishing write of the `u32` at `off` (a skiplist link). Immediate
    /// on both planes — remote uses `put_one`, which is a direct volatile
    /// store through the mapped segment, not an NBI.
    fn write_u32(&self, off: usize, v: u32) {
        debug_assert_eq!(off % 4, 0);
        match self {
            ShardView::Local { base } => {
                // SAFETY: in-arena, 4-aligned.
                unsafe { (*(base.add(off) as *const AtomicU32)).store(v, Ordering::Release) }
            }
            ShardView::Remote { ctx, pe, arena, .. } => {
                ctx.put_one(SymPtr::<u32>::from_raw(arena.offset() + off, 1), v, *pe);
            }
        }
    }

    /// Publishing write of the `u64` at `off` (value word, seq, header
    /// fields). Immediate on both planes.
    fn write_u64(&self, off: usize, v: u64) {
        debug_assert_eq!(off % 8, 0);
        match self {
            ShardView::Local { base } => {
                // SAFETY: in-arena, 8-aligned.
                unsafe { (*(base.add(off) as *const AtomicU64)).store(v, Ordering::Release) }
            }
            ShardView::Remote { ctx, pe, arena, .. } => {
                ctx.put_one(SymPtr::<u64>::from_raw(arena.offset() + off, 1), v, *pe);
            }
        }
    }

    /// Complete all [`Self::write_bytes`] traffic: flag-after-data's "data"
    /// half. Local: a release fence. Remote: quiet the thread's NBI context
    /// so every deferred put is delivered before the caller publishes.
    fn flush_data(&self) {
        match self {
            ShardView::Local { .. } => fence(Ordering::Release),
            ShardView::Remote { ctx, comm, .. } => match comm {
                Some(c) => c.quiet(),
                None => ctx.quiet(),
            },
        }
    }

    /// Order the link/word publications before the version bump (the "flag"
    /// half of flag-after-data).
    fn publish_fence(&self) {
        match self {
            ShardView::Local { .. } => fence(Ordering::Release),
            ShardView::Remote { ctx, .. } => ctx.quiet(),
        }
    }
}

/// Pack (blob offset, blob length) into a node's value word.
fn pack_val(off: usize, len: usize) -> u64 {
    debug_assert!(off <= u32::MAX as usize && len <= u32::MAX as usize);
    ((off as u64) << 32) | len as u64
}

/// Unpack a node's value word.
fn unpack_val(word: u64) -> (usize, usize) {
    ((word >> 32) as usize, (word & 0xFFFF_FFFF) as usize)
}

/// Deterministic node height from the key hash: p = 1/4 per extra level.
/// The hash is remixed first so the height bits are independent of the
/// routing bits (which consume the raw low/high halves).
pub(crate) fn height_of(hash: u64) -> u8 {
    let mut bits = hash.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 16;
    let mut h: u8 = 1;
    while (h as usize) < MAX_HEIGHT && bits & 3 == 0 {
        h += 1;
        bits >>= 2;
    }
    h
}

/// Initialise a freshly allocated arena: zero the header (heap memory may
/// be recycled) and point the bump cursor past it. Must run on the owner's
/// local view before any PE touches the shard.
pub(crate) fn init_header(view: &ShardView<'_>) {
    view.write_bytes(0, &[0u8; ARENA_HDR]);
    view.flush_data();
    view.write_u64(OFF_BUMP, ARENA_HDR as u64);
}

/// Arena offset of the level-`lvl` link cell of `pred` (`0` = the head).
fn link_off(pred: u32, lvl: usize) -> usize {
    if pred == 0 {
        OFF_HEAD + 4 * lvl
    } else {
        pred as usize + NODE_LINKS + 4 * lvl
    }
}

/// Read a node's (key length, height) header.
fn node_meta(view: &ShardView<'_>, node: u32) -> (usize, usize) {
    let mut hdr = [0u8; 4];
    view.read_bytes(node as usize, &mut hdr);
    let klen = u16::from_le_bytes([hdr[0], hdr[1]]) as usize;
    let height = hdr[2] as usize;
    debug_assert!(height >= 1 && height <= MAX_HEIGHT, "corrupt node at {node:#x}");
    (klen, height)
}

/// Compare `node`'s key against `key` byte-lexicographically. `buf` is a
/// caller-provided scratch buffer (reused across the nodes of one descent
/// so the remote path doesn't allocate per visited node).
fn cmp_node_key(
    view: &ShardView<'_>,
    node: u32,
    key: &[u8],
    buf: &mut Vec<u8>,
) -> std::cmp::Ordering {
    let (klen, height) = node_meta(view, node);
    buf.clear();
    buf.resize(klen, 0);
    view.read_bytes(node as usize + NODE_LINKS + 4 * height, buf);
    buf.as_slice().cmp(key)
}

/// Skiplist descent. Returns the node holding `key` (if present) and, per
/// level, the arena offset of the link cell to splice a new node into —
/// i.e. the level-`lvl` link of the rightmost element with key `< key`.
fn search(view: &ShardView<'_>, key: &[u8]) -> (Option<u32>, [usize; MAX_HEIGHT]) {
    let mut update = [0usize; MAX_HEIGHT];
    let mut buf = Vec::with_capacity(64);
    let mut pred: u32 = 0;
    for lvl in (0..MAX_HEIGHT).rev() {
        loop {
            let next = view.read_u32(link_off(pred, lvl));
            if next == 0 || cmp_node_key(view, next, key, &mut buf) != std::cmp::Ordering::Less {
                break;
            }
            pred = next;
        }
        update[lvl] = link_off(pred, lvl);
    }
    let next = view.read_u32(link_off(pred, 0));
    let found = (next != 0 && cmp_node_key(view, next, key, &mut buf) == std::cmp::Ordering::Equal)
        .then_some(next);
    (found, update)
}

/// Insert or overwrite `key` → `value`. **Caller must hold the shard's
/// named lock** — this routine is single-writer. Returns the sequence
/// number assigned to the write (shard-monotonic: within one shard, a
/// larger seq means a strictly later commit).
///
/// Publication order (safe against lock-free readers):
/// 1. node bytes + value blob into unpublished bump space,
/// 2. [`ShardView::flush_data`] — everything delivered,
/// 3. links spliced bottom-up / value word swung (single-word stores),
/// 4. [`ShardView::publish_fence`], then the header version ← seq.
pub(crate) fn put(
    view: &ShardView<'_>,
    arena_bytes: usize,
    key: &[u8],
    value: &[u8],
    hash: u64,
) -> Result<u64> {
    let seq = view.read_u64(OFF_VERSION) + 1;
    let (found, update) = search(view, key);
    let bump = view.read_u64(OFF_BUMP) as usize;
    let vlen_pad = align_up(value.len(), 8);

    match found {
        Some(node) => {
            // Overwrite: append a fresh blob, swing the value word.
            let val_off = align_up(bump, 8);
            if val_off + vlen_pad > arena_bytes {
                bail!(
                    "kv shard arena exhausted: need {} value bytes at {val_off}, arena is {arena_bytes}",
                    vlen_pad
                );
            }
            if !value.is_empty() {
                view.write_bytes(val_off, value);
            }
            view.write_u64(OFF_BUMP, (val_off + vlen_pad) as u64);
            view.flush_data();
            view.write_u64(node as usize + NODE_VAL, pack_val(val_off, value.len()));
            view.write_u64(node as usize + NODE_SEQ, seq);
        }
        None => {
            // Fresh key: node, then blob, laid out back to back.
            let height = height_of(hash) as usize;
            let node_off = align_up(bump, 8);
            let node_size = align_up(NODE_LINKS + 4 * height + key.len(), 8);
            let val_off = node_off + node_size;
            if val_off + vlen_pad > arena_bytes {
                bail!(
                    "kv shard arena exhausted: need {} bytes at {node_off}, arena is {arena_bytes}",
                    node_size + vlen_pad
                );
            }
            let mut node_bytes = vec![0u8; node_size];
            node_bytes[0..2].copy_from_slice(&(key.len() as u16).to_le_bytes());
            node_bytes[2] = height as u8;
            node_bytes[NODE_VAL..NODE_VAL + 8]
                .copy_from_slice(&pack_val(val_off, value.len()).to_le_bytes());
            node_bytes[NODE_SEQ..NODE_SEQ + 8].copy_from_slice(&seq.to_le_bytes());
            for (lvl, link) in update.iter().enumerate().take(height) {
                let succ = view.read_u32(*link);
                node_bytes[NODE_LINKS + 4 * lvl..NODE_LINKS + 4 * lvl + 4]
                    .copy_from_slice(&succ.to_le_bytes());
            }
            let koff = NODE_LINKS + 4 * height;
            node_bytes[koff..koff + key.len()].copy_from_slice(key);

            view.write_bytes(node_off, &node_bytes);
            if !value.is_empty() {
                view.write_bytes(val_off, value);
            }
            view.write_u64(OFF_BUMP, (val_off + vlen_pad) as u64);
            view.flush_data();
            // Splice bottom-up: a concurrent reader may briefly miss the
            // node at upper levels (a slow path, never a wrong answer).
            for link in update.iter().take(height) {
                view.write_u32(*link, node_off as u32);
            }
            let count = view.read_u64(OFF_COUNT);
            view.write_u64(OFF_COUNT, count + 1);
        }
    }
    view.publish_fence();
    view.write_u64(OFF_VERSION, seq);
    Ok(seq)
}

/// Look up `key`; lock-free. Returns `(seq, value)` of a committed version,
/// or `None` if the key is absent. Racing a concurrent overwrite, the seq
/// and value are each individually valid but may belong to adjacent
/// versions; on a quiescent shard they always match.
pub(crate) fn get(view: &ShardView<'_>, key: &[u8]) -> Option<(u64, Vec<u8>)> {
    let (found, _) = search(view, key);
    let node = found? as usize;
    let seq = view.read_u64(node + NODE_SEQ);
    let (off, len) = unpack_val(view.read_u64(node + NODE_VAL));
    let mut value = vec![0u8; len];
    if len > 0 {
        view.read_bytes(off, &mut value);
    }
    Some((seq, value))
}

/// Number of distinct keys committed to the shard.
pub(crate) fn key_count(view: &ShardView<'_>) -> u64 {
    view.read_u64(OFF_COUNT)
}

/// Bytes of the arena consumed by the bump allocator (header included).
pub(crate) fn used_bytes(view: &ShardView<'_>) -> u64 {
    view.read_u64(OFF_BUMP)
}

#[cfg(test)]
mod tests {
    use super::*;

    const ARENA: usize = 64 * 1024;

    /// An 8-aligned scratch arena standing in for a symmetric-heap shard.
    fn mk_arena(bytes: usize) -> Vec<u64> {
        vec![0u64; bytes / 8]
    }

    fn hash(key: &[u8]) -> u64 {
        super::super::key_hash(key)
    }

    #[test]
    fn put_get_roundtrip_and_overwrite() {
        let mut buf = mk_arena(ARENA);
        let view = ShardView::Local { base: buf.as_mut_ptr() as *mut u8 };
        init_header(&view);
        // Insert in a scrambled order so the skiplist actually branches.
        for i in (0..100u32).map(|i| (i * 37) % 100) {
            let key = format!("user{i:08}");
            let val = format!("value-{i}-{}", "x".repeat((i % 13) as usize));
            put(&view, ARENA, key.as_bytes(), val.as_bytes(), hash(key.as_bytes())).unwrap();
        }
        assert_eq!(key_count(&view), 100);
        for i in 0..100u32 {
            let key = format!("user{i:08}");
            let (_, v) = get(&view, key.as_bytes()).expect("key present");
            assert_eq!(v, format!("value-{i}-{}", "x".repeat((i % 13) as usize)).as_bytes());
        }
        // Overwrites bump seq, not the key count.
        let k = b"user00000042";
        let (s1, _) = get(&view, k).unwrap();
        let s2 = put(&view, ARENA, k, b"fresh", hash(k)).unwrap();
        assert!(s2 > s1);
        assert_eq!(key_count(&view), 100);
        let (s3, v) = get(&view, k).unwrap();
        assert_eq!(s3, s2);
        assert_eq!(v, b"fresh");
        assert_eq!(view.read_u64(OFF_VERSION), s2);
    }

    #[test]
    fn level0_chain_is_sorted() {
        let mut buf = mk_arena(ARENA);
        let view = ShardView::Local { base: buf.as_mut_ptr() as *mut u8 };
        init_header(&view);
        for i in (0..64u32).rev() {
            let key = format!("k{:04}", (i * 29) % 64);
            put(&view, ARENA, key.as_bytes(), b"v", hash(key.as_bytes())).unwrap();
        }
        let mut node = view.read_u32(OFF_HEAD);
        let mut prev: Option<Vec<u8>> = None;
        let mut seen = 0;
        while node != 0 {
            let (klen, h) = node_meta(&view, node);
            let mut key = vec![0u8; klen];
            view.read_bytes(node as usize + NODE_LINKS + 4 * h, &mut key);
            if let Some(p) = &prev {
                assert!(p < &key, "chain out of order");
            }
            prev = Some(key);
            node = view.read_u32(link_off(node, 0));
            seen += 1;
        }
        assert_eq!(seen, 64);
    }

    #[test]
    fn missing_key_is_none() {
        let mut buf = mk_arena(ARENA);
        let view = ShardView::Local { base: buf.as_mut_ptr() as *mut u8 };
        init_header(&view);
        assert!(get(&view, b"nope").is_none());
        put(&view, ARENA, b"aa", b"1", hash(b"aa")).unwrap();
        assert!(get(&view, b"ab").is_none());
        assert!(get(&view, b"a").is_none());
        assert!(get(&view, b"aaa").is_none());
    }

    #[test]
    fn empty_value_roundtrips() {
        let mut buf = mk_arena(ARENA);
        let view = ShardView::Local { base: buf.as_mut_ptr() as *mut u8 };
        init_header(&view);
        put(&view, ARENA, b"key", b"", hash(b"key")).unwrap();
        let (_, v) = get(&view, b"key").unwrap();
        assert!(v.is_empty());
    }

    #[test]
    fn exhaustion_errors_and_preserves_existing() {
        let small = 512;
        let mut buf = mk_arena(small);
        let view = ShardView::Local { base: buf.as_mut_ptr() as *mut u8 };
        init_header(&view);
        let mut stored = vec![];
        let mut failed = false;
        for i in 0..64u32 {
            let key = format!("key{i:03}");
            match put(&view, small, key.as_bytes(), &[i as u8; 48], hash(key.as_bytes())) {
                Ok(_) => stored.push((key, i as u8)),
                Err(_) => {
                    failed = true;
                    break;
                }
            }
        }
        assert!(failed, "a 512-byte arena cannot hold 64 writes");
        assert!(!stored.is_empty(), "at least one write must fit");
        for (key, b) in &stored {
            let (_, v) = get(&view, key.as_bytes()).expect("pre-exhaustion key lost");
            assert_eq!(v, vec![*b; 48]);
        }
    }

    #[test]
    fn heights_deterministic_and_geometric() {
        let mut counts = [0usize; MAX_HEIGHT + 1];
        for i in 0..10_000u64 {
            let h = height_of(i.wrapping_mul(0x243F_6A88_85A3_08D3));
            assert!((1..=MAX_HEIGHT as u8).contains(&h));
            assert_eq!(h, height_of(i.wrapping_mul(0x243F_6A88_85A3_08D3)));
            counts[h as usize] += 1;
        }
        // p = 1/4: height 1 should hold roughly 3/4 of keys.
        assert!(counts[1] > 6_000, "height-1 share too small: {counts:?}");
        assert!(counts[2] > 1_000, "height-2 share too small: {counts:?}");
    }
}
