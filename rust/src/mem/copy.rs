//! The copy implementations themselves. See module docs in [`crate::mem`].
//!
//! All variants have the same contract as `memcpy`: `dst` and `src` must not
//! overlap and both must be valid for `len` bytes. Every vector variant
//! handles unaligned heads/tails by falling back to byte copies at the edges
//! and runs its vector body on the aligned middle, exactly like the paper's
//! hand-written loops.

use std::sync::atomic::{AtomicU8, Ordering};

/// Which copy implementation to use. Mirrors the paper's
/// `-D_MEMCPY_{MMX,MMX2,SSE}` compile switches; see [`CopyImpl::default_impl`]
/// for the feature wiring.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum CopyImpl {
    /// libc/compiler `memcpy` — the paper's "stock" row.
    Stock = 0,
    /// 8×-unrolled 64-bit scalar loop — the MMX-era 64-bit path.
    Unrolled64 = 1,
    /// 128-bit SSE2 loads/stores — the paper's SSE row.
    Sse2 = 2,
    /// 256-bit AVX2 loads/stores — the modern continuation of the sweep.
    Avx2 = 3,
    /// 128-bit non-temporal (streaming) stores — the MMX2 `movnt` trick.
    NonTemporal = 4,
}

impl CopyImpl {
    /// All variants that can run on the current CPU, in table order.
    pub fn available() -> Vec<CopyImpl> {
        let mut v = vec![CopyImpl::Stock, CopyImpl::Unrolled64];
        #[cfg(target_arch = "x86_64")]
        {
            // SSE2 is baseline on x86_64.
            v.push(CopyImpl::Sse2);
            if std::arch::is_x86_feature_detected!("avx2") {
                v.push(CopyImpl::Avx2);
            }
            v.push(CopyImpl::NonTemporal);
        }
        v
    }

    /// The compile-time default (paper §4.4: one impl is activated by a
    /// compiler directive; default = stock with a note in the build log).
    pub const fn default_impl() -> CopyImpl {
        #[cfg(feature = "copy-avx2")]
        {
            return CopyImpl::Avx2;
        }
        #[cfg(all(feature = "copy-sse2", not(feature = "copy-avx2")))]
        {
            return CopyImpl::Sse2;
        }
        #[cfg(all(
            feature = "copy-unrolled",
            not(any(feature = "copy-sse2", feature = "copy-avx2"))
        ))]
        {
            return CopyImpl::Unrolled64;
        }
        #[cfg(all(
            feature = "copy-nontemporal",
            not(any(
                feature = "copy-sse2",
                feature = "copy-avx2",
                feature = "copy-unrolled"
            ))
        ))]
        {
            return CopyImpl::NonTemporal;
        }
        #[allow(unreachable_code)]
        CopyImpl::Stock
    }

    /// Human-readable name matching the paper's table headers.
    pub fn name(&self) -> &'static str {
        match self {
            CopyImpl::Stock => "memcpy",
            CopyImpl::Unrolled64 => "unrolled64",
            CopyImpl::Sse2 => "sse2",
            CopyImpl::Avx2 => "avx2",
            CopyImpl::NonTemporal => "nontemporal",
        }
    }

    /// Parse from CLI / env spellings.
    pub fn parse(s: &str) -> Option<CopyImpl> {
        match s.to_ascii_lowercase().as_str() {
            "stock" | "memcpy" => Some(CopyImpl::Stock),
            "unrolled" | "unrolled64" | "mmx" => Some(CopyImpl::Unrolled64),
            "sse" | "sse2" => Some(CopyImpl::Sse2),
            "avx" | "avx2" => Some(CopyImpl::Avx2),
            "nt" | "nontemporal" | "mmx2" => Some(CopyImpl::NonTemporal),
            _ => None,
        }
    }
}

/// Process-wide selected implementation (runtime dispatch). Initialised to
/// the compile-time default; `set_global_impl` may override it once at
/// start-up (e.g. from `POSH_COPY=sse2`), after which the hot path reads it
/// with a relaxed load — one predictable branch-free indirect call, matching
/// the paper's "no conditional branches on the data path" goal.
static GLOBAL_IMPL: AtomicU8 = AtomicU8::new(CopyImpl::default_impl() as u8);

/// Install the process-wide copy implementation.
pub fn set_global_impl(imp: CopyImpl) {
    GLOBAL_IMPL.store(imp as u8, Ordering::Relaxed);
}

/// Read the process-wide copy implementation.
#[inline]
pub fn global_impl() -> CopyImpl {
    match GLOBAL_IMPL.load(Ordering::Relaxed) {
        0 => CopyImpl::Stock,
        1 => CopyImpl::Unrolled64,
        2 => CopyImpl::Sse2,
        3 => CopyImpl::Avx2,
        _ => CopyImpl::NonTemporal,
    }
}

/// Copy `len` bytes with the process-wide implementation.
///
/// # Safety
/// Same contract as `memcpy`: non-overlapping, both valid for `len`.
#[inline]
pub unsafe fn copy_bytes(dst: *mut u8, src: *const u8, len: usize) {
    copy_bytes_with(global_impl(), dst, src, len)
}

/// Copy `len` bytes with an explicit implementation (bench sweeps).
///
/// # Safety
/// Same contract as `memcpy`.
#[inline]
pub unsafe fn copy_bytes_with(imp: CopyImpl, dst: *mut u8, src: *const u8, len: usize) {
    match imp {
        CopyImpl::Stock => std::ptr::copy_nonoverlapping(src, dst, len),
        CopyImpl::Unrolled64 => copy_unrolled64(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::Sse2 => copy_sse2(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::Avx2 => copy_avx2(dst, src, len),
        #[cfg(target_arch = "x86_64")]
        CopyImpl::NonTemporal => copy_nontemporal(dst, src, len),
        #[cfg(not(target_arch = "x86_64"))]
        _ => std::ptr::copy_nonoverlapping(src, dst, len),
    }
}

/// 8×-unrolled 64-bit word loop with byte head/tail.
///
/// # Safety
/// `memcpy` contract.
pub unsafe fn copy_unrolled64(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    // Align the *destination* to 8 bytes (stores are the expensive side).
    while len > 0 && (dst as usize) & 7 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    let mut d = dst as *mut u64;
    let mut s = src as *const u64;
    while len >= 64 {
        // Unaligned reads are fine on x86; the stores are aligned.
        let v0 = (s as *const u64).read_unaligned();
        let v1 = (s.add(1) as *const u64).read_unaligned();
        let v2 = (s.add(2) as *const u64).read_unaligned();
        let v3 = (s.add(3) as *const u64).read_unaligned();
        let v4 = (s.add(4) as *const u64).read_unaligned();
        let v5 = (s.add(5) as *const u64).read_unaligned();
        let v6 = (s.add(6) as *const u64).read_unaligned();
        let v7 = (s.add(7) as *const u64).read_unaligned();
        d.write(v0);
        d.add(1).write(v1);
        d.add(2).write(v2);
        d.add(3).write(v3);
        d.add(4).write(v4);
        d.add(5).write(v5);
        d.add(6).write(v6);
        d.add(7).write(v7);
        d = d.add(8);
        s = s.add(8);
        len -= 64;
    }
    while len >= 8 {
        d.write((s as *const u64).read_unaligned());
        d = d.add(1);
        s = s.add(1);
        len -= 8;
    }
    let mut db = d as *mut u8;
    let mut sb = s as *const u8;
    while len > 0 {
        *db = *sb;
        db = db.add(1);
        sb = sb.add(1);
        len -= 1;
    }
}

/// 128-bit SSE2 loop (paper's SSE implementation).
///
/// # Safety
/// `memcpy` contract; x86_64 only (SSE2 is baseline there).
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_sse2(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 15 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 64 {
        let v0 = _mm_loadu_si128(src as *const __m128i);
        let v1 = _mm_loadu_si128(src.add(16) as *const __m128i);
        let v2 = _mm_loadu_si128(src.add(32) as *const __m128i);
        let v3 = _mm_loadu_si128(src.add(48) as *const __m128i);
        _mm_store_si128(dst as *mut __m128i, v0);
        _mm_store_si128(dst.add(16) as *mut __m128i, v1);
        _mm_store_si128(dst.add(32) as *mut __m128i, v2);
        _mm_store_si128(dst.add(48) as *mut __m128i, v3);
        dst = dst.add(64);
        src = src.add(64);
        len -= 64;
    }
    while len >= 16 {
        let v = _mm_loadu_si128(src as *const __m128i);
        _mm_store_si128(dst as *mut __m128i, v);
        dst = dst.add(16);
        src = src.add(16);
        len -= 16;
    }
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
}

/// 256-bit AVX2 loop. Falls back to SSE2 when AVX2 is absent.
///
/// # Safety
/// `memcpy` contract.
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_avx2(dst: *mut u8, src: *const u8, len: usize) {
    if std::arch::is_x86_feature_detected!("avx2") {
        copy_avx2_inner(dst, src, len);
    } else {
        copy_sse2(dst, src, len);
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn copy_avx2_inner(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 31 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 128 {
        let v0 = _mm256_loadu_si256(src as *const __m256i);
        let v1 = _mm256_loadu_si256(src.add(32) as *const __m256i);
        let v2 = _mm256_loadu_si256(src.add(64) as *const __m256i);
        let v3 = _mm256_loadu_si256(src.add(96) as *const __m256i);
        _mm256_store_si256(dst as *mut __m256i, v0);
        _mm256_store_si256(dst.add(32) as *mut __m256i, v1);
        _mm256_store_si256(dst.add(64) as *mut __m256i, v2);
        _mm256_store_si256(dst.add(96) as *mut __m256i, v3);
        dst = dst.add(128);
        src = src.add(128);
        len -= 128;
    }
    while len >= 32 {
        let v = _mm256_loadu_si256(src as *const __m256i);
        _mm256_store_si256(dst as *mut __m256i, v);
        dst = dst.add(32);
        src = src.add(32);
        len -= 32;
    }
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
}

/// 128-bit streaming (non-temporal) stores + trailing sfence — the MMX2
/// `movntq` idea: don't pollute the cache with data the producer will not
/// re-read. Only profitable for large copies; the put/get engine never picks
/// it for small messages.
///
/// # Safety
/// `memcpy` contract.
#[cfg(target_arch = "x86_64")]
pub unsafe fn copy_nontemporal(mut dst: *mut u8, mut src: *const u8, mut len: usize) {
    use std::arch::x86_64::*;
    while len > 0 && (dst as usize) & 15 != 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
    while len >= 64 {
        let v0 = _mm_loadu_si128(src as *const __m128i);
        let v1 = _mm_loadu_si128(src.add(16) as *const __m128i);
        let v2 = _mm_loadu_si128(src.add(32) as *const __m128i);
        let v3 = _mm_loadu_si128(src.add(48) as *const __m128i);
        _mm_stream_si128(dst as *mut __m128i, v0);
        _mm_stream_si128(dst.add(16) as *mut __m128i, v1);
        _mm_stream_si128(dst.add(32) as *mut __m128i, v2);
        _mm_stream_si128(dst.add(48) as *mut __m128i, v3);
        dst = dst.add(64);
        src = src.add(64);
        len -= 64;
    }
    while len >= 16 {
        let v = _mm_loadu_si128(src as *const __m128i);
        _mm_stream_si128(dst as *mut __m128i, v);
        dst = dst.add(16);
        src = src.add(16);
        len -= 16;
    }
    // Streaming stores are weakly ordered; fence before anyone reads them.
    _mm_sfence();
    while len > 0 {
        *dst = *src;
        dst = dst.add(1);
        src = src.add(1);
        len -= 1;
    }
}

/// Safe wrapper: copy between slices (must be same length, non-overlapping by
/// construction).
pub fn copy_slice_with(imp: CopyImpl, dst: &mut [u8], src: &[u8]) {
    assert_eq!(dst.len(), src.len(), "copy_slice_with length mismatch");
    unsafe { copy_bytes_with(imp, dst.as_mut_ptr(), src.as_ptr(), src.len()) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn check_impl(imp: CopyImpl) {
        let mut rng = Rng::new(0xDEAD ^ imp as u64);
        // Cover: 0, tiny, word-size, odd sizes, vector sizes, unaligned offsets.
        for &len in &[0usize, 1, 3, 7, 8, 15, 16, 17, 31, 32, 63, 64, 65, 100, 127, 128, 1000, 4096, 10_000] {
            for &(doff, soff) in &[(0usize, 0usize), (1, 0), (0, 1), (3, 5), (7, 9)] {
                let mut src = vec![0u8; len + soff];
                rng.fill_bytes(&mut src);
                let mut dst = vec![0xAAu8; len + doff + 1]; // +1 canary
                let canary_idx = len + doff;
                dst[canary_idx] = 0x5C;
                unsafe {
                    copy_bytes_with(imp, dst.as_mut_ptr().add(doff), src.as_ptr().add(soff), len);
                }
                assert_eq!(&dst[doff..doff + len], &src[soff..soff + len],
                    "{:?} len={} doff={} soff={}", imp, len, doff, soff);
                assert_eq!(dst[canary_idx], 0x5C, "{:?} overwrote past end (len={})", imp, len);
                // head must be untouched
                assert!(dst[..doff].iter().all(|&b| b == 0xAA), "{:?} underwrote (len={})", imp, len);
            }
        }
    }

    #[test]
    fn stock_correct() {
        check_impl(CopyImpl::Stock);
    }

    #[test]
    fn unrolled64_correct() {
        check_impl(CopyImpl::Unrolled64);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse2_correct() {
        check_impl(CopyImpl::Sse2);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_correct() {
        check_impl(CopyImpl::Avx2); // falls back to sse2 when unavailable
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn nontemporal_correct() {
        check_impl(CopyImpl::NonTemporal);
    }

    #[test]
    fn available_contains_baselines() {
        let avail = CopyImpl::available();
        assert!(avail.contains(&CopyImpl::Stock));
        assert!(avail.contains(&CopyImpl::Unrolled64));
    }

    #[test]
    fn parse_roundtrip() {
        for imp in CopyImpl::available() {
            assert_eq!(CopyImpl::parse(imp.name()), Some(imp));
        }
        assert_eq!(CopyImpl::parse("mmx"), Some(CopyImpl::Unrolled64));
        assert_eq!(CopyImpl::parse("bogus"), None);
    }

    #[test]
    fn global_impl_roundtrip() {
        let before = global_impl();
        set_global_impl(CopyImpl::Unrolled64);
        assert_eq!(global_impl(), CopyImpl::Unrolled64);
        set_global_impl(before);
    }

    #[test]
    fn copy_slice_matches() {
        let src: Vec<u8> = (0..=255u8).cycle().take(777).collect();
        for imp in CopyImpl::available() {
            let mut dst = vec![0u8; 777];
            copy_slice_with(imp, &mut dst, &src);
            assert_eq!(dst, src);
        }
    }
}
