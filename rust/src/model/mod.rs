//! The communication model (paper §1: "We present a model for its
//! communications").
//!
//! * [`costmodel`] — the postal/Hockney model `T(n) = α + n/β` (latency +
//!   size/bandwidth), fitted from measurements by least squares; used to
//!   summarise benches, predict crossovers, and check the paper's shape
//!   claims quantitatively.
//! * [`machines`] — the five evaluation machines of §5 as α/β profiles
//!   extracted from the paper's own tables, so the benches can print
//!   "paper-predicted" columns next to measured ones (we cannot fabricate a
//!   2006 Opteron, but we can replay its fitted model — the DESIGN.md §1
//!   substitution).
//! * [`piecewise`] — the per-size-regime extension: one α/β per
//!   L1/L2/LLC/DRAM bucket, because a single affine fit misprices exactly
//!   the regimes the paper's Figure 3 sweeps.
//! * [`topology`] — NUMA layout detection (`/sys/devices/system/node`, with
//!   a fixture-dir API and a flat fallback): the socket dimension the
//!   two-level collective schedules and the cross-socket α/β tier price by.

pub mod costmodel;
pub mod machines;
pub mod piecewise;
pub mod topology;

pub use costmodel::CostModel;
pub use machines::MachineProfile;
pub use piecewise::{PiecewiseModel, RangeModel};
pub use topology::{NumaNode, Topology, TopologySource};
