//! Integration tests for the posh-kv subsystem (docs/kv.md).
//!
//! * **LWW oracle** — under a concurrent multi-PE, multi-thread mixed
//!   read/write workload, the final value of every key must be the one
//!   written by the put that committed with the highest shard sequence
//!   number. Shard sequences are allocated under the shard writer lock, so
//!   they totally order all writes to a key; replaying every thread's
//!   `(key, seq, value)` log against the quiescent store checks the whole
//!   publication protocol (flag-after-data, quiet-before-version-bump).
//! * **Torn reads** — lock-free readers hammering one hot key while every
//!   PE overwrites it must only ever observe complete, well-formed values
//!   (value blobs are immutable; the value word swings atomically).
//! * **Routing** — `owner_of` must agree across PEs, since routing is what
//!   makes a key's home shard the same from everywhere.
//!
//! The same oracle runs in process mode in `tests/proc_mode.rs`.

use posh::kv::{KvConfig, KvStore};
use posh::pe::{PoshConfig, World};
use posh::util::prng::Rng;

fn cfg() -> KvConfig {
    KvConfig { shards_per_pe: 4, arena_bytes: 256 * 1024, max_key_len: 32, max_val_len: 64 }
}

/// 4 PEs × 4 threads, 80/20 put/get over a 64-key universe, then replay
/// the merged write logs: max-seq entry per key must equal what every PE
/// reads back from the quiescent store.
#[test]
fn lww_oracle_concurrent_mixed_workload() {
    const PES: usize = 4;
    const THREADS: usize = 4;
    const OPS: usize = 200;
    const KEYS: usize = 64;
    let w = World::threads(PES, PoshConfig::small()).unwrap();
    let per_pe = w.run_collect(move |ctx| {
        let kv = KvStore::create(&ctx, cfg()).unwrap();
        ctx.barrier_all();
        let mut logs: Vec<(usize, u64, Vec<u8>)> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..THREADS)
                .map(|t| {
                    let kv = &kv;
                    let ctx = &ctx;
                    s.spawn(move || {
                        let mut rng =
                            Rng::new(0x51ab_0000 + (ctx.my_pe() * THREADS + t) as u64);
                        let mut log = Vec::new();
                        for i in 0..OPS {
                            let k = rng.usize_in(0, KEYS);
                            let key = format!("k{k:03}");
                            if rng.bool(0.8) {
                                let val = format!("{key}#{}.{t}.{i}", ctx.my_pe());
                                let seq = kv.put(key.as_bytes(), val.as_bytes()).unwrap();
                                log.push((k, seq, val.into_bytes()));
                            } else if let Some(v) = kv.get(key.as_bytes()) {
                                assert!(
                                    v.starts_with(key.as_bytes()),
                                    "mid-run read of {key} returned a foreign blob: {v:?}"
                                );
                            }
                        }
                        log
                    })
                })
                .collect();
            for h in handles {
                logs.extend(h.join().unwrap());
            }
        });
        ctx.barrier_all();
        // Quiescent: every write is published, every lock released. Each PE
        // reads every key (local fast path for its own shards, one-sided
        // copies for the rest).
        let finals: Vec<Option<(u64, Vec<u8>)>> = (0..KEYS)
            .map(|k| kv.get_versioned(format!("k{k:03}").as_bytes()))
            .collect();
        ctx.barrier_all();
        kv.destroy().unwrap();
        (logs, finals)
    });

    let mut winner: Vec<Option<(u64, Vec<u8>)>> = vec![None; KEYS];
    for (logs, _) in &per_pe {
        for (k, seq, val) in logs {
            if winner[*k].as_ref().map_or(true, |(ws, _)| seq > ws) {
                winner[*k] = Some((*seq, val.clone()));
            }
        }
    }
    let total_writes: usize = per_pe.iter().map(|(l, _)| l.len()).sum();
    assert!(total_writes > 0, "workload generated no writes");
    for (pe, (_, finals)) in per_pe.iter().enumerate() {
        for k in 0..KEYS {
            assert_eq!(
                finals[k], winner[k],
                "PE {pe}: final read of key k{k:03} disagrees with the LWW oracle"
            );
        }
    }
}

/// One hot key, every PE overwriting it while dedicated reader threads spin
/// on `get`: every observed value must be complete and well-formed
/// (`hot#<pe>.<i>` with in-range fields) — a torn or stale-length read
/// would fail the parse.
#[test]
fn hot_key_never_tears() {
    const PES: usize = 4;
    const WRITES: usize = 300;
    const READS: usize = 600;
    let w = World::threads(PES, PoshConfig::small()).unwrap();
    let observed = w.run_collect(move |ctx| {
        let kv = KvStore::create(&ctx, cfg()).unwrap();
        ctx.barrier_all();
        let mut seen = 0usize;
        std::thread::scope(|s| {
            let writer = {
                let kv = &kv;
                let ctx = &ctx;
                s.spawn(move || {
                    for i in 0..WRITES {
                        let val = format!("hot#{}.{i}", ctx.my_pe());
                        kv.put(b"hot", val.as_bytes()).unwrap();
                    }
                })
            };
            let readers: Vec<_> = (0..2)
                .map(|_| {
                    let kv = &kv;
                    s.spawn(move || {
                        let mut seen = 0usize;
                        for _ in 0..READS {
                            if let Some(v) = kv.get(b"hot") {
                                let s = std::str::from_utf8(&v)
                                    .expect("torn read: value is not UTF-8");
                                let rest = s
                                    .strip_prefix("hot#")
                                    .unwrap_or_else(|| panic!("torn read: {s:?}"));
                                let (pe, i) = rest
                                    .split_once('.')
                                    .unwrap_or_else(|| panic!("torn read: {s:?}"));
                                let pe: usize = pe.parse().expect("torn read: bad pe");
                                let i: usize = i.parse().expect("torn read: bad index");
                                assert!(pe < PES && i < WRITES, "impossible value {s:?}");
                                seen += 1;
                            }
                        }
                        seen
                    })
                })
                .collect();
            writer.join().unwrap();
            for r in readers {
                seen += r.join().unwrap();
            }
        });
        ctx.barrier_all();
        kv.destroy().unwrap();
        seen
    });
    // The hot key exists after the first write; readers must have actually
    // observed values, or the test proved nothing.
    assert!(observed.iter().sum::<usize>() > PES * READS / 2, "readers mostly missed");
}

/// Key routing is pure in (hash, n_pes, shards): every PE must compute the
/// same owner for every key, and a modest key set must touch every PE.
#[test]
fn routing_agrees_across_pes() {
    const PES: usize = 3;
    let w = World::threads(PES, PoshConfig::small()).unwrap();
    let views = w.run_collect(|ctx| {
        let kv = KvStore::create(&ctx, cfg()).unwrap();
        let owners: Vec<(usize, usize)> =
            (0..200).map(|i| kv.owner_of(format!("key-{i}").as_bytes())).collect();
        ctx.barrier_all();
        kv.destroy().unwrap();
        owners
    });
    assert!(
        views.windows(2).all(|w| w[0] == w[1]),
        "PEs disagree on key ownership"
    );
    for pe in 0..PES {
        assert!(
            views[0].iter().any(|&(p, _)| p == pe),
            "200 keys never routed to PE {pe}"
        );
    }
}
