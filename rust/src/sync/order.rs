//! Memory-ordering primitives: `shmem_fence` and `shmem_quiet`, resolved
//! against an NBI **ordering domain**.
//!
//! On a shared-memory node every put is a synchronous memory copy performed
//! by the origin core, so by the time `put` returns the stores have been
//! *issued*. What fence/quiet must still guarantee is **ordering as observed
//! by other PEs**:
//!
//! * `fence` — puts to the *same* PE are delivered in order. x86-TSO already
//!   orders normal stores; a compiler fence prevents reordering by the
//!   optimiser, and a `Release` fence covers the weakly-ordered case.
//! * `quiet` — all outstanding puts (to *all* PEs) are complete and visible.
//!   Our non-temporal copy variant uses weakly-ordered streaming stores, so
//!   quiet must issue a full `SeqCst` fence (which lowers to `mfence` on
//!   x86, ordering streaming stores too — `sfence` semantics included).
//!
//! Since the context redesign, completion is resolved per ordering domain,
//! and the two domain kinds complete differently:
//!
//! * the **default domain** issues eagerly, so [`Ctx::quiet_nbi`] is the
//!   1.0 contract verbatim: the `SeqCst` completion fence, then retire the
//!   thread-local accounting;
//! * an **explicit context's** quiet ([`crate::ctx::CommCtx::quiet`]) is a
//!   **batched drain**: issue that context's deferred puts (and only
//!   those), then a `Release` fence — *no process-wide `SeqCst` fence*. The
//!   drain performs the copies synchronously, the copy engine orders its
//!   own streaming stores (`sfence` after every non-temporal loop), and the
//!   release fence publishes them; nothing about a sibling context's or the
//!   default domain's traffic is completed, fenced for, or retired.
//!
//! That asymmetry is the point: two independent NBI streams quiesce
//! independently instead of serialising through one global `mfence`, and a
//! context's pending ops are *provably* still pending after a sibling's
//! quiet (see the flag-after-data conformance tests).
//!
//! **Threads** (`SHMEM_THREAD_MULTIPLE`): an explicit context's quiet is
//! thread-safe without a queue-wide lock. The drain sweeps the batch's
//! per-thread shards one at a time (each shard swap is `Acquire`, pairing
//! with the issuing thread's `Release` push), performs the copies, and then
//! publishes everything with a **single** `Release` fence — one fence per
//! quiet, not one per shard. Concurrent quiets on one context are safe and
//! each retires exactly the operations it shipped; a quiet on one
//! [`crate::ctx::CommCtx`] still never completes, fences for, or retires a
//! sibling context's traffic, even when that sibling lives on another
//! thread (pinned by `tests/stress_threads.rs`).

use crate::p2p::nbi::NbiDomain;
use crate::pe::Ctx;
use std::sync::atomic::{fence, Ordering};

impl Ctx {
    /// `shmem_fence`: order puts to each PE.
    #[inline]
    pub fn fence(&self) {
        fence(Ordering::Release);
    }

    /// `shmem_quiet`: complete all outstanding puts; afterwards any PE that
    /// observes a subsequent store of ours also observes all prior puts.
    #[inline]
    pub fn quiet(&self) {
        fence(Ordering::SeqCst);
    }

    /// Quiet resolved against one ordering domain: complete that domain's
    /// (and only that domain's) operations, then retire its accounting.
    pub(crate) fn quiet_domain(&self, domain: &NbiDomain<'_>) {
        match domain {
            // Default domain ops were issued eagerly; the full SeqCst fence
            // is what "complete and visible" means for them (it also drains
            // weakly-ordered streaming stores).
            NbiDomain::Default => {
                self.quiet();
                self.nbi_retire(domain);
            }
            // Explicit domain: sharded drain + one release publication +
            // retire-what-shipped (a racing put_nbi from a sibling thread
            // is either taken by the drain's Acquire swap or stays queued
            // with its count intact). The drain copies synchronously, so no
            // global completion fence is needed — and deliberately none is
            // issued.
            NbiDomain::Explicit(batch) => self.nbi_quiet_batch(batch),
        }
    }

    /// Fence resolved against one ordering domain. Fences order, they do
    /// not complete — no accounting is retired, on any domain. An explicit
    /// domain's queue is drained first: its puts must be *delivered* before
    /// anything issued after the fence, which deferral would otherwise
    /// invert.
    pub(crate) fn fence_domain(&self, domain: &NbiDomain<'_>) {
        if let NbiDomain::Explicit(batch) = domain {
            self.nbi_drain(batch);
        }
        self.fence();
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};
    use std::sync::atomic::Ordering;

    #[test]
    fn fence_and_quiet_are_callable() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            ctx.fence();
            ctx.quiet();
            ctx.barrier_all();
        });
    }

    /// Message-passing litmus: PE0 puts data then (after fence) raises a
    /// flag on PE1; PE1 spins on the flag and must observe the data.
    #[test]
    fn fence_orders_put_then_flag() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let data = ctx.shmalloc_n::<u64>(64).unwrap();
            let flag = ctx.shmalloc_n::<u64>(1).unwrap();
            for round in 1..200u64 {
                if ctx.my_pe() == 0 {
                    let payload = vec![round; 64];
                    ctx.put(data, &payload, 1);
                    ctx.fence();
                    ctx.put_one(flag, round, 1);
                } else {
                    ctx.wait_until(flag, crate::sync::CmpOp::Ge, round);
                    let seen = unsafe { ctx.local(data).to_vec() };
                    assert!(seen.iter().all(|&x| x == round), "round {round}: {seen:?}");
                }
                ctx.barrier_all();
            }
        });
    }

    /// The barrier itself must include quiet semantics: data put before the
    /// barrier is visible to everyone after it, with no explicit flag.
    #[test]
    fn barrier_includes_quiet() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let slot = ctx.shmalloc_n::<u64>(3).unwrap();
            for round in 1..100u64 {
                // Everyone writes its own slot on every PE.
                for pe in 0..3 {
                    ctx.put(slot.slice(ctx.my_pe(), 1), &[round * 10 + ctx.my_pe() as u64], pe);
                }
                ctx.barrier_all();
                let local = unsafe { ctx.local(slot) };
                for (i, &v) in local.iter().enumerate() {
                    assert_eq!(v, round * 10 + i as u64, "round {round}");
                }
                ctx.barrier_all();
            }
            let _ = Ordering::SeqCst;
        });
    }

    /// Quiet on an explicit context must not retire the default domain's
    /// pending NBI operations, and vice versa — the ordering-domain
    /// isolation guarantee of the context redesign.
    #[test]
    fn quiet_does_not_cross_domains() {
        use crate::ctx::CtxOptions;
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let world = ctx.team_world();
            let c = world.create_ctx(CtxOptions::new());
            let buf = ctx.shmalloc_n::<u8>(4).unwrap();
            let peer = (ctx.my_pe() + 1) % 2;
            ctx.put_nbi(buf, &[9; 4], peer);
            c.put_nbi(buf, &[9; 4], peer);
            c.quiet();
            assert_eq!(c.pending_nbi(), 0);
            assert_eq!(ctx.pending_nbi(), 1, "ctx quiet must not retire the default domain");
            ctx.quiet_nbi();
            assert_eq!(ctx.pending_nbi(), 0);
            c.destroy();
            ctx.barrier_all();
        });
    }
}
