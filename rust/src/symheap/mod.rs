//! The symmetric heap (paper §3.1, §4.1.1).
//!
//! Every PE owns one segment laid out as:
//!
//! ```text
//! ┌────────────────┬──────────────────────┬───────────────────────────┐
//! │ HeapHeader     │ statics area         │ dynamic symmetric heap    │
//! │ (sync cells,   │ (pre-parser output,  │ (shmalloc / shmemalign /  │
//! │  §4.5.1 state) │  §4.2)               │  shfree / shrealloc)      │
//! └────────────────┴──────────────────────┴───────────────────────────┘
//! ```
//!
//! The paper's two foundational properties are implemented — and *checked* —
//! here:
//!
//! * **Fact 1** — with a deterministic allocator and symmetric call
//!   sequences, the offset of an object from its heap base is identical on
//!   every PE. Our allocator ([`alloc::FreeList`]) is deterministic by
//!   construction; safe-mode builds additionally maintain an allocation
//!   journal hash that PEs cross-check at barriers.
//! * **Corollary 1** — `addr_remote = heap_remote + (addr_local −
//!   heap_local)`: see [`handle::SymPtr`] (an offset, i.e. exactly the Boost
//!   `handle` trick of §4.1.1) and [`handle::translate`].

pub mod alloc;
pub mod handle;
pub mod heap;
pub mod layout;

pub use alloc::{AllocStats, SlabClassStats};
pub use handle::SymPtr;
pub use heap::SymHeap;
