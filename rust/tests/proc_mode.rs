//! End-to-end multi-PROCESS integration test (`harness = false`).
//!
//! The test binary plays both roles: invoked plain it acts as the launcher
//! (spawning itself N times under the RTE, §4.7); invoked with the `POSH_*`
//! environment it acts as a PE, attaches to the job's POSIX segments, and
//! runs a full SHMEM workout — put/get, atomics, locks, barrier, reduce,
//! broadcast, fcollect, team splits — over *real* `/dev/shm` segments
//! across processes.

use posh::collectives::ReduceOp;
use posh::pe::World;
use posh::rte::gateway::Gateway;
use posh::rte::launcher::{JobSpec, Launcher};
use posh::rte::monitor;

const N_PES: usize = 3;

fn pe_body() {
    let world = World::from_env().expect("attach from oshrun env");
    let ctx = world.my_ctx();
    let me = ctx.my_pe();
    let n = ctx.n_pes();
    assert_eq!(n, N_PES);

    // p2p ring.
    let cell = ctx.shmalloc_n::<i64>(1).unwrap();
    ctx.put_one(cell, me as i64 + 1, (me + 1) % n);
    ctx.barrier_all();
    let got = ctx.get_one(cell, me);
    assert_eq!(got, ((me + n - 1) % n) as i64 + 1, "ring value on PE {me}");

    // bulk put/get across processes.
    let buf = ctx.shmalloc_n::<u64>(4096).unwrap();
    if me == 0 {
        let data: Vec<u64> = (0..4096u64).map(|i| i * 3 + 1).collect();
        for pe in 1..n {
            ctx.put(buf, &data, pe);
        }
    }
    ctx.barrier_all();
    if me != 0 {
        let local = unsafe { ctx.local(buf) };
        assert!(local.iter().enumerate().all(|(i, &v)| v == i as u64 * 3 + 1));
    }

    // atomics across processes.
    let counter = ctx.shmalloc_n::<i64>(1).unwrap();
    for _ in 0..500 {
        ctx.atomic_add(counter, 1, 0);
    }
    ctx.barrier_all();
    if me == 0 {
        assert_eq!(ctx.get_one(counter, 0), (n as i64) * 500);
    }

    // lock across processes.
    let lock = ctx.shmalloc_n::<i64>(1).unwrap();
    let shared = ctx.shmalloc_n::<i64>(1).unwrap();
    for _ in 0..100 {
        ctx.with_lock(lock, || {
            let v = ctx.get_one(shared, 0);
            ctx.put_one(shared, v + 1, 0);
        });
    }
    ctx.barrier_all();
    if me == 0 {
        assert_eq!(ctx.get_one(shared, 0), (n as i64) * 100);
    }

    // collectives across processes (team surface over real shm headers).
    let team = ctx.team_world();
    let src = ctx.shmalloc_n::<i64>(32).unwrap();
    let dst = ctx.shmalloc_n::<i64>(32).unwrap();
    unsafe {
        for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
            *s = (me * 10 + j) as i64;
        }
    }
    ctx.barrier_all();
    ctx.reduce_to_all(dst, src, 32, ReduceOp::Sum, &team);
    for j in 0..32 {
        let want: i64 = (0..n).map(|pe| (pe * 10 + j) as i64).sum();
        assert_eq!(unsafe { ctx.local(dst)[j] }, want);
    }
    ctx.broadcast(dst, src, 32, 1, &team);
    if me != 1 {
        assert_eq!(unsafe { ctx.local(dst)[5] }, 15);
    }
    let gat = ctx.shmalloc_n::<i64>(32 * n).unwrap();
    ctx.fcollect(gat, src, 32, &team);
    for pe in 0..n {
        assert_eq!(unsafe { ctx.local(gat)[pe * 32 + 7] }, (pe * 10 + 7) as i64);
    }

    // team split across processes: slot claim + membership agreement run
    // over PE 0's real shared-memory header.
    let front = team.split_strided(0, 1, 2); // PEs {0, 1}
    if me < 2 {
        let t = front.as_ref().unwrap();
        assert_eq!(t.my_pe(), me);
        assert_eq!(t.n_pes(), 2);
        t.sync();
        ctx.reduce_to_all(dst, src, 32, ReduceOp::Max, t);
        assert_eq!(unsafe { ctx.local(dst)[0] }, 10); // max over PEs 0,1 of pe*10
    } else {
        assert!(front.is_none());
    }
    ctx.barrier_all();
    if let Some(t) = front {
        t.destroy();
    }

    // posh-kv across processes: deterministic cross-PE reads over the real
    // shm segments, then a concurrent same-key LWW race resolved through a
    // symmetric seq array (the process-mode half of tests/kv_store.rs).
    {
        use posh::kv::{KvConfig, KvStore};
        let kv = KvStore::create(
            &ctx,
            KvConfig {
                shards_per_pe: 4,
                arena_bytes: 128 * 1024,
                max_key_len: 32,
                max_val_len: 64,
            },
        )
        .unwrap();
        ctx.barrier_all();
        // Deterministic phase: each PE writes its own keys; every PE reads
        // every key (local fast path for its own, one-sided for the rest).
        for i in 0..16 {
            let key = format!("p{me}-{i}");
            let val = format!("{key}={}", i * 7 + me);
            kv.put(key.as_bytes(), val.as_bytes()).unwrap();
        }
        ctx.barrier_all();
        for pe in 0..n {
            for i in 0..16 {
                let key = format!("p{pe}-{i}");
                let want = format!("{key}={}", i * 7 + pe);
                assert_eq!(
                    kv.get(key.as_bytes()).as_deref(),
                    Some(want.as_bytes()),
                    "PE {me}: kv read of {key} across processes"
                );
            }
        }
        assert_eq!(kv.len(), (16 * n) as u64);

        // LWW race: two threads per PE hammer one hot key. Each PE
        // publishes its highest committed shard seq into a symmetric
        // array; the final stored seq must be the global max, and the PE
        // that committed it checks the stored value is its own.
        let (a, b) = std::thread::scope(|s| {
            let hammer = |t: usize| {
                let kv = &kv;
                move || {
                    let mut best = (0u64, String::new());
                    for i in 0..200 {
                        let val = format!("hot#{me}.{t}.{i}");
                        let seq = kv.put(b"hot", val.as_bytes()).unwrap();
                        if seq > best.0 {
                            best = (seq, val);
                        }
                    }
                    best
                }
            };
            let ha = s.spawn(hammer(0));
            let hb = s.spawn(hammer(1));
            (ha.join().unwrap(), hb.join().unwrap())
        });
        let my_best = if a.0 >= b.0 { a } else { b };
        let seqs = ctx.shmalloc_n::<i64>(n).unwrap();
        for pe in 0..n {
            ctx.put_one(seqs.at(me), my_best.0 as i64, pe);
        }
        ctx.barrier_all();
        let all: Vec<i64> = (0..n).map(|i| ctx.get_one(seqs.at(i), me)).collect();
        let gmax = *all.iter().max().unwrap() as u64;
        let (fseq, fval) = kv.get_versioned(b"hot").expect("hot key exists");
        assert_eq!(fseq, gmax, "PE {me}: final hot-key seq is not the global max");
        if my_best.0 == gmax {
            assert_eq!(fval, my_best.1.into_bytes(), "LWW winner value mismatch");
        }
        ctx.barrier_all();
        kv.destroy().unwrap();
    }

    ctx.barrier_all();
    println!("PE {me}: process-mode workout OK");
}

fn launcher_role() {
    let exe = std::env::current_exe().unwrap();
    let mut spec = JobSpec::new(N_PES, exe.to_str().unwrap());
    // libtest arg so a stray harness doesn't eat the run; ignored by us.
    spec.args = vec!["--posh-child".into()];
    spec.env = vec![("POSH_HEAP_SIZE".into(), "8M".into())];
    let launcher = Launcher::new(spec);
    let job = launcher.job_id;
    let mut pes = launcher.spawn_all().expect("spawn PEs");
    let mut gw = Gateway::new();
    for pe in pes.iter_mut() {
        gw.attach(pe.rank, false, pe.child.stdout.take().unwrap());
        gw.attach(pe.rank, true, pe.child.stderr.take().unwrap());
    }
    let io = std::thread::spawn(move || {
        let mut sink = Vec::new();
        gw.pump_to(&mut sink).unwrap()
    });
    let outcome = monitor::wait_all(pes);
    let lines = io.join().unwrap();
    monitor::cleanup_job_segments(job, N_PES);
    assert!(
        outcome.success(),
        "job failed: {:?}\nIO:\n{}",
        outcome.exit_codes,
        lines.iter().map(|l| l.render()).collect::<Vec<_>>().join("\n")
    );
    let ok_lines = lines
        .iter()
        .filter(|l| l.line.contains("process-mode workout OK"))
        .count();
    assert_eq!(ok_lines, N_PES, "every PE must report success");
    println!("proc_mode integration: {N_PES} processes OK");
}

fn main() {
    if World::env_present() {
        pe_body();
    } else {
        // True multi-process POSIX-shm mode needs a *writable* /dev/shm
        // (normal on Linux; absent or read-only in some hardened sandboxes).
        // Probe by actually creating a file there — existence alone is not
        // enough. Skip rather than fail — tracking: revisit if a shm-less
        // CI runner ever becomes the primary environment, e.g. by falling
        // back to a file-backed segment under $TMPDIR.
        let probe = format!("/dev/shm/posh.probe.{}", std::process::id());
        let shm_ok = match std::fs::File::create(&probe) {
            Ok(_) => {
                let _ = std::fs::remove_file(&probe);
                true
            }
            Err(_) => false,
        };
        if !shm_ok {
            println!("proc_mode: skipping ( /dev/shm not writable in this environment )");
            return;
        }
        launcher_role();
    }
}
