//! The PJRT runtime: load AOT-compiled HLO artifacts produced by the
//! build-time Python layer (`python/compile/aot.py`) and execute them from
//! Rust. Python never runs at job time — the `.hlo.txt` files and the
//! manifest are the entire interface between the layers.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that the image's xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

#[cfg(feature = "xla")]
pub mod artifact;
#[cfg(feature = "xla")]
pub mod client;
pub mod manifest;

#[cfg(feature = "xla")]
pub use artifact::Artifact;
pub use manifest::Manifest;

/// Stub of [`client`] for builds without the `xla` feature: the manifest
/// layer (pure Rust) stays available, the PJRT surface reports itself
/// unavailable instead of failing to link. Tracking: the `xla` feature gains
/// a real dependency in the PR that lands the AOT artifact pipeline.
#[cfg(not(feature = "xla"))]
pub mod client {
    use crate::Result;
    use anyhow::bail;

    /// Platform info string (for `oshrun info`). Always an error here: this
    /// build carries no PJRT client.
    pub fn platform_info() -> Result<String> {
        bail!("built without the `xla` feature: PJRT runtime not compiled in")
    }
}
