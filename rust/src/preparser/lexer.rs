//! Lexing support for the pre-parser: comment/string stripping and a
//! C-enough tokeniser. The §4.2 tool only needs to recognise file-scope
//! declarations, so the token set is small but the *stripping* must be
//! exact — a `static int x;` inside a comment, string, or function body must
//! not be lifted into the symmetric heap.

/// A lexical token.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword.
    Ident(String),
    /// Integer literal (decimal/hex/octal), value kept when it fits.
    Int(i64),
    /// Any other single significant character (`;`, `{`, `[`, `=`, `,`, …).
    Punct(char),
    /// String literal (contents dropped; only presence matters).
    Str,
    /// Character literal.
    Char,
}

/// Replace comments with spaces and blank out string/char literal contents,
/// preserving byte offsets and newlines (so line numbers survive).
pub fn strip_comments_and_strings(src: &str) -> String {
    let b = src.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                while i < b.len() && b[i] != b'\n' {
                    out.push(b' ');
                    i += 1;
                }
            }
            b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                out.push(b' ');
                out.push(b' ');
                i += 2;
                while i + 1 < b.len() && !(b[i] == b'*' && b[i + 1] == b'/') {
                    out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                    i += 1;
                }
                if i + 1 < b.len() {
                    out.push(b' ');
                    out.push(b' ');
                    i += 2;
                } else {
                    i = b.len();
                }
            }
            q @ (b'"' | b'\'') => {
                out.push(q);
                i += 1;
                while i < b.len() && b[i] != q {
                    if b[i] == b'\\' && i + 1 < b.len() {
                        out.push(b' ');
                        out.push(b' ');
                        i += 2;
                    } else {
                        out.push(if b[i] == b'\n' { b'\n' } else { b' ' });
                        i += 1;
                    }
                }
                if i < b.len() {
                    out.push(q);
                    i += 1;
                }
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// Tokenise pre-stripped source. Preprocessor lines (`#…`) are skipped.
pub fn tokenize(stripped: &str) -> Vec<Tok> {
    let b = stripped.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == b'#' {
            // Skip the preprocessor line including continuations.
            while i < b.len() && b[i] != b'\n' {
                if b[i] == b'\\' && i + 1 < b.len() && b[i + 1] == b'\n' {
                    i += 1;
                }
                i += 1;
            }
        } else if c.is_ascii_whitespace() {
            i += 1;
        } else if c.is_ascii_alphabetic() || c == b'_' {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            toks.push(Tok::Ident(stripped[start..i].to_string()));
        } else if c.is_ascii_digit() {
            let start = i;
            while i < b.len()
                && (b[i].is_ascii_alphanumeric() || b[i] == b'.' || b[i] == b'x' || b[i] == b'X')
            {
                i += 1;
            }
            let text = &stripped[start..i];
            let v = if let Some(hex) = text.strip_prefix("0x").or(text.strip_prefix("0X")) {
                i64::from_str_radix(hex.trim_end_matches(['u', 'U', 'l', 'L']), 16).unwrap_or(0)
            } else {
                text.trim_end_matches(['u', 'U', 'l', 'L'])
                    .parse()
                    .unwrap_or(0)
            };
            toks.push(Tok::Int(v));
        } else if c == b'"' {
            toks.push(Tok::Str);
            i += 1;
            while i < b.len() && b[i] != b'"' {
                i += 1;
            }
            i += 1;
        } else if c == b'\'' {
            toks.push(Tok::Char);
            i += 1;
            while i < b.len() && b[i] != b'\'' {
                i += 1;
            }
            i += 1;
        } else {
            toks.push(Tok::Punct(c as char));
            i += 1;
        }
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_line_comments() {
        let s = strip_comments_and_strings("int x; // static int y;\nint z;");
        assert!(s.contains("int x;"));
        assert!(!s.contains("static"));
        assert!(s.contains("int z;"));
    }

    #[test]
    fn strips_block_comments_preserving_lines() {
        let src = "a /* line1\nline2 */ b";
        let s = strip_comments_and_strings(src);
        assert_eq!(s.matches('\n').count(), 1);
        assert!(s.contains('a') && s.contains('b'));
        assert!(!s.contains("line1"));
    }

    #[test]
    fn blanks_string_contents() {
        let s = strip_comments_and_strings(r#"char* s = "static int x;";"#);
        assert!(!s.contains("static int x"));
        assert!(s.starts_with("char* s = \""));
    }

    #[test]
    fn handles_escaped_quotes() {
        let s = strip_comments_and_strings(r#"char* s = "a\"b"; int y;"#);
        assert!(s.contains("int y;"));
    }

    #[test]
    fn tokenizes_declaration() {
        let toks = tokenize("static int foo[10];");
        assert_eq!(
            toks,
            vec![
                Tok::Ident("static".into()),
                Tok::Ident("int".into()),
                Tok::Ident("foo".into()),
                Tok::Punct('['),
                Tok::Int(10),
                Tok::Punct(']'),
                Tok::Punct(';'),
            ]
        );
    }

    #[test]
    fn skips_preprocessor() {
        let toks = tokenize("#include <stdio.h>\nint x;");
        assert_eq!(toks[0], Tok::Ident("int".into()));
    }

    #[test]
    fn hex_and_suffixed_ints() {
        assert_eq!(tokenize("0x10"), vec![Tok::Int(16)]);
        assert_eq!(tokenize("10UL"), vec![Tok::Int(10)]);
    }
}
