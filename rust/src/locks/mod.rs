//! Distributed locks (§4.6).
//!
//! Two facilities, mirroring the paper:
//!
//! * The OpenSHMEM lock API (`shmem_set_lock` / `shmem_test_lock` /
//!   `shmem_clear_lock`) over a symmetric `i64`. Implemented as a **ticket
//!   lock** whose state lives in the lock variable's copy **on PE 0** (the
//!   customary convention — one well-known home makes the protocol a pair of
//!   remote atomics). Word layout: high 32 bits = next ticket, low 32 bits =
//!   now serving. FIFO-fair, like Boost's queued named mutexes.
//! * [`named`] — POSH's named-mutex registry: "each process uses the same
//!   given name for a given chunk of data on a given symmetric heap".

pub mod named;

use crate::pe::Ctx;
use crate::symheap::SymPtr;

/// Home PE of the lock state (the spec only requires *some* deterministic
/// convention; every mainstream implementation uses PE 0).
const HOME: usize = 0;

const TICKET_ONE: u64 = 1 << 32;
const SERVING_MASK: u64 = 0xFFFF_FFFF;

fn as_word(lock: SymPtr<i64>) -> SymPtr<u64> {
    SymPtr::from_raw(lock.offset(), lock.len())
}

impl Ctx {
    /// `shmem_set_lock`: acquire, blocking. FIFO order among contenders.
    pub fn set_lock(&self, lock: SymPtr<i64>) {
        let w = as_word(lock);
        let prev = self.atomic_fadd(w, TICKET_ONE, HOME);
        let my_ticket = prev >> 32;
        if (prev & SERVING_MASK) == my_ticket {
            return; // uncontended fast path
        }
        self.spin_wait(|| (self.get_one(w, HOME) & SERVING_MASK) == my_ticket);
        // Acquire fence: everything the previous holder published before
        // clear_lock is visible to us now.
        std::sync::atomic::fence(std::sync::atomic::Ordering::Acquire);
    }

    /// `shmem_test_lock`: try to acquire; `true` if the lock was obtained.
    pub fn test_lock(&self, lock: SymPtr<i64>) -> bool {
        let w = as_word(lock);
        let cur = self.get_one(w, HOME);
        let ticket = cur >> 32;
        let serving = cur & SERVING_MASK;
        if ticket != serving {
            return false; // someone holds or waits for it
        }
        // Claim the next ticket only if nobody raced us.
        let prev = self.atomic_cswap(w, cur, cur + TICKET_ONE, HOME);
        if prev == cur {
            std::sync::atomic::fence(std::sync::atomic::Ordering::Acquire);
            true
        } else {
            false
        }
    }

    /// `shmem_clear_lock`: release. Must be called by the holder.
    pub fn clear_lock(&self, lock: SymPtr<i64>) {
        // Publish the critical section before handing the lock over.
        self.quiet();
        let w = as_word(lock);
        self.atomic_add(w, 1, HOME); // bump "now serving"
    }

    /// Run `f` under the lock (RAII convenience; not part of the C API).
    pub fn with_lock<R>(&self, lock: SymPtr<i64>, f: impl FnOnce() -> R) -> R {
        self.set_lock(lock);
        let r = f();
        self.clear_lock(lock);
        r
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn mutual_exclusion_increments() {
        let n = 4;
        let iters = 300u64;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let lock = ctx.shmalloc_n::<i64>(1).unwrap();
            let shared = ctx.shmalloc_n::<u64>(1).unwrap();
            for _ in 0..iters {
                ctx.with_lock(lock, || {
                    // Non-atomic read-modify-write: only safe under the lock.
                    let v = ctx.get_one(shared, 0);
                    ctx.put_one(shared, v + 1, 0);
                });
            }
            ctx.barrier_all();
            if ctx.my_pe() == 0 {
                assert_eq!(ctx.get_one(shared, 0), n as u64 * iters);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn test_lock_nonblocking() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let lock = ctx.shmalloc_n::<i64>(1).unwrap();
            let flag = ctx.shmalloc_n::<u64>(1).unwrap();
            if ctx.my_pe() == 0 {
                ctx.set_lock(lock);
                ctx.put_one(flag, 1, 1); // tell PE1 the lock is held
                ctx.wait_until(flag, crate::sync::CmpOp::Eq, 2); // PE1 probed
                ctx.clear_lock(lock);
            } else {
                ctx.wait_until(flag, crate::sync::CmpOp::Eq, 1);
                assert!(!ctx.test_lock(lock), "lock is held by PE 0");
                ctx.put_one(flag, 2, 0); // signal the waiter (PE 0)
                // Eventually acquirable once PE 0 releases.
                ctx.spin_wait(|| ctx.test_lock(lock));
                ctx.clear_lock(lock);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn lock_is_fifo_fair() {
        // With a ticket lock, grants follow ticket order; verify no PE is
        // starved by recording the grant sequence length per PE.
        let n = 3;
        let per = 100;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        let counts = w.run_collect(|ctx| {
            let lock = ctx.shmalloc_n::<i64>(1).unwrap();
            let mut acquired = 0usize;
            for _ in 0..per {
                ctx.with_lock(lock, || {
                    acquired += 1;
                });
            }
            ctx.barrier_all();
            acquired
        });
        assert!(counts.iter().all(|&c| c == per));
    }
}
