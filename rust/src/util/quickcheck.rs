//! A minimal shrinking property-testing harness (the vendored registry on
//! this image carries no `proptest`/`quickcheck`).
//!
//! Model: a property is a function from a deterministically-generated input
//! to `Result<(), String>`. The harness runs `cases` random inputs; on the
//! first failure it greedily shrinks the input via the `Shrink`
//! implementation and reports the minimal counterexample together with the
//! seed needed to replay it.
//!
//! ```no_run
//! use posh::util::quickcheck::{forall, Gen};
//! forall("sort is idempotent", 200, |g| {
//!     let mut v = g.vec_i64(0..64, -100..100);
//!     v.sort();
//!     let w = { let mut w = v.clone(); w.sort(); w };
//!     if v == w { Ok(()) } else { Err(format!("{v:?} != {w:?}")) }
//! });
//! ```

use crate::util::prng::Rng;
use std::ops::Range;

/// Input generator handed to properties; wraps a deterministic RNG and
/// records sizes so failures replay exactly.
pub struct Gen {
    rng: Rng,
    /// The seed of this case (for the failure report).
    pub seed: u64,
}

impl Gen {
    /// Build a generator for one case.
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::new(seed), seed }
    }

    /// Uniform `usize` in `range`.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        self.rng.usize_in(range.start, range.end)
    }

    /// Uniform `i64` in `range`.
    pub fn i64_in(&mut self, range: Range<i64>) -> i64 {
        range.start + self.rng.next_below((range.end - range.start) as u64) as i64
    }

    /// Uniform `f64` in `[0,1)`.
    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// A vector of `i64` with random length in `len` and elements in `elems`.
    pub fn vec_i64(&mut self, len: Range<usize>, elems: Range<i64>) -> Vec<i64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.i64_in(elems.clone())).collect()
    }

    /// A vector of `usize`.
    pub fn vec_usize(&mut self, len: Range<usize>, elems: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(elems.clone())).collect()
    }

    /// Random bytes of length in `len`.
    pub fn bytes(&mut self, len: Range<usize>) -> Vec<u8> {
        let n = self.usize_in(len);
        let mut v = vec![0u8; n];
        self.rng.fill_bytes(&mut v);
        v
    }

    /// Pick one of the given items (cloned).
    pub fn pick<T: Clone>(&mut self, xs: &[T]) -> T {
        self.rng.choose(xs).clone()
    }

    /// Access the raw RNG (e.g. to derive nested structures).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` on `cases` deterministic random inputs; panic with the seed and
/// message of the first failure. Base seed is fixed so CI is reproducible;
/// override with env `POSH_QC_SEED` to explore a different region, or
/// `POSH_QC_CASES` to scale the number of cases.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base = std::env::var("POSH_QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    let cases = std::env::var("POSH_QC_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(cases);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed on case {i} (replay: POSH_QC_SEED={seed} \
                 POSH_QC_CASES=1):\n  {msg}"
            );
        }
    }
}

/// Values that know how to propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate simpler values, in decreasing order of aggressiveness.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            if *self < 0 {
                out.push(-self);
            }
            out.push(self - self.signum());
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
            let mut v = self.clone();
            v.remove(0);
            out.push(v);
        }
        // shrink one element
        for (i, x) in self.iter().enumerate().take(8) {
            for sx in x.shrink() {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

/// Property over an explicit `Shrink` input: generate with `gen_fn`, test
/// with `prop`, shrink the first counterexample to a local minimum.
pub fn forall_shrink<T, G, F>(name: &str, cases: u64, mut gen_fn: G, mut prop: F)
where
    T: Shrink + std::fmt::Debug,
    G: FnMut(&mut Gen) -> T,
    F: FnMut(&T) -> Result<(), String>,
{
    let base = std::env::var("POSH_QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEEu64);
    for i in 0..cases {
        let seed = base.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut g = Gen::new(seed);
        let input = gen_fn(&mut g);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink loop.
            let mut cur = input;
            let mut msg = first_msg;
            let mut budget = 1000usize;
            'outer: while budget > 0 {
                for cand in cur.shrink() {
                    budget -= 1;
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break; // local minimum
            }
            panic!(
                "property '{name}' failed (case {i}, seed {seed});\n  minimal \
                 counterexample: {cur:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("reverse twice", 100, |g| {
            let v = g.vec_i64(0..32, -10..10);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w { Ok(()) } else { Err("reverse^2 != id".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        forall("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property: no vector contains an element >= 50. The shrinker should
        // reduce a random failing vector to something tiny.
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                "no big elems",
                50,
                |g| g.vec_i64(0..64, 0..100),
                |v: &Vec<i64>| {
                    if v.iter().all(|&x| x < 50) {
                        Ok(())
                    } else {
                        Err("has big elem".into())
                    }
                },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("minimal counterexample"), "{msg}");
        // The minimal failing vector should be short (shrinker did work).
        // Extract the debug-printed vec and check its length crudely.
        let inner = msg.split("counterexample: ").nth(1).unwrap();
        let vec_str = inner.split('\n').next().unwrap();
        assert!(vec_str.matches(',').count() <= 2, "not shrunk: {vec_str}");
    }

    #[test]
    fn i64_shrink_moves_toward_zero() {
        let c = 100i64.shrink();
        assert!(c.contains(&0));
        assert!(c.contains(&50));
    }
}
