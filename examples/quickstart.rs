//! Quickstart: the OpenSHMEM "hello world" — symmetric allocation, put/get,
//! barrier, atomics, a reduction, a broadcast, a lock, and the 1.4 team +
//! communication-context surface.
//!
//! Run in-process (thread mode):
//! ```text
//! cargo run --release --example quickstart
//! ```
//! Or as real processes under the RTE (§4.7):
//! ```text
//! cargo run --release --bin oshrun -- -np 4 target/release/examples/quickstart
//! ```

use posh::collectives::ReduceOp;
use posh::pe::{Ctx, PoshConfig, World};

fn pe_body(ctx: Ctx) {
    let me = ctx.my_pe();
    let n = ctx.n_pes();
    println!("PE {me}/{n} up (mode {:?})", ctx.mode());

    // --- Symmetric allocation (same handle on every PE — Fact 1).
    let ring = ctx.shmalloc_n::<i64>(1).unwrap();
    let table = ctx.shmalloc_n::<i64>(n).unwrap();

    // --- One-sided put around a ring.
    let next = (me + 1) % n;
    ctx.put_one(ring, me as i64 * 100, next);
    ctx.barrier_all();
    let prev = (me + n - 1) % n;
    let got = ctx.get_one(ring, me); // local read via the get path
    assert_eq!(got, prev as i64 * 100);
    println!("PE {me}: ring value {got} (from PE {prev})");

    // --- Everyone writes its slot in everyone's table.
    for pe in 0..n {
        ctx.put_one(table.at(me), me as i64 + 1, pe);
    }
    ctx.barrier_all();

    // --- Reduction: sum of 1..=n, identical on all PEs.
    let src = ctx.shmalloc_n::<i64>(1).unwrap();
    let dst = ctx.shmalloc_n::<i64>(1).unwrap();
    unsafe { ctx.local_mut(src)[0] = me as i64 + 1 };
    ctx.barrier_all();
    let world = ctx.team_world();
    ctx.reduce_to_all(dst, src, 1, ReduceOp::Sum, &world);
    let sum = unsafe { ctx.local(dst)[0] };
    assert_eq!(sum, (n as i64 * (n as i64 + 1)) / 2);
    if me == 0 {
        println!("sum over PEs: {sum}");
    }

    // --- Broadcast from the last PE.
    let msg = ctx.shmalloc_n::<i64>(4).unwrap();
    let out = ctx.shmalloc_n::<i64>(4).unwrap();
    if me == n - 1 {
        unsafe { ctx.local_mut(msg).copy_from_slice(&[7, 7, 7, 7]) };
    }
    ctx.barrier_all();
    ctx.broadcast(out, msg, 4, n - 1, &world);
    if me != n - 1 {
        assert_eq!(unsafe { ctx.local(out) }, &[7i64; 4]);
    }

    // --- Teams & communication contexts (OpenSHMEM 1.4): split off the
    // lower half of the world, ring-put inside it on an explicit context,
    // and quiesce that context independently of the default domain.
    let probe = ctx.shmalloc_n::<i64>(1).unwrap(); // collective: all PEs
    let half = world.split_strided(0, 1, (n + 1) / 2);
    if let Some(half) = &half {
        let cc = half.create_ctx(posh::ctx::CtxOptions::new());
        let next = (half.my_pe() + 1) % half.n_pes(); // team-relative rank
        cc.put_nbi(probe, &[half.my_pe() as i64], next);
        cc.quiet(); // retires cc's NBI ops only
        half.sync();
        let prev = (half.my_pe() + half.n_pes() - 1) % half.n_pes();
        assert_eq!(unsafe { ctx.local(probe)[0] }, prev as i64);
        println!("PE {me}: team rank {}/{} ring OK", half.my_pe(), half.n_pes());
        cc.destroy();
    }
    ctx.barrier_all();
    if let Some(half) = half {
        half.destroy();
    }
    ctx.barrier_all();

    // --- Atomic counter + lock-protected critical section.
    let counter = ctx.shmalloc_n::<i64>(1).unwrap();
    let lock = ctx.shmalloc_n::<i64>(1).unwrap();
    for _ in 0..100 {
        ctx.atomic_add(counter, 1, 0);
    }
    ctx.with_lock(lock, || {
        // Lock-serialised read-modify-write.
        let v = ctx.get_one(counter, 0);
        ctx.put_one(counter, v, 0);
    });
    ctx.barrier_all();
    if me == 0 {
        let total = ctx.get_one(counter, 0);
        assert_eq!(total, n as i64 * 100);
        println!("atomic counter: {total}");
        println!("quickstart OK");
    }
    ctx.barrier_all();
}

fn main() -> posh::Result<()> {
    if World::env_present() {
        // Launched by `oshrun`: process mode, one PE per process.
        let world = World::from_env()?;
        pe_body(world.my_ctx());
    } else {
        // Standalone: thread mode, 4 PEs.
        let world = World::threads(4, PoshConfig::default())?;
        world.run(pe_body);
    }
    Ok(())
}
