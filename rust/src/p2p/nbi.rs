//! Non-blocking-implicit transfers: `shmem_put_nbi` / `shmem_get_nbi`,
//! accounted per **ordering domain**.
//!
//! **Extension** (OpenSHMEM 1.3; not in the 1.0 spec the paper implements —
//! listed under "future works" in its conclusion). On a shared-memory node
//! the origin core performs the copy either way, so the useful freedom NBI
//! grants an implementation is *deferral*: batch small transfers and issue
//! them at the next `quiet`, amortising per-call overhead.
//!
//! POSH-RS issues NBI transfers eagerly (measurements in EXPERIMENTS.md
//! show deferral buys nothing when the transport is a local memcpy — there
//! is no NIC to overlap with) but keeps the full accounting contract, now
//! split by domain ([`NbiDomain`]):
//!
//! * the **default domain** is a thread-local counter — the 1.0 behaviour:
//!   [`Ctx::put_nbi`] issues into it, [`Ctx::quiet_nbi`] retires it;
//! * each **explicit domain** is the private counter of one
//!   [`crate::ctx::CommCtx`]; `ctx.quiet()` retires that counter and *only*
//!   that counter.
//!
//! `pending_nbi()` counts issued-but-unretired operations per domain, so
//! programs written against the 1.3/1.4 semantics run unmodified and the
//! completion discipline — including its per-context scoping — is testable.

use crate::pe::Ctx;
use crate::symheap::SymPtr;
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

thread_local! {
    /// Issued-but-unretired NBI operations of the calling PE thread's
    /// default domain.
    static PENDING: Cell<u64> = const { Cell::new(0) };
}

/// An NBI ordering domain: where issued-but-unretired operations are
/// counted, and which counter a quiet retires.
pub(crate) enum NbiDomain<'a> {
    /// The thread-local default context (OpenSHMEM 1.0 behaviour).
    Default,
    /// An explicit context's private counter.
    Explicit(&'a AtomicU64),
}

impl Ctx {
    /// Record one issued NBI operation in `domain`.
    pub(crate) fn nbi_issued(&self, domain: &NbiDomain<'_>) {
        match domain {
            NbiDomain::Default => PENDING.with(|p| p.set(p.get() + 1)),
            NbiDomain::Explicit(cell) => {
                cell.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Retire every pending NBI operation of `domain`.
    pub(crate) fn nbi_retire(&self, domain: &NbiDomain<'_>) {
        match domain {
            NbiDomain::Default => PENDING.with(|p| p.set(0)),
            NbiDomain::Explicit(cell) => cell.store(0, Ordering::Relaxed),
        }
    }

    /// `put_nbi` into an explicit domain (the [`crate::ctx::CommCtx`] path).
    pub(crate) fn put_nbi_domain<T: Copy>(
        &self,
        domain: &NbiDomain<'_>,
        dest: SymPtr<T>,
        src: &[T],
        pe: usize,
    ) {
        self.put(dest, src, pe);
        self.nbi_issued(domain);
    }

    /// `get_nbi` into an explicit domain (the [`crate::ctx::CommCtx`] path).
    pub(crate) fn get_nbi_domain<T: Copy>(
        &self,
        domain: &NbiDomain<'_>,
        dest: &mut [T],
        src: SymPtr<T>,
        pe: usize,
    ) {
        self.get(dest, src, pe);
        self.nbi_issued(domain);
    }

    /// `shmem_put_nbi` (default context): start a put; completion only at
    /// the next [`Ctx::quiet_nbi`] (or barrier, which includes a quiet).
    pub fn put_nbi<T: Copy>(&self, dest: SymPtr<T>, src: &[T], pe: usize) {
        self.put_nbi_domain(&NbiDomain::Default, dest, src, pe);
    }

    /// `shmem_get_nbi` (default context): start a get; the value is only
    /// guaranteed after the next [`Ctx::quiet_nbi`].
    pub fn get_nbi<T: Copy>(&self, dest: &mut [T], src: SymPtr<T>, pe: usize) {
        self.get_nbi_domain(&NbiDomain::Default, dest, src, pe);
    }

    /// Number of NBI operations issued by this PE on the **default**
    /// context and not yet retired by a [`Ctx::quiet_nbi`]. Explicit
    /// contexts keep their own count ([`crate::ctx::CommCtx::pending_nbi`]).
    pub fn pending_nbi(&self) -> u64 {
        PENDING.with(|p| p.get())
    }

    /// `shmem_quiet` variant that also retires the default context's NBI
    /// accounting. (The plain `quiet` in `sync::order` is the fence; this
    /// is the bookkeeping face used by programs that check `pending_nbi`.)
    pub fn quiet_nbi(&self) {
        self.quiet_domain(&NbiDomain::Default);
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn nbi_accounting() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let buf = ctx.shmalloc_n::<u32>(8).unwrap();
            assert_eq!(ctx.pending_nbi(), 0);
            ctx.put_nbi(buf, &[1; 8], (ctx.my_pe() + 1) % 2);
            let mut tmp = [0u32; 8];
            ctx.get_nbi(&mut tmp, buf, ctx.my_pe());
            assert_eq!(ctx.pending_nbi(), 2);
            ctx.quiet_nbi();
            assert_eq!(ctx.pending_nbi(), 0);
            ctx.barrier_all();
            // Data actually arrived.
            assert_eq!(unsafe { ctx.local(buf) }, &[1u32; 8][..]);
            ctx.barrier_all();
        });
    }
}
