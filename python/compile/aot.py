"""AOT pipeline: lower the Layer-2 JAX functions (with their Layer-1 Pallas
kernels inlined) to **HLO text** artifacts + a flat-JSON manifest + the
initial parameter image.

Run once by `make artifacts`; the Rust coordinator is self-contained after
that. HLO *text* — not `.serialize()` — is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects,
while the text parser reassigns ids (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out ../artifacts [--vocab 256 ...]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import copy as copy_k
from .kernels import reduce as reduce_k


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_write(fn, args, path: str) -> int:
    """Lower `fn(*args)` and write the HLO text; returns the byte count."""
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def build(out_dir: str, cfg: model.ModelConfig, seed: int = 0,
          n_shards: int = 8, copy_mb: int = 16) -> dict:
    """Produce every artifact; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "n_layers": cfg.n_layers,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "lr": cfg.lr,
        "param_count": model.param_count(cfg),
        "reduce_shards": n_shards,
    }
    p = manifest["param_count"]

    # --- train_step(params, tokens) -> (loss, grads)
    params_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    n = lower_and_write(
        functools.partial(model.train_step, cfg),
        (params_spec, tokens_spec),
        os.path.join(out_dir, "train_step.hlo.txt"),
    )
    manifest["train_step"] = "train_step.hlo.txt"
    print(f"train_step.hlo.txt        {n/1e6:.2f} MB  (P={p})")

    # --- sgd_update(params, grad_sum, scale) -> (new_params,)
    scale_spec = jax.ShapeDtypeStruct((), jnp.float32)
    n = lower_and_write(
        model.sgd_update,
        (params_spec, params_spec, scale_spec),
        os.path.join(out_dir, "sgd_update.hlo.txt"),
    )
    manifest["sgd_update"] = "sgd_update.hlo.txt"
    print(f"sgd_update.hlo.txt        {n/1e6:.2f} MB")

    # --- grad_reduce(parts) -> (sum,) — the Pallas combine kernel.
    chunk = 1 << 14
    parts_spec = jax.ShapeDtypeStruct((n_shards, chunk), jnp.float32)
    n = lower_and_write(
        lambda parts: (reduce_k.sum_reduce(parts),),
        (parts_spec,),
        os.path.join(out_dir, "grad_reduce.hlo.txt"),
    )
    manifest["grad_reduce"] = "grad_reduce.hlo.txt"
    manifest["reduce_chunk"] = chunk
    print(f"grad_reduce.hlo.txt       {n/1e6:.2f} MB")

    # --- copy-kernel variants (the TPU Table-1 analog, DESIGN.md §6).
    side = 1024
    rows = max(1, (copy_mb << 20) // (4 * side))
    x_spec = jax.ShapeDtypeStruct((rows, side), jnp.float32)
    for name, (bm, bn) in copy_k.VARIANTS.items():
        n = lower_and_write(
            lambda x, bm=bm, bn=bn: (copy_k.copy_tiled(x, bm=bm, bn=bn),),
            (x_spec,),
            os.path.join(out_dir, f"{name}.hlo.txt"),
        )
        manifest[name] = f"{name}.hlo.txt"
        manifest[f"{name}_vmem"] = copy_k.vmem_footprint_bytes(bm, bn)
    manifest["copy_rows"] = rows
    manifest["copy_cols"] = side
    print(f"copy variants             {len(copy_k.VARIANTS)} × ({rows}x{side})")

    # --- initial parameters (raw little-endian f32 image).
    import numpy as np

    params = model.init_params(cfg, jax.random.PRNGKey(seed))
    raw = np.asarray(params, dtype="<f4").tobytes()
    with open(os.path.join(out_dir, "params_init.f32"), "wb") as f:
        f.write(raw)
    manifest["params_init"] = "params_init.f32"
    print(f"params_init.f32           {len(raw)/1e6:.2f} MB")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json             {len(manifest)} fields")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--n-heads", type=int, default=4)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args()
    cfg = model.ModelConfig(
        vocab=a.vocab, d_model=a.d_model, n_heads=a.n_heads, d_ff=a.d_ff,
        n_layers=a.n_layers, seq=a.seq, batch=a.batch, lr=a.lr,
    )
    build(a.out, cfg, seed=a.seed)


if __name__ == "__main__":
    main()
