//! Shared-memory segments (paper §4.1).
//!
//! POSH builds each PE's symmetric heap on a Boost.Interprocess
//! `managed_shared_memory`, which is itself a wrapper over POSIX `shm`.
//! Here the same substrate is written directly over `libc`:
//!
//! * [`posix::PosixShmSegment`] — a `/dev/shm` object created with
//!   `shm_open` + `ftruncate` + `mmap(MAP_SHARED)`. This is what the
//!   multi-process mode (`oshrun`) uses; any process that knows the segment
//!   *name* can map it, which is exactly the paper's "contact information"
//!   mechanism (§4.7: names are `constant basis + rank`).
//! * [`inproc::InProcSegment`] — an anonymous private mapping. Thread-mode
//!   worlds use it; unit tests and benches run on it without touching
//!   `/dev/shm`.
//! * [`memfd::MemfdSegment`] — an anonymous tmpfs file reached through an
//!   inherited fd instead of a name. The automatic fallback when `/dev/shm`
//!   is unwritable (hardened sandboxes, some CI runners); the `oshrun`
//!   launcher brokers the fds (see [`memfd::SEGFDS_ENV`]).
//!
//! All implement [`Segment`]; everything above this module (allocator,
//! p2p engine, collectives) is generic over it. [`ShmEngine::resolve`]
//! picks between the two process-mode engines.

pub mod inproc;
pub mod memfd;
pub mod naming;
pub mod posix;

use crate::Result;
use anyhow::bail;

/// How a segment's backing pages relate to huge pages. The symmetric heap
/// is the hottest mapping in the job — every put/get walks it — so TLB
/// reach matters for the DRAM-regime copies the streaming engines target.
/// Segments *attempt* huge-page backing and report what they got; nothing
/// fails if the machine has no huge pages (`oshrun info` surfaces the
/// outcome).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HugePageStatus {
    /// The mapping was created with `MAP_HUGETLB` — pages come from the
    /// pre-reserved hugetlb pool and are guaranteed huge.
    Explicit,
    /// The mapping is ordinary but `madvise(MADV_HUGEPAGE)` succeeded —
    /// the kernel's THP machinery *may* back it with huge pages.
    Transparent,
    /// Ordinary pages only (small segment, or the kernel refused both
    /// mechanisms).
    None,
}

impl std::fmt::Display for HugePageStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            HugePageStatus::Explicit => "explicit (MAP_HUGETLB)",
            HugePageStatus::Transparent => "transparent (MADV_HUGEPAGE)",
            HugePageStatus::None => "none",
        })
    }
}

/// A mapped region of memory that other PEs may also have mapped.
///
/// # Safety-relevant contract
/// `base()..base()+len()` stays valid and constant for the lifetime of the
/// object; the memory is plain bytes with no destructor.
pub trait Segment: Send + Sync {
    /// Base address of the mapping *in this address space*.
    fn base(&self) -> *mut u8;
    /// Mapping length in bytes.
    fn len(&self) -> usize;
    /// The segment's global name, if it has one (POSIX segments do; private
    /// in-process segments do not).
    fn name(&self) -> Option<&str> {
        None
    }
    /// Huge-page backing the segment ended up with (best effort; see
    /// [`HugePageStatus`]).
    fn huge_pages(&self) -> HugePageStatus {
        HugePageStatus::None
    }
    /// Byte slice view. Unsafe because aliasing across PEs is the caller's
    /// (i.e. the SHMEM memory model's) responsibility.
    ///
    /// # Safety
    /// Caller must uphold the OpenSHMEM data-race rules: no concurrent
    /// conflicting access without an intervening synchronisation.
    unsafe fn bytes(&self) -> &[u8] {
        std::slice::from_raw_parts(self.base(), self.len())
    }
}

/// Boxed segment used by the world structures.
pub type BoxedSegment = Box<dyn Segment>;

/// Create the segment kind appropriate for an execution mode.
pub fn create_inproc(len: usize) -> Result<BoxedSegment> {
    Ok(Box::new(inproc::InProcSegment::new(len)?))
}

/// Which segment substrate process mode runs on.
///
/// Selection order ([`ShmEngine::resolve`]): an explicit `POSH_SHM_ENGINE`
/// override wins; otherwise `/dev/shm` writability is probed and the POSIX
/// engine is used when it passes, the memfd engine when it does not.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShmEngine {
    /// Named `/dev/shm` objects (`shm_open`) — the paper's substrate.
    /// Peers reach each other by rebuilding the name from the rank (§4.7).
    Posix,
    /// `memfd_create`-backed segments reached through launcher-inherited
    /// fds — the shm-less fallback.
    Memfd,
}

impl ShmEngine {
    /// Pick the engine for this process. Honour `POSH_SHM_ENGINE`
    /// (`posix`/`memfd`); otherwise auto-select on a `/dev/shm` probe.
    pub fn resolve() -> Self {
        match std::env::var("POSH_SHM_ENGINE") {
            Ok(v) if v.eq_ignore_ascii_case("memfd") => ShmEngine::Memfd,
            Ok(v) if v.eq_ignore_ascii_case("posix") => ShmEngine::Posix,
            _ => {
                if dev_shm_writable() {
                    ShmEngine::Posix
                } else {
                    ShmEngine::Memfd
                }
            }
        }
    }

    /// Short lowercase name (`"posix"` / `"memfd"`), matching what
    /// `POSH_SHM_ENGINE` accepts.
    pub fn name(self) -> &'static str {
        match self {
            ShmEngine::Posix => "posix",
            ShmEngine::Memfd => "memfd",
        }
    }
}

/// `true` if this process can create POSIX shm objects (cached probe:
/// create-and-drop a tiny segment). `false` on runners where `/dev/shm` is
/// read-only or absent — the case the memfd engine exists for.
pub fn dev_shm_writable() -> bool {
    use std::sync::OnceLock;
    static WRITABLE: OnceLock<bool> = OnceLock::new();
    *WRITABLE.get_or_init(|| {
        let name = format!("/posh.probe.{}", std::process::id());
        match posix::PosixShmSegment::create(&name, 4096) {
            Ok(seg) => {
                drop(seg); // owner drop unlinks the probe object
                true
            }
            Err(_) => false,
        }
    })
}

/// Map `len` bytes of `fd` as `MAP_SHARED` and attempt transparent
/// huge-page backing for large mappings — the shared tail of segment
/// creation for every fd-backed engine (POSIX shm and memfd).
pub(crate) fn map_shared_fd(fd: libc::c_int, len: usize) -> Result<(*mut u8, HugePageStatus)> {
    // SAFETY: mapping a valid fd MAP_SHARED.
    let ptr = unsafe {
        libc::mmap(
            std::ptr::null_mut(),
            len,
            libc::PROT_READ | libc::PROT_WRITE,
            libc::MAP_SHARED,
            fd,
            0,
        )
    };
    if ptr == libc::MAP_FAILED {
        bail!("mmap failed: {}", std::io::Error::last_os_error());
    }
    let huge = if len >= inproc::HUGE_PAGE_BYTES {
        // SAFETY: advising our own fresh mapping; refusal leaves plain pages.
        let rc = unsafe { libc::madvise(ptr, len, libc::MADV_HUGEPAGE) };
        if rc == 0 {
            HugePageStatus::Transparent
        } else {
            HugePageStatus::None
        }
    } else {
        HugePageStatus::None
    };
    Ok((ptr as *mut u8, huge))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boxed_segment_trait_object() {
        let seg = create_inproc(4096).unwrap();
        assert_eq!(seg.len(), 4096);
        assert!(!seg.base().is_null());
        assert!(seg.name().is_none());
        assert_eq!(seg.huge_pages(), HugePageStatus::None);
    }
}
