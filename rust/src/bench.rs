//! The paper's §5 measurement protocol, as a reusable harness.
//!
//! "Time measurements were done using `clock_gettime()` on the
//! `CLOCK_REALTIME` to achieve nanosecond precision. … Each experiment was
//! repeated 20 times after a warm-up round."
//!
//! `Instant::now()` reads `CLOCK_MONOTONIC` — same nanosecond source on
//! Linux without the wall-clock-adjustment hazard. For operations too fast
//! for a single clock read (an 8-byte copy is ~40 ns; the paper's own
//! latency rows sit at the measurement floor, as it notes for the "fast"
//! machines) each repetition times a *batch* and divides, which is the
//! standard refinement.
//!
//! Output goes to stdout as paper-shaped tables and to `bench_out/*.csv`
//! for regeneration of the figures.

use crate::util::stats::Summary;
use std::io::Write as _;
use std::time::Instant;

/// Repetitions per experiment (paper: 20, after warm-up).
pub const PAPER_REPS: usize = 20;

/// One measured quantity.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Per-repetition values, in nanoseconds per operation.
    pub ns_per_op: Vec<f64>,
    /// Bytes moved per operation (0 when not a data-movement op).
    pub bytes: usize,
}

impl Measurement {
    /// Summary statistics over the repetitions.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.ns_per_op)
    }

    /// Median latency in ns.
    pub fn latency_ns(&self) -> f64 {
        self.summary().median
    }

    /// Median bandwidth in **Gb/s** (the paper reports gigabits).
    pub fn bandwidth_gbps(&self) -> f64 {
        if self.bytes == 0 {
            return 0.0;
        }
        let ns = self.latency_ns();
        (self.bytes as f64 * 8.0) / ns // bytes*8 bits / ns == Gb/s
    }
}

/// Measure `op` with the paper protocol: one warm-up round, then
/// [`PAPER_REPS`] repetitions, each timing `batch` back-to-back executions.
pub fn measure<F: FnMut()>(bytes: usize, batch: usize, mut op: F) -> Measurement {
    assert!(batch >= 1);
    // Warm-up round (paper) — also faults in pages and trains the caches.
    for _ in 0..batch {
        op();
    }
    let mut ns = Vec::with_capacity(PAPER_REPS);
    for _ in 0..PAPER_REPS {
        let t0 = Instant::now();
        for _ in 0..batch {
            op();
        }
        let dt = t0.elapsed();
        ns.push(dt.as_nanos() as f64 / batch as f64);
    }
    Measurement { ns_per_op: ns, bytes }
}

/// Pick a batch size so one repetition takes ≥ ~200 µs (amortises the timer).
pub fn auto_batch(approx_ns_per_op: f64) -> usize {
    ((200_000.0 / approx_ns_per_op.max(1.0)).ceil() as usize).clamp(1, 1_000_000)
}

/// A paper-shaped results table: row labels × column labels.
pub struct Table {
    title: String,
    columns: Vec<String>,
    rows: Vec<(String, Vec<f64>)>,
    unit: String,
}

impl Table {
    /// New table with the given column headers.
    pub fn new(title: &str, unit: &str, columns: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            unit: unit.to_string(),
        }
    }

    /// Append a row.
    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
    }

    /// Render to stdout in the paper's layout.
    pub fn print(&self) {
        let w0 = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([8])
            .max()
            .unwrap();
        println!("\n{} [{}]", self.title, self.unit);
        print!("{:w0$}", "");
        for c in &self.columns {
            print!(" | {c:>12}");
        }
        println!();
        println!("{}", "-".repeat(w0 + self.columns.len() * 15));
        for (label, vals) in &self.rows {
            print!("{label:w0$}");
            for v in vals {
                if *v == 0.0 {
                    print!(" | {:>12}", "-");
                } else if *v >= 1000.0 {
                    print!(" | {v:>12.1}");
                } else {
                    print!(" | {v:>12.2}");
                }
            }
            println!();
        }
    }

    /// Write as CSV under `bench_out/`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        std::fs::create_dir_all("bench_out")?;
        let path = std::path::PathBuf::from(format!("bench_out/{name}.csv"));
        let mut f = std::fs::File::create(&path)?;
        write!(f, "label")?;
        for c in &self.columns {
            write!(f, ",{c}")?;
        }
        writeln!(f)?;
        for (label, vals) in &self.rows {
            write!(f, "{label}")?;
            for v in vals {
                write!(f, ",{v}")?;
            }
            writeln!(f)?;
        }
        Ok(path)
    }
}

/// A (size, value) series for figure regeneration, with CSV output and a
/// crude ASCII log-log plot so the shape is visible in the terminal.
pub struct Series {
    name: String,
    points: Vec<(usize, f64)>,
}

impl Series {
    /// New named series.
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), points: Vec::new() }
    }

    /// Append a point.
    pub fn push(&mut self, size: usize, value: f64) {
        self.points.push((size, value));
    }

    /// The points.
    pub fn points(&self) -> &[(usize, f64)] {
        &self.points
    }

    /// Series name.
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Write several series (same x-axis) to one CSV and print an ASCII plot.
pub fn write_series_csv(
    name: &str,
    xlabel: &str,
    series: &[Series],
) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all("bench_out")?;
    let path = std::path::PathBuf::from(format!("bench_out/{name}.csv"));
    let mut f = std::fs::File::create(&path)?;
    write!(f, "{xlabel}")?;
    for s in series {
        write!(f, ",{}", s.name)?;
    }
    writeln!(f)?;
    if let Some(first) = series.first() {
        for (i, &(x, _)) in first.points.iter().enumerate() {
            write!(f, "{x}")?;
            for s in series {
                write!(f, ",{}", s.points[i].1)?;
            }
            writeln!(f)?;
        }
    }
    Ok(path)
}

/// ASCII log-y plot of one series (figure shape check in the terminal).
pub fn ascii_plot(s: &Series, height: usize) {
    if s.points.is_empty() {
        return;
    }
    let logs: Vec<f64> = s.points.iter().map(|&(_, v)| v.max(1e-12).log10()).collect();
    let (lo, hi) = logs
        .iter()
        .fold((f64::MAX, f64::MIN), |(a, b), &v| (a.min(v), b.max(v)));
    let span = (hi - lo).max(1e-9);
    println!("  {} (log scale, {:.3e} .. {:.3e})", s.name, 10f64.powf(lo), 10f64.powf(hi));
    for level in (0..height).rev() {
        let thresh = lo + span * level as f64 / (height - 1) as f64;
        let line: String = logs
            .iter()
            .map(|&v| if v >= thresh { '#' } else { ' ' })
            .collect();
        println!("  |{line}");
    }
    println!("  +{}", "-".repeat(s.points.len()));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let m = measure(64, 10, || { std::hint::black_box(1 + 1); });
        assert_eq!(m.ns_per_op.len(), PAPER_REPS);
        assert!(m.latency_ns() >= 0.0);
        assert_eq!(m.bytes, 64);
    }

    #[test]
    fn bandwidth_math() {
        // 1 GiB moved in 1 s  ==  8.59 Gb/s.
        let m = Measurement { ns_per_op: vec![1e9; 3], bytes: 1 << 30 };
        let gbps = m.bandwidth_gbps();
        assert!((gbps - 8.589934592).abs() < 1e-6, "{gbps}");
    }

    #[test]
    fn auto_batch_scales() {
        assert!(auto_batch(40.0) >= 1000);
        assert_eq!(auto_batch(1e9), 1);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("Test", "ns", &["a", "b"]);
        t.row("row1", vec![1.0, 2.0]);
        t.row("row2", vec![1000.5, 0.0]);
        t.print();
        // write_csv targets ./bench_out; tests must not touch the process
        // cwd (libtest runs tests on parallel threads, cwd is global).
        let p = t.write_csv("test_table_roundtrip").unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("label,a,b"));
        assert!(s.contains("row1,1,2"));
    }

    #[test]
    fn series_csv() {
        let mut s1 = Series::new("put");
        let mut s2 = Series::new("get");
        for i in 0..4 {
            s1.push(8 << i, i as f64);
            s2.push(8 << i, i as f64 * 2.0);
        }
        ascii_plot(&s1, 4);
        let p = write_series_csv("test_series_csv", "bytes", &[s1, s2]).unwrap();
        let content = std::fs::read_to_string(&p).unwrap();
        assert!(content.starts_with("bytes,put,get"));
        assert_eq!(content.lines().count(), 5);
    }
}
