//! Collective communications (paper §4.5).
//!
//! Everything here is built from one-sided put/get plus the per-PE
//! *collective data structure* of §4.5.1 (the `coll` field of every heap
//! header): a buffer handle, a remote-access counter, an operation tag, an
//! in-progress flag, and (safe mode) the buffer size.
//!
//! Design points carried over from the paper:
//!
//! * **Put-based vs get-based variants** (§4.5): both are implemented. The
//!   paper chooses at compile time via compiler flags (§4.5.4); POSH-RS
//!   resolves per call through the fitted `T(n) = α + n/β` cost model
//!   ([`tuning`], the default), with the compile-time cargo features and
//!   the `POSH_COLL_ALGO` runtime override surviving as forced choices for
//!   the ablation benches.
//! * **Late-entry handling** (§4.5.2): a PE can be drafted into a collective
//!   before it enters the call — get-based ops publish their buffer handle
//!   and peers spin on it; put-based reductions publish the root's temporary
//!   buffer the same way.
//! * **Temporary non-symmetric allocations** (§4.5.3, Lemma 1): reductions
//!   allocate scratch space in the *root's* heap only, and free it before
//!   leaving the collective — the property tests in `rust/tests/` verify the
//!   heaps are byte-symmetric again afterwards.
//! * **State reset** (§4.5.1): every PE zeroes its collective structure on
//!   exit, after the closing active-set barrier, "to make sure the place is
//!   clean for the next collective communication".
//! * **Run-time error checking** (§4.5.5): safe mode validates operation
//!   tags and buffer sizes across participants and detects a PE entering
//!   two collectives at once.
//!
//! Membership follows OpenSHMEM 1.4 **teams**: every collective takes a
//! `&`[`crate::team::Team`], built collectively by `split_strided`/
//! `split_2d` from the world team. [`ActiveSet`] survives as the internal
//! strided-membership representation behind a team (now with arbitrary
//! stride, not just powers of two); the 1.0 `(PE_start, logPE_stride,
//! PE_size)` triplet is only spoken by the deprecated [`crate::api`] shims,
//! which wrap it in a temporary legacy team. The `pSync`/`pWrk` arrays of
//! the C API are accepted by those shims but not needed — coordination runs
//! over the header cells (per-team sync cells for real teams) and Lemma-1
//! temporaries, which is exactly the latitude the spec grants
//! implementations.

pub mod algorithm;
pub mod alltoall;
pub mod barrier;
pub mod broadcast;
pub mod collect;
pub mod hierarchy;
pub mod reduce;
pub mod state;
pub mod tuning;

pub use algorithm::AlgoKind;
pub use reduce::ReduceOp;
pub use state::ActiveSet;
pub use tuning::{CollOp, Tuning, TuningSource};
