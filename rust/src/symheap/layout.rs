//! Segment layout: the heap header (synchronisation cells + the paper's
//! §4.5.1 collective data structure), the statics area (§4.2), and the
//! dynamic heap.
//!
//! The header is all atomics — it is concurrently written by *remote* PEs
//! (that is the whole point of one-sided communication), so every field is
//! an `Atomic*` accessed through shared references.

use std::sync::atomic::{AtomicU32, AtomicU64};

/// Segment magic ("POSHHEAP" little-endian-ish).
pub const MAGIC: u64 = 0x504F_5348_4845_4150;

/// log2 of the maximum PE count supported by the dissemination barrier.
pub const MAX_BARRIER_ROUNDS: usize = 20; // up to 2^20 PEs

/// Number of named-lock slots in each header (§4.6 named mutexes).
pub const NAMED_LOCK_SLOTS: usize = 64;

/// Default size of the statics area (pre-parser output target, §4.2).
pub const DEFAULT_STATICS_SIZE: usize = 1 << 20;

/// Collective operation tags stored in [`CollectiveState::op_type`].
/// (Paper §4.5.1: "a type, that keeps what collective operation is
/// underway".)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u32)]
pub enum CollOpTag {
    /// No collective in progress.
    None = 0,
    /// Barrier.
    Barrier = 1,
    /// Broadcast.
    Broadcast = 2,
    /// Reduction.
    Reduce = 3,
    /// Fixed-size collect (fcollect).
    Fcollect = 4,
    /// Variable-size collect.
    Collect = 5,
    /// All-to-all (extension).
    Alltoall = 6,
}

impl CollOpTag {
    /// Decode from the stored u32 (unknown values map to `None`).
    pub fn from_u32(v: u32) -> CollOpTag {
        match v {
            1 => CollOpTag::Barrier,
            2 => CollOpTag::Broadcast,
            3 => CollOpTag::Reduce,
            4 => CollOpTag::Fcollect,
            5 => CollOpTag::Collect,
            6 => CollOpTag::Alltoall,
            _ => CollOpTag::None,
        }
    }
}

/// The per-PE collective data structure (paper §4.5.1), one cache line.
///
/// * `buf_offset` — segment offset + 1 of the buffer the collective moves
///   ("a pointer to the buffer"); 0 means null. Offsets, not addresses,
///   cross PE boundaries (Corollary 1 / Boost handles).
/// * `counter` — "counts how many remote processes have accessed the local
///   data".
/// * `op_type` — which collective is underway ([`CollOpTag`]).
/// * `in_progress` — "whether the collective communication is already in
///   progress"; set remotely when a peer initialises our structure before we
///   enter the call (§4.5.2).
/// * `data_size` — "in debug and in safe mode we keep the size of the data
///   buffer" (§4.5.1, §4.5.5). Always present; only *checked* in safe mode.
/// * `seq` — collective epoch, distinguishes successive collectives so a
///   fast PE's next operation cannot be confused with the current one.
#[repr(C, align(128))]
pub struct CollectiveState {
    /// Offset+1 of the data buffer in the owner's segment; 0 = null.
    pub buf_offset: AtomicU64,
    /// Remote-access counter.
    pub counter: AtomicU64,
    /// Current operation tag.
    pub op_type: AtomicU32,
    /// 1 while a collective is underway on this PE (possibly set remotely).
    pub in_progress: AtomicU32,
    /// Byte size of the buffer (safe-mode check).
    pub data_size: AtomicU64,
    /// Collective sequence number.
    pub seq: AtomicU64,
}

/// Dissemination-barrier mailboxes: `flags[r]` holds the highest epoch
/// signalled to this PE at round `r`.
#[repr(C, align(128))]
pub struct BarrierCells {
    /// Per-round epoch mailboxes.
    pub flags: [AtomicU64; MAX_BARRIER_ROUNDS],
    /// This PE's completed-barrier epoch (monotone).
    pub epoch: AtomicU64,
    /// Central-counter barrier (ablation baseline): arrivals this round.
    pub central_count: AtomicU64,
    /// Central barrier sense word.
    pub central_sense: AtomicU64,
    /// Active-set barrier arrivals (root's cell counts its set's members).
    pub set_count: AtomicU64,
    /// Active-set barrier release word (monotone, bumped by the set root).
    pub set_sense: AtomicU64,
}

/// The header at offset 0 of every symmetric-heap segment.
#[repr(C)]
pub struct HeapHeader {
    /// [`MAGIC`] once the owner has initialised the segment.
    pub magic: AtomicU64,
    /// Owner's rank (debugging / sanity checks).
    pub rank: AtomicU64,
    /// Set to 1 when the owner finished initialising its heap; peers spin on
    /// this before first access (the paper's "wait a little bit and try
    /// again" applies to segment *existence*; this flag covers content).
    pub ready: AtomicU32,
    /// Set to 1 when the owner has left the job (clean shutdown signal).
    pub finished: AtomicU32,
    /// Fact-1 cross-check: the owner's allocation-journal hash, refreshed at
    /// every barrier in safe mode.
    pub journal_hash: AtomicU64,
    /// Barrier cells.
    pub barrier: BarrierCells,
    /// The §4.5.1 collective structure.
    pub coll: CollectiveState,
    /// Named-lock words (§4.6): 0 = unlocked, else holder's `rank+1`
    /// in the low 32 bits and a ticket in the high bits.
    pub named_locks: [AtomicU64; NAMED_LOCK_SLOTS],
    /// Per-PE "signal" mailbox used by wait/wait_until tests and the RTE.
    pub mailbox: AtomicU64,
}

impl HeapHeader {
    /// Size of the header region, rounded to a page so the statics area and
    /// the heap start page-aligned (Fact 1 requires identical bases).
    pub fn region_size() -> usize {
        crate::util::align_up(
            std::mem::size_of::<HeapHeader>(),
            crate::shm::inproc::page_size(),
        )
    }

    /// Reinterpret the start of a segment as the header.
    ///
    /// # Safety
    /// `base` must point at a segment of at least [`Self::region_size`]
    /// bytes, zero-initialised or previously initialised as a header.
    /// All fields are atomics, so concurrent access is sound.
    pub unsafe fn at(base: *mut u8) -> &'static HeapHeader {
        &*(base as *const HeapHeader)
    }
}

/// Computed byte offsets of the three regions of a segment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout {
    /// Start of the statics area.
    pub statics_off: usize,
    /// Size of the statics area.
    pub statics_size: usize,
    /// Start of the dynamic heap.
    pub heap_off: usize,
    /// Total segment size.
    pub total: usize,
}

impl Layout {
    /// Compute the layout for a heap of `heap_size` data bytes and a statics
    /// area of `statics_size` bytes.
    pub fn compute(heap_size: usize, statics_size: usize) -> Layout {
        let page = crate::shm::inproc::page_size();
        let statics_off = HeapHeader::region_size();
        let statics_size = crate::util::align_up(statics_size, page);
        let heap_off = statics_off + statics_size;
        let total = heap_off + crate::util::align_up(heap_size, page);
        Layout { statics_off, statics_size, heap_off, total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn header_fits_one_page_region() {
        // Keep the header compact; if this grows past 2 pages something is
        // wrong (the named-lock table dominates: 64 * 8B).
        assert!(std::mem::size_of::<HeapHeader>() < 8192);
        assert_eq!(HeapHeader::region_size() % crate::shm::inproc::page_size(), 0);
    }

    #[test]
    fn coll_state_is_cacheline_isolated() {
        assert_eq!(std::mem::align_of::<CollectiveState>(), 128);
        assert_eq!(std::mem::align_of::<BarrierCells>(), 128);
    }

    #[test]
    fn header_view_over_zeroed_memory() {
        let seg = crate::shm::inproc::InProcSegment::new(HeapHeader::region_size()).unwrap();
        let hdr = unsafe { HeapHeader::at(seg.base()) };
        assert_eq!(hdr.magic.load(Ordering::Relaxed), 0);
        hdr.magic.store(MAGIC, Ordering::Release);
        assert_eq!(hdr.magic.load(Ordering::Acquire), MAGIC);
        hdr.barrier.flags[3].fetch_add(7, Ordering::AcqRel);
        assert_eq!(hdr.barrier.flags[3].load(Ordering::Relaxed), 7);
        use crate::shm::Segment;
    }

    #[test]
    fn layout_regions_ordered_and_aligned() {
        let l = Layout::compute(1 << 20, 1 << 16);
        let page = crate::shm::inproc::page_size();
        assert!(l.statics_off >= std::mem::size_of::<HeapHeader>());
        assert_eq!(l.statics_off % page, 0);
        assert_eq!(l.heap_off % page, 0);
        assert!(l.heap_off >= l.statics_off + (1 << 16));
        assert!(l.total >= l.heap_off + (1 << 20));
    }

    #[test]
    fn coll_tag_roundtrip() {
        for t in [
            CollOpTag::None,
            CollOpTag::Barrier,
            CollOpTag::Broadcast,
            CollOpTag::Reduce,
            CollOpTag::Fcollect,
            CollOpTag::Collect,
            CollOpTag::Alltoall,
        ] {
            assert_eq!(CollOpTag::from_u32(t as u32), t);
        }
        assert_eq!(CollOpTag::from_u32(999), CollOpTag::None);
    }
}
