"""Layer 1: tiled copy Pallas kernel — the TPU analog of the paper's §4.4
memcpy study (DESIGN.md §6 Hardware-Adaptation).

POSH's question "which copy loop (stock/MMX/MMX2/SSE) moves bytes fastest on
this machine?" becomes, on TPU, "which HBM↔VMEM block schedule?". The block
shape `(bm, bn)` is the tuning axis: one grid step DMAs a `(bm, bn)` tile
into VMEM and streams it back out. `aot.py` exports several variants (the
Table-1 column analog); `vmem_footprint_bytes` is the roofline input used in
EXPERIMENTS.md §Perf (a copy kernel is DMA-bound: the figure of merit is HBM
bandwidth utilisation, exactly Table 1's Gb/s column).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def copy_tiled(x, bm: int = 256, bn: int = 256):
    """Copy a 2-D array tile by tile. x: [M, N] -> [M, N] (same dtype)."""
    m, n = x.shape
    bm = _divisor_block(m, bm)
    bn = _divisor_block(n, bn)
    return pl.pallas_call(
        _copy_kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)


def _divisor_block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def vmem_footprint_bytes(bm: int, bn: int, dtype_bytes: int = 4) -> int:
    """VMEM working set of one grid step (in tile + out tile)."""
    return dtype_bytes * 2 * bm * bn


#: The block-shape sweep exported by aot.py — the TPU "Table 1" columns.
VARIANTS = {
    "copy_128x128": (128, 128),
    "copy_256x256": (256, 256),
    "copy_512x128": (512, 128),
    "copy_64x512": (64, 512),
}
