//! One-sided point-to-point communication (§3.2, §4.4): `put` and `get`.
//!
//! Both are memory copies between the caller's private memory and the
//! target's public (symmetric) memory, routed through the tunable copy
//! engine of [`crate::mem`]. The destination address on the remote PE is
//! computed with Corollary 1 (base-of-remote + handle offset) via the cached
//! remote-heap table — no handshake with the target, ever.
//!
//! Safe-mode (§4.5.5 analog) validates PE range and buffer bounds on every
//! call; release builds compile those checks down to debug asserts.

pub mod nbi;
pub mod ptr;
pub(crate) mod shard_queue;
pub mod strided;

use crate::mem::copy::{copy_bytes, copy_bytes_with, CopyImpl};
use crate::pe::Ctx;
use crate::symheap::SymPtr;

impl Ctx {
    #[inline]
    fn check_p2p<T>(&self, dest: SymPtr<T>, nelems: usize, pe: usize) {
        if self.config().safe {
            assert!(pe < self.n_pes(), "target PE {pe} out of range");
            assert!(
                nelems <= dest.len(),
                "buffer overflow: {} elems into a {}-elem symmetric object",
                nelems,
                dest.len()
            );
        } else {
            debug_assert!(pe < self.n_pes());
            debug_assert!(nelems <= dest.len());
        }
    }

    /// `shmem_put`: copy `src` into the symmetric object `dest` on PE `pe`.
    ///
    /// Dispatches through the process-wide copy state: a forced engine when
    /// one is configured (`POSH_COPY`, `copy-*` features), otherwise the
    /// size-aware [`crate::mem::plan::CopyPlan`] picks per payload size.
    #[inline]
    pub fn put<T: Copy>(&self, dest: SymPtr<T>, src: &[T], pe: usize) {
        self.check_p2p(dest, src.len(), pe);
        // SAFETY: handle in-bounds (checked), src is a live slice, regions
        // cannot overlap (private stack/heap vs mapped segment).
        unsafe {
            copy_bytes(
                self.remote_addr(dest, pe) as *mut u8,
                src.as_ptr() as *const u8,
                std::mem::size_of_val(src),
            );
        }
    }

    /// `put` with an explicit copy implementation (bench sweeps, Table 2).
    #[inline]
    pub fn put_with<T: Copy>(&self, imp: CopyImpl, dest: SymPtr<T>, src: &[T], pe: usize) {
        self.check_p2p(dest, src.len(), pe);
        // SAFETY: handle in-bounds (checked), src is a live slice, regions
        // cannot overlap (private stack/heap vs mapped segment).
        unsafe {
            copy_bytes_with(
                imp,
                self.remote_addr(dest, pe) as *mut u8,
                src.as_ptr() as *const u8,
                std::mem::size_of_val(src),
            );
        }
    }

    /// `shmem_get`: copy the symmetric object `src` on PE `pe` into `dest`.
    ///
    /// Same size-aware dispatch as [`Ctx::put`].
    #[inline]
    pub fn get<T: Copy>(&self, dest: &mut [T], src: SymPtr<T>, pe: usize) {
        self.check_p2p(src, dest.len(), pe);
        // SAFETY: as `put`, directions reversed.
        unsafe {
            copy_bytes(
                dest.as_mut_ptr() as *mut u8,
                self.remote_addr(src, pe) as *const u8,
                std::mem::size_of_val(dest),
            );
        }
    }

    /// `get` with an explicit copy implementation.
    #[inline]
    pub fn get_with<T: Copy>(&self, imp: CopyImpl, dest: &mut [T], src: SymPtr<T>, pe: usize) {
        self.check_p2p(src, dest.len(), pe);
        // SAFETY: as `put_with`, directions reversed.
        unsafe {
            copy_bytes_with(
                imp,
                dest.as_mut_ptr() as *mut u8,
                self.remote_addr(src, pe) as *const u8,
                std::mem::size_of_val(dest),
            );
        }
    }

    /// `shmem_<type>_p`: write a single element (§4.3's `shmem_template_p`).
    #[inline]
    pub fn put_one<T: Copy>(&self, dest: SymPtr<T>, value: T, pe: usize) {
        self.check_p2p(dest, 1, pe);
        // SAFETY: single in-bounds element; volatile so the store is not
        // elided or torn apart by the optimiser (remote PEs observe it).
        unsafe {
            (self.remote_addr(dest, pe)).write_volatile(value);
        }
    }

    /// `shmem_<type>_g`: read a single element (§4.3's `shmem_template_g`).
    #[inline]
    pub fn get_one<T: Copy>(&self, src: SymPtr<T>, pe: usize) -> T {
        self.check_p2p(src, 1, pe);
        // SAFETY: as put_one.
        unsafe { (self.remote_addr(src, pe) as *const T).read_volatile() }
    }

    /// Copy between two symmetric objects without staging through private
    /// memory (used by collectives: remote-to-remote via local mapping).
    #[inline]
    pub fn put_sym<T: Copy>(
        &self,
        dest: SymPtr<T>,
        dest_pe: usize,
        src: SymPtr<T>,
        src_pe: usize,
        nelems: usize,
    ) {
        self.check_p2p(dest, nelems, dest_pe);
        self.check_p2p(src, nelems, src_pe);
        // SAFETY: both sides resolved in-bounds; overlap only possible when
        // dest_pe == src_pe and handles overlap, which the SHMEM model
        // forbids for concurrent access; use memmove-safe stock copy if the
        // handles alias.
        unsafe {
            let d = self.remote_addr(dest, dest_pe) as *mut u8;
            let s = self.remote_addr(src, src_pe) as *const u8;
            let bytes = nelems * std::mem::size_of::<T>();
            if dest_pe == src_pe {
                std::ptr::copy(s, d, bytes); // memmove semantics
            } else {
                crate::mem::copy::copy_bytes(d, s, bytes);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::pe::{PoshConfig, World};

    #[test]
    fn put_then_get_roundtrip() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let buf = ctx.shmalloc_n::<u32>(128).unwrap();
            if ctx.my_pe() == 0 {
                let data: Vec<u32> = (0..128).map(|i| i * 7).collect();
                ctx.put(buf, &data, 1);
            }
            ctx.barrier_all();
            if ctx.my_pe() == 1 {
                let local = unsafe { ctx.local(buf) };
                assert!(local.iter().enumerate().all(|(i, &v)| v == i as u32 * 7));
            }
            // And get it back from PE 1 on PE 0.
            if ctx.my_pe() == 0 {
                let mut back = vec![0u32; 128];
                ctx.get(&mut back, buf, 1);
                assert!(back.iter().enumerate().all(|(i, &v)| v == i as u32 * 7));
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn put_one_get_one_all_pairs() {
        let n = 4;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let cell = ctx.shmalloc_n::<i64>(n).unwrap();
            // Every PE writes its rank into its slot on every PE.
            for pe in 0..n {
                ctx.put_one(cell.at(ctx.my_pe()), ctx.my_pe() as i64 + 100, pe);
            }
            ctx.barrier_all();
            // Every PE reads every slot from every PE.
            for pe in 0..n {
                for slot in 0..n {
                    assert_eq!(ctx.get_one(cell.at(slot), pe), slot as i64 + 100);
                }
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn put_with_every_impl() {
        use crate::mem::copy::CopyImpl;
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let buf = ctx.shmalloc_n::<u8>(4097).unwrap();
            for (k, imp) in CopyImpl::available().into_iter().enumerate() {
                if ctx.my_pe() == 0 {
                    let data: Vec<u8> = (0..4097u32).map(|i| (i as u8) ^ (k as u8)).collect();
                    ctx.put_with(imp, buf, &data, 1);
                }
                ctx.barrier_all();
                if ctx.my_pe() == 1 {
                    let local = unsafe { ctx.local(buf) };
                    assert!(
                        local
                            .iter()
                            .enumerate()
                            .all(|(i, &v)| v == (i as u8) ^ (k as u8)),
                        "{imp:?}"
                    );
                }
                ctx.barrier_all();
            }
        });
    }

    #[test]
    fn put_sym_remote_to_remote() {
        let w = World::threads(3, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let a = ctx.shmalloc_n::<u64>(16).unwrap();
            let b = ctx.shmalloc_n::<u64>(16).unwrap();
            if ctx.my_pe() == 1 {
                let vals = vec![0xABCDu64; 16];
                unsafe { ctx.local_mut(a).copy_from_slice(&vals) };
            }
            ctx.barrier_all();
            if ctx.my_pe() == 0 {
                // Move PE1's `a` into PE2's `b` without touching PE0 memory.
                ctx.put_sym(b, 2, a, 1, 16);
            }
            ctx.barrier_all();
            if ctx.my_pe() == 2 {
                assert_eq!(unsafe { ctx.local(b) }, &[0xABCDu64; 16][..]);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn self_put_is_local_copy() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let buf = ctx.shmalloc_n::<f64>(8).unwrap();
            ctx.put(buf, &[1.5; 8], 0);
            assert_eq!(unsafe { ctx.local(buf) }, &[1.5; 8][..]);
        });
    }

    #[cfg(feature = "safe-mode")]
    #[test]
    fn safe_mode_catches_overflow() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            w.run(|ctx| {
                let buf = ctx.shmalloc_n::<u8>(4).unwrap();
                ctx.put(buf, &[0u8; 64], 0); // 64 into 4
            });
        }));
        assert!(r.is_err());
    }
}
