//! The store facade: routing, per-shard writer locks, and the two access
//! planes (local pointer vs one-sided copies) behind one `put`/`get` API.

use super::shard::{self, ShardView, ARENA_HDR};
use super::{key_hash, route, KvConfig};
use crate::ctx::CommCtx;
use crate::pe::Ctx;
use crate::symheap::SymPtr;
use crate::team::Team;
use crate::Result;
use anyhow::ensure;

/// A PE-sharded key-value store on the symmetric heap. Cheap to use from
/// many threads of one PE concurrently (`&self` everywhere; remote writes
/// ride the calling thread's pooled [`CommCtx`]).
///
/// Create with the collective [`KvStore::create`]; every PE must
/// participate with an identical [`KvConfig`]. Tear down with the
/// collective [`KvStore::destroy`].
pub struct KvStore {
    ctx: Ctx,
    team: Team,
    cfg: KvConfig,
    /// One handle per shard index. By Fact 1 the handle is identical on
    /// every PE, so `arenas[s]` resolved against PE `p`'s base *is* shard
    /// `s` of PE `p` — the store needs `shards_per_pe` handles, not
    /// `n_pes × shards_per_pe`.
    arenas: Vec<SymPtr<u8>>,
}

/// Point-in-time statistics of the calling PE's own shards (remote shards
/// belong to their owners; aggregate with a reduction if needed).
#[derive(Clone, Debug)]
pub struct KvStats {
    /// Shards owned by this PE.
    pub shards: usize,
    /// Distinct keys across this PE's shards.
    pub keys: u64,
    /// Arena bytes consumed by nodes, blobs, and headers.
    pub used_bytes: u64,
    /// Total arena capacity of this PE's shards.
    pub capacity_bytes: u64,
}

impl KvStore {
    /// Collective constructor: allocates `shards_per_pe` symmetric arenas
    /// (each allocation is itself collective, preserving Fact 1), formats
    /// the local shard headers, and barriers so no PE can observe an
    /// unformatted shard.
    pub fn create(ctx: &Ctx, cfg: KvConfig) -> Result<KvStore> {
        ensure!(cfg.shards_per_pe >= 1, "kv: need at least one shard per PE");
        ensure!(
            cfg.arena_bytes >= ARENA_HDR + 256,
            "kv: arena_bytes {} below the {}+256 floor",
            cfg.arena_bytes,
            ARENA_HDR
        );
        ensure!(
            cfg.arena_bytes < u32::MAX as usize,
            "kv: arena_bytes {} overflows the u32 link words",
            cfg.arena_bytes
        );
        ensure!(
            cfg.max_key_len >= 1 && cfg.max_key_len <= u16::MAX as usize,
            "kv: max_key_len {} outside 1..=65535",
            cfg.max_key_len
        );
        ensure!(
            cfg.max_val_len < u32::MAX as usize,
            "kv: max_val_len {} overflows the value word",
            cfg.max_val_len
        );
        let mut arenas = Vec::with_capacity(cfg.shards_per_pe);
        for _ in 0..cfg.shards_per_pe {
            arenas.push(ctx.shmalloc_n::<u8>(cfg.arena_bytes)?);
        }
        // Format this PE's shards through the direct pointer (heap memory
        // may be recycled and must not leak a stale header).
        for a in &arenas {
            let base = ctx.shmem_ptr(*a, ctx.my_pe()).expect("own PE is accessible");
            shard::init_header(&ShardView::Local { base });
        }
        ctx.barrier_all();
        Ok(KvStore { ctx: ctx.clone(), team: ctx.team_world(), cfg, arenas })
    }

    /// The store's configuration.
    pub fn config(&self) -> &KvConfig {
        &self.cfg
    }

    /// Which `(owner PE, shard index)` a key routes to.
    pub fn owner_of(&self, key: &[u8]) -> (usize, usize) {
        route(key_hash(key), self.ctx.n_pes(), self.cfg.shards_per_pe)
    }

    /// The named-lock name of shard `s` (identical on every PE: derived
    /// from the first arena's Fact-1-symmetric offset, so two coexisting
    /// stores never share locks).
    fn lock_name(&self, shard: usize) -> String {
        format!("posh-kv/{:x}/{shard}", self.arenas[0].offset())
    }

    /// A view of shard `s` on PE `pe`: the calling PE's own shards go
    /// through the `shmem_ptr` direct plane, everything else through
    /// one-sided copies (with `comm` carrying bulk writes, when given).
    fn view_for<'a>(&'a self, pe: usize, shard: usize, comm: Option<&'a CommCtx>) -> ShardView<'a> {
        if pe == self.ctx.my_pe() {
            let base = self.ctx.shmem_ptr(self.arenas[shard], pe).expect("own PE is accessible");
            ShardView::Local { base }
        } else {
            ShardView::Remote { ctx: &self.ctx, pe, arena: self.arenas[shard], comm }
        }
    }

    /// Insert or overwrite `key` → `value`. Routes to the owner shard,
    /// serialises on that shard's named lock (homed on the owner's heap
    /// header, paper §4.6), and publishes flag-after-data. Returns the
    /// write's sequence number — shard-monotonic, so for one key a larger
    /// seq is the later write (the LWW winner).
    pub fn put(&self, key: &[u8], value: &[u8]) -> Result<u64> {
        ensure!(!key.is_empty(), "kv: empty keys are not supported");
        ensure!(
            key.len() <= self.cfg.max_key_len,
            "kv: key length {} exceeds max_key_len {}",
            key.len(),
            self.cfg.max_key_len
        );
        ensure!(
            value.len() <= self.cfg.max_val_len,
            "kv: value length {} exceeds max_val_len {}",
            value.len(),
            self.cfg.max_val_len
        );
        let hash = key_hash(key);
        let (pe, s) = route(hash, self.ctx.n_pes(), self.cfg.shards_per_pe);
        let name = self.lock_name(s);
        if pe == self.ctx.my_pe() {
            let _g = self.ctx.named_lock(&name, pe);
            shard::put(&self.view_for(pe, s, None), self.cfg.arena_bytes, key, value, hash)
        } else {
            // Thread-private NBI domain for the bulk bytes: this thread's
            // flush cannot stall (or be stalled by) a sibling thread's.
            let comm = self.team.ctx_for_thread();
            let _g = self.ctx.named_lock(&name, pe);
            shard::put(&self.view_for(pe, s, Some(&comm)), self.cfg.arena_bytes, key, value, hash)
        }
    }

    /// Look up `key`. Lock-free on both planes; a remote get is a pure
    /// one-sided walk of the owner's arena. `None` if absent.
    pub fn get(&self, key: &[u8]) -> Option<Vec<u8>> {
        self.get_versioned(key).map(|(_, v)| v)
    }

    /// [`KvStore::get`] plus the stored sequence number. On a quiescent
    /// store the pair is exact; racing a concurrent overwrite of the same
    /// key, seq and value may belong to adjacent versions (each is
    /// individually committed — the value is never torn).
    pub fn get_versioned(&self, key: &[u8]) -> Option<(u64, Vec<u8>)> {
        let (pe, s) = route(key_hash(key), self.ctx.n_pes(), self.cfg.shards_per_pe);
        shard::get(&self.view_for(pe, s, None), key)
    }

    /// Total distinct keys across **all** PEs' shards (one-sided header
    /// reads; exact when writers are quiescent).
    pub fn len(&self) -> u64 {
        let mut total = 0;
        for pe in 0..self.ctx.n_pes() {
            for s in 0..self.cfg.shards_per_pe {
                total += shard::key_count(&self.view_for(pe, s, None));
            }
        }
        total
    }

    /// `true` if no PE's shard holds a key.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Statistics of the calling PE's own shards.
    pub fn stats(&self) -> KvStats {
        let mut keys = 0;
        let mut used = 0;
        let me = self.ctx.my_pe();
        for s in 0..self.cfg.shards_per_pe {
            let view = self.view_for(me, s, None);
            keys += shard::key_count(&view);
            used += shard::used_bytes(&view);
        }
        KvStats {
            shards: self.cfg.shards_per_pe,
            keys,
            used_bytes: used,
            capacity_bytes: (self.cfg.shards_per_pe * self.cfg.arena_bytes) as u64,
        }
    }

    /// Collective destructor: frees the arenas (each `shfree` barriers).
    /// Every PE must call it; no PE may touch the store afterwards.
    pub fn destroy(self) -> Result<()> {
        for a in &self.arenas {
            self.ctx.shfree(*a)?;
        }
        Ok(())
    }
}

impl std::fmt::Debug for KvStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KvStore{{shards_per_pe={}, arena_bytes={}, pes={}}}",
            self.cfg.shards_per_pe,
            self.cfg.arena_bytes,
            self.ctx.n_pes()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};

    fn bail_if(r: Result<KvStore>) -> KvStore {
        r.expect("store creation")
    }

    #[test]
    fn put_get_across_pes() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let kv = bail_if(KvStore::create(&ctx, KvConfig::small()));
            // Each PE writes 50 disjoint keys; routing scatters them over
            // both PEs, so this exercises local and remote puts.
            for i in 0..50u32 {
                let key = format!("pe{}/k{i:04}", ctx.my_pe());
                let val = format!("pe{}-val-{i}", ctx.my_pe());
                kv.put(key.as_bytes(), val.as_bytes()).unwrap();
            }
            ctx.barrier_all();
            // Everyone reads everything (local + remote gets).
            for pe in 0..2 {
                for i in 0..50u32 {
                    let key = format!("pe{pe}/k{i:04}");
                    let got = kv.get(key.as_bytes()).expect("key present");
                    assert_eq!(got, format!("pe{pe}-val-{i}").as_bytes());
                }
            }
            assert_eq!(kv.len(), 100);
            assert!(!kv.is_empty());
            ctx.barrier_all();
            kv.destroy().unwrap();
        });
    }

    #[test]
    fn overwrite_is_lww_by_seq() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let kv = bail_if(KvStore::create(&ctx, KvConfig::small()));
            let key = b"contended-key";
            // Phase 1: PE 0 writes; phase 2: PE 1 overwrites. Barriers make
            // the order deterministic; seqs must be strictly increasing and
            // the final value must be PE 1's.
            let mut s0 = 0;
            if ctx.my_pe() == 0 {
                s0 = kv.put(key, b"first").unwrap();
            }
            ctx.barrier_all();
            if ctx.my_pe() == 1 {
                let s1 = kv.put(key, b"second").unwrap();
                assert!(s1 >= 2, "overwrite seq {s1} must follow the first write");
            }
            ctx.barrier_all();
            let (seq, v) = kv.get_versioned(key).expect("key present");
            assert_eq!(v, b"second");
            if ctx.my_pe() == 0 {
                assert!(seq > s0);
            }
            assert_eq!(kv.len(), 1, "overwrites must not grow the key count");
            ctx.barrier_all();
            kv.destroy().unwrap();
        });
    }

    #[test]
    fn missing_keys_and_validation() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let kv = bail_if(KvStore::create(&ctx, KvConfig::small()));
            assert!(kv.get(b"absent").is_none());
            assert!(kv.put(b"", b"x").is_err(), "empty key must be rejected");
            let long_key = vec![b'k'; kv.config().max_key_len + 1];
            assert!(kv.put(&long_key, b"x").is_err());
            let long_val = vec![0u8; kv.config().max_val_len + 1];
            assert!(kv.put(b"k", &long_val).is_err());
            // Max-size key and value are accepted.
            let key = vec![b'k'; kv.config().max_key_len];
            let val = vec![7u8; kv.config().max_val_len];
            kv.put(&key, &val).unwrap();
            assert_eq!(kv.get(&key).unwrap(), val);
            kv.destroy().unwrap();
        });
    }

    #[test]
    fn stats_track_usage() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let kv = bail_if(KvStore::create(&ctx, KvConfig::small()));
            let empty = kv.stats();
            assert_eq!(empty.keys, 0);
            assert_eq!(empty.shards, kv.config().shards_per_pe);
            for i in 0..40u32 {
                kv.put(format!("sk{i}").as_bytes(), &[1u8; 64]).unwrap();
            }
            ctx.barrier_all();
            let st = kv.stats();
            let peer: u64 = kv.len() - st.keys;
            assert_eq!(st.keys + peer, 40);
            assert!(st.used_bytes > empty.used_bytes || st.keys == 0);
            assert!(st.used_bytes <= st.capacity_bytes);
            ctx.barrier_all();
            kv.destroy().unwrap();
        });
    }

    #[test]
    fn create_rejects_bad_configs() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let bad = KvConfig { shards_per_pe: 0, ..KvConfig::small() };
            assert!(KvStore::create(&ctx, bad).is_err());
            let bad = KvConfig { arena_bytes: 64, ..KvConfig::small() };
            assert!(KvStore::create(&ctx, bad).is_err());
            let bad = KvConfig { max_key_len: 0, ..KvConfig::small() };
            assert!(KvStore::create(&ctx, bad).is_err());
        });
    }
}
