//! A Berkeley-UPC/GASNet-style one-sided library (Table 3 baseline).
//!
//! What distinguishes UPC's data movement from SHMEM's, on shared memory:
//!
//! * **Global pointers** carry `(thread, phase, offset)` and every
//!   dereference resolves affinity *at access time* — where POSH resolves a
//!   symmetric handle with one add against a cached base, UPC's generic
//!   `upc_memput/memget` goes through a conduit dispatch that re-derives the
//!   target mapping per call (GASNet's `gasnet_put/get` on the smp conduit).
//! * **Relaxed vs strict** accesses: strict ops fence around every access.
//!
//! The implementation reproduces those structural costs honestly: a
//! `GlobalPtr` is a fat struct, affinity resolution walks the directory
//! through a bounds- and phase-checked path (marked `#[inline(never)]`, as
//! the conduit boundary is a real call in GASNet), and strict mode issues
//! the fences UPC's memory model requires. Payload movement itself is the
//! stock `memcpy` — same as GASNet smp — so Table 3's "both are ≈ memcpy at
//! large sizes, UPC pays extra at small sizes" shape is reproducible.

use crate::shm::BoxedSegment;
use crate::Result;
use anyhow::bail;
use std::sync::atomic::{fence, Ordering};
use std::sync::Arc;

/// A UPC-style "thread" directory: every thread's shared segment.
pub struct UpcWorld {
    segs: Vec<BoxedSegment>,
    seg_len: usize,
}

/// UPC global pointer: thread affinity + phase + byte offset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GlobalPtr {
    /// Owning thread.
    pub thread: usize,
    /// Block phase (cyclic layouts); carried and checked like real UPC
    /// pointers even when unused, because carrying it is part of the cost.
    pub phase: usize,
    /// Byte offset within the owner's segment.
    pub offset: usize,
}

/// Memory-consistency mode of an access (UPC §5: relaxed/strict).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Consistency {
    /// No ordering beyond the access itself.
    Relaxed,
    /// Sequentially-consistent fencing around the access.
    Strict,
}

impl UpcWorld {
    /// Build a world of `threads` segments of `seg_len` bytes.
    pub fn new(threads: usize, seg_len: usize) -> Result<Arc<UpcWorld>> {
        if threads == 0 {
            bail!("UPC world needs at least one thread");
        }
        let mut segs = Vec::with_capacity(threads);
        for _ in 0..threads {
            segs.push(crate::shm::create_inproc(seg_len)?);
        }
        Ok(Arc::new(UpcWorld { segs, seg_len }))
    }

    /// Number of threads.
    pub fn threads(&self) -> usize {
        self.segs.len()
    }

    /// Segment length.
    pub fn seg_len(&self) -> usize {
        self.seg_len
    }

    /// `upc_alloc`-style allocation: one block at the same offset on every
    /// thread (collective global allocation). Bump allocation for the
    /// baseline; returns a pointer with affinity to `thread`.
    pub fn global_ptr(&self, thread: usize, offset: usize) -> GlobalPtr {
        GlobalPtr { thread, phase: 0, offset }
    }

    /// Affinity resolution — the conduit boundary. Deliberately
    /// `inline(never)`: GASNet's put/get entry is a real function call with
    /// validation, and that per-access cost is precisely what Table 3
    /// contrasts with POSH's inlined base+offset add.
    #[inline(never)]
    fn resolve(&self, p: GlobalPtr, len: usize) -> *mut u8 {
        assert!(p.thread < self.segs.len(), "global ptr thread out of range");
        assert!(
            p.offset + len <= self.seg_len,
            "global ptr {}+{} outside segment of {}",
            p.offset,
            len,
            self.seg_len
        );
        debug_assert_eq!(p.phase, 0, "cyclic phase not used by this baseline");
        // SAFETY: bounds just checked.
        unsafe { self.segs[p.thread].base().add(p.offset) }
    }

    /// `upc_memput`: private → shared.
    pub fn memput(&self, dst: GlobalPtr, src: &[u8], mode: Consistency) {
        if mode == Consistency::Strict {
            fence(Ordering::SeqCst);
        }
        let d = self.resolve(dst, src.len());
        // GASNet smp conduit moves payload with memcpy (paper §5.3).
        // SAFETY: resolve() bounds-checked; src is a live slice.
        unsafe { std::ptr::copy_nonoverlapping(src.as_ptr(), d, src.len()) }
        if mode == Consistency::Strict {
            fence(Ordering::SeqCst);
        }
    }

    /// `upc_memget`: shared → private.
    pub fn memget(&self, dst: &mut [u8], src: GlobalPtr, mode: Consistency) {
        if mode == Consistency::Strict {
            fence(Ordering::SeqCst);
        }
        let s = self.resolve(src, dst.len());
        // SAFETY: as memput.
        unsafe { std::ptr::copy_nonoverlapping(s, dst.as_mut_ptr(), dst.len()) }
        if mode == Consistency::Strict {
            fence(Ordering::SeqCst);
        }
    }

    /// Shared-to-shared `upc_memcpy`.
    pub fn memcpy(&self, dst: GlobalPtr, src: GlobalPtr, len: usize, mode: Consistency) {
        if mode == Consistency::Strict {
            fence(Ordering::SeqCst);
        }
        let d = self.resolve(dst, len);
        let s = self.resolve(src, len);
        // SAFETY: both resolved in-bounds; distinct segments never overlap,
        // same-segment overlap handled with memmove semantics.
        unsafe {
            if dst.thread == src.thread {
                std::ptr::copy(s, d, len);
            } else {
                std::ptr::copy_nonoverlapping(s, d, len);
            }
        }
        if mode == Consistency::Strict {
            fence(Ordering::SeqCst);
        }
    }

    /// Single-element typed read (`shared int x; … = x;`): one resolution
    /// per element — the UPC fine-grained access pattern.
    pub fn read_u64(&self, p: GlobalPtr, mode: Consistency) -> u64 {
        let mut buf = [0u8; 8];
        self.memget(&mut buf, p, mode);
        u64::from_le_bytes(buf)
    }

    /// Single-element typed write.
    pub fn write_u64(&self, p: GlobalPtr, v: u64, mode: Consistency) {
        self.memput(p, &v.to_le_bytes(), mode);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memput_memget_roundtrip() {
        let w = UpcWorld::new(3, 1 << 16).unwrap();
        let p = w.global_ptr(2, 1024);
        let data: Vec<u8> = (0..255).collect();
        w.memput(p, &data, Consistency::Relaxed);
        let mut back = vec![0u8; 255];
        w.memget(&mut back, p, Consistency::Relaxed);
        assert_eq!(back, data);
    }

    #[test]
    fn strict_mode_works() {
        let w = UpcWorld::new(2, 4096).unwrap();
        let p = w.global_ptr(1, 0);
        w.write_u64(p, 0xDEAD, Consistency::Strict);
        assert_eq!(w.read_u64(p, Consistency::Strict), 0xDEAD);
    }

    #[test]
    fn shared_to_shared_across_threads() {
        let w = UpcWorld::new(2, 4096).unwrap();
        let a = w.global_ptr(0, 64);
        let b = w.global_ptr(1, 128);
        w.memput(a, &[7u8; 32], Consistency::Relaxed);
        w.memcpy(b, a, 32, Consistency::Relaxed);
        let mut out = [0u8; 32];
        w.memget(&mut out, b, Consistency::Relaxed);
        assert_eq!(out, [7u8; 32]);
    }

    #[test]
    #[should_panic(expected = "outside segment")]
    fn out_of_bounds_rejected() {
        let w = UpcWorld::new(1, 4096).unwrap();
        let p = w.global_ptr(0, 4090);
        w.memput(p, &[0u8; 16], Consistency::Relaxed);
    }

    #[test]
    #[should_panic(expected = "thread out of range")]
    fn bad_thread_rejected() {
        let w = UpcWorld::new(1, 4096).unwrap();
        let p = w.global_ptr(5, 0);
        let mut b = [0u8; 1];
        w.memget(&mut b, p, Consistency::Relaxed);
    }
}
