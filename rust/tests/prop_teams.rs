//! Property tests for the team/context surface (OpenSHMEM 1.4):
//!
//! * **Translation round-trip** — for random strided and 2-D splits,
//!   team-rank → world-rank → team-rank is the identity on members.
//! * **Partition** — disjoint sibling splits cover the parent exactly once.
//! * **Oracle** — a team reduction equals the serial oracle restricted to
//!   the team's members.
//! * **Quiet scoping** — quiet on one communication context never retires
//!   another context's (or the default domain's) pending NBI operations,
//!   and (flag-after-data oracle) never *delivers* a sibling's deferred
//!   puts: ctx A's quiet provably leaves ctx B's data un-arrived.

use posh::collectives::ReduceOp;
use posh::ctx::CtxOptions;
use posh::pe::{PoshConfig, World};
use posh::sync::CmpOp;
use posh::util::quickcheck::{forall, Gen};

/// Random strided split parameters within `n_pes` world ranks.
fn random_split(g: &mut Gen, n_pes: usize) -> (usize, usize, usize) {
    let stride = g.usize_in(1..4);
    let max_size = (n_pes + stride - 1) / stride;
    let size = g.usize_in(1..max_size + 1);
    let max_start = n_pes - (size - 1) * stride;
    let start = g.usize_in(0..max_start);
    (start, stride, size)
}

#[test]
fn strided_split_translation_round_trips() {
    forall("strided round-trip", 30, |g: &mut Gen| {
        let n_pes = g.usize_in(2..8);
        let (start, stride, size) = random_split(g, n_pes);
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let oks = w.run_collect(move |ctx| {
            let world = ctx.team_world();
            let team = world.split_strided(start, stride, size);
            let me = ctx.my_pe();
            let expect_member = me >= start && (me - start) % stride == 0
                && (me - start) / stride < size;
            let mut ok = team.is_some() == expect_member;
            if let Some(t) = &team {
                // team → world → team is the identity.
                ok &= t.world_rank(t.my_pe()) == me;
                ok &= t.team_rank_of(me) == Some(t.my_pe());
                ok &= t.translate_pe(t.my_pe(), &world) == Some(me);
                ok &= world.translate_pe(me, t) == Some(t.my_pe());
                // Every team rank maps to a distinct member world rank.
                for r in 0..t.n_pes() {
                    ok &= t.team_rank_of(t.world_rank(r)) == Some(r);
                }
            }
            ctx.barrier_all();
            if let Some(t) = team {
                t.destroy();
            }
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("round-trip failed for split ({start},{stride},{size})"))
        }
    });
}

#[test]
fn split_2d_translation_round_trips() {
    forall("2d round-trip", 20, |g: &mut Gen| {
        let n_pes = g.usize_in(2..9);
        let xrange = g.usize_in(1..5);
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let oks = w.run_collect(move |ctx| {
            let world = ctx.team_world();
            let (x, y) = world.split_2d(xrange);
            let me = ctx.my_pe();
            let xr = xrange.min(n_pes);
            let mut ok = true;
            // Row team: contiguous, my x-rank is my column.
            ok &= x.my_pe() == me % xr;
            ok &= x.world_rank(x.my_pe()) == me;
            // Column team: stride xr, my y-rank is my row.
            ok &= y.my_pe() == me / xr;
            ok &= y.world_rank(y.my_pe()) == me;
            // The row and column teams intersect exactly at me.
            ok &= x.translate_pe(x.my_pe(), &y) == Some(y.my_pe());
            ctx.barrier_all();
            x.destroy();
            y.destroy();
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("2d round-trip failed (n={n_pes}, xrange={xrange})"))
        }
    });
}

#[test]
fn sibling_splits_partition_parent() {
    forall("sibling partition", 25, |g: &mut Gen| {
        let n_pes = g.usize_in(2..9);
        // Cut the world at a random point into [0, cut) and [cut, n).
        let cut = g.usize_in(1..n_pes);
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let memberships = w.run_collect(move |ctx| {
            let world = ctx.team_world();
            let lo = world.split_strided(0, 1, cut);
            let hi = world.split_strided(cut, 1, n_pes - cut);
            let membership = (lo.is_some(), hi.is_some());
            let my_rank = lo.as_ref().or(hi.as_ref()).map(|t| t.my_pe());
            ctx.barrier_all();
            for t in [lo, hi].into_iter().flatten() {
                t.destroy();
            }
            (membership, my_rank)
        });
        for (pe, ((in_lo, in_hi), rank)) in memberships.iter().enumerate() {
            // Exactly one sibling contains each parent rank…
            if *in_lo == *in_hi {
                return Err(format!("PE {pe} in {} siblings (cut {cut})",
                    if *in_lo { 2 } else { 0 }));
            }
            // …at the rank the partition predicts.
            let want = if pe < cut { pe } else { pe - cut };
            if *rank != Some(want) {
                return Err(format!("PE {pe}: rank {rank:?}, want {want} (cut {cut})"));
            }
        }
        Ok(())
    });
}

#[test]
fn team_reduction_matches_member_oracle() {
    forall("team reduce oracle", 20, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let (start, stride, size) = random_split(g, n_pes);
        let nreduce = g.usize_in(1..64);
        let op = g.pick(&ReduceOp::all());
        let w = World::threads(n_pes, PoshConfig::small()).unwrap();
        let results = w.run_collect(move |ctx| {
            let src = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            let dst = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = seed(ctx.my_pe(), j);
                }
            }
            ctx.barrier_all();
            let team = ctx.team_world().split_strided(start, stride, size);
            let out = if let Some(team) = &team {
                ctx.reduce_to_all(dst, src, nreduce, op, team);
                Some(unsafe { ctx.local(dst).to_vec() })
            } else {
                None
            };
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            out
        });
        let members: Vec<usize> = (0..size).map(|i| start + i * stride).collect();
        for j in 0..nreduce {
            // Serial oracle restricted to the members.
            use posh::collectives::reduce::ReduceElem;
            let mut acc = seed(members[0], j);
            for &m in &members[1..] {
                acc = i64::combine(op, acc, seed(m, j));
            }
            for &m in &members {
                let got = results[m].as_ref().unwrap()[j];
                if got != acc {
                    return Err(format!(
                        "{op:?} split ({start},{stride},{size}): PE {m} elem {j} \
                         got {got}, want {acc}"
                    ));
                }
            }
            // Non-members were never written.
            for (pe, r) in results.iter().enumerate() {
                if !members.contains(&pe) && r.is_some() {
                    return Err(format!("non-member PE {pe} ran the reduction"));
                }
            }
        }
        Ok(())
    });
}

fn seed(pe: usize, j: usize) -> i64 {
    ((pe as i64 + 5) * (j as i64 + 3)) % 23 + 2
}

/// Quiet on one context must not retire any other domain's pending NBI
/// operations — for a random number of contexts and a random interleaving
/// of issues.
#[test]
fn ctx_quiet_never_crosses_domains() {
    forall("ctx quiet isolation", 15, |g: &mut Gen| {
        let n_ctx = g.usize_in(2..5);
        let issues: Vec<usize> = (0..g.usize_in(3..20)).map(|_| g.usize_in(0..n_ctx)).collect();
        let quiesce = g.usize_in(0..n_ctx);
        let w = World::threads(2, PoshConfig::small()).unwrap();
        let issues2 = issues.clone();
        let oks = w.run_collect(move |ctx| {
            let world = ctx.team_world();
            let ctxs: Vec<_> = (0..n_ctx)
                .map(|_| world.create_ctx(CtxOptions::new()))
                .collect();
            let buf = ctx.shmalloc_n::<u64>(4).unwrap();
            let peer = (ctx.my_pe() + 1) % 2;
            let mut want = vec![0u64; n_ctx];
            for &k in &issues2 {
                ctxs[k].put_nbi(buf, &[7; 4], peer);
                want[k] += 1;
            }
            ctxs[quiesce].quiet();
            want[quiesce] = 0;
            let ok = ctxs.iter().zip(&want).all(|(c, &w)| c.pending_nbi() == w);
            for c in ctxs {
                c.destroy();
            }
            ctx.barrier_all();
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!(
                "quiet on ctx {quiesce} disturbed a sibling (issues {issues:?})"
            ))
        }
    });
}

/// The flag-after-data conformance oracle for the memory-model row
/// "`quiet` on ctx A does not complete NBI ops issued on ctx B": both PEs
/// issue a small (hence deferred) `put_nbi` on context B, quiesce context
/// A, and raise a flag. When the peer's flag arrives, B's data must **not**
/// have landed — deterministically, because deferred puts are only
/// delivered by their own context's drain. After `B.quiet()` and a second
/// flag, the data must be there.
#[test]
fn ctx_a_quiet_leaves_ctx_b_data_unarrived() {
    let w = World::threads(2, PoshConfig::small()).unwrap();
    w.run(|ctx| {
        let world = ctx.team_world();
        let a = world.create_ctx(CtxOptions::new());
        let b = world.create_ctx(CtxOptions::new());
        let data = ctx.shmalloc_n::<u64>(8).unwrap();
        let flag = ctx.shmalloc_n::<u64>(1).unwrap();
        unsafe { ctx.local_mut(data).fill(0) };
        ctx.barrier_all();
        let peer = (ctx.my_pe() + 1) % 2;
        for round in 1..=40u64 {
            b.put_nbi(data, &[round; 8], peer); // deferred on B
            a.quiet(); // must not deliver or retire B's put
            assert_eq!(b.pending_nbi(), 1, "A's quiet retired B's op");
            // Phase 1: "my B-put is issued, my A-quiet is done".
            ctx.put_one(flag, 3 * round - 2, peer);
            ctx.wait_until(flag, CmpOp::Ge, 3 * round - 2);
            let seen = unsafe { ctx.local(data).to_vec() };
            assert!(
                seen.iter().all(|&x| x != round),
                "round {round}: ctx B's deferred put arrived after only ctx A's quiet: {seen:?}"
            );
            // Phase 2: "my not-arrived check is complete" — the peer may
            // only drain B after this, or its delivery races the check.
            ctx.put_one(flag, 3 * round - 1, peer);
            ctx.wait_until(flag, CmpOp::Ge, 3 * round - 1);

            b.quiet(); // now deliver
            assert_eq!(b.pending_nbi(), 0);
            // Phase 3: "my B drain is done".
            ctx.put_one(flag, 3 * round, peer);
            ctx.wait_until(flag, CmpOp::Ge, 3 * round);
            let seen = unsafe { ctx.local(data).to_vec() };
            assert!(
                seen.iter().all(|&x| x == round),
                "round {round}: ctx B's put missing after B's quiet: {seen:?}"
            );
        }
        a.destroy();
        b.destroy();
        ctx.barrier_all();
    });
}
