//! **KV YCSB** — throughput scaling of the `posh-kv` subsystem (docs/kv.md).
//!
//! Sweeps PE count × threads-per-PE × read/write mix over a zipfian (or
//! uniform) key-popularity distribution, YCSB-style: mixes A (50/50),
//! B (95/5), C (read-only) plus the write-heavy W (5/95) stressor that
//! exercises the NBI defer/drain knobs (docs/tuning.md §NBI re-derivation).
//! Worker threads drive remote writes through their pooled per-thread
//! contexts (`Team::ctx_for_thread`), so the thread axis doubles as a
//! `SHMEM_THREAD_MULTIPLE` scaling probe.
//!
//! All logic lives in `posh::kv::driver` so `oshrun kv-bench` runs the
//! identical sweep. Results: throughput table on stdout,
//! `bench_out/kv_ycsb.csv`, `bench_out/BENCH_kv.json`. Self-check gates
//! demote to warnings with `POSH_BENCH_NO_ASSERT=1`.
//!
//! Flags: `--smoke`, `--dist uniform|zipfian`, `--mix A[,B,...]`,
//! `--keys N`, `--ops N`, `--seed N`.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = posh::kv::driver::run_cli(&args) {
        eprintln!("kv_ycsb: {e:#}");
        std::process::exit(1);
    }
}
