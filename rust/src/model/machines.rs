//! The paper's five evaluation machines as cost-model profiles (§5).
//!
//! Numbers are lifted directly from the paper's Tables 1–3: the latency rows
//! give α (ns), the bandwidth rows give the asymptotic Gb/s. The profiles
//! let every bench print the paper's predicted row next to the measured one
//! (same-shape check), and power the `machine-sim` mode of the Table
//! benches which regenerates the paper's table *values* from the profiles —
//! the honest substitute for hardware we cannot have (DESIGN.md §1).

use super::costmodel::CostModel;

/// One evaluation platform of the paper.
#[derive(Clone, Debug)]
pub struct MachineProfile {
    /// Paper's machine name.
    pub name: &'static str,
    /// CPU description from §5.1.
    pub cpu: &'static str,
    /// Socket (NUMA-node) count from §5.1 — the topology descriptor the
    /// two-level collective model prices by. 1 = flat (no cross-socket
    /// tier); the paper's NUMA boxes are Magi10 (4×) and Pastel (2×).
    pub sockets: usize,
    /// Stock-memcpy model (Table 1).
    pub memcpy: CostModel,
    /// Best tuned copy model (Table 1, best of MMX/MMX2/SSE).
    pub best_copy: CostModel,
    /// POSH put model (Table 2, best copy).
    pub posh_put: CostModel,
    /// POSH get model (Table 2, best copy).
    pub posh_get: CostModel,
    /// Berkeley UPC put model (Table 3).
    pub upc_put: CostModel,
    /// Berkeley UPC get model (Table 3).
    pub upc_get: CostModel,
}

/// All five machines of §5.1, in the paper's row order.
pub fn paper_machines() -> Vec<MachineProfile> {
    vec![
        MachineProfile {
            name: "Caire",
            cpu: "Pentium Dual-Core E5300 @ 2.60GHz",
            sockets: 1,
            memcpy: CostModel::from_alpha_gbps(38.85, 18.40),
            best_copy: CostModel::from_alpha_gbps(38.05, 18.37),
            posh_put: CostModel::from_alpha_gbps(38.40, 18.38),
            posh_get: CostModel::from_alpha_gbps(38.40, 18.36),
            upc_put: CostModel::from_alpha_gbps(37.55, 18.45),
            upc_get: CostModel::from_alpha_gbps(39.40, 18.03),
        },
        MachineProfile {
            name: "Jaune",
            cpu: "AMD Athlon 64 X2 5200+",
            sockets: 1,
            memcpy: CostModel::from_alpha_gbps(1277.90, 9.84),
            best_copy: CostModel::from_alpha_gbps(1279.90, 16.60), // SSE
            posh_put: CostModel::from_alpha_gbps(1665.90, 17.55),
            posh_get: CostModel::from_alpha_gbps(1741.85, 17.62),
            upc_put: CostModel::from_alpha_gbps(1623.90, 10.63),
            upc_get: CostModel::from_alpha_gbps(1623.90, 9.95),
        },
        MachineProfile {
            name: "Magi10",
            cpu: "4x Intel Xeon E7-4850 @ 2.00GHz (NUMA)",
            sockets: 4,
            memcpy: CostModel::from_alpha_gbps(45.40, 22.93),
            best_copy: CostModel::from_alpha_gbps(38.20, 21.13), // MMX latency best
            posh_put: CostModel::from_alpha_gbps(38.40, 20.16),
            posh_get: CostModel::from_alpha_gbps(38.40, 20.46),
            upc_put: CostModel::from_alpha_gbps(54.90, 16.33),
            upc_get: CostModel::from_alpha_gbps(73.80, 18.64),
        },
        MachineProfile {
            name: "Maximum",
            cpu: "Intel Core i7-2600 @ 3.40GHz",
            sockets: 1,
            memcpy: CostModel::from_alpha_gbps(21.70, 67.47),
            best_copy: CostModel::from_alpha_gbps(21.00, 77.91), // SSE
            posh_put: CostModel::from_alpha_gbps(38.40, 76.15),
            posh_get: CostModel::from_alpha_gbps(38.40, 74.09),
            upc_put: CostModel::from_alpha_gbps(25.00, 68.86),
            upc_get: CostModel::from_alpha_gbps(26.75, 67.45),
        },
        MachineProfile {
            name: "Pastel",
            cpu: "2x Dual-Core AMD Opteron 2218 @ 2.60GHz (NUMA)",
            sockets: 2,
            memcpy: CostModel::from_alpha_gbps(1997.30, 20.27),
            best_copy: CostModel::from_alpha_gbps(1997.35, 20.32), // MMX2
            posh_put: CostModel::from_alpha_gbps(1689.60, 25.50),
            posh_get: CostModel::from_alpha_gbps(1830.40, 26.07),
            upc_put: CostModel::from_alpha_gbps(1689.95, 25.06),
            upc_get: CostModel::from_alpha_gbps(2025.10, 23.52),
        },
    ]
}

/// The paper's qualitative claims, checkable against any profile set (the
/// §5 "shape" in DESIGN.md). Returns human-readable violations.
pub fn check_shape_claims(machines: &[MachineProfile]) -> Vec<String> {
    let mut violations = Vec::new();
    for m in machines {
        // Claim 1+2: POSH put/get ≈ memcpy — "little overhead, not to say a
        // negligible one". Interpret as: peak bandwidth within 25% of the
        // better of (stock, best tuned copy) — POSH on Jaune/Pastel actually
        // *beats* the single-threaded copy thanks to cache effects, so the
        // check is one-sided.
        let copy_peak = m.memcpy.peak_gbps().max(m.best_copy.peak_gbps());
        for (dir, cm) in [("put", &m.posh_put), ("get", &m.posh_get)] {
            if cm.peak_gbps() < 0.75 * copy_peak {
                violations.push(format!(
                    "{}: POSH {dir} peak {:.1} Gb/s below 75% of copy peak {:.1}",
                    m.name,
                    cm.peak_gbps(),
                    copy_peak
                ));
            }
        }
        // Claim 4: POSH bandwidth is within 25% of UPC's or better.
        if m.posh_put.peak_gbps() < 0.75 * m.upc_put.peak_gbps() {
            violations.push(format!(
                "{}: POSH put peak {:.1} far below UPC {:.1}",
                m.name,
                m.posh_put.peak_gbps(),
                m.upc_put.peak_gbps()
            ));
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_machines() {
        let ms = paper_machines();
        assert_eq!(ms.len(), 5);
        assert_eq!(ms[3].name, "Maximum");
    }

    #[test]
    fn topology_descriptors_match_the_cpu_strings() {
        // The sockets field is the §5.1 machine descriptions made
        // machine-readable: every profile whose CPU string carries a "Nx …
        // (NUMA)" prefix must declare N sockets, everything else is flat.
        for m in paper_machines() {
            if m.cpu.contains("(NUMA)") {
                let n: usize = m.cpu.split('x').next().unwrap().parse().unwrap();
                assert_eq!(m.sockets, n, "{}", m.name);
                assert!(m.sockets > 1, "{}", m.name);
            } else {
                assert_eq!(m.sockets, 1, "{}", m.name);
            }
        }
    }

    #[test]
    fn paper_numbers_satisfy_paper_claims() {
        // The shape-checker must accept the paper's own data.
        let v = check_shape_claims(&paper_machines());
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn maximum_is_fastest_slowest_ordering() {
        let ms = paper_machines();
        let max = ms.iter().find(|m| m.name == "Maximum").unwrap();
        let pastel = ms.iter().find(|m| m.name == "Pastel").unwrap();
        assert!(max.memcpy.peak_gbps() > pastel.memcpy.peak_gbps());
        assert!(max.memcpy.alpha_ns < pastel.memcpy.alpha_ns);
    }

    #[test]
    fn profiles_regenerate_table_values() {
        // Replaying a profile at large n must reproduce the paper's
        // bandwidth cell to within rounding.
        let ms = paper_machines();
        let max = ms.iter().find(|m| m.name == "Maximum").unwrap();
        let bw = max.posh_put.predict_gbps(64 << 20);
        assert!((bw - 76.15).abs() < 0.5, "{bw}");
    }
}
