//! Property battery for the NUMA-aware hierarchical collectives: over
//! random team shapes × random synthetic PE→socket maps (forced with
//! `PoshConfig::pes_per_socket`, so no test depends on the runner's real
//! topology), the forced two-level schedules must produce exactly what the
//! flat engines produce — here checked against the same serial oracles the
//! flat property tests use. All payloads are integers, where reduction is
//! exact under any association, so "≡ flat engine" is equality, not
//! approximation (the hierarchical schedule re-associates the combine
//! order, which is visible only in floating point).
//!
//! Safe mode stays on throughout: every `split_strided` cross-checks the
//! member-computed socket descriptor against the one the child root
//! published, so a nondeterministic leader election (different PEs deriving
//! different group shapes) panics instead of deadlocking.

use posh::collectives::{AlgoKind, ReduceOp};
use posh::pe::{PoshConfig, TeamBarrierKind, World};
use posh::util::quickcheck::{forall, Gen};

/// Random strided split parameters `(start, stride, size)` within a world
/// of `n_pes` — strides beyond 1 make the socket groups non-trivial
/// sub-intervals of the team index space.
fn random_split(g: &mut Gen, n_pes: usize) -> (usize, usize, usize) {
    let stride = g.usize_in(1..4);
    let max_size = (n_pes + stride - 1) / stride;
    let size = g.usize_in(1..max_size + 1);
    let max_start = n_pes - (size - 1) * stride;
    let start = g.usize_in(0..max_start);
    (start, stride, size)
}

fn split_members(start: usize, stride: usize, size: usize) -> Vec<usize> {
    (0..size).map(|i| start + i * stride).collect()
}

fn contrib(pe: usize, j: usize) -> i64 {
    ((pe as i64 + 3) * (j as i64 + 7)) % 41 + 1
}

fn combine(op: ReduceOp, a: i64, b: i64) -> i64 {
    use posh::collectives::reduce::ReduceElem;
    i64::combine(op, a, b)
}

/// A config that forces the two-level reduce/broadcast schedule under a
/// synthetic `pps`-wide blocked socket map, with safe mode (descriptor
/// cross-checking) on.
fn hier_cfg(pps: usize) -> PoshConfig {
    let mut cfg = PoshConfig::small();
    cfg.coll_algo = Some(AlgoKind::Hierarchical);
    cfg.pes_per_socket = Some(pps);
    cfg.safe = true;
    cfg
}

#[test]
fn hier_reduce_matches_oracle_random_topologies() {
    forall("hier reduce oracle", 25, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let (start, stride, size) = random_split(g, n_pes);
        // pps sweeps through every interesting shape: 1 (every PE its own
        // socket, all-leader), mid (real two-level splits), ≥ n (collapses
        // to one flat group).
        let pps = g.usize_in(1..n_pes + 2);
        let nreduce = g.usize_in(1..200);
        let op = g.pick(&ReduceOp::all());
        let w = World::threads(n_pes, hier_cfg(pps)).unwrap();
        let results = w.run_collect(move |ctx| {
            let src = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            let dst = ctx.shmalloc_n::<i64>(nreduce).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = contrib(ctx.my_pe(), j);
                }
                ctx.local_mut(dst).fill(i64::MIN);
            }
            ctx.barrier_all();
            let team = ctx.team_world().split_strided(start, stride, size);
            let out = if let Some(team) = &team {
                ctx.reduce_to_all(dst, src, nreduce, op, team);
                Some(unsafe { ctx.local(dst).to_vec() })
            } else {
                None
            };
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            out
        });
        let members = split_members(start, stride, size);
        for j in 0..nreduce {
            let mut acc = contrib(members[0], j);
            for &m in &members[1..] {
                acc = combine(op, acc, contrib(m, j));
            }
            for &m in &members {
                let got = results[m].as_ref().unwrap()[j];
                if got != acc {
                    return Err(format!(
                        "hier {op:?} pps={pps} split ({start},{stride},{size}) elem {j}: \
                         PE {m} got {got}, want {acc}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn hier_broadcast_matches_oracle_random_topologies() {
    forall("hier broadcast oracle", 25, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let (start, stride, size) = random_split(g, n_pes);
        let pps = g.usize_in(1..n_pes + 2);
        let nelems = g.usize_in(1..300);
        let root_idx = g.usize_in(0..size);
        let w = World::threads(n_pes, hier_cfg(pps)).unwrap();
        let results = w.run_collect(move |ctx| {
            let src = ctx.shmalloc_n::<u64>(nelems).unwrap();
            let dst = ctx.shmalloc_n::<u64>(nelems).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = (ctx.my_pe() * 1_000 + j) as u64;
                }
                ctx.local_mut(dst).fill(u64::MAX);
            }
            ctx.barrier_all();
            let team = ctx.team_world().split_strided(start, stride, size);
            let out = if let Some(team) = &team {
                ctx.broadcast(dst, src, nelems, root_idx, team);
                Some(unsafe { ctx.local(dst).to_vec() })
            } else {
                None
            };
            ctx.barrier_all();
            if let Some(team) = team {
                team.destroy();
            }
            out
        });
        let members = split_members(start, stride, size);
        let root_pe = members[root_idx];
        for &m in &members {
            let got = results[m].as_ref().unwrap();
            if m == root_pe {
                // The spec quirk survives the two-level schedule: the
                // root's own target is never written.
                if got.iter().any(|&v| v != u64::MAX) {
                    return Err(format!("hier pps={pps}: root target written"));
                }
            } else {
                for (j, &v) in got.iter().enumerate() {
                    let want = (root_pe * 1_000 + j) as u64;
                    if v != want {
                        return Err(format!(
                            "hier pps={pps} split ({start},{stride},{size}) root \
                             {root_idx}: PE {m} elem {j} = {v}, want {want}"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// The hierarchical team sync under random shapes: a barrier has no output,
/// so its oracle is the synchronization contract itself — a put issued
/// before `team.barrier()` must be visible to its target after the barrier
/// returns (barrier = quiet + sync), round after round. A broken two-level
/// release (a member let through before the root leader's epoch flip) shows
/// up as a stale read; a broken fan-in deadlocks and fails the suite's
/// timeout.
#[test]
fn hier_barrier_synchronizes_random_topologies() {
    forall("hier barrier contract", 20, |g: &mut Gen| {
        let n_pes = g.usize_in(2..7);
        let stride = g.usize_in(1..4);
        let max_size = (n_pes + stride - 1) / stride;
        let lo = 2usize.min(max_size);
        let size = g.usize_in(lo..max_size + 1);
        let max_start = n_pes - (size - 1) * stride;
        let start = g.usize_in(0..max_start);
        let pps = g.usize_in(1..n_pes + 2);
        let rounds = g.usize_in(3..12);
        let mut cfg = hier_cfg(pps);
        cfg.team_barrier = Some(TeamBarrierKind::Hierarchical);
        let w = World::threads(n_pes, cfg).unwrap();
        let oks = w.run_collect(move |ctx| {
            let world = ctx.team_world();
            let team = world.split_strided(start, stride, size);
            let mut ok = true;
            if let Some(t) = &team {
                let mailbox = ctx.shmalloc_n::<u64>(1).unwrap();
                unsafe { ctx.local_mut(mailbox)[0] = u64::MAX };
                t.barrier();
                let me = t.my_pe();
                let next = t.world_rank((me + 1) % t.n_pes());
                for round in 0..rounds as u64 {
                    ctx.put(mailbox, &[round * 10 + me as u64], next);
                    t.barrier();
                    let prev = (me + t.n_pes() - 1) % t.n_pes();
                    let got = unsafe { ctx.local(mailbox)[0] };
                    ok &= got == round * 10 + prev as u64;
                    t.barrier();
                }
                ctx.shfree(mailbox).unwrap();
            }
            ctx.barrier_all();
            if let Some(t) = team {
                t.destroy();
            }
            ctx.barrier_all();
            ok
        });
        if oks.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!(
                "hier barrier pps={pps} split ({start},{stride},{size}) let a stale \
                 read through"
            ))
        }
    });
}

/// The PE→socket map is a job-wide agreement: every rank derives the same
/// `pes_per_socket` and the same socket index for every peer — with no
/// communication. This is the determinism that makes leader election a pure
/// function (the per-team descriptor cross-check in safe mode is the
/// protocol-level face of the same property).
#[test]
fn socket_map_is_agreed_job_wide() {
    for (n_pes, pps) in [(4usize, 2usize), (5, 2), (6, 3), (6, 1), (4, 8)] {
        let w = World::threads(n_pes, hier_cfg(pps)).unwrap();
        let maps = w.run_collect(move |ctx| {
            let map: Vec<usize> = (0..ctx.n_pes()).map(|pe| ctx.socket_of(pe)).collect();
            (ctx.pes_per_socket(), map)
        });
        let effective = if pps >= n_pes { 0 } else { pps };
        for (rank, (got_pps, map)) in maps.iter().enumerate() {
            assert_eq!(*got_pps, effective, "n={n_pes} pps={pps} rank {rank}");
            assert_eq!(map, &maps[0].1, "n={n_pes} pps={pps} rank {rank}");
            for (pe, &s) in map.iter().enumerate() {
                let want = if effective == 0 { 0 } else { pe / effective };
                assert_eq!(s, want, "n={n_pes} pps={pps} rank {rank} pe {pe}");
            }
        }
    }
}

/// Forcing `Hierarchical` on an op with no two-level schedule (fcollect)
/// runs its single-protocol path — same bytes, no panic. Guards the
/// dispatch catch-all.
#[test]
fn forced_hier_on_single_protocol_ops_degenerates() {
    for (n_pes, pps) in [(4usize, 2usize), (5, 2), (3, 1)] {
        let nelems = 37usize;
        let w = World::threads(n_pes, hier_cfg(pps)).unwrap();
        let results = w.run_collect(move |ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<u32>(nelems).unwrap();
            let dst = ctx.shmalloc_n::<u32>(nelems * ctx.n_pes()).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = (ctx.my_pe() * 10_000 + j) as u32;
                }
            }
            ctx.barrier_all();
            ctx.fcollect(dst, src, nelems, &team);
            let out = unsafe { ctx.local(dst).to_vec() };
            ctx.barrier_all();
            out
        });
        for (rank, got) in results.iter().enumerate() {
            for pe in 0..n_pes {
                for j in 0..nelems {
                    assert_eq!(
                        got[pe * nelems + j],
                        (pe * 10_000 + j) as u32,
                        "n={n_pes} pps={pps} rank {rank} block {pe} elem {j}"
                    );
                }
            }
        }
    }
}

/// Heap symmetry after hierarchical reductions: the Lemma-1 staging
/// temporaries (root slots and leader slots both) are freed before the
/// collective exits, so the live-allocation count is back to exactly the
/// two user buffers on every PE.
#[test]
fn hier_reduce_frees_all_staging() {
    for pps in [1usize, 2, 3, 8] {
        let w = World::threads(6, hier_cfg(pps)).unwrap();
        let counts = w.run_collect(move |ctx| {
            let team = ctx.team_world();
            let src = ctx.shmalloc_n::<i64>(100).unwrap();
            let dst = ctx.shmalloc_n::<i64>(100).unwrap();
            unsafe {
                for (j, s) in ctx.local_mut(src).iter_mut().enumerate() {
                    *s = contrib(ctx.my_pe(), j);
                }
            }
            ctx.barrier_all();
            for _ in 0..3 {
                ctx.reduce_to_all(dst, src, 100, ReduceOp::Sum, &team);
            }
            ctx.barrier_all();
            ctx.heap().live_allocations()
        });
        for (rank, &live) in counts.iter().enumerate() {
            assert_eq!(live, 2, "pps={pps} rank {rank}: staging leaked");
        }
    }
}
