//! Named locks — the paper's Boost *named mutex* usage (§4.6).
//!
//! "Each process uses the same given name for a given chunk of data on a
//! given symmetric heap. Using a mutex that locally has the same name as all
//! the other local mutexes, processes ensure mutual exclusion."
//!
//! POSH-RS realises a named mutex as a slot in the per-PE
//! [`crate::symheap::layout::HeapHeader::named_locks`] table: the name is
//! hashed to a slot index, and the *target heap's* slot arbitrates access to
//! that heap's data — a lock **specific to a given symmetric heap**, exactly
//! as in the paper. Slots are ticket locks (same word protocol as the spec
//! lock, but homed on the named heap rather than PE 0).
//!
//! Hash collisions between names are benign for correctness (two names
//! sharing a slot serialise against each other — stricter, never weaker);
//! [`slot_of`] is exposed so tests can construct colliding names on purpose.

use crate::pe::Ctx;
use crate::symheap::layout::NAMED_LOCK_SLOTS;
use std::sync::atomic::Ordering;

const TICKET_ONE: u64 = 1 << 32;
const SERVING_MASK: u64 = 0xFFFF_FFFF;

/// FNV-1a hash of the name, reduced to a slot index.
pub fn slot_of(name: &str) -> usize {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (h % NAMED_LOCK_SLOTS as u64) as usize
}

/// RAII guard for a named lock; releases on drop.
pub struct NamedLockGuard<'a> {
    ctx: &'a Ctx,
    heap_pe: usize,
    slot: usize,
}

impl Drop for NamedLockGuard<'_> {
    fn drop(&mut self) {
        self.ctx.quiet();
        self.ctx.header_of(self.heap_pe).named_locks[self.slot].fetch_add(1, Ordering::AcqRel);
    }
}

impl Ctx {
    /// Acquire the named lock guarding data on `heap_pe`'s symmetric heap.
    /// Blocks until granted; returns a guard that releases on drop.
    pub fn named_lock<'a>(&'a self, name: &str, heap_pe: usize) -> NamedLockGuard<'a> {
        assert!(heap_pe < self.n_pes(), "heap PE out of range");
        let slot = slot_of(name);
        let cell = &self.header_of(heap_pe).named_locks[slot];
        let prev = cell.fetch_add(TICKET_ONE, Ordering::AcqRel);
        let my_ticket = prev >> 32;
        if (prev & SERVING_MASK) != my_ticket {
            self.spin_wait(|| (cell.load(Ordering::Acquire) & SERVING_MASK) == my_ticket);
        }
        std::sync::atomic::fence(Ordering::Acquire);
        NamedLockGuard { ctx: self, heap_pe, slot }
    }

    /// Try to acquire without blocking; `None` if the lock is busy.
    pub fn try_named_lock<'a>(&'a self, name: &str, heap_pe: usize) -> Option<NamedLockGuard<'a>> {
        let slot = slot_of(name);
        let cell = &self.header_of(heap_pe).named_locks[slot];
        let cur = cell.load(Ordering::Acquire);
        if (cur >> 32) != (cur & SERVING_MASK) {
            return None;
        }
        if cell
            .compare_exchange(cur, cur + TICKET_ONE, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            std::sync::atomic::fence(Ordering::Acquire);
            Some(NamedLockGuard { ctx: self, heap_pe, slot })
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pe::{PoshConfig, World};

    #[test]
    fn slots_stable_and_in_range() {
        for name in ["a", "b", "counter", "table/7", ""] {
            let s = slot_of(name);
            assert!(s < NAMED_LOCK_SLOTS);
            assert_eq!(s, slot_of(name));
        }
    }

    #[test]
    fn named_mutual_exclusion() {
        let n = 4;
        let iters = 250u64;
        let w = World::threads(n, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let shared = ctx.shmalloc_n::<u64>(1).unwrap();
            for _ in 0..iters {
                let _g = ctx.named_lock("shared-counter", 0);
                let v = ctx.get_one(shared, 0);
                ctx.put_one(shared, v + 1, 0);
            }
            ctx.barrier_all();
            if ctx.my_pe() == 0 {
                assert_eq!(ctx.get_one(shared, 0), n as u64 * iters);
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn distinct_heaps_independent() {
        // The same name on different heaps must be two different locks.
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            if ctx.my_pe() == 0 {
                let _g0 = ctx.named_lock("x", 0);
                // Lock "x" on heap 1 must still be free.
                let g1 = ctx.try_named_lock("x", 1);
                assert!(g1.is_some());
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn try_lock_reports_busy() {
        let w = World::threads(2, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            let flag = ctx.shmalloc_n::<u64>(1).unwrap();
            if ctx.my_pe() == 0 {
                let _g = ctx.named_lock("busy-test", 0);
                ctx.put_one(flag, 1, 1);
                ctx.wait_until(flag, crate::sync::CmpOp::Eq, 2);
            } else {
                ctx.wait_until(flag, crate::sync::CmpOp::Eq, 1);
                assert!(ctx.try_named_lock("busy-test", 0).is_none());
                ctx.put_one(flag, 2, 0); // signal the waiter (PE 0)
            }
            ctx.barrier_all();
        });
    }

    #[test]
    fn guard_drop_releases() {
        let w = World::threads(1, PoshConfig::small()).unwrap();
        w.run(|ctx| {
            {
                let _g = ctx.named_lock("rel", 0);
            }
            // Immediately re-acquirable.
            assert!(ctx.try_named_lock("rel", 0).is_some());
        });
    }
}
