//! The artifacts manifest: a flat JSON object written by `aot.py` mapping
//! model hyper-parameters and artifact file names.
//!
//! The vendored registry has no `serde`, so a ~100-line parser for the flat
//! subset we emit (string keys; string / integer / float values) lives here.
//! `aot.py` guarantees flatness.

use crate::Result;
use anyhow::{bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A flat JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    /// String.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
}

impl JsonValue {
    /// As integer (floats with zero fraction coerce).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            JsonValue::Int(v) => Some(*v),
            JsonValue::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    /// As float.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            JsonValue::Int(v) => Some(*v as f64),
            JsonValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parse a flat JSON object.
pub fn parse_flat_json(src: &str) -> Result<BTreeMap<String, JsonValue>> {
    let mut out = BTreeMap::new();
    let b: Vec<char> = src.chars().collect();
    let mut i = 0usize;
    let skip_ws = |b: &Vec<char>, i: &mut usize| {
        while *i < b.len() && b[*i].is_whitespace() {
            *i += 1;
        }
    };
    let parse_string = |b: &Vec<char>, i: &mut usize| -> Result<String> {
        if b.get(*i) != Some(&'"') {
            bail!("expected '\"' at char {}", i);
        }
        *i += 1;
        let mut s = String::new();
        while *i < b.len() && b[*i] != '"' {
            if b[*i] == '\\' && *i + 1 < b.len() {
                *i += 1;
                s.push(match b[*i] {
                    'n' => '\n',
                    't' => '\t',
                    c => c,
                });
            } else {
                s.push(b[*i]);
            }
            *i += 1;
        }
        if *i >= b.len() {
            bail!("unterminated string");
        }
        *i += 1;
        Ok(s)
    };
    skip_ws(&b, &mut i);
    if b.get(i) != Some(&'{') {
        bail!("manifest must be a JSON object");
    }
    i += 1;
    loop {
        skip_ws(&b, &mut i);
        if b.get(i) == Some(&'}') {
            break;
        }
        let key = parse_string(&b, &mut i)?;
        skip_ws(&b, &mut i);
        if b.get(i) != Some(&':') {
            bail!("expected ':' after key {key:?}");
        }
        i += 1;
        skip_ws(&b, &mut i);
        let val = match b.get(i) {
            Some(&'"') => JsonValue::Str(parse_string(&b, &mut i)?),
            Some(c) if c.is_ascii_digit() || *c == '-' => {
                let start = i;
                while i < b.len()
                    && (b[i].is_ascii_digit()
                        || b[i] == '-'
                        || b[i] == '+'
                        || b[i] == '.'
                        || b[i] == 'e'
                        || b[i] == 'E')
                {
                    i += 1;
                }
                let text: String = b[start..i].iter().collect();
                if text.contains(['.', 'e', 'E']) {
                    JsonValue::Float(text.parse().context("bad float")?)
                } else {
                    JsonValue::Int(text.parse().context("bad int")?)
                }
            }
            other => bail!("unsupported JSON value starting with {other:?} (manifest is flat)"),
        };
        out.insert(key, val);
        skip_ws(&b, &mut i);
        match b.get(i) {
            Some(&',') => i += 1,
            Some(&'}') => break,
            other => bail!("expected ',' or '}}', got {other:?}"),
        }
    }
    Ok(out)
}

/// The parsed artifacts manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    dir: PathBuf,
    fields: BTreeMap<String, JsonValue>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Ok(Manifest { dir, fields: parse_flat_json(&text)? })
    }

    /// Construct from already-parsed fields (tests).
    pub fn from_fields(dir: PathBuf, fields: BTreeMap<String, JsonValue>) -> Manifest {
        Manifest { dir, fields }
    }

    /// The artifacts directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Integer field.
    pub fn int(&self, key: &str) -> Result<i64> {
        self.fields
            .get(key)
            .and_then(|v| v.as_int())
            .with_context(|| format!("manifest missing integer field {key:?}"))
    }

    /// Float field.
    pub fn float(&self, key: &str) -> Result<f64> {
        self.fields
            .get(key)
            .and_then(|v| v.as_float())
            .with_context(|| format!("manifest missing float field {key:?}"))
    }

    /// String field.
    pub fn str(&self, key: &str) -> Result<&str> {
        self.fields
            .get(key)
            .and_then(|v| v.as_str())
            .with_context(|| format!("manifest missing string field {key:?}"))
    }

    /// Resolve an artifact path field relative to the manifest directory.
    pub fn artifact_path(&self, key: &str) -> Result<PathBuf> {
        Ok(self.dir.join(self.str(key)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_object() {
        let m = parse_flat_json(
            r#"{ "a": 1, "b": -2.5, "c": "hello", "d": "x.hlo.txt", "e": 1e3 }"#,
        )
        .unwrap();
        assert_eq!(m["a"], JsonValue::Int(1));
        assert_eq!(m["b"], JsonValue::Float(-2.5));
        assert_eq!(m["c"].as_str(), Some("hello"));
        assert_eq!(m["e"].as_float(), Some(1000.0));
    }

    #[test]
    fn rejects_nested() {
        assert!(parse_flat_json(r#"{"a": {"b": 1}}"#).is_err());
        assert!(parse_flat_json(r#"{"a": [1,2]}"#).is_err());
        assert!(parse_flat_json(r#"not json"#).is_err());
    }

    #[test]
    fn escaped_strings() {
        let m = parse_flat_json(r#"{"k": "a\"b\nc"}"#).unwrap();
        assert_eq!(m["k"].as_str(), Some("a\"b\nc"));
    }

    #[test]
    fn manifest_accessors() {
        let mut f = BTreeMap::new();
        f.insert("n".into(), JsonValue::Int(42));
        f.insert("lr".into(), JsonValue::Float(0.1));
        f.insert("train_step".into(), JsonValue::Str("ts.hlo.txt".into()));
        let m = Manifest::from_fields("/tmp/arts".into(), f);
        assert_eq!(m.int("n").unwrap(), 42);
        assert!((m.float("lr").unwrap() - 0.1).abs() < 1e-12);
        assert_eq!(
            m.artifact_path("train_step").unwrap(),
            PathBuf::from("/tmp/arts/ts.hlo.txt")
        );
        assert!(m.int("missing").is_err());
    }

    #[test]
    fn load_missing_dir_errors_helpfully() {
        let e = Manifest::load("/nonexistent/arts").unwrap_err();
        assert!(e.to_string().contains("make artifacts"));
    }
}
