//! Declaration recognition for the pre-parser: find file-scope objects,
//! compute size/alignment from the C type, detect initialisers (data vs BSS
//! segment, §4.2 / fig. 1).

use super::lexer::{strip_comments_and_strings, tokenize, Tok};

/// The subset of C object types the pre-parser sizes (LP64 model, matching
/// the paper's x86-64 Linux platforms).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CType {
    /// `char` / `signed char` / `unsigned char`
    Char,
    /// `short`
    Short,
    /// `int` / `unsigned`
    Int,
    /// `long` / `unsigned long` / `size_t`-ish
    Long,
    /// `long long`
    LongLong,
    /// `float`
    Float,
    /// `double`
    Double,
    /// `long double` (x86-64 SysV: 16 bytes)
    LongDouble,
    /// Any pointer (`T*`)
    Pointer,
}

impl CType {
    /// Size in bytes (LP64).
    pub fn size(&self) -> usize {
        match self {
            CType::Char => 1,
            CType::Short => 2,
            CType::Int | CType::Float => 4,
            CType::Long | CType::LongLong | CType::Double | CType::Pointer => 8,
            CType::LongDouble => 16,
        }
    }

    /// Natural alignment (LP64: == size).
    pub fn align(&self) -> usize {
        self.size()
    }

    /// C spelling (for generated code).
    pub fn c_name(&self) -> &'static str {
        match self {
            CType::Char => "char",
            CType::Short => "short",
            CType::Int => "int",
            CType::Long => "long",
            CType::LongLong => "long long",
            CType::Float => "float",
            CType::Double => "double",
            CType::LongDouble => "long double",
            CType::Pointer => "void*",
        }
    }
}

/// One recognised file-scope static/global object.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StaticDecl {
    /// Variable name.
    pub name: String,
    /// Element type.
    pub ty: CType,
    /// Array element count (1 for scalars; product for multi-dim).
    pub count: usize,
    /// Had an initialiser ⇒ lives in the data segment, else BSS (§4.2).
    pub initialized: bool,
    /// Declared `static` (the paper's primary target) vs plain global.
    pub is_static: bool,
}

impl StaticDecl {
    /// Total byte size.
    pub fn byte_size(&self) -> usize {
        self.ty.size() * self.count
    }

    /// Required alignment.
    pub fn align(&self) -> usize {
        self.ty.align()
    }
}

/// Extract the file-scope object declarations from a C source.
///
/// Function bodies (and any other `{…}` block) are skipped wholesale, so
/// local statics stay local — the paper's tool targets *global* statics; and
/// function definitions/prototypes are rejected by the `(`-lookahead.
pub fn parse_declarations(src: &str) -> Vec<StaticDecl> {
    let stripped = strip_comments_and_strings(src);
    let toks = tokenize(&stripped);
    let mut out = Vec::new();
    let mut i = 0;
    let mut depth = 0usize;
    while i < toks.len() {
        match &toks[i] {
            Tok::Punct('{') => {
                depth += 1;
                i += 1;
            }
            Tok::Punct('}') => {
                depth = depth.saturating_sub(1);
                i += 1;
            }
            _ if depth > 0 => {
                i += 1;
            }
            _ => {
                // At file scope: try to parse one declaration statement,
                // ending at ';' (or skip to next ';'/'{' on no-match).
                let (consumed, decls) = parse_one(&toks[i..]);
                out.extend(decls);
                i += consumed;
            }
        }
    }
    out
}

/// Parse one file-scope statement starting at `toks[0]`.
/// Returns (tokens consumed, declarations found).
fn parse_one(toks: &[Tok]) -> (usize, Vec<StaticDecl>) {
    let mut i = 0;
    let mut is_static = false;
    let mut is_extern = false;
    let mut is_typedef = false;
    let mut base: Option<CType> = None;
    let mut longs = 0usize;
    let mut unsigned_or_signed = false;

    // Qualifier/type-specifier run.
    while i < toks.len() {
        let Tok::Ident(w) = &toks[i] else { break };
        match w.as_str() {
            "static" => is_static = true,
            "extern" => is_extern = true,
            "typedef" => is_typedef = true,
            "const" | "volatile" | "register" | "inline" => {}
            "unsigned" | "signed" => unsigned_or_signed = true,
            "char" => base = Some(CType::Char),
            "short" => base = Some(CType::Short),
            "int" => {
                if base.is_none() {
                    base = Some(CType::Int)
                }
            }
            "long" => longs += 1,
            "float" => base = Some(CType::Float),
            "double" => base = Some(CType::Double),
            "struct" | "union" | "enum" => {
                // Unsupported aggregate: skip this statement entirely.
                return (skip_statement(toks), Vec::new());
            }
            _ => break, // declarator name (or unknown type — handled below)
        }
        i += 1;
    }
    // Resolve long/double combinations.
    let ty = match (base, longs) {
        (Some(CType::Double), l) if l >= 1 => Some(CType::LongDouble),
        (Some(t), 0) => Some(t),
        (Some(CType::Int), 1) | (None, 1) => Some(CType::Long),
        (Some(CType::Int), l) | (None, l) if l >= 2 => Some(CType::LongLong),
        (None, 0) if unsigned_or_signed => Some(CType::Int),
        _ => base,
    };
    let Some(mut ty) = ty else {
        return (skip_statement(toks), Vec::new());
    };
    if is_typedef || is_extern {
        return (skip_statement(toks), Vec::new());
    }

    // Declarator list: [*…] name [\[N\]…] [= init] {, …} ;
    let mut decls = Vec::new();
    loop {
        // Pointer stars.
        let mut is_ptr = false;
        while matches!(toks.get(i), Some(Tok::Punct('*'))) {
            is_ptr = true;
            i += 1;
        }
        let Some(Tok::Ident(name)) = toks.get(i) else {
            return (skip_statement(toks), decls);
        };
        let name = name.clone();
        i += 1;
        // Function definition/prototype? Not an object — skip statement
        // (and its body if any).
        if matches!(toks.get(i), Some(Tok::Punct('('))) {
            return (skip_statement(toks), decls);
        }
        // Array dimensions.
        let mut count = 1usize;
        let mut dims = 0;
        while matches!(toks.get(i), Some(Tok::Punct('['))) {
            i += 1;
            let mut dim = 0usize;
            if let Some(Tok::Int(v)) = toks.get(i) {
                dim = *v as usize;
                i += 1;
            }
            // Constant-expression dims (e.g. [N*2]) are skipped to ']'.
            while !matches!(toks.get(i), Some(Tok::Punct(']')) | None) {
                i += 1;
            }
            i += 1; // ']'
            dims += 1;
            count = count.saturating_mul(dim.max(if dims == 1 { 0 } else { 1 }));
        }
        // Initialiser?
        let mut initialized = false;
        let mut init_items = 0usize;
        if matches!(toks.get(i), Some(Tok::Punct('='))) {
            initialized = true;
            i += 1;
            if matches!(toks.get(i), Some(Tok::Punct('{'))) {
                // Count top-level items in the brace initialiser.
                let mut d = 0usize;
                loop {
                    match toks.get(i) {
                        Some(Tok::Punct('{')) => {
                            d += 1;
                            if d == 1 {
                                init_items = 1;
                            }
                            i += 1;
                        }
                        Some(Tok::Punct('}')) => {
                            d -= 1;
                            i += 1;
                            if d == 0 {
                                break;
                            }
                        }
                        Some(Tok::Punct(',')) => {
                            if d == 1 {
                                init_items += 1;
                            }
                            i += 1;
                        }
                        None => break,
                        _ => i += 1,
                    }
                }
            } else {
                // Scalar initialiser: skip to ',' or ';'.
                while !matches!(toks.get(i), Some(Tok::Punct(',')) | Some(Tok::Punct(';')) | None)
                {
                    i += 1;
                }
            }
        }
        // `int a[] = {1,2,3}` — derive the dimension from the initialiser.
        if dims > 0 && count == 0 && init_items > 0 {
            count = init_items;
        }
        if dims == 0 {
            count = 1;
        }
        if is_ptr {
            ty = CType::Pointer;
        }
        if count > 0 {
            decls.push(StaticDecl { name, ty: ty.clone(), count, initialized, is_static });
        }
        match toks.get(i) {
            Some(Tok::Punct(',')) => {
                i += 1;
                continue;
            }
            Some(Tok::Punct(';')) => {
                i += 1;
                break;
            }
            _ => {
                return (skip_statement(toks), decls);
            }
        }
    }
    (i, decls)
}

/// Skip to just past the next top-level `;`, or past a brace block (function
/// body) if one opens first.
fn skip_statement(toks: &[Tok]) -> usize {
    let mut i = 0;
    let mut depth = 0usize;
    while i < toks.len() {
        match toks[i] {
            Tok::Punct('{') => depth += 1,
            Tok::Punct('}') => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    return i + 1;
                }
            }
            Tok::Punct(';') if depth == 0 => return i + 1,
            _ => {}
        }
        i += 1;
    }
    i.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_static() {
        let d = parse_declarations("static int counter;");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].name, "counter");
        assert_eq!(d[0].ty, CType::Int);
        assert_eq!(d[0].count, 1);
        assert!(!d[0].initialized);
        assert!(d[0].is_static);
        assert_eq!(d[0].byte_size(), 4);
    }

    #[test]
    fn array_with_size() {
        let d = parse_declarations("static double table[100];");
        assert_eq!(d[0].byte_size(), 800);
        assert_eq!(d[0].align(), 8);
    }

    #[test]
    fn multidim_array() {
        let d = parse_declarations("static float m[4][8];");
        assert_eq!(d[0].count, 32);
        assert_eq!(d[0].byte_size(), 128);
    }

    #[test]
    fn initialized_goes_to_data_segment() {
        let d = parse_declarations("static long x = 42;");
        assert!(d[0].initialized);
    }

    #[test]
    fn array_size_from_initializer() {
        let d = parse_declarations("static int a[] = {1, 2, 3, 4, 5};");
        assert_eq!(d[0].count, 5);
        assert!(d[0].initialized);
    }

    #[test]
    fn multiple_declarators() {
        let d = parse_declarations("static int a, b = 2, c[3];");
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].name, "a");
        assert!(!d[0].initialized);
        assert!(d[1].initialized);
        assert_eq!(d[2].count, 3);
    }

    #[test]
    fn long_variants() {
        let d = parse_declarations(
            "static long a; static long long b; static unsigned long c; static long double e;",
        );
        assert_eq!(d[0].ty, CType::Long);
        assert_eq!(d[1].ty, CType::LongLong);
        assert_eq!(d[2].ty, CType::Long);
        assert_eq!(d[3].ty, CType::LongDouble);
        assert_eq!(d[3].byte_size(), 16);
    }

    #[test]
    fn pointers() {
        let d = parse_declarations("static char *msg; static int **pp;");
        assert_eq!(d[0].ty, CType::Pointer);
        assert_eq!(d[1].ty, CType::Pointer);
        assert_eq!(d[0].byte_size(), 8);
    }

    #[test]
    fn functions_and_locals_ignored() {
        let src = r#"
            static int global_hits;
            int main(void) {
                static int local_counter = 0;
                int x = 1;
                return x;
            }
            static void helper(int a) { static double inner[4]; }
            static long after_fn;
        "#;
        let d = parse_declarations(src);
        let names: Vec<&str> = d.iter().map(|d| d.name.as_str()).collect();
        assert_eq!(names, vec!["global_hits", "after_fn"]);
    }

    #[test]
    fn extern_and_typedef_ignored() {
        let d = parse_declarations("extern int shared_x; typedef long mytime_t; static int y;");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].name, "y");
    }

    #[test]
    fn structs_skipped_cleanly() {
        let d =
            parse_declarations("struct point { int x; int y; }; static struct point p; static int q;");
        // `p` is unsupported (aggregate) but `q` must still be found.
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].name, "q");
    }

    #[test]
    fn comments_and_strings_do_not_confuse() {
        let src = r#"
            // static int fake1;
            /* static int fake2[10]; */
            const char* s = "static int fake3;";
            static int real;
        "#;
        let d = parse_declarations(src);
        // `s` is a real file-scope global pointer; only the commented/string
        // "declarations" must be ignored.
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].name, "s");
        assert_eq!(d[0].ty, CType::Pointer);
        assert_eq!(d[1].name, "real");
    }

    #[test]
    fn plain_globals_also_found() {
        let d = parse_declarations("int world_visible[8];");
        assert_eq!(d.len(), 1);
        assert!(!d[0].is_static);
        assert_eq!(d[0].count, 8);
    }
}
