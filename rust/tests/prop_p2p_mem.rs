//! Property tests over the data-movement layer: copy engines, strided
//! transfers, put/get composition, and the UPC baseline — all against
//! byte-exact oracles.

use posh::baseline::upc::{Consistency, UpcWorld};
use posh::mem::copy::{copy_slice_with, CopyImpl};
use posh::mem::plan::{CacheInfo, CopyPlan};
use posh::pe::{PoshConfig, World};
use posh::util::quickcheck::{forall, Gen};

/// Every engine must be byte-identical to the stock copy on arbitrary
/// lengths and (simulated) alignments.
#[test]
fn all_engines_agree_with_stock() {
    forall("engines agree", 150, |g: &mut Gen| {
        let data = g.bytes(0..20_000);
        let head = g.usize_in(0..16.min(data.len() + 1));
        let src = &data[head..];
        for imp in CopyImpl::available() {
            let mut dst = vec![0u8; src.len()];
            copy_slice_with(imp, &mut dst, src);
            if dst != src {
                return Err(format!("{imp:?} corrupted {} bytes (head {head})", src.len()));
            }
        }
        Ok(())
    });
}

/// Size-aware planned dispatch is byte-identical to the stock copy for
/// random plans (random thresholds over the machine's real engines) and
/// random lengths/alignments — the plan may only ever change speed, never
/// bytes. Plans are built locally (not installed globally) so the property
/// runs in parallel with the rest of the battery.
#[test]
fn planned_dispatch_matches_stock() {
    let machine = CopyPlan::for_machine(&CacheInfo::detect());
    forall("planned == stock", 120, |g: &mut Gen| {
        let small_max = g.usize_in(0..4096);
        let nt_min = (small_max + 1).max(g.usize_in(1..40_000));
        let plan = CopyPlan { small_max, nt_min, ..machine };
        let data = g.bytes(0..40_000);
        let head = g.usize_in(0..16.min(data.len() + 1));
        let src = &data[head..];
        let imp = plan.engine_for(src.len());
        let mut dst = vec![0u8; src.len()];
        copy_slice_with(imp, &mut dst, src);
        if dst != src {
            return Err(format!(
                "plan(small_max={small_max}, nt_min={nt_min}) -> {imp:?} corrupted {} bytes",
                src.len()
            ));
        }
        Ok(())
    });
}

/// iput/iget round-trip: scatter with stride, gather with the same stride,
/// recover the original.
#[test]
fn strided_roundtrip_recovers_original() {
    let w = World::threads(2, PoshConfig::small()).unwrap();
    forall("iput/iget roundtrip", 60, |g: &mut Gen| {
        let nelems = g.usize_in(1..200);
        let dst_stride = g.usize_in(1..5);
        let src_stride = g.usize_in(1..5);
        let ok = w.run_collect(move |ctx| {
            let span = (nelems - 1) * dst_stride + 1;
            let remote = ctx.shmalloc_n::<i64>(span).unwrap();
            let mut ok = true;
            if ctx.my_pe() == 0 {
                let src: Vec<i64> =
                    (0..(nelems - 1) * src_stride + 1).map(|i| i as i64 * 7 - 3).collect();
                ctx.iput(remote, &src, dst_stride, src_stride, nelems, 1);
                // Gather back with the transposed strides.
                let mut back = vec![0i64; (nelems - 1) * src_stride + 1];
                ctx.iget(&mut back, remote, src_stride, dst_stride, nelems, 1);
                for i in 0..nelems {
                    ok &= back[i * src_stride] == src[i * src_stride];
                }
            }
            ctx.barrier_all();
            ctx.shfree(remote).unwrap();
            ok
        });
        if ok.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("roundtrip lost data (n={nelems}, dst={dst_stride}, src={src_stride})"))
        }
    });
}

/// put followed by get from a third PE observes exactly the written bytes
/// (after a barrier) for random sizes/offsets.
#[test]
fn put_get_third_party_consistency() {
    let w = World::threads(3, PoshConfig::small()).unwrap();
    forall("3-party put/get", 40, |g: &mut Gen| {
        let len = g.usize_in(1..5000);
        let off = g.usize_in(0..64);
        let seed = g.usize_in(0..1_000_000) as u64;
        let ok = w.run_collect(move |ctx| {
            let buf = ctx.shmalloc_n::<u8>(len + off).unwrap();
            let view = buf.slice(off, len);
            let mut expect = vec![0u8; len];
            let mut r = posh::util::prng::Rng::new(seed);
            r.fill_bytes(&mut expect);
            if ctx.my_pe() == 0 {
                ctx.put(view, &expect, 1); // 0 writes PE 1
            }
            ctx.barrier_all();
            let mut ok = true;
            if ctx.my_pe() == 2 {
                let mut got = vec![0u8; len];
                ctx.get(&mut got, view, 1); // 2 reads PE 1
                ok = got == expect;
            }
            ctx.barrier_all();
            ctx.shfree(buf).unwrap();
            ok
        });
        if ok.iter().all(|&b| b) {
            Ok(())
        } else {
            Err(format!("third-party get diverged (len {len}, off {off})"))
        }
    });
}

/// UPC baseline: memput/memget/memcpy against a Vec oracle for random
/// programs of operations.
#[test]
fn upc_baseline_matches_oracle() {
    forall("upc oracle", 40, |g: &mut Gen| {
        let threads = g.usize_in(1..4);
        let seg = 1 << 14;
        let w = UpcWorld::new(threads, seg).unwrap();
        // Oracle: a plain Vec per thread.
        let mut oracle: Vec<Vec<u8>> = vec![vec![0u8; seg]; threads];
        for _ in 0..g.usize_in(1..40) {
            let t = g.usize_in(0..threads);
            let len = g.usize_in(1..512);
            let off = g.usize_in(0..seg - len);
            let mode = if g.bool(0.3) { Consistency::Strict } else { Consistency::Relaxed };
            match g.usize_in(0..3) {
                0 => {
                    let data = g.bytes(len..len + 1);
                    w.memput(w.global_ptr(t, off), &data, mode);
                    oracle[t][off..off + len].copy_from_slice(&data);
                }
                1 => {
                    let mut got = vec![0u8; len];
                    w.memget(&mut got, w.global_ptr(t, off), mode);
                    if got != oracle[t][off..off + len] {
                        return Err(format!("memget diverged at t{t} off {off} len {len}"));
                    }
                }
                _ => {
                    let t2 = g.usize_in(0..threads);
                    let off2 = g.usize_in(0..seg - len);
                    // Skip same-thread overlapping windows: UPC's memcpy has
                    // memmove semantics but our Vec oracle models distinct
                    // windows only.
                    if t == t2 && (off.max(off2) - off.min(off2)) < len {
                        continue;
                    }
                    w.memcpy(w.global_ptr(t2, off2), w.global_ptr(t, off), len, mode);
                    let src: Vec<u8> = oracle[t][off..off + len].to_vec();
                    oracle[t2][off2..off2 + len].copy_from_slice(&src);
                }
            }
        }
        Ok(())
    });
}

/// Locks under randomized critical-section lengths still give exact counts.
#[test]
fn lock_mutual_exclusion_randomized() {
    forall("lock excl", 8, |g: &mut Gen| {
        let n = g.usize_in(2..5);
        let iters = g.usize_in(20..120);
        let w = World::threads(n, PoshConfig::small()).unwrap();
        let totals = w.run_collect(move |ctx| {
            let lock = ctx.shmalloc_n::<i64>(1).unwrap();
            let cell = ctx.shmalloc_n::<u64>(1).unwrap();
            let mut local_rng = posh::util::prng::Rng::for_pe(9, ctx.my_pe());
            for _ in 0..iters {
                ctx.with_lock(lock, || {
                    let v = ctx.get_one(cell, 0);
                    // Variable-length critical section.
                    for _ in 0..local_rng.next_below(64) {
                        std::hint::spin_loop();
                    }
                    ctx.put_one(cell, v + 1, 0);
                });
            }
            ctx.barrier_all();
            ctx.get_one(cell, 0)
        });
        let want = (n * iters) as u64;
        if totals.iter().all(|&t| t == want) {
            Ok(())
        } else {
            Err(format!("lost updates: {totals:?}, want {want}"))
        }
    });
}
