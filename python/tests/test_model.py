"""Layer-2 correctness: shapes, flattening round-trip, loss semantics, and a
short pure-JAX training run (the loss must actually fall — same signal the
Rust e2e driver asserts through the artifacts)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = model.ModelConfig(
    vocab=64, d_model=32, n_heads=2, d_ff=64, n_layers=2, seq=16, batch=4
)


def test_param_count_matches_shapes():
    total = sum(int(np.prod(s)) for _, s in model.param_shapes(CFG))
    assert model.param_count(CFG) == total
    flat = model.init_params(CFG, jax.random.PRNGKey(0))
    assert flat.shape == (total,)
    assert flat.dtype == jnp.float32


def test_unflatten_roundtrip():
    flat = model.init_params(CFG, jax.random.PRNGKey(1))
    p = model.unflatten(CFG, flat)
    # Re-concatenate in spec order == original.
    rebuilt = jnp.concatenate([p[name].ravel() for name, _ in model.param_shapes(CFG)])
    np.testing.assert_array_equal(np.asarray(rebuilt), np.asarray(flat))
    # Layer-norm gains start at exactly 1.
    np.testing.assert_array_equal(np.asarray(p["lnf_g"]), np.ones(CFG.d_model))


def test_forward_shape_and_finite():
    flat = model.init_params(CFG, jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (CFG.batch, CFG.seq), 0, CFG.vocab)
    logits = model.forward(CFG, flat, toks)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_initial_loss_near_uniform():
    # Untrained model ⇒ cross-entropy ≈ ln(vocab).
    flat = model.init_params(CFG, jax.random.PRNGKey(4))
    toks = jax.random.randint(jax.random.PRNGKey(5), (CFG.batch, CFG.seq), 0, CFG.vocab)
    loss = model.loss_fn(CFG, flat, toks)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0, float(loss)


def test_loss_matches_reference_xent():
    flat = model.init_params(CFG, jax.random.PRNGKey(6))
    toks = jax.random.randint(jax.random.PRNGKey(7), (CFG.batch, CFG.seq), 0, CFG.vocab)
    logits = model.forward(CFG, flat, toks)
    want = ref.softmax_xent_ref(
        logits[:, :-1, :].reshape(-1, CFG.vocab), toks[:, 1:].reshape(-1)
    )
    got = model.loss_fn(CFG, flat, toks)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_grads_shape_and_nonzero():
    flat = model.init_params(CFG, jax.random.PRNGKey(8))
    toks = jax.random.randint(jax.random.PRNGKey(9), (CFG.batch, CFG.seq), 0, CFG.vocab)
    loss, grads = model.train_step(CFG, flat, toks)
    assert grads.shape == flat.shape
    assert float(jnp.linalg.norm(grads)) > 0
    assert bool(jnp.isfinite(loss))


def test_sgd_update_formula():
    p = jnp.arange(8.0)
    g = jnp.ones(8)
    (new,) = model.sgd_update(p, g, jnp.float32(0.5))
    np.testing.assert_allclose(np.asarray(new), np.arange(8.0) - 0.5)


def _structured_batch(key, cfg, noise=0.05):
    """Same affine-recurrence stream the Rust dataset generates."""
    a, c = 5, 7
    ks = jax.random.split(key, 3)
    first = jax.random.randint(ks[0], (cfg.batch, 1), 0, cfg.vocab)
    rows = [first]
    cur = first
    for _ in range(cfg.seq - 1):
        nxt = (a * cur + c) % cfg.vocab
        cur = nxt
        rows.append(nxt)
    toks = jnp.concatenate(rows, axis=1)
    flip = jax.random.bernoulli(ks[1], noise, toks.shape)
    rand = jax.random.randint(ks[2], toks.shape, 0, cfg.vocab)
    return jnp.where(flip, rand, toks)


@pytest.mark.slow
def test_training_reduces_loss():
    cfg = CFG
    flat = model.init_params(cfg, jax.random.PRNGKey(10))
    step = jax.jit(lambda p, t: model.train_step(cfg, p, t))
    key = jax.random.PRNGKey(11)
    first = None
    tail = []
    for i in range(100):
        key, sub = jax.random.split(key)
        toks = _structured_batch(sub, cfg)
        loss, grads = step(flat, toks)
        (flat,) = model.sgd_update(flat, grads, jnp.float32(cfg.lr))
        if i == 0:
            first = float(loss)
        tail.append(float(loss))
    final = float(np.mean(tail[-10:]))
    assert final < first * 0.85, f"loss did not fall: {first} -> {final}"
