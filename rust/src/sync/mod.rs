//! Synchronisation: barriers, ordering (fence/quiet), and point-to-point
//! waits — the glue of the one-sided model (§3.2).

pub mod barrier;
pub mod order;
pub mod wait;

pub use wait::CmpOp;
