//! Processing elements: the world (all PEs of a job), per-PE contexts, and
//! configuration.
//!
//! POSH runs PEs as OS processes spawned by its run-time environment (§4.7).
//! POSH-RS supports that mode (`oshrun`, [`World::attach_process`]) *and* a
//! thread mode ([`World::threads`]) where PEs are threads of one process and
//! heaps are private mappings — same code paths above the segment layer,
//! much friendlier for unit tests and benches.

pub mod config;
pub mod ctx;
pub mod remote_table;
pub mod world;

pub use config::{BarrierKind, Mode, PoshConfig, TeamBarrierKind};
pub use ctx::Ctx;
pub use world::World;
