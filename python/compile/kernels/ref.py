"""Pure-jnp oracles for every Pallas kernel (the build-time correctness
contract: pytest asserts `kernel(x) == ref(x)` across shapes and dtypes).
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Reference for the tiled matmul kernel: plain jnp.dot in f32."""
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def copy_ref(x):
    """Reference for the tiled copy kernel: identity."""
    return x


def sum_reduce_ref(parts):
    """Reference for the sharded sum-reduce kernel.

    parts: [n_shards, chunk] -> [chunk], element-wise sum over shards.
    """
    return jnp.sum(parts, axis=0)


def softmax_xent_ref(logits, targets):
    """Reference next-token cross-entropy (mean, in nats).

    logits: [N, V]; targets: [N] int32.
    """
    logp = logits - jnp.max(logits, axis=-1, keepdims=True)
    logp = logp - jnp.log(jnp.sum(jnp.exp(logp), axis=-1, keepdims=True))
    n = logits.shape[0]
    picked = logp[jnp.arange(n), targets]
    return -jnp.mean(picked)
