"""AOT pipeline tests: artifacts exist, are valid HLO text, the manifest is
flat JSON with the fields the Rust side reads, and the params image has the
advertised size."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

TINY = model.ModelConfig(
    vocab=32, d_model=16, n_heads=2, d_ff=32, n_layers=1, seq=8, batch=2
)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("arts")
    manifest = aot.build(str(out), TINY, n_shards=4, copy_mb=1)
    return str(out), manifest


def test_manifest_is_flat_json(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest
    for k, v in loaded.items():
        assert isinstance(v, (int, float, str)), f"{k} is {type(v)} — not flat"


def test_required_fields_present(built):
    _, m = built
    for field in ("param_count", "batch", "seq", "vocab", "lr",
                  "train_step", "sgd_update", "params_init", "grad_reduce"):
        assert field in m, field


def test_artifacts_are_hlo_text(built):
    out, m = built
    for key in ("train_step", "sgd_update", "grad_reduce"):
        path = os.path.join(out, m[key])
        with open(path) as f:
            text = f.read()
        assert text.lstrip().startswith("HloModule"), key
        assert "ENTRY" in text, key


def test_params_image_size(built):
    out, m = built
    raw = open(os.path.join(out, m["params_init"]), "rb").read()
    assert len(raw) == m["param_count"] * 4
    params = np.frombuffer(raw, dtype="<f4")
    assert np.isfinite(params).all()
    # Layer-norm gains land somewhere in the vector as exact 1.0s.
    assert (params == 1.0).sum() >= TINY.d_model


def test_copy_variants_exported(built):
    out, m = built
    from compile.kernels.copy import VARIANTS

    for name in VARIANTS:
        assert name in m
        assert os.path.exists(os.path.join(out, m[name]))
        assert m[f"{name}_vmem"] <= 16 << 20


def test_train_step_artifact_shapes_runnable(built):
    """Execute the lowered train_step through jax's own runtime as a final
    sanity check that the artifact's entry signature matches the manifest."""
    out, m = built
    p = m["param_count"]
    params = model.init_params(TINY, jax.random.PRNGKey(0))
    toks = jnp.zeros((m["batch"], m["seq"]), jnp.int32)
    loss, grads = model.train_step(TINY, params, toks)
    assert grads.shape == (p,)
    assert jnp.isfinite(loss)
