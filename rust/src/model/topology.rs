//! NUMA topology detection (`/sys/devices/system/node`) with a
//! parseable-from-fixture-dir API.
//!
//! The fitted channel model of §5 is NUMA-oblivious: one α/β pair per node
//! prices a cross-socket reduce like an L2-resident one. The two-level
//! collective schedules ([`crate::collectives::hierarchy`]) need to know
//! *which PEs share a socket*, and the tuning engine needs a second
//! (cross-socket) α/β tier. This module supplies the topology half:
//!
//! * [`Topology::from_sysfs_root`] parses any directory shaped like the
//!   kernel's `/sys/devices/system/node` tree (`node<N>/cpulist`), which is
//!   what the fixture trees under `rust/tests/fixtures/topology/` exercise —
//!   **no test depends on the runner's real topology**;
//! * [`Topology::detect`] reads the real tree, degrading to the flat
//!   single-socket fallback when sysfs is absent (non-Linux, sandboxes);
//! * [`Topology::pe_socket_of`] / [`Topology::pes_per_socket`] derive the
//!   *blocked* PE→socket map every PE computes identically (a pure function
//!   of `(n_pes, socket count)` — the determinism the leader-election
//!   descriptor in [`crate::symheap::layout::TeamCell`] cross-checks).
//!
//! Synthetic topologies (`oshrun --pes-per-socket N` /
//! `POSH_PES_PER_SOCKET`) bypass detection entirely: they shape the blocked
//! map directly, so the hierarchical schedules can be exercised on any
//! machine, including a single-socket CI runner.

use std::path::Path;

/// Where a [`Topology`] came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologySource {
    /// Parsed from a `/sys/devices/system/node`-shaped tree.
    Sysfs,
    /// Shaped by `POSH_PES_PER_SOCKET` / `--pes-per-socket` (no sysfs read).
    Synthetic,
    /// No usable sysfs: one flat socket.
    Flat,
}

impl std::fmt::Display for TopologySource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TopologySource::Sysfs => write!(f, "sysfs"),
            TopologySource::Synthetic => write!(f, "synthetic"),
            TopologySource::Flat => write!(f, "flat"),
        }
    }
}

/// One NUMA node: its kernel id and the CPUs it carries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NumaNode {
    /// Kernel node id (`nodeN`). Ids need not be contiguous — multi-socket
    /// boxes with memory-less or offlined nodes leave holes.
    pub id: usize,
    /// CPU ids from the node's `cpulist`, ascending.
    pub cpus: Vec<usize>,
}

/// A machine's NUMA layout: the nodes that carry CPUs, ascending by id.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// CPU-carrying nodes, ascending by kernel id.
    pub nodes: Vec<NumaNode>,
    /// Provenance of the layout.
    pub source: TopologySource,
}

impl Topology {
    /// The degenerate single-socket topology (the no-sysfs fallback).
    pub fn flat() -> Topology {
        Topology {
            nodes: vec![NumaNode { id: 0, cpus: Vec::new() }],
            source: TopologySource::Flat,
        }
    }

    /// A synthetic `sockets`-node topology (what `--pes-per-socket`
    /// ultimately shapes); `sockets` is clamped to ≥ 1.
    pub fn synthetic(sockets: usize) -> Topology {
        Topology {
            nodes: (0..sockets.max(1))
                .map(|id| NumaNode { id, cpus: Vec::new() })
                .collect(),
            source: TopologySource::Synthetic,
        }
    }

    /// Parse a `/sys/devices/system/node`-shaped directory: every entry
    /// named `node<N>` that carries a parseable, non-empty `cpulist`
    /// becomes one node. Node numbering may have holes (entries are
    /// *scanned*, not enumerated `0..n`). Returns `None` when the directory
    /// is missing or no CPU-carrying node was found — callers fall back to
    /// [`Topology::flat`].
    pub fn from_sysfs_root<P: AsRef<Path>>(root: P) -> Option<Topology> {
        let mut nodes = Vec::new();
        for entry in std::fs::read_dir(root.as_ref()).ok()? {
            let entry = entry.ok()?;
            let name = entry.file_name();
            let name = name.to_str()?;
            let id: usize = match name.strip_prefix("node") {
                Some(digits) if !digits.is_empty() => match digits.parse() {
                    Ok(id) => id,
                    Err(_) => continue, // e.g. "node_something"
                },
                _ => continue, // "possible", "online", "has_cpu", …
            };
            let cpulist = match std::fs::read_to_string(entry.path().join("cpulist")) {
                Ok(s) => s,
                Err(_) => continue, // memory-only node or malformed entry
            };
            let cpus = parse_cpulist(&cpulist);
            if cpus.is_empty() {
                continue; // CPU-less node: no PE can live there
            }
            nodes.push(NumaNode { id, cpus });
        }
        if nodes.is_empty() {
            return None;
        }
        nodes.sort_by_key(|n| n.id);
        Some(Topology { nodes, source: TopologySource::Sysfs })
    }

    /// Detect the real machine's topology, falling back to
    /// [`Topology::flat`] when `/sys/devices/system/node` is absent or
    /// unusable.
    pub fn detect() -> Topology {
        Self::from_sysfs_root("/sys/devices/system/node").unwrap_or_else(Topology::flat)
    }

    /// Number of CPU-carrying sockets (≥ 1).
    pub fn sockets(&self) -> usize {
        self.nodes.len().max(1)
    }

    /// Total CPUs across all nodes (0 for flat/synthetic layouts, which
    /// carry no cpulists).
    pub fn total_cpus(&self) -> usize {
        self.nodes.iter().map(|n| n.cpus.len()).sum()
    }

    /// The blocked PEs-per-socket count for an `n_pes`-PE job:
    /// `⌈n_pes / sockets⌉`, so PEs `[k·q, (k+1)·q)` land on socket `k`. A
    /// pure function of `(n_pes, socket count)` — every PE computes the same
    /// map with no communication.
    pub fn pes_per_socket(&self, n_pes: usize) -> usize {
        let s = self.sockets();
        ((n_pes + s - 1) / s).max(1)
    }

    /// Socket index of world rank `pe` under the blocked map (always 0 on a
    /// flat topology).
    pub fn pe_socket_of(&self, pe: usize, n_pes: usize) -> usize {
        pe / self.pes_per_socket(n_pes)
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} socket(s) [{}]", self.sockets(), self.source)?;
        for n in &self.nodes {
            if !n.cpus.is_empty() {
                write!(f, " node{}:{}cpus", n.id, n.cpus.len())?;
            }
        }
        Ok(())
    }
}

/// Parse a kernel cpulist ("0-7,16-23", "0", "1,3,5"); malformed pieces are
/// skipped, the result is ascending and deduplicated.
pub fn parse_cpulist(s: &str) -> Vec<usize> {
    let mut cpus = Vec::new();
    for piece in s.trim().split(',') {
        let piece = piece.trim();
        if piece.is_empty() {
            continue;
        }
        match piece.split_once('-') {
            Some((lo, hi)) => {
                if let (Ok(lo), Ok(hi)) = (lo.trim().parse::<usize>(), hi.trim().parse::<usize>())
                {
                    if lo <= hi && hi - lo < 1 << 20 {
                        cpus.extend(lo..=hi);
                    }
                }
            }
            None => {
                if let Ok(c) = piece.parse::<usize>() {
                    cpus.push(c);
                }
            }
        }
    }
    cpus.sort_unstable();
    cpus.dedup();
    cpus
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpulist_forms() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0,2,4"), vec![0, 2, 4]);
        assert_eq!(parse_cpulist("0-1,8-9"), vec![0, 1, 8, 9]);
        assert_eq!(parse_cpulist(" 5 "), vec![5]);
        assert_eq!(parse_cpulist("3,1,1,2"), vec![1, 2, 3]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist("x,7,3-"), vec![7]);
        // Inverted range is skipped, not panicked on.
        assert_eq!(parse_cpulist("9-3,1"), vec![1]);
    }

    #[test]
    fn flat_fallback_shape() {
        let t = Topology::flat();
        assert_eq!(t.sockets(), 1);
        assert_eq!(t.source, TopologySource::Flat);
        assert_eq!(t.pes_per_socket(8), 8);
        for pe in 0..8 {
            assert_eq!(t.pe_socket_of(pe, 8), 0);
        }
    }

    #[test]
    fn synthetic_blocked_map() {
        let t = Topology::synthetic(2);
        assert_eq!(t.sockets(), 2);
        assert_eq!(t.pes_per_socket(4), 2);
        assert_eq!(
            (0..4).map(|pe| t.pe_socket_of(pe, 4)).collect::<Vec<_>>(),
            vec![0, 0, 1, 1]
        );
        // Ragged division: 5 PEs over 2 sockets → q = 3 → [0,0,0,1,1].
        assert_eq!(
            (0..5).map(|pe| t.pe_socket_of(pe, 5)).collect::<Vec<_>>(),
            vec![0, 0, 0, 1, 1]
        );
    }

    #[test]
    fn missing_root_is_none() {
        assert!(Topology::from_sysfs_root("/nonexistent/posh/topology").is_none());
        // detect() must never panic, whatever the runner looks like.
        let t = Topology::detect();
        assert!(t.sockets() >= 1);
    }

    #[test]
    fn detect_matches_fixture_shape_contract() {
        // Build a throwaway fixture in a temp dir: 2 sockets + 1 memory-only
        // node + 1 non-node entry, and check the parser's filtering rules
        // without touching the runner's real /sys.
        let dir = std::env::temp_dir().join(format!("posh-topo-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        for (node, list) in [("node0", "0-3"), ("node2", "4-7")] {
            std::fs::create_dir_all(dir.join(node)).unwrap();
            std::fs::write(dir.join(node).join("cpulist"), list).unwrap();
        }
        std::fs::create_dir_all(dir.join("node1")).unwrap(); // no cpulist
        std::fs::create_dir_all(dir.join("possible")).unwrap();
        let t = Topology::from_sysfs_root(&dir).unwrap();
        assert_eq!(t.sockets(), 2);
        assert_eq!(t.nodes[0].id, 0);
        assert_eq!(t.nodes[1].id, 2); // hole at node1 preserved by id
        assert_eq!(t.total_cpus(), 8);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
